"""Layer-1 Pallas kernel for truncated signatures via Horner's algorithm
(paper Algorithm 2).

One program instance per path (grid over the batch). The signature lives in
a single flat VMEM vector — the paper's design choice (1) — and each path
step applies the Horner factorisation with a static Python loop over levels
(the truncation level N is a compile-time constant, so the loop unrolls into
straight-line VPU code; the outer product ``B ⊗ z`` maps to a rank-1
broadcast-multiply on the vector unit).

TPU note: the natural layout puts the fastest-varying tensor index in the
lane dimension; the flat level-k block of size d^k is contiguous, so the
broadcast multiply is lane-parallel. VMEM footprint is
sig_length(d, N) + d^{N-1} + L·d floats per instance — e.g. (L=1024, d=5,
N=6): ~19.5k + 3.1k + 5.1k ≈ 28k f32 ≈ 110 KiB, comfortably inside VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import level_offsets, sig_length


def _exp_flat(z: jnp.ndarray, depth: int, dim: int) -> jnp.ndarray:
    """Flat tensor exponential (1, z, z⊗²/2!, ...)."""
    parts = [jnp.ones((1,), z.dtype), z]
    cur = z
    for k in range(2, depth + 1):
        cur = (cur[:, None] * z[None, :]).reshape(-1) / k
        parts.append(cur)
    return jnp.concatenate(parts)


def _horner_step(a: jnp.ndarray, z: jnp.ndarray, depth: int, dim: int, offs) -> jnp.ndarray:
    """One Chen step A <- A ⊗ exp(z) by Horner (Algorithm 2), on the flat
    signature vector."""
    for k in range(depth, 1, -1):
        b = z / k
        for i in range(1, k - 1):
            b = b + jax.lax.dynamic_slice(a, (offs[i],), (offs[i + 1] - offs[i],))
            b = (b[:, None] * (z / (k - i))[None, :]).reshape(-1)
        b = b + jax.lax.dynamic_slice(a, (offs[k - 1],), (offs[k] - offs[k - 1],))
        ak = jax.lax.dynamic_slice(a, (offs[k],), (offs[k + 1] - offs[k],))
        ak = ak + (b[:, None] * z[None, :]).reshape(-1)
        a = jax.lax.dynamic_update_slice(a, ak, (offs[k],))
    a1 = jax.lax.dynamic_slice(a, (offs[1],), (dim,)) + z
    return jax.lax.dynamic_update_slice(a, a1, (offs[1],))


def _sig_kernel_body(path_ref, out_ref, *, depth: int):
    path = path_ref[0]  # [L, d]
    length, dim = path.shape
    offs = level_offsets(dim, depth)
    zs = path[1:] - path[:-1]  # [L-1, d]
    a0 = _exp_flat(zs[0], depth, dim)

    def step(l, a):
        return _horner_step(a, zs[l], depth, dim, offs)

    a = jax.lax.fori_loop(1, length - 1, step, a0)
    out_ref[0] = a


@functools.partial(jax.jit, static_argnames=("depth",))
def signature_pallas(paths: jnp.ndarray, depth: int) -> jnp.ndarray:
    """Batched truncated signatures: ``[B, L, d]`` -> ``[B, sig_length]``."""
    batch, length, dim = paths.shape
    slen = sig_length(dim, depth)
    return pl.pallas_call(
        functools.partial(_sig_kernel_body, depth=depth),
        grid=(batch,),
        in_specs=[pl.BlockSpec((1, length, dim), lambda b: (b, 0, 0))],
        out_specs=pl.BlockSpec((1, slen), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, slen), paths.dtype),
        interpret=True,
    )(paths)
