"""Layer-1 Pallas kernels for the signature-kernel Goursat PDE.

TPU adaptation of the paper's CUDA scheme (§3.3) — see DESIGN.md
§Hardware-Adaptation:

* one *program instance* per batch pair (CUDA: one thread block per pair);
* the anti-diagonal is a VMEM *vector*, updated by fused VPU ops (CUDA:
  32 threads of a warp, one per entry);
* only the current anti-diagonal and the two before it are live, rotated
  through the ``fori_loop`` carry (CUDA: three shared-memory buffers);
* the Δ precompute is a batched matmul on the MXU (CUDA: cuBLAS).

Kernels are lowered with ``interpret=True``: the CPU PJRT plugin cannot run
Mosaic custom-calls, so correctness is validated on CPU and real-TPU
performance is estimated from the VMEM/MXU model in DESIGN.md.

The backward kernel implements Algorithm 4 (the paper's exact-gradient
scheme): one reverse wavefront computing the adjoint d1 and scattering
∂F/∂Δ per refined cell.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _wavefront(delta, lam1: int, lam2: int):
    """Forward anti-diagonal sweep. ``delta``: [m, n]. Returns (kRC, diags).

    ``diags`` stacks every anti-diagonal (indexed by row i), so the stored
    grid is recovered as k[i, j] = diags[i + j, i]; the backward kernel reads
    it in the same diagonal form it was produced.
    """
    m, n = delta.shape
    rows, cols = m << lam1, n << lam2
    scale = 1.0 / (1 << (lam1 + lam2))
    idx = jnp.arange(rows + 1)

    # Cell lookup for node (i, j): p = Δ[(i-1) >> λ1, (j-1) >> λ2] · scale.
    def p_for_diag(mdiag):
        j = mdiag - idx
        ci = jnp.clip((idx - 1) >> lam1, 0, m - 1)
        cj = jnp.clip((j - 1) >> lam2, 0, n - 1)
        return delta[ci, cj] * scale, j

    ones = jnp.ones(rows + 1, delta.dtype)

    def body(mdiag, carry):
        prev2, prev, diags = carry
        p, j = p_for_diag(mdiag)
        a = 1.0 + 0.5 * p + p * p / 12.0
        b = 1.0 - p * p / 12.0
        prev_im1 = jnp.concatenate([jnp.ones((1,), delta.dtype), prev[:-1]])
        prev2_im1 = jnp.concatenate([jnp.ones((1,), delta.dtype), prev2[:-1]])
        val = (prev_im1 + prev) * a - prev2_im1 * b
        boundary = (idx == 0) | (j <= 0) | (j > cols) | (idx > rows)
        cur = jnp.where(boundary, 1.0, val)
        diags = jax.lax.dynamic_update_index_in_dim(diags, cur, mdiag, 0)
        return prev2, prev, diags  # rotated below

    def rotated(mdiag, carry):
        prev2, prev, diags = carry
        _, _, diags = body(mdiag, (prev2, prev, diags))
        cur = diags[mdiag]
        return prev, cur, diags

    diags0 = jnp.ones((rows + cols + 1, rows + 1), delta.dtype)
    carry = (ones, ones, diags0)  # diag -1 (dummy), diag 0 (all boundary = 1)
    carry = jax.lax.fori_loop(1, rows + cols + 1, rotated, carry)
    diags = carry[2]
    return diags[rows + cols, rows], diags


def _sweep_light(delta, lam1: int, lam2: int):
    """Forward sweep keeping only the three rotating diagonals (the exact
    shared-memory footprint of the paper's CUDA kernel)."""
    m, n = delta.shape
    rows, cols = m << lam1, n << lam2
    scale = 1.0 / (1 << (lam1 + lam2))
    idx = jnp.arange(rows + 1)
    ones = jnp.ones(rows + 1, delta.dtype)

    def body(mdiag, carry):
        prev2, prev = carry
        j = mdiag - idx
        ci = jnp.clip((idx - 1) >> lam1, 0, m - 1)
        cj = jnp.clip((j - 1) >> lam2, 0, n - 1)
        p = delta[ci, cj] * scale
        a = 1.0 + 0.5 * p + p * p / 12.0
        b = 1.0 - p * p / 12.0
        prev_im1 = jnp.concatenate([jnp.ones((1,), delta.dtype), prev[:-1]])
        prev2_im1 = jnp.concatenate([jnp.ones((1,), delta.dtype), prev2[:-1]])
        val = (prev_im1 + prev) * a - prev2_im1 * b
        boundary = (idx == 0) | (j <= 0) | (j > cols)
        cur = jnp.where(boundary, 1.0, val)
        return prev, cur

    _, last = jax.lax.fori_loop(1, rows + cols + 1, body, (ones, ones))
    return last[rows]


def _fwd_kernel(delta_ref, out_ref, *, lam1: int, lam2: int):
    delta = delta_ref[0]
    out_ref[0] = _sweep_light(delta, lam1, lam2)


@functools.partial(jax.jit, static_argnames=("lam1", "lam2"))
def sig_kernel_pallas(delta: jnp.ndarray, lam1: int = 0, lam2: int = 0):
    """Batched signature-kernel PDE solve: Δ ``[B, m, n]`` -> k ``[B]``."""
    batch, m, n = delta.shape
    return pl.pallas_call(
        functools.partial(_fwd_kernel, lam1=lam1, lam2=lam2),
        grid=(batch,),
        in_specs=[pl.BlockSpec((1, m, n), lambda b: (b, 0, 0))],
        out_specs=pl.BlockSpec((1,), lambda b: (b,)),
        out_shape=jax.ShapeDtypeStruct((batch,), delta.dtype),
        interpret=True,
    )(delta)


def _bwd_kernel(delta_ref, gout_ref, d2_ref, *, lam1: int, lam2: int):
    """Algorithm 4: reverse wavefront -> exact ∂F/∂Δ for one pair."""
    delta = delta_ref[0]
    gout = gout_ref[0]
    m, n = delta.shape
    rows, cols = m << lam1, n << lam2
    scale = 1.0 / (1 << (lam1 + lam2))
    _, kdiags = _wavefront(delta, lam1, lam2)  # k[i,j] = kdiags[i+j, i]
    idx = jnp.arange(rows + 1)

    def p_at(ci, cj):
        # p for cell (ci, cj), with masking handled by callers.
        cci = jnp.clip(ci >> lam1, 0, m - 1)
        ccj = jnp.clip(cj >> lam2, 0, n - 1)
        return delta[cci, ccj] * scale

    def body(step, carry):
        # step counts down: diagonal mdiag = rows + cols - step.
        next1, next2, d2 = carry
        mdiag = rows + cols - step
        j = mdiag - idx
        interior = (idx >= 1) & (j >= 1) & (idx <= rows) & (j <= cols)
        # d1[i,j] = d1[i+1,j]·A(p_{i,j-1}) + d1[i,j+1]·A(p_{i-1,j})
        #         − d1[i+1,j+1]·B(p_{i,j})  (+ gout at the terminal node).
        n1_ip1 = jnp.concatenate([next1[1:], jnp.zeros((1,), delta.dtype)])
        n2_ip1 = jnp.concatenate([next2[1:], jnp.zeros((1,), delta.dtype)])
        p_r = p_at(idx, j - 1)  # cell (i, j-1) feeding node (i+1, j)
        p_d = p_at(idx - 1, j)  # cell (i-1, j) feeding node (i, j+1)
        p_c = p_at(idx, j)  # cell (i, j) feeding node (i+1, j+1)
        a_r = 1.0 + 0.5 * p_r + p_r * p_r / 12.0
        a_d = 1.0 + 0.5 * p_d + p_d * p_d / 12.0
        b_c = 1.0 - p_c * p_c / 12.0
        term1 = jnp.where(idx < rows, n1_ip1 * a_r, 0.0)
        term2 = jnp.where(j < cols, next1 * a_d, 0.0)
        term3 = jnp.where((idx < rows) & (j < cols), n2_ip1 * b_c, 0.0)
        val = term1 + term2 - term3
        val = val + jnp.where((idx == rows) & (j == cols), gout, 0.0)
        d1 = jnp.where(interior, val, 0.0)
        # ∂F/∂Δ for cell (i-1, j-1) whose output node is (i, j):
        # d1[i,j]·[(k[i,j-1] + k[i-1,j])·A'(p) − k[i-1,j-1]·B'(p)]·scale.
        p = p_at(idx - 1, j - 1)
        k_l = kdiags[jnp.clip(mdiag - 1, 0, rows + cols), idx]  # k[i, j-1]
        k_u = kdiags[
            jnp.clip(mdiag - 1, 0, rows + cols), jnp.clip(idx - 1, 0, rows)
        ]  # k[i-1, j]
        k_ul = kdiags[
            jnp.clip(mdiag - 2, 0, rows + cols), jnp.clip(idx - 1, 0, rows)
        ]  # k[i-1, j-1]
        dk_dp = (k_l + k_u) * (0.5 + p / 6.0) + k_ul * (p / 6.0)
        contrib = jnp.where(interior, d1 * dk_dp * scale, 0.0)
        ci = jnp.clip((idx - 1) >> lam1, 0, m - 1)
        cj = jnp.clip((j - 1) >> lam2, 0, n - 1)
        flat = ci * n + cj
        d2 = d2.at[flat].add(contrib)
        return next1, d1, d2  # rotate: next2 <- next1 <- d1... see swap below

    def rotated(step, carry):
        next1, next2, d2 = carry
        _, d1, d2 = body(step, (next1, next2, d2))
        return d1, next1, d2

    zeros = jnp.zeros(rows + 1, delta.dtype)
    d2 = jnp.zeros(m * n, delta.dtype)
    carry = (zeros, zeros, d2)  # diagonals beyond the terminal are 0
    carry = jax.lax.fori_loop(0, rows + cols - 1, rotated, carry)
    d2_ref[0] = carry[2].reshape(m, n)


@functools.partial(jax.jit, static_argnames=("lam1", "lam2"))
def sig_kernel_vjp_pallas(delta: jnp.ndarray, gout: jnp.ndarray, lam1: int = 0, lam2: int = 0):
    """Batched exact ∂F/∂Δ: Δ ``[B,m,n]``, ∂F/∂k ``[B]`` -> ``[B,m,n]``."""
    batch, m, n = delta.shape
    return pl.pallas_call(
        functools.partial(_bwd_kernel, lam1=lam1, lam2=lam2),
        grid=(batch,),
        in_specs=[
            pl.BlockSpec((1, m, n), lambda b: (b, 0, 0)),
            pl.BlockSpec((1,), lambda b: (b,)),
        ],
        out_specs=pl.BlockSpec((1, m, n), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, m, n), delta.dtype),
        interpret=True,
    )(delta, gout)
