"""Pure-jnp reference oracles for the Pallas kernels.

Everything here is written for clarity, not speed: naive level-by-level
tensor products for signatures and a plain double loop (via ``lax.scan``)
for the Goursat PDE. These are the correctness anchors — the Pallas kernels
in this package and the Rust native implementations are both validated
against them (the latter through golden values exported by the test suite).

All functions are differentiable with ``jax.grad``, which gives reference
gradients for the custom-vjp wiring in ``model.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def level_offsets(dim: int, depth: int) -> list[int]:
    """Flat offsets of levels 0..depth (+ total) for dimension ``dim``."""
    offs = [0]
    size = 1
    for _ in range(depth + 1):
        offs.append(offs[-1] + size)
        size *= dim
    return offs


def sig_length(dim: int, depth: int) -> int:
    """Flat signature length including the scalar level."""
    return level_offsets(dim, depth)[-1]


def exp_increment(z: jnp.ndarray, depth: int) -> list[jnp.ndarray]:
    """Tensor exponential of a level-1 increment, as a list of levels."""
    levels = [jnp.ones(()), z]
    for k in range(2, depth + 1):
        levels.append(jnp.tensordot(levels[-1], z, axes=0) / k)
    return levels


def tensor_prod_levels(a: list[jnp.ndarray], b: list[jnp.ndarray], depth: int):
    """Truncated tensor-algebra product of two level lists."""
    out = []
    for n_ in range(depth + 1):
        acc = jnp.zeros((a[1].shape[0],) * n_) if n_ > 0 else jnp.zeros(())
        for i in range(n_ + 1):
            term = jnp.tensordot(a[i], b[n_ - i], axes=0)
            acc = acc + term.reshape(acc.shape) if n_ > 0 else acc + term
        out.append(acc)
    return out


def signature_ref(path: jnp.ndarray, depth: int) -> jnp.ndarray:
    """Truncated signature of one path ``[L, d]`` -> flat ``[sig_length]``.

    Naive Chen products of segment exponentials.
    """
    length, dim = path.shape
    z0 = path[1] - path[0]
    levels = exp_increment(z0, depth)
    for step in range(1, length - 1):
        z = path[step + 1] - path[step]
        levels = tensor_prod_levels(levels, exp_increment(z, depth), depth)
    flat = [lv.reshape(-1) for lv in levels]
    flat[0] = jnp.ones((1,))
    return jnp.concatenate(flat)


def signature_batch_ref(paths: jnp.ndarray, depth: int) -> jnp.ndarray:
    """Batched [B, L, d] -> [B, sig_length]."""
    return jax.vmap(lambda p: signature_ref(p, depth))(paths)


def solve_pde_ref(
    delta: jnp.ndarray, lam1: int = 0, lam2: int = 0
) -> jnp.ndarray:
    """Goursat PDE terminal value from the increment-product matrix ``[m, n]``.

    Row-by-row scan; within a row the recurrence is a sequential carry, also
    a scan. Differentiable, dyadic refinement applied by index arithmetic.
    """
    m, n = delta.shape
    rows, cols = m << lam1, n << lam2
    scale = 1.0 / (1 << (lam1 + lam2))

    t_idx = jnp.arange(cols) >> lam2  # cell column -> delta column

    def row_step(prev_row, s):
        drow = delta[s >> lam1]  # [n]
        p = drow[t_idx] * scale  # [cols]
        a = 1.0 + 0.5 * p + p * p / 12.0
        b = 1.0 - p * p / 12.0

        def cell(kleft, t):
            v = (kleft + prev_row[t + 1]) * a[t] - prev_row[t] * b[t]
            return v, v

        _, new_tail = jax.lax.scan(cell, jnp.asarray(1.0), jnp.arange(cols))
        new_row = jnp.concatenate([jnp.ones((1,)), new_tail])
        return new_row, None

    init = jnp.ones(cols + 1)
    final_row, _ = jax.lax.scan(row_step, init, jnp.arange(rows))
    return final_row[-1]


def delta_ref(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Increment inner-product matrix of two paths [Lx,d], [Ly,d]."""
    dx = x[1:] - x[:-1]
    dy = y[1:] - y[:-1]
    return dx @ dy.T


def sig_kernel_ref(
    x: jnp.ndarray, y: jnp.ndarray, lam1: int = 0, lam2: int = 0
) -> jnp.ndarray:
    """Signature kernel k(x, y) of two paths."""
    return solve_pde_ref(delta_ref(x, y), lam1, lam2)


def sig_kernel_batch_ref(x, y, lam1: int = 0, lam2: int = 0):
    """Paired batch [B,Lx,d] x [B,Ly,d] -> [B]."""
    return jax.vmap(lambda a, b: sig_kernel_ref(a, b, lam1, lam2))(x, y)


def gram_ref(x, y, lam1: int = 0, lam2: int = 0):
    """Gram matrix [Bx, By]."""
    return jax.vmap(
        lambda a: jax.vmap(lambda b: sig_kernel_ref(a, b, lam1, lam2))(y)
    )(x)


def truncated_kernel_ref(x, y, depth: int):
    """<S(x), S(y)> truncated at ``depth`` — series check for the PDE."""
    return jnp.dot(signature_ref(x, depth), signature_ref(y, depth))


def time_augment_ref(path: jnp.ndarray) -> jnp.ndarray:
    """Append a uniform time channel."""
    length = path.shape[0]
    t = jnp.linspace(0.0, 1.0, length)[:, None]
    return jnp.concatenate([path, t], axis=1)


def lead_lag_ref(path: jnp.ndarray) -> jnp.ndarray:
    """Lead-lag transform: [L, d] -> [2L-1, 2d]."""
    length = path.shape[0]
    idx = jnp.arange(2 * length - 1)
    lead = path[(idx + 1) // 2]
    lag = path[idx // 2]
    return jnp.concatenate([lead, lag], axis=1)
