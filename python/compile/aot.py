"""AOT lowering: jit → StableHLO → XLA HLO **text** under artifacts/.

HLO text (not ``.serialize()``) is the interchange format: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Each artifact gets a sidecar line in ``artifacts/manifest.txt``:
    name|input0_shape,input1_shape,...|output_dtype
so the Rust runtime can validate shapes before dispatch.

Usage: python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


# Artifact registry: name -> (callable, example specs). Shapes are the
# serving shapes the Rust coordinator batches to (see coordinator::batcher).
def registry():
    f = jnp.float32
    return {
        # Paired signature-kernel batch: x[B,L,d], y[B,L,d] -> k[B].
        "sigkernel_b8_l16_d3": (
            lambda x, y: (model.sig_kernel_batch(x, y, 0, 0),),
            [spec(8, 16, 3, dtype=f), spec(8, 16, 3, dtype=f)],
        ),
        # Exact kernel vjp bundled with the forward (value, grad_x, grad_y).
        "sigkernel_vjp_b4_l16_d3": (
            lambda x, y: _kernel_value_and_grads(x, y),
            [spec(4, 16, 3, dtype=f), spec(4, 16, 3, dtype=f)],
        ),
        # Truncated signatures: paths[B,L,d] -> sig[B,S].
        "signature_b8_l32_d2_n4": (
            lambda p: (model.signature_batch(p, 4),),
            [spec(8, 32, 2, dtype=f)],
        ),
        # Lead-lag signature featuriser.
        "signature_leadlag_b8_l16_d2_n3": (
            lambda p: (model.signature_batch_leadlag(p, 3),),
            [spec(8, 16, 2, dtype=f)],
        ),
        # MMD² loss + generator gradient — the e2e training step core.
        "mmd2_grad_b4_l12_d2": (
            lambda x, y: model.mmd2_loss_and_grad(x, y, 0, 0),
            [spec(4, 12, 2, dtype=f), spec(4, 12, 2, dtype=f)],
        ),
    }


def _kernel_value_and_grads(x, y):
    k = model.sig_kernel_batch(x, y, 0, 0)
    # Sum-of-kernels cotangent: per-pair unit gradients.
    gx, gy = jax.grad(
        lambda xx, yy: model.sig_kernel_batch(xx, yy, 0, 0).sum(), argnums=(0, 1)
    )(x, y)
    return k, gx, gy


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="lower a single artifact")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    manifest = []
    for name, (fn, specs) in registry().items():
        if args.only and name != args.only:
            continue
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as fh:
            fh.write(text)
        shapes = ",".join("x".join(map(str, s.shape)) for s in specs)
        manifest.append(f"{name}|{shapes}|f32")
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as fh:
        fh.write("\n".join(manifest) + "\n")
    print(f"manifest: {len(manifest)} artifacts")


if __name__ == "__main__":
    main()
