"""Layer-2 JAX model: batched signature / signature-kernel computations with
the Pallas kernels on the hot spots, path transformations, and the
signature-kernel MMD loss head used by the end-to-end driver.

This module is build-time only: `aot.py` lowers the jitted entry points to
HLO text once; the Rust runtime executes the artifacts via PJRT and Python
never appears on the request path.

The kernel vjp is wired with ``jax.custom_vjp``: the forward pass is the
Pallas wavefront solver, the backward pass is the Pallas Algorithm-4 kernel
(exact gradients), chained to the paths with two einsum contractions (MXU)
and a difference-adjoint scatter.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels.sigkernel import sig_kernel_pallas, sig_kernel_vjp_pallas
from .kernels.signature import signature_pallas


# ---------------------------------------------------------------------------
# Path transformations (paper §4)
# ---------------------------------------------------------------------------

def time_augment(paths: jnp.ndarray) -> jnp.ndarray:
    """[B, L, d] -> [B, L, d+1], uniform time channel in [0, 1]."""
    b, length, _ = paths.shape
    t = jnp.broadcast_to(jnp.linspace(0.0, 1.0, length)[None, :, None], (b, length, 1))
    return jnp.concatenate([paths, t.astype(paths.dtype)], axis=2)


def lead_lag(paths: jnp.ndarray) -> jnp.ndarray:
    """[B, L, d] -> [B, 2L-1, 2d] lead-lag transform."""
    length = paths.shape[1]
    idx = jnp.arange(2 * length - 1)
    lead = paths[:, (idx + 1) // 2, :]
    lag = paths[:, idx // 2, :]
    return jnp.concatenate([lead, lag], axis=2)


# ---------------------------------------------------------------------------
# Signature kernel with exact custom vjp
# ---------------------------------------------------------------------------

def _delta_batch(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Δ[b,i,j] = <dx_i, dy_j> — one batched matmul (MXU)."""
    dx = x[:, 1:] - x[:, :-1]
    dy = y[:, 1:] - y[:, :-1]
    return jnp.einsum("bid,bjd->bij", dx, dy)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def sig_kernel_batch(x: jnp.ndarray, y: jnp.ndarray, lam1: int = 0, lam2: int = 0):
    """Paired signature kernels k(x_b, y_b): [B,Lx,d] × [B,Ly,d] -> [B]."""
    return sig_kernel_pallas(_delta_batch(x, y), lam1, lam2)


def _sk_fwd(x, y, lam1, lam2):
    return sig_kernel_batch(x, y, lam1, lam2), (x, y)


def _sk_bwd(lam1, lam2, res, gk):
    x, y = res
    delta = _delta_batch(x, y)
    d2 = sig_kernel_vjp_pallas(delta, gk, lam1, lam2)  # [B, m, n]
    dx = x[:, 1:] - x[:, :-1]
    dy = y[:, 1:] - y[:, :-1]
    gdx = jnp.einsum("bij,bjd->bid", d2, dy)
    gdy = jnp.einsum("bij,bid->bjd", d2, dx)
    gx = jnp.zeros_like(x).at[:, 1:].add(gdx).at[:, :-1].add(-gdx)
    gy = jnp.zeros_like(y).at[:, 1:].add(gdy).at[:, :-1].add(-gdy)
    return gx, gy


sig_kernel_batch.defvjp(_sk_fwd, _sk_bwd)


def sig_kernel_gram(x: jnp.ndarray, y: jnp.ndarray, lam1: int = 0, lam2: int = 0):
    """Gram matrix [Bx, By] of pairwise signature kernels.

    Materialises the pair batch and reuses the paired kernel, so the whole
    Gram shares one Pallas dispatch — the batch dimension is what keeps the
    device busy (paper §3.3: blocks of different kernels run asynchronously).
    """
    bx, lx, d = x.shape
    by, ly, _ = y.shape
    xr = jnp.repeat(x, by, axis=0)  # [Bx*By, Lx, d]
    yr = jnp.tile(y, (bx, 1, 1))  # [Bx*By, Ly, d]
    return sig_kernel_batch(xr, yr, lam1, lam2).reshape(bx, by)


def mmd2_loss(x: jnp.ndarray, y: jnp.ndarray, lam1: int = 0, lam2: int = 0):
    """Biased signature-kernel MMD²: the training loss for generative models
    on time series (the paper's headline application)."""
    kxx = sig_kernel_gram(x, x, lam1, lam2)
    kxy = sig_kernel_gram(x, y, lam1, lam2)
    kyy = sig_kernel_gram(y, y, lam1, lam2)
    return kxx.mean() - 2.0 * kxy.mean() + kyy.mean()


def mmd2_loss_and_grad(x: jnp.ndarray, y: jnp.ndarray, lam1: int = 0, lam2: int = 0):
    """(loss, ∂loss/∂x) — the generator-training step's compute core."""
    return jax.value_and_grad(lambda xx: mmd2_loss(xx, y, lam1, lam2))(x)


# ---------------------------------------------------------------------------
# Truncated signatures
# ---------------------------------------------------------------------------

def signature_batch(paths: jnp.ndarray, depth: int) -> jnp.ndarray:
    """Batched truncated signature (Pallas Horner kernel): [B,L,d] -> [B,S]."""
    return signature_pallas(paths, depth)


def signature_batch_leadlag(paths: jnp.ndarray, depth: int) -> jnp.ndarray:
    """Signature of the lead-lag-transformed batch (financial featuriser)."""
    return signature_pallas(lead_lag(paths), depth)
