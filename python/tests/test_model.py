"""L2 model tests: custom_vjp wiring vs jax.grad of the oracle, transforms,
MMD properties."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import ref


def brownian_batch(seed, b, length, dim, scale=0.5):
    rng = np.random.default_rng(seed)
    steps = rng.normal(size=(b, length - 1, dim)) * scale
    paths = np.concatenate([np.zeros((b, 1, dim)), np.cumsum(steps, axis=1)], axis=1)
    return jnp.asarray(paths)


def test_sig_kernel_batch_matches_ref():
    x = brownian_batch(1, 3, 6, 2)
    y = brownian_batch(2, 3, 8, 2)
    got = model.sig_kernel_batch(x, y, 0, 0)
    want = ref.sig_kernel_batch_ref(x, y)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-10)


def test_custom_vjp_matches_autodiff_of_ref():
    """grad through the Pallas custom_vjp == grad through the jnp oracle."""
    x = brownian_batch(3, 2, 5, 2)
    y = brownian_batch(4, 2, 5, 2)

    def loss_pallas(xx):
        return model.sig_kernel_batch(xx, y, 0, 0).sum()

    def loss_ref(xx):
        return ref.sig_kernel_batch_ref(xx, y).sum()

    gp = jax.grad(loss_pallas)(x)
    gr = jax.grad(loss_ref)(x)
    np.testing.assert_allclose(np.asarray(gp), np.asarray(gr), atol=1e-9)


def test_custom_vjp_y_gradient():
    x = brownian_batch(5, 2, 4, 2)
    y = brownian_batch(6, 2, 6, 2)
    gp = jax.grad(lambda yy: model.sig_kernel_batch(x, yy, 0, 0).sum())(y)
    gr = jax.grad(lambda yy: ref.sig_kernel_batch_ref(x, yy).sum())(y)
    np.testing.assert_allclose(np.asarray(gp), np.asarray(gr), atol=1e-9)


def test_custom_vjp_with_dyadic_refinement():
    x = brownian_batch(7, 2, 4, 2)
    y = brownian_batch(8, 2, 4, 2)
    gp = jax.grad(lambda xx: model.sig_kernel_batch(xx, y, 1, 1).sum())(x)
    gr = jax.grad(lambda xx: ref.sig_kernel_batch_ref(xx, y, 1, 1).sum())(x)
    np.testing.assert_allclose(np.asarray(gp), np.asarray(gr), atol=1e-9)


def test_gram_matches_pairwise():
    x = brownian_batch(9, 3, 5, 2)
    y = brownian_batch(10, 2, 5, 2)
    g = model.sig_kernel_gram(x, y)
    want = ref.gram_ref(x, y)
    np.testing.assert_allclose(np.asarray(g), np.asarray(want), rtol=1e-10)


def test_mmd_identical_is_zero():
    x = brownian_batch(11, 4, 5, 2)
    m = model.mmd2_loss(x, x)
    assert abs(float(m)) < 1e-10


def test_mmd_grad_runs_and_matches_ref():
    x = brownian_batch(12, 3, 4, 2)
    y = brownian_batch(13, 3, 4, 2)
    val, grad = model.mmd2_loss_and_grad(x, y)

    def mmd_ref(xx):
        kxx = ref.gram_ref(xx, xx)
        kxy = ref.gram_ref(xx, y)
        kyy = ref.gram_ref(y, y)
        return kxx.mean() - 2 * kxy.mean() + kyy.mean()

    want_val = mmd_ref(x)
    want_grad = jax.grad(mmd_ref)(x)
    np.testing.assert_allclose(float(val), float(want_val), rtol=1e-10)
    np.testing.assert_allclose(np.asarray(grad), np.asarray(want_grad), atol=1e-9)


def test_transforms_match_ref():
    x = brownian_batch(14, 2, 6, 2)
    ta = model.time_augment(x)
    ll = model.lead_lag(x)
    for i in range(2):
        np.testing.assert_allclose(
            np.asarray(ta[i]), np.asarray(ref.time_augment_ref(x[i])), atol=1e-12
        )
        np.testing.assert_allclose(
            np.asarray(ll[i]), np.asarray(ref.lead_lag_ref(x[i])), atol=1e-12
        )


def test_signature_batch_leadlag_composition():
    x = brownian_batch(15, 2, 5, 2)
    got = model.signature_batch_leadlag(x, 3)
    want = ref.signature_batch_ref(model.lead_lag(x), 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-10)
