import jax

# f64 everywhere in tests: the oracles are compared against each other and
# against finite differences, where f32 noise would mask real bugs.
jax.config.update("jax_enable_x64", True)
