"""Pallas Horner signature kernel vs the naive-Chen oracle."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.signature import signature_pallas


def brownian_batch(seed, b, length, dim, scale=0.5, dtype=jnp.float64):
    rng = np.random.default_rng(seed)
    steps = rng.normal(size=(b, length - 1, dim)) * scale
    paths = np.concatenate(
        [np.zeros((b, 1, dim)), np.cumsum(steps, axis=1)], axis=1
    )
    return jnp.asarray(paths, dtype=dtype)


@settings(deadline=None, max_examples=20)
@given(
    st.integers(1, 4),
    st.integers(2, 10),
    st.integers(1, 3),
    st.integers(1, 5),
    st.integers(0, 10_000),
)
def test_matches_ref(b, length, dim, depth, seed):
    paths = brownian_batch(seed, b, length, dim)
    got = signature_pallas(paths, depth)
    want = ref.signature_batch_ref(paths, depth)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-10)


def test_two_point_path_is_exponential():
    paths = jnp.array([[[0.0, 0.0], [1.0, 2.0]]])
    s = signature_pallas(paths, 3)[0]
    z = jnp.array([1.0, 2.0])
    np.testing.assert_allclose(float(s[0]), 1.0)
    np.testing.assert_allclose(np.asarray(s[1:3]), np.asarray(z))
    np.testing.assert_allclose(
        np.asarray(s[3:7]), np.asarray(jnp.outer(z, z).reshape(-1) / 2), rtol=1e-12
    )


def test_f32_close_to_f64():
    p64 = brownian_batch(3, 2, 8, 2)
    p32 = p64.astype(jnp.float32)
    s64 = signature_pallas(p64, 4)
    s32 = signature_pallas(p32, 4)
    np.testing.assert_allclose(
        np.asarray(s32), np.asarray(s64), rtol=2e-4, atol=2e-4
    )


def test_depth_one_is_total_increment():
    paths = brownian_batch(9, 3, 6, 2)
    s = signature_pallas(paths, 1)
    want = paths[:, -1] - paths[:, 0]
    np.testing.assert_allclose(np.asarray(s[:, 1:]), np.asarray(want), atol=1e-12)
