"""AOT lowering smoke tests: every registry entry lowers to parseable HLO
text with the expected entry computation."""

import jax
import jax.numpy as jnp

from compile import aot


def test_registry_nonempty():
    assert len(aot.registry()) >= 5


def test_all_entries_lower_to_hlo_text():
    for name, (fn, specs) in aot.registry().items():
        lowered = jax.jit(fn).lower(*specs)
        text = aot.to_hlo_text(lowered)
        assert "HloModule" in text, name
        assert "ENTRY" in text, name
        assert len(text) > 200, name


def test_lowered_kernel_is_executable_in_jax():
    # The lowered computation must agree with direct execution.
    name = "sigkernel_b8_l16_d3"
    fn, specs = aot.registry()[name]
    import numpy as np

    rng = np.random.default_rng(0)
    args = [jnp.asarray(rng.normal(size=s.shape), dtype=s.dtype) for s in specs]
    direct = fn(*args)
    compiled = jax.jit(fn).lower(*specs).compile()
    via_aot = compiled(*args)
    np.testing.assert_allclose(
        np.asarray(direct[0]), np.asarray(via_aot[0]), rtol=1e-5
    )
