"""Sanity checks for the pure-jnp oracle itself: algebraic identities that
hold independently of any implementation choice."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref


def brownian(key_seed, length, dim, scale=0.5):
    rng = np.random.default_rng(key_seed)
    steps = rng.normal(size=(length - 1, dim)) * scale
    return jnp.asarray(np.vstack([np.zeros((1, dim)), np.cumsum(steps, 0)]))


def test_sig_length_formula():
    assert ref.sig_length(1, 6) == 7
    assert ref.sig_length(3, 4) == 1 + 3 + 9 + 27 + 81
    assert ref.level_offsets(2, 3) == [0, 1, 3, 7, 15]


def test_linear_path_signature_is_exponential():
    path = jnp.array([[0.0, 0.0], [1.0, 2.0]])
    s = ref.signature_ref(path, 3)
    # levels: 1, z, z⊗z/2, z⊗z⊗z/6
    z = jnp.array([1.0, 2.0])
    lvl2 = (jnp.outer(z, z) / 2).reshape(-1)
    np.testing.assert_allclose(s[0], 1.0)
    np.testing.assert_allclose(s[1:3], z)
    np.testing.assert_allclose(s[3:7], lvl2, rtol=1e-12)


@settings(deadline=None, max_examples=20)
@given(
    st.integers(2, 8),
    st.integers(1, 3),
    st.integers(1, 4),
    st.integers(0, 10_000),
)
def test_chen_identity(length, dim, depth, seed):
    """S(x * y) = S(x) ⊗ S(y) — checked via concatenated paths."""
    x = brownian(seed, length, dim)
    y = brownian(seed + 1, length, dim) + x[-1]
    full = jnp.vstack([x, y[1:] + (x[-1] - y[0])])
    sx = ref.signature_ref(x, depth)
    sy = ref.signature_ref(y, depth)
    sfull = ref.signature_ref(full, depth)
    # tensor product on flat arrays, via level lists
    offs = ref.level_offsets(dim, depth)
    lx = [sx[offs[k]:offs[k + 1]].reshape((dim,) * k) for k in range(depth + 1)]
    ly = [sy[offs[k]:offs[k + 1]].reshape((dim,) * k) for k in range(depth + 1)]
    prod = ref.tensor_prod_levels(
        [l.reshape(l.shape) for l in lx], [l.reshape(l.shape) for l in ly], depth
    )
    flat = jnp.concatenate([p.reshape(-1) for p in prod])
    np.testing.assert_allclose(np.asarray(sfull), np.asarray(flat), atol=1e-9)


def test_pde_single_cell_closed_form():
    p = 0.37
    k = ref.solve_pde_ref(jnp.array([[p]]))
    want = 2 * (1 + p / 2 + p * p / 12) - (1 - p * p / 12)
    np.testing.assert_allclose(float(k), want, rtol=1e-12)


def test_pde_zero_delta_is_one():
    assert float(ref.solve_pde_ref(jnp.zeros((3, 4)))) == 1.0
    assert float(ref.solve_pde_ref(jnp.zeros((3, 4)), 2, 1)) == 1.0


@settings(deadline=None, max_examples=15)
@given(st.integers(2, 6), st.integers(1, 3), st.integers(0, 10_000))
def test_kernel_symmetry(length, dim, seed):
    x = brownian(seed, length, dim)
    y = brownian(seed + 7, length, dim)
    kxy = ref.sig_kernel_ref(x, y)
    kyx = ref.sig_kernel_ref(y, x)
    np.testing.assert_allclose(float(kxy), float(kyx), rtol=1e-12)


def test_kernel_matches_truncated_series():
    x = brownian(3, 4, 2, scale=0.2)
    y = brownian(4, 4, 2, scale=0.2)
    k = ref.sig_kernel_ref(x, y, 6, 6)
    ip = ref.truncated_kernel_ref(x, y, 10)
    np.testing.assert_allclose(float(k), float(ip), rtol=2e-3)


def test_lead_lag_shape_and_values():
    p = jnp.array([[1.0], [2.0], [3.0]])
    ll = ref.lead_lag_ref(p)
    assert ll.shape == (5, 2)
    np.testing.assert_allclose(
        np.asarray(ll),
        [[1, 1], [2, 1], [2, 2], [3, 2], [3, 3]],
    )


def test_time_augment():
    p = jnp.zeros((5, 2))
    ta = ref.time_augment_ref(p)
    assert ta.shape == (5, 3)
    np.testing.assert_allclose(np.asarray(ta[:, 2]), np.linspace(0, 1, 5))
