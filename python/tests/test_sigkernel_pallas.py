"""Pallas signature-kernel wavefront vs the pure-jnp oracle — the core L1
correctness signal — plus exact-gradient checks for the Algorithm-4 kernel."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.sigkernel import sig_kernel_pallas, sig_kernel_vjp_pallas


def rand_delta(seed, b, m, n, dtype=jnp.float64):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(b, m, n)) * 0.3, dtype=dtype)


@settings(deadline=None, max_examples=25)
@given(
    st.integers(1, 4),
    st.integers(1, 10),
    st.integers(1, 10),
    st.integers(0, 2),
    st.integers(0, 2),
    st.integers(0, 10_000),
)
def test_forward_matches_ref(b, m, n, lam1, lam2, seed):
    delta = rand_delta(seed, b, m, n)
    got = sig_kernel_pallas(delta, lam1, lam2)
    want = jnp.stack([ref.solve_pde_ref(delta[i], lam1, lam2) for i in range(b)])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-10)


def test_forward_f32_dtype():
    delta = rand_delta(0, 2, 5, 7, dtype=jnp.float32)
    got = sig_kernel_pallas(delta, 0, 0)
    assert got.dtype == jnp.float32
    want = jnp.stack([ref.solve_pde_ref(delta[i]) for i in range(2)])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


@settings(deadline=None, max_examples=15)
@given(
    st.integers(1, 3),
    st.integers(1, 6),
    st.integers(1, 6),
    st.integers(0, 1),
    st.integers(0, 1),
    st.integers(0, 10_000),
)
def test_backward_matches_jax_grad_of_ref(b, m, n, lam1, lam2, seed):
    """The Algorithm-4 Pallas kernel must equal autodiff through the oracle
    solver — this is the 'exact gradients' claim of paper §3.4."""
    delta = rand_delta(seed, b, m, n)
    gout = jnp.asarray(np.random.default_rng(seed + 1).normal(size=(b,)))
    got = sig_kernel_vjp_pallas(delta, gout, lam1, lam2)
    grad_fn = jax.grad(lambda d: ref.solve_pde_ref(d, lam1, lam2))
    want = jnp.stack([gout[i] * grad_fn(delta[i]) for i in range(b)])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-9)


def test_backward_zero_cotangent():
    delta = rand_delta(5, 2, 4, 4)
    got = sig_kernel_vjp_pallas(delta, jnp.zeros(2), 0, 0)
    np.testing.assert_allclose(np.asarray(got), 0.0)


def test_asymmetric_dyadic_orders():
    delta = rand_delta(7, 2, 3, 9)
    k = sig_kernel_pallas(delta, 3, 0)
    want = jnp.stack([ref.solve_pde_ref(delta[i], 3, 0) for i in range(2)])
    np.testing.assert_allclose(np.asarray(k), np.asarray(want), rtol=1e-10)


def test_long_stream_beyond_32_diagonal():
    # Crosses the warp-width analogue: diagonals longer than 32 entries.
    delta = rand_delta(11, 1, 40, 45)
    k = sig_kernel_pallas(delta, 0, 0)
    want = ref.solve_pde_ref(delta[0])
    np.testing.assert_allclose(float(k[0]), float(want), rtol=1e-10)
