#!/usr/bin/env python3
"""Accuracy gate for the Goursat discretisation schemes.

Compares the fresh ``bench_results/BENCH_accuracy.json`` (written by
``cargo bench --bench accuracy``) against the committed repo-root
``BENCH_accuracy.json`` and fails (exit 1) when:

* any fresh ``err_*`` value exceeds its committed ``envelope`` (every
  baseline row carrying an ``envelope`` key must be present in the fresh
  results — a silently dropped row is a failure, not a skip); or
* the headline cost/accuracy pair breaks: order-2 at the coarse dyadic
  level (``--coarse``, default 2) must be at least as accurate as order-1
  one level finer (``--fine``, default 3) within ``--slack`` (default
  1.5x), while solving STRICTLY fewer PDE cells. This is the claim that
  justifies shipping the second-order scheme: fine-grid accuracy at a
  coarser grid's cost.

``--self-test`` runs the gate's own logic against inline fixtures (one
passing, one envelope breach, one cells breach) and exits 0 only if all
three behave; CI runs it before the real comparison so a broken gate
cannot silently pass everything.
"""

import argparse
import json
import sys
from pathlib import Path


def load_cases(path: Path):
    doc = json.loads(path.read_text())
    return {c["case"]: c for c in doc.get("cases", [])}


def check(base, fresh, coarse, fine, slack):
    """Return a list of failure strings (empty = gate passes)."""
    failures = []

    def fresh_val(name):
        row = fresh.get(name)
        if row is None:
            return None
        return row.get("median_seconds")

    # 1. Committed error envelopes.
    for name, bc in sorted(base.items()):
        env = bc.get("envelope")
        if env is None:
            continue
        val = fresh_val(name)
        if val is None:
            failures.append(f"envelope row '{name}' missing from fresh results")
        elif val > env:
            failures.append(f"'{name}' = {val:.3e} exceeds the committed envelope {env:.3e}")
        else:
            print(f"  {name:24} {val:>12.3e}  <= envelope {env:.0e} OK")

    # 2. The headline pair: order-2 coarse vs order-1 fine.
    e2 = fresh_val(f"err_order2_lam{coarse}")
    e1 = fresh_val(f"err_order1_lam{fine}")
    c2 = fresh_val(f"cells_order2_lam{coarse}")
    c1 = fresh_val(f"cells_order1_lam{fine}")
    if None in (e2, e1, c2, c1):
        failures.append(
            f"headline pair rows missing (need err/cells for order2@lam{coarse} "
            f"and order1@lam{fine})"
        )
        return failures
    if e2 > slack * e1:
        failures.append(
            f"order-2 at lam{coarse} err {e2:.3e} worse than {slack}x order-1 "
            f"at lam{fine} err {e1:.3e}"
        )
    else:
        print(f"  accuracy: order2@lam{coarse} {e2:.3e} <= {slack} * order1@lam{fine} {e1:.3e} OK")
    if c2 >= c1:
        failures.append(
            f"order-2 at lam{coarse} solved {c2:.0f} cells, not strictly fewer "
            f"than order-1 at lam{fine} ({c1:.0f})"
        )
    else:
        print(f"  cost: order2@lam{coarse} {c2:.0f} cells < order1@lam{fine} {c1:.0f} OK")
    return failures


def self_test() -> int:
    def rows(**vals):
        return {k: {"case": k, "median_seconds": v, "runs": 0} for k, v in vals.items()}

    base = rows(err_order2_lam2=0.0)
    base["err_order2_lam2"]["envelope"] = 1e-3
    good = rows(
        err_order2_lam2=5e-4, err_order1_lam3=4e-4, cells_order2_lam2=42320, cells_order1_lam3=135424
    )
    bad_env = dict(good)
    bad_env.update(rows(err_order2_lam2=5e-3))
    bad_cells = dict(good)
    bad_cells.update(rows(cells_order2_lam2=200000))
    cases = [
        ("pass", good, 0),
        ("envelope breach", bad_env, 1),
        ("cells breach", bad_cells, 1),
    ]
    for label, fresh, want in cases:
        got = len(check(base, fresh, coarse=2, fine=3, slack=1.5))
        ok = (got > 0) == (want > 0)
        print(f"  self-test [{label}]: {'OK' if ok else 'BROKEN'} ({got} failure(s))")
        if not ok:
            return 1
    print("self-test passed")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", type=Path, default=Path("BENCH_accuracy.json"))
    ap.add_argument("--results", type=Path, default=Path("rust/bench_results/BENCH_accuracy.json"))
    ap.add_argument("--coarse", type=int, default=2)
    ap.add_argument("--fine", type=int, default=3)
    ap.add_argument("--slack", type=float, default=1.5)
    ap.add_argument("--self-test", action="store_true")
    args = ap.parse_args()

    if args.self_test:
        return self_test()

    if not args.baseline.is_file():
        print(f"error: no committed baseline at {args.baseline}", file=sys.stderr)
        return 1
    if not args.results.is_file():
        print(f"error: no fresh results at {args.results}", file=sys.stderr)
        return 1
    failures = check(
        load_cases(args.baseline), load_cases(args.results), args.coarse, args.fine, args.slack
    )
    if failures:
        print("\naccuracy gate FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print("\naccuracy gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
