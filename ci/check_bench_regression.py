#!/usr/bin/env python3
"""Bench-regression gate.

Compares freshly produced ``bench_results/BENCH_<suite>.json`` files
against the committed repo-root ``BENCH_<suite>.json`` baselines and
fails (exit 1) when any timed case's median regresses more than
``--threshold`` (default 1.3x) against its baseline median.

Rules:

* Every baseline file must have a matching fresh results file, and every
  timed baseline case (``runs > 0`` with a numeric median) must appear in
  the fresh results — a silently renamed or dropped case is a gate
  failure, not a skip.
* Derived rows (``runs == 0``, e.g. speedup ratios) and ``null`` medians
  (failure markers) are not timing measurements and are skipped.
* Fresh cases with no baseline are reported informationally; add them to
  the baseline when they stabilise.
* A fresh median far below baseline (< baseline/2) is flagged as
  headroom: the committed baseline is a bootstrap envelope written
  without hardware access, meant to be tightened to measured values by
  the first toolchain-equipped maintainer. For every headroom case the
  gate prints a **suggested tightened baseline** (fresh median x 1.25,
  leaving run-to-run noise margin under the 1.3x threshold) so tightening
  is a copy-paste job, not a measurement campaign.
* Tightening suggestions are only trustworthy when the fresh run's
  toolchain matches the one the baseline was measured with: a baseline
  carrying a top-level ``"toolchain"`` field that differs from the fresh
  ``toolchain.txt`` (recorded by CI's probe step) suppresses suggestions
  for that suite — a faster compiler is not a reason to ratchet the
  envelope down on everyone else.

A merged ``SUMMARY.json`` (per-suite case counts, headroom counts and
``expect_min`` floor outcomes) is written next to the fresh results and
uploaded as a PR-visible artifact.

``--self-test`` exercises the gate's own logic against inline fixtures
(regression, missing case, floor breach, headroom, cross-toolchain
suppression) and exits nonzero if any behaves unexpectedly.
"""

import argparse
import json
import sys
from pathlib import Path


def load_doc(path: Path):
    return json.loads(path.read_text())


def cases_by_name(doc):
    return {c["case"]: c for c in doc.get("cases", [])}


def compare_suite(fname, base_doc, fresh_doc, threshold, fresh_toolchain, *, log=print):
    """Compare one suite. Returns (failures, headroom, summary_dict).

    ``headroom`` entries are (fname, case_name, fresh_row); suggestions are
    suppressed (empty headroom, but still counted in the summary) when the
    baseline records a toolchain that differs from the fresh one.
    """
    base = cases_by_name(base_doc)
    fresh = cases_by_name(fresh_doc)
    failures, headroom = [], []
    compared = 0
    floors = {}
    base_toolchain = base_doc.get("toolchain")
    toolchain_match = base_toolchain is None or (
        fresh_toolchain is not None and base_toolchain == fresh_toolchain
    )
    for name, bc in sorted(base.items()):
        # Derived ratio rows may carry an "expect_min" floor (e.g. the
        # corpus warm-over-cold speedup must stay >= 5x at n = 256).
        floor = bc.get("expect_min")
        if floor is not None:
            fc = fresh.get(name)
            val = fc.get("median_seconds") if fc else None
            if val is None:
                failures.append(f"{fname}: ratio row '{name}' missing")
                floors[name] = {"floor": floor, "value": None, "ok": False}
            elif val < floor:
                failures.append(
                    f"{fname}: '{name}' = {val:.2f} below the required floor {floor}"
                )
                floors[name] = {"floor": floor, "value": val, "ok": False}
            else:
                log(f"  {fname:24} {name:44} {val:>10.2f}   >= {floor} OK")
                floors[name] = {"floor": floor, "value": val, "ok": True}
        if not bc.get("runs"):
            continue  # derived row (speedup ratio etc), not a timing
        bmed = bc.get("median_seconds")
        if bmed is None:
            continue  # failure marker in the baseline
        fc = fresh.get(name)
        if fc is None:
            failures.append(
                f"{fname}: case '{name}' missing from fresh results "
                "(renamed without refreshing the baseline?)"
            )
            continue
        fmed = fc.get("median_seconds")
        if fmed is None:
            failures.append(f"{fname}: case '{name}' produced no timing")
            continue
        compared += 1
        ratio = fmed / bmed if bmed > 0 else float("inf")
        marker = ""
        if ratio > threshold:
            failures.append(
                f"{fname}: '{name}' median {fmed:.6f}s vs baseline "
                f"{bmed:.6f}s ({ratio:.2f}x > {threshold}x)"
            )
            marker = "  << REGRESSION"
        elif ratio < 0.5:
            if toolchain_match:
                headroom.append((fname, name, fc))
                marker = "  (headroom: tighten baseline)"
            else:
                marker = "  (headroom; suggestion withheld: toolchain differs)"
        log(f"  {fname:24} {name:44} {fmed:>10.6f}s  {ratio:>5.2f}x{marker}")
    unbaselined = 0
    for name in sorted(set(fresh) - set(base)):
        if fresh[name].get("runs"):
            unbaselined += 1
            log(f"  {fname:24} {name:44} (no baseline; consider adding)")
    summary = {
        "cases_compared": compared,
        "failures": len(failures),
        "headroom": len(headroom),
        "unbaselined": unbaselined,
        "expect_min": floors,
        "toolchain_match": toolchain_match,
    }
    return failures, headroom, summary


def print_suggestions(headroom):
    print(
        "\nsuggested tightened baselines (fresh median x 1.25; these are "
        "complete rows — replace the matching case in the repo-root "
        "BENCH_*.json verbatim; keeping runs > 0 is what arms the gate):"
    )
    for fname, name, fc in headroom:
        row = {
            "case": name,
            "min_seconds": round(fc.get("min_seconds", fc["median_seconds"]) * 1.25, 6),
            "median_seconds": round(fc["median_seconds"] * 1.25, 6),
            "runs": fc.get("runs", 1),
        }
        print(f"  {fname}: {json.dumps(row)}")


def self_test() -> int:
    base_doc = {
        "suite": "t",
        "cases": [
            {"case": "fast", "median_seconds": 1.0, "runs": 3},
            {"case": "gone", "median_seconds": 1.0, "runs": 3},
            {"case": "wide", "median_seconds": 1.0, "runs": 3},
            {"case": "ratio", "median_seconds": 2.0, "runs": 0, "expect_min": 2.0},
        ],
    }
    fresh_doc = {
        "suite": "t",
        "cases": [
            {"case": "fast", "median_seconds": 2.0, "runs": 3},  # 2.0x > 1.3x
            {"case": "wide", "median_seconds": 0.1, "runs": 3},  # headroom
            {"case": "ratio", "median_seconds": 1.5, "runs": 0},  # below floor
        ],
    }
    sink = lambda *a, **k: None
    bad = 0

    failures, headroom, summary = compare_suite(
        "BENCH_t.json", base_doc, fresh_doc, 1.3, "rustc 1.80.0", log=sink
    )
    checks = [
        ("regression detected", any("REGRESSION" not in f and "2.00x" in f for f in failures)),
        ("missing case detected", any("missing from fresh results" in f for f in failures)),
        ("floor breach detected", any("below the required floor" in f for f in failures)),
        ("headroom suggested", len(headroom) == 1 and headroom[0][1] == "wide"),
        ("summary counts", summary["cases_compared"] == 2 and summary["failures"] == 3),
    ]

    # Same fixtures, but the baseline records a different toolchain: the
    # suggestion must be withheld while every failure still fires.
    base_other = dict(base_doc, toolchain="rustc 1.79.0")
    failures2, headroom2, summary2 = compare_suite(
        "BENCH_t.json", base_other, fresh_doc, 1.3, "rustc 1.80.0", log=sink
    )
    checks += [
        ("cross-toolchain suggestion withheld", len(headroom2) == 0),
        ("cross-toolchain failures kept", len(failures2) == len(failures)),
        ("cross-toolchain flagged in summary", summary2["toolchain_match"] is False),
    ]
    # An unknown fresh toolchain is also not evidence for tightening.
    _, headroom3, _ = compare_suite(
        "BENCH_t.json", base_other, fresh_doc, 1.3, None, log=sink
    )
    checks.append(("unknown fresh toolchain withheld", len(headroom3) == 0))

    for label, ok in checks:
        print(f"  self-test [{label}]: {'OK' if ok else 'BROKEN'}")
        bad += 0 if ok else 1
    print("self-test passed" if bad == 0 else f"self-test FAILED ({bad} checks)")
    return 1 if bad else 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline-dir", type=Path, default=Path("."))
    ap.add_argument("--results-dir", type=Path, default=Path("rust/bench_results"))
    ap.add_argument("--threshold", type=float, default=1.3)
    ap.add_argument(
        "--toolchain-file",
        type=Path,
        default=None,
        help="fresh toolchain probe (default: <results-dir>/toolchain.txt)",
    )
    ap.add_argument(
        "--summary",
        type=Path,
        default=None,
        help="merged summary output (default: <results-dir>/SUMMARY.json)",
    )
    ap.add_argument("--self-test", action="store_true")
    args = ap.parse_args()

    if args.self_test:
        return self_test()

    baselines = sorted(args.baseline_dir.glob("BENCH_*.json"))
    if not baselines:
        print(f"error: no BENCH_*.json baselines in {args.baseline_dir}", file=sys.stderr)
        return 1

    toolchain_file = args.toolchain_file or args.results_dir / "toolchain.txt"
    fresh_toolchain = None
    if toolchain_file.is_file():
        fresh_toolchain = toolchain_file.read_text().strip() or None
    if fresh_toolchain:
        print(f"fresh toolchain: {fresh_toolchain}")
    else:
        print("fresh toolchain: unknown (no toolchain.txt; tightening suggestions withheld "
              "for toolchain-pinned baselines)")

    all_failures, all_headroom, compared = [], [], 0
    suites = {}
    for base_path in baselines:
        fresh_path = args.results_dir / base_path.name
        if not fresh_path.is_file():
            all_failures.append(f"{base_path.name}: no fresh results at {fresh_path}")
            suites[base_path.name] = {"error": "no fresh results"}
            continue
        failures, headroom, summary = compare_suite(
            base_path.name,
            load_doc(base_path),
            load_doc(fresh_path),
            args.threshold,
            fresh_toolchain,
        )
        all_failures.extend(failures)
        all_headroom.extend(headroom)
        compared += summary["cases_compared"]
        suites[base_path.name] = summary

    print(
        f"\ncompared {compared} case(s); {len(all_failures)} failure(s); "
        f"{len(all_headroom)} case(s) with >2x headroom"
    )
    if all_headroom:
        print_suggestions(all_headroom)

    summary_path = args.summary or args.results_dir / "SUMMARY.json"
    try:
        summary_path.write_text(
            json.dumps(
                {
                    "toolchain": fresh_toolchain,
                    "threshold": args.threshold,
                    "cases_compared": compared,
                    "failures": all_failures,
                    "suites": suites,
                },
                indent=2,
            )
            + "\n"
        )
        print(f"[wrote {summary_path}]")
    except OSError as e:
        print(f"warning: could not write {summary_path}: {e}", file=sys.stderr)

    if all_failures:
        print("\nbench-regression gate FAILED:", file=sys.stderr)
        for f in all_failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
