#!/usr/bin/env python3
"""Bench-regression gate.

Compares freshly produced ``bench_results/BENCH_<suite>.json`` files
against the committed repo-root ``BENCH_<suite>.json`` baselines and
fails (exit 1) when any timed case's median regresses more than
``--threshold`` (default 1.3x) against its baseline median.

Rules:

* Every baseline file must have a matching fresh results file, and every
  timed baseline case (``runs > 0`` with a numeric median) must appear in
  the fresh results — a silently renamed or dropped case is a gate
  failure, not a skip.
* Derived rows (``runs == 0``, e.g. speedup ratios) and ``null`` medians
  (failure markers) are not timing measurements and are skipped.
* Fresh cases with no baseline are reported informationally; add them to
  the baseline when they stabilise.
* A fresh median far below baseline (< baseline/2) is flagged as
  headroom: the committed baseline is a bootstrap envelope written
  without hardware access, meant to be tightened to measured values by
  the first toolchain-equipped maintainer. For every headroom case the
  gate prints a **suggested tightened baseline** (fresh median x 1.25,
  leaving run-to-run noise margin under the 1.3x threshold) so tightening
  is a copy-paste job, not a measurement campaign.
"""

import argparse
import json
import sys
from pathlib import Path


def load_cases(path: Path):
    doc = json.loads(path.read_text())
    return {c["case"]: c for c in doc.get("cases", [])}


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline-dir", type=Path, default=Path("."))
    ap.add_argument("--results-dir", type=Path, default=Path("rust/bench_results"))
    ap.add_argument("--threshold", type=float, default=1.3)
    args = ap.parse_args()

    baselines = sorted(args.baseline_dir.glob("BENCH_*.json"))
    if not baselines:
        print(f"error: no BENCH_*.json baselines in {args.baseline_dir}", file=sys.stderr)
        return 1

    failures, headroom, compared = [], [], 0
    for base_path in baselines:
        fresh_path = args.results_dir / base_path.name
        if not fresh_path.is_file():
            failures.append(f"{base_path.name}: no fresh results at {fresh_path}")
            continue
        base = load_cases(base_path)
        fresh = load_cases(fresh_path)
        for name, bc in sorted(base.items()):
            # Derived ratio rows may carry an "expect_min" floor (e.g. the
            # corpus warm-over-cold speedup must stay >= 5x at n = 256).
            floor = bc.get("expect_min")
            if floor is not None:
                fc = fresh.get(name)
                val = fc.get("median_seconds") if fc else None
                if val is None:
                    failures.append(f"{base_path.name}: ratio row '{name}' missing")
                elif val < floor:
                    failures.append(
                        f"{base_path.name}: '{name}' = {val:.2f} below the "
                        f"required floor {floor}"
                    )
                else:
                    print(f"  {base_path.name:24} {name:44} {val:>10.2f}   >= {floor} OK")
            if not bc.get("runs"):
                continue  # derived row (speedup ratio etc), not a timing
            bmed = bc.get("median_seconds")
            if bmed is None:
                continue  # failure marker in the baseline
            fc = fresh.get(name)
            if fc is None:
                failures.append(
                    f"{base_path.name}: case '{name}' missing from fresh results "
                    "(renamed without refreshing the baseline?)"
                )
                continue
            fmed = fc.get("median_seconds")
            if fmed is None:
                failures.append(f"{base_path.name}: case '{name}' produced no timing")
                continue
            compared += 1
            ratio = fmed / bmed if bmed > 0 else float("inf")
            marker = ""
            if ratio > args.threshold:
                failures.append(
                    f"{base_path.name}: '{name}' median {fmed:.6f}s vs baseline "
                    f"{bmed:.6f}s ({ratio:.2f}x > {args.threshold}x)"
                )
                marker = "  << REGRESSION"
            elif ratio < 0.5:
                headroom.append((base_path.name, name, fc))
                marker = "  (headroom: tighten baseline)"
            print(f"  {base_path.name:24} {name:44} {fmed:>10.6f}s  {ratio:>5.2f}x{marker}")
        for name in sorted(set(fresh) - set(base)):
            if fresh[name].get("runs"):
                print(f"  {base_path.name:24} {name:44} (no baseline; consider adding)")

    print(
        f"\ncompared {compared} case(s); {len(failures)} failure(s); "
        f"{len(headroom)} case(s) with >2x headroom"
    )
    if headroom:
        print(
            "\nsuggested tightened baselines (fresh median x 1.25; these are "
            "complete rows — replace the matching case in the repo-root "
            "BENCH_*.json verbatim; keeping runs > 0 is what arms the gate):"
        )
        for fname, name, fc in headroom:
            row = {
                "case": name,
                "min_seconds": round(fc.get("min_seconds", fc["median_seconds"]) * 1.25, 6),
                "median_seconds": round(fc["median_seconds"] * 1.25, 6),
                "runs": fc.get("runs", 1),
            }
            print(f"  {fname}: {json.dumps(row)}")
    if failures:
        print("\nbench-regression gate FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
