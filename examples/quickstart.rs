//! Quickstart: the core operations on a couple of small paths, plus the
//! typed `Path`/`PathBatch` API with a ragged (variable-length) batch.
//!
//!     cargo run --release --example quickstart

use pysiglib::kernel::{
    sig_kernel, sig_kernel_vjp, try_gram, try_mmd2, try_sig_kernel, KernelOptions,
};
use pysiglib::sig::{log_signature, sig, sig_length, signature_vjp, try_batch_signature, SigOptions};
use pysiglib::transforms::Transform;
use pysiglib::util::rng::Rng;
use pysiglib::{Path, PathBatch};

fn main() {
    // Two Brownian-like paths in R^3.
    let (len, dim) = (64, 3);
    let mut rng = Rng::new(7);
    let x = rng.brownian_path(len, dim, 0.3);
    let y = rng.brownian_path(len, dim, 0.3);

    // 1. Truncated signature (Horner algorithm, the library default).
    let depth = 4;
    let s = sig(&x, len, dim, depth);
    println!("signature: depth {depth}, {} coefficients", s.len());
    println!("  level 1 (total increment): {:?}", &s[1..1 + dim]);

    // 2. Log-signature (tensor form).
    let l = log_signature(&x, len, dim, depth, Transform::None);
    println!("log-signature: {} coefficients, scalar part {:.1e}", l.len(), l[0]);

    // 3. Signature kernel via the Goursat PDE (dyadic order 1).
    let opts = KernelOptions::default().dyadic(1, 1);
    let k = sig_kernel(&x, &y, len, len, dim, &opts);
    let kxx = sig_kernel(&x, &x, len, len, dim, &opts);
    println!("signature kernel: k(x,y) = {k:.6}, k(x,x) = {kxx:.6}");

    // 4. Exact gradients of the kernel with respect to both paths
    //    (Algorithm 4 — the paper's novel differentiation scheme).
    let (gx, gy) = sig_kernel_vjp(&x, &y, len, len, dim, &opts, 1.0);
    println!(
        "kernel gradients: |∂k/∂x| = {:.4}, |∂k/∂y| = {:.4}",
        pysiglib::util::linalg::norm2(&gx),
        pysiglib::util::linalg::norm2(&gy)
    );

    // 5. Backprop through the signature itself: ∂<c, S(x)>/∂x.
    let mut cot = vec![0.0; sig_length(dim, depth)];
    rng.fill_normal(&mut cot);
    let gsig = signature_vjp(&x, len, dim, depth, Transform::None, &cot);
    println!("signature vjp: |∂F/∂x| = {:.4}", pysiglib::util::linalg::norm2(&gsig));

    // Transforms compose with everything, on-the-fly.
    let sll = pysiglib::sig::signature(
        &x,
        len,
        dim,
        3,
        Transform::LeadLag,
        pysiglib::sig::SigMethod::Horner,
    );
    println!(
        "lead-lag signature (fused, never materialised): {} coefficients",
        sll.len()
    );

    // 6. The typed, fallible API: shape checks happen at construction, and
    //    every entry point returns Result instead of panicking.
    let xp = Path::new(&x, len, dim).expect("valid shape");
    let yp = Path::new(&y, len, dim).expect("valid shape");
    let k2 = try_sig_kernel(xp, yp, &opts).expect("same dims");
    assert_eq!(k2, k);
    println!("typed API: try_sig_kernel(Path, Path) == sig_kernel(slices)");

    // 7. Ragged batches: variable-length paths, no padding. One flat buffer
    //    plus per-path lengths; Gram and MMD pair every length with every
    //    other.
    let lengths = [32usize, 7, 64, 18];
    let mut flat = Vec::new();
    for &l in &lengths {
        flat.extend(rng.brownian_path(l, dim, 0.3));
    }
    let batch = PathBatch::ragged(&flat, &lengths, dim).expect("valid ragged batch");
    let sigs = try_batch_signature(&batch, &SigOptions::new(depth)).expect("signatures");
    println!(
        "ragged batch: {} paths (lengths {:?}) → {} signature rows of {}",
        batch.batch(),
        lengths,
        sigs.len() / sig_length(dim, depth),
        sig_length(dim, depth)
    );
    let g = try_gram(&batch, &batch, &opts).expect("gram");
    println!(
        "ragged Gram: {}×{} kernel matrix, k(x0,x0) = {:.4}",
        batch.batch(),
        batch.batch(),
        g[0]
    );
    let uniform = PathBatch::uniform(&x, 1, len, dim).expect("valid");
    let m = try_mmd2(&batch, &uniform, &opts).expect("mmd");
    println!("ragged MMD²(batch, {{x}}) = {m:.6}");

    // 8. Compile once, execute many: a `Plan` does all validation, layout
    //    and workspace setup up front; repeat executions on the same shape
    //    class allocate nothing and the record's retained forward state
    //    feeds exact gradients without re-running the forward sweep.
    use pysiglib::engine::{Gradients, OpSpec, Plan, ShapeClass};
    let plan = Plan::compile(OpSpec::Sig(SigOptions::new(depth)), ShapeClass::uniform(dim, len))
        .expect("compile");
    let xb = PathBatch::uniform(&x, 1, len, dim).expect("valid");
    let record = plan.execute(&xb).expect("execute");
    let cold = plan.allocations();
    drop(record);
    let mut checksum = 0.0;
    for _ in 0..100 {
        let record = plan.execute(&xb).expect("execute");
        checksum += record.values()[1];
        let g = match record.vjp(&cot).expect("vjp") {
            Gradients::Single(g) => g,
            _ => unreachable!(),
        };
        checksum += g[0];
    }
    println!(
        "plan reuse: 100 executions, {} arena allocations after warmup (checksum {checksum:.3})",
        plan.allocations() - cold
    );
    println!("quickstart OK");
}
