//! Scaling beyond exact Grams: train against the **low-rank** signature-
//! kernel MMD². The exact MMD² costs O(n²·L²) per step through three Gram
//! matrices; the Nyström feature map costs O(n·r·L²) and its gradient flows
//! through the same Algorithm-4 kernel backward — so the training signal
//! stays exact in the feature space while the budget is set by the rank,
//! not the corpus.
//!
//! The run first shows the rank knob (low-rank MMD² converging to the exact
//! value as r grows), then fits a one-parameter generator (Brownian scale σ)
//! to a target scale σ★ by descending the low-rank MMD with gradients from
//! `ExecutionRecord::vjp` on an `OpSpec::Mmd2LowRank` plan.
//!
//!     cargo run --release --example lowrank_mmd

use pysiglib::engine::{Gradients, OpSpec, Plan, ShapeClass};
use pysiglib::kernel::{
    try_mmd2, FeatureMap, KernelOptions, LowRankSpec, NystromFeatures, try_mmd2_lowrank,
};
use pysiglib::util::rng::Rng;
use pysiglib::PathBatch;

fn main() {
    let (batch, len, dim) = (24usize, 16usize, 2usize);
    let mut rng = Rng::new(77);
    let opts = KernelOptions::default();

    // ---- Part 1: the rank knob ------------------------------------------
    let x = rng.brownian_batch(batch, len, dim, 0.30);
    let y = rng.brownian_batch(batch, len, dim, 0.45);
    let xb = PathBatch::uniform(&x, batch, len, dim).unwrap();
    let yb = PathBatch::uniform(&y, batch, len, dim).unwrap();
    let exact = try_mmd2(&xb, &yb, &opts).unwrap();
    // Nested landmark prefixes of the pooled corpus: the approximation
    // improves monotonically toward the exact value.
    let mut pooled = x.clone();
    pooled.extend_from_slice(&y);
    println!("exact biased MMD² = {exact:.6e}");
    println!("{:>6} {:>14} {:>12}", "rank", "mmd2_lowrank", "abs err");
    for r in [2usize, 4, 8, 16, 2 * batch] {
        let zb = PathBatch::uniform(&pooled[..r * len * dim], r, len, dim).unwrap();
        let f = NystromFeatures::try_new(&zb, &opts).unwrap();
        let lr = try_mmd2_lowrank(&f, &xb, &yb).unwrap();
        println!("{r:>6} {lr:>14.6e} {:>12.2e}", (lr - exact).abs());
    }

    // ---- Part 2: training against the low-rank MMD ----------------------
    // Generator: path = σ · z with z a unit Brownian path, so ∂path/∂σ = z
    // and the chain rule from the MMD's path gradient is a dot product.
    let sigma_star = 0.5;
    let target = rng.brownian_batch(batch, len, dim, sigma_star);
    let tb = PathBatch::uniform(&target, batch, len, dim).unwrap();
    let rank = 8;
    let plan = Plan::compile(
        OpSpec::Mmd2LowRank {
            opts,
            // Landmarks come from the target batch (the second input), so
            // the σ-gradient is exact — no frozen-landmark approximation.
            lowrank: LowRankSpec::nystrom(rank, 7),
        },
        ShapeClass::uniform(dim, len),
    )
    .expect("compile low-rank MMD plan");

    let mut sigma = 0.15f64;
    let start_gap = (sigma - sigma_star).abs();
    let steps = 120;
    let lr_rate = 0.05;
    println!("\ntraining σ against σ★ = {sigma_star} (rank-{rank} Nyström MMD²)");
    println!("{:>5} {:>14} {:>8}", "step", "mmd2_lowrank", "σ");
    let mut first = None;
    let mut last = 0.0;
    for step in 0..steps {
        let z = rng.brownian_batch(batch, len, dim, 1.0);
        let xs: Vec<f64> = z.iter().map(|v| sigma * v).collect();
        let xb = PathBatch::uniform(&xs, batch, len, dim).unwrap();
        let record = plan.execute_pair(&xb, &tb).expect("lowrank mmd forward");
        let loss = record.value();
        let gpaths = match record.vjp(&[1.0]).expect("lowrank mmd vjp") {
            Gradients::Single(g) => g,
            _ => unreachable!("mmd2 yields one gradient"),
        };
        let gsigma: f64 = gpaths.iter().zip(z.iter()).map(|(g, zi)| g * zi).sum();
        sigma -= lr_rate * gsigma.clamp(-2.0, 2.0);
        if first.is_none() {
            first = Some(loss);
        }
        last = loss;
        if step % 10 == 0 || step == steps - 1 {
            println!("{step:>5} {loss:>14.6e} {sigma:>8.4}");
        }
    }
    let end_gap = (sigma - sigma_star).abs();
    println!(
        "σ: gap {start_gap:.3} -> {end_gap:.3}; loss {:.3e} -> {last:.3e}",
        first.unwrap()
    );
    assert!(
        end_gap < 0.5 * start_gap,
        "σ did not approach σ★ ({start_gap:.3} -> {end_gap:.3})"
    );

    // The same feature machinery is reusable directly: the record retains Φ.
    let z = rng.brownian_batch(batch, len, dim, 1.0);
    let xs: Vec<f64> = z.iter().map(|v| sigma * v).collect();
    let xb = PathBatch::uniform(&xs, batch, len, dim).unwrap();
    let record = plan.execute_pair(&xb, &tb).unwrap();
    let (phi_x, phi_y, r) = record.lowrank_features().expect("retained features");
    assert_eq!(phi_x.len(), batch * r);
    assert_eq!(phi_y.len(), batch * r);
    // Consistency: the retained features reproduce the record's value.
    let map = FeatureMap::try_build(&LowRankSpec::nystrom(rank, 7), &opts, &tb).unwrap();
    let direct = try_mmd2_lowrank(&map, &xb, &tb).unwrap();
    assert!((direct - record.value()).abs() < 1e-12);
    println!("lowrank_mmd OK");
}
