//! Two-sample hypothesis testing with the signature-kernel MMD — the
//! classic discriminator use-case for signature kernels (paper §1: "powerful
//! discriminators ... for time-series").
//!
//! Tests H0: P = Q with a permutation test on the unbiased MMD² statistic:
//!  * under the null (both samples Brownian, same scale) the test should
//!    accept at the nominal level;
//!  * under the alternative (Ornstein–Uhlenbeck vs Brownian with matched
//!    marginal scale) it should reject decisively.
//!
//!     cargo run --release --example mmd_twosample

use pysiglib::kernel::{try_gram, KernelOptions};
use pysiglib::transforms::Transform;
use pysiglib::util::rng::Rng;
use pysiglib::PathBatch;

/// MMD² (unbiased) from precomputed joint Gram of the pooled sample.
fn mmd2_from_gram(k: &[f64], n: usize, m: usize, perm: &[usize]) -> f64 {
    // perm maps pooled index -> pooled index; first n are "x", rest "y".
    let tot = n + m;
    debug_assert_eq!(k.len(), tot * tot);
    let mut kxx = 0.0;
    let mut kyy = 0.0;
    let mut kxy = 0.0;
    for i in 0..tot {
        for j in 0..tot {
            if i == j {
                continue;
            }
            let v = k[perm[i] * tot + perm[j]];
            match (i < n, j < n) {
                (true, true) => kxx += v,
                (false, false) => kyy += v,
                (true, false) => kxy += v,
                (false, true) => {}
            }
        }
    }
    kxx / (n * (n - 1)) as f64 + kyy / (m * (m - 1)) as f64 - 2.0 * kxy / (n * m) as f64
}

/// Permutation-test p-value (upper tail).
fn permutation_pvalue(k: &[f64], n: usize, m: usize, rng: &mut Rng, n_perm: usize) -> f64 {
    let tot = n + m;
    let identity: Vec<usize> = (0..tot).collect();
    let observed = mmd2_from_gram(k, n, m, &identity);
    let mut worse = 0usize;
    let mut perm = identity.clone();
    for _ in 0..n_perm {
        // Fisher–Yates.
        for i in (1..tot).rev() {
            let j = rng.below(i + 1);
            perm.swap(i, j);
        }
        if mmd2_from_gram(k, n, m, &perm) >= observed {
            worse += 1;
        }
    }
    (worse + 1) as f64 / (n_perm + 1) as f64
}

/// Ornstein–Uhlenbeck path: mean-reverting, same stationary scale as the
/// Brownian alternative is matched to.
fn ou_path(rng: &mut Rng, len: usize, dim: usize, theta: f64, sigma: f64) -> Vec<f64> {
    let dt = 1.0 / (len - 1) as f64;
    let mut out = vec![0.0; len * dim];
    for t in 1..len {
        for j in 0..dim {
            let prev = out[(t - 1) * dim + j];
            out[t * dim + j] = prev - theta * prev * dt + sigma * dt.sqrt() * rng.normal();
        }
    }
    out
}

fn pooled_gram(
    paths: &[Vec<f64>],
    len: usize,
    dim: usize,
    opts: &KernelOptions,
) -> Vec<f64> {
    // Typed batch view over the pooled sample (uniform here, but the same
    // call serves ragged pools — see PathBatch::ragged).
    let tot = paths.len();
    let mut flat = Vec::with_capacity(tot * len * dim);
    for p in paths {
        flat.extend_from_slice(p);
    }
    let batch = PathBatch::uniform(&flat, tot, len, dim).expect("pooled sample shape");
    try_gram(&batch, &batch, opts).expect("pooled Gram")
}

fn main() {
    let (n, m, len, dim) = (24usize, 24usize, 48usize, 2usize);
    let n_perm = 400;
    let mut rng = Rng::new(99);
    // Time-augmentation makes the test sensitive to dynamics, not just
    // marginal laws — the standard preprocessing for signature MMD tests.
    let opts = KernelOptions::default().dyadic(1, 1).transform(Transform::TimeAug);
    let scale = 1.0 / (len as f64).sqrt();

    // --- Null: both samples Brownian with the same scale. ---
    let xs: Vec<Vec<f64>> = (0..n).map(|_| rng.brownian_path(len, dim, scale)).collect();
    let ys: Vec<Vec<f64>> = (0..m).map(|_| rng.brownian_path(len, dim, scale)).collect();
    let pooled: Vec<Vec<f64>> = xs.iter().chain(ys.iter()).cloned().collect();
    let t = std::time::Instant::now();
    let k = pooled_gram(&pooled, len, dim, &opts);
    let gram_time = t.elapsed().as_secs_f64();
    let p_null = permutation_pvalue(&k, n, m, &mut rng, n_perm);
    println!(
        "null (BM vs BM):       Gram {}x{} in {gram_time:.3}s, p = {p_null:.4}",
        n + m,
        n + m
    );

    // --- Alternative: OU vs Brownian, matched scale. ---
    let xs: Vec<Vec<f64>> = (0..n)
        .map(|_| ou_path(&mut rng, len, dim, 8.0, 1.0))
        .collect();
    let ys: Vec<Vec<f64>> = (0..m).map(|_| rng.brownian_path(len, dim, scale)).collect();
    let pooled: Vec<Vec<f64>> = xs.iter().chain(ys.iter()).cloned().collect();
    let k = pooled_gram(&pooled, len, dim, &opts);
    let p_alt = permutation_pvalue(&k, n, m, &mut rng, n_perm);
    println!("alternative (OU vs BM): p = {p_alt:.4}");

    assert!(p_null > 0.05, "null rejected at 5% — test is mis-sized (p={p_null})");
    assert!(p_alt < 0.05, "alternative not detected (p={p_alt})");
    println!("mmd_twosample OK (accepts the null, rejects the alternative)");
}
