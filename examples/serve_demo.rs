//! Serving demo: start the coordinator in-process, drive it with concurrent
//! clients over loopback TCP, and report throughput / latency / batching
//! metrics — the L3 story end-to-end.
//!
//!     cargo run --release --example serve_demo

use std::sync::Arc;
use std::time::{Duration, Instant};

use pysiglib::coordinator::{serve, Batcher, BatcherConfig, Client, Op, Router};
use pysiglib::runtime::RuntimeHandle;
use pysiglib::util::rng::Rng;

fn main() {
    // Prefer the PJRT artifacts when present (exercises the AOT path for
    // matching shapes); the native backend serves everything else.
    let router = match RuntimeHandle::spawn("artifacts") {
        Ok(rt) => {
            println!(
                "PJRT runtime: platform={}, {} artifacts",
                rt.platform(),
                rt.manifest().len()
            );
            Router::with_runtime(rt)
        }
        Err(_) => {
            println!("artifacts/ not built; serving with the native backend only");
            Router::native_only()
        }
    };
    let batcher = Arc::new(Batcher::start(
        Arc::new(router),
        BatcherConfig {
            max_batch: 64,
            max_wait: Duration::from_micros(800),
            ..BatcherConfig::default()
        },
    ));
    let handle = serve("127.0.0.1:0", batcher.clone()).expect("bind");
    println!("coordinator listening on {}", handle.addr);

    let n_clients = 6;
    let per_client = 200;
    let (len, dim) = (64usize, 3usize);
    let t0 = Instant::now();
    let workers: Vec<_> = (0..n_clients)
        .map(|c| {
            let addr = handle.addr;
            std::thread::spawn(move || {
                let mut client = Client::connect(addr).expect("connect");
                let mut rng = Rng::new(7000 + c as u64);
                let mut lat_us: Vec<u64> = Vec::with_capacity(per_client);
                for i in 0..per_client {
                    let x = rng.brownian_path(len, dim, 0.3);
                    let t = Instant::now();
                    let r = match i % 3 {
                        0 => client.signature(&x, len, dim, 4).map(|r| r.map(|_| ())),
                        1 => {
                            let y = rng.brownian_path(len, dim, 0.3);
                            client.sig_kernel(&x, &y, len, dim).map(|r| r.map(|_| ()))
                        }
                        _ => client
                            .call(
                                Op::Signature {
                                    depth: 4,
                                    transform: 2, // lead-lag
                                },
                                len,
                                dim,
                                x,
                            )
                            .map(|r| r.map(|_| ())),
                    };
                    match r {
                        Ok(Ok(())) => lat_us.push(t.elapsed().as_micros() as u64),
                        Ok(Err(e)) => panic!("server error: {e}"),
                        Err(e) => panic!("io error: {e}"),
                    }
                }
                lat_us
            })
        })
        .collect();

    let mut all_lat: Vec<u64> = Vec::new();
    for w in workers {
        all_lat.extend(w.join().expect("client thread"));
    }
    let wall = t0.elapsed().as_secs_f64();
    all_lat.sort_unstable();
    let total = all_lat.len();
    let p = |q: f64| all_lat[((total - 1) as f64 * q) as usize];
    println!("\n{} requests over {} clients in {wall:.2}s", total, n_clients);
    println!("throughput: {:.0} req/s", total as f64 / wall);
    println!(
        "latency: p50={}µs p90={}µs p99={}µs max={}µs",
        p(0.50),
        p(0.90),
        p(0.99),
        p(1.0)
    );
    println!("server metrics: {}", batcher.metrics.summary());
    assert_eq!(
        batcher
            .metrics
            .responses_total
            .load(std::sync::atomic::Ordering::Relaxed),
        total as u64
    );
    println!("serve_demo OK");
}
