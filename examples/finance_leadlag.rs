//! Financial featurisation with lead-lag signatures (the workload the
//! paper's §4 motivates): predict the forward realised volatility of a
//! synthetic price series from the signature of its lead-lag transform,
//! with a plain ridge regression on top — signatures as features for a
//! linear model (the universal-approximation use-case).
//!
//!     cargo run --release --example finance_leadlag

use pysiglib::sig::{batch_signature, sig_length, SigOptions};
use pysiglib::transforms::Transform;
use pysiglib::util::rng::Rng;

/// Synthetic market: log-price with regime-switching volatility. Returns
/// (windows `[n, len, 1]`, forward realised vol per window).
fn make_dataset(rng: &mut Rng, n: usize, len: usize) -> (Vec<f64>, Vec<f64>) {
    let mut windows = Vec::with_capacity(n * len);
    let mut targets = Vec::with_capacity(n);
    for _ in 0..n {
        // Per-window stochastic volatility level, persistent within window.
        let base_vol = 0.005 + 0.03 * rng.uniform();
        let mut price: f64 = 0.0;
        let mut vol = base_vol;
        let mut win = Vec::with_capacity(len);
        for _ in 0..len {
            vol = (vol + 0.1 * base_vol * rng.normal()).clamp(0.2 * base_vol, 5.0 * base_vol);
            price += vol * rng.normal();
            win.push(price);
        }
        // Forward vol is driven by the same regime: realised vol of a fresh
        // continuation (what a trader would want to predict).
        let mut fwd = 0.0;
        for _ in 0..len {
            let r = vol * rng.normal();
            fwd += r * r;
        }
        targets.push((fwd / len as f64).sqrt());
        windows.extend(win);
    }
    (windows, targets)
}

/// Ridge regression via normal equations (features are a few hundred wide).
fn ridge_fit(x: &[f64], y: &[f64], n: usize, p: usize, lambda: f64) -> Vec<f64> {
    // A = XᵀX + λI (p×p), b = Xᵀy.
    let mut a = vec![0.0; p * p];
    let mut b = vec![0.0; p];
    for i in 0..n {
        let row = &x[i * p..(i + 1) * p];
        for j in 0..p {
            b[j] += row[j] * y[i];
            for k in j..p {
                a[j * p + k] += row[j] * row[k];
            }
        }
    }
    for j in 0..p {
        for k in 0..j {
            a[j * p + k] = a[k * p + j];
        }
        a[j * p + j] += lambda;
    }
    // Cholesky solve.
    let mut l = vec![0.0; p * p];
    for i in 0..p {
        for j in 0..=i {
            let mut s = a[i * p + j];
            for k in 0..j {
                s -= l[i * p + k] * l[j * p + k];
            }
            if i == j {
                l[i * p + i] = s.max(1e-12).sqrt();
            } else {
                l[i * p + j] = s / l[j * p + j];
            }
        }
    }
    let mut z = vec![0.0; p];
    for i in 0..p {
        let mut s = b[i];
        for k in 0..i {
            s -= l[i * p + k] * z[k];
        }
        z[i] = s / l[i * p + i];
    }
    let mut w = vec![0.0; p];
    for i in (0..p).rev() {
        let mut s = z[i];
        for k in i + 1..p {
            s -= l[k * p + i] * w[k];
        }
        w[i] = s / l[i * p + i];
    }
    w
}

fn r2(pred: &[f64], y: &[f64]) -> f64 {
    let mean = y.iter().sum::<f64>() / y.len() as f64;
    let ss_tot: f64 = y.iter().map(|v| (v - mean) * (v - mean)).sum();
    let ss_res: f64 = pred.iter().zip(y).map(|(p, v)| (p - v) * (p - v)).sum();
    1.0 - ss_res / ss_tot
}

fn main() {
    let mut rng = Rng::new(2024);
    let (n_train, n_test, len) = (512, 256, 64);
    let (xtr, ytr) = make_dataset(&mut rng, n_train, len);
    let (xte, yte) = make_dataset(&mut rng, n_test, len);

    // Feature map: signature of the lead-lag(+time) path, depth 3 — the QV
    // information lives in the lead/lag cross terms (Itô-signature proxy).
    let depth = 3;
    let tr = Transform::LeadLagTimeAug;
    let opts = SigOptions::new(depth).transform(tr);
    let p = sig_length(tr.out_dim(1), depth);
    let t = std::time::Instant::now();
    let ftr = batch_signature(&xtr, n_train, len, 1, &opts);
    let fte = batch_signature(&xte, n_test, len, 1, &opts);
    println!(
        "lead-lag signature features: {p} per window, {:.3}s for {} windows",
        t.elapsed().as_secs_f64(),
        n_train + n_test
    );

    let w = ridge_fit(&ftr, &ytr, n_train, p, 1e-6);
    let pred: Vec<f64> = (0..n_test)
        .map(|i| {
            fte[i * p..(i + 1) * p]
                .iter()
                .zip(&w)
                .map(|(f, w)| f * w)
                .sum()
        })
        .collect();
    let r2_sig = r2(&pred, &yte);

    // Baseline 1: constant predictor (R² = 0 by construction).
    // Baseline 2: plain increment features (endpoint + abs-increment mean) —
    // what you get without signatures.
    let mut fb_tr = Vec::with_capacity(n_train * 3);
    let mut fb_te = Vec::with_capacity(n_test * 3);
    let naive_feats = |x: &[f64], out: &mut Vec<f64>| {
        let l = len;
        let total = x[l - 1] - x[0];
        let mav: f64 = (0..l - 1).map(|i| (x[i + 1] - x[i]).abs()).sum::<f64>() / (l - 1) as f64;
        out.extend([1.0, total, mav]);
    };
    for i in 0..n_train {
        naive_feats(&xtr[i * len..(i + 1) * len], &mut fb_tr);
    }
    for i in 0..n_test {
        naive_feats(&xte[i * len..(i + 1) * len], &mut fb_te);
    }
    let wb = ridge_fit(&fb_tr, &ytr, n_train, 3, 1e-8);
    let pred_b: Vec<f64> = (0..n_test)
        .map(|i| fb_te[i * 3..(i + 1) * 3].iter().zip(&wb).map(|(f, w)| f * w).sum())
        .collect();
    let r2_naive = r2(&pred_b, &yte);

    println!("test R²: lead-lag signature features = {r2_sig:.4}, naive features = {r2_naive:.4}");
    assert!(
        r2_sig > r2_naive,
        "signature features should beat naive features"
    );
    assert!(r2_sig > 0.5, "signature features should be predictive");
    println!("finance_leadlag OK");
}
