//! # pySigLib (Rust reproduction)
//!
//! High-performance signature-based computations: truncated path signatures
//! and signature kernels, with exact backpropagation, batched parallel
//! execution, path transformations, a PJRT runtime for AOT-compiled JAX/Pallas
//! artifacts, and a serving coordinator.
//!
//! Reproduces: Shmelev & Salvi, "pySigLib — Fast Signature-Based Computations
//! on CPU and GPU" (2025).
//!
//! ## API layers
//!
//! * [`path`] — the typed core API: [`Path`](path::Path) /
//!   [`PathBatch`](path::PathBatch) views (uniform **and ragged** batches),
//!   the [`SigError`](path::SigError) error type, and the options layer
//!   shared by both subsystems. Every computation has a fallible `try_*`
//!   entry point taking these types; nothing on that route panics on
//!   malformed input.
//! * [`sig`] — truncated signatures, log-signatures, streaming/batched
//!   variants and exact vjps (plus the flat-slice convenience wrappers).
//! * [`kernel`] — signature kernels via the Goursat PDE, Gram matrices,
//!   MMD², kernel ridge regression and exact vjps.
//! * [`transforms`] — time-augmentation / lead-lag / basepoint, fused
//!   on-the-fly into every sweep.
//! * [`coordinator`] — the serving layer: a validated binary wire protocol
//!   (single-path and ragged-batch frames), shape-grouped dynamic batching,
//!   and a router that executes [`PathBatch`](path::PathBatch)es natively or
//!   on PJRT artifacts.
//! * [`runtime`] — PJRT execution of AOT artifacts (behind the `pjrt`
//!   feature; the default build has no external dependencies).

pub mod tensor;
pub mod util;
pub mod path;
pub mod sig;
pub mod kernel;
pub mod transforms;
pub mod baselines;
pub mod runtime;
pub mod coordinator;
pub mod config;
pub mod bench;
pub mod cli;

pub use path::{ExecOptions, Path, PathBatch, SigError};
