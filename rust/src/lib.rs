//! # pySigLib (Rust reproduction)
//!
//! High-performance signature-based computations: truncated path signatures
//! and signature kernels, with exact backpropagation, batched parallel
//! execution, path transformations, a PJRT runtime for AOT-compiled JAX/Pallas
//! artifacts, and a serving coordinator.
//!
//! Reproduces: Shmelev & Salvi, "pySigLib — Fast Signature-Based Computations
//! on CPU and GPU" (2025).

pub mod tensor;
pub mod util;
pub mod sig;
pub mod kernel;
pub mod transforms;
pub mod baselines;
pub mod runtime;
pub mod coordinator;
pub mod config;
pub mod bench;
pub mod cli;
