//! # pySigLib (Rust reproduction)
//!
//! High-performance signature-based computations: truncated path signatures
//! and signature kernels, with exact backpropagation, batched parallel
//! execution, path transformations, a PJRT runtime for AOT-compiled JAX/Pallas
//! artifacts, and a serving coordinator.
//!
//! Reproduces: Shmelev & Salvi, "pySigLib — Fast Signature-Based Computations
//! on CPU and GPU" (2025).
//!
//! ## API layers
//!
//! * [`path`] — the typed core API: [`Path`](path::Path) /
//!   [`PathBatch`](path::PathBatch) views (uniform **and ragged** batches),
//!   the [`SigError`](path::SigError) error type, and the options layer
//!   shared by both subsystems. Every computation has a fallible `try_*`
//!   entry point taking these types; nothing on that route panics on
//!   malformed input.
//! * [`engine`] — compile-once / execute-many: a [`Plan`](engine::Plan) is
//!   compiled from an op spec + shape class (all validation, layout tables,
//!   backend selection, workspace arena happen once), then
//!   `plan.execute(&batch)` runs with **zero shape-dependent allocation**
//!   and returns an [`ExecutionRecord`](engine::ExecutionRecord) whose
//!   retained forward intermediates feed exact
//!   [`vjp`](engine::ExecutionRecord::vjp) gradients without re-running the
//!   forward sweep. [`Session`](engine::Session) adds an LRU plan cache.
//!   Use this layer for training loops and serving; the `try_*` wrappers
//!   below compile one-shot plans for one-off calls.
//! * [`sig`] — truncated signatures, log-signatures, streaming/batched
//!   variants and exact vjps (plus the flat-slice convenience wrappers).
//! * [`kernel`] — signature kernels via the Goursat PDE, Gram matrices,
//!   MMD², kernel ridge regression and exact vjps. Gram production is
//!   **lane-batched** ([`kernel::lanes`]): W ∈ {4, 8} same-shape pairs ride
//!   one structure-of-arrays Goursat sweep (one stacked Δ GEMM per lane
//!   group), bit-identical to the scalar path and overridable with
//!   `PYSIGLIB_LANES` (`0` = scalar) — the schedule behind every exact
//!   Gram/MMD²/KRR/corpus workload.
//! * [`kernel::lowrank`] — **scaling beyond exact Grams**: the exact Gram
//!   is O(n²·L²) in corpus size n; Nyström landmarks and random
//!   truncated-signature features give explicit rank-r maps Φ with
//!   k(x, y) ≈ φ(x)·φ(y), making Gram/MMD²/KRR O(n·r²)
//!   ([`try_gram_lowrank`](kernel::try_gram_lowrank),
//!   [`try_mmd2_lowrank`](kernel::try_mmd2_lowrank),
//!   [`KernelRidge::try_fit_lowrank`](kernel::KernelRidge::try_fit_lowrank)).
//!   Prefer Nyström when fidelity to the exact PDE kernel matters (exact at
//!   full rank; landmarks from the reference batch keep training gradients
//!   exact); prefer random signature features when the map must be
//!   data-independent or PDE solves dominate. First-class engine plans:
//!   [`OpSpec::{GramLowRank, Mmd2LowRank, KrrLowRank}`](engine::OpSpec).
//! * [`corpus`] — the **corpus service**: register a reference corpus once
//!   under a [`CorpusId`](corpus::CorpusId), query Gram/MMD² against it
//!   repeatedly, append incrementally. A
//!   [`CorpusRegistry`](corpus::CorpusRegistry) caches the corpus-side
//!   state (self-Gram tiles, low-rank feature matrices) so warm re-queries
//!   pay only query-side cost, and a cache-sized
//!   [`TileScheduler`](corpus::TileScheduler) shards Gram work
//!   bit-identically across threads. First-class engine plans:
//!   [`OpSpec::{GramCorpus, Mmd2Corpus}`](engine::OpSpec); served over the
//!   wire as `RegisterCorpus` / `AppendCorpus` / `Mmd2Corpus`.
//! * [`transforms`] — time-augmentation / lead-lag / basepoint, fused
//!   on-the-fly into every sweep.
//! * [`coordinator`] — the serving layer: a validated binary wire protocol
//!   (single-path and ragged-batch frames), shape-grouped dynamic batching,
//!   and a router that executes [`PathBatch`](path::PathBatch)es through an
//!   LRU-cached plan per shape group, natively or on PJRT artifacts.
//! * [`runtime`] — PJRT execution of AOT artifacts (behind the `pjrt`
//!   feature; the default build has no external dependencies).

// Style allowances for numeric-kernel idiom (indexed loops over flat tensor
// layouts, wide argument lists on hot entry points) — the clippy CI job runs
// with `-D warnings` for everything else.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::manual_memcpy,
    clippy::type_complexity,
    clippy::neg_cmp_op_on_partial_ord // `!(x > 0.0)` deliberately catches NaN
)]

pub mod tensor;
pub mod util;
pub mod path;
pub mod engine;
pub mod sig;
pub mod kernel;
pub mod corpus;
pub mod transforms;
pub mod baselines;
pub mod runtime;
pub mod coordinator;
pub mod config;
pub mod bench;
pub mod cli;

pub use corpus::{CorpusId, CorpusRegistry};
pub use engine::{ExecutionRecord, Gradients, OpSpec, Plan, PlanCache, Session, ShapeClass};
pub use path::{ExecOptions, KernelOptions, Path, PathBatch, SigError, SigOptions};
