//! Path-to-path transformations (paper §4): time-augmentation, lead-lag and
//! basepoint, each available in two forms:
//!
//! 1. **Materialised** — produce the transformed path explicitly.
//! 2. **On-the-fly** — the signature / kernel algorithms only ever consume
//!    path *increments* (signatures) or increment *inner products* (kernels),
//!    so both transforms can be fused into the sweep without materialising
//!    the transformed path. This is the paper's "adapting the algorithms
//!    internally", and is both faster and more memory-efficient.
//!
//! Conventions: paths are row-major `[len, dim]`. Time augmentation appends
//! a uniform time channel t_i = i/(len-1) (so the total time increment is 1).
//! Lead-lag maps a length-L path to a length-(2L-1), dimension-2d path
//! `(lead, lag)` per the paper's definition.

/// Which transformation to apply before the transform under computation.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Transform {
    /// Use the path as-is.
    None,
    /// Append a time channel: x̂_i = (x_i, t_i), dim d+1.
    TimeAug,
    /// Lead-lag: X^LL_i = (lead_i, lag_i), 2L-1 points of dim 2d.
    LeadLag,
    /// Lead-lag then time augmentation: 2L-1 points of dim 2d+1.
    LeadLagTimeAug,
}

impl Transform {
    /// Length of the transformed path given input length.
    pub fn out_len(&self, len: usize) -> usize {
        match self {
            Transform::None | Transform::TimeAug => len,
            Transform::LeadLag | Transform::LeadLagTimeAug => 2 * len - 1,
        }
    }

    /// Dimension of the transformed path given input dimension.
    pub fn out_dim(&self, dim: usize) -> usize {
        match self {
            Transform::None => dim,
            Transform::TimeAug => dim + 1,
            Transform::LeadLag => 2 * dim,
            Transform::LeadLagTimeAug => 2 * dim + 1,
        }
    }

    /// Parse a CLI/config name.
    pub fn parse(s: &str) -> Option<Transform> {
        match s {
            "none" => Some(Transform::None),
            "time" | "timeaug" | "time_aug" => Some(Transform::TimeAug),
            "leadlag" | "lead_lag" => Some(Transform::LeadLag),
            "leadlag_time" | "leadlagtimeaug" => Some(Transform::LeadLagTimeAug),
            _ => None,
        }
    }
}

/// Materialise the time-augmented path `[len, dim+1]`.
pub fn time_augment(path: &[f64], len: usize, dim: usize) -> Vec<f64> {
    assert_eq!(path.len(), len * dim);
    let mut out = Vec::with_capacity(len * (dim + 1));
    let denom = (len.max(2) - 1) as f64;
    for i in 0..len {
        out.extend_from_slice(&path[i * dim..(i + 1) * dim]);
        out.push(i as f64 / denom);
    }
    out
}

/// Materialise the lead-lag path `[2*len-1, 2*dim]`.
///
/// Point i has lead = x_{ceil(i/2)}, lag = x_{floor(i/2)}: even points are
/// (x_k, x_k), odd points are (x_{k+1}, x_k).
pub fn lead_lag(path: &[f64], len: usize, dim: usize) -> Vec<f64> {
    assert_eq!(path.len(), len * dim);
    assert!(len >= 1);
    let olen = 2 * len - 1;
    let mut out = Vec::with_capacity(olen * 2 * dim);
    for i in 0..olen {
        let lead = (i + 1) / 2;
        let lag = i / 2;
        out.extend_from_slice(&path[lead * dim..(lead + 1) * dim]);
        out.extend_from_slice(&path[lag * dim..(lag + 1) * dim]);
    }
    out
}

/// Materialise an arbitrary [`Transform`].
pub fn apply(transform: Transform, path: &[f64], len: usize, dim: usize) -> Vec<f64> {
    match transform {
        Transform::None => path.to_vec(),
        Transform::TimeAug => time_augment(path, len, dim),
        Transform::LeadLag => lead_lag(path, len, dim),
        Transform::LeadLagTimeAug => {
            let ll = lead_lag(path, len, dim);
            time_augment(&ll, 2 * len - 1, 2 * dim)
        }
    }
}

/// Prepend a basepoint (the origin) to the path: `[len+1, dim]`. Standard
/// trick to make the signature sensitive to the starting level of the path.
pub fn basepoint(path: &[f64], len: usize, dim: usize) -> Vec<f64> {
    assert_eq!(path.len(), len * dim);
    let mut out = vec![0.0; (len + 1) * dim];
    out[dim..].copy_from_slice(path);
    out
}

/// Streaming increment source: yields the increments of the *transformed*
/// path without materialising it. This is what the signature algorithms
/// consume for on-the-fly transforms.
pub struct IncrementStream<'a> {
    path: &'a [f64],
    len: usize,
    dim: usize,
    transform: Transform,
    step: usize,
}

impl<'a> IncrementStream<'a> {
    pub fn new(path: &'a [f64], len: usize, dim: usize, transform: Transform) -> Self {
        assert_eq!(path.len(), len * dim);
        assert!(len >= 2, "need at least two points");
        IncrementStream {
            path,
            len,
            dim,
            transform,
            step: 0,
        }
    }

    /// Number of increments of the transformed path.
    pub fn num_steps(&self) -> usize {
        self.transform.out_len(self.len) - 1
    }

    /// Dimension of each increment.
    pub fn out_dim(&self) -> usize {
        self.transform.out_dim(self.dim)
    }

    /// Write the next increment into `z` (length `out_dim()`).
    /// Returns false when exhausted.
    pub fn next_into(&mut self, z: &mut [f64]) -> bool {
        let s = self.step;
        if s >= self.num_steps() {
            return false;
        }
        let d = self.dim;
        let p = self.path;
        let diff = |k: usize, out: &mut [f64]| {
            for j in 0..d {
                out[j] = p[(k + 1) * d + j] - p[k * d + j];
            }
        };
        match self.transform {
            Transform::None => {
                debug_assert_eq!(z.len(), d);
                diff(s, z);
            }
            Transform::TimeAug => {
                debug_assert_eq!(z.len(), d + 1);
                diff(s, &mut z[..d]);
                z[d] = 1.0 / (self.len - 1) as f64;
            }
            Transform::LeadLag => {
                debug_assert_eq!(z.len(), 2 * d);
                z.fill(0.0);
                let k = s / 2;
                if s % 2 == 0 {
                    // lead moves: z = (dx_k, 0)
                    diff(k, &mut z[..d]);
                } else {
                    // lag moves: z = (0, dx_k)
                    diff(k, &mut z[d..]);
                }
            }
            Transform::LeadLagTimeAug => {
                debug_assert_eq!(z.len(), 2 * d + 1);
                z.fill(0.0);
                let k = s / 2;
                if s % 2 == 0 {
                    diff(k, &mut z[..d]);
                } else {
                    diff(k, &mut z[d..2 * d]);
                }
                z[2 * d] = 1.0 / (2 * (self.len - 1)) as f64;
            }
        }
        self.step += 1;
        true
    }
}

/// Adjoint of the transformed-increment map: scatter a gradient with respect
/// to the increments of the *transformed* path back onto the original path
/// points. `grad_z` is `[num_steps, out_dim]` row-major; output is
/// `[len, dim]`, accumulated into `grad_x`.
pub fn increments_vjp(
    transform: Transform,
    grad_z: &[f64],
    len: usize,
    dim: usize,
    grad_x: &mut [f64],
) {
    let steps = transform.out_len(len) - 1;
    let od = transform.out_dim(dim);
    assert_eq!(grad_z.len(), steps * od);
    assert_eq!(grad_x.len(), len * dim);
    // For every step s, the transformed increment is (x_{k+1} - x_k) routed
    // into some block of coordinates; the adjoint adds +g to x_{k+1} and -g
    // to x_k for the routed block (the time channel has zero dependence on x).
    for s in 0..steps {
        let g = &grad_z[s * od..(s + 1) * od];
        match transform {
            Transform::None | Transform::TimeAug => {
                let k = s;
                for j in 0..dim {
                    grad_x[(k + 1) * dim + j] += g[j];
                    grad_x[k * dim + j] -= g[j];
                }
            }
            Transform::LeadLag | Transform::LeadLagTimeAug => {
                let k = s / 2;
                let block = if s % 2 == 0 { 0 } else { dim };
                for j in 0..dim {
                    grad_x[(k + 1) * dim + j] += g[block + j];
                    grad_x[k * dim + j] -= g[block + j];
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn time_augment_shapes_and_values() {
        let p = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // 3 points, d=2
        let t = time_augment(&p, 3, 2);
        assert_eq!(t.len(), 9);
        assert_eq!(&t[0..3], &[1.0, 2.0, 0.0]);
        assert_eq!(&t[3..6], &[3.0, 4.0, 0.5]);
        assert_eq!(&t[6..9], &[5.0, 6.0, 1.0]);
    }

    #[test]
    fn lead_lag_matches_definition() {
        let p = [1.0, 2.0, 3.0]; // 3 points, d=1
        let ll = lead_lag(&p, 3, 1);
        // points: (1,1) (2,1) (2,2) (3,2) (3,3)
        assert_eq!(ll, vec![1.0, 1.0, 2.0, 1.0, 2.0, 2.0, 3.0, 2.0, 3.0, 3.0]);
    }

    #[test]
    fn stream_matches_materialised_increments() {
        check("on-the-fly increments == materialised", 40, |g| {
            let len = g.usize_in(2, 12);
            let dim = g.usize_in(1, 4);
            let path = g.path(len, dim, 1.0);
            for tr in [
                Transform::None,
                Transform::TimeAug,
                Transform::LeadLag,
                Transform::LeadLagTimeAug,
            ] {
                let mat = apply(tr, &path, len, dim);
                let olen = tr.out_len(len);
                let od = tr.out_dim(dim);
                let mut stream = IncrementStream::new(&path, len, dim, tr);
                let mut z = vec![0.0; od];
                for s in 0..olen - 1 {
                    assert!(stream.next_into(&mut z));
                    for j in 0..od {
                        let want = mat[(s + 1) * od + j] - mat[s * od + j];
                        assert!(
                            (z[j] - want).abs() < 1e-12,
                            "tr={tr:?} s={s} j={j}: {} vs {want}",
                            z[j]
                        );
                    }
                }
                assert!(!stream.next_into(&mut z));
            }
        });
    }

    #[test]
    fn basepoint_prepends_origin() {
        let p = [1.0, 2.0];
        let b = basepoint(&p, 1, 2);
        assert_eq!(b, vec![0.0, 0.0, 1.0, 2.0]);
    }

    #[test]
    fn increments_vjp_matches_finite_difference() {
        check("transform increment vjp", 20, |g| {
            let len = g.usize_in(2, 6);
            let dim = g.usize_in(1, 3);
            let path = g.path(len, dim, 1.0);
            for tr in [Transform::None, Transform::TimeAug, Transform::LeadLag] {
                let steps = tr.out_len(len) - 1;
                let od = tr.out_dim(dim);
                // random cotangent on increments
                let gz = g.normal_vec(steps * od);
                let mut gx = vec![0.0; len * dim];
                increments_vjp(tr, &gz, len, dim, &mut gx);
                // F(x) = sum_s <gz_s, z_s(x)>; check dF/dx via finite diff
                let f = |p: &[f64]| -> f64 {
                    let mut stream = IncrementStream::new(p, len, dim, tr);
                    let mut z = vec![0.0; od];
                    let mut acc = 0.0;
                    let mut s = 0;
                    while stream.next_into(&mut z) {
                        for j in 0..od {
                            acc += gz[s * od + j] * z[j];
                        }
                        s += 1;
                    }
                    acc
                };
                let eps = 1e-6;
                for i in 0..len * dim {
                    let mut pp = path.to_vec();
                    pp[i] += eps;
                    let mut pm = path.to_vec();
                    pm[i] -= eps;
                    let fd = (f(&pp) - f(&pm)) / (2.0 * eps);
                    assert!(
                        (fd - gx[i]).abs() < 1e-5,
                        "tr={tr:?} i={i}: fd={fd} vjp={}",
                        gx[i]
                    );
                }
            }
        });
    }
}
