//! Configuration system: defaults ← config file (KEY=VALUE) ← environment
//! (`PYSIGLIB_*`) ← CLI flags, in increasing precedence. A from-scratch
//! stand-in for serde+figment, with typed accessors and validation.

use std::collections::HashMap;
use std::time::Duration;

/// Read-once cached `PYSIGLIB_*` runtime knobs.
///
/// Every `getenv` on the library's compute paths funnels through these
/// accessors; each variable is read **once per process** (a `OnceLock`
/// cache) and the parsed value is served from then on. Two consequences:
///
/// * No `set_var`-vs-`getenv` race: mutating the environment from a test
///   thread can no longer race a concurrent `getenv` in a sibling sweep
///   (a libc-level data race that used to force the thread-count property
///   test into its own single-test binary). Tests and benches that sweep
///   worker counts use [`crate::util::pool::set_thread_override`] instead.
/// * Knobs are process-stable: a compiled plan or tile schedule never sees
///   the environment change under it mid-run.
///
/// `siglint`'s `env_discipline` rule enforces that raw `std::env::var`
/// reads appear only in this file.
pub mod env {
    use std::sync::OnceLock;

    fn read_usize(name: &str, min: usize) -> Option<usize> {
        std::env::var(name)
            .ok()?
            .parse::<usize>()
            .ok()
            .filter(|&v| v >= min)
    }

    /// `PYSIGLIB_THREADS` (worker threads, at least 1), read once.
    pub fn threads() -> Option<usize> {
        static CACHE: OnceLock<Option<usize>> = OnceLock::new();
        *CACHE.get_or_init(|| read_usize("PYSIGLIB_THREADS", 1))
    }

    /// `PYSIGLIB_TILE` (Gram tile edge, at least 1), read once.
    pub fn tile() -> Option<usize> {
        static CACHE: OnceLock<Option<usize>> = OnceLock::new();
        *CACHE.get_or_init(|| read_usize("PYSIGLIB_TILE", 1))
    }

    /// `PYSIGLIB_LANES` (lane width; 0 = scalar), read once, un-normalised
    /// (callers snap to a supported width).
    pub fn lanes() -> Option<usize> {
        static CACHE: OnceLock<Option<usize>> = OnceLock::new();
        *CACHE.get_or_init(|| read_usize("PYSIGLIB_LANES", 0))
    }
}

/// Fully-resolved service/compute configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct Config {
    /// Worker threads for batch compute (0 = all cores).
    pub threads: usize,
    /// Dynamic batcher: flush at this many queued items per shape group.
    pub max_batch: usize,
    /// Dynamic batcher: flush a group when its head has waited this long.
    pub max_wait: Duration,
    /// Dynamic batcher: admission cap per shape group; requests beyond it
    /// are shed with a typed `Overloaded` response.
    pub queue_cap: usize,
    /// Dynamic batcher: admission cap across all groups together.
    pub global_cap: usize,
    /// Per-request deadline, measured from enqueue (`None` = no deadline).
    /// Work past its deadline is answered `DeadlineExceeded`, not computed.
    pub deadline: Option<Duration>,
    /// Directory for corpus snapshots (empty = persistence disabled). The
    /// server snapshots here on drain and restores from here on start.
    pub snapshot_dir: String,
    /// TCP bind address for `serve`.
    pub bind: String,
    /// Artifact directory for the PJRT runtime.
    pub artifacts_dir: String,
    /// Prefer PJRT artifacts when shapes match.
    pub use_pjrt: bool,
    /// Default truncation depth for signature ops.
    pub default_depth: usize,
    /// Default dyadic order for kernel ops.
    pub default_dyadic: u32,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            threads: 0,
            max_batch: 128,
            max_wait: Duration::from_millis(2),
            queue_cap: 4096,
            global_cap: 65536,
            deadline: None,
            snapshot_dir: String::new(),
            bind: "127.0.0.1:7462".to_string(),
            artifacts_dir: "artifacts".to_string(),
            use_pjrt: false,
            default_depth: 4,
            default_dyadic: 0,
        }
    }
}

/// Error with the offending key, for actionable messages.
#[derive(Debug, PartialEq, Eq)]
pub enum ConfigError {
    Invalid {
        key: String,
        value: String,
        reason: String,
    },
    UnknownKey(String),
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::Invalid { key, value, reason } => {
                write!(f, "invalid value for {key}: {value:?} ({reason})")
            }
            ConfigError::UnknownKey(key) => write!(f, "unknown configuration key {key:?}"),
        }
    }
}

impl std::error::Error for ConfigError {}

impl Config {
    /// Apply `KEY=VALUE` lines (comments with '#', blank lines ignored).
    pub fn apply_file_text(&mut self, text: &str) -> Result<(), ConfigError> {
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| ConfigError::Invalid {
                key: line.to_string(),
                value: String::new(),
                reason: "expected KEY=VALUE".into(),
            })?;
            self.set(k.trim(), v.trim())?;
        }
        Ok(())
    }

    /// Apply `PYSIGLIB_*` environment variables.
    pub fn apply_env(&mut self) -> Result<(), ConfigError> {
        let vars: HashMap<String, String> = std::env::vars().collect();
        for (key, cfg_key) in [
            ("PYSIGLIB_THREADS", "threads"),
            ("PYSIGLIB_MAX_BATCH", "max_batch"),
            ("PYSIGLIB_MAX_WAIT_US", "max_wait_us"),
            ("PYSIGLIB_QUEUE_CAP", "queue_cap"),
            ("PYSIGLIB_GLOBAL_QUEUE_CAP", "global_cap"),
            ("PYSIGLIB_DEADLINE_US", "deadline_us"),
            ("PYSIGLIB_SNAPSHOT_DIR", "snapshot_dir"),
            ("PYSIGLIB_BIND", "bind"),
            ("PYSIGLIB_ARTIFACTS", "artifacts_dir"),
            ("PYSIGLIB_USE_PJRT", "use_pjrt"),
        ] {
            if let Some(v) = vars.get(key) {
                self.set(cfg_key, v)?;
            }
        }
        Ok(())
    }

    /// Set one key from its string form.
    pub fn set(&mut self, key: &str, value: &str) -> Result<(), ConfigError> {
        let bad = |reason: &str| ConfigError::Invalid {
            key: key.to_string(),
            value: value.to_string(),
            reason: reason.to_string(),
        };
        match key {
            "threads" => self.threads = value.parse().map_err(|_| bad("not an integer"))?,
            "max_batch" => {
                self.max_batch = value.parse().map_err(|_| bad("not an integer"))?;
                if self.max_batch == 0 {
                    return Err(bad("must be >= 1"));
                }
            }
            "max_wait_us" => {
                let us: u64 = value.parse().map_err(|_| bad("not an integer"))?;
                self.max_wait = Duration::from_micros(us);
            }
            "queue_cap" => {
                self.queue_cap = value.parse().map_err(|_| bad("not an integer"))?;
                if self.queue_cap == 0 {
                    return Err(bad("must be >= 1"));
                }
            }
            "global_cap" => {
                self.global_cap = value.parse().map_err(|_| bad("not an integer"))?;
                if self.global_cap == 0 {
                    return Err(bad("must be >= 1"));
                }
            }
            "deadline_us" => {
                let us: u64 = value.parse().map_err(|_| bad("not an integer"))?;
                // 0 disables the deadline rather than rejecting everything.
                self.deadline = (us > 0).then(|| Duration::from_micros(us));
            }
            "snapshot_dir" => self.snapshot_dir = value.to_string(),
            "bind" => self.bind = value.to_string(),
            "artifacts_dir" => self.artifacts_dir = value.to_string(),
            "use_pjrt" => {
                self.use_pjrt = match value {
                    "1" | "true" | "yes" => true,
                    "0" | "false" | "no" => false,
                    _ => return Err(bad("expected true/false")),
                }
            }
            "default_depth" => {
                self.default_depth = value.parse().map_err(|_| bad("not an integer"))?;
                if self.default_depth == 0 {
                    return Err(bad("must be >= 1"));
                }
            }
            "default_dyadic" => {
                self.default_dyadic = value.parse().map_err(|_| bad("not an integer"))?;
                if self.default_dyadic > 12 {
                    return Err(bad("dyadic order > 12 is certainly a mistake"));
                }
            }
            other => return Err(ConfigError::UnknownKey(other.to_string())),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = Config::default();
        assert!(c.max_batch >= 1);
        assert!(c.default_depth >= 1);
    }

    #[test]
    fn file_text_applies_in_order() {
        let mut c = Config::default();
        c.apply_file_text("# comment\nmax_batch=64\nthreads = 3\nuse_pjrt=true\n")
            .unwrap();
        assert_eq!(c.max_batch, 64);
        assert_eq!(c.threads, 3);
        assert!(c.use_pjrt);
    }

    #[test]
    fn invalid_values_are_rejected_with_key() {
        let mut c = Config::default();
        let e = c.set("max_batch", "0").unwrap_err();
        assert!(matches!(e, ConfigError::Invalid { .. }));
        let e = c.set("nonsense", "1").unwrap_err();
        assert_eq!(e, ConfigError::UnknownKey("nonsense".into()));
    }

    #[test]
    fn wait_is_microseconds() {
        let mut c = Config::default();
        c.set("max_wait_us", "1500").unwrap();
        assert_eq!(c.max_wait, Duration::from_micros(1500));
    }

    #[test]
    fn admission_and_snapshot_knobs_parse_and_validate() {
        let mut c = Config::default();
        c.apply_file_text("queue_cap=8\nglobal_cap=32\ndeadline_us=2500\nsnapshot_dir=/tmp/snaps\n")
            .unwrap();
        assert_eq!(c.queue_cap, 8);
        assert_eq!(c.global_cap, 32);
        assert_eq!(c.deadline, Some(Duration::from_micros(2500)));
        assert_eq!(c.snapshot_dir, "/tmp/snaps");
        // 0 disables the deadline instead of instantly expiring everything.
        c.set("deadline_us", "0").unwrap();
        assert_eq!(c.deadline, None);
        assert!(c.set("queue_cap", "0").is_err());
        assert!(c.set("global_cap", "x").is_err());
    }
}
