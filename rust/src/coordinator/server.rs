//! TCP front-end for the coordinator: one reader thread per connection,
//! requests flow into the shared dynamic batcher, responses return in
//! request order per connection (concurrency comes from multiple
//! connections and from batching across them).
//!
//! Malformed-but-framed requests (validated at wire decode) are answered
//! with an `Err` response and the connection keeps serving; only
//! framing-destroying input (bad magic, absurd sizes) drops the connection.
//!
//! Admission rejections cross the wire typed: [`Response::Overloaded`]
//! becomes wire status 2 (with a `retry_after_ms` backoff hint in the
//! payload) and [`Response::DeadlineExceeded`] status 3, so clients can
//! tell "back off" from "your request was bad". The bundled [`Client`]
//! honours the hint with capped exponential backoff and deterministic
//! seeded jitter (see [`RetryPolicy`]). Shutdown drains rather than drops:
//! [`ServerHandle::stop`] closes the admission gate, flushes everything
//! already accepted, then snapshots registered corpora through the router
//! (see [`Router::with_snapshot_dir`](crate::coordinator::Router)) so the
//! next process starts warm.

use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use crate::coordinator::wire::{
    read_request, read_response, read_typed_response, write_ragged_request, write_request,
    write_typed_response, Frame, RaggedFrame, RequestFrame, WireResponse,
};
use crate::coordinator::{Batcher, Op, Request, Response};
use crate::util::rng::Rng;

/// Handle to a running server (drop or call `stop()` to shut down).
pub struct ServerHandle {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    batcher: Option<Arc<Batcher>>,
}

impl ServerHandle {
    pub fn stop(mut self) {
        self.shutdown();
    }
    /// Shutdown is a drain, not a drop: stop accepting connections, close
    /// the batcher's admission gate and flush what it already accepted
    /// (late arrivals get a typed rejection), then snapshot registered
    /// corpora if the router has a snapshot path configured.
    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Nudge the accept loop awake.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
        if let Some(batcher) = self.batcher.take() {
            batcher.drain();
            // No snapshot path configured is the common case and not an
            // error; a failed write is best-effort at this point (the
            // process is exiting) and must not panic the drop.
            let _ = batcher.router().snapshot_corpora();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Start serving on `addr` (use port 0 for an ephemeral port). Returns after
/// binding; connections are handled on background threads.
pub fn serve(addr: impl ToSocketAddrs, batcher: Arc<Batcher>) -> std::io::Result<ServerHandle> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = stop.clone();
    let accept_batcher = batcher.clone();
    let accept_thread = std::thread::spawn(move || {
        for conn in listener.incoming() {
            if stop2.load(Ordering::SeqCst) {
                break;
            }
            match conn {
                Ok(stream) => {
                    let batcher = accept_batcher.clone();
                    std::thread::spawn(move || {
                        let _ = handle_connection(stream, batcher);
                    });
                }
                Err(_) => break,
            }
        }
    });
    Ok(ServerHandle {
        addr: local,
        stop,
        accept_thread: Some(accept_thread),
        batcher: Some(batcher),
    })
}

fn split_payload(frame: &Frame) -> Result<(Vec<f64>, Option<Vec<f64>>), String> {
    let per = frame.len * frame.dim;
    match frame.op {
        Op::SigKernel { .. } | Op::SigKernelGrad { .. } => {
            if frame.values.len() != 2 * per {
                return Err(format!(
                    "kernel op expects 2·len·dim = {} values, got {}",
                    2 * per,
                    frame.values.len()
                ));
            }
            match (frame.values.get(..per), frame.values.get(per..)) {
                (Some(x), Some(y)) => Ok((x.to_vec(), Some(y.to_vec()))),
                _ => Err("internal: kernel payload split out of bounds".to_string()),
            }
        }
        _ => {
            if frame.values.len() != per {
                return Err(format!(
                    "expected len·dim = {per} values, got {}",
                    frame.values.len()
                ));
            }
            Ok((frame.values.clone(), None))
        }
    }
}

fn handle_single(frame: Frame, batcher: &Batcher) -> WireResponse {
    let (data, data2) = match split_payload(&frame) {
        Ok(p) => p,
        Err(e) => return WireResponse::Error(e),
    };
    let (tx, rx) = mpsc::channel();
    batcher.submit(Request {
        op: frame.op,
        len: frame.len,
        dim: frame.dim,
        data,
        data2,
        reply: tx,
    });
    match rx.recv() {
        Ok(Response::Values(v)) => WireResponse::Values(v),
        Ok(Response::Error(e)) => WireResponse::Error(e),
        Ok(Response::Overloaded { retry_after_ms }) => WireResponse::Overloaded { retry_after_ms },
        Ok(Response::DeadlineExceeded) => WireResponse::DeadlineExceeded,
        Ok(Response::ShuttingDown) | Err(_) => {
            WireResponse::Error("server shutting down".to_string())
        }
    }
}

fn handle_connection(mut stream: TcpStream, batcher: Arc<Batcher>) -> std::io::Result<()> {
    let mut out = stream.try_clone()?;
    while let Some(decoded) = read_request(&mut stream)? {
        let resp: WireResponse = match decoded {
            // Malformed but framed: answer with the decode error and keep
            // the connection alive.
            Err(e) => WireResponse::Error(e.to_string()),
            Ok(RequestFrame::Single(frame)) => handle_single(frame, &batcher),
            // A ragged frame is already a batch: run it directly — unless
            // the server is draining (ragged frames bypass the batcher's
            // queues, so the admission gate is checked here).
            Ok(RequestFrame::Ragged(frame)) => {
                if !batcher.accepting() {
                    WireResponse::Error("server shutting down".to_string())
                } else {
                    match batcher.execute_ragged(&frame) {
                        Ok(v) => WireResponse::Values(v),
                        Err(e) => WireResponse::Error(e.to_string()),
                    }
                }
            }
        };
        write_typed_response(&mut out, &resp)?;
    }
    Ok(())
}

/// Client-side retry policy for [`WireResponse::Overloaded`] rejections:
/// capped exponential backoff with deterministic seeded jitter.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Total attempts (first try included). 1 disables retrying.
    pub max_attempts: u32,
    /// Backoff before the first retry, in milliseconds; doubles per retry.
    pub base_ms: u64,
    /// Ceiling on the exponential term (the jitter rides on top).
    pub cap_ms: u64,
    /// Jitter seed. Two clients with different seeds desynchronise their
    /// retries; the same seed replays the same delays, which is what the
    /// fault-injection tests pin down.
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 4,
            base_ms: 1,
            cap_ms: 100,
            seed: 0x5e11,
        }
    }
}

/// Next backoff delay in ms. The exponential term honours the server's
/// `retry_after_ms` hint as a floor and `cap_ms` as a ceiling; jitter adds
/// up to half the delay on top; and the result is clamped strictly above
/// the previous delay, so the sequence is monotonically increasing even
/// once the cap is reached.
fn next_backoff(
    policy: &RetryPolicy,
    attempt: u32,
    hint_ms: u64,
    prev_ms: u64,
    rng: &mut Rng,
) -> u64 {
    let exp = policy
        .base_ms
        .saturating_mul(1u64 << attempt.min(20))
        .max(hint_ms)
        .min(policy.cap_ms.max(1));
    let jitter = rng.next_u64() % (exp / 2 + 1);
    (exp + jitter).max(prev_ms + 1)
}

/// Blocking client for the wire protocol.
pub struct Client {
    stream: TcpStream,
    retry: RetryPolicy,
    rng: Rng,
    backoffs: Vec<u64>,
}

impl Client {
    pub fn connect(addr: impl ToSocketAddrs) -> std::io::Result<Client> {
        let retry = RetryPolicy::default();
        Ok(Client {
            stream: TcpStream::connect(addr)?,
            retry,
            rng: Rng::new(retry.seed),
            backoffs: Vec::new(),
        })
    }

    /// Replace the retry policy (and reseed the jitter stream from it).
    pub fn with_retry(mut self, policy: RetryPolicy) -> Client {
        self.retry = policy;
        self.rng = Rng::new(policy.seed);
        self
    }

    /// Backoff delays (ms) slept so far across every retried call, in
    /// order — observability for tests and callers tuning the policy.
    pub fn backoffs_ms(&self) -> &[u64] {
        &self.backoffs
    }

    /// Send one request and read the typed response (overload and deadline
    /// rejections stay distinguishable from errors). No retrying.
    pub fn call_typed(
        &mut self,
        op: Op,
        len: usize,
        dim: usize,
        values: Vec<f64>,
    ) -> std::io::Result<WireResponse> {
        write_request(
            &mut self.stream,
            &Frame {
                op,
                len,
                dim,
                values,
            },
        )?;
        read_typed_response(&mut self.stream)
    }

    /// Like [`call_typed`](Client::call_typed), but on
    /// [`WireResponse::Overloaded`] the client sleeps out the backoff
    /// (policy delay, floored by the server's hint) and retries, up to
    /// [`RetryPolicy::max_attempts`]. Any other response returns
    /// immediately; exhausting the attempts returns the last rejection.
    pub fn call_with_retry(
        &mut self,
        op: Op,
        len: usize,
        dim: usize,
        values: &[f64],
    ) -> std::io::Result<WireResponse> {
        let attempts = self.retry.max_attempts.max(1);
        let mut prev_ms = 0u64;
        let mut attempt = 0u32;
        loop {
            let resp = self.call_typed(op, len, dim, values.to_vec())?;
            let hint = match resp {
                WireResponse::Overloaded { retry_after_ms } if attempt + 1 < attempts => {
                    retry_after_ms
                }
                other => return Ok(other),
            };
            let policy = self.retry;
            let delay = next_backoff(&policy, attempt, hint, prev_ms, &mut self.rng);
            prev_ms = delay;
            self.backoffs.push(delay);
            std::thread::sleep(Duration::from_millis(delay));
            attempt += 1;
        }
    }

    /// Send one request and wait for its response.
    pub fn call(
        &mut self,
        op: Op,
        len: usize,
        dim: usize,
        values: Vec<f64>,
    ) -> std::io::Result<Result<Vec<f64>, String>> {
        write_request(
            &mut self.stream,
            &Frame {
                op,
                len,
                dim,
                values,
            },
        )?;
        read_response(&mut self.stream)
    }

    /// Send one ragged-batch request (paths back-to-back, per-path lengths)
    /// and wait for its flat response.
    pub fn call_ragged(
        &mut self,
        op: Op,
        dim: usize,
        lengths: Vec<usize>,
        values: Vec<f64>,
    ) -> std::io::Result<Result<Vec<f64>, String>> {
        write_ragged_request(
            &mut self.stream,
            &RaggedFrame {
                op,
                dim,
                lengths,
                values,
            },
        )?;
        read_response(&mut self.stream)
    }

    /// Convenience: truncated signature of one path.
    pub fn signature(
        &mut self,
        path: &[f64],
        len: usize,
        dim: usize,
        depth: u32,
    ) -> std::io::Result<Result<Vec<f64>, String>> {
        self.call(
            Op::Signature {
                depth,
                transform: 0,
            },
            len,
            dim,
            path.to_vec(),
        )
    }

    /// Convenience: signatures of a ragged batch of paths in one round trip.
    /// Returns `[batch, sig_length(dim, depth)]` flattened.
    pub fn batch_signature_ragged(
        &mut self,
        paths: &[&[f64]],
        dim: usize,
        depth: u32,
    ) -> std::io::Result<Result<Vec<f64>, String>> {
        let mut lengths = Vec::with_capacity(paths.len());
        let mut values = Vec::new();
        for p in paths {
            lengths.push(if dim == 0 { 0 } else { p.len() / dim });
            values.extend_from_slice(p);
        }
        self.call_ragged(
            Op::Signature {
                depth,
                transform: 0,
            },
            dim,
            lengths,
            values,
        )
    }

    /// Convenience: signature kernel of a pair of equal-shape paths.
    pub fn sig_kernel(
        &mut self,
        x: &[f64],
        y: &[f64],
        len: usize,
        dim: usize,
    ) -> std::io::Result<Result<f64, String>> {
        let mut values = x.to_vec();
        values.extend_from_slice(y);
        let r = self.call(
            Op::SigKernel {
                lam1: 0,
                lam2: 0,
                transform: 0,
                scheme: 0,
            },
            len,
            dim,
            values,
        )?;
        Ok(r.and_then(|v| {
            v.first()
                .copied()
                .ok_or_else(|| "empty response from server".to_string())
        }))
    }

    /// Convenience: low-rank (Nyström, `rank` landmarks) MMD² between two
    /// corpora of arbitrary-length paths in one round trip.
    pub fn mmd2_lowrank(
        &mut self,
        xs: &[&[f64]],
        ys: &[&[f64]],
        dim: usize,
        rank: u32,
    ) -> std::io::Result<Result<f64, String>> {
        let mut lengths = Vec::with_capacity(xs.len() + ys.len());
        let mut values = Vec::new();
        for p in xs.iter().chain(ys.iter()) {
            lengths.push(if dim == 0 { 0 } else { p.len() / dim });
            values.extend_from_slice(p);
        }
        let r = self.call_ragged(
            Op::Mmd2LowRank {
                rank,
                nx: xs.len() as u32,
                transform: 0,
            },
            dim,
            lengths,
            values,
        )?;
        Ok(r.and_then(|v| {
            v.first()
                .copied()
                .ok_or_else(|| "empty response from server".to_string())
        }))
    }

    /// Flatten a slice-of-paths into the ragged wire layout.
    fn ragged_payload(paths: &[&[f64]], dim: usize) -> (Vec<usize>, Vec<f64>) {
        let mut lengths = Vec::with_capacity(paths.len());
        let mut values = Vec::new();
        for p in paths {
            lengths.push(if dim == 0 { 0 } else { p.len() / dim });
            values.extend_from_slice(p);
        }
        (lengths, values)
    }

    /// Convenience: register a corpus of arbitrary-length paths; returns
    /// its (content-hash deduplicated) id for `append_corpus` /
    /// `mmd2_corpus` calls.
    pub fn register_corpus(
        &mut self,
        paths: &[&[f64]],
        dim: usize,
    ) -> std::io::Result<Result<u32, String>> {
        let (lengths, values) = Self::ragged_payload(paths, dim);
        let r = self.call_ragged(Op::RegisterCorpus, dim, lengths, values)?;
        Ok(r.map(|v| v.first().copied().unwrap_or(0.0) as u32))
    }

    /// Convenience: append paths to a registered corpus; returns the new
    /// path count.
    pub fn append_corpus(
        &mut self,
        id: u32,
        paths: &[&[f64]],
        dim: usize,
    ) -> std::io::Result<Result<usize, String>> {
        let (lengths, values) = Self::ragged_payload(paths, dim);
        let r = self.call_ragged(Op::AppendCorpus { id }, dim, lengths, values)?;
        Ok(r.map(|v| v.first().copied().unwrap_or(0.0) as usize))
    }

    /// Convenience: biased MMD² between query paths and a registered
    /// corpus (`rank` = 0 → exact against the cached corpus self-Gram;
    /// `rank` > 0 → Nyström at that rank).
    pub fn mmd2_corpus(
        &mut self,
        id: u32,
        queries: &[&[f64]],
        dim: usize,
        rank: u32,
    ) -> std::io::Result<Result<f64, String>> {
        let (lengths, values) = Self::ragged_payload(queries, dim);
        let r = self.call_ragged(
            Op::Mmd2Corpus {
                id,
                rank,
                transform: 0,
            },
            dim,
            lengths,
            values,
        )?;
        Ok(r.map(|v| v.first().copied().unwrap_or(0.0)))
    }

    /// Convenience: append `points` (row-major `[n, dim]`, n ≥ 1) to path
    /// `path_idx` of a registered corpus, advancing its cached border
    /// strips in place; returns the path's new length in points.
    pub fn extend_path(
        &mut self,
        id: u32,
        path_idx: u32,
        points: &[f64],
        dim: usize,
    ) -> std::io::Result<Result<usize, String>> {
        let n = if dim == 0 { 0 } else { points.len() / dim };
        let r = self.call_ragged(
            Op::ExtendPath { id, path_idx },
            dim,
            vec![n],
            points.to_vec(),
        )?;
        Ok(r.map(|v| v.first().copied().unwrap_or(0.0) as usize))
    }

    /// Convenience: evict all but the newest `keep` paths of a registered
    /// corpus (sliding-window truncation); returns the surviving count.
    pub fn evict_corpus(
        &mut self,
        id: u32,
        keep: u32,
        dim: usize,
    ) -> std::io::Result<Result<usize, String>> {
        let r = self.call_ragged(
            Op::EvictCorpus {
                id,
                keep,
                max_age: 0,
            },
            dim,
            vec![],
            vec![],
        )?;
        Ok(r.map(|v| v.first().copied().unwrap_or(0.0) as usize))
    }

    /// Convenience: evict every path of a registered corpus older than
    /// `max_age` append ticks (registration is tick 0; each append batch
    /// advances the corpus clock by one), keeping at least `keep_floor`
    /// paths (at least one survives regardless). Returns the surviving
    /// count. `max_age` must be positive — use
    /// [`evict_corpus`](Client::evict_corpus) for the pure count bound.
    pub fn evict_corpus_by_age(
        &mut self,
        id: u32,
        max_age: u32,
        keep_floor: u32,
        dim: usize,
    ) -> std::io::Result<Result<usize, String>> {
        let r = self.call_ragged(
            Op::EvictCorpus {
                id,
                keep: keep_floor,
                max_age,
            },
            dim,
            vec![],
            vec![],
        )?;
        Ok(r.map(|v| v.first().copied().unwrap_or(0.0) as usize))
    }

    /// Convenience: snapshot every registered corpus (paths + warm derived
    /// state) to the server's configured snapshot path; returns the number
    /// of corpora written. Errors if the server has no snapshot path.
    pub fn snapshot_corpus(&mut self) -> std::io::Result<Result<usize, String>> {
        let r = self.call_ragged(Op::SnapshotCorpus, 1, vec![], vec![])?;
        Ok(r.map(|v| v.first().copied().unwrap_or(0.0) as usize))
    }

    /// Convenience: exponentially-weighted MMD² between a query window
    /// (oldest path first, newest last) and a registered corpus. `decay_bp`
    /// is the per-step weight decay in basis points (1..=10000; 10000 →
    /// uniform weights).
    pub fn mmd2_window(
        &mut self,
        id: u32,
        window: &[&[f64]],
        dim: usize,
        decay_bp: u32,
    ) -> std::io::Result<Result<f64, String>> {
        let (lengths, values) = Self::ragged_payload(window, dim);
        let r = self.call_ragged(
            Op::Mmd2Window {
                id,
                decay_bp,
                transform: 0,
            },
            dim,
            lengths,
            values,
        )?;
        Ok(r.map(|v| v.first().copied().unwrap_or(0.0)))
    }

    /// Convenience: signature kernels of (x_i, y_i) pairs of arbitrary
    /// lengths in one round trip. Returns `[pairs]`.
    pub fn sig_kernel_ragged(
        &mut self,
        pairs: &[(&[f64], &[f64])],
        dim: usize,
    ) -> std::io::Result<Result<Vec<f64>, String>> {
        let mut lengths = Vec::with_capacity(2 * pairs.len());
        let mut values = Vec::new();
        for (x, y) in pairs {
            lengths.push(if dim == 0 { 0 } else { x.len() / dim });
            lengths.push(if dim == 0 { 0 } else { y.len() / dim });
            values.extend_from_slice(x);
            values.extend_from_slice(y);
        }
        self.call_ragged(
            Op::SigKernel {
                lam1: 0,
                lam2: 0,
                transform: 0,
                scheme: 0,
            },
            dim,
            lengths,
            values,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_is_monotone_capped_and_deterministic() {
        let policy = RetryPolicy {
            max_attempts: 8,
            base_ms: 1,
            cap_ms: 16,
            seed: 42,
        };
        let run = |seed: u64| -> Vec<u64> {
            let mut rng = Rng::new(seed);
            let mut prev = 0u64;
            (0..8)
                .map(|attempt| {
                    let d = next_backoff(&policy, attempt, 0, prev, &mut rng);
                    prev = d;
                    d
                })
                .collect()
        };
        let delays = run(policy.seed);
        for w in delays.windows(2) {
            assert!(w[1] > w[0], "backoff must increase: {delays:?}");
        }
        // Cap + max jitter (half the cap) bounds every delay... except where
        // the strictly-monotone clamp has to step past it, which adds at
        // most 1 per attempt.
        for (i, d) in delays.iter().enumerate() {
            assert!(*d <= policy.cap_ms + policy.cap_ms / 2 + i as u64 + 1, "{delays:?}");
        }
        // Same seed, same delays; the server hint floors the exponential.
        assert_eq!(delays, run(policy.seed));
        let mut rng = Rng::new(7);
        let hinted = next_backoff(&policy, 0, 9, 0, &mut rng);
        assert!(hinted >= 9, "hint is a floor: {hinted}");
    }
}
