//! TCP front-end for the coordinator: one reader thread per connection,
//! requests flow into the shared dynamic batcher, responses return in
//! request order per connection (concurrency comes from multiple
//! connections and from batching across them).
//!
//! Malformed-but-framed requests (validated at wire decode) are answered
//! with an `Err` response and the connection keeps serving; only
//! framing-destroying input (bad magic, absurd sizes) drops the connection.

use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};

use crate::coordinator::wire::{
    read_request, read_response, write_ragged_request, write_request, write_response, Frame,
    RaggedFrame, RequestFrame,
};
use crate::coordinator::{Batcher, Op, Request, Response};

/// Handle to a running server (drop or call `stop()` to shut down).
pub struct ServerHandle {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    pub fn stop(mut self) {
        self.shutdown();
    }
    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Nudge the accept loop awake.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Start serving on `addr` (use port 0 for an ephemeral port). Returns after
/// binding; connections are handled on background threads.
pub fn serve(addr: impl ToSocketAddrs, batcher: Arc<Batcher>) -> std::io::Result<ServerHandle> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = stop.clone();
    let accept_thread = std::thread::spawn(move || {
        for conn in listener.incoming() {
            if stop2.load(Ordering::SeqCst) {
                break;
            }
            match conn {
                Ok(stream) => {
                    let batcher = batcher.clone();
                    std::thread::spawn(move || {
                        let _ = handle_connection(stream, batcher);
                    });
                }
                Err(_) => break,
            }
        }
    });
    Ok(ServerHandle {
        addr: local,
        stop,
        accept_thread: Some(accept_thread),
    })
}

fn split_payload(frame: &Frame) -> Result<(Vec<f64>, Option<Vec<f64>>), String> {
    let per = frame.len * frame.dim;
    match frame.op {
        Op::SigKernel { .. } | Op::SigKernelGrad { .. } => {
            if frame.values.len() != 2 * per {
                return Err(format!(
                    "kernel op expects 2·len·dim = {} values, got {}",
                    2 * per,
                    frame.values.len()
                ));
            }
            match (frame.values.get(..per), frame.values.get(per..)) {
                (Some(x), Some(y)) => Ok((x.to_vec(), Some(y.to_vec()))),
                _ => Err("internal: kernel payload split out of bounds".to_string()),
            }
        }
        _ => {
            if frame.values.len() != per {
                return Err(format!(
                    "expected len·dim = {per} values, got {}",
                    frame.values.len()
                ));
            }
            Ok((frame.values.clone(), None))
        }
    }
}

fn handle_single(frame: Frame, batcher: &Batcher) -> Result<Vec<f64>, String> {
    let (data, data2) = split_payload(&frame)?;
    let (tx, rx) = mpsc::channel();
    batcher.submit(Request {
        op: frame.op,
        len: frame.len,
        dim: frame.dim,
        data,
        data2,
        reply: tx,
    });
    match rx.recv() {
        Ok(Response::Values(v)) => Ok(v),
        Ok(Response::Error(e)) => Err(e),
        Err(_) => Err("server shutting down".to_string()),
    }
}

fn handle_connection(mut stream: TcpStream, batcher: Arc<Batcher>) -> std::io::Result<()> {
    let mut out = stream.try_clone()?;
    while let Some(decoded) = read_request(&mut stream)? {
        let result: Result<Vec<f64>, String> = match decoded {
            // Malformed but framed: answer with the decode error and keep
            // the connection alive.
            Err(e) => Err(e.to_string()),
            Ok(RequestFrame::Single(frame)) => handle_single(frame, &batcher),
            // A ragged frame is already a batch: run it directly.
            Ok(RequestFrame::Ragged(frame)) => {
                batcher.execute_ragged(&frame).map_err(|e| e.to_string())
            }
        };
        write_response(&mut out, &result)?;
    }
    Ok(())
}

/// Blocking client for the wire protocol.
pub struct Client {
    stream: TcpStream,
}

impl Client {
    pub fn connect(addr: impl ToSocketAddrs) -> std::io::Result<Client> {
        Ok(Client {
            stream: TcpStream::connect(addr)?,
        })
    }

    /// Send one request and wait for its response.
    pub fn call(
        &mut self,
        op: Op,
        len: usize,
        dim: usize,
        values: Vec<f64>,
    ) -> std::io::Result<Result<Vec<f64>, String>> {
        write_request(
            &mut self.stream,
            &Frame {
                op,
                len,
                dim,
                values,
            },
        )?;
        read_response(&mut self.stream)
    }

    /// Send one ragged-batch request (paths back-to-back, per-path lengths)
    /// and wait for its flat response.
    pub fn call_ragged(
        &mut self,
        op: Op,
        dim: usize,
        lengths: Vec<usize>,
        values: Vec<f64>,
    ) -> std::io::Result<Result<Vec<f64>, String>> {
        write_ragged_request(
            &mut self.stream,
            &RaggedFrame {
                op,
                dim,
                lengths,
                values,
            },
        )?;
        read_response(&mut self.stream)
    }

    /// Convenience: truncated signature of one path.
    pub fn signature(
        &mut self,
        path: &[f64],
        len: usize,
        dim: usize,
        depth: u32,
    ) -> std::io::Result<Result<Vec<f64>, String>> {
        self.call(
            Op::Signature {
                depth,
                transform: 0,
            },
            len,
            dim,
            path.to_vec(),
        )
    }

    /// Convenience: signatures of a ragged batch of paths in one round trip.
    /// Returns `[batch, sig_length(dim, depth)]` flattened.
    pub fn batch_signature_ragged(
        &mut self,
        paths: &[&[f64]],
        dim: usize,
        depth: u32,
    ) -> std::io::Result<Result<Vec<f64>, String>> {
        let mut lengths = Vec::with_capacity(paths.len());
        let mut values = Vec::new();
        for p in paths {
            lengths.push(if dim == 0 { 0 } else { p.len() / dim });
            values.extend_from_slice(p);
        }
        self.call_ragged(
            Op::Signature {
                depth,
                transform: 0,
            },
            dim,
            lengths,
            values,
        )
    }

    /// Convenience: signature kernel of a pair of equal-shape paths.
    pub fn sig_kernel(
        &mut self,
        x: &[f64],
        y: &[f64],
        len: usize,
        dim: usize,
    ) -> std::io::Result<Result<f64, String>> {
        let mut values = x.to_vec();
        values.extend_from_slice(y);
        let r = self.call(
            Op::SigKernel {
                lam1: 0,
                lam2: 0,
                transform: 0,
                scheme: 0,
            },
            len,
            dim,
            values,
        )?;
        Ok(r.and_then(|v| {
            v.first()
                .copied()
                .ok_or_else(|| "empty response from server".to_string())
        }))
    }

    /// Convenience: low-rank (Nyström, `rank` landmarks) MMD² between two
    /// corpora of arbitrary-length paths in one round trip.
    pub fn mmd2_lowrank(
        &mut self,
        xs: &[&[f64]],
        ys: &[&[f64]],
        dim: usize,
        rank: u32,
    ) -> std::io::Result<Result<f64, String>> {
        let mut lengths = Vec::with_capacity(xs.len() + ys.len());
        let mut values = Vec::new();
        for p in xs.iter().chain(ys.iter()) {
            lengths.push(if dim == 0 { 0 } else { p.len() / dim });
            values.extend_from_slice(p);
        }
        let r = self.call_ragged(
            Op::Mmd2LowRank {
                rank,
                nx: xs.len() as u32,
                transform: 0,
            },
            dim,
            lengths,
            values,
        )?;
        Ok(r.and_then(|v| {
            v.first()
                .copied()
                .ok_or_else(|| "empty response from server".to_string())
        }))
    }

    /// Flatten a slice-of-paths into the ragged wire layout.
    fn ragged_payload(paths: &[&[f64]], dim: usize) -> (Vec<usize>, Vec<f64>) {
        let mut lengths = Vec::with_capacity(paths.len());
        let mut values = Vec::new();
        for p in paths {
            lengths.push(if dim == 0 { 0 } else { p.len() / dim });
            values.extend_from_slice(p);
        }
        (lengths, values)
    }

    /// Convenience: register a corpus of arbitrary-length paths; returns
    /// its (content-hash deduplicated) id for `append_corpus` /
    /// `mmd2_corpus` calls.
    pub fn register_corpus(
        &mut self,
        paths: &[&[f64]],
        dim: usize,
    ) -> std::io::Result<Result<u32, String>> {
        let (lengths, values) = Self::ragged_payload(paths, dim);
        let r = self.call_ragged(Op::RegisterCorpus, dim, lengths, values)?;
        Ok(r.map(|v| v.first().copied().unwrap_or(0.0) as u32))
    }

    /// Convenience: append paths to a registered corpus; returns the new
    /// path count.
    pub fn append_corpus(
        &mut self,
        id: u32,
        paths: &[&[f64]],
        dim: usize,
    ) -> std::io::Result<Result<usize, String>> {
        let (lengths, values) = Self::ragged_payload(paths, dim);
        let r = self.call_ragged(Op::AppendCorpus { id }, dim, lengths, values)?;
        Ok(r.map(|v| v.first().copied().unwrap_or(0.0) as usize))
    }

    /// Convenience: biased MMD² between query paths and a registered
    /// corpus (`rank` = 0 → exact against the cached corpus self-Gram;
    /// `rank` > 0 → Nyström at that rank).
    pub fn mmd2_corpus(
        &mut self,
        id: u32,
        queries: &[&[f64]],
        dim: usize,
        rank: u32,
    ) -> std::io::Result<Result<f64, String>> {
        let (lengths, values) = Self::ragged_payload(queries, dim);
        let r = self.call_ragged(
            Op::Mmd2Corpus {
                id,
                rank,
                transform: 0,
            },
            dim,
            lengths,
            values,
        )?;
        Ok(r.map(|v| v.first().copied().unwrap_or(0.0)))
    }

    /// Convenience: append `points` (row-major `[n, dim]`, n ≥ 1) to path
    /// `path_idx` of a registered corpus, advancing its cached border
    /// strips in place; returns the path's new length in points.
    pub fn extend_path(
        &mut self,
        id: u32,
        path_idx: u32,
        points: &[f64],
        dim: usize,
    ) -> std::io::Result<Result<usize, String>> {
        let n = if dim == 0 { 0 } else { points.len() / dim };
        let r = self.call_ragged(
            Op::ExtendPath { id, path_idx },
            dim,
            vec![n],
            points.to_vec(),
        )?;
        Ok(r.map(|v| v.first().copied().unwrap_or(0.0) as usize))
    }

    /// Convenience: evict all but the newest `keep` paths of a registered
    /// corpus (sliding-window truncation); returns the surviving count.
    pub fn evict_corpus(
        &mut self,
        id: u32,
        keep: u32,
        dim: usize,
    ) -> std::io::Result<Result<usize, String>> {
        let r = self.call_ragged(
            Op::EvictCorpus {
                id,
                keep,
                max_age: 0,
            },
            dim,
            vec![],
            vec![],
        )?;
        Ok(r.map(|v| v.first().copied().unwrap_or(0.0) as usize))
    }

    /// Convenience: evict every path of a registered corpus older than
    /// `max_age` append ticks (registration is tick 0; each append batch
    /// advances the corpus clock by one), keeping at least `keep_floor`
    /// paths (at least one survives regardless). Returns the surviving
    /// count. `max_age` must be positive — use
    /// [`evict_corpus`](Client::evict_corpus) for the pure count bound.
    pub fn evict_corpus_by_age(
        &mut self,
        id: u32,
        max_age: u32,
        keep_floor: u32,
        dim: usize,
    ) -> std::io::Result<Result<usize, String>> {
        let r = self.call_ragged(
            Op::EvictCorpus {
                id,
                keep: keep_floor,
                max_age,
            },
            dim,
            vec![],
            vec![],
        )?;
        Ok(r.map(|v| v.first().copied().unwrap_or(0.0) as usize))
    }

    /// Convenience: exponentially-weighted MMD² between a query window
    /// (oldest path first, newest last) and a registered corpus. `decay_bp`
    /// is the per-step weight decay in basis points (1..=10000; 10000 →
    /// uniform weights).
    pub fn mmd2_window(
        &mut self,
        id: u32,
        window: &[&[f64]],
        dim: usize,
        decay_bp: u32,
    ) -> std::io::Result<Result<f64, String>> {
        let (lengths, values) = Self::ragged_payload(window, dim);
        let r = self.call_ragged(
            Op::Mmd2Window {
                id,
                decay_bp,
                transform: 0,
            },
            dim,
            lengths,
            values,
        )?;
        Ok(r.map(|v| v.first().copied().unwrap_or(0.0)))
    }

    /// Convenience: signature kernels of (x_i, y_i) pairs of arbitrary
    /// lengths in one round trip. Returns `[pairs]`.
    pub fn sig_kernel_ragged(
        &mut self,
        pairs: &[(&[f64], &[f64])],
        dim: usize,
    ) -> std::io::Result<Result<Vec<f64>, String>> {
        let mut lengths = Vec::with_capacity(2 * pairs.len());
        let mut values = Vec::new();
        for (x, y) in pairs {
            lengths.push(if dim == 0 { 0 } else { x.len() / dim });
            lengths.push(if dim == 0 { 0 } else { y.len() / dim });
            values.extend_from_slice(x);
            values.extend_from_slice(y);
        }
        self.call_ragged(
            Op::SigKernel {
                lam1: 0,
                lam2: 0,
                transform: 0,
                scheme: 0,
            },
            dim,
            lengths,
            values,
        )
    }
}
