//! Serving metrics: request / batch counters and latency aggregates,
//! lock-free on the hot path (atomics; latencies in integer microseconds).

use std::sync::atomic::{AtomicU64, Ordering};

use crate::coordinator::OP_CODE_COUNT;

/// Aggregated service metrics. All methods are thread-safe.
pub struct Metrics {
    pub requests_total: AtomicU64,
    pub responses_total: AtomicU64,
    pub errors_total: AtomicU64,
    pub batches_total: AtomicU64,
    pub batched_items_total: AtomicU64,
    /// Requests currently waiting in batcher queues (gauge, set by the
    /// batcher on every enqueue/flush) and requests shed at admission or
    /// expiry (counter: queue caps, drain rejections, missed deadlines).
    /// Watch them as a pair — depth pinned at the cap plus a climbing shed
    /// count is the saturation signature.
    pub queue_depth: AtomicU64,
    pub shed_total: AtomicU64,
    /// Sum of request latencies (µs) and max, for mean/max reporting.
    lat_sum_us: AtomicU64,
    lat_max_us: AtomicU64,
    /// Queue-time share of latency (µs).
    queue_sum_us: AtomicU64,
    /// Per-op request counters, indexed by wire op code − 1 (each path —
    /// or pair, for paired ops — of a ragged frame counts once, matching
    /// `requests_total`).
    per_op_total: [AtomicU64; OP_CODE_COUNT],
    /// Plan-cache counters, mirrored from the router's
    /// [`PlanCache`](crate::engine::PlanCache) after each batch so the
    /// snapshot/summary always reflects the serving path's cache behaviour.
    pub plan_hits_total: AtomicU64,
    pub plan_misses_total: AtomicU64,
    pub plan_evictions_total: AtomicU64,
    /// Corpus-registry counters, mirrored from the router's
    /// [`CorpusRegistry`](crate::corpus::CorpusRegistry) after each corpus
    /// request: warm hits reused cached corpus state, cold builds paid the
    /// O(n²) / feature-map cost.
    pub corpus_warm_hits_total: AtomicU64,
    pub corpus_cold_builds_total: AtomicU64,
    pub corpus_registered_total: AtomicU64,
    /// Streaming mirrors: path extensions (`ExtendPath`) and sliding-window
    /// evictions (`EvictCorpus`) applied to the router's registry.
    pub corpus_extended_total: AtomicU64,
    pub corpus_evicted_total: AtomicU64,
    /// Lane-engine occupancy, mirrored from the counters in
    /// [`kernel::lanes`](crate::kernel::lanes) after each batch / corpus
    /// request: Gram tiles executed by the tile scheduler, full lane groups
    /// dispatched through the SoA sweep, and pairs that fell to the scalar
    /// remainder while lane batching was active. Unlike the plan-cache and
    /// corpus mirrors (owned per router), these sources are **process-wide**
    /// — direct library Gram calls in the same process count too, so read
    /// them as "lane engine occupancy on this host", not "this server's
    /// share".
    pub tiles_executed_total: AtomicU64,
    pub lane_groups_total: AtomicU64,
    pub lane_scalar_pairs_total: AtomicU64,
    /// Backward-pass lane occupancy: full groups through the lane-batched
    /// Algorithm-4 adjoint sweep and pairs that ran the scalar backward
    /// remainder. Process-wide, like the forward lane mirrors above.
    pub vjp_lane_groups_total: AtomicU64,
    pub vjp_scalar_pairs_total: AtomicU64,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            requests_total: AtomicU64::new(0),
            responses_total: AtomicU64::new(0),
            errors_total: AtomicU64::new(0),
            batches_total: AtomicU64::new(0),
            batched_items_total: AtomicU64::new(0),
            queue_depth: AtomicU64::new(0),
            shed_total: AtomicU64::new(0),
            lat_sum_us: AtomicU64::new(0),
            lat_max_us: AtomicU64::new(0),
            queue_sum_us: AtomicU64::new(0),
            per_op_total: std::array::from_fn(|_| AtomicU64::new(0)),
            plan_hits_total: AtomicU64::new(0),
            plan_misses_total: AtomicU64::new(0),
            plan_evictions_total: AtomicU64::new(0),
            corpus_warm_hits_total: AtomicU64::new(0),
            corpus_cold_builds_total: AtomicU64::new(0),
            corpus_registered_total: AtomicU64::new(0),
            corpus_extended_total: AtomicU64::new(0),
            corpus_evicted_total: AtomicU64::new(0),
            tiles_executed_total: AtomicU64::new(0),
            lane_groups_total: AtomicU64::new(0),
            lane_scalar_pairs_total: AtomicU64::new(0),
            vjp_lane_groups_total: AtomicU64::new(0),
            vjp_scalar_pairs_total: AtomicU64::new(0),
        }
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_request(&self) {
        self.requests_total.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one request against its wire op code (codes are 1-based;
    /// unknown codes are ignored rather than panicking — the wire already
    /// rejected them).
    pub fn record_op(&self, code: u32) {
        if let Some(c) = self.per_op_total.get((code as usize).wrapping_sub(1)) {
            c.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Requests recorded against a wire op code (0 for unknown codes).
    pub fn op_count(&self, code: u32) -> u64 {
        self.per_op_total
            .get((code as usize).wrapping_sub(1))
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Gauge: requests currently queued in the batcher.
    pub fn set_queue_depth(&self, depth: u64) {
        self.queue_depth.store(depth, Ordering::Relaxed);
    }

    /// Count one request shed without compute (overload, drain, deadline).
    pub fn record_shed(&self) {
        self.shed_total.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_batch(&self, items: usize) {
        self.batches_total.fetch_add(1, Ordering::Relaxed);
        self.batched_items_total
            .fetch_add(items as u64, Ordering::Relaxed);
    }

    pub fn record_response(&self, latency_us: u64, queue_us: u64, is_err: bool) {
        self.responses_total.fetch_add(1, Ordering::Relaxed);
        if is_err {
            self.errors_total.fetch_add(1, Ordering::Relaxed);
        }
        self.lat_sum_us.fetch_add(latency_us, Ordering::Relaxed);
        self.queue_sum_us.fetch_add(queue_us, Ordering::Relaxed);
        self.lat_max_us.fetch_max(latency_us, Ordering::Relaxed);
    }

    /// Mirror the router's plan-cache counters into the snapshot (the cache
    /// owns the live values; this keeps the metrics surface one-stop).
    pub fn set_plan_cache(&self, stats: crate::engine::CacheStats) {
        self.plan_hits_total.store(stats.hits, Ordering::Relaxed);
        self.plan_misses_total.store(stats.misses, Ordering::Relaxed);
        self.plan_evictions_total
            .store(stats.evictions, Ordering::Relaxed);
    }

    /// Mirror the lane engine's occupancy counters into the snapshot (the
    /// process-wide counters in [`kernel::lanes`](crate::kernel::lanes) own
    /// the live values).
    pub fn set_lanes(&self, stats: crate::kernel::LaneStats) {
        self.tiles_executed_total
            .store(stats.tiles_executed, Ordering::Relaxed);
        self.lane_groups_total
            .store(stats.lane_groups, Ordering::Relaxed);
        self.lane_scalar_pairs_total
            .store(stats.scalar_pairs, Ordering::Relaxed);
        self.vjp_lane_groups_total
            .store(stats.vjp_lane_groups, Ordering::Relaxed);
        self.vjp_scalar_pairs_total
            .store(stats.vjp_scalar_pairs, Ordering::Relaxed);
    }

    /// Mirror the router's corpus-registry counters into the snapshot.
    pub fn set_corpus(&self, stats: crate::corpus::CorpusStats) {
        self.corpus_warm_hits_total
            .store(stats.warm_hits, Ordering::Relaxed);
        self.corpus_cold_builds_total
            .store(stats.cold_builds, Ordering::Relaxed);
        self.corpus_registered_total
            .store(stats.registered, Ordering::Relaxed);
        self.corpus_extended_total
            .store(stats.extended, Ordering::Relaxed);
        self.corpus_evicted_total
            .store(stats.evicted, Ordering::Relaxed);
    }

    /// Mean items per flushed batch — the batching efficiency signal.
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches_total.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batched_items_total.load(Ordering::Relaxed) as f64 / b as f64
    }

    pub fn mean_latency_us(&self) -> f64 {
        let n = self.responses_total.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.lat_sum_us.load(Ordering::Relaxed) as f64 / n as f64
    }

    pub fn max_latency_us(&self) -> u64 {
        self.lat_max_us.load(Ordering::Relaxed)
    }

    pub fn mean_queue_us(&self) -> f64 {
        let n = self.responses_total.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.queue_sum_us.load(Ordering::Relaxed) as f64 / n as f64
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        let ops: Vec<String> = (1..=OP_CODE_COUNT as u32)
            .filter(|&c| self.op_count(c) > 0)
            .map(|c| format!("op{c}={}", self.op_count(c)))
            .collect();
        format!(
            "requests={} responses={} errors={} batches={} queue_depth={} shed={} mean_batch={:.2} mean_latency_us={:.0} max_latency_us={} mean_queue_us={:.0} plan_hits={} plan_misses={} plan_evictions={} corpus_warm={} corpus_cold={} tiles={} lane_groups={} lane_scalar={} vjp_groups={} vjp_scalar={} [{}]",
            self.requests_total.load(Ordering::Relaxed),
            self.responses_total.load(Ordering::Relaxed),
            self.errors_total.load(Ordering::Relaxed),
            self.batches_total.load(Ordering::Relaxed),
            self.queue_depth.load(Ordering::Relaxed),
            self.shed_total.load(Ordering::Relaxed),
            self.mean_batch_size(),
            self.mean_latency_us(),
            self.max_latency_us(),
            self.mean_queue_us(),
            self.plan_hits_total.load(Ordering::Relaxed),
            self.plan_misses_total.load(Ordering::Relaxed),
            self.plan_evictions_total.load(Ordering::Relaxed),
            self.corpus_warm_hits_total.load(Ordering::Relaxed),
            self.corpus_cold_builds_total.load(Ordering::Relaxed),
            self.tiles_executed_total.load(Ordering::Relaxed),
            self.lane_groups_total.load(Ordering::Relaxed),
            self.lane_scalar_pairs_total.load(Ordering::Relaxed),
            self.vjp_lane_groups_total.load(Ordering::Relaxed),
            self.vjp_scalar_pairs_total.load(Ordering::Relaxed),
            ops.join(" "),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.record_request();
        m.record_request();
        m.record_batch(2);
        m.record_response(100, 40, false);
        m.record_response(300, 60, true);
        assert_eq!(m.requests_total.load(Ordering::Relaxed), 2);
        assert_eq!(m.errors_total.load(Ordering::Relaxed), 1);
        assert_eq!(m.mean_batch_size(), 2.0);
        assert_eq!(m.mean_latency_us(), 200.0);
        assert_eq!(m.max_latency_us(), 300);
        assert_eq!(m.mean_queue_us(), 50.0);
        assert!(m.summary().contains("batches=1"));
    }

    #[test]
    fn queue_depth_and_shed_surface_in_snapshot() {
        let m = Metrics::new();
        m.set_queue_depth(17);
        m.record_shed();
        m.record_shed();
        assert_eq!(m.queue_depth.load(Ordering::Relaxed), 17);
        assert_eq!(m.shed_total.load(Ordering::Relaxed), 2);
        let s = m.summary();
        assert!(s.contains("queue_depth=17"), "{s}");
        assert!(s.contains("shed=2"), "{s}");
        m.set_queue_depth(0);
        assert!(m.summary().contains("queue_depth=0"));
    }

    #[test]
    fn per_op_counters_track_codes_and_ignore_unknowns() {
        let m = Metrics::new();
        m.record_op(1);
        m.record_op(1);
        m.record_op(9);
        m.record_op(0); // out of range: ignored
        m.record_op(99); // out of range: ignored
        assert_eq!(m.op_count(1), 2);
        assert_eq!(m.op_count(9), 1);
        assert_eq!(m.op_count(2), 0);
        assert_eq!(m.op_count(0), 0);
        assert_eq!(m.op_count(99), 0);
        let s = m.summary();
        assert!(s.contains("op1=2"), "{s}");
        assert!(s.contains("op9=1"), "{s}");
        assert!(!s.contains("op2="), "{s}");
    }

    #[test]
    fn plan_cache_counters_surface_in_snapshot() {
        let m = Metrics::new();
        m.set_plan_cache(crate::engine::CacheStats {
            hits: 7,
            misses: 2,
            evictions: 1,
        });
        assert_eq!(m.plan_hits_total.load(Ordering::Relaxed), 7);
        assert_eq!(m.plan_misses_total.load(Ordering::Relaxed), 2);
        assert_eq!(m.plan_evictions_total.load(Ordering::Relaxed), 1);
        let s = m.summary();
        assert!(s.contains("plan_hits=7"), "{s}");
        assert!(s.contains("plan_misses=2"), "{s}");
        assert!(s.contains("plan_evictions=1"), "{s}");
    }

    #[test]
    fn lane_counters_surface_in_snapshot() {
        let m = Metrics::new();
        m.set_lanes(crate::kernel::LaneStats {
            tiles_executed: 12,
            lane_groups: 34,
            scalar_pairs: 5,
            vjp_lane_groups: 9,
            vjp_scalar_pairs: 2,
        });
        assert_eq!(m.tiles_executed_total.load(Ordering::Relaxed), 12);
        assert_eq!(m.lane_groups_total.load(Ordering::Relaxed), 34);
        assert_eq!(m.lane_scalar_pairs_total.load(Ordering::Relaxed), 5);
        assert_eq!(m.vjp_lane_groups_total.load(Ordering::Relaxed), 9);
        assert_eq!(m.vjp_scalar_pairs_total.load(Ordering::Relaxed), 2);
        let s = m.summary();
        assert!(s.contains("tiles=12"), "{s}");
        assert!(s.contains("lane_groups=34"), "{s}");
        assert!(s.contains("lane_scalar=5"), "{s}");
        assert!(s.contains("vjp_groups=9"), "{s}");
        assert!(s.contains("vjp_scalar=2"), "{s}");
    }

    #[test]
    fn corpus_counters_surface_in_snapshot() {
        let m = Metrics::new();
        m.set_corpus(crate::corpus::CorpusStats {
            registered: 2,
            appended: 1,
            queries: 9,
            warm_hits: 6,
            cold_builds: 3,
            extended: 4,
            evicted: 2,
        });
        assert_eq!(m.corpus_warm_hits_total.load(Ordering::Relaxed), 6);
        assert_eq!(m.corpus_cold_builds_total.load(Ordering::Relaxed), 3);
        assert_eq!(m.corpus_registered_total.load(Ordering::Relaxed), 2);
        assert_eq!(m.corpus_extended_total.load(Ordering::Relaxed), 4);
        assert_eq!(m.corpus_evicted_total.load(Ordering::Relaxed), 2);
        let s = m.summary();
        assert!(s.contains("corpus_warm=6"), "{s}");
        assert!(s.contains("corpus_cold=3"), "{s}");
    }
}
