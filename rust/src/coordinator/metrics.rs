//! Serving metrics: request / batch counters and latency aggregates,
//! lock-free on the hot path (atomics; latencies in integer microseconds).

use std::sync::atomic::{AtomicU64, Ordering};

/// Aggregated service metrics. All methods are thread-safe.
#[derive(Default)]
pub struct Metrics {
    pub requests_total: AtomicU64,
    pub responses_total: AtomicU64,
    pub errors_total: AtomicU64,
    pub batches_total: AtomicU64,
    pub batched_items_total: AtomicU64,
    /// Sum of request latencies (µs) and max, for mean/max reporting.
    lat_sum_us: AtomicU64,
    lat_max_us: AtomicU64,
    /// Queue-time share of latency (µs).
    queue_sum_us: AtomicU64,
    /// Plan-cache counters, mirrored from the router's
    /// [`PlanCache`](crate::engine::PlanCache) after each batch so the
    /// snapshot/summary always reflects the serving path's cache behaviour.
    pub plan_hits_total: AtomicU64,
    pub plan_misses_total: AtomicU64,
    pub plan_evictions_total: AtomicU64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_request(&self) {
        self.requests_total.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_batch(&self, items: usize) {
        self.batches_total.fetch_add(1, Ordering::Relaxed);
        self.batched_items_total
            .fetch_add(items as u64, Ordering::Relaxed);
    }

    pub fn record_response(&self, latency_us: u64, queue_us: u64, is_err: bool) {
        self.responses_total.fetch_add(1, Ordering::Relaxed);
        if is_err {
            self.errors_total.fetch_add(1, Ordering::Relaxed);
        }
        self.lat_sum_us.fetch_add(latency_us, Ordering::Relaxed);
        self.queue_sum_us.fetch_add(queue_us, Ordering::Relaxed);
        self.lat_max_us.fetch_max(latency_us, Ordering::Relaxed);
    }

    /// Mirror the router's plan-cache counters into the snapshot (the cache
    /// owns the live values; this keeps the metrics surface one-stop).
    pub fn set_plan_cache(&self, stats: crate::engine::CacheStats) {
        self.plan_hits_total.store(stats.hits, Ordering::Relaxed);
        self.plan_misses_total.store(stats.misses, Ordering::Relaxed);
        self.plan_evictions_total
            .store(stats.evictions, Ordering::Relaxed);
    }

    /// Mean items per flushed batch — the batching efficiency signal.
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches_total.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batched_items_total.load(Ordering::Relaxed) as f64 / b as f64
    }

    pub fn mean_latency_us(&self) -> f64 {
        let n = self.responses_total.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.lat_sum_us.load(Ordering::Relaxed) as f64 / n as f64
    }

    pub fn max_latency_us(&self) -> u64 {
        self.lat_max_us.load(Ordering::Relaxed)
    }

    pub fn mean_queue_us(&self) -> f64 {
        let n = self.responses_total.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.queue_sum_us.load(Ordering::Relaxed) as f64 / n as f64
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "requests={} responses={} errors={} batches={} mean_batch={:.2} mean_latency_us={:.0} max_latency_us={} mean_queue_us={:.0} plan_hits={} plan_misses={} plan_evictions={}",
            self.requests_total.load(Ordering::Relaxed),
            self.responses_total.load(Ordering::Relaxed),
            self.errors_total.load(Ordering::Relaxed),
            self.batches_total.load(Ordering::Relaxed),
            self.mean_batch_size(),
            self.mean_latency_us(),
            self.max_latency_us(),
            self.mean_queue_us(),
            self.plan_hits_total.load(Ordering::Relaxed),
            self.plan_misses_total.load(Ordering::Relaxed),
            self.plan_evictions_total.load(Ordering::Relaxed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.record_request();
        m.record_request();
        m.record_batch(2);
        m.record_response(100, 40, false);
        m.record_response(300, 60, true);
        assert_eq!(m.requests_total.load(Ordering::Relaxed), 2);
        assert_eq!(m.errors_total.load(Ordering::Relaxed), 1);
        assert_eq!(m.mean_batch_size(), 2.0);
        assert_eq!(m.mean_latency_us(), 200.0);
        assert_eq!(m.max_latency_us(), 300);
        assert_eq!(m.mean_queue_us(), 50.0);
        assert!(m.summary().contains("batches=1"));
    }

    #[test]
    fn plan_cache_counters_surface_in_snapshot() {
        let m = Metrics::new();
        m.set_plan_cache(crate::engine::CacheStats {
            hits: 7,
            misses: 2,
            evictions: 1,
        });
        assert_eq!(m.plan_hits_total.load(Ordering::Relaxed), 7);
        assert_eq!(m.plan_misses_total.load(Ordering::Relaxed), 2);
        assert_eq!(m.plan_evictions_total.load(Ordering::Relaxed), 1);
        let s = m.summary();
        assert!(s.contains("plan_hits=7"), "{s}");
        assert!(s.contains("plan_misses=2"), "{s}");
        assert!(s.contains("plan_evictions=1"), "{s}");
    }
}
