//! Shape-grouped dynamic batcher — the serving-side heart of the
//! coordinator. Requests for the same (op, len, dim) are queued together
//! and flushed when the group reaches `max_batch` or its oldest request has
//! waited `max_wait`; the flushed batch runs on the data-parallel compute
//! backend, and each requester gets its slice of the result.
//!
//! The same policy (batch by shape, bound queueing delay) is what dynamic
//! batchers in LLM inference routers do; here the "model" is the signature /
//! signature-kernel computation.

use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::{Metrics, Op, Request, Response, Router};
use crate::util::sync::lock_unpoisoned;

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Flush a group at this many items.
    pub max_batch: usize,
    /// Flush a group when its oldest item has waited this long.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 128,
            max_wait: Duration::from_millis(2),
        }
    }
}

/// Grouping key: identical shapes and parameters batch together.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
struct GroupKey {
    op: Op,
    len: usize,
    dim: usize,
}

struct Pending {
    req: Request,
    enqueued: Instant,
}

struct Shared {
    queues: Mutex<HashMap<GroupKey, Vec<Pending>>>,
    wake: Condvar,
    shutdown: Mutex<bool>,
}

/// The dynamic batcher. Submissions are non-blocking; a background flusher
/// thread owns the flush policy.
pub struct Batcher {
    shared: Arc<Shared>,
    config: BatcherConfig,
    router: Arc<Router>,
    pub metrics: Arc<Metrics>,
    flusher: Option<std::thread::JoinHandle<()>>,
}

impl Batcher {
    /// Start the batcher with its background flusher.
    pub fn start(router: Arc<Router>, config: BatcherConfig) -> Batcher {
        let shared = Arc::new(Shared {
            queues: Mutex::new(HashMap::new()),
            wake: Condvar::new(),
            shutdown: Mutex::new(false),
        });
        let metrics = Arc::new(Metrics::new());
        let flusher = {
            let shared = shared.clone();
            let router = router.clone();
            let metrics = metrics.clone();
            std::thread::spawn(move || flusher_loop(shared, router, metrics, config))
        };
        Batcher {
            shared,
            config,
            router,
            metrics,
            flusher: Some(flusher),
        }
    }

    /// Enqueue a request. The response arrives on `req.reply`.
    pub fn submit(&self, req: Request) {
        self.metrics.record_request();
        self.metrics.record_op(req.op.code());
        let key = GroupKey {
            op: req.op,
            len: req.len,
            dim: req.dim,
        };
        let flush_now = {
            let mut queues = lock_unpoisoned(&self.shared.queues);
            let q = queues.entry(key).or_default();
            q.push(Pending {
                req,
                enqueued: Instant::now(),
            });
            q.len() >= self.config.max_batch
        };
        if flush_now {
            // Opportunistic inline flush keeps tail latency flat when load
            // is high (the flusher thread alone would serialise flushes).
            let batch = {
                let mut queues = lock_unpoisoned(&self.shared.queues);
                queues.remove(&key)
            };
            if let Some(batch) = batch {
                execute_group(&self.router, &self.metrics, key, batch);
            }
        } else {
            self.shared.wake.notify_one();
        }
    }

    /// Execute a ragged-batch frame synchronously on the compute backend.
    /// A ragged frame *is* a batch already, so it bypasses the shape-grouped
    /// queues and goes straight to the router; metrics still see it as one
    /// batch of `frame.batch()` requests.
    pub fn execute_ragged(
        &self,
        frame: &crate::coordinator::wire::RaggedFrame,
    ) -> Result<Vec<f64>, crate::path::SigError> {
        let b = frame.batch();
        for _ in 0..b {
            self.metrics.record_request();
            self.metrics.record_op(frame.op.code());
        }
        self.metrics.record_batch(b);
        let started = Instant::now();
        let result = self.router.execute_ragged(frame);
        let compute_us = started.elapsed().as_micros() as u64;
        let is_err = result.is_err();
        for _ in 0..b {
            self.metrics.record_response(compute_us, 0, is_err);
        }
        self.metrics.set_plan_cache(self.router.plan_cache_stats());
        self.metrics.set_corpus(self.router.corpus_stats());
        self.metrics.set_lanes(crate::kernel::lanes::stats());
        result
    }

    /// Flush everything immediately (used by tests and shutdown).
    pub fn flush_all(&self) {
        let drained: Vec<(GroupKey, Vec<Pending>)> = {
            let mut queues = lock_unpoisoned(&self.shared.queues);
            queues.drain().collect()
        };
        for (key, batch) in drained {
            execute_group(&self.router, &self.metrics, key, batch);
        }
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        *lock_unpoisoned(&self.shared.shutdown) = true;
        self.shared.wake.notify_all();
        if let Some(h) = self.flusher.take() {
            let _ = h.join();
        }
        self.flush_all();
    }
}

fn flusher_loop(
    shared: Arc<Shared>,
    router: Arc<Router>,
    metrics: Arc<Metrics>,
    config: BatcherConfig,
) {
    loop {
        if *lock_unpoisoned(&shared.shutdown) {
            return;
        }
        // Collect groups whose oldest entry is past the deadline.
        let mut due: Vec<(GroupKey, Vec<Pending>)> = Vec::new();
        {
            let mut queues = lock_unpoisoned(&shared.queues);
            let now = Instant::now();
            let keys: Vec<GroupKey> = queues
                .iter()
                .filter(|(_, q)| {
                    q.len() >= config.max_batch
                        || q.first()
                            .is_some_and(|p| now.duration_since(p.enqueued) >= config.max_wait)
                })
                .map(|(k, _)| *k)
                .collect();
            for k in keys {
                if let Some(q) = queues.remove(&k) {
                    due.push((k, q));
                }
            }
            if due.is_empty() {
                // Sleep until woken or the shortest remaining deadline.
                let wait = queues
                    .values()
                    .filter_map(|q| q.first())
                    .map(|p| {
                        config
                            .max_wait
                            .saturating_sub(Instant::now().duration_since(p.enqueued))
                    })
                    .min()
                    .unwrap_or(config.max_wait);
                let _unused = shared
                    .wake
                    .wait_timeout(queues, wait.max(Duration::from_micros(100)))
                    .unwrap_or_else(|p| p.into_inner());
                continue;
            }
        }
        for (key, batch) in due {
            execute_group(&router, &metrics, key, batch);
        }
    }
}

/// Run one flushed group on the compute backend and fan results back.
fn execute_group(router: &Router, metrics: &Metrics, key: GroupKey, batch: Vec<Pending>) {
    metrics.record_batch(batch.len());
    let started = Instant::now();
    let queue_us: Vec<u64> = batch
        .iter()
        .map(|p| started.duration_since(p.enqueued).as_micros() as u64)
        .collect();
    let reqs: Vec<&Request> = batch.iter().map(|p| &p.req).collect();
    let results = router.execute_batch(key.op, key.len, key.dim, &reqs);
    metrics.set_plan_cache(router.plan_cache_stats());
    metrics.set_lanes(crate::kernel::lanes::stats());
    let compute_us = started.elapsed().as_micros() as u64;
    for ((p, result), q_us) in batch.iter().zip(results).zip(queue_us) {
        let is_err = matches!(result, Response::Error(_));
        metrics.record_response(q_us + compute_us, q_us, is_err);
        let _ = p.req.reply.send(result);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::transform_to_u8;
    use crate::transforms::Transform;
    use crate::util::rng::Rng;
    use std::sync::mpsc;

    fn submit_one(
        batcher: &Batcher,
        op: Op,
        len: usize,
        dim: usize,
        rng: &mut Rng,
    ) -> mpsc::Receiver<Response> {
        let (tx, rx) = mpsc::channel();
        let data = rng.brownian_path(len, dim, 0.5);
        let data2 = match op {
            Op::SigKernel { .. } | Op::SigKernelGrad { .. } => {
                Some(rng.brownian_path(len, dim, 0.5))
            }
            _ => None,
        };
        batcher.submit(Request {
            op,
            len,
            dim,
            data,
            data2,
            reply: tx,
        });
        rx
    }

    #[test]
    fn every_request_gets_exactly_one_response() {
        let router = Arc::new(Router::native_only());
        let batcher = Batcher::start(
            router,
            BatcherConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
            },
        );
        let op = Op::Signature {
            depth: 3,
            transform: transform_to_u8(Transform::None),
        };
        let mut rng = Rng::new(1);
        let rxs: Vec<_> = (0..23).map(|_| submit_one(&batcher, op, 10, 2, &mut rng)).collect();
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(5)).expect("response");
            match resp {
                Response::Values(v) => assert_eq!(v.len(), crate::sig::sig_length(2, 3)),
                Response::Error(e) => panic!("unexpected error: {e}"),
            }
        }
        assert_eq!(
            batcher
                .metrics
                .responses_total
                .load(std::sync::atomic::Ordering::Relaxed),
            23
        );
    }

    #[test]
    fn different_shapes_batch_separately_but_all_complete() {
        let router = Arc::new(Router::native_only());
        let batcher = Batcher::start(router, BatcherConfig::default());
        let op = Op::SigKernel {
            lam1: 0,
            lam2: 0,
            transform: 0,
            scheme: 0,
        };
        let mut rng = Rng::new(2);
        let rx1 = submit_one(&batcher, op, 8, 2, &mut rng);
        let rx2 = submit_one(&batcher, op, 12, 3, &mut rng);
        batcher.flush_all();
        for rx in [rx1, rx2] {
            match rx.recv_timeout(Duration::from_secs(5)).unwrap() {
                Response::Values(v) => assert_eq!(v.len(), 1),
                Response::Error(e) => panic!("{e}"),
            }
        }
    }

    #[test]
    fn timeout_flush_fires_without_filling_batch() {
        let router = Arc::new(Router::native_only());
        let batcher = Batcher::start(
            router,
            BatcherConfig {
                max_batch: 1000,
                max_wait: Duration::from_millis(5),
            },
        );
        let op = Op::Signature {
            depth: 2,
            transform: 0,
        };
        let mut rng = Rng::new(3);
        let rx = submit_one(&batcher, op, 6, 2, &mut rng);
        // No explicit flush: the deadline must trigger it.
        let resp = rx.recv_timeout(Duration::from_secs(5)).expect("deadline flush");
        assert!(matches!(resp, Response::Values(_)));
    }

    #[test]
    fn batch_results_match_direct_computation() {
        let router = Arc::new(Router::native_only());
        let batcher = Batcher::start(
            router,
            BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
            },
        );
        let op = Op::Signature {
            depth: 4,
            transform: 0,
        };
        let mut rng = Rng::new(4);
        let paths: Vec<Vec<f64>> = (0..8).map(|_| rng.brownian_path(9, 2, 0.5)).collect();
        let rxs: Vec<_> = paths
            .iter()
            .map(|p| {
                let (tx, rx) = mpsc::channel();
                batcher.submit(Request {
                    op,
                    len: 9,
                    dim: 2,
                    data: p.clone(),
                    data2: None,
                    reply: tx,
                });
                rx
            })
            .collect();
        for (p, rx) in paths.iter().zip(rxs) {
            match rx.recv_timeout(Duration::from_secs(5)).unwrap() {
                Response::Values(v) => {
                    let want = crate::sig::sig(p, 9, 2, 4);
                    assert!(crate::util::linalg::max_abs_diff(&v, &want) < 1e-12);
                }
                Response::Error(e) => panic!("{e}"),
            }
        }
    }
}
