//! Shape-grouped dynamic batcher — the serving-side heart of the
//! coordinator. Requests for the same (op, len, dim) are queued together
//! and flushed when the group reaches `max_batch` or its oldest request has
//! waited `max_wait`; the flushed batch runs on the data-parallel compute
//! backend, and each requester gets its slice of the result.
//!
//! The same policy (batch by shape, bound queueing delay) is what dynamic
//! batchers in LLM inference routers do; here the "model" is the signature /
//! signature-kernel computation.
//!
//! Admission is bounded, not best-effort. Each group queue holds at most
//! [`BatcherConfig::queue_cap`] requests and the batcher as a whole at most
//! [`BatcherConfig::global_cap`]; a request that would exceed either is
//! answered immediately with [`Response::Overloaded`] carrying a retry
//! hint, so overload degrades to fast rejections instead of unbounded
//! memory growth and collapsing tail latency. An optional per-request
//! [`deadline`](BatcherConfig::deadline) is enforced twice: at enqueue, and
//! again when the group flushes — a request whose deadline passed while it
//! queued gets [`Response::DeadlineExceeded`] and is *never* computed.
//! [`Batcher::drain`] flips the admission gate **before** touching the
//! queues (late arrivals get [`Response::ShuttingDown`], none are
//! stranded), then flushes everything already admitted.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::{Metrics, Op, Request, Response, Router};
use crate::util::sync::lock_unpoisoned;

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Flush a group at this many items.
    pub max_batch: usize,
    /// Flush a group when its oldest item has waited this long.
    pub max_wait: Duration,
    /// Admission cap per shape group; an arriving request that would make a
    /// group exceed this is shed with [`Response::Overloaded`].
    pub queue_cap: usize,
    /// Admission cap across all groups together.
    pub global_cap: usize,
    /// Per-request deadline, measured from enqueue. `None` disables the
    /// check. A request past its deadline at flush time is answered with
    /// [`Response::DeadlineExceeded`] instead of being computed.
    pub deadline: Option<Duration>,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 128,
            max_wait: Duration::from_millis(2),
            queue_cap: 4096,
            global_cap: 65536,
            deadline: None,
        }
    }
}

/// Grouping key: identical shapes and parameters batch together.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
struct GroupKey {
    op: Op,
    len: usize,
    dim: usize,
}

struct Pending {
    req: Request,
    enqueued: Instant,
}

struct Shared {
    queues: Mutex<HashMap<GroupKey, Vec<Pending>>>,
    wake: Condvar,
    shutdown: Mutex<bool>,
    /// Admission gate: flipped off *before* the final flush on drain, so a
    /// request observes either an open gate (and is flushed) or a typed
    /// shutdown rejection — never a silently dropped queue entry.
    accepting: AtomicBool,
    /// Requests admitted and not yet flushed, across all groups.
    depth: AtomicU64,
}

/// The dynamic batcher. Submissions are non-blocking; a background flusher
/// thread owns the flush policy.
pub struct Batcher {
    shared: Arc<Shared>,
    config: BatcherConfig,
    router: Arc<Router>,
    pub metrics: Arc<Metrics>,
    flusher: Option<std::thread::JoinHandle<()>>,
}

impl Batcher {
    /// Start the batcher with its background flusher.
    pub fn start(router: Arc<Router>, config: BatcherConfig) -> Batcher {
        let shared = Arc::new(Shared {
            queues: Mutex::new(HashMap::new()),
            wake: Condvar::new(),
            shutdown: Mutex::new(false),
            accepting: AtomicBool::new(true),
            depth: AtomicU64::new(0),
        });
        let metrics = Arc::new(Metrics::new());
        let flusher = {
            let shared = shared.clone();
            let router = router.clone();
            let metrics = metrics.clone();
            std::thread::spawn(move || flusher_loop(shared, router, metrics, config))
        };
        Batcher {
            shared,
            config,
            router,
            metrics,
            flusher: Some(flusher),
        }
    }

    /// Enqueue a request. The response arrives on `req.reply` — immediately
    /// for admission rejections ([`Response::ShuttingDown`],
    /// [`Response::Overloaded`], [`Response::DeadlineExceeded`]), after the
    /// group flushes otherwise.
    pub fn submit(&self, req: Request) {
        self.metrics.record_request();
        self.metrics.record_op(req.op.code());
        if !self.shared.accepting.load(Ordering::Acquire) {
            self.shed(req, Response::ShuttingDown);
            return;
        }
        let enqueued = Instant::now();
        if past_deadline(enqueued, self.config.deadline) {
            self.shed(req, Response::DeadlineExceeded);
            return;
        }
        let key = GroupKey {
            op: req.op,
            len: req.len,
            dim: req.dim,
        };
        let admit_fail = crate::failpoint!("batcher.enqueue_full").is_some();
        let flush_now = {
            let mut queues = lock_unpoisoned(&self.shared.queues);
            let q = queues.entry(key).or_default();
            let global = self.shared.depth.load(Ordering::Relaxed) as usize;
            let full = q.len() >= self.config.queue_cap || global >= self.config.global_cap;
            if admit_fail || full {
                drop(queues);
                self.shed(req, self.overloaded());
                return;
            }
            q.push(Pending { req, enqueued });
            let depth = self.shared.depth.fetch_add(1, Ordering::Relaxed) + 1;
            self.metrics.set_queue_depth(depth);
            q.len() >= self.config.max_batch
        };
        if flush_now {
            // Opportunistic inline flush keeps tail latency flat when load
            // is high (the flusher thread alone would serialise flushes).
            let batch = {
                let mut queues = lock_unpoisoned(&self.shared.queues);
                queues.remove(&key)
            };
            if let Some(batch) = batch {
                self.settle_depth(batch.len());
                execute_group(&self.router, &self.metrics, &self.config, key, batch);
            }
        } else {
            self.shared.wake.notify_one();
        }
    }

    /// Answer `req` with an admission rejection, counting it as shed.
    fn shed(&self, req: Request, resp: Response) {
        self.metrics.record_shed();
        self.metrics.record_response(0, 0, true);
        let _ = req.reply.send(resp);
    }

    fn overloaded(&self) -> Response {
        Response::Overloaded {
            retry_after_ms: (self.config.max_wait.as_millis() as u64).max(1),
        }
    }

    fn settle_depth(&self, flushed: usize) {
        let before = self.shared.depth.fetch_sub(flushed as u64, Ordering::Relaxed);
        self.metrics
            .set_queue_depth(before.saturating_sub(flushed as u64));
    }

    /// Execute a ragged-batch frame synchronously on the compute backend.
    /// A ragged frame *is* a batch already, so it bypasses the shape-grouped
    /// queues and goes straight to the router; metrics still see it as one
    /// batch of `frame.batch()` requests.
    pub fn execute_ragged(
        &self,
        frame: &crate::coordinator::wire::RaggedFrame,
    ) -> Result<Vec<f64>, crate::path::SigError> {
        let b = frame.batch();
        for _ in 0..b {
            self.metrics.record_request();
            self.metrics.record_op(frame.op.code());
        }
        self.metrics.record_batch(b);
        let started = Instant::now();
        let result = self.router.execute_ragged(frame);
        let compute_us = started.elapsed().as_micros() as u64;
        let is_err = result.is_err();
        for _ in 0..b {
            self.metrics.record_response(compute_us, 0, is_err);
        }
        self.metrics.set_plan_cache(self.router.plan_cache_stats());
        self.metrics.set_corpus(self.router.corpus_stats());
        self.metrics.set_lanes(crate::kernel::lanes::stats());
        result
    }

    /// Whether the batcher is still admitting work.
    pub fn accepting(&self) -> bool {
        self.shared.accepting.load(Ordering::Acquire)
    }

    /// The router this batcher executes on (the server uses it to snapshot
    /// corpora during shutdown, after `drain`).
    pub fn router(&self) -> &Arc<Router> {
        &self.router
    }

    /// Stop admitting and flush everything already admitted. The gate flips
    /// **first** (with the queue lock held, so no submit can slip between
    /// the gate check and its enqueue), which closes the shutdown race
    /// where requests enqueued during the final flush were stranded: a late
    /// arrival now gets [`Response::ShuttingDown`] instead of silence.
    pub fn drain(&self) {
        {
            let _queues = lock_unpoisoned(&self.shared.queues);
            self.shared.accepting.store(false, Ordering::Release);
        }
        self.flush_all();
    }

    /// Flush everything immediately (used by tests, drain and shutdown).
    pub fn flush_all(&self) {
        let drained: Vec<(GroupKey, Vec<Pending>)> = {
            let mut queues = lock_unpoisoned(&self.shared.queues);
            queues.drain().collect()
        };
        for (key, batch) in drained {
            self.settle_depth(batch.len());
            execute_group(&self.router, &self.metrics, &self.config, key, batch);
        }
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        // Same ordering as `drain`: close the gate before the final flush.
        {
            let _queues = lock_unpoisoned(&self.shared.queues);
            self.shared.accepting.store(false, Ordering::Release);
        }
        *lock_unpoisoned(&self.shared.shutdown) = true;
        self.shared.wake.notify_all();
        if let Some(h) = self.flusher.take() {
            let _ = h.join();
        }
        self.flush_all();
    }
}

/// Deadline check, shared by the enqueue and flush sides. The
/// `batcher.flush_late` failpoint forces lateness so tests can drive the
/// expiry path without real clock pressure.
fn past_deadline(enqueued: Instant, deadline: Option<Duration>) -> bool {
    if crate::failpoint!("batcher.flush_late").is_some() {
        return true;
    }
    deadline.is_some_and(|d| enqueued.elapsed() >= d)
}

fn flusher_loop(
    shared: Arc<Shared>,
    router: Arc<Router>,
    metrics: Arc<Metrics>,
    config: BatcherConfig,
) {
    loop {
        if *lock_unpoisoned(&shared.shutdown) {
            return;
        }
        // Collect groups whose oldest entry is past the deadline.
        let mut due: Vec<(GroupKey, Vec<Pending>)> = Vec::new();
        {
            let mut queues = lock_unpoisoned(&shared.queues);
            let now = Instant::now();
            let keys: Vec<GroupKey> = queues
                .iter()
                .filter(|(_, q)| {
                    q.len() >= config.max_batch
                        || q.first()
                            .is_some_and(|p| now.duration_since(p.enqueued) >= config.max_wait)
                })
                .map(|(k, _)| *k)
                .collect();
            for k in keys {
                if let Some(q) = queues.remove(&k) {
                    let before = shared.depth.fetch_sub(q.len() as u64, Ordering::Relaxed);
                    metrics.set_queue_depth(before.saturating_sub(q.len() as u64));
                    due.push((k, q));
                }
            }
            if due.is_empty() {
                // Sleep until woken or the shortest remaining deadline.
                let wait = queues
                    .values()
                    .filter_map(|q| q.first())
                    .map(|p| {
                        config
                            .max_wait
                            .saturating_sub(Instant::now().duration_since(p.enqueued))
                    })
                    .min()
                    .unwrap_or(config.max_wait);
                let _unused = shared
                    .wake
                    .wait_timeout(queues, wait.max(Duration::from_micros(100)))
                    .unwrap_or_else(|p| p.into_inner());
                continue;
            }
        }
        for (key, batch) in due {
            execute_group(&router, &metrics, &config, key, batch);
        }
    }
}

/// Run one flushed group on the compute backend and fan results back.
/// Requests whose deadline expired while queued are answered with
/// [`Response::DeadlineExceeded`] up front and excluded from the batch —
/// past-deadline work is shed, never silently computed.
fn execute_group(
    router: &Router,
    metrics: &Metrics,
    config: &BatcherConfig,
    key: GroupKey,
    batch: Vec<Pending>,
) {
    let mut live = Vec::with_capacity(batch.len());
    for p in batch {
        if past_deadline(p.enqueued, config.deadline) {
            metrics.record_shed();
            metrics.record_response(0, 0, true);
            let _ = p.req.reply.send(Response::DeadlineExceeded);
        } else {
            live.push(p);
        }
    }
    if live.is_empty() {
        return;
    }
    metrics.record_batch(live.len());
    let started = Instant::now();
    let queue_us: Vec<u64> = live
        .iter()
        .map(|p| started.duration_since(p.enqueued).as_micros() as u64)
        .collect();
    let reqs: Vec<&Request> = live.iter().map(|p| &p.req).collect();
    let results = router.execute_batch(key.op, key.len, key.dim, &reqs);
    metrics.set_plan_cache(router.plan_cache_stats());
    metrics.set_lanes(crate::kernel::lanes::stats());
    let compute_us = started.elapsed().as_micros() as u64;
    for ((p, result), q_us) in live.iter().zip(results).zip(queue_us) {
        let is_err = matches!(result, Response::Error(_));
        metrics.record_response(q_us + compute_us, q_us, is_err);
        let _ = p.req.reply.send(result);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::transform_to_u8;
    use crate::transforms::Transform;
    use crate::util::failpoint;
    use crate::util::rng::Rng;
    use std::sync::mpsc;

    fn submit_one(
        batcher: &Batcher,
        op: Op,
        len: usize,
        dim: usize,
        rng: &mut Rng,
    ) -> mpsc::Receiver<Response> {
        let (tx, rx) = mpsc::channel();
        let data = rng.brownian_path(len, dim, 0.5);
        let data2 = match op {
            Op::SigKernel { .. } | Op::SigKernelGrad { .. } => {
                Some(rng.brownian_path(len, dim, 0.5))
            }
            _ => None,
        };
        batcher.submit(Request {
            op,
            len,
            dim,
            data,
            data2,
            reply: tx,
        });
        rx
    }

    /// Config whose flusher never fires on its own — admission tests need
    /// queues that sit still.
    fn parked(queue_cap: usize, global_cap: usize) -> BatcherConfig {
        BatcherConfig {
            max_batch: 1000,
            max_wait: Duration::from_secs(30),
            queue_cap,
            global_cap,
            deadline: None,
        }
    }

    // Every test here holds `serial_guard`: the batcher contains failpoint
    // sites, and an armed site would leak into a concurrently running test.

    #[test]
    fn every_request_gets_exactly_one_response() {
        let _g = failpoint::serial_guard();
        let router = Arc::new(Router::native_only());
        let batcher = Batcher::start(
            router,
            BatcherConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                ..BatcherConfig::default()
            },
        );
        let op = Op::Signature {
            depth: 3,
            transform: transform_to_u8(Transform::None),
        };
        let mut rng = Rng::new(1);
        let rxs: Vec<_> = (0..23).map(|_| submit_one(&batcher, op, 10, 2, &mut rng)).collect();
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(5)).expect("response");
            match resp {
                Response::Values(v) => assert_eq!(v.len(), crate::sig::sig_length(2, 3)),
                other => panic!("unexpected response: {other:?}"),
            }
        }
        assert_eq!(
            batcher
                .metrics
                .responses_total
                .load(std::sync::atomic::Ordering::Relaxed),
            23
        );
    }

    #[test]
    fn different_shapes_batch_separately_but_all_complete() {
        let _g = failpoint::serial_guard();
        let router = Arc::new(Router::native_only());
        let batcher = Batcher::start(router, BatcherConfig::default());
        let op = Op::SigKernel {
            lam1: 0,
            lam2: 0,
            transform: 0,
            scheme: 0,
        };
        let mut rng = Rng::new(2);
        let rx1 = submit_one(&batcher, op, 8, 2, &mut rng);
        let rx2 = submit_one(&batcher, op, 12, 3, &mut rng);
        batcher.flush_all();
        for rx in [rx1, rx2] {
            match rx.recv_timeout(Duration::from_secs(5)).unwrap() {
                Response::Values(v) => assert_eq!(v.len(), 1),
                other => panic!("{other:?}"),
            }
        }
    }

    #[test]
    fn timeout_flush_fires_without_filling_batch() {
        let _g = failpoint::serial_guard();
        let router = Arc::new(Router::native_only());
        let batcher = Batcher::start(
            router,
            BatcherConfig {
                max_batch: 1000,
                max_wait: Duration::from_millis(5),
                ..BatcherConfig::default()
            },
        );
        let op = Op::Signature {
            depth: 2,
            transform: 0,
        };
        let mut rng = Rng::new(3);
        let rx = submit_one(&batcher, op, 6, 2, &mut rng);
        // No explicit flush: the deadline must trigger it.
        let resp = rx.recv_timeout(Duration::from_secs(5)).expect("deadline flush");
        assert!(matches!(resp, Response::Values(_)));
    }

    #[test]
    fn batch_results_match_direct_computation() {
        let _g = failpoint::serial_guard();
        let router = Arc::new(Router::native_only());
        let batcher = Batcher::start(
            router,
            BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
                ..BatcherConfig::default()
            },
        );
        let op = Op::Signature {
            depth: 4,
            transform: 0,
        };
        let mut rng = Rng::new(4);
        let paths: Vec<Vec<f64>> = (0..8).map(|_| rng.brownian_path(9, 2, 0.5)).collect();
        let rxs: Vec<_> = paths
            .iter()
            .map(|p| {
                let (tx, rx) = mpsc::channel();
                batcher.submit(Request {
                    op,
                    len: 9,
                    dim: 2,
                    data: p.clone(),
                    data2: None,
                    reply: tx,
                });
                rx
            })
            .collect();
        for (p, rx) in paths.iter().zip(rxs) {
            match rx.recv_timeout(Duration::from_secs(5)).unwrap() {
                Response::Values(v) => {
                    let want = crate::sig::sig(p, 9, 2, 4);
                    assert!(crate::util::linalg::max_abs_diff(&v, &want) < 1e-12);
                }
                other => panic!("{other:?}"),
            }
        }
    }

    #[test]
    fn full_group_queue_sheds_with_a_retry_hint() {
        let _g = failpoint::serial_guard();
        let router = Arc::new(Router::native_only());
        let batcher = Batcher::start(router, parked(2, 1000));
        let op = Op::Signature {
            depth: 2,
            transform: 0,
        };
        let mut rng = Rng::new(5);
        let rx1 = submit_one(&batcher, op, 6, 2, &mut rng);
        let rx2 = submit_one(&batcher, op, 6, 2, &mut rng);
        let rx3 = submit_one(&batcher, op, 6, 2, &mut rng);
        match rx3.recv_timeout(Duration::from_secs(5)).unwrap() {
            Response::Overloaded { retry_after_ms } => assert!(retry_after_ms >= 1),
            other => panic!("expected Overloaded, got {other:?}"),
        }
        assert_eq!(
            batcher
                .metrics
                .shed_total
                .load(std::sync::atomic::Ordering::Relaxed),
            1
        );
        assert_eq!(
            batcher
                .metrics
                .queue_depth
                .load(std::sync::atomic::Ordering::Relaxed),
            2
        );
        batcher.flush_all();
        assert_eq!(
            batcher
                .metrics
                .queue_depth
                .load(std::sync::atomic::Ordering::Relaxed),
            0
        );
        for rx in [rx1, rx2] {
            assert!(matches!(
                rx.recv_timeout(Duration::from_secs(5)).unwrap(),
                Response::Values(_)
            ));
        }
    }

    #[test]
    fn global_cap_sheds_across_groups() {
        let _g = failpoint::serial_guard();
        let router = Arc::new(Router::native_only());
        let batcher = Batcher::start(router, parked(1000, 2));
        let op = Op::Signature {
            depth: 2,
            transform: 0,
        };
        let mut rng = Rng::new(6);
        let _rx1 = submit_one(&batcher, op, 6, 2, &mut rng);
        let _rx2 = submit_one(&batcher, op, 7, 2, &mut rng);
        // Third request targets a *fresh* group; only the global cap stops it.
        let rx3 = submit_one(&batcher, op, 8, 2, &mut rng);
        assert!(matches!(
            rx3.recv_timeout(Duration::from_secs(5)).unwrap(),
            Response::Overloaded { .. }
        ));
        batcher.flush_all();
    }

    #[test]
    fn enqueue_full_failpoint_forces_shedding() {
        let _g = failpoint::serial_guard();
        let router = Arc::new(Router::native_only());
        let batcher = Batcher::start(router, parked(1000, 1000));
        let op = Op::Signature {
            depth: 2,
            transform: 0,
        };
        let mut rng = Rng::new(7);
        failpoint::arm_times("batcher.enqueue_full", 1, 1);
        let rx = submit_one(&batcher, op, 6, 2, &mut rng);
        assert!(matches!(
            rx.recv_timeout(Duration::from_secs(5)).unwrap(),
            Response::Overloaded { .. }
        ));
        failpoint::disarm("batcher.enqueue_full");
        let rx = submit_one(&batcher, op, 6, 2, &mut rng);
        batcher.flush_all();
        assert!(matches!(
            rx.recv_timeout(Duration::from_secs(5)).unwrap(),
            Response::Values(_)
        ));
    }

    #[test]
    fn expired_requests_are_answered_not_computed() {
        let _g = failpoint::serial_guard();
        let router = Arc::new(Router::native_only());
        let batcher = Batcher::start(router, parked(1000, 1000));
        let op = Op::Signature {
            depth: 2,
            transform: 0,
        };
        let mut rng = Rng::new(8);
        // Admitted with the failpoint quiet...
        let rx = submit_one(&batcher, op, 6, 2, &mut rng);
        // ...then expired at flush time.
        failpoint::arm("batcher.flush_late", 1);
        batcher.flush_all();
        failpoint::disarm("batcher.flush_late");
        assert!(matches!(
            rx.recv_timeout(Duration::from_secs(5)).unwrap(),
            Response::DeadlineExceeded
        ));
        let m = &batcher.metrics;
        assert_eq!(m.shed_total.load(std::sync::atomic::Ordering::Relaxed), 1);
        assert_eq!(
            m.batches_total.load(std::sync::atomic::Ordering::Relaxed),
            0,
            "an all-expired flush must not run a batch"
        );
    }

    #[test]
    fn zero_deadline_rejects_at_enqueue() {
        let _g = failpoint::serial_guard();
        let router = Arc::new(Router::native_only());
        let mut cfg = parked(1000, 1000);
        cfg.deadline = Some(Duration::ZERO);
        let batcher = Batcher::start(router, cfg);
        let op = Op::Signature {
            depth: 2,
            transform: 0,
        };
        let mut rng = Rng::new(9);
        let rx = submit_one(&batcher, op, 6, 2, &mut rng);
        assert!(matches!(
            rx.recv_timeout(Duration::from_secs(5)).unwrap(),
            Response::DeadlineExceeded
        ));
    }

    #[test]
    fn drain_flushes_admitted_work_and_rejects_late_arrivals() {
        let _g = failpoint::serial_guard();
        let router = Arc::new(Router::native_only());
        let batcher = Batcher::start(router, parked(1000, 1000));
        let op = Op::Signature {
            depth: 2,
            transform: 0,
        };
        let mut rng = Rng::new(10);
        let admitted = submit_one(&batcher, op, 6, 2, &mut rng);
        assert!(batcher.accepting());
        batcher.drain();
        assert!(!batcher.accepting());
        // Admitted before the gate closed: flushed with a real answer.
        assert!(matches!(
            admitted.recv_timeout(Duration::from_secs(5)).unwrap(),
            Response::Values(_)
        ));
        // Arrived after: typed shutdown rejection, never stranded.
        let late = submit_one(&batcher, op, 6, 2, &mut rng);
        assert!(matches!(
            late.recv_timeout(Duration::from_secs(5)).unwrap(),
            Response::ShuttingDown
        ));
    }
}
