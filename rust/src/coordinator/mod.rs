//! L3 serving coordinator: a request router + shape-grouped dynamic batcher
//! + TCP server in the style of an inference router (vLLM-like), built on
//! std::net + threads (no async runtime is available offline; a blocking
//! threaded design is also the right fit for a compute-bound service).
//!
//! Life of a request:
//!   client → wire protocol (validated on decode; malformed frames become
//!   `Err` responses, never panics) → [`server`] → single-path requests go
//!   through [`batcher::Batcher`], which groups same-shape work and flushes
//!   by size or deadline; ragged-batch frames are already batches and route
//!   straight to [`router::Router`] → the router executes each batch as a
//!   typed [`PathBatch`](crate::path::PathBatch) on the compute backend
//!   (native Rust kernels, or a PJRT artifact when one matches the batch
//!   shape) → responses fan back out.
//!
//! Corpus lifecycle ops (`RegisterCorpus` / `AppendCorpus` / `Mmd2Corpus`)
//! are stateful: they route to the router's
//! [`CorpusRegistry`](crate::corpus::CorpusRegistry), which caches
//! corpus-side Gram/feature state so warm re-queries pay only query-side
//! cost (see [`corpus`](crate::corpus)).
//!
//! The batcher admits rather than accumulates: queues are bounded
//! (per-group and globally), overload answers immediately with
//! [`Response::Overloaded`] and a retry hint instead of queueing without
//! limit, per-request deadlines are enforced at enqueue *and* at flush
//! ([`Response::DeadlineExceeded`] — expired work is never computed), and
//! shutdown drains: the server stops admitting
//! ([`Response::ShuttingDown`]), flushes what it accepted, and snapshots
//! registered corpora to disk (see
//! [`corpus::persist`](crate::corpus::persist)) so a restart resumes warm.

pub mod batcher;
pub mod metrics;
pub mod router;
pub mod server;
pub mod wire;

pub use batcher::{Batcher, BatcherConfig};
pub use metrics::Metrics;
pub use router::Router;
pub use server::{serve, Client, RetryPolicy, ServerHandle};
pub use wire::{Frame, RaggedFrame, RequestFrame, WireResponse};

use crate::transforms::Transform;

/// Seed the router uses for wire-requested low-rank ops (the wire header
/// has no seed field; a fixed seed keeps repeated requests deterministic
/// and cache-friendly).
pub const WIRE_LOWRANK_SEED: u64 = 0x51_6c0_3a11;

/// Operations the coordinator serves.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Op {
    /// Truncated signature of one path.
    Signature { depth: u32, transform: u8 },
    /// Expanded log-signature of one path.
    LogSignature { depth: u32, transform: u8 },
    /// Signature kernel of a pair of equal-length paths. `scheme` selects
    /// the Goursat discretisation (0 = order-1, 1 = order-2 Richardson).
    SigKernel {
        lam1: u32,
        lam2: u32,
        transform: u8,
        scheme: u8,
    },
    /// Exact gradient of the signature kernel w.r.t. both paths, under the
    /// same scheme encoding as [`Op::SigKernel`].
    SigKernelGrad { lam1: u32, lam2: u32, scheme: u8 },
    /// Low-rank (Nyström, `rank` landmarks) biased MMD² between the first
    /// `nx` paths and the rest of a ragged frame. Ragged frames only.
    Mmd2LowRank { rank: u32, nx: u32, transform: u8 },
    /// Low-rank cross-Gram `[nx, rest]` with the same split convention.
    /// Ragged frames only.
    GramLowRank { rank: u32, nx: u32, transform: u8 },
    /// Register the frame's paths as a reference corpus; responds with the
    /// (content-hash deduplicated) corpus id. Ragged frames only.
    RegisterCorpus,
    /// Append the frame's paths to corpus `id`, extending its cached
    /// serving state incrementally; responds with the new path count.
    /// Ragged frames only.
    AppendCorpus { id: u32 },
    /// Biased MMD² between the frame's query paths and corpus `id`
    /// (`rank` = 0 → exact with the cached corpus self-Gram; `rank` > 0 →
    /// Nyström at that rank with the wire seed). Ragged frames only.
    Mmd2Corpus { id: u32, rank: u32, transform: u8 },
    /// Append the frame's single path (≥ 1 points) to path `path_idx` of
    /// corpus `id`, advancing the cached Goursat border strips in place;
    /// responds with the path's new length in points. Ragged frames only.
    ExtendPath { id: u32, path_idx: u32 },
    /// Evict old paths of corpus `id` (sliding-window truncation); responds
    /// with the surviving path count. The frame carries no paths. Ragged
    /// frames only. Two criteria, combinable:
    /// * `keep > 0` — keep at most the newest `keep` paths (count bound);
    /// * `max_age > 0` — drop paths older than `max_age` append ticks
    ///   (registration is tick 0, every append batch advances the corpus
    ///   clock by one); `keep` then acts as a floor on the survivors.
    ///
    /// `keep == 0 && max_age == 0` is rejected at decode — an empty corpus
    /// has no means.
    EvictCorpus { id: u32, keep: u32, max_age: u32 },
    /// Exponentially-weighted MMD² between the frame's query window and
    /// corpus `id`. `decay_bp` is the per-step weight decay in basis points
    /// (1..=10000; 10000 → uniform weights). Exact kernel only. Ragged
    /// frames only.
    Mmd2Window { id: u32, decay_bp: u32, transform: u8 },
    /// Snapshot every registered corpus (paths + warm derived state) to the
    /// server's configured snapshot path (see
    /// [`Router::with_snapshot_dir`](router::Router::with_snapshot_dir));
    /// responds with the number of corpora written. The frame carries no
    /// paths. Ragged frames only.
    SnapshotCorpus,
}

impl Op {
    pub fn code(&self) -> u32 {
        match self {
            Op::Signature { .. } => 1,
            Op::LogSignature { .. } => 2,
            Op::SigKernel { .. } => 3,
            Op::SigKernelGrad { .. } => 4,
            Op::Mmd2LowRank { .. } => 5,
            Op::GramLowRank { .. } => 6,
            Op::RegisterCorpus => 7,
            Op::AppendCorpus { .. } => 8,
            Op::Mmd2Corpus { .. } => 9,
            Op::ExtendPath { .. } => 10,
            Op::EvictCorpus { .. } => 11,
            Op::Mmd2Window { .. } => 12,
            Op::SnapshotCorpus => 13,
        }
    }
}

/// Number of wire op codes (codes are 1-based and dense) — sizes the
/// per-op metrics counters.
pub const OP_CODE_COUNT: usize = 13;

/// Decode the transform byte used on the wire.
pub fn transform_from_u8(v: u8) -> Option<Transform> {
    match v {
        0 => Some(Transform::None),
        1 => Some(Transform::TimeAug),
        2 => Some(Transform::LeadLag),
        3 => Some(Transform::LeadLagTimeAug),
        _ => None,
    }
}

/// Encode a transform for the wire.
pub fn transform_to_u8(t: Transform) -> u8 {
    match t {
        Transform::None => 0,
        Transform::TimeAug => 1,
        Transform::LeadLag => 2,
        Transform::LeadLagTimeAug => 3,
    }
}

/// A single in-flight request: one path (or pair), plus the reply channel.
pub struct Request {
    pub op: Op,
    pub len: usize,
    pub dim: usize,
    /// Primary path, row-major `[len, dim]`.
    pub data: Vec<f64>,
    /// Second path for kernel ops.
    pub data2: Option<Vec<f64>>,
    pub reply: std::sync::mpsc::Sender<Response>,
}

/// Response payload.
#[derive(Debug, Clone)]
pub enum Response {
    Values(Vec<f64>),
    Error(String),
    /// Load was shed at admission: a queue cap was hit. Carries the
    /// server's backoff hint; clients should retry after roughly this long
    /// (the bundled [`Client`] does, with capped exponential backoff).
    Overloaded { retry_after_ms: u64 },
    /// The request's deadline passed before compute started — the batcher
    /// never runs work whose requester has already given up on it.
    DeadlineExceeded,
    /// The server is draining for shutdown and no longer admits work.
    ShuttingDown,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transform_roundtrip() {
        for t in [
            Transform::None,
            Transform::TimeAug,
            Transform::LeadLag,
            Transform::LeadLagTimeAug,
        ] {
            assert_eq!(transform_from_u8(transform_to_u8(t)), Some(t));
        }
        assert_eq!(transform_from_u8(9), None);
    }

    #[test]
    fn op_codes_distinct() {
        let ops = [
            Op::Signature {
                depth: 3,
                transform: 0,
            },
            Op::LogSignature {
                depth: 3,
                transform: 0,
            },
            Op::SigKernel {
                lam1: 0,
                lam2: 0,
                transform: 0,
                scheme: 0,
            },
            Op::SigKernelGrad {
                lam1: 0,
                lam2: 0,
                scheme: 0,
            },
            Op::Mmd2LowRank {
                rank: 1,
                nx: 1,
                transform: 0,
            },
            Op::GramLowRank {
                rank: 1,
                nx: 1,
                transform: 0,
            },
            Op::RegisterCorpus,
            Op::AppendCorpus { id: 0 },
            Op::Mmd2Corpus {
                id: 0,
                rank: 0,
                transform: 0,
            },
            Op::ExtendPath { id: 0, path_idx: 0 },
            Op::EvictCorpus {
                id: 0,
                keep: 1,
                max_age: 0,
            },
            Op::Mmd2Window {
                id: 0,
                decay_bp: 10000,
                transform: 0,
            },
            Op::SnapshotCorpus,
        ];
        let codes: std::collections::HashSet<u32> = ops.iter().map(|o| o.code()).collect();
        assert_eq!(codes.len(), ops.len());
        assert_eq!(ops.len(), OP_CODE_COUNT, "codes are 1-based and dense");
        assert!(ops.iter().all(|o| o.code() as usize <= OP_CODE_COUNT));
    }
}
