//! Length-prefixed little-endian binary wire protocol for the coordinator
//! (a from-scratch stand-in for serde/bincode, unavailable offline).
//!
//! Single-path request frame (magic `SIGL`):
//!   u32 magic | u32 op | u32 p1 | u32 p2 | u32 transform |
//!   u32 len | u32 dim | u32 n_values | n_values × f64
//! (kernel ops carry x followed by y, so n_values = 2·len·dim).
//!
//! Ragged-batch request frame (magic `SIGR`):
//!   u32 magic | u32 op | u32 p1 | u32 p2 | u32 transform |
//!   u32 n_lengths | u32 dim | u32 n_values |
//!   n_lengths × u32 path lengths | n_values × f64
//! Paths live back-to-back in the value payload; kernel ops interleave
//! (x_i, y_i) pairs, so n_lengths must be even for them.
//!
//! Response frame:
//!   u32 status | u32 n | payload
//!   status 0 = ok (payload: n × f64). Every other status carries n utf-8
//!   bytes: 1 = error, 2 = overloaded (the text embeds a
//!   `retry_after_ms=<n>` backoff hint), 3 = deadline exceeded. Peers that
//!   predate statuses 2/3 read any nonzero status as a generic error
//!   string, so new servers degrade gracefully against old clients.
//!
//! **Headers are validated on decode.** A malformed-but-framed request
//! (unknown op, zero dim, `n_values` disagreeing with the declared shape, …)
//! consumes exactly its declared payload and surfaces as a decode-level
//! `Err(SigError)`, so the server can answer with a wire error response and
//! keep the connection alive. Only errors that destroy framing (bad magic,
//! absurd sizes) tear the connection down.

use std::io::{Read, Write};

use crate::coordinator::Op;
use crate::path::SigError;

pub const MAGIC: u32 = 0x5349_474C; // "SIGL"
pub const MAGIC_RAGGED: u32 = 0x5349_4752; // "SIGR"

/// Refuse single frames above this many f64 values before allocating
/// (simple DoS guard).
const MAX_VALUES: usize = 1 << 28;
/// Refuse ragged frames with more than this many length entries.
const MAX_LENGTHS: usize = 1 << 22;

/// A decoded single-path request frame.
#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    pub op: Op,
    pub len: usize,
    pub dim: usize,
    pub values: Vec<f64>,
}

/// A decoded ragged-batch request frame: paths of different lengths,
/// back-to-back in `values`. Kernel ops interleave (x_i, y_i) pairs in
/// `lengths`/`values`.
#[derive(Debug, Clone, PartialEq)]
pub struct RaggedFrame {
    pub op: Op,
    pub dim: usize,
    pub lengths: Vec<usize>,
    pub values: Vec<f64>,
}

impl RaggedFrame {
    /// Number of requests in the frame (pairs count once for kernel ops).
    pub fn batch(&self) -> usize {
        if op_is_paired(self.op) {
            self.lengths.len() / 2
        } else {
            self.lengths.len()
        }
    }
}

/// Either kind of request the wire can carry.
#[derive(Debug, Clone, PartialEq)]
pub enum RequestFrame {
    Single(Frame),
    Ragged(RaggedFrame),
}

/// Does this op carry a pair of paths per request?
pub fn op_is_paired(op: Op) -> bool {
    matches!(op, Op::SigKernel { .. } | Op::SigKernelGrad { .. })
}

fn op_to_parts(op: Op) -> (u32, u32, u32, u32) {
    match op {
        Op::Signature { depth, transform } => (1, depth, 0, transform as u32),
        Op::LogSignature { depth, transform } => (2, depth, 0, transform as u32),
        // The scheme byte rides the high byte of the otherwise-small
        // transform slot (transform ≤ 3), keeping the frame layout fixed at
        // 8 fields; SigKernelGrad's slot was previously unused (always 0),
        // so old peers decode as scheme 0 = Order1.
        Op::SigKernel {
            lam1,
            lam2,
            transform,
            scheme,
        } => (3, lam1, lam2, transform as u32 | (scheme as u32) << 8),
        Op::SigKernelGrad { lam1, lam2, scheme } => (4, lam1, lam2, (scheme as u32) << 8),
        Op::Mmd2LowRank {
            rank,
            nx,
            transform,
        } => (5, rank, nx, transform as u32),
        Op::GramLowRank {
            rank,
            nx,
            transform,
        } => (6, rank, nx, transform as u32),
        Op::RegisterCorpus => (7, 0, 0, 0),
        Op::AppendCorpus { id } => (8, id, 0, 0),
        Op::Mmd2Corpus {
            id,
            rank,
            transform,
        } => (9, id, rank, transform as u32),
        Op::ExtendPath { id, path_idx } => (10, id, path_idx, 0),
        // Pure-control op: the otherwise unused transform slot carries the
        // optional age bound, keeping the frame layout fixed at 8 fields.
        Op::EvictCorpus { id, keep, max_age } => (11, id, keep, max_age),
        Op::Mmd2Window {
            id,
            decay_bp,
            transform,
        } => (12, id, decay_bp, transform as u32),
        Op::SnapshotCorpus => (13, 0, 0, 0),
    }
}

/// Split a kernel op's `tr` slot into `(scheme, low byte)`. The scheme byte
/// must name a known Goursat scheme (0 = order-1, 1 = order-2), and nothing
/// may ride above the two defined bytes.
fn split_scheme(tr: u32) -> Result<(u8, u32), SigError> {
    if tr > 0xFFFF {
        return Err(SigError::Protocol(format!(
            "kernel op tr slot {tr:#x} has bits above the transform/scheme bytes"
        )));
    }
    let scheme = (tr >> 8) as u8;
    if scheme > 1 {
        return Err(SigError::Protocol(format!(
            "unknown Goursat scheme byte {scheme} (known: 0 = order-1, 1 = order-2)"
        )));
    }
    Ok((scheme, tr & 0xFF))
}

fn op_from_parts(code: u32, p1: u32, p2: u32, tr: u32) -> Result<Op, SigError> {
    // Lazy: the slot is only a transform for the ops that carry one —
    // EvictCorpus (code 11) reuses it for its age bound, so validation
    // must happen per-arm, not up front.
    let transform = || {
        u8::try_from(tr)
            .ok()
            .filter(|&t| t <= 3)
            .ok_or(SigError::BadTransform(tr.min(255) as u8))
    };
    match code {
        1 => Ok(Op::Signature {
            depth: p1,
            transform: transform()?,
        }),
        2 => Ok(Op::LogSignature {
            depth: p1,
            transform: transform()?,
        }),
        3 => {
            // Low byte: transform; high byte: Goursat scheme (see
            // op_to_parts). Anything above two bytes is a malformed frame.
            let (scheme, low) = split_scheme(tr)?;
            let transform = u8::try_from(low)
                .ok()
                .filter(|&t| t <= 3)
                .ok_or(SigError::BadTransform(low.min(255) as u8))?;
            Ok(Op::SigKernel {
                lam1: p1,
                lam2: p2,
                transform,
                scheme,
            })
        }
        4 => {
            let (scheme, low) = split_scheme(tr)?;
            if low != 0 {
                return Err(SigError::Protocol(format!(
                    "SigKernelGrad carries no transform; got nonzero low byte {low}"
                )));
            }
            Ok(Op::SigKernelGrad {
                lam1: p1,
                lam2: p2,
                scheme,
            })
        }
        5 => Ok(Op::Mmd2LowRank {
            rank: p1,
            nx: p2,
            transform: transform()?,
        }),
        6 => Ok(Op::GramLowRank {
            rank: p1,
            nx: p2,
            transform: transform()?,
        }),
        7 => Ok(Op::RegisterCorpus),
        8 => Ok(Op::AppendCorpus { id: p1 }),
        9 => Ok(Op::Mmd2Corpus {
            id: p1,
            rank: p2,
            transform: transform()?,
        }),
        10 => Ok(Op::ExtendPath {
            id: p1,
            path_idx: p2,
        }),
        11 => {
            if p2 == 0 && tr == 0 {
                return Err(SigError::Protocol(
                    "EvictCorpus needs a keep count or a max age (both zero would empty the corpus)"
                        .to_string(),
                ));
            }
            Ok(Op::EvictCorpus {
                id: p1,
                keep: p2,
                max_age: tr,
            })
        }
        12 => {
            if p2 == 0 || p2 > 10_000 {
                return Err(SigError::Protocol(format!(
                    "Mmd2Window decay_bp {p2} outside 1..=10000"
                )));
            }
            Ok(Op::Mmd2Window {
                id: p1,
                decay_bp: p2,
                transform: transform()?,
            })
        }
        13 => Ok(Op::SnapshotCorpus),
        other => Err(SigError::Protocol(format!("unknown op code {other}"))),
    }
}

/// A header field must fit u32 exactly — refuse to encode (and silently
/// truncate into a desynchronized frame) otherwise.
fn fit_u32(v: usize, what: &str) -> std::io::Result<u32> {
    u32::try_from(v).map_err(|_| {
        std::io::Error::new(
            std::io::ErrorKind::InvalidInput,
            format!("{what} ({v}) does not fit the wire's u32 header field"),
        )
    })
}

pub fn write_request<W: Write>(w: &mut W, frame: &Frame) -> std::io::Result<()> {
    let (code, p1, p2, tr) = op_to_parts(frame.op);
    let header = [
        MAGIC,
        code,
        p1,
        p2,
        tr,
        fit_u32(frame.len, "path length")?,
        fit_u32(frame.dim, "path dimension")?,
        fit_u32(frame.values.len(), "value count")?,
    ];
    let mut buf = Vec::with_capacity(32 + frame.values.len() * 8);
    for h in header {
        buf.extend_from_slice(&h.to_le_bytes());
    }
    for v in &frame.values {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    w.write_all(&buf)
}

/// Encode a ragged-batch request frame.
pub fn write_ragged_request<W: Write>(w: &mut W, frame: &RaggedFrame) -> std::io::Result<()> {
    let (code, p1, p2, tr) = op_to_parts(frame.op);
    let header = [
        MAGIC_RAGGED,
        code,
        p1,
        p2,
        tr,
        fit_u32(frame.lengths.len(), "path count")?,
        fit_u32(frame.dim, "path dimension")?,
        fit_u32(frame.values.len(), "value count")?,
    ];
    let mut buf = Vec::with_capacity(32 + frame.lengths.len() * 4 + frame.values.len() * 8);
    for h in header {
        buf.extend_from_slice(&h.to_le_bytes());
    }
    for &l in &frame.lengths {
        buf.extend_from_slice(&fit_u32(l, "path length")?.to_le_bytes());
    }
    for v in &frame.values {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    w.write_all(&buf)
}

fn hard_err(msg: &str) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, msg)
}

/// Copy the head of `src` into a fixed-size array for `from_le_bytes`,
/// replacing the `try_into().unwrap()` idiom the panic-freedom lint
/// forbids. Every caller passes exactly `N` bytes (`chunks_exact` /
/// `split_at` slices); a short `src` zero-pads instead of panicking.
fn le_array<const N: usize>(src: &[u8]) -> [u8; N] {
    let mut out = [0u8; N];
    for (o, s) in out.iter_mut().zip(src) {
        *o = *s;
    }
    out
}

fn read_f64s<R: Read>(r: &mut R, n: usize) -> std::io::Result<Vec<f64>> {
    let mut data = vec![0u8; n * 8];
    r.read_exact(&mut data)?;
    Ok(data
        .chunks_exact(8)
        .map(|c| f64::from_le_bytes(le_array(c)))
        .collect())
}

/// Validate a single frame's shape against its op. The payload has already
/// been consumed, so a failure here leaves the stream at a frame boundary.
fn validate_single(op: Op, len: usize, dim: usize, n_values: usize) -> Result<(), SigError> {
    if matches!(op, Op::Mmd2LowRank { .. } | Op::GramLowRank { .. }) {
        return Err(SigError::Protocol(
            "low-rank ops take a ragged-batch frame (two corpora), not a single-path frame"
                .to_string(),
        ));
    }
    if matches!(
        op,
        Op::RegisterCorpus
            | Op::AppendCorpus { .. }
            | Op::Mmd2Corpus { .. }
            | Op::ExtendPath { .. }
            | Op::EvictCorpus { .. }
            | Op::Mmd2Window { .. }
            | Op::SnapshotCorpus
    ) {
        return Err(SigError::Protocol(
            "corpus ops take a ragged-batch frame, not a single-path frame".to_string(),
        ));
    }
    if dim == 0 {
        return Err(SigError::ZeroDim);
    }
    if len == 0 {
        return Err(SigError::EmptyPath);
    }
    // Checked arithmetic: a wrapped multiplication here would let a crafted
    // header bypass the shape check entirely.
    let per = len
        .checked_mul(dim)
        .ok_or(SigError::TooLarge("frame shape"))?;
    let expected = if op_is_paired(op) {
        per.checked_mul(2).ok_or(SigError::TooLarge("frame shape"))?
    } else {
        per
    };
    if n_values != expected {
        return Err(SigError::Protocol(format!(
            "header declares len={len} dim={dim} but carries {n_values} values \
             (expected {expected})"
        )));
    }
    Ok(())
}

/// Validate a ragged frame's lengths against its op and payload size.
fn validate_ragged(
    op: Op,
    dim: usize,
    lengths: &[usize],
    n_values: usize,
) -> Result<(), SigError> {
    if dim == 0 {
        return Err(SigError::ZeroDim);
    }
    if op_is_paired(op) && lengths.len() % 2 != 0 {
        return Err(SigError::Protocol(format!(
            "kernel ops need (x, y) length pairs; got {} lengths",
            lengths.len()
        )));
    }
    // Corpus ops carry at least one path (an empty registration / append /
    // query is meaningless and the registry would reject it anyway).
    // Streaming ops have their own shapes: ExtendPath is exactly one path
    // of new points, EvictCorpus is pure control and carries none.
    if matches!(
        op,
        Op::RegisterCorpus | Op::AppendCorpus { .. } | Op::Mmd2Corpus { .. } | Op::Mmd2Window { .. }
    ) && lengths.is_empty()
    {
        return Err(SigError::Protocol(
            "corpus ops need at least one path in the frame".to_string(),
        ));
    }
    if matches!(op, Op::ExtendPath { .. }) && lengths.len() != 1 {
        return Err(SigError::Protocol(format!(
            "ExtendPath takes exactly one path of new points; got {} paths",
            lengths.len()
        )));
    }
    if matches!(op, Op::EvictCorpus { .. } | Op::SnapshotCorpus) && !lengths.is_empty() {
        return Err(SigError::Protocol(format!(
            "pure-control corpus ops carry no paths; the frame has {}",
            lengths.len()
        )));
    }
    // Low-rank ops split the frame's paths at `nx`: both corpora must be
    // non-empty for the split to be meaningful.
    if let Op::Mmd2LowRank { nx, .. } | Op::GramLowRank { nx, .. } = op {
        let nx = nx as usize;
        if nx == 0 || nx >= lengths.len() {
            return Err(SigError::Protocol(format!(
                "low-rank op splits {} paths at nx={nx}; both sides must be non-empty",
                lengths.len()
            )));
        }
    }
    let mut total = 0usize;
    for &l in lengths {
        if l == 0 {
            return Err(SigError::EmptyPath);
        }
        total = total
            .checked_add(l)
            .ok_or(SigError::TooLarge("ragged frame size"))?;
    }
    let expected = total
        .checked_mul(dim)
        .ok_or(SigError::TooLarge("ragged frame size"))?;
    if expected != n_values {
        return Err(SigError::Protocol(format!(
            "lengths sum to {total} points × dim {dim} but frame carries \
             {n_values} values"
        )));
    }
    Ok(())
}

/// Read one request frame.
///
/// * `Ok(None)` — clean EOF at a frame boundary.
/// * `Ok(Some(Ok(frame)))` — a validated frame.
/// * `Ok(Some(Err(e)))` — a malformed but correctly framed request; its
///   payload has been consumed, the connection is still usable, and `e`
///   should be sent back as a wire error response.
/// * `Err(_)` — I/O failure or a frame that destroys framing (bad magic,
///   absurd sizes); the connection must be dropped.
pub fn read_request<R: Read>(
    r: &mut R,
) -> std::io::Result<Option<Result<RequestFrame, SigError>>> {
    let mut header = [0u8; 32];
    match r.read_exact(&mut header) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e),
    }
    let mut fields = [0u32; 8];
    for (f, c) in fields.iter_mut().zip(header.chunks_exact(4)) {
        *f = u32::from_le_bytes(le_array(c));
    }
    let [magic, code, p1, p2, tr, f5, f6, f7] = fields;
    if magic != MAGIC && magic != MAGIC_RAGGED {
        return Err(hard_err("bad magic"));
    }
    let op = op_from_parts(code, p1, p2, tr);
    let n_values = f7 as usize;
    if n_values > MAX_VALUES {
        return Err(hard_err("frame too large"));
    }
    if magic == MAGIC {
        let len = f5 as usize;
        let dim = f6 as usize;
        // Consume the payload first so that validation failures keep the
        // stream at a frame boundary.
        let values = read_f64s(r, n_values)?;
        let frame = op.and_then(|op| {
            validate_single(op, len, dim, n_values)?;
            Ok(RequestFrame::Single(Frame {
                op,
                len,
                dim,
                values,
            }))
        });
        Ok(Some(frame))
    } else {
        let n_lengths = f5 as usize;
        let dim = f6 as usize;
        if n_lengths > MAX_LENGTHS {
            return Err(hard_err("too many paths in ragged frame"));
        }
        let mut lbytes = vec![0u8; n_lengths * 4];
        r.read_exact(&mut lbytes)?;
        let lengths: Vec<usize> = lbytes
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(le_array(c)) as usize)
            .collect();
        let values = read_f64s(r, n_values)?;
        let frame = op.and_then(|op| {
            validate_ragged(op, dim, &lengths, n_values)?;
            Ok(RequestFrame::Ragged(RaggedFrame {
                op,
                dim,
                lengths,
                values,
            }))
        });
        Ok(Some(frame))
    }
}

pub fn write_response<W: Write>(
    w: &mut W,
    result: &Result<Vec<f64>, String>,
) -> std::io::Result<()> {
    let mut buf = Vec::new();
    match result {
        Ok(values) => {
            buf.extend_from_slice(&0u32.to_le_bytes());
            buf.extend_from_slice(&(values.len() as u32).to_le_bytes());
            for v in values {
                buf.extend_from_slice(&v.to_le_bytes());
            }
        }
        Err(msg) => {
            buf.extend_from_slice(&1u32.to_le_bytes());
            buf.extend_from_slice(&(msg.len() as u32).to_le_bytes());
            buf.extend_from_slice(msg.as_bytes());
        }
    }
    w.write_all(&buf)
}

pub fn read_response<R: Read>(r: &mut R) -> std::io::Result<Result<Vec<f64>, String>> {
    let mut header = [0u8; 8];
    r.read_exact(&mut header)?;
    let (sb, nb) = header.split_at(4);
    let status = u32::from_le_bytes(le_array(sb));
    let n = u32::from_le_bytes(le_array(nb)) as usize;
    if status == 0 {
        let mut data = vec![0u8; n * 8];
        r.read_exact(&mut data)?;
        Ok(Ok(data
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(le_array(c)))
            .collect()))
    } else {
        let mut data = vec![0u8; n];
        r.read_exact(&mut data)?;
        Ok(Err(String::from_utf8_lossy(&data).into_owned()))
    }
}

/// Response statuses. 0 and 1 predate the admission-control statuses; every
/// nonzero status carries a utf-8 payload so peers that only know 0/1 read
/// statuses 2/3 as a generic error string instead of desyncing the stream.
pub const STATUS_OK: u32 = 0;
pub const STATUS_ERR: u32 = 1;
pub const STATUS_OVERLOADED: u32 = 2;
pub const STATUS_DEADLINE: u32 = 3;

/// A decoded response that preserves the typed overload / deadline statuses
/// which the legacy [`read_response`] flattens into `Err(String)`.
#[derive(Debug, Clone, PartialEq)]
pub enum WireResponse {
    Values(Vec<f64>),
    Error(String),
    /// Status 2. The payload text embeds `retry_after_ms=<n>`, which doubles
    /// as a human-readable message for old peers and a machine-parsable
    /// backoff hint for new ones.
    Overloaded { retry_after_ms: u64 },
    /// Status 3: the request's deadline passed before compute started.
    DeadlineExceeded,
}

fn write_status_text<W: Write>(w: &mut W, status: u32, text: &str) -> std::io::Result<()> {
    let mut buf = Vec::with_capacity(8 + text.len());
    buf.extend_from_slice(&status.to_le_bytes());
    buf.extend_from_slice(&(text.len() as u32).to_le_bytes());
    buf.extend_from_slice(text.as_bytes());
    w.write_all(&buf)
}

pub fn write_typed_response<W: Write>(w: &mut W, resp: &WireResponse) -> std::io::Result<()> {
    match resp {
        WireResponse::Values(values) => {
            let mut buf = Vec::with_capacity(8 + values.len() * 8);
            buf.extend_from_slice(&STATUS_OK.to_le_bytes());
            buf.extend_from_slice(&(values.len() as u32).to_le_bytes());
            for v in values {
                buf.extend_from_slice(&v.to_le_bytes());
            }
            w.write_all(&buf)
        }
        WireResponse::Error(msg) => write_status_text(w, STATUS_ERR, msg),
        WireResponse::Overloaded { retry_after_ms } => write_status_text(
            w,
            STATUS_OVERLOADED,
            &format!("server overloaded; retry_after_ms={retry_after_ms}"),
        ),
        WireResponse::DeadlineExceeded => {
            write_status_text(w, STATUS_DEADLINE, "deadline exceeded")
        }
    }
}

pub fn read_typed_response<R: Read>(r: &mut R) -> std::io::Result<WireResponse> {
    let mut header = [0u8; 8];
    r.read_exact(&mut header)?;
    let (sb, nb) = header.split_at(4);
    let status = u32::from_le_bytes(le_array(sb));
    let n = u32::from_le_bytes(le_array(nb)) as usize;
    if status == STATUS_OK {
        let mut data = vec![0u8; n * 8];
        r.read_exact(&mut data)?;
        return Ok(WireResponse::Values(
            data.chunks_exact(8)
                .map(|c| f64::from_le_bytes(le_array(c)))
                .collect(),
        ));
    }
    let mut data = vec![0u8; n];
    r.read_exact(&mut data)?;
    let text = String::from_utf8_lossy(&data).into_owned();
    Ok(match status {
        STATUS_OVERLOADED => WireResponse::Overloaded {
            // A hint, not a contract: a mangled payload degrades to the
            // minimum backoff rather than an error.
            retry_after_ms: text
                .split_once("retry_after_ms=")
                .and_then(|(_, t)| t.trim().parse().ok())
                .unwrap_or(1),
        },
        STATUS_DEADLINE => WireResponse::DeadlineExceeded,
        _ => WireResponse::Error(text),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ok_frame<R: Read>(r: &mut R) -> RequestFrame {
        read_request(r).unwrap().unwrap().unwrap()
    }

    #[test]
    fn request_roundtrip() {
        let frame = Frame {
            op: Op::SigKernel {
                lam1: 1,
                lam2: 2,
                transform: 1,
                scheme: 1,
            },
            len: 4,
            dim: 2,
            values: (0..16).map(|v| v as f64).collect(),
        };
        let mut buf = Vec::new();
        write_request(&mut buf, &frame).unwrap();
        assert_eq!(
            ok_frame(&mut buf.as_slice()),
            RequestFrame::Single(frame)
        );
    }

    #[test]
    fn ragged_request_roundtrip() {
        let frame = RaggedFrame {
            op: Op::Signature {
                depth: 3,
                transform: 0,
            },
            dim: 2,
            lengths: vec![3, 1, 2],
            values: (0..12).map(|v| v as f64 * 0.5).collect(),
        };
        let mut buf = Vec::new();
        write_ragged_request(&mut buf, &frame).unwrap();
        assert_eq!(frame.batch(), 3);
        assert_eq!(
            ok_frame(&mut buf.as_slice()),
            RequestFrame::Ragged(frame)
        );
    }

    #[test]
    fn ragged_kernel_pairs_roundtrip() {
        let frame = RaggedFrame {
            op: Op::SigKernel {
                lam1: 0,
                lam2: 0,
                transform: 0,
                scheme: 0,
            },
            dim: 1,
            lengths: vec![2, 3, 4, 2],
            values: (0..11).map(|v| v as f64).collect(),
        };
        let mut buf = Vec::new();
        write_ragged_request(&mut buf, &frame).unwrap();
        assert_eq!(frame.batch(), 2);
        assert_eq!(
            ok_frame(&mut buf.as_slice()),
            RequestFrame::Ragged(frame)
        );
    }

    #[test]
    fn scheme_byte_roundtrips_and_junk_is_rejected() {
        // Both kernel ops carry the scheme in the high byte of the tr slot.
        for op in [
            Op::SigKernel {
                lam1: 2,
                lam2: 1,
                transform: 3,
                scheme: 1,
            },
            Op::SigKernelGrad {
                lam1: 1,
                lam2: 1,
                scheme: 1,
            },
        ] {
            let (code, p1, p2, tr) = op_to_parts(op);
            assert_eq!(op_from_parts(code, p1, p2, tr).unwrap(), op);
        }
        // Unknown scheme byte, junk above the two defined bytes, and a
        // transform smuggled into a grad frame all fail typed, not panic.
        assert!(matches!(
            op_from_parts(3, 0, 0, 2 << 8),
            Err(SigError::Protocol(_))
        ));
        assert!(matches!(
            op_from_parts(3, 0, 0, 1 << 16),
            Err(SigError::Protocol(_))
        ));
        assert!(matches!(
            op_from_parts(4, 0, 0, 7),
            Err(SigError::Protocol(_))
        ));
        assert!(matches!(
            op_from_parts(3, 0, 0, 9),
            Err(SigError::BadTransform(9))
        ));
    }

    #[test]
    fn response_roundtrip_ok_and_err() {
        for result in [Ok(vec![1.5, -2.0]), Err("boom".to_string())] {
            let mut buf = Vec::new();
            write_response(&mut buf, &result).unwrap();
            let got = read_response(&mut buf.as_slice()).unwrap();
            assert_eq!(got, result);
        }
    }

    #[test]
    fn eof_at_boundary_is_none() {
        let empty: &[u8] = &[];
        assert!(read_request(&mut &*empty).unwrap().is_none());
    }

    #[test]
    fn bad_magic_tears_down_the_connection() {
        let buf = vec![0u8; 32];
        assert!(read_request(&mut buf.as_slice()).is_err());
    }

    /// The satellite requirement: a frame whose header disagrees with its
    /// payload size decodes to a soft error, consumes exactly its payload,
    /// and the next frame on the stream still parses.
    #[test]
    fn malformed_frame_roundtrip_preserves_framing() {
        let mut buf = Vec::new();
        // Frame 1: declares len=4 dim=2 (expects 8 values) but carries 3.
        let bad = Frame {
            op: Op::Signature {
                depth: 2,
                transform: 0,
            },
            len: 4,
            dim: 2,
            values: vec![1.0, 2.0, 3.0],
        };
        write_request(&mut buf, &bad).unwrap();
        // Frame 2: well-formed.
        let good = Frame {
            op: Op::Signature {
                depth: 2,
                transform: 0,
            },
            len: 2,
            dim: 2,
            values: vec![0.0, 0.0, 1.0, 1.0],
        };
        write_request(&mut buf, &good).unwrap();
        let mut r = buf.as_slice();
        let first = read_request(&mut r).unwrap().unwrap();
        assert!(matches!(first, Err(SigError::Protocol(_))), "{first:?}");
        let second = ok_frame(&mut r);
        assert_eq!(second, RequestFrame::Single(good));
        assert!(read_request(&mut r).unwrap().is_none());
    }

    #[test]
    fn zero_dim_and_zero_len_are_soft_errors() {
        for (len, dim, want_zero_dim) in [(4usize, 0usize, true), (0, 2, false)] {
            let mut buf = Vec::new();
            let f = Frame {
                op: Op::Signature {
                    depth: 2,
                    transform: 0,
                },
                len,
                dim,
                values: vec![],
            };
            write_request(&mut buf, &f).unwrap();
            let got = read_request(&mut buf.as_slice()).unwrap().unwrap();
            if want_zero_dim {
                assert_eq!(got, Err(SigError::ZeroDim));
            } else {
                assert_eq!(got, Err(SigError::EmptyPath));
            }
        }
    }

    #[test]
    fn unknown_op_and_bad_transform_are_soft_errors() {
        // Unknown op code 14 (codes 1..=13 are assigned).
        let mut buf = Vec::new();
        for h in [MAGIC, 14, 0, 0, 0, 2, 1, 2u32] {
            buf.extend_from_slice(&h.to_le_bytes());
        }
        buf.extend_from_slice(&1.0f64.to_le_bytes());
        buf.extend_from_slice(&2.0f64.to_le_bytes());
        let got = read_request(&mut buf.as_slice()).unwrap().unwrap();
        assert!(matches!(got, Err(SigError::Protocol(_))), "{got:?}");
        // Known op, unknown transform code 9.
        let mut buf = Vec::new();
        for h in [MAGIC, 1, 2, 0, 9, 2, 1, 2u32] {
            buf.extend_from_slice(&h.to_le_bytes());
        }
        buf.extend_from_slice(&1.0f64.to_le_bytes());
        buf.extend_from_slice(&2.0f64.to_le_bytes());
        let got = read_request(&mut buf.as_slice()).unwrap().unwrap();
        assert_eq!(got, Err(SigError::BadTransform(9)));
    }

    #[test]
    fn lowrank_ops_roundtrip_with_rank_field() {
        let frame = RaggedFrame {
            op: Op::Mmd2LowRank {
                rank: 4,
                nx: 2,
                transform: 0,
            },
            dim: 1,
            lengths: vec![2, 3, 4],
            values: (0..9).map(|v| v as f64).collect(),
        };
        let mut buf = Vec::new();
        write_ragged_request(&mut buf, &frame).unwrap();
        // Not a paired op: every path counts once.
        assert_eq!(frame.batch(), 3);
        assert_eq!(ok_frame(&mut buf.as_slice()), RequestFrame::Ragged(frame));
        let gram = RaggedFrame {
            op: Op::GramLowRank {
                rank: 8,
                nx: 1,
                transform: 1,
            },
            dim: 2,
            lengths: vec![2, 2],
            values: vec![0.0; 8],
        };
        let mut buf = Vec::new();
        write_ragged_request(&mut buf, &gram).unwrap();
        assert_eq!(ok_frame(&mut buf.as_slice()), RequestFrame::Ragged(gram));
    }

    #[test]
    fn lowrank_ops_reject_bad_split_and_single_frames() {
        // nx out of range (0 or >= path count) is a soft error.
        for nx in [0u32, 3, 9] {
            let frame = RaggedFrame {
                op: Op::Mmd2LowRank {
                    rank: 2,
                    nx,
                    transform: 0,
                },
                dim: 1,
                lengths: vec![2, 3, 4],
                values: vec![0.0; 9],
            };
            let mut buf = Vec::new();
            write_ragged_request(&mut buf, &frame).unwrap();
            let got = read_request(&mut buf.as_slice()).unwrap().unwrap();
            assert!(matches!(got, Err(SigError::Protocol(_))), "nx={nx}: {got:?}");
        }
        // A single-path frame cannot carry a low-rank op.
        let f = Frame {
            op: Op::GramLowRank {
                rank: 2,
                nx: 1,
                transform: 0,
            },
            len: 2,
            dim: 1,
            values: vec![0.0, 1.0],
        };
        let mut buf = Vec::new();
        write_request(&mut buf, &f).unwrap();
        let got = read_request(&mut buf.as_slice()).unwrap().unwrap();
        assert!(matches!(got, Err(SigError::Protocol(_))), "{got:?}");
    }

    #[test]
    fn corpus_ops_roundtrip_with_id_and_rank_fields() {
        for op in [
            Op::RegisterCorpus,
            Op::AppendCorpus { id: 3 },
            Op::Mmd2Corpus {
                id: 3,
                rank: 8,
                transform: 1,
            },
        ] {
            let frame = RaggedFrame {
                op,
                dim: 2,
                lengths: vec![3, 2],
                values: (0..10).map(|v| v as f64).collect(),
            };
            let mut buf = Vec::new();
            write_ragged_request(&mut buf, &frame).unwrap();
            assert_eq!(ok_frame(&mut buf.as_slice()), RequestFrame::Ragged(frame));
        }
    }

    #[test]
    fn corpus_ops_reject_single_and_empty_frames() {
        // Single-path frames cannot carry corpus ops.
        let f = Frame {
            op: Op::Mmd2Corpus {
                id: 1,
                rank: 0,
                transform: 0,
            },
            len: 2,
            dim: 1,
            values: vec![0.0, 1.0],
        };
        let mut buf = Vec::new();
        write_request(&mut buf, &f).unwrap();
        let got = read_request(&mut buf.as_slice()).unwrap().unwrap();
        assert!(matches!(got, Err(SigError::Protocol(_))), "{got:?}");
        // A ragged corpus frame with zero paths is a soft error.
        let frame = RaggedFrame {
            op: Op::RegisterCorpus,
            dim: 2,
            lengths: vec![],
            values: vec![],
        };
        let mut buf = Vec::new();
        write_ragged_request(&mut buf, &frame).unwrap();
        let got = read_request(&mut buf.as_slice()).unwrap().unwrap();
        assert!(matches!(got, Err(SigError::Protocol(_))), "{got:?}");
    }

    #[test]
    fn stream_ops_roundtrip_with_their_frame_shapes() {
        // ExtendPath: exactly one path of new points (a single point is a
        // legal extension).
        for len in [1usize, 4] {
            let frame = RaggedFrame {
                op: Op::ExtendPath { id: 2, path_idx: 1 },
                dim: 2,
                lengths: vec![len],
                values: vec![0.5; len * 2],
            };
            let mut buf = Vec::new();
            write_ragged_request(&mut buf, &frame).unwrap();
            assert_eq!(ok_frame(&mut buf.as_slice()), RequestFrame::Ragged(frame));
        }
        // EvictCorpus: pure control, no paths. All three field mixes the
        // decoder accepts survive the round trip, including an age bound
        // far above the transform range the slot normally carries.
        for (keep, max_age) in [(3u32, 0u32), (0, 17), (2, 1_000_000)] {
            let frame = RaggedFrame {
                op: Op::EvictCorpus {
                    id: 2,
                    keep,
                    max_age,
                },
                dim: 1,
                lengths: vec![],
                values: vec![],
            };
            let mut buf = Vec::new();
            write_ragged_request(&mut buf, &frame).unwrap();
            assert_eq!(ok_frame(&mut buf.as_slice()), RequestFrame::Ragged(frame));
        }
        // Mmd2Window: a normal query window.
        let frame = RaggedFrame {
            op: Op::Mmd2Window {
                id: 2,
                decay_bp: 9500,
                transform: 1,
            },
            dim: 2,
            lengths: vec![3, 2],
            values: (0..10).map(|v| v as f64).collect(),
        };
        let mut buf = Vec::new();
        write_ragged_request(&mut buf, &frame).unwrap();
        assert_eq!(ok_frame(&mut buf.as_slice()), RequestFrame::Ragged(frame));
    }

    #[test]
    fn stream_ops_reject_malformed_frames() {
        // ExtendPath with two paths is a soft error.
        let frame = RaggedFrame {
            op: Op::ExtendPath { id: 0, path_idx: 0 },
            dim: 1,
            lengths: vec![2, 2],
            values: vec![0.0; 4],
        };
        let mut buf = Vec::new();
        write_ragged_request(&mut buf, &frame).unwrap();
        let got = read_request(&mut buf.as_slice()).unwrap().unwrap();
        assert!(matches!(got, Err(SigError::Protocol(_))), "{got:?}");
        // EvictCorpus carrying paths is a soft error.
        let frame = RaggedFrame {
            op: Op::EvictCorpus {
                id: 0,
                keep: 1,
                max_age: 0,
            },
            dim: 1,
            lengths: vec![2],
            values: vec![0.0; 2],
        };
        let mut buf = Vec::new();
        write_ragged_request(&mut buf, &frame).unwrap();
        let got = read_request(&mut buf.as_slice()).unwrap().unwrap();
        assert!(matches!(got, Err(SigError::Protocol(_))), "{got:?}");
        // EvictCorpus with keep=0 AND max_age=0 (it would empty the corpus)
        // and Mmd2Window decay_bp outside 1..=10000 are rejected at decode —
        // soft errors: the payload is consumed, the connection survives.
        for (code, p2, tr) in [(11u32, 0u32, 0u32), (12, 0, 0), (12, 10_001, 0)] {
            let mut buf = Vec::new();
            for h in [MAGIC_RAGGED, code, 1, p2, tr, 0, 1, 0u32] {
                buf.extend_from_slice(&h.to_le_bytes());
            }
            let got = read_request(&mut buf.as_slice()).unwrap().unwrap();
            assert!(
                matches!(got, Err(SigError::Protocol(_))),
                "code={code} p2={p2}: {got:?}"
            );
        }
        // keep=0 with a positive age bound is well-formed (age-only evict).
        let mut buf = Vec::new();
        for h in [MAGIC_RAGGED, 11u32, 1, 0, 5, 0, 1, 0u32] {
            buf.extend_from_slice(&h.to_le_bytes());
        }
        assert_eq!(
            ok_frame(&mut buf.as_slice()),
            RequestFrame::Ragged(RaggedFrame {
                op: Op::EvictCorpus {
                    id: 1,
                    keep: 0,
                    max_age: 5,
                },
                dim: 1,
                lengths: vec![],
                values: vec![],
            })
        );
        // Single-path frames cannot carry stream ops.
        let f = Frame {
            op: Op::ExtendPath { id: 0, path_idx: 0 },
            len: 2,
            dim: 1,
            values: vec![0.0, 1.0],
        };
        let mut buf = Vec::new();
        write_request(&mut buf, &f).unwrap();
        let got = read_request(&mut buf.as_slice()).unwrap().unwrap();
        assert!(matches!(got, Err(SigError::Protocol(_))), "{got:?}");
    }

    #[test]
    fn snapshot_op_is_pure_control() {
        // Round-trips with an empty frame.
        let frame = RaggedFrame {
            op: Op::SnapshotCorpus,
            dim: 1,
            lengths: vec![],
            values: vec![],
        };
        let mut buf = Vec::new();
        write_ragged_request(&mut buf, &frame).unwrap();
        assert_eq!(ok_frame(&mut buf.as_slice()), RequestFrame::Ragged(frame));
        // Carrying paths is a soft error.
        let frame = RaggedFrame {
            op: Op::SnapshotCorpus,
            dim: 1,
            lengths: vec![2],
            values: vec![0.0; 2],
        };
        let mut buf = Vec::new();
        write_ragged_request(&mut buf, &frame).unwrap();
        let got = read_request(&mut buf.as_slice()).unwrap().unwrap();
        assert!(matches!(got, Err(SigError::Protocol(_))), "{got:?}");
        // So is a single-path frame.
        let f = Frame {
            op: Op::SnapshotCorpus,
            len: 2,
            dim: 1,
            values: vec![0.0, 1.0],
        };
        let mut buf = Vec::new();
        write_request(&mut buf, &f).unwrap();
        let got = read_request(&mut buf.as_slice()).unwrap().unwrap();
        assert!(matches!(got, Err(SigError::Protocol(_))), "{got:?}");
    }

    #[test]
    fn typed_responses_roundtrip_and_degrade_for_old_peers() {
        let cases = [
            WireResponse::Values(vec![1.5, -2.0]),
            WireResponse::Error("bad frame".to_string()),
            WireResponse::Overloaded { retry_after_ms: 7 },
            WireResponse::DeadlineExceeded,
        ];
        for resp in &cases {
            let mut buf = Vec::new();
            write_typed_response(&mut buf, resp).unwrap();
            assert_eq!(&read_typed_response(&mut buf.as_slice()).unwrap(), resp);
        }
        // A peer that predates statuses 2/3 reads them through the legacy
        // decoder as generic error strings — readable, and the stream stays
        // in sync because the payload length is honest.
        let mut buf = Vec::new();
        write_typed_response(&mut buf, &WireResponse::Overloaded { retry_after_ms: 7 }).unwrap();
        write_typed_response(&mut buf, &WireResponse::DeadlineExceeded).unwrap();
        let mut r = buf.as_slice();
        let first = read_response(&mut r).unwrap().unwrap_err();
        assert!(first.contains("retry_after_ms=7"), "{first}");
        let second = read_response(&mut r).unwrap().unwrap_err();
        assert!(second.contains("deadline"), "{second}");
        assert!(r.is_empty());
        // And the legacy encoder's frames decode through the typed reader.
        let mut buf = Vec::new();
        write_response(&mut buf, &Ok(vec![3.0])).unwrap();
        write_response(&mut buf, &Err("boom".to_string())).unwrap();
        let mut r = buf.as_slice();
        assert_eq!(
            read_typed_response(&mut r).unwrap(),
            WireResponse::Values(vec![3.0])
        );
        assert_eq!(
            read_typed_response(&mut r).unwrap(),
            WireResponse::Error("boom".to_string())
        );
        // A mangled overload payload degrades to the minimum backoff hint.
        let mut buf = Vec::new();
        write_status_text(&mut buf, STATUS_OVERLOADED, "???").unwrap();
        assert_eq!(
            read_typed_response(&mut buf.as_slice()).unwrap(),
            WireResponse::Overloaded { retry_after_ms: 1 }
        );
    }

    #[test]
    fn ragged_shape_mismatch_is_a_soft_error() {
        let frame = RaggedFrame {
            op: Op::Signature {
                depth: 2,
                transform: 0,
            },
            dim: 2,
            lengths: vec![3, 2],
            values: vec![0.0; 9], // should be 10
        };
        let mut buf = Vec::new();
        write_ragged_request(&mut buf, &frame).unwrap();
        let got = read_request(&mut buf.as_slice()).unwrap().unwrap();
        assert!(matches!(got, Err(SigError::Protocol(_))), "{got:?}");
        // Odd pair count for a kernel op.
        let frame = RaggedFrame {
            op: Op::SigKernelGrad {
                lam1: 0,
                lam2: 0,
                scheme: 0,
            },
            dim: 1,
            lengths: vec![2, 3, 4],
            values: vec![0.0; 9],
        };
        let mut buf = Vec::new();
        write_ragged_request(&mut buf, &frame).unwrap();
        let got = read_request(&mut buf.as_slice()).unwrap().unwrap();
        assert!(matches!(got, Err(SigError::Protocol(_))), "{got:?}");
    }
}
