//! Length-prefixed little-endian binary wire protocol for the coordinator
//! (a from-scratch stand-in for serde/bincode, unavailable offline).
//!
//! Request frame:
//!   u32 magic "SIGL" | u32 op | u32 p1 | u32 p2 | u32 transform |
//!   u32 len | u32 dim | u32 n_values | n_values × f64
//! (kernel ops carry x followed by y, so n_values = 2·len·dim).
//!
//! Response frame:
//!   u32 status (0 = ok, 1 = error) | u32 n | payload
//!   (ok: n × f64; error: n utf-8 bytes).

use std::io::{Read, Write};

use crate::coordinator::Op;

pub const MAGIC: u32 = 0x5349_474C; // "SIGL"

/// A decoded request frame.
#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    pub op: Op,
    pub len: usize,
    pub dim: usize,
    pub values: Vec<f64>,
}

fn op_to_parts(op: Op) -> (u32, u32, u32, u32) {
    match op {
        Op::Signature { depth, transform } => (1, depth, 0, transform as u32),
        Op::LogSignature { depth, transform } => (2, depth, 0, transform as u32),
        Op::SigKernel {
            lam1,
            lam2,
            transform,
        } => (3, lam1, lam2, transform as u32),
        Op::SigKernelGrad { lam1, lam2 } => (4, lam1, lam2, 0),
    }
}

fn op_from_parts(code: u32, p1: u32, p2: u32, tr: u32) -> Option<Op> {
    let transform = u8::try_from(tr).ok()?;
    match code {
        1 => Some(Op::Signature {
            depth: p1,
            transform,
        }),
        2 => Some(Op::LogSignature {
            depth: p1,
            transform,
        }),
        3 => Some(Op::SigKernel {
            lam1: p1,
            lam2: p2,
            transform,
        }),
        4 => Some(Op::SigKernelGrad { lam1: p1, lam2: p2 }),
        _ => None,
    }
}

pub fn write_request<W: Write>(w: &mut W, frame: &Frame) -> std::io::Result<()> {
    let (code, p1, p2, tr) = op_to_parts(frame.op);
    let header = [
        MAGIC,
        code,
        p1,
        p2,
        tr,
        frame.len as u32,
        frame.dim as u32,
        frame.values.len() as u32,
    ];
    let mut buf = Vec::with_capacity(32 + frame.values.len() * 8);
    for h in header {
        buf.extend_from_slice(&h.to_le_bytes());
    }
    for v in &frame.values {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    w.write_all(&buf)
}

/// Read one request frame; Ok(None) on clean EOF at a frame boundary.
pub fn read_request<R: Read>(r: &mut R) -> std::io::Result<Option<Frame>> {
    let mut header = [0u8; 32];
    match r.read_exact(&mut header) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e),
    }
    let u = |i: usize| u32::from_le_bytes(header[i * 4..i * 4 + 4].try_into().unwrap());
    if u(0) != MAGIC {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "bad magic",
        ));
    }
    let op = op_from_parts(u(1), u(2), u(3), u(4)).ok_or_else(|| {
        std::io::Error::new(std::io::ErrorKind::InvalidData, "unknown op code")
    })?;
    let len = u(5) as usize;
    let dim = u(6) as usize;
    let n = u(7) as usize;
    // Refuse absurd frames before allocating (simple DoS guard).
    if n > (1 << 28) {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "frame too large",
        ));
    }
    let mut data = vec![0u8; n * 8];
    r.read_exact(&mut data)?;
    let values = data
        .chunks_exact(8)
        .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
        .collect();
    Ok(Some(Frame {
        op,
        len,
        dim,
        values,
    }))
}

pub fn write_response<W: Write>(
    w: &mut W,
    result: &Result<Vec<f64>, String>,
) -> std::io::Result<()> {
    let mut buf = Vec::new();
    match result {
        Ok(values) => {
            buf.extend_from_slice(&0u32.to_le_bytes());
            buf.extend_from_slice(&(values.len() as u32).to_le_bytes());
            for v in values {
                buf.extend_from_slice(&v.to_le_bytes());
            }
        }
        Err(msg) => {
            buf.extend_from_slice(&1u32.to_le_bytes());
            buf.extend_from_slice(&(msg.len() as u32).to_le_bytes());
            buf.extend_from_slice(msg.as_bytes());
        }
    }
    w.write_all(&buf)
}

pub fn read_response<R: Read>(r: &mut R) -> std::io::Result<Result<Vec<f64>, String>> {
    let mut header = [0u8; 8];
    r.read_exact(&mut header)?;
    let status = u32::from_le_bytes(header[0..4].try_into().unwrap());
    let n = u32::from_le_bytes(header[4..8].try_into().unwrap()) as usize;
    if status == 0 {
        let mut data = vec![0u8; n * 8];
        r.read_exact(&mut data)?;
        Ok(Ok(data
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect()))
    } else {
        let mut data = vec![0u8; n];
        r.read_exact(&mut data)?;
        Ok(Err(String::from_utf8_lossy(&data).into_owned()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let frame = Frame {
            op: Op::SigKernel {
                lam1: 1,
                lam2: 2,
                transform: 1,
            },
            len: 4,
            dim: 2,
            values: vec![1.0, -2.5, 3.25, 0.0, 5.0, 6.0, 7.0, 8.0],
        };
        let mut buf = Vec::new();
        write_request(&mut buf, &frame).unwrap();
        let got = read_request(&mut buf.as_slice()).unwrap().unwrap();
        assert_eq!(got, frame);
    }

    #[test]
    fn response_roundtrip_ok_and_err() {
        for result in [Ok(vec![1.5, -2.0]), Err("boom".to_string())] {
            let mut buf = Vec::new();
            write_response(&mut buf, &result).unwrap();
            let got = read_response(&mut buf.as_slice()).unwrap();
            assert_eq!(got, result);
        }
    }

    #[test]
    fn eof_at_boundary_is_none() {
        let empty: &[u8] = &[];
        assert!(read_request(&mut &*empty).unwrap().is_none());
    }

    #[test]
    fn bad_magic_rejected() {
        let buf = vec![0u8; 32];
        assert!(read_request(&mut buf.as_slice()).is_err());
    }
}
