//! Request router: validates requests, picks a compute backend for each
//! flushed batch (native Rust kernels always; a PJRT artifact when one
//! matches the op + batch shape exactly), and runs it.
//!
//! All native execution goes through compiled engine
//! [`Plan`](crate::engine::Plan)s held in an LRU [`PlanCache`] keyed by
//! shape group, so repeated traffic classes skip validation/layout work and
//! reuse warm workspaces. A malformed or shape-inconsistent request can only
//! ever produce a [`Response::Error`] — no panic is reachable from the
//! request path.

use std::sync::Arc;

use crate::coordinator::wire::RaggedFrame;
use crate::coordinator::{transform_from_u8, Op, Request, Response, WIRE_LOWRANK_SEED};
use crate::corpus::{CorpusId, CorpusRegistry, CorpusStats};
use crate::engine::{CacheStats, OpSpec, PlanCache, ShapeClass};
use crate::kernel::lowrank::LowRankSpec;
use crate::kernel::{KernelOptions, Scheme};
use crate::path::{PathBatch, SigError};
use crate::runtime::RuntimeHandle;
use crate::sig::SigOptions;

/// Plans cached per router (shape groups recur heavily under load; 64
/// classes is far beyond any realistic concurrent working set).
const PLAN_CACHE_CAPACITY: usize = 64;

/// File name snapshots use inside a configured snapshot directory.
const SNAPSHOT_FILE: &str = "corpus.snapshot";

/// Compute backend selection per batch.
pub struct Router {
    /// Optional PJRT runtime over `artifacts/`; `None` = native only.
    runtime: Option<Arc<RuntimeHandle>>,
    /// Warm compiled plans keyed by (op, shape class).
    plans: PlanCache,
    /// Registered reference corpora served by the corpus wire ops.
    corpus: Arc<CorpusRegistry>,
    /// Directory corpus snapshots are written to / restored from (the
    /// `SnapshotCorpus` wire op and server drain need it configured).
    snapshot_dir: Option<std::path::PathBuf>,
}

impl Router {
    /// Native Rust kernels only (no artifacts needed).
    pub fn native_only() -> Router {
        Router {
            runtime: None,
            plans: PlanCache::new(PLAN_CACHE_CAPACITY),
            corpus: Arc::new(CorpusRegistry::new()),
            snapshot_dir: None,
        }
    }

    /// Prefer PJRT artifacts when shapes match; fall back to native.
    pub fn with_runtime(runtime: Arc<RuntimeHandle>) -> Router {
        Router {
            runtime: Some(runtime),
            plans: PlanCache::new(PLAN_CACHE_CAPACITY),
            corpus: Arc::new(CorpusRegistry::new()),
            snapshot_dir: None,
        }
    }

    /// Configure the directory corpus snapshots live in (`corpus.snapshot`
    /// inside it). Enables the `SnapshotCorpus` wire op and the server's
    /// snapshot-on-drain.
    pub fn with_snapshot_dir(mut self, dir: std::path::PathBuf) -> Router {
        self.snapshot_dir = Some(dir);
        self
    }

    /// Write all registered corpora to the configured snapshot file.
    /// Returns the number of corpora written.
    pub fn snapshot_corpora(&self) -> Result<usize, SigError> {
        let dir = self
            .snapshot_dir
            .as_ref()
            .ok_or(SigError::Invalid("no snapshot path configured"))?;
        self.corpus.snapshot_to(&dir.join(SNAPSHOT_FILE))
    }

    /// Replace the registry with one restored from the configured snapshot
    /// file, if that file exists. Returns the number of corpora restored
    /// (0 when there is no snapshot yet — a cold start is not an error).
    pub fn restore_corpora(&mut self) -> Result<usize, SigError> {
        let dir = self
            .snapshot_dir
            .as_ref()
            .ok_or(SigError::Invalid("no snapshot path configured"))?;
        let file = dir.join(SNAPSHOT_FILE);
        if !file.exists() {
            return Ok(0);
        }
        let reg = CorpusRegistry::restore_from(&file)?;
        let n = reg.ids().len();
        self.corpus = Arc::new(reg);
        Ok(n)
    }

    pub fn has_runtime(&self) -> bool {
        self.runtime.is_some()
    }

    /// Plan-cache hit/miss/eviction counters (surfaced in server metrics).
    pub fn plan_cache_stats(&self) -> CacheStats {
        self.plans.stats()
    }

    /// The corpus registry this router serves (shared with tests / metrics).
    pub fn corpus_registry(&self) -> &Arc<CorpusRegistry> {
        &self.corpus
    }

    /// Registry counters (surfaced in server metrics).
    pub fn corpus_stats(&self) -> CorpusStats {
        self.corpus.stats()
    }

    /// Decode an op's wire transform + options into an engine spec.
    /// `retain` selects a record-keeping plan (gradient ops).
    fn op_spec(op: Op) -> Result<(OpSpec, bool), SigError> {
        // Wire decode already validates the scheme byte; re-check here so
        // locally-constructed Ops (tests, embedded clients) fail typed too.
        let scheme_from_wire = |s: u8| {
            Scheme::from_u8(s)
                .ok_or_else(|| SigError::Protocol(format!("unknown Goursat scheme byte {s}")))
        };
        match op {
            Op::Signature { depth, transform } => {
                let tr = transform_from_u8(transform).ok_or(SigError::BadTransform(transform))?;
                Ok((OpSpec::Sig(SigOptions::new(depth as usize).transform(tr)), false))
            }
            Op::LogSignature { depth, transform } => {
                let tr = transform_from_u8(transform).ok_or(SigError::BadTransform(transform))?;
                Ok((
                    OpSpec::LogSig(SigOptions::new(depth as usize).transform(tr)),
                    false,
                ))
            }
            Op::SigKernel {
                lam1,
                lam2,
                transform,
                scheme,
            } => {
                let tr = transform_from_u8(transform).ok_or(SigError::BadTransform(transform))?;
                let sc = scheme_from_wire(scheme)?;
                Ok((
                    OpSpec::SigKernel(
                        KernelOptions::default()
                            .dyadic(lam1, lam2)
                            .transform(tr)
                            .scheme(sc),
                    ),
                    false,
                ))
            }
            Op::SigKernelGrad { lam1, lam2, scheme } => Ok((
                OpSpec::SigKernel(
                    KernelOptions::default()
                        .dyadic(lam1, lam2)
                        .scheme(scheme_from_wire(scheme)?),
                ),
                true,
            )),
            // The wire's rank field selects a Nyström budget; the seed is
            // fixed (WIRE_LOWRANK_SEED) so repeated requests are
            // deterministic and share a cached plan.
            Op::Mmd2LowRank {
                rank, transform, ..
            } => {
                let tr = transform_from_u8(transform).ok_or(SigError::BadTransform(transform))?;
                Ok((
                    OpSpec::Mmd2LowRank {
                        opts: KernelOptions::default().transform(tr),
                        lowrank: LowRankSpec::nystrom(rank as usize, WIRE_LOWRANK_SEED),
                    },
                    false,
                ))
            }
            Op::GramLowRank {
                rank, transform, ..
            } => {
                let tr = transform_from_u8(transform).ok_or(SigError::BadTransform(transform))?;
                Ok((
                    OpSpec::GramLowRank {
                        opts: KernelOptions::default().transform(tr),
                        lowrank: LowRankSpec::nystrom(rank as usize, WIRE_LOWRANK_SEED),
                    },
                    false,
                ))
            }
            // Corpus ops are stateful and routed through the registry, not
            // through a bare op spec (see `execute_ragged`).
            Op::RegisterCorpus
            | Op::AppendCorpus { .. }
            | Op::Mmd2Corpus { .. }
            | Op::ExtendPath { .. }
            | Op::EvictCorpus { .. }
            | Op::Mmd2Window { .. }
            | Op::SnapshotCorpus => Err(SigError::Invalid(
                "corpus ops are served by the corpus route",
            )),
        }
    }

    /// Name of the PJRT artifact that can serve this batch, if any.
    /// Artifact naming convention (see aot.py): op_b{B}_l{L}_d{D}[...].
    pub fn artifact_for(&self, op: Op, batch: usize, len: usize, dim: usize) -> Option<String> {
        let rt = self.runtime.as_ref()?;
        let name = match op {
            // Artifacts implement the order-1 scheme only — any other
            // scheme byte falls through to the native kernels.
            Op::SigKernel {
                lam1: 0,
                lam2: 0,
                transform: 0,
                scheme: 0,
            } => format!("sigkernel_b{batch}_l{len}_d{dim}"),
            Op::Signature {
                depth,
                transform: 0,
            } => format!("signature_b{batch}_l{len}_d{dim}_n{depth}"),
            _ => return None,
        };
        rt.info(&name).map(|_| name)
    }

    /// Execute one shape-homogeneous batch of requests.
    pub fn execute_batch(
        &self,
        op: Op,
        len: usize,
        dim: usize,
        reqs: &[&Request],
    ) -> Vec<Response> {
        // A degenerate shape poisons the whole group — answer every request
        // with an error rather than panicking anywhere downstream.
        if len == 0 || dim == 0 {
            let e = if dim == 0 {
                SigError::ZeroDim
            } else {
                SigError::EmptyPath
            };
            return reqs.iter().map(|_| Response::Error(e.to_string())).collect();
        }
        // Validate payload sizes up front; a malformed request must not sink
        // the whole batch.
        let expect = len * dim;
        let bad: Vec<bool> = reqs
            .iter()
            .map(|r| {
                r.data.len() != expect
                    || match op {
                        Op::SigKernel { .. } | Op::SigKernelGrad { .. } => {
                            r.data2.as_ref().map(|d| d.len()) != Some(expect)
                        }
                        _ => r.data2.is_some(),
                    }
            })
            .collect();
        let good: Vec<&Request> = reqs
            .iter()
            .zip(&bad)
            .filter(|(_, &is_bad)| !is_bad)
            .map(|(r, _)| *r)
            .collect();

        // Try the PJRT path for an exactly-matching artifact. Runtime
        // failures are propagated to every client in the batch as wire
        // errors — not silently swallowed, not silently re-routed.
        if good.len() == reqs.len() {
            if let Some(name) = self.artifact_for(op, reqs.len(), len, dim) {
                return match self.execute_pjrt(&name, op, len, dim, reqs) {
                    Ok(resps) => resps,
                    Err(e) => reqs
                        .iter()
                        .map(|_| Response::Error(e.to_string()))
                        .collect(),
                };
            }
        }

        let computed = self.execute_native(op, len, dim, &good);
        let mut out: Vec<Response> = Vec::with_capacity(reqs.len());
        let mut it = computed.into_iter();
        for &is_bad in &bad {
            if is_bad {
                out.push(Response::Error(format!(
                    "payload size mismatch: expected {} values per path",
                    expect
                )));
            } else {
                out.push(it.next().unwrap_or_else(|| {
                    Response::Error("internal: missing batch result".to_string())
                }));
            }
        }
        out
    }

    /// Execute a ragged-batch frame directly (it is already a batch): one
    /// flat result vector for the whole frame, or one error for the frame.
    pub fn execute_ragged(&self, frame: &RaggedFrame) -> Result<Vec<f64>, SigError> {
        if crate::coordinator::wire::op_is_paired(frame.op) && frame.lengths.len() % 2 != 0 {
            return Err(SigError::Protocol(format!(
                "kernel ops need (x, y) length pairs; got {} lengths",
                frame.lengths.len()
            )));
        }
        // Corpus ops first: they are registry operations, not op specs.
        if let Some(result) = self.execute_corpus_op(frame)? {
            return Ok(result);
        }
        let (spec, retain) = Self::op_spec(frame.op)?;
        let pb = PathBatch::ragged(&frame.values, &frame.lengths, frame.dim)?;
        match frame.op {
            Op::Signature { .. } | Op::LogSignature { .. } => {
                let plan = self.plans.get_or_compile(
                    spec,
                    ShapeClass::for_batch(&pb).bucketed(),
                    retain,
                    None,
                )?;
                Ok(plan.execute(&pb)?.into_values())
            }
            Op::SigKernel { .. } | Op::SigKernelGrad { .. } => {
                // Pairs (x_i, y_i) interleave as paths (2i, 2i+1);
                // de-interleave into the paired plan's two batches (one
                // pre-sized copy of the already-validated payload).
                let b = frame.batch();
                let dim = frame.dim;
                let (mut xl, mut yl) = (Vec::with_capacity(b), Vec::with_capacity(b));
                let half = pb.total_points() * dim / 2 + dim;
                let (mut xdata, mut ydata) =
                    (Vec::with_capacity(half), Vec::with_capacity(half));
                for i in 0..b {
                    xl.push(pb.len_of(2 * i));
                    xdata.extend_from_slice(pb.values_of(2 * i));
                    yl.push(pb.len_of(2 * i + 1));
                    ydata.extend_from_slice(pb.values_of(2 * i + 1));
                }
                let xb = PathBatch::ragged(&xdata, &xl, dim)?;
                let yb = PathBatch::ragged(&ydata, &yl, dim)?;
                let shape = ShapeClass::for_pair(&xb, &yb).bucketed();
                let plan = self.plans.get_or_compile(spec, shape, retain, None)?;
                let rec = plan.execute_pair(&xb, &yb)?;
                if matches!(frame.op, Op::SigKernel { .. }) {
                    return Ok(rec.into_values());
                }
                // Gradient frames: re-interleave (grad_x_i ++ grad_y_i) per
                // pair — exactly each pair's slice of the input layout.
                let (gx, gy) = rec.vjp(&vec![1.0; b])?.into_pair()?;
                let xo = xb.element_offsets();
                let yo = yb.element_offsets();
                let oob = || SigError::Invalid("internal: gradient slice out of bounds");
                let mut out = Vec::with_capacity(pb.total_points() * dim);
                for (xw, yw) in xo.windows(2).zip(yo.windows(2)) {
                    let (xs, ys) = match (xw, yw) {
                        ([x0, x1], [y0, y1]) => (
                            gx.get(*x0..*x1).ok_or_else(oob)?,
                            gy.get(*y0..*y1).ok_or_else(oob)?,
                        ),
                        _ => return Err(oob()),
                    };
                    out.extend_from_slice(xs);
                    out.extend_from_slice(ys);
                }
                Ok(out)
            }
            // Handled by `execute_corpus_op` before the spec route; `op_spec`
            // above already returned this error, so this arm is never reached
            // — kept as a typed error rather than `unreachable!` so the
            // request path stays panic-free even if the dispatch order drifts.
            Op::RegisterCorpus
            | Op::AppendCorpus { .. }
            | Op::Mmd2Corpus { .. }
            | Op::ExtendPath { .. }
            | Op::EvictCorpus { .. }
            | Op::Mmd2Window { .. }
            | Op::SnapshotCorpus => Err(SigError::Invalid(
                "corpus ops are served by the corpus route",
            )),
            Op::Mmd2LowRank { nx, .. } | Op::GramLowRank { nx, .. } => {
                // Split the frame's paths at nx into the two corpora
                // (validated at decode; re-checked here because frames can
                // also be constructed programmatically).
                let nx = nx as usize;
                let b = pb.batch();
                if nx == 0 || nx >= b {
                    return Err(SigError::Protocol(format!(
                        "low-rank op splits {b} paths at nx={nx}; both sides must be non-empty"
                    )));
                }
                let dim = frame.dim;
                let split = pb
                    .offsets()
                    .get(nx)
                    .copied()
                    .ok_or(SigError::Invalid("internal: offsets shorter than batch"))?
                    * dim;
                let xl: Vec<usize> = (0..nx).map(|i| pb.len_of(i)).collect();
                let yl: Vec<usize> = (nx..b).map(|i| pb.len_of(i)).collect();
                let (xv, yv) = match (frame.values.get(..split), frame.values.get(split..)) {
                    (Some(x), Some(y)) => (x, y),
                    _ => {
                        return Err(SigError::Invalid(
                            "internal: corpus split exceeds frame values",
                        ))
                    }
                };
                let xb = PathBatch::ragged(xv, &xl, dim)?;
                let yb = PathBatch::ragged(yv, &yl, dim)?;
                let shape = ShapeClass::for_pair(&xb, &yb).bucketed();
                let plan = self.plans.get_or_compile(spec, shape, retain, None)?;
                Ok(plan.execute_pair(&xb, &yb)?.into_values())
            }
        }
    }

    /// The corpus lifecycle route: `Ok(Some(values))` when the frame was a
    /// corpus op, `Ok(None)` to fall through to the op-spec route.
    fn execute_corpus_op(&self, frame: &RaggedFrame) -> Result<Option<Vec<f64>>, SigError> {
        match frame.op {
            Op::RegisterCorpus => {
                let pb = PathBatch::ragged(&frame.values, &frame.lengths, frame.dim)?;
                let id = self.corpus.register(&pb)?;
                Ok(Some(vec![id.0 as f64]))
            }
            Op::AppendCorpus { id } => {
                let pb = PathBatch::ragged(&frame.values, &frame.lengths, frame.dim)?;
                let total = self.corpus.append(CorpusId(id), &pb)?;
                Ok(Some(vec![total as f64]))
            }
            Op::Mmd2Corpus {
                id,
                rank,
                transform,
            } => {
                let tr = transform_from_u8(transform).ok_or(SigError::BadTransform(transform))?;
                let pb = PathBatch::ragged(&frame.values, &frame.lengths, frame.dim)?;
                // rank = 0 selects the exact path; a positive rank selects
                // Nyström with the wire's fixed seed, so repeated queries
                // share the registry's cached feature state.
                let lowrank =
                    (rank > 0).then(|| LowRankSpec::nystrom(rank as usize, WIRE_LOWRANK_SEED));
                let spec = OpSpec::Mmd2Corpus {
                    opts: KernelOptions::default().transform(tr),
                    corpus: CorpusId(id),
                    lowrank,
                };
                let shape = ShapeClass::for_batch(&pb).bucketed();
                let plan = self.plans.get_or_compile_corpus(spec, shape, &self.corpus)?;
                Ok(Some(plan.execute(&pb)?.into_values()))
            }
            // Streaming lifecycle: ExtendPath's frame is exactly one path of
            // new points (validated at decode; the registry re-checks the
            // shape), EvictCorpus carries no paths at all.
            Op::ExtendPath { id, path_idx } => {
                // The registry only checks divisibility by the corpus dim;
                // match the frame's declared dim against it so a dim-1 frame
                // cannot silently extend a dim-2 corpus with half as many
                // points (unknown ids fall through to the registry's error).
                match self.corpus.dim_of(CorpusId(id)) {
                    Some(d) if d != frame.dim => {
                        return Err(SigError::DimMismatch {
                            left: frame.dim,
                            right: d,
                        })
                    }
                    _ => {}
                }
                let new_len =
                    self.corpus
                        .extend_path(CorpusId(id), path_idx as usize, &frame.values)?;
                Ok(Some(vec![new_len as f64]))
            }
            Op::EvictCorpus { id, keep, max_age } => {
                // max_age > 0 selects the age criterion with `keep` as a
                // floor on survivors; max_age == 0 is the pure count bound
                // (decode guarantees keep > 0 then).
                let kept = if max_age > 0 {
                    self.corpus
                        .evict_by_age(CorpusId(id), max_age as u64, keep as usize)?
                } else {
                    self.corpus.evict(CorpusId(id), keep as usize)?
                };
                Ok(Some(vec![kept as f64]))
            }
            Op::Mmd2Window {
                id,
                decay_bp,
                transform,
            } => {
                let tr = transform_from_u8(transform).ok_or(SigError::BadTransform(transform))?;
                let pb = PathBatch::ragged(&frame.values, &frame.lengths, frame.dim)?;
                let spec = OpSpec::Mmd2Window {
                    opts: KernelOptions::default().transform(tr),
                    corpus: CorpusId(id),
                    decay: decay_bp as f64 / 10_000.0,
                };
                let shape = ShapeClass::for_batch(&pb).bucketed();
                let plan = self.plans.get_or_compile_corpus(spec, shape, &self.corpus)?;
                Ok(Some(plan.execute(&pb)?.into_values()))
            }
            Op::SnapshotCorpus => {
                let n = self.snapshot_corpora()?;
                Ok(Some(vec![n as f64]))
            }
            _ => Ok(None),
        }
    }

    /// Run one shape-homogeneous batch on the native backend. `good`
    /// holds only the size-validated requests, in arrival order.
    fn execute_native(&self, op: Op, len: usize, dim: usize, good: &[&Request]) -> Vec<Response> {
        let b = good.len();
        if b == 0 {
            return Vec::new();
        }
        let errs = |msg: String| -> Vec<Response> {
            good.iter().map(|_| Response::Error(msg.clone())).collect()
        };
        let mut paths = Vec::with_capacity(b * len * dim);
        for r in good {
            paths.extend_from_slice(&r.data);
        }
        let pb = match PathBatch::uniform(&paths, b, len, dim) {
            Ok(pb) => pb,
            Err(e) => return errs(e.to_string()),
        };
        // Gather the second paths for paired ops (validated present above).
        let gather_ys = || -> Result<Vec<f64>, String> {
            let mut ys = Vec::with_capacity(b * len * dim);
            for r in good {
                match r.data2.as_ref() {
                    Some(d) => ys.extend_from_slice(d),
                    None => return Err("kernel op missing second path".to_string()),
                }
            }
            Ok(ys)
        };
        // Warm (or compile) the shape group's plan — repeated traffic
        // classes skip validation and layout work entirely.
        let (spec, retain) = match Self::op_spec(op) {
            Ok(s) => s,
            Err(e) => return errs(e.to_string()),
        };
        let plan = match self
            .plans
            .get_or_compile(spec, ShapeClass::uniform(dim, len), retain, None)
        {
            Ok(p) => p,
            Err(e) => return errs(e.to_string()),
        };
        match op {
            Op::Signature { .. } | Op::LogSignature { .. } => {
                // Row length was precomputed at plan compilation; borrowing
                // `values()` (rather than detaching them) lets the record
                // return its output buffer to the warm plan's arena.
                let slen = plan.row_len();
                match plan.execute(&pb) {
                    Ok(rec) => rec
                        .values()
                        .chunks(slen)
                        .map(|c| Response::Values(c.to_vec()))
                        .collect(),
                    Err(e) => errs(e.to_string()),
                }
            }
            Op::SigKernel { .. } => {
                let ys = match gather_ys() {
                    Ok(ys) => ys,
                    Err(e) => return errs(e),
                };
                let yb = match PathBatch::uniform(&ys, b, len, dim) {
                    Ok(yb) => yb,
                    Err(e) => return errs(e.to_string()),
                };
                match plan.execute_pair(&pb, &yb) {
                    Ok(rec) => rec
                        .values()
                        .iter()
                        .map(|&k| Response::Values(vec![k]))
                        .collect(),
                    Err(e) => errs(e.to_string()),
                }
            }
            Op::SigKernelGrad { .. } => {
                let ys = match gather_ys() {
                    Ok(ys) => ys,
                    Err(e) => return errs(e),
                };
                let yb = match PathBatch::uniform(&ys, b, len, dim) {
                    Ok(yb) => yb,
                    Err(e) => return errs(e.to_string()),
                };
                let gk = vec![1.0; b];
                let vjp = plan
                    .execute_pair(&pb, &yb)
                    .and_then(|rec| rec.vjp(&gk))
                    .and_then(|g| g.into_pair());
                match vjp {
                    Ok((gx, gy)) => gx
                        .chunks(len * dim)
                        .zip(gy.chunks(len * dim))
                        .map(|(cx, cy)| {
                            let mut v = cx.to_vec();
                            v.extend_from_slice(cy);
                            Response::Values(v)
                        })
                        .collect(),
                    Err(e) => errs(e.to_string()),
                }
            }
            Op::Mmd2LowRank { .. } | Op::GramLowRank { .. } => {
                // Corpus-level ops have no single-path form; the wire
                // rejects these frames at decode, so this only guards
                // programmatic construction.
                errs("low-rank ops require a ragged-batch frame".to_string())
            }
            Op::RegisterCorpus
            | Op::AppendCorpus { .. }
            | Op::Mmd2Corpus { .. }
            | Op::ExtendPath { .. }
            | Op::EvictCorpus { .. }
            | Op::Mmd2Window { .. }
            | Op::SnapshotCorpus => {
                // Same guard for the corpus lifecycle ops.
                errs("corpus ops require a ragged-batch frame".to_string())
            }
        }
    }

    /// Execute via a PJRT artifact. Any runtime failure is returned as an
    /// error (and surfaces to every client in the batch as a wire `Err`
    /// response) — the artifacts are an accelerator, not an excuse to
    /// swallow failures.
    fn execute_pjrt(
        &self,
        name: &str,
        op: Op,
        len: usize,
        dim: usize,
        reqs: &[&Request],
    ) -> Result<Vec<Response>, SigError> {
        let rt = self
            .runtime
            .as_ref()
            .ok_or_else(|| SigError::Backend("no PJRT runtime attached".to_string()))?;
        let b = reqs.len();
        if b == 0 {
            return Ok(Vec::new());
        }
        let mut xs = Vec::with_capacity(b * len * dim);
        for r in reqs {
            xs.extend(r.data.iter().map(|&v| v as f32));
        }
        let inputs: Vec<Vec<f32>> = match op {
            Op::SigKernel { .. } => {
                let mut ys = Vec::with_capacity(b * len * dim);
                for r in reqs {
                    let d2 = r.data2.as_ref().ok_or_else(|| {
                        SigError::Backend("kernel op missing second path".to_string())
                    })?;
                    ys.extend(d2.iter().map(|&v| v as f32));
                }
                vec![xs, ys]
            }
            _ => vec![xs],
        };
        let outputs = rt
            .execute_f32(name, inputs)
            .map_err(|e| SigError::Backend(format!("pjrt artifact '{name}': {e}")))?;
        let flat = outputs.first().ok_or_else(|| {
            SigError::Backend(format!("pjrt artifact '{name}' returned no outputs"))
        })?;
        if flat.is_empty() || flat.len() % b != 0 {
            return Err(SigError::Backend(format!(
                "pjrt artifact '{name}' returned {} values for a batch of {b}",
                flat.len()
            )));
        }
        let per = flat.len() / b;
        Ok(flat
            .chunks(per)
            .map(|c| Response::Values(c.iter().map(|&v| v as f64).collect()))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::sync::mpsc;

    fn req(op: Op, len: usize, dim: usize, rng: &mut Rng, pair: bool) -> Request {
        let (tx, _rx) = mpsc::channel();
        // keep receiver alive is unnecessary: router never sends; batcher does
        std::mem::forget(_rx);
        Request {
            op,
            len,
            dim,
            data: rng.brownian_path(len, dim, 0.5),
            data2: pair.then(|| rng.brownian_path(len, dim, 0.5)),
            reply: tx,
        }
    }

    #[test]
    fn signature_batch_matches_direct() {
        let router = Router::native_only();
        let op = Op::Signature {
            depth: 3,
            transform: 0,
        };
        let mut rng = Rng::new(7);
        let reqs: Vec<Request> = (0..5).map(|_| req(op, 8, 2, &mut rng, false)).collect();
        let refs: Vec<&Request> = reqs.iter().collect();
        let out = router.execute_batch(op, 8, 2, &refs);
        for (r, o) in reqs.iter().zip(&out) {
            match o {
                Response::Values(v) => {
                    let want = crate::sig::sig(&r.data, 8, 2, 3);
                    assert!(crate::util::linalg::max_abs_diff(v, &want) < 1e-12);
                }
                other => panic!("{other:?}"),
            }
        }
    }

    #[test]
    fn kernel_grad_returns_both_gradients() {
        let router = Router::native_only();
        let op = Op::SigKernelGrad {
            lam1: 0,
            lam2: 0,
            scheme: 0,
        };
        let mut rng = Rng::new(8);
        let reqs: Vec<Request> = (0..3).map(|_| req(op, 6, 2, &mut rng, true)).collect();
        let refs: Vec<&Request> = reqs.iter().collect();
        let out = router.execute_batch(op, 6, 2, &refs);
        for o in &out {
            match o {
                Response::Values(v) => assert_eq!(v.len(), 2 * 6 * 2),
                other => panic!("{other:?}"),
            }
        }
    }

    #[test]
    fn malformed_request_errors_without_sinking_batch() {
        let router = Router::native_only();
        let op = Op::Signature {
            depth: 2,
            transform: 0,
        };
        let mut rng = Rng::new(9);
        let good = req(op, 8, 2, &mut rng, false);
        let mut bad = req(op, 8, 2, &mut rng, false);
        bad.data.truncate(3); // wrong payload
        let refs: Vec<&Request> = vec![&good, &bad];
        let out = router.execute_batch(op, 8, 2, &refs);
        assert!(matches!(out[0], Response::Values(_)));
        assert!(matches!(out[1], Response::Error(_)));
    }

    /// Degenerate group shapes (zero dim / zero len) must answer every
    /// request with an error — never panic (the coordinator's no-panic
    /// contract).
    #[test]
    fn degenerate_shapes_error_instead_of_panicking() {
        let router = Router::native_only();
        let op = Op::Signature {
            depth: 2,
            transform: 0,
        };
        let mut rng = Rng::new(10);
        let r = req(op, 4, 2, &mut rng, false);
        let refs: Vec<&Request> = vec![&r];
        for (len, dim) in [(0usize, 2usize), (4, 0), (0, 0)] {
            let out = router.execute_batch(op, len, dim, &refs);
            assert_eq!(out.len(), 1);
            assert!(matches!(out[0], Response::Error(_)), "len={len} dim={dim}");
        }
        // A kernel request without its second path errors cleanly too.
        let kop = Op::SigKernel {
            lam1: 0,
            lam2: 0,
            transform: 0,
            scheme: 0,
        };
        let k = req(kop, 4, 2, &mut rng, false); // pair missing
        let refs: Vec<&Request> = vec![&k];
        let out = router.execute_batch(kop, 4, 2, &refs);
        assert!(matches!(out[0], Response::Error(_)));
    }

    /// A well-formed frame with an absurd depth must answer with an error,
    /// not overflow inside the tensor layout and kill the flush thread.
    #[test]
    fn huge_depth_errors_instead_of_panicking() {
        let router = Router::native_only();
        for depth in [64u32, 1000, u32::MAX] {
            let op = Op::Signature {
                depth,
                transform: 0,
            };
            let mut rng = Rng::new(20);
            let r = req(op, 4, 2, &mut rng, false);
            let refs: Vec<&Request> = vec![&r];
            let out = router.execute_batch(op, 4, 2, &refs);
            assert!(matches!(out[0], Response::Error(_)), "depth={depth}");
        }
        // Same through the ragged route, plus an absurd dyadic order.
        let frame = RaggedFrame {
            op: Op::Signature {
                depth: 64,
                transform: 0,
            },
            dim: 2,
            lengths: vec![2],
            values: vec![0.0; 4],
        };
        assert!(router.execute_ragged(&frame).is_err());
        let frame = RaggedFrame {
            op: Op::SigKernel {
                lam1: 60,
                lam2: 60,
                transform: 0,
                scheme: 0,
            },
            dim: 1,
            lengths: vec![4, 4],
            values: vec![0.0; 8],
        };
        assert!(router.execute_ragged(&frame).is_err());
    }

    #[test]
    fn logsignature_served() {
        let router = Router::native_only();
        let op = Op::LogSignature {
            depth: 3,
            transform: 0,
        };
        let mut rng = Rng::new(10);
        let r = req(op, 7, 2, &mut rng, false);
        let refs: Vec<&Request> = vec![&r];
        let out = router.execute_batch(op, 7, 2, &refs);
        match &out[0] {
            Response::Values(v) => {
                let want =
                    crate::sig::log_signature(&r.data, 7, 2, 3, crate::transforms::Transform::None);
                assert!(crate::util::linalg::max_abs_diff(v, &want) < 1e-12);
            }
            other => panic!("{other:?}"),
        }
    }

    /// Ragged frames execute against the typed API and match per-path
    /// computation exactly.
    #[test]
    fn ragged_frame_signature_matches_per_path() {
        let router = Router::native_only();
        let mut rng = Rng::new(11);
        let d = 2;
        let lengths = [5usize, 1, 8];
        let mut values = Vec::new();
        for &l in &lengths {
            values.extend(rng.brownian_path(l, d, 0.5));
        }
        let frame = RaggedFrame {
            op: Op::Signature {
                depth: 3,
                transform: 0,
            },
            dim: d,
            lengths: lengths.to_vec(),
            values: values.clone(),
        };
        let out = router.execute_ragged(&frame).unwrap();
        let slen = crate::sig::sig_length(d, 3);
        assert_eq!(out.len(), lengths.len() * slen);
        let mut off = 0;
        for (i, &l) in lengths.iter().enumerate() {
            let want = crate::sig::sig(&values[off * d..(off + l) * d], l, d, 3);
            assert_eq!(&out[i * slen..(i + 1) * slen], &want[..]);
            off += l;
        }
    }

    #[test]
    fn ragged_frame_kernel_pairs_match_sig_kernel() {
        let router = Router::native_only();
        let mut rng = Rng::new(12);
        let d = 2;
        let lengths = [4usize, 6, 3, 5]; // two (x, y) pairs
        let mut values = Vec::new();
        for &l in &lengths {
            values.extend(rng.brownian_path(l, d, 0.4));
        }
        let frame = RaggedFrame {
            op: Op::SigKernel {
                lam1: 1,
                lam2: 0,
                transform: 0,
                scheme: 0,
            },
            dim: d,
            lengths: lengths.to_vec(),
            values: values.clone(),
        };
        let out = router.execute_ragged(&frame).unwrap();
        assert_eq!(out.len(), 2);
        let opts = KernelOptions::default().dyadic(1, 0);
        let o: Vec<usize> = {
            let mut acc = vec![0];
            for &l in &lengths {
                acc.push(acc.last().unwrap() + l);
            }
            acc
        };
        for p in 0..2 {
            let (lx, ly) = (lengths[2 * p], lengths[2 * p + 1]);
            let want = crate::kernel::sig_kernel(
                &values[o[2 * p] * d..o[2 * p + 1] * d],
                &values[o[2 * p + 1] * d..o[2 * p + 2] * d],
                lx,
                ly,
                d,
                &opts,
            );
            assert_eq!(out[p], want, "pair {p}");
        }
    }

    /// Low-rank frames split at nx and bit-match direct engine execution
    /// with the wire's fixed seed.
    #[test]
    fn ragged_frame_lowrank_ops_match_engine_execution() {
        let router = Router::native_only();
        let mut rng = Rng::new(13);
        let d = 2;
        let xl = [4usize, 6, 5];
        let yl = [3usize, 5, 4, 6];
        let mut values = Vec::new();
        for &l in xl.iter().chain(yl.iter()) {
            values.extend(rng.brownian_path(l, d, 0.4));
        }
        let lengths: Vec<usize> = xl.iter().chain(yl.iter()).copied().collect();
        let rank = 3u32;
        let frame = RaggedFrame {
            op: Op::Mmd2LowRank {
                rank,
                nx: xl.len() as u32,
                transform: 0,
            },
            dim: d,
            lengths: lengths.clone(),
            values: values.clone(),
        };
        let out = router.execute_ragged(&frame).unwrap();
        assert_eq!(out.len(), 1);
        // Reference: the same engine plan executed directly.
        let split = xl.iter().sum::<usize>() * d;
        let xb = PathBatch::ragged(&values[..split], &xl, d).unwrap();
        let yb = PathBatch::ragged(&values[split..], &yl, d).unwrap();
        let spec = OpSpec::Mmd2LowRank {
            opts: KernelOptions::default(),
            lowrank: LowRankSpec::nystrom(rank as usize, WIRE_LOWRANK_SEED),
        };
        let plan = crate::engine::Plan::compile_forward(
            spec,
            ShapeClass::for_pair(&xb, &yb).bucketed(),
        )
        .unwrap();
        let want = plan.execute_pair(&xb, &yb).unwrap().value();
        assert_eq!(out[0], want);
        // Gram variant: [nx, b - nx] values.
        let gframe = RaggedFrame {
            op: Op::GramLowRank {
                rank,
                nx: xl.len() as u32,
                transform: 0,
            },
            dim: d,
            lengths,
            values: values.clone(),
        };
        let gout = router.execute_ragged(&gframe).unwrap();
        assert_eq!(gout.len(), xl.len() * yl.len());
        assert!(gout.iter().all(|v| v.is_finite()));
        // A bad split from a programmatic frame is an error, not a panic.
        let bad = RaggedFrame {
            op: Op::Mmd2LowRank {
                rank,
                nx: 7,
                transform: 0,
            },
            dim: d,
            lengths: xl.to_vec(),
            values: values[..split].to_vec(),
        };
        assert!(matches!(
            router.execute_ragged(&bad),
            Err(SigError::Protocol(_))
        ));
    }

    /// The corpus lifecycle over the router: register → query (cold, warm)
    /// → append → query, with results matching the registry driven
    /// directly and the plan cache warming across queries.
    #[test]
    fn corpus_ops_roundtrip_through_the_router() {
        let router = Router::native_only();
        let mut rng = Rng::new(14);
        let d = 2;
        let corpus_lens = [5usize, 3, 6, 4];
        let mut corpus_values = Vec::new();
        for &l in &corpus_lens {
            corpus_values.extend(rng.brownian_path(l, d, 0.4));
        }
        let id = router
            .execute_ragged(&RaggedFrame {
                op: Op::RegisterCorpus,
                dim: d,
                lengths: corpus_lens.to_vec(),
                values: corpus_values.clone(),
            })
            .unwrap();
        assert_eq!(id.len(), 1);
        let id_u = id[0] as u32;
        // Registering identical content again returns the same id.
        let again = router
            .execute_ragged(&RaggedFrame {
                op: Op::RegisterCorpus,
                dim: d,
                lengths: corpus_lens.to_vec(),
                values: corpus_values.clone(),
            })
            .unwrap();
        assert_eq!(again[0], id[0]);
        // Query: matches the registry driven directly.
        let q_lens = [4usize, 5];
        let mut q_values = Vec::new();
        for &l in &q_lens {
            q_values.extend(rng.brownian_path(l, d, 0.4));
        }
        let qframe = RaggedFrame {
            op: Op::Mmd2Corpus {
                id: id_u,
                rank: 0,
                transform: 0,
            },
            dim: d,
            lengths: q_lens.to_vec(),
            values: q_values.clone(),
        };
        let cold = router.execute_ragged(&qframe).unwrap();
        let warm = router.execute_ragged(&qframe).unwrap();
        assert_eq!(cold, warm, "warm corpus re-query must be bit-identical");
        let qb = PathBatch::ragged(&q_values, &q_lens, d).unwrap();
        let want = router
            .corpus_registry()
            .mmd2_query(
                crate::corpus::CorpusId(id_u),
                &qb,
                &KernelOptions::default(),
                None,
            )
            .unwrap();
        assert_eq!(cold[0], want);
        // Append, then query again (and the low-rank route works too).
        let extra = rng.brownian_path(4, d, 0.4);
        let total = router
            .execute_ragged(&RaggedFrame {
                op: Op::AppendCorpus { id: id_u },
                dim: d,
                lengths: vec![4],
                values: extra,
            })
            .unwrap();
        assert_eq!(total[0], 5.0);
        let post = router.execute_ragged(&qframe).unwrap();
        assert_ne!(post[0], cold[0], "appended corpus changes the estimate");
        let lr = router
            .execute_ragged(&RaggedFrame {
                op: Op::Mmd2Corpus {
                    id: id_u,
                    rank: 3,
                    transform: 0,
                },
                dim: d,
                lengths: q_lens.to_vec(),
                values: q_values.clone(),
            })
            .unwrap();
        assert!(lr[0].is_finite());
        // Unknown id is an error, not a panic.
        let bad = RaggedFrame {
            op: Op::Mmd2Corpus {
                id: 999,
                rank: 0,
                transform: 0,
            },
            dim: d,
            lengths: q_lens.to_vec(),
            values: q_values,
        };
        assert!(router.execute_ragged(&bad).is_err());
        let st = router.corpus_stats();
        assert_eq!(st.registered, 1);
        assert_eq!(st.appended, 1);
        assert!(st.warm_hits >= 1 && st.cold_builds >= 1);
    }

    /// The streaming lifecycle over the router: extend a registered path
    /// (bit-matching the registry driven directly), evict down to a window,
    /// and score a weighted window MMD² — all through wire frames.
    #[test]
    fn stream_ops_roundtrip_through_the_router() {
        let router = Router::native_only();
        let mut rng = Rng::new(15);
        let d = 2;
        let corpus_lens = [5usize, 4, 6];
        let mut corpus_values = Vec::new();
        for &l in &corpus_lens {
            corpus_values.extend(rng.brownian_path(l, d, 0.4));
        }
        let id = router
            .execute_ragged(&RaggedFrame {
                op: Op::RegisterCorpus,
                dim: d,
                lengths: corpus_lens.to_vec(),
                values: corpus_values.clone(),
            })
            .unwrap()[0] as u32;
        // Extend path 1 by three points; the response is its new length.
        let extra = rng.brownian_path(3, d, 0.4);
        let out = router
            .execute_ragged(&RaggedFrame {
                op: Op::ExtendPath { id, path_idx: 1 },
                dim: d,
                lengths: vec![3],
                values: extra.clone(),
            })
            .unwrap();
        assert_eq!(out, vec![7.0]);
        // A dim-mismatched extension errors instead of corrupting the path.
        assert!(matches!(
            router.execute_ragged(&RaggedFrame {
                op: Op::ExtendPath { id, path_idx: 0 },
                dim: 1,
                lengths: vec![2],
                values: vec![0.0, 1.0],
            }),
            Err(SigError::DimMismatch { .. })
        ));
        // Weighted window MMD² matches the registry driven directly.
        let q_lens = [4usize, 5];
        let mut q_values = Vec::new();
        for &l in &q_lens {
            q_values.extend(rng.brownian_path(l, d, 0.4));
        }
        let wout = router
            .execute_ragged(&RaggedFrame {
                op: Op::Mmd2Window {
                    id,
                    decay_bp: 9000,
                    transform: 0,
                },
                dim: d,
                lengths: q_lens.to_vec(),
                values: q_values.clone(),
            })
            .unwrap();
        let qb = PathBatch::ragged(&q_values, &q_lens, d).unwrap();
        let want = router
            .corpus_registry()
            .mmd2_window(
                crate::corpus::CorpusId(id),
                &qb,
                &KernelOptions::default(),
                0.9,
            )
            .unwrap();
        assert_eq!(wout, vec![want]);
        // Evict down to the newest two paths; the response is the count.
        let kept = router
            .execute_ragged(&RaggedFrame {
                op: Op::EvictCorpus {
                    id,
                    keep: 2,
                    max_age: 0,
                },
                dim: d,
                lengths: vec![],
                values: vec![],
            })
            .unwrap();
        assert_eq!(kept, vec![2.0]);
        assert_eq!(router.corpus_registry().path_count(CorpusId(id)), Some(2));
        let st = router.corpus_stats();
        assert_eq!(st.extended, 1);
        assert_eq!(st.evicted, 1);
        // Age-based eviction through the wire op: append a fresh batch
        // (advancing the corpus clock), then drop everything older than
        // that append. Only the appended path survives.
        let fresh = rng.brownian_path(4, d, 0.4);
        router
            .execute_ragged(&RaggedFrame {
                op: Op::AppendCorpus { id },
                dim: d,
                lengths: vec![4],
                values: fresh,
            })
            .unwrap();
        // A 0/0 op reaching the router directly (decode would reject it)
        // falls through to count-eviction and errors there instead of
        // emptying the corpus.
        assert!(router
            .execute_ragged(&RaggedFrame {
                op: Op::EvictCorpus {
                    id,
                    keep: 0,
                    max_age: 0,
                },
                dim: d,
                lengths: vec![],
                values: vec![],
            })
            .is_err());
        let kept = router
            .execute_ragged(&RaggedFrame {
                op: Op::EvictCorpus {
                    id,
                    keep: 0,
                    max_age: 1,
                },
                dim: d,
                lengths: vec![],
                values: vec![],
            })
            .unwrap();
        // Paths kept by the earlier count-evict were born at tick 0; the
        // append bumped the clock to 1, so max_age=1 keeps them all.
        assert_eq!(kept, vec![3.0]);
        // Age 0 keeps only the trailing tick-1 run: the appended path.
        let kept = router
            .corpus_registry()
            .evict_by_age(CorpusId(id), 0, 0)
            .unwrap();
        assert_eq!(kept, 1);
        assert_eq!(router.corpus_registry().path_count(CorpusId(id)), Some(1));
    }

    /// The snapshot wire op writes through the configured directory, and a
    /// restored router answers corpus queries bit-identically.
    #[test]
    fn snapshot_op_roundtrips_through_the_router() {
        let dir = std::env::temp_dir().join(format!("pysiglib-router-snap-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        // Without a configured directory, the op is a typed error.
        let bare = Router::native_only();
        let snap_frame = RaggedFrame {
            op: Op::SnapshotCorpus,
            dim: 1,
            lengths: vec![],
            values: vec![],
        };
        assert!(matches!(
            bare.execute_ragged(&snap_frame),
            Err(SigError::Invalid(_))
        ));
        let router = Router::native_only().with_snapshot_dir(dir.clone());
        let mut rng = Rng::new(16);
        let d = 2;
        let lens = [5usize, 4, 6];
        let mut values = Vec::new();
        for &l in &lens {
            values.extend(rng.brownian_path(l, d, 0.4));
        }
        let id = router
            .execute_ragged(&RaggedFrame {
                op: Op::RegisterCorpus,
                dim: d,
                lengths: lens.to_vec(),
                values: values.clone(),
            })
            .unwrap()[0] as u32;
        // Warm the exact cache, then snapshot.
        let q_lens = [4usize];
        let q_values = rng.brownian_path(4, d, 0.4);
        let qframe = RaggedFrame {
            op: Op::Mmd2Corpus {
                id,
                rank: 0,
                transform: 0,
            },
            dim: d,
            lengths: q_lens.to_vec(),
            values: q_values.clone(),
        };
        let before = router.execute_ragged(&qframe).unwrap();
        let wrote = router.execute_ragged(&snap_frame).unwrap();
        assert_eq!(wrote, vec![1.0]);
        // A restored router serves the same answer, warm.
        let mut restored = Router::native_only().with_snapshot_dir(dir.clone());
        assert_eq!(restored.restore_corpora().unwrap(), 1);
        let after = restored.execute_ragged(&qframe).unwrap();
        assert_eq!(before, after, "restored corpus must answer bit-identically");
        let st = restored.corpus_stats();
        assert!(st.warm_hits >= 1, "restored cache serves warm");
        assert_eq!(st.cold_builds, 0, "restore must not pay a cold rebuild");
        // Restoring with no snapshot file present is a clean cold start.
        let empty =
            std::env::temp_dir().join(format!("pysiglib-router-none-{}", std::process::id()));
        std::fs::create_dir_all(&empty).unwrap();
        let mut cold = Router::native_only().with_snapshot_dir(empty.clone());
        assert_eq!(cold.restore_corpora().unwrap(), 0);
        std::fs::remove_dir_all(&dir).ok();
        std::fs::remove_dir_all(&empty).ok();
    }

    #[test]
    fn ragged_frame_with_bad_shape_is_an_error() {
        let router = Router::native_only();
        let frame = RaggedFrame {
            op: Op::Signature {
                depth: 3,
                transform: 0,
            },
            dim: 2,
            lengths: vec![3],
            values: vec![0.0; 5], // needs 6
        };
        assert!(router.execute_ragged(&frame).is_err());
        let frame = RaggedFrame {
            op: Op::Signature {
                depth: 3,
                transform: 7, // unknown
            },
            dim: 2,
            lengths: vec![2],
            values: vec![0.0; 4],
        };
        assert_eq!(
            router.execute_ragged(&frame),
            Err(SigError::BadTransform(7))
        );
    }
}
