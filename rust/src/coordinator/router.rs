//! Request router: validates requests, picks a compute backend for each
//! flushed batch (native Rust kernels always; a PJRT artifact when one
//! matches the op + batch shape exactly), and runs it.

use std::sync::Arc;

use crate::coordinator::{transform_from_u8, Op, Request, Response};
use crate::kernel::KernelOptions;
use crate::runtime::RuntimeHandle;
use crate::sig::SigOptions;

/// Compute backend selection per batch.
pub struct Router {
    /// Optional PJRT runtime over `artifacts/`; `None` = native only.
    runtime: Option<Arc<RuntimeHandle>>,
}

impl Router {
    /// Native Rust kernels only (no artifacts needed).
    pub fn native_only() -> Router {
        Router { runtime: None }
    }

    /// Prefer PJRT artifacts when shapes match; fall back to native.
    pub fn with_runtime(runtime: Arc<RuntimeHandle>) -> Router {
        Router {
            runtime: Some(runtime),
        }
    }

    pub fn has_runtime(&self) -> bool {
        self.runtime.is_some()
    }

    /// Name of the PJRT artifact that can serve this batch, if any.
    /// Artifact naming convention (see aot.py): op_b{B}_l{L}_d{D}[...].
    pub fn artifact_for(&self, op: Op, batch: usize, len: usize, dim: usize) -> Option<String> {
        let rt = self.runtime.as_ref()?;
        let name = match op {
            Op::SigKernel {
                lam1: 0,
                lam2: 0,
                transform: 0,
            } => format!("sigkernel_b{batch}_l{len}_d{dim}"),
            Op::Signature {
                depth,
                transform: 0,
            } => format!("signature_b{batch}_l{len}_d{dim}_n{depth}"),
            _ => return None,
        };
        rt.info(&name).map(|_| name)
    }

    /// Execute one shape-homogeneous batch of requests.
    pub fn execute_batch(
        &self,
        op: Op,
        len: usize,
        dim: usize,
        reqs: &[&Request],
    ) -> Vec<Response> {
        // Validate payload sizes up front; a malformed request must not sink
        // the whole batch.
        let expect = len * dim;
        let bad: Vec<bool> = reqs
            .iter()
            .map(|r| {
                r.data.len() != expect
                    || match op {
                        Op::SigKernel { .. } | Op::SigKernelGrad { .. } => {
                            r.data2.as_ref().map(|d| d.len()) != Some(expect)
                        }
                        _ => r.data2.is_some(),
                    }
            })
            .collect();
        let good_idx: Vec<usize> = (0..reqs.len()).filter(|&i| !bad[i]).collect();

        // Try the PJRT path for an exactly-matching artifact.
        if good_idx.len() == reqs.len() {
            if let Some(name) = self.artifact_for(op, reqs.len(), len, dim) {
                if let Some(resps) = self.execute_pjrt(&name, op, len, dim, reqs) {
                    return resps;
                }
            }
        }

        let computed = self.execute_native(op, len, dim, reqs, &good_idx);
        let mut out: Vec<Response> = Vec::with_capacity(reqs.len());
        let mut it = computed.into_iter();
        for i in 0..reqs.len() {
            if bad[i] {
                out.push(Response::Error(format!(
                    "payload size mismatch: expected {} values per path",
                    expect
                )));
            } else {
                out.push(it.next().unwrap());
            }
        }
        out
    }

    fn execute_native(
        &self,
        op: Op,
        len: usize,
        dim: usize,
        reqs: &[&Request],
        good_idx: &[usize],
    ) -> Vec<Response> {
        let b = good_idx.len();
        if b == 0 {
            return Vec::new();
        }
        let mut paths = Vec::with_capacity(b * len * dim);
        for &i in good_idx {
            paths.extend_from_slice(&reqs[i].data);
        }
        match op {
            Op::Signature { depth, transform } | Op::LogSignature { depth, transform } => {
                let tr = match transform_from_u8(transform) {
                    Some(t) => t,
                    None => {
                        return good_idx
                            .iter()
                            .map(|_| Response::Error("bad transform".into()))
                            .collect()
                    }
                };
                let opts = SigOptions::new(depth as usize).transform(tr);
                let slen = crate::sig::sig_length(tr.out_dim(dim), depth as usize);
                if matches!(op, Op::Signature { .. }) {
                    let sigs = crate::sig::batch_signature(&paths, b, len, dim, &opts);
                    sigs.chunks(slen)
                        .map(|c| Response::Values(c.to_vec()))
                        .collect()
                } else {
                    // Log-signatures: per-path (tensor log after the batch
                    // signature sweep).
                    good_idx
                        .iter()
                        .map(|&i| {
                            Response::Values(crate::sig::log_signature(
                                &reqs[i].data,
                                len,
                                dim,
                                depth as usize,
                                tr,
                            ))
                        })
                        .collect()
                }
            }
            Op::SigKernel {
                lam1,
                lam2,
                transform,
            } => {
                let tr = match transform_from_u8(transform) {
                    Some(t) => t,
                    None => {
                        return good_idx
                            .iter()
                            .map(|_| Response::Error("bad transform".into()))
                            .collect()
                    }
                };
                let mut ys = Vec::with_capacity(b * len * dim);
                for &i in good_idx {
                    ys.extend_from_slice(reqs[i].data2.as_ref().unwrap());
                }
                let opts = KernelOptions::default().dyadic(lam1, lam2).transform(tr);
                let ks = crate::kernel::batch_kernel(&paths, &ys, b, len, len, dim, &opts);
                ks.iter().map(|&k| Response::Values(vec![k])).collect()
            }
            Op::SigKernelGrad { lam1, lam2 } => {
                let mut ys = Vec::with_capacity(b * len * dim);
                for &i in good_idx {
                    ys.extend_from_slice(reqs[i].data2.as_ref().unwrap());
                }
                let opts = KernelOptions::default().dyadic(lam1, lam2);
                let gk = vec![1.0; b];
                let (gx, gy) =
                    crate::kernel::batch_kernel_vjp(&paths, &ys, &gk, b, len, len, dim, &opts);
                (0..b)
                    .map(|i| {
                        let mut v = gx[i * len * dim..(i + 1) * len * dim].to_vec();
                        v.extend_from_slice(&gy[i * len * dim..(i + 1) * len * dim]);
                        Response::Values(v)
                    })
                    .collect()
            }
        }
    }

    /// Execute via a PJRT artifact. Returns None (falls back to native) on
    /// any runtime error — the artifacts are an accelerator, not a
    /// correctness dependency.
    fn execute_pjrt(
        &self,
        name: &str,
        op: Op,
        len: usize,
        dim: usize,
        reqs: &[&Request],
    ) -> Option<Vec<Response>> {
        let rt = self.runtime.as_ref()?;
        let b = reqs.len();
        let mut xs = Vec::with_capacity(b * len * dim);
        for r in reqs {
            xs.extend(r.data.iter().map(|&v| v as f32));
        }
        let inputs: Vec<Vec<f32>> = match op {
            Op::SigKernel { .. } => {
                let mut ys = Vec::with_capacity(b * len * dim);
                for r in reqs {
                    ys.extend(r.data2.as_ref().unwrap().iter().map(|&v| v as f32));
                }
                vec![xs, ys]
            }
            _ => vec![xs],
        };
        let outputs = rt.execute_f32(name, inputs).ok()?;
        let flat = &outputs[0];
        let per = flat.len() / b;
        Some(
            flat.chunks(per)
                .map(|c| Response::Values(c.iter().map(|&v| v as f64).collect()))
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::sync::mpsc;

    fn req(op: Op, len: usize, dim: usize, rng: &mut Rng, pair: bool) -> Request {
        let (tx, _rx) = mpsc::channel();
        // keep receiver alive is unnecessary: router never sends; batcher does
        std::mem::forget(_rx);
        Request {
            op,
            len,
            dim,
            data: rng.brownian_path(len, dim, 0.5),
            data2: pair.then(|| rng.brownian_path(len, dim, 0.5)),
            reply: tx,
        }
    }

    #[test]
    fn signature_batch_matches_direct() {
        let router = Router::native_only();
        let op = Op::Signature {
            depth: 3,
            transform: 0,
        };
        let mut rng = Rng::new(7);
        let reqs: Vec<Request> = (0..5).map(|_| req(op, 8, 2, &mut rng, false)).collect();
        let refs: Vec<&Request> = reqs.iter().collect();
        let out = router.execute_batch(op, 8, 2, &refs);
        for (r, o) in reqs.iter().zip(&out) {
            match o {
                Response::Values(v) => {
                    let want = crate::sig::sig(&r.data, 8, 2, 3);
                    assert!(crate::util::linalg::max_abs_diff(v, &want) < 1e-12);
                }
                Response::Error(e) => panic!("{e}"),
            }
        }
    }

    #[test]
    fn kernel_grad_returns_both_gradients() {
        let router = Router::native_only();
        let op = Op::SigKernelGrad { lam1: 0, lam2: 0 };
        let mut rng = Rng::new(8);
        let reqs: Vec<Request> = (0..3).map(|_| req(op, 6, 2, &mut rng, true)).collect();
        let refs: Vec<&Request> = reqs.iter().collect();
        let out = router.execute_batch(op, 6, 2, &refs);
        for o in &out {
            match o {
                Response::Values(v) => assert_eq!(v.len(), 2 * 6 * 2),
                Response::Error(e) => panic!("{e}"),
            }
        }
    }

    #[test]
    fn malformed_request_errors_without_sinking_batch() {
        let router = Router::native_only();
        let op = Op::Signature {
            depth: 2,
            transform: 0,
        };
        let mut rng = Rng::new(9);
        let good = req(op, 8, 2, &mut rng, false);
        let mut bad = req(op, 8, 2, &mut rng, false);
        bad.data.truncate(3); // wrong payload
        let refs: Vec<&Request> = vec![&good, &bad];
        let out = router.execute_batch(op, 8, 2, &refs);
        assert!(matches!(out[0], Response::Values(_)));
        assert!(matches!(out[1], Response::Error(_)));
    }

    #[test]
    fn logsignature_served() {
        let router = Router::native_only();
        let op = Op::LogSignature {
            depth: 3,
            transform: 0,
        };
        let mut rng = Rng::new(10);
        let r = req(op, 7, 2, &mut rng, false);
        let refs: Vec<&Request> = vec![&r];
        let out = router.execute_batch(op, 7, 2, &refs);
        match &out[0] {
            Response::Values(v) => {
                let want =
                    crate::sig::log_signature(&r.data, 7, 2, 3, crate::transforms::Transform::None);
                assert!(crate::util::linalg::max_abs_diff(v, &want) < 1e-12);
            }
            Response::Error(e) => panic!("{e}"),
        }
    }
}
