//! PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust hot path.
//!
//! This is the stand-in for the paper's CUDA backend: the L2/L1 JAX+Pallas
//! computation is compiled once at build time; at run time the coordinator
//! dispatches batches to compiled executables with no Python anywhere.
//!
//! Wiring (see /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! HLO *text* is the interchange format — jax ≥ 0.5 serialized protos use
//! 64-bit instruction ids that xla_extension 0.5.1 rejects.
//!
//! The PJRT backend sits behind the **`pjrt` cargo feature** (it needs the
//! `xla` crate, which must be vendored — it is not available in the offline
//! build). The default build compiles a stub whose constructors return an
//! error, so every caller's "artifacts unavailable → native backend"
//! fallback path engages; the manifest parser and the thread-confined
//! [`RuntimeHandle`] façade are feature-independent.

use crate::format_err as anyhow;
use crate::util::error::{Context, Result};
#[cfg(feature = "pjrt")]
use std::collections::HashMap;
use std::path::Path;
#[cfg(feature = "pjrt")]
use std::path::PathBuf;
use std::sync::Arc;
#[cfg(feature = "pjrt")]
use std::sync::Mutex;

/// One artifact from `artifacts/manifest.txt`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArtifactInfo {
    pub name: String,
    /// Shapes of the (f32) inputs, e.g. [[8,16,3],[8,16,3]].
    pub input_shapes: Vec<Vec<usize>>,
}

impl ArtifactInfo {
    /// Parse one `name|8x16x3,8x16x3|f32` manifest line.
    pub fn parse(line: &str) -> Result<ArtifactInfo> {
        let mut parts = line.trim().split('|');
        let name = parts.next().ok_or_else(|| anyhow!("empty manifest line"))?;
        let shapes = parts
            .next()
            .ok_or_else(|| anyhow!("manifest line missing shapes: {line}"))?;
        let input_shapes = shapes
            .split(',')
            .map(|s| {
                s.split('x')
                    .map(|v| v.parse::<usize>().context("bad shape dim"))
                    .collect::<Result<Vec<_>>>()
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(ArtifactInfo {
            name: name.to_string(),
            input_shapes,
        })
    }

    pub fn input_len(&self, i: usize) -> usize {
        self.input_shapes[i].iter().product()
    }
}

/// PJRT-backed executor with a compile-once cache per artifact.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Vec<ArtifactInfo>,
    cache: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
}

/// Stub executor compiled when the `pjrt` feature is off: construction
/// always fails, so callers take their native-backend fallback path.
#[cfg(not(feature = "pjrt"))]
pub struct Runtime {
    manifest: Vec<ArtifactInfo>,
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    /// Always fails: the PJRT backend is not compiled in.
    pub fn new(dir: impl AsRef<Path>) -> Result<Runtime> {
        let _ = dir;
        Err(anyhow!(
            "PJRT backend not compiled in; rebuild with `--features pjrt` \
             (requires vendoring the `xla` crate)"
        ))
    }

    pub fn manifest(&self) -> &[ArtifactInfo] {
        &self.manifest
    }

    pub fn info(&self, name: &str) -> Option<&ArtifactInfo> {
        self.manifest.iter().find(|a| a.name == name)
    }

    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    pub fn execute_f32(&self, name: &str, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let _ = (name, inputs);
        Err(anyhow!("PJRT backend not compiled in"))
    }
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Open the artifact directory (reads `manifest.txt`) on the CPU PJRT
    /// client.
    pub fn new(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?}; run `make artifacts` first"))?;
        let manifest = text
            .lines()
            .filter(|l| !l.trim().is_empty())
            .map(ArtifactInfo::parse)
            .collect::<Result<Vec<_>>>()?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime {
            client,
            dir,
            manifest,
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// Artifact metadata.
    pub fn manifest(&self) -> &[ArtifactInfo] {
        &self.manifest
    }

    pub fn info(&self, name: &str) -> Option<&ArtifactInfo> {
        self.manifest.iter().find(|a| a.name == name)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load (compile) an artifact, cached.
    pub fn load(&self, name: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(
            self.client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?,
        );
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact on f32 inputs; returns every tuple output
    /// flattened. Input lengths are validated against the manifest.
    pub fn execute_f32(&self, name: &str, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let info = self
            .info(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?
            .clone();
        if inputs.len() != info.input_shapes.len() {
            return Err(anyhow!(
                "{name}: expected {} inputs, got {}",
                info.input_shapes.len(),
                inputs.len()
            ));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, data) in inputs.iter().enumerate() {
            if data.len() != info.input_len(i) {
                return Err(anyhow!(
                    "{name}: input {i} has {} elements, expected {} ({:?})",
                    data.len(),
                    info.input_len(i),
                    info.input_shapes[i]
                ));
            }
            let dims: Vec<i64> = info.input_shapes[i].iter().map(|&v| v as i64).collect();
            let lit = xla::Literal::vec1(data)
                .reshape(&dims)
                .map_err(|e| anyhow!("reshape input {i}: {e:?}"))?;
            literals.push(lit);
        }
        let exe = self.load(name)?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let literal = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result: {e:?}"))?;
        // aot.py lowers with return_tuple=True: unpack every element.
        let elements = literal
            .to_tuple()
            .map_err(|e| anyhow!("untupling result: {e:?}"))?;
        elements
            .iter()
            .map(|l| l.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}")))
            .collect()
    }
}

/// The xla crate's client/executable types are `!Send` (Rc + raw PJRT
/// pointers), so the multi-threaded coordinator cannot hold a [`Runtime`]
/// directly. `RuntimeHandle` confines the whole PJRT stack to one dedicated
/// worker thread and exposes a `Send + Sync` façade: calls are serialised
/// through a channel (PJRT CPU execution is internally parallel anyway, so
/// one dispatcher thread is not a throughput limit at our batch sizes).
pub struct RuntimeHandle {
    manifest: Vec<ArtifactInfo>,
    platform: String,
    tx: std::sync::Mutex<std::sync::mpsc::Sender<Job>>,
}

struct Job {
    name: String,
    inputs: Vec<Vec<f32>>,
    reply: std::sync::mpsc::Sender<Result<Vec<Vec<f32>>>>,
}

impl RuntimeHandle {
    /// Start the PJRT worker thread over an artifact directory.
    pub fn spawn(dir: impl AsRef<Path>) -> Result<Arc<RuntimeHandle>> {
        let dir = dir.as_ref().to_path_buf();
        let (tx, rx) = std::sync::mpsc::channel::<Job>();
        let (init_tx, init_rx) = std::sync::mpsc::channel();
        std::thread::spawn(move || {
            let runtime = match Runtime::new(&dir) {
                Ok(rt) => {
                    let _ = init_tx.send(Ok((rt.manifest().to_vec(), rt.platform())));
                    rt
                }
                Err(e) => {
                    let _ = init_tx.send(Err(e));
                    return;
                }
            };
            while let Ok(job) = rx.recv() {
                let result = runtime.execute_f32(&job.name, &job.inputs);
                let _ = job.reply.send(result);
            }
        });
        let (manifest, platform) = init_rx
            .recv()
            .map_err(|_| anyhow!("PJRT worker died during init"))??;
        Ok(Arc::new(RuntimeHandle {
            manifest,
            platform,
            tx: std::sync::Mutex::new(tx),
        }))
    }

    pub fn manifest(&self) -> &[ArtifactInfo] {
        &self.manifest
    }

    pub fn info(&self, name: &str) -> Option<&ArtifactInfo> {
        self.manifest.iter().find(|a| a.name == name)
    }

    pub fn platform(&self) -> &str {
        &self.platform
    }

    /// Execute an artifact on the worker thread (blocking).
    pub fn execute_f32(&self, name: &str, inputs: Vec<Vec<f32>>) -> Result<Vec<Vec<f32>>> {
        let (reply, rx) = std::sync::mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send(Job {
                name: name.to_string(),
                inputs,
                reply,
            })
            .map_err(|_| anyhow!("PJRT worker gone"))?;
        rx.recv().map_err(|_| anyhow!("PJRT worker died"))?
    }

    /// f64 convenience wrapper (native code is f64; artifacts are f32).
    pub fn execute_f64(&self, name: &str, inputs: &[&[f64]]) -> Result<Vec<Vec<f64>>> {
        let f32_inputs: Vec<Vec<f32>> = inputs
            .iter()
            .map(|x| x.iter().map(|&v| v as f32).collect())
            .collect();
        Ok(self
            .execute_f32(name, f32_inputs)?
            .into_iter()
            .map(|o| o.into_iter().map(|v| v as f64).collect())
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_line_parses() {
        let a = ArtifactInfo::parse("sigkernel_b8_l16_d3|8x16x3,8x16x3|f32").unwrap();
        assert_eq!(a.name, "sigkernel_b8_l16_d3");
        assert_eq!(a.input_shapes, vec![vec![8, 16, 3], vec![8, 16, 3]]);
        assert_eq!(a.input_len(0), 384);
    }

    #[test]
    fn bad_manifest_line_errors() {
        assert!(ArtifactInfo::parse("justaname").is_err());
        assert!(ArtifactInfo::parse("n|axb|f32").is_err());
    }
}
