//! The free tensor algebra substrate.
//!
//! Truncated elements of T((R^d)) = ⊕_k (R^d)^{⊗k} are stored as one flat,
//! contiguous `Vec<f64>` — level k occupies `d^k` consecutive entries — the
//! layout the paper's design choice (1) calls for ("the signature
//! (A_0,...,A_N) is stored as a single flattened contiguous array").

pub mod alg;

pub use alg::{
    exp_increment, group_inverse, inner_product, tensor_exp, tensor_log, tensor_log_into,
    tensor_prod, tensor_prod_accum, LevelLayout,
};

/// An element of the truncated free tensor algebra, owning its flat storage.
///
/// This is the value returned by the signature APIs; most hot-path code works
/// on raw slices with a shared [`LevelLayout`] instead.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSeq {
    pub layout: LevelLayout,
    pub data: Vec<f64>,
}

impl TensorSeq {
    /// The identity element (1, 0, 0, ...).
    pub fn one(dim: usize, depth: usize) -> Self {
        let layout = LevelLayout::new(dim, depth);
        let mut data = vec![0.0; layout.total()];
        data[0] = 1.0;
        TensorSeq { layout, data }
    }

    /// Zero element.
    pub fn zero(dim: usize, depth: usize) -> Self {
        let layout = LevelLayout::new(dim, depth);
        TensorSeq {
            data: vec![0.0; layout.total()],
            layout,
        }
    }

    /// View of level k.
    pub fn level(&self, k: usize) -> &[f64] {
        let (s, e) = self.layout.level_range(k);
        &self.data[s..e]
    }

    /// Mutable view of level k.
    pub fn level_mut(&mut self, k: usize) -> &mut [f64] {
        let (s, e) = self.layout.level_range(k);
        &mut self.data[s..e]
    }

    /// Chen product: self ⊗ other (truncated).
    pub fn prod(&self, other: &TensorSeq) -> TensorSeq {
        assert_eq!(self.layout, other.layout);
        let mut out = TensorSeq::zero(self.layout.dim, self.layout.depth);
        tensor_prod(&self.layout, &self.data, &other.data, &mut out.data);
        out
    }

    /// Group inverse (requires scalar part 1).
    pub fn inverse(&self) -> TensorSeq {
        let mut out = TensorSeq::zero(self.layout.dim, self.layout.depth);
        group_inverse(&self.layout, &self.data, &mut out.data);
        out
    }

    /// Tensor logarithm (requires scalar part 1).
    pub fn log(&self) -> TensorSeq {
        let mut out = TensorSeq::zero(self.layout.dim, self.layout.depth);
        tensor_log(&self.layout, &self.data, &mut out.data);
        out
    }

    /// Inner product ⟨self, other⟩ = Σ_k ⟨self_k, other_k⟩.
    pub fn inner(&self, other: &TensorSeq) -> f64 {
        assert_eq!(self.layout, other.layout);
        inner_product(&self.data, &other.data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_is_identity_for_prod() {
        let one = TensorSeq::one(3, 4);
        let mut x = TensorSeq::one(3, 4);
        x.data.iter_mut().enumerate().for_each(|(i, v)| {
            if i > 0 {
                *v = (i as f64).sin();
            }
        });
        let y = one.prod(&x);
        let z = x.prod(&one);
        for i in 0..x.data.len() {
            assert!((y.data[i] - x.data[i]).abs() < 1e-14);
            assert!((z.data[i] - x.data[i]).abs() < 1e-14);
        }
    }

    #[test]
    fn inverse_of_exp_is_exp_of_negative() {
        let layout = LevelLayout::new(2, 5);
        let z = [0.3, -0.7];
        let mut e = vec![0.0; layout.total()];
        exp_increment(&layout, &z, &mut e);
        let seq = TensorSeq {
            layout: layout.clone(),
            data: e,
        };
        let inv = seq.inverse();
        let zn = [-0.3, 0.7];
        let mut en = vec![0.0; layout.total()];
        exp_increment(&layout, &zn, &mut en);
        for i in 0..en.len() {
            assert!((inv.data[i] - en[i]).abs() < 1e-12, "i={i}");
        }
    }

    #[test]
    fn log_of_exp_recovers_increment() {
        let layout = LevelLayout::new(3, 4);
        let z = [0.2, 0.1, -0.4];
        let mut e = vec![0.0; layout.total()];
        exp_increment(&layout, &z, &mut e);
        let seq = TensorSeq {
            layout: layout.clone(),
            data: e,
        };
        let l = seq.log();
        // log(exp(z)) = z exactly (z is level-1 only, primitive).
        assert!((l.data[0]).abs() < 1e-14);
        for j in 0..3 {
            assert!((l.level(1)[j] - z[j]).abs() < 1e-12);
        }
        // Higher levels of log vanish.
        for k in 2..=4 {
            for &v in seq.log().level(k) {
                assert!(v.abs() < 1e-12);
            }
        }
    }
}
