//! Flat-storage operations on the truncated free tensor algebra T^N(R^d).
//!
//! Level k of an element lives at `offsets[k] .. offsets[k+1]` of the flat
//! array, with `d^k` entries indexed lexicographically: the multi-index
//! (i_1,...,i_k) maps to `((i_1*d + i_2)*d + ...)*d + i_k`. Under this
//! indexing the tensor product of a level-i block `a` and a level-j block `b`
//! is the outer product `out[u*d^j + v] = a[u]*b[v]` — contiguous in `v`,
//! which is what every inner loop below exploits.

/// Shape descriptor for a truncated tensor sequence: dimension `d` and
/// truncation depth `N`, with precomputed level offsets.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LevelLayout {
    pub dim: usize,
    pub depth: usize,
    /// offsets[k] = start index of level k; offsets[depth+1] = total length.
    offsets: Vec<usize>,
}

impl LevelLayout {
    /// Build the layout for dimension `dim`, truncation `depth`.
    ///
    /// Panics if the flat size overflows or exceeds 2^31 entries (16 GiB of
    /// f64) — far beyond any practical signature computation.
    pub fn new(dim: usize, depth: usize) -> Self {
        assert!(dim >= 1, "dimension must be >= 1");
        let mut offsets = Vec::with_capacity(depth + 2);
        let mut total: usize = 0;
        let mut level_size: usize = 1;
        for _k in 0..=depth {
            offsets.push(total);
            total = total.checked_add(level_size).expect("layout overflow");
            level_size = level_size.checked_mul(dim).expect("layout overflow");
            assert!(total < (1usize << 31), "signature too large to store");
        }
        offsets.push(total);
        LevelLayout {
            dim,
            depth,
            offsets,
        }
    }

    /// Total flat length = (d^{N+1}-1)/(d-1).
    #[inline]
    pub fn total(&self) -> usize {
        self.offsets[self.depth + 1]
    }

    /// Number of entries in level k (= d^k).
    #[inline]
    pub fn level_size(&self, k: usize) -> usize {
        self.offsets[k + 1] - self.offsets[k]
    }

    /// Half-open range of level k in the flat array.
    #[inline]
    pub fn level_range(&self, k: usize) -> (usize, usize) {
        (self.offsets[k], self.offsets[k + 1])
    }

    /// Start offset of level k.
    #[inline]
    pub fn offset(&self, k: usize) -> usize {
        self.offsets[k]
    }
}

/// out = exp(z) truncated: (1, z, z^{⊗2}/2!, ..., z^{⊗N}/N!).
/// `z` has length `layout.dim`; `out` has length `layout.total()`.
pub fn exp_increment(layout: &LevelLayout, z: &[f64], out: &mut [f64]) {
    assert_eq!(z.len(), layout.dim);
    assert_eq!(out.len(), layout.total());
    let d = layout.dim;
    out[0] = 1.0;
    if layout.depth == 0 {
        return;
    }
    out[1..1 + d].copy_from_slice(z);
    for k in 2..=layout.depth {
        let (ps, pe) = layout.level_range(k - 1);
        let (cs, _ce) = layout.level_range(k);
        let inv_k = 1.0 / k as f64;
        // out_k = out_{k-1} ⊗ z / k, built forward (reads previous level only).
        let prev_len = pe - ps;
        for u in 0..prev_len {
            let a = out[ps + u] * inv_k;
            let dst = cs + u * d;
            for j in 0..d {
                out[dst + j] = a * z[j];
            }
        }
    }
}

/// General tensor exponential of a truncated element with zero scalar part:
/// out = 1 + x + x⊗x/2! + ... (series terminates at depth N).
pub fn tensor_exp(layout: &LevelLayout, x: &[f64], out: &mut [f64]) {
    assert_eq!(x.len(), layout.total());
    assert!(x[0].abs() < 1e-14, "tensor_exp requires zero scalar part");
    let n = layout.total();
    out.fill(0.0);
    out[0] = 1.0;
    // Horner: out = 1 + x(1 + x/2 (1 + x/3 (...)))
    let mut acc = vec![0.0; n];
    acc[0] = 1.0;
    for k in (1..=layout.depth).rev() {
        // acc = 1 + (x/k) ⊗ acc
        let mut next = vec![0.0; n];
        tensor_prod(layout, x, &acc, &mut next);
        for v in next.iter_mut() {
            *v /= k as f64;
        }
        next[0] += 1.0;
        acc = next;
    }
    out.copy_from_slice(&acc);
}

/// Truncated tensor product: out_n = Σ_{i+j=n} a_i ⊗ b_j for n = 0..=N.
/// `out` must not alias `a` or `b`.
pub fn tensor_prod(layout: &LevelLayout, a: &[f64], b: &[f64], out: &mut [f64]) {
    assert_eq!(a.len(), layout.total());
    assert_eq!(b.len(), layout.total());
    assert_eq!(out.len(), layout.total());
    out.fill(0.0);
    tensor_prod_accum(layout, a, b, out);
}

/// out += a ⊗ b (truncated). `out` must not alias `a` or `b`.
pub fn tensor_prod_accum(layout: &LevelLayout, a: &[f64], b: &[f64], out: &mut [f64]) {
    for n in 0..=layout.depth {
        let (os, _oe) = layout.level_range(n);
        for i in 0..=n {
            let j = n - i;
            let (as_, ae) = layout.level_range(i);
            let (bs, be) = layout.level_range(j);
            let bj = be - bs;
            let av = &a[as_..ae];
            let bv = &b[bs..be];
            // out_n[u*d^j + v] += a_i[u] * b_j[v]
            for (u, &au) in av.iter().enumerate() {
                if au == 0.0 {
                    continue;
                }
                let dst = os + u * bj;
                let orow = &mut out[dst..dst + bj];
                for (o, &bvv) in orow.iter_mut().zip(bv.iter()) {
                    *o += au * bvv;
                }
            }
        }
    }
}

/// Group inverse of a group-like (scalar part 1) element:
/// (1 + x)^{-1} = Σ_{n≤N} (-x)^{⊗n}, computed by Horner.
pub fn group_inverse(layout: &LevelLayout, a: &[f64], out: &mut [f64]) {
    assert!((a[0] - 1.0).abs() < 1e-12, "group_inverse needs scalar 1");
    let n = layout.total();
    // x = a - 1 (zero scalar part), negated.
    let mut negx = a.to_vec();
    negx[0] = 0.0;
    for v in negx.iter_mut() {
        *v = -*v;
    }
    // Horner: inv = 1 + (-x)(1 + (-x)(1 + ...))
    let mut acc = vec![0.0; n];
    acc[0] = 1.0;
    for _ in 0..layout.depth {
        let mut next = vec![0.0; n];
        tensor_prod(layout, &negx, &acc, &mut next);
        next[0] += 1.0;
        acc = next;
    }
    out.copy_from_slice(&acc);
}

/// Tensor logarithm of a group-like element:
/// log(1 + x) = Σ_{n=1..N} (-1)^{n+1} x^{⊗n} / n, computed by Horner:
/// log(1+x) = x ⊗ (1 - x/2 ⊗ (1 - 2x/3 ⊗ (...))) — we use the direct
/// alternating Horner form 1 - x(1/2 - x(1/3 - ...)) multiplied by x.
pub fn tensor_log(layout: &LevelLayout, a: &[f64], out: &mut [f64]) {
    let n = layout.total();
    let mut x = vec![0.0; n];
    let mut acc = vec![0.0; n];
    let mut next = vec![0.0; n];
    tensor_log_into(layout, a, out, &mut x, &mut acc, &mut next);
}

/// [`tensor_log`] with caller-provided scratch (`x`, `acc`, `next`, each of
/// length `layout.total()`), so steady-state callers (the engine's
/// log-signature plans) allocate nothing.
pub fn tensor_log_into(
    layout: &LevelLayout,
    a: &[f64],
    out: &mut [f64],
    x: &mut [f64],
    acc: &mut Vec<f64>,
    next: &mut Vec<f64>,
) {
    assert!((a[0] - 1.0).abs() < 1e-12, "tensor_log needs scalar 1");
    let n = layout.total();
    assert!(x.len() == n && acc.len() == n && next.len() == n);
    x.copy_from_slice(a);
    x[0] = 0.0;
    // Horner over coefficients c_n = (-1)^{n+1}/n:
    // log = x(c1 + x(c2/c1... )) — simpler: acc = c_N; for k=N-1..1: acc = c_k + x ⊗ acc
    // then log = x ⊗ acc... but that computes Σ c_k x^{k} with one extra x.
    // Directly: acc = c_N * 1; for k = N-1 down to 1: acc = c_k + x⊗acc; out = x⊗acc.
    let depth = layout.depth;
    if depth == 0 {
        out.fill(0.0);
        return;
    }
    let coef = |k: usize| -> f64 {
        let s = if k % 2 == 1 { 1.0 } else { -1.0 };
        s / k as f64
    };
    acc.fill(0.0);
    acc[0] = coef(depth);
    for k in (1..depth).rev() {
        tensor_prod(layout, x, acc, next);
        next[0] += coef(k);
        std::mem::swap(acc, next);
    }
    tensor_prod(layout, x, acc, out);
}

/// Full inner product ⟨a, b⟩ = Σ_k ⟨a_k, b_k⟩ over the flat arrays (the
/// truncated signature-kernel inner product with the standard Euclidean
/// pairing on each level).
#[inline]
pub fn inner_product(a: &[f64], b: &[f64]) -> f64 {
    crate::util::linalg::dot(a, b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn layout_sizes() {
        let l = LevelLayout::new(3, 4);
        assert_eq!(l.total(), 1 + 3 + 9 + 27 + 81);
        assert_eq!(l.level_size(0), 1);
        assert_eq!(l.level_size(3), 27);
        assert_eq!(l.level_range(2), (4, 13));
    }

    #[test]
    fn layout_dim_one() {
        let l = LevelLayout::new(1, 6);
        assert_eq!(l.total(), 7);
    }

    #[test]
    fn exp_increment_matches_tensor_exp() {
        let layout = LevelLayout::new(3, 5);
        let z = [0.4, -0.2, 0.9];
        let mut fast = vec![0.0; layout.total()];
        exp_increment(&layout, &z, &mut fast);
        let mut x = vec![0.0; layout.total()];
        x[1..4].copy_from_slice(&z);
        let mut slow = vec![0.0; layout.total()];
        tensor_exp(&layout, &x, &mut slow);
        for i in 0..fast.len() {
            assert!((fast[i] - slow[i]).abs() < 1e-12, "i={i}");
        }
    }

    #[test]
    fn prod_is_associative() {
        check("tensor product associativity", 30, |g| {
            let d = g.usize_in(1, 4);
            let n = g.usize_in(1, 4);
            let layout = LevelLayout::new(d, n);
            let t = layout.total();
            let a = g.normal_vec(t);
            let b = g.normal_vec(t);
            let c = g.normal_vec(t);
            let mut ab = vec![0.0; t];
            let mut bc = vec![0.0; t];
            let mut ab_c = vec![0.0; t];
            let mut a_bc = vec![0.0; t];
            tensor_prod(&layout, &a, &b, &mut ab);
            tensor_prod(&layout, &b, &c, &mut bc);
            tensor_prod(&layout, &ab, &c, &mut ab_c);
            tensor_prod(&layout, &a, &bc, &mut a_bc);
            let err = crate::util::linalg::max_abs_diff(&ab_c, &a_bc);
            assert!(err < 1e-9, "associativity violated: {err}");
        });
    }

    #[test]
    fn prod_distributes_over_addition() {
        check("tensor product bilinearity", 30, |g| {
            let d = g.usize_in(1, 3);
            let n = g.usize_in(1, 4);
            let layout = LevelLayout::new(d, n);
            let t = layout.total();
            let a = g.normal_vec(t);
            let b = g.normal_vec(t);
            let c = g.normal_vec(t);
            let bc: Vec<f64> = b.iter().zip(&c).map(|(x, y)| x + y).collect();
            let mut left = vec![0.0; t];
            tensor_prod(&layout, &a, &bc, &mut left);
            let mut r1 = vec![0.0; t];
            let mut r2 = vec![0.0; t];
            tensor_prod(&layout, &a, &b, &mut r1);
            tensor_prod(&layout, &a, &c, &mut r2);
            let right: Vec<f64> = r1.iter().zip(&r2).map(|(x, y)| x + y).collect();
            assert!(crate::util::linalg::max_abs_diff(&left, &right) < 1e-9);
        });
    }

    #[test]
    fn inverse_is_two_sided() {
        check("group inverse", 20, |g| {
            let d = g.usize_in(1, 3);
            let n = g.usize_in(1, 4);
            let layout = LevelLayout::new(d, n);
            let t = layout.total();
            let mut a = g.normal_vec(t);
            a[0] = 1.0;
            // keep entries modest so the truncated inverse is well-conditioned
            for v in a[1..].iter_mut() {
                *v *= 0.3;
            }
            let mut inv = vec![0.0; t];
            group_inverse(&layout, &a, &mut inv);
            let mut prod = vec![0.0; t];
            tensor_prod(&layout, &a, &inv, &mut prod);
            let mut one = vec![0.0; t];
            one[0] = 1.0;
            assert!(crate::util::linalg::max_abs_diff(&prod, &one) < 1e-8);
            tensor_prod(&layout, &inv, &a, &mut prod);
            assert!(crate::util::linalg::max_abs_diff(&prod, &one) < 1e-8);
        });
    }

    #[test]
    fn exp_log_roundtrip() {
        check("exp/log roundtrip", 20, |g| {
            let d = g.usize_in(1, 3);
            let n = g.usize_in(1, 4);
            let layout = LevelLayout::new(d, n);
            let t = layout.total();
            let mut x = g.normal_vec(t);
            x[0] = 0.0;
            for v in x.iter_mut() {
                *v *= 0.3;
            }
            let mut e = vec![0.0; t];
            tensor_exp(&layout, &x, &mut e);
            let mut l = vec![0.0; t];
            tensor_log(&layout, &e, &mut l);
            assert!(crate::util::linalg::max_abs_diff(&l, &x) < 1e-8);
        });
    }
}
