//! Versioned, checksummed corpus snapshots — restart without the cold
//! rebuild.
//!
//! A registry snapshot serialises every registered corpus (paths, birth
//! ticks, content hash) **and** its warm derived state (exact self-Grams,
//! retained Goursat pair borders, low-rank feature matrices), so a
//! restarted coordinator restores in O(bytes) instead of re-paying the
//! O(n²·L²) corpus-side solves. The format is deliberately dumb: fixed
//! little-endian `u64`/`f64` words, no compression, every section
//! independently checksummed.
//!
//! ## Format (version 1)
//!
//! | field            | size      | meaning                                    |
//! |------------------|-----------|--------------------------------------------|
//! | magic            | u64       | `0x5349_474c_534e_4150` ("SIGLSNAP")       |
//! | version          | u64       | format version (currently 1)               |
//! | section count    | u64       | number of sections that follow             |
//! | per section: tag | u64       | 1 = paths, 2 = exact cache, 3 = low-rank   |
//! | body length      | u64       | section body size in bytes                 |
//! | body hash        | u64       | FNV-1a over the body bytes                 |
//! | body             | length    | tag-specific payload                       |
//!
//! **Paths** sections (tag 1) are mandatory: a checksum or decode failure
//! fails the whole load with [`SigError::SnapshotCorrupt`] — serving wrong
//! path data is never acceptable. **Derived** sections (tags 2–3, and any
//! unknown tag from a future writer) are an optimisation: a corrupt one is
//! dropped and the registry rebuilds that state lazily on the next query,
//! exactly as if it had never been cached. Low-rank sections carry the
//! corpus feature matrix `Φ_c` but not the feature map itself — the map is
//! rebuilt deterministically from its seeded landmark pool on restore, which
//! keeps sketch matrices out of the file without giving up bit-identity.
//!
//! Writes are atomic: the encoded bytes land in a same-directory temp file
//! (synced) which is then renamed over the target, so a crash mid-write
//! leaves any previous snapshot intact. The `snapshot.torn_write` /
//! `snapshot.short_read` [failpoints](crate::util::failpoint) truncate the
//! byte stream at either seam to drive the recovery tests.

use std::path::Path;

use crate::kernel::border::{PairBorder, SchemeBorder};
use crate::kernel::lowrank::LowRankSpec;
use crate::kernel::scheme::{Scheme, TargetEps};
use crate::kernel::{KernelOptions, LowRankMethod, SolverKind};
use crate::kernel::lowrank::SketchKind;
use crate::path::SigError;
use crate::transforms::Transform;

const MAGIC: u64 = 0x5349_474c_534e_4150; // "SIGLSNAP" big-endian byte order
const VERSION: u64 = 1;
const TAG_PATHS: u64 = 1;
const TAG_EXACT: u64 = 2;
const TAG_LOWRANK: u64 = 3;

/// Plain-data view of one registered corpus — the exchange type between the
/// registry's locked internals and this module's byte format.
pub(crate) struct CorpusExport {
    pub id: u32,
    pub dim: usize,
    pub tick: u64,
    pub hash: u64,
    pub lengths: Vec<usize>,
    pub born: Vec<u64>,
    pub data: Vec<f64>,
    pub exact: Vec<ExactExport>,
    pub lowrank: Vec<LowRankExport>,
}

/// One exact-kernel cache: the self-Gram plus retained pair borders.
pub(crate) struct ExactExport {
    pub opts: KernelOptions,
    pub kcc: Vec<f64>,
    pub borders: Vec<BorderExport>,
}

/// One retained Goursat border, keyed by its ordered path pair.
pub(crate) struct BorderExport {
    pub i: usize,
    pub j: usize,
    pub border: SchemeBorder,
}

/// One low-rank cache: spec, landmark-pool size and the feature matrix.
pub(crate) struct LowRankExport {
    pub opts: KernelOptions,
    pub spec: LowRankSpec,
    pub pool: usize,
    pub phi: Vec<f64>,
}

fn corrupt(msg: &str) -> SigError {
    SigError::SnapshotCorrupt(msg.to_string())
}

/// FNV-1a over raw bytes — same constants as the registry's content hash.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1_0000_0000_01b3);
    }
    h
}

// ---------------------------------------------------------------------------
// Little-endian word writer / checked reader.

#[derive(Default)]
struct Buf {
    bytes: Vec<u8>,
}

impl Buf {
    fn u64(&mut self, v: u64) {
        self.bytes.extend_from_slice(&v.to_le_bytes());
    }

    fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }

    fn f64s(&mut self, vs: &[f64]) {
        self.bytes.reserve(vs.len() * 8);
        for &v in vs {
            self.bytes.extend_from_slice(&v.to_bits().to_le_bytes());
        }
    }

    fn u64s(&mut self, vs: &[u64]) {
        self.bytes.reserve(vs.len() * 8);
        for &v in vs {
            self.bytes.extend_from_slice(&v.to_le_bytes());
        }
    }
}

/// Bounds-checked reader over a byte slice: every overrun is a typed
/// truncation error, and counted reads verify the bytes exist *before*
/// allocating — a hostile length word cannot trigger a huge allocation.
struct Cursor<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(bytes: &'a [u8]) -> Cursor<'a> {
        Cursor { bytes, pos: 0 }
    }

    fn take(&mut self, len: usize) -> Result<&'a [u8], SigError> {
        let end = self
            .pos
            .checked_add(len)
            .ok_or_else(|| corrupt("section length overflows"))?;
        let out = self
            .bytes
            .get(self.pos..end)
            .ok_or_else(|| corrupt("truncated snapshot"))?;
        self.pos = end;
        Ok(out)
    }

    fn u64(&mut self) -> Result<u64, SigError> {
        let raw = self.take(8)?;
        let mut le = [0u8; 8];
        le.copy_from_slice(raw);
        Ok(u64::from_le_bytes(le))
    }

    fn usize(&mut self) -> Result<usize, SigError> {
        usize::try_from(self.u64()?).map_err(|_| corrupt("count exceeds this platform"))
    }

    fn u64s(&mut self, count: usize) -> Result<Vec<u64>, SigError> {
        let raw = self.take(count.checked_mul(8).ok_or_else(|| corrupt("count overflows"))?)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| {
                let mut le = [0u8; 8];
                le.copy_from_slice(c);
                u64::from_le_bytes(le)
            })
            .collect())
    }

    fn usizes(&mut self, count: usize) -> Result<Vec<usize>, SigError> {
        self.u64s(count)?
            .into_iter()
            .map(|v| usize::try_from(v).map_err(|_| corrupt("count exceeds this platform")))
            .collect()
    }

    fn f64s(&mut self, count: usize) -> Result<Vec<f64>, SigError> {
        Ok(self
            .u64s(count)?
            .into_iter()
            .map(f64::from_bits)
            .collect())
    }

    fn done(&self) -> bool {
        self.pos == self.bytes.len()
    }
}

// ---------------------------------------------------------------------------
// Options / spec encoding (fixed-width, decode-validated).

fn transform_code(t: Transform) -> u64 {
    match t {
        Transform::None => 0,
        Transform::TimeAug => 1,
        Transform::LeadLag => 2,
        Transform::LeadLagTimeAug => 3,
    }
}

fn transform_from_code(v: u64) -> Option<Transform> {
    match v {
        0 => Some(Transform::None),
        1 => Some(Transform::TimeAug),
        2 => Some(Transform::LeadLag),
        3 => Some(Transform::LeadLagTimeAug),
        _ => None,
    }
}

fn put_opts(buf: &mut Buf, o: &KernelOptions) {
    buf.u64(o.dyadic_x as u64);
    buf.u64(o.dyadic_y as u64);
    buf.u64(match o.solver {
        SolverKind::Row => 0,
        SolverKind::Blocked => 1,
    });
    buf.u64(o.scheme.to_u8() as u64);
    match o.target_eps.get() {
        Some(eps) => {
            buf.u64(1);
            buf.u64(eps.to_bits());
        }
        None => {
            buf.u64(0);
            buf.u64(0);
        }
    }
    buf.u64(transform_code(o.exec.transform));
    buf.u64(o.exec.parallel as u64);
}

fn get_opts(c: &mut Cursor<'_>) -> Result<KernelOptions, SigError> {
    let dyadic_x = u32::try_from(c.u64()?).map_err(|_| corrupt("dyadic order out of range"))?;
    let dyadic_y = u32::try_from(c.u64()?).map_err(|_| corrupt("dyadic order out of range"))?;
    let solver = match c.u64()? {
        0 => SolverKind::Row,
        1 => SolverKind::Blocked,
        _ => return Err(corrupt("unknown solver code")),
    };
    let scheme_byte = u8::try_from(c.u64()?).map_err(|_| corrupt("scheme code out of range"))?;
    let scheme = Scheme::from_u8(scheme_byte).ok_or_else(|| corrupt("unknown scheme code"))?;
    let eps_set = c.u64()?;
    let eps_bits = c.u64()?;
    let target_eps = match eps_set {
        0 => TargetEps::UNSET,
        1 => TargetEps::new(f64::from_bits(eps_bits)),
        _ => return Err(corrupt("bad target-eps flag")),
    };
    let transform =
        transform_from_code(c.u64()?).ok_or_else(|| corrupt("unknown transform code"))?;
    let parallel = match c.u64()? {
        0 => false,
        1 => true,
        _ => return Err(corrupt("bad parallel flag")),
    };
    let mut opts = KernelOptions::default()
        .dyadic(dyadic_x, dyadic_y)
        .solver(solver)
        .scheme(scheme)
        .transform(transform);
    opts.target_eps = target_eps;
    opts.exec.parallel = parallel;
    Ok(opts)
}

fn put_spec(buf: &mut Buf, s: &LowRankSpec) {
    match s.method {
        LowRankMethod::Nystrom => {
            buf.u64(0);
            buf.u64(0); // depth (unused)
            buf.u64(0); // sketch (unused)
        }
        LowRankMethod::RandomSig { depth, sketch } => {
            buf.u64(1);
            buf.usize(depth);
            buf.u64(match sketch {
                SketchKind::Gaussian => 0,
                SketchKind::Rademacher => 1,
            });
        }
    }
    buf.usize(s.rank);
    buf.u64(s.seed);
}

fn get_spec(c: &mut Cursor<'_>) -> Result<LowRankSpec, SigError> {
    let method_tag = c.u64()?;
    let depth = c.usize()?;
    let sketch_tag = c.u64()?;
    let method = match method_tag {
        0 => LowRankMethod::Nystrom,
        1 => {
            let sketch = match sketch_tag {
                0 => SketchKind::Gaussian,
                1 => SketchKind::Rademacher,
                _ => return Err(corrupt("unknown sketch code")),
            };
            LowRankMethod::RandomSig { depth, sketch }
        }
        _ => return Err(corrupt("unknown low-rank method code")),
    };
    let rank = c.usize()?;
    let seed = c.u64()?;
    Ok(LowRankSpec { method, rank, seed })
}

// ---------------------------------------------------------------------------
// Section bodies.

fn encode_paths(exp: &CorpusExport) -> Vec<u8> {
    let mut b = Buf::default();
    b.u64(exp.id as u64);
    b.usize(exp.dim);
    b.u64(exp.tick);
    b.u64(exp.hash);
    b.usize(exp.lengths.len());
    for &l in &exp.lengths {
        b.usize(l);
    }
    b.u64s(&exp.born);
    b.usize(exp.data.len());
    b.f64s(&exp.data);
    b.bytes
}

fn decode_paths(body: &[u8]) -> Result<CorpusExport, SigError> {
    let mut c = Cursor::new(body);
    let id = u32::try_from(c.u64()?).map_err(|_| corrupt("corpus id out of range"))?;
    let dim = c.usize()?;
    let tick = c.u64()?;
    let hash = c.u64()?;
    let n = c.usize()?;
    let lengths = c.usizes(n)?;
    let born = c.u64s(n)?;
    let values = c.usize()?;
    let data = c.f64s(values)?;
    if !c.done() {
        return Err(corrupt("path section has trailing bytes"));
    }
    Ok(CorpusExport {
        id,
        dim,
        tick,
        hash,
        lengths,
        born,
        data,
        exact: Vec::new(),
        lowrank: Vec::new(),
    })
}

fn put_border(b: &mut Buf, pb: &PairBorder) {
    let (bottom, right) = pb.parts();
    b.usize(bottom.len());
    b.f64s(bottom);
    b.usize(right.len());
    b.f64s(right);
}

fn get_border(c: &mut Cursor<'_>) -> Result<PairBorder, SigError> {
    let bl = c.usize()?;
    let bottom = c.f64s(bl)?;
    let rl = c.usize()?;
    let right = c.f64s(rl)?;
    PairBorder::from_parts(bottom, right)
        .map_err(|_| corrupt("border section violates the corner invariants"))
}

fn encode_exact(id: u32, ex: &ExactExport) -> Vec<u8> {
    let mut b = Buf::default();
    b.u64(id as u64);
    put_opts(&mut b, &ex.opts);
    b.usize(ex.kcc.len());
    b.f64s(&ex.kcc);
    b.usize(ex.borders.len());
    for bd in &ex.borders {
        b.usize(bd.i);
        b.usize(bd.j);
        put_border(&mut b, bd.border.fine());
        match bd.border.coarse() {
            Some(coarse) => {
                b.u64(1);
                put_border(&mut b, coarse);
            }
            None => b.u64(0),
        }
    }
    b.bytes
}

fn decode_exact(body: &[u8]) -> Result<(u32, ExactExport), SigError> {
    let mut c = Cursor::new(body);
    let id = u32::try_from(c.u64()?).map_err(|_| corrupt("corpus id out of range"))?;
    let opts = get_opts(&mut c)?;
    let kcc_len = c.usize()?;
    let kcc = c.f64s(kcc_len)?;
    let nb = c.usize()?;
    let mut borders = Vec::with_capacity(nb.min(1024));
    for _ in 0..nb {
        let i = c.usize()?;
        let j = c.usize()?;
        let fine = get_border(&mut c)?;
        let coarse = match c.u64()? {
            0 => None,
            1 => Some(get_border(&mut c)?),
            _ => return Err(corrupt("bad coarse-border flag")),
        };
        borders.push(BorderExport {
            i,
            j,
            border: SchemeBorder::from_parts(fine, coarse),
        });
    }
    if !c.done() {
        return Err(corrupt("exact section has trailing bytes"));
    }
    Ok((id, ExactExport { opts, kcc, borders }))
}

fn encode_lowrank(id: u32, lr: &LowRankExport) -> Vec<u8> {
    let mut b = Buf::default();
    b.u64(id as u64);
    put_opts(&mut b, &lr.opts);
    put_spec(&mut b, &lr.spec);
    b.usize(lr.pool);
    b.usize(lr.phi.len());
    b.f64s(&lr.phi);
    b.bytes
}

fn decode_lowrank(body: &[u8]) -> Result<(u32, LowRankExport), SigError> {
    let mut c = Cursor::new(body);
    let id = u32::try_from(c.u64()?).map_err(|_| corrupt("corpus id out of range"))?;
    let opts = get_opts(&mut c)?;
    let spec = get_spec(&mut c)?;
    let pool = c.usize()?;
    let phi_len = c.usize()?;
    let phi = c.f64s(phi_len)?;
    if !c.done() {
        return Err(corrupt("low-rank section has trailing bytes"));
    }
    Ok((
        id,
        LowRankExport {
            opts,
            spec,
            pool,
            phi,
        },
    ))
}

// ---------------------------------------------------------------------------
// Whole-file encode / decode.

fn encode_snapshot(exports: &[CorpusExport]) -> Vec<u8> {
    let mut sections: Vec<(u64, Vec<u8>)> = Vec::new();
    // Path sections first: the reader installs corpora before derived state.
    for exp in exports {
        sections.push((TAG_PATHS, encode_paths(exp)));
    }
    for exp in exports {
        for ex in &exp.exact {
            sections.push((TAG_EXACT, encode_exact(exp.id, ex)));
        }
        for lr in &exp.lowrank {
            sections.push((TAG_LOWRANK, encode_lowrank(exp.id, lr)));
        }
    }
    let mut out = Buf::default();
    out.u64(MAGIC);
    out.u64(VERSION);
    out.usize(sections.len());
    for (tag, body) in &sections {
        out.u64(*tag);
        out.usize(body.len());
        out.u64(fnv1a(body));
        out.bytes.extend_from_slice(body);
    }
    out.bytes
}

/// Decode snapshot bytes into per-corpus exports. Header problems and
/// corrupt path sections fail the load; corrupt derived sections (and
/// sections for unknown corpora or future tags) are silently dropped.
fn decode_snapshot(bytes: &[u8]) -> Result<Vec<CorpusExport>, SigError> {
    let mut c = Cursor::new(bytes);
    let magic = c
        .u64()
        .map_err(|_| corrupt("file too short for a snapshot header"))?;
    if magic != MAGIC {
        return Err(corrupt("bad magic — not a pysiglib corpus snapshot"));
    }
    let version = c.u64()?;
    if version != VERSION {
        return Err(SigError::SnapshotCorrupt(format!(
            "unsupported snapshot format version {version} (this build reads {VERSION})"
        )));
    }
    let count = c.usize()?;
    let mut raw: Vec<(u64, &[u8], bool)> = Vec::with_capacity(count.min(1024));
    for _ in 0..count {
        let tag = c.u64()?;
        let len = c.usize()?;
        let hash = c.u64()?;
        let body = c.take(len)?;
        raw.push((tag, body, fnv1a(body) == hash));
    }
    if !c.done() {
        return Err(corrupt("trailing bytes after the last section"));
    }
    let mut exports: Vec<CorpusExport> = Vec::new();
    for (_, body, hash_ok) in raw.iter().filter(|(tag, ..)| *tag == TAG_PATHS) {
        if !*hash_ok {
            return Err(corrupt("corpus path section failed its checksum"));
        }
        let exp = decode_paths(body)?;
        if exports.iter().any(|e| e.id == exp.id) {
            return Err(corrupt("duplicate corpus id across path sections"));
        }
        exports.push(exp);
    }
    for (tag, body, hash_ok) in raw {
        if !hash_ok || tag == TAG_PATHS {
            continue; // corrupt derived state: drop, rebuild lazily
        }
        match tag {
            TAG_EXACT => {
                if let Ok((id, ex)) = decode_exact(body) {
                    if let Some(e) = exports.iter_mut().find(|e| e.id == id) {
                        e.exact.push(ex);
                    }
                }
            }
            TAG_LOWRANK => {
                if let Ok((id, lr)) = decode_lowrank(body) {
                    if let Some(e) = exports.iter_mut().find(|e| e.id == id) {
                        e.lowrank.push(lr);
                    }
                }
            }
            _ => {} // a future writer's section kind: ignore
        }
    }
    Ok(exports)
}

/// Encode `exports` and write them atomically to `path` (same-directory
/// temp file, synced, then renamed). I/O failures are
/// [`SigError::Backend`]; nothing here panics.
pub(crate) fn write_snapshot(exports: &[CorpusExport], path: &Path) -> Result<(), SigError> {
    let mut bytes = encode_snapshot(exports);
    if let Some(cut) = crate::failpoint!("snapshot.torn_write") {
        bytes.truncate(cut as usize);
    }
    let io =
        |e: std::io::Error| SigError::Backend(format!("snapshot write {}: {e}", path.display()));
    let mut tmp_name = path
        .file_name()
        .map(|n| n.to_os_string())
        .unwrap_or_else(|| std::ffi::OsString::from("corpus.snapshot"));
    tmp_name.push(format!(".tmp.{}", std::process::id()));
    let tmp = path.with_file_name(tmp_name);
    {
        use std::io::Write;
        let mut f = std::fs::File::create(&tmp).map_err(io)?;
        f.write_all(&bytes).map_err(io)?;
        f.sync_all().map_err(io)?;
    }
    std::fs::rename(&tmp, path).map_err(io)
}

/// Read and decode a snapshot file. Missing/unreadable files are
/// [`SigError::Backend`]; malformed content is
/// [`SigError::SnapshotCorrupt`] (see [`decode_snapshot`] for what is fatal
/// versus dropped).
pub(crate) fn read_snapshot(path: &Path) -> Result<Vec<CorpusExport>, SigError> {
    let mut bytes = std::fs::read(path)
        .map_err(|e| SigError::Backend(format!("snapshot read {}: {e}", path.display())))?;
    if let Some(cut) = crate::failpoint!("snapshot.short_read") {
        bytes.truncate(cut as usize);
    }
    decode_snapshot(&bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_export() -> CorpusExport {
        let lengths = vec![3usize, 2];
        let data: Vec<f64> = (0..10).map(|v| v as f64 * 0.25).collect();
        CorpusExport {
            id: 7,
            dim: 2,
            tick: 1,
            hash: 0xdead_beef,
            lengths,
            born: vec![0, 1],
            data,
            exact: vec![ExactExport {
                opts: KernelOptions::default().dyadic(1, 1),
                kcc: vec![1.0, 0.5, 0.5, 1.0],
                borders: Vec::new(),
            }],
            lowrank: vec![LowRankExport {
                opts: KernelOptions::default(),
                spec: LowRankSpec::nystrom(2, 9),
                pool: 2,
                phi: vec![0.1, 0.2, 0.3, 0.4],
            }],
        }
    }

    #[test]
    fn encode_decode_round_trips() {
        let exp = sample_export();
        let bytes = encode_snapshot(&[exp]);
        let back = decode_snapshot(&bytes).unwrap();
        assert_eq!(back.len(), 1);
        let b = &back[0];
        assert_eq!(b.id, 7);
        assert_eq!(b.lengths, vec![3, 2]);
        assert_eq!(b.born, vec![0, 1]);
        assert_eq!(b.exact.len(), 1);
        assert_eq!(b.exact[0].kcc, vec![1.0, 0.5, 0.5, 1.0]);
        assert_eq!(b.exact[0].opts, KernelOptions::default().dyadic(1, 1));
        assert_eq!(b.lowrank.len(), 1);
        assert_eq!(b.lowrank[0].spec, LowRankSpec::nystrom(2, 9));
        assert_eq!(b.lowrank[0].phi, vec![0.1, 0.2, 0.3, 0.4]);
    }

    #[test]
    fn options_and_spec_encodings_round_trip_every_field() {
        let mut opts = KernelOptions::default()
            .dyadic(3, 2)
            .solver(SolverKind::Blocked)
            .scheme(Scheme::Order2)
            .target_eps(1e-4)
            .transform(Transform::LeadLagTimeAug);
        opts.exec.parallel = false;
        let mut b = Buf::default();
        put_opts(&mut b, &opts);
        let mut c = Cursor::new(&b.bytes);
        assert_eq!(get_opts(&mut c).unwrap(), opts);
        assert!(c.done());
        for spec in [
            LowRankSpec::nystrom(5, 11),
            LowRankSpec::random_sig(4, 3, 13),
            LowRankSpec {
                method: LowRankMethod::RandomSig {
                    depth: 2,
                    sketch: SketchKind::Gaussian,
                },
                rank: 6,
                seed: 17,
            },
        ] {
            let mut b = Buf::default();
            put_spec(&mut b, &spec);
            let mut c = Cursor::new(&b.bytes);
            assert_eq!(get_spec(&mut c).unwrap(), spec);
            assert!(c.done());
        }
    }

    #[test]
    fn bad_magic_and_version_are_typed_errors() {
        let exp = sample_export();
        let mut bytes = encode_snapshot(&[exp]);
        let good = bytes.clone();
        bytes[0] ^= 0xff;
        assert!(matches!(
            decode_snapshot(&bytes),
            Err(SigError::SnapshotCorrupt(_))
        ));
        let mut vbad = good.clone();
        vbad[8] = 99;
        assert!(matches!(
            decode_snapshot(&vbad),
            Err(SigError::SnapshotCorrupt(_))
        ));
        assert!(decode_snapshot(&good).is_ok());
    }

    #[test]
    fn every_truncation_point_is_a_typed_error_or_a_clean_drop() {
        let exp = sample_export();
        let bytes = encode_snapshot(&[exp]);
        for cut in 0..bytes.len() {
            match decode_snapshot(&bytes[..cut]) {
                Err(SigError::SnapshotCorrupt(_)) => {}
                Err(e) => panic!("cut at {cut}: unexpected error kind {e}"),
                Ok(_) => panic!("cut at {cut}: truncated snapshot decoded"),
            }
        }
    }

    #[test]
    fn corrupt_derived_sections_drop_but_corrupt_paths_fail() {
        let exp = sample_export();
        let bytes = encode_snapshot(&[exp]);
        // Flip one byte at every offset: the decode must either succeed with
        // derived state possibly dropped, or fail with the typed error —
        // never panic, never mis-decode a checksummed section.
        for at in 0..bytes.len() {
            let mut b = bytes.clone();
            b[at] ^= 0x01;
            match decode_snapshot(&b) {
                Ok(exports) => {
                    // A flip that decodes cleanly must not have touched the
                    // (checksummed) path payload.
                    if let Some(e) = exports.first() {
                        assert_eq!(e.lengths, vec![3, 2], "flip at {at}");
                    }
                }
                Err(SigError::SnapshotCorrupt(_)) => {}
                Err(e) => panic!("flip at {at}: unexpected error kind {e}"),
            }
        }
        // A flip inside the exact-cache body specifically: load succeeds,
        // derived state is gone, paths intact.
        let paths_body = encode_paths(&sample_export());
        let header = 3 * 8; // magic, version, count
        let sec_hdr = 3 * 8; // tag, len, hash
        let exact_at = header + sec_hdr + paths_body.len() + sec_hdr + 12;
        let mut b = bytes.clone();
        b[exact_at] ^= 0xff;
        let exports = decode_snapshot(&b).unwrap();
        assert_eq!(exports.len(), 1);
        assert!(exports[0].exact.is_empty(), "corrupt exact section dropped");
        assert_eq!(exports[0].lowrank.len(), 1, "other sections survive");
    }

    #[test]
    fn derived_sections_for_unknown_corpora_are_dropped() {
        let mut exp = sample_export();
        let stray = encode_exact(99, &exp.exact[0]);
        exp.exact.clear();
        exp.lowrank.clear();
        let mut bytes = Buf::default();
        bytes.u64(MAGIC);
        bytes.u64(VERSION);
        bytes.usize(2);
        let paths = encode_paths(&exp);
        for body in [&paths, &stray] {
            bytes.u64(if std::ptr::eq(body, &paths) { TAG_PATHS } else { TAG_EXACT });
            bytes.usize(body.len());
            bytes.u64(fnv1a(body));
            bytes.bytes.extend_from_slice(body);
        }
        let exports = decode_snapshot(&bytes.bytes).unwrap();
        assert_eq!(exports.len(), 1);
        assert!(exports[0].exact.is_empty());
    }

    #[test]
    fn torn_write_failpoint_truncates_and_restore_rejects() {
        let _g = crate::util::failpoint::serial_guard();
        let dir = std::env::temp_dir().join(format!("pysiglib-persist-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let file = dir.join("torn.snapshot");
        crate::util::failpoint::arm("snapshot.torn_write", 40);
        write_snapshot(&[sample_export()], &file).unwrap();
        crate::util::failpoint::disarm("snapshot.torn_write");
        assert_eq!(std::fs::metadata(&file).unwrap().len(), 40);
        assert!(matches!(
            read_snapshot(&file),
            Err(SigError::SnapshotCorrupt(_))
        ));
        // A clean rewrite replaces the torn file atomically.
        write_snapshot(&[sample_export()], &file).unwrap();
        assert_eq!(read_snapshot(&file).unwrap().len(), 1);
        // Short reads are typed errors too.
        crate::util::failpoint::arm("snapshot.short_read", 16);
        assert!(matches!(
            read_snapshot(&file),
            Err(SigError::SnapshotCorrupt(_))
        ));
        crate::util::failpoint::disarm("snapshot.short_read");
        std::fs::remove_dir_all(&dir).ok();
    }
}
