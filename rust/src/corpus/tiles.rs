//! Cache-sized tile scheduler for Gram blocks, lane-batched inside each
//! tile.
//!
//! The engine's Gram op parallelises per row strip: workers claim strips of
//! one x-row, so consecutive claims touch unrelated rows of x and columns
//! of y and the path data is re-streamed from memory for every solve. This
//! scheduler shards the same work into `tile × tile` blocks: within a block
//! one worker solves every pair over a small, cache-resident set of paths,
//! and blocks (not entries) are what the atomic cursor hands out — far
//! fewer claims, far better locality, identical values. Inside each tile
//! row the [`lanes`](crate::kernel::lanes) engine groups same-shape columns
//! into lane groups of W and sweeps W kernels per pass (one stacked GEMM +
//! one SoA PDE sweep per group), with a scalar remainder.
//!
//! **Bit-identity.** Each Gram entry is an independent computation
//! (Δ matrix via [`delta_matrix_into`](crate::kernel::delta::delta_matrix_into),
//! then the Goursat sweep) whose value does not depend on which worker,
//! tile or lane computed it — every lane runs the scalar FP sequence — so
//! the tiled, lane-batched Gram is bit-for-bit identical to the engine's
//! strip path and to a single-threaded loop, regardless of
//! `PYSIGLIB_THREADS`, `PYSIGLIB_TILE` and `PYSIGLIB_LANES` (asserted by
//! the property tests). This is also what makes the registry's incremental
//! append sound: a cross block computed later is exactly the block a
//! from-scratch Gram would have produced.
//!
//! Block support ([`TileScheduler::gram_block_into`]) is the piece the
//! strip path lacks: an append to a registered corpus computes only the
//! old×new cross strips and the new diagonal block of the cached self-Gram,
//! writing into the enlarged matrix at an arbitrary offset and stride.

use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};

use crate::kernel::lanes::{self, LaneScratch};
use crate::kernel::KernelOptions;
use crate::path::{PathBatch, SigError};
use crate::util::pool::num_threads;

/// Default tile edge: 16 × 16 = 256 PDE solves per claim — large enough to
/// amortise the cursor, small enough that both path sets stay cache-hot.
const DEFAULT_TILE: usize = 16;

/// Shards Gram work into `tile × tile` blocks over the thread pool and
/// dispatches lane groups inside each tile.
#[derive(Clone, Copy, Debug)]
pub struct TileScheduler {
    tile: usize,
    /// Lane width override (`PYSIGLIB_LANES` / [`with_lanes`]); `None`
    /// picks the per-block default (8 for uniform batches, 4 for ragged).
    ///
    /// [`with_lanes`]: TileScheduler::with_lanes
    lanes: Option<usize>,
}

impl Default for TileScheduler {
    fn default() -> Self {
        TileScheduler::from_env()
    }
}

impl TileScheduler {
    /// Tile edge from `PYSIGLIB_TILE` (entries per side, default 16) and
    /// lane width from `PYSIGLIB_LANES` (0 = scalar; unset = per-block
    /// default). Both knobs are read once per process and cached (see
    /// [`crate::config::env`]).
    pub fn from_env() -> TileScheduler {
        let tile = crate::config::env::tile().unwrap_or(DEFAULT_TILE);
        TileScheduler {
            tile,
            lanes: lanes::lane_width_override(),
        }
    }

    /// Explicit tile edge (at least 1); lane width stays the environment /
    /// default choice.
    pub fn with_tile(tile: usize) -> TileScheduler {
        TileScheduler {
            tile: tile.max(1),
            lanes: lanes::lane_width_override(),
        }
    }

    /// Pin the lane width (snapped to 0/4/8). Values are bit-identical for
    /// every width — this is a scheduling knob for tests, benches and the
    /// CLI.
    pub fn with_lanes(mut self, width: usize) -> TileScheduler {
        self.lanes = Some(lanes::normalize_lane_width(width));
        self
    }

    /// The tile edge in Gram entries.
    pub fn tile(&self) -> usize {
        self.tile
    }

    /// The pinned lane width, if any.
    pub fn lane_width(&self) -> Option<usize> {
        self.lanes
    }

    /// Full Gram: `out` is `[x.batch(), y.batch()]` row-major, filled with
    /// k(x_i, y_j) for every pair.
    pub fn gram_into(
        &self,
        x: &PathBatch<'_>,
        y: &PathBatch<'_>,
        opts: &KernelOptions,
        out: &mut [f64],
    ) -> Result<(), SigError> {
        let cols = y.batch();
        self.gram_block_into(x, 0..x.batch(), y, 0..y.batch(), opts, out, cols, 0, 0)
    }

    /// Gram sub-block: k(x_i, y_j) for `i ∈ xr`, `j ∈ yr`, written into the
    /// larger matrix `out` (row stride `out_cols`) at origin `(row0, col0)`
    /// — i.e. entry (i, j) lands at `out[(row0 + i - xr.start) * out_cols +
    /// col0 + (j - yr.start)]`. This is the incremental-append primitive:
    /// only the new strips of an enlarged corpus self-Gram are computed.
    #[allow(clippy::too_many_arguments)]
    pub fn gram_block_into(
        &self,
        x: &PathBatch<'_>,
        xr: Range<usize>,
        y: &PathBatch<'_>,
        yr: Range<usize>,
        opts: &KernelOptions,
        out: &mut [f64],
        out_cols: usize,
        row0: usize,
        col0: usize,
    ) -> Result<(), SigError> {
        if x.dim() != y.dim() {
            return Err(SigError::DimMismatch {
                left: x.dim(),
                right: y.dim(),
            });
        }
        if xr.end > x.batch() || yr.end > y.batch() {
            return Err(SigError::Invalid("tile range exceeds the batch"));
        }
        let (nr, nc) = (xr.len(), yr.len());
        if nr == 0 || nc == 0 {
            return Ok(());
        }
        if col0 + nc > out_cols || (row0 + nr) * out_cols > out.len() {
            return Err(SigError::Invalid("tile block exceeds the output buffer"));
        }
        // The longest pair bounds every pair's refined grid (monotone), so
        // per-pair solves below cannot fail.
        let mx = xr.clone().map(|i| x.len_of(i)).max().unwrap_or(0);
        let my = yr.clone().map(|j| y.len_of(j)).max().unwrap_or(0);
        if mx >= 2 && my >= 2 {
            crate::kernel::check_grid_size(mx, my, opts)?;
        }
        // Blocked-solver requests run the scalar schedule — width 0 keeps
        // the per-worker scratch scalar-sized too.
        let width = if opts.solver == crate::kernel::SolverKind::Blocked {
            0
        } else {
            self.lanes.unwrap_or_else(|| {
                lanes::default_lane_width(x.uniform_len().is_some() && y.uniform_len().is_some())
            })
        };
        let tiles_x = nr.div_ceil(self.tile);
        let tiles_y = nc.div_ceil(self.tile);
        let n_tiles = tiles_x * tiles_y;
        let workers = if opts.exec.parallel {
            num_threads().min(n_tiles)
        } else {
            1
        };
        let base = out.as_mut_ptr() as usize;
        let run_tile = |t: usize, sc: &mut LaneScratch| {
            let (bx, by) = (t / tiles_y, t % tiles_y);
            let i_lo = xr.start + bx * self.tile;
            let i_hi = (i_lo + self.tile).min(xr.end);
            let j_lo = yr.start + by * self.tile;
            let j_hi = (j_lo + self.tile).min(yr.end);
            for i in i_lo..i_hi {
                let orow = row0 + (i - xr.start);
                // SAFETY: this tile owns exactly the entries
                // [orow * out_cols + col0 + (j_lo - yr.start) ..
                //  .. + (j_hi - j_lo)); tiles partition the (i, j) index
                // space, so writes are disjoint, and `out` outlives the
                // scope below.
                let row = unsafe {
                    std::slice::from_raw_parts_mut(
                        (base as *mut f64).add(orow * out_cols + col0 + (j_lo - yr.start)),
                        j_hi - j_lo,
                    )
                };
                lanes::solve_gram_row(x, i, y, j_lo..j_hi, opts, width, sc, row);
            }
            lanes::count_tile();
        };
        if workers <= 1 {
            let mut sc = LaneScratch::new();
            for t in 0..n_tiles {
                run_tile(t, &mut sc);
            }
            return Ok(());
        }
        let cursor = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for _ in 0..workers {
                let cursor = &cursor;
                let run_tile = &run_tile;
                scope.spawn(move || {
                    let mut sc = LaneScratch::new();
                    loop {
                        let t = cursor.fetch_add(1, Ordering::Relaxed);
                        if t >= n_tiles {
                            break;
                        }
                        run_tile(t, &mut sc);
                    }
                });
            }
        });
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::{try_gram, KernelOptions, SolverKind};
    use crate::transforms::Transform;
    use crate::util::rng::Rng;

    fn ragged_batch(rng: &mut Rng, lens: &[usize], d: usize) -> (Vec<f64>, Vec<usize>) {
        let mut data = Vec::new();
        for &l in lens {
            data.extend(rng.brownian_path(l, d, 0.4));
        }
        (data, lens.to_vec())
    }

    #[test]
    fn tiled_gram_bit_matches_engine_gram() {
        let mut rng = Rng::new(600);
        let d = 2;
        let (xd, xl) = ragged_batch(&mut rng, &[5, 1, 8, 3, 6, 7, 2, 9, 4], d);
        let (yd, yl) = ragged_batch(&mut rng, &[4, 6, 1, 7, 5], d);
        let xb = PathBatch::ragged(&xd, &xl, d).unwrap();
        let yb = PathBatch::ragged(&yd, &yl, d).unwrap();
        for opts in [
            KernelOptions::default(),
            KernelOptions::default().dyadic(1, 2),
            KernelOptions::default().transform(Transform::TimeAug),
            KernelOptions::default().transform(Transform::LeadLag),
            KernelOptions::default().solver(SolverKind::Blocked),
        ] {
            let want = try_gram(&xb, &yb, &opts).unwrap();
            for tile in [1usize, 2, 4, 64] {
                for lanes in [0usize, 4, 8] {
                    let mut got = vec![0.0; xb.batch() * yb.batch()];
                    TileScheduler::with_tile(tile)
                        .with_lanes(lanes)
                        .gram_into(&xb, &yb, &opts, &mut got)
                        .unwrap();
                    assert_eq!(got, want, "tile={tile} lanes={lanes} opts={opts:?}");
                }
            }
        }
    }

    #[test]
    fn block_fill_equals_full_fill() {
        let mut rng = Rng::new(601);
        let d = 3;
        let (xd, xl) = ragged_batch(&mut rng, &[4, 5, 6, 7, 3, 8], d);
        let xb = PathBatch::ragged(&xd, &xl, d).unwrap();
        let opts = KernelOptions::default();
        let n = xb.batch();
        let sched = TileScheduler::with_tile(2);
        let mut full = vec![0.0; n * n];
        sched.gram_into(&xb, &xb, &opts, &mut full).unwrap();
        // Rebuild the same matrix from four blocks split at s.
        let s = 4;
        let mut parts = vec![0.0; n * n];
        sched
            .gram_block_into(&xb, 0..s, &xb, 0..s, &opts, &mut parts, n, 0, 0)
            .unwrap();
        sched
            .gram_block_into(&xb, 0..s, &xb, s..n, &opts, &mut parts, n, 0, s)
            .unwrap();
        sched
            .gram_block_into(&xb, s..n, &xb, 0..n, &opts, &mut parts, n, s, 0)
            .unwrap();
        assert_eq!(parts, full);
    }

    #[test]
    fn block_bounds_are_validated() {
        let data = vec![0.0; 4 * 3 * 2];
        let xb = PathBatch::uniform(&data, 4, 3, 2).unwrap();
        let opts = KernelOptions::default();
        let sched = TileScheduler::from_env();
        let mut out = vec![0.0; 4];
        // Range beyond the batch.
        assert!(sched
            .gram_block_into(&xb, 0..5, &xb, 0..1, &opts, &mut out, 1, 0, 0)
            .is_err());
        // Output too small for the block.
        assert!(sched
            .gram_block_into(&xb, 0..4, &xb, 0..4, &opts, &mut out, 4, 0, 0)
            .is_err());
        // Degenerate empty range is a no-op.
        assert!(sched
            .gram_block_into(&xb, 2..2, &xb, 0..4, &opts, &mut out, 4, 0, 0)
            .is_ok());
    }

    #[test]
    fn tile_counter_moves_when_tiles_run() {
        let before = lanes::stats().tiles_executed;
        let mut rng = Rng::new(602);
        let data = rng.brownian_batch(6, 5, 2, 0.4);
        let xb = PathBatch::uniform(&data, 6, 5, 2).unwrap();
        let mut out = vec![0.0; 36];
        TileScheduler::with_tile(3)
            .gram_into(&xb, &xb, &KernelOptions::default(), &mut out)
            .unwrap();
        assert!(lanes::stats().tiles_executed >= before + 4, "2×2 tile grid");
    }
}
