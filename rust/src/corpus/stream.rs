//! Streaming corpus subsystem: sliding-window corpora and a live drift
//! monitor on top of the registry's border-strip path extension.
//!
//! **Cost model.** A static corpus pays O(n²·L²) once at registration and
//! O(q·n·L²) per warm query. Streaming changes the write side:
//! [`CorpusRegistry::extend_path`] appends `L_new` points to one registered
//! path and advances only the right/bottom **border strips** of the 2n−1
//! affected Goursat grids — `O(n·L_new·L)` cells per extension (after a
//! one-time full retaining solve per pair, paid on the first extension)
//! instead of the `O(n·L²)` a re-registration would re-solve. See
//! [`crate::kernel::border`] for the strip recurrence and the bit-identity
//! argument; `cargo run -- corpus watch` demos the counters.
//!
//! **Window and decay knobs.** [`SlidingCorpus`] keeps ring-buffer
//! semantics over a registered corpus: pushing past `capacity` — or past a
//! path's `max_age` in pushes — evicts the oldest paths
//! ([`CorpusRegistry::evict`]), shrinking every cached Gram/feature matrix
//! to the surviving suffix. [`DriftMonitor`] scores a rolling window of
//! live paths against a *reference* corpus with the exponentially-weighted
//! MMD² ([`CorpusRegistry::mmd2_window`]): the newest window path has
//! weight 1 and each older one decays by `decay ∈ (0, 1]`, so the score
//! tracks the present without forgetting the window outright. The monitor
//! raises `alarm` whenever the weighted MMD² exceeds its threshold.
//!
//! Per-point arrivals route through the shared
//! [`StreamingSignature`](crate::sig::stream::StreamingSignature) helper
//! ([`DriftMonitor::observe_point`]), so a monitor can also expose the live
//! path's running signature between window closes.

use std::collections::VecDeque;
use std::sync::Arc;

use crate::corpus::{CorpusId, CorpusRegistry};
use crate::kernel::KernelOptions;
use crate::path::{PathBatch, SigError};
use crate::sig::stream::StreamingSignature;

/// Ring-buffer window over a registered corpus: pushes past `capacity` (or
/// past `max_age` pushes) evict the oldest paths through
/// [`CorpusRegistry::evict`], so cached Gram/feature state always matches a
/// from-scratch registration of the surviving suffix.
pub struct SlidingCorpus {
    registry: Arc<CorpusRegistry>,
    id: CorpusId,
    capacity: usize,
    max_age: Option<u64>,
    /// Monotone push counter; per-path birth stamps drive age eviction.
    ticks: u64,
    born: VecDeque<u64>,
}

impl SlidingCorpus {
    /// Register `seed` as the initial window contents (all stamped at tick
    /// 0) and trim it to `capacity`. `capacity` must be at least 1.
    pub fn try_new(
        registry: Arc<CorpusRegistry>,
        seed: &PathBatch<'_>,
        capacity: usize,
        max_age: Option<u64>,
    ) -> Result<SlidingCorpus, SigError> {
        if capacity == 0 {
            return Err(SigError::Invalid("sliding corpus capacity must be at least 1"));
        }
        let id = registry.register(seed)?;
        let n = registry
            .path_count(id)
            .ok_or(SigError::Invalid("sliding corpus vanished at registration"))?;
        let mut sc = SlidingCorpus {
            registry,
            id,
            capacity,
            max_age,
            ticks: 0,
            born: (0..n).map(|_| 0).collect(),
        };
        sc.trim()?;
        Ok(sc)
    }

    /// The underlying registered corpus id (usable with every registry
    /// query).
    pub fn id(&self) -> CorpusId {
        self.id
    }

    /// Live paths in the window.
    pub fn len(&self) -> usize {
        self.born.len()
    }

    pub fn is_empty(&self) -> bool {
        self.born.is_empty()
    }

    /// Push one flat `[len, dim]` path into the window, evicting by
    /// capacity/age. Returns the live path count.
    pub fn push(&mut self, path: &[f64], len: usize) -> Result<usize, SigError> {
        let dim = self
            .registry
            .dim_of(self.id)
            .ok_or(SigError::Invalid("sliding corpus id is no longer registered"))?;
        let lens = [len];
        let pb = PathBatch::ragged(path, &lens, dim)?;
        self.registry.append(self.id, &pb)?;
        self.ticks += 1;
        self.born.push_back(self.ticks);
        self.trim()
    }

    /// Stream points into the *newest* window path in place (the live,
    /// still-open path) via the registry's border-strip extension.
    /// Returns the path's new length.
    pub fn extend_newest(&mut self, points: &[f64]) -> Result<usize, SigError> {
        let n = self.born.len();
        if n == 0 {
            return Err(SigError::Invalid("sliding corpus has no path to extend"));
        }
        self.registry.extend_path(self.id, n - 1, points)
    }

    /// Evict to the capacity/age policy; the registry always keeps at
    /// least the newest path.
    fn trim(&mut self) -> Result<usize, SigError> {
        let n = self.born.len();
        let mut keep = n.min(self.capacity);
        if let Some(age) = self.max_age {
            let fresh = self
                .born
                .iter()
                .filter(|&&b| self.ticks.saturating_sub(b) <= age)
                .count();
            keep = keep.min(fresh.max(1));
        }
        if keep < n {
            self.registry.evict(self.id, keep)?;
            while self.born.len() > keep {
                self.born.pop_front();
            }
        }
        Ok(self.born.len())
    }
}

/// One drift observation: the weighted window MMD² against the reference
/// corpus, whether it crossed the monitor's threshold, and the live window
/// size that produced it.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DriftSample {
    pub mmd2: f64,
    pub alarm: bool,
    pub window_len: usize,
}

/// Rolling MMD²(live window, reference corpus) with a threshold alarm.
///
/// Completed paths slide through a ring window of `capacity` paths and are
/// scored with [`CorpusRegistry::mmd2_window`] (newest path weight 1, each
/// older path decayed by `decay`). Points of the still-open path stream
/// through the shared [`StreamingSignature`] accumulator, whose running
/// signature is observable between window closes.
pub struct DriftMonitor {
    registry: Arc<CorpusRegistry>,
    reference: CorpusId,
    opts: KernelOptions,
    dim: usize,
    capacity: usize,
    decay: f64,
    threshold: f64,
    window: VecDeque<Vec<f64>>,
    pending: Vec<f64>,
    live: StreamingSignature,
    samples: u64,
}

impl DriftMonitor {
    /// `reference` must be registered in `registry`; `capacity` is the
    /// window size in paths (≥ 1); `decay ∈ (0, 1]` weights the window;
    /// a sample alarms when its weighted MMD² exceeds `threshold`.
    /// `sig_depth` sizes the live-path signature accumulator.
    #[allow(clippy::too_many_arguments)]
    pub fn try_new(
        registry: Arc<CorpusRegistry>,
        reference: CorpusId,
        opts: KernelOptions,
        capacity: usize,
        decay: f64,
        threshold: f64,
        sig_depth: usize,
    ) -> Result<DriftMonitor, SigError> {
        if capacity == 0 {
            return Err(SigError::Invalid("drift monitor window capacity must be at least 1"));
        }
        if !decay.is_finite() || decay <= 0.0 || decay > 1.0 {
            return Err(SigError::Invalid("window decay must lie in (0, 1]"));
        }
        if !threshold.is_finite() {
            return Err(SigError::Invalid("drift threshold must be finite"));
        }
        let dim = registry
            .dim_of(reference)
            .ok_or(SigError::Invalid("drift monitor: unknown reference corpus id"))?;
        if dim == 0 {
            return Err(SigError::Invalid("drift monitor: reference corpus has zero dim"));
        }
        let live = StreamingSignature::try_new(dim, sig_depth)?;
        Ok(DriftMonitor {
            registry,
            reference,
            opts,
            dim,
            capacity,
            decay,
            threshold,
            window: VecDeque::new(),
            pending: Vec::new(),
            live,
            samples: 0,
        })
    }

    /// Feed one point of the live path. Routed through the shared
    /// [`StreamingSignature`] helper, so [`live_signature`]
    /// (DriftMonitor::live_signature) stays current point by point.
    pub fn observe_point(&mut self, point: &[f64]) -> Result<(), SigError> {
        self.live.try_push(point)?;
        self.pending.extend_from_slice(point);
        Ok(())
    }

    /// Close the live path: slide it into the window, score the window
    /// against the reference, and reset the live accumulator.
    pub fn complete_path(&mut self) -> Result<DriftSample, SigError> {
        let flat = std::mem::take(&mut self.pending);
        let len = flat.len() / self.dim;
        if len < 2 {
            self.pending = flat;
            return Err(SigError::Invalid("a drift window path needs at least two points"));
        }
        self.window.push_back(flat);
        while self.window.len() > self.capacity {
            self.window.pop_front();
        }
        self.live.reset();
        self.score()
    }

    /// Observe one completed flat `[len, dim]` path: every point streams
    /// through [`observe_point`](DriftMonitor::observe_point), then the
    /// path closes and the window is scored.
    pub fn observe(&mut self, path: &[f64], len: usize) -> Result<DriftSample, SigError> {
        if path.len() != len * self.dim {
            return Err(SigError::Invalid("drift observe: path shape mismatch"));
        }
        for point in path.chunks(self.dim) {
            self.observe_point(point)?;
        }
        self.complete_path()
    }

    /// Running signature of the still-open live path.
    pub fn live_signature(&self) -> &[f64] {
        self.live.signature()
    }

    /// Completed paths currently in the window.
    pub fn window_len(&self) -> usize {
        self.window.len()
    }

    /// Drift samples produced so far.
    pub fn samples(&self) -> u64 {
        self.samples
    }

    fn score(&mut self) -> Result<DriftSample, SigError> {
        let mut data = Vec::new();
        let mut lens = Vec::with_capacity(self.window.len());
        for flat in &self.window {
            data.extend_from_slice(flat);
            lens.push(flat.len() / self.dim);
        }
        let q = PathBatch::ragged(&data, &lens, self.dim)?;
        let mmd2 = self
            .registry
            .mmd2_window(self.reference, &q, &self.opts, self.decay)?;
        self.samples += 1;
        Ok(DriftSample {
            mmd2,
            alarm: mmd2 > self.threshold,
            window_len: lens.len(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn arc_registry() -> Arc<CorpusRegistry> {
        Arc::new(CorpusRegistry::new())
    }

    #[test]
    fn sliding_capacity_eviction_matches_suffix_registration() {
        let reg = arc_registry();
        let mut rng = Rng::new(710);
        let (l, d) = (6, 2);
        let seed_data = rng.brownian_batch(2, l, d, 0.3);
        let seed = PathBatch::uniform(&seed_data, 2, l, d).unwrap();
        let mut sc = SlidingCorpus::try_new(reg.clone(), &seed, 3, None).unwrap();
        assert_eq!(sc.len(), 2);
        let mut pushed: Vec<Vec<f64>> = vec![
            seed_data[..l * d].to_vec(),
            seed_data[l * d..].to_vec(),
        ];
        for _ in 0..4 {
            let p = rng.brownian_path(l, d, 0.3);
            sc.push(&p, l).unwrap();
            pushed.push(p);
        }
        assert_eq!(sc.len(), 3, "capacity bounds the window");
        // The live corpus answers exactly like a fresh registration of the
        // last three pushed paths.
        let tail: Vec<f64> = pushed[pushed.len() - 3..].concat();
        let want_b = PathBatch::uniform(&tail, 3, l, d).unwrap();
        let fresh = arc_registry();
        let fid = fresh.register(&want_b).unwrap();
        let qdata = rng.brownian_batch(2, l, d, 0.3);
        let q = PathBatch::uniform(&qdata, 2, l, d).unwrap();
        let opts = KernelOptions::default();
        assert_eq!(
            reg.mmd2_query(sc.id(), &q, &opts, None).unwrap(),
            fresh.mmd2_query(fid, &q, &opts, None).unwrap()
        );
    }

    #[test]
    fn age_eviction_expires_stale_paths() {
        let reg = arc_registry();
        let mut rng = Rng::new(711);
        let (l, d) = (5, 2);
        let seed_data = rng.brownian_batch(1, l, d, 0.3);
        let seed = PathBatch::uniform(&seed_data, 1, l, d).unwrap();
        // Large capacity, but paths expire after 1 push of age.
        let mut sc = SlidingCorpus::try_new(reg.clone(), &seed, 16, Some(1)).unwrap();
        for _ in 0..3 {
            let p = rng.brownian_path(l, d, 0.3);
            sc.push(&p, l).unwrap();
        }
        // Only paths born within the last push survive (plus the newest).
        assert!(sc.len() <= 2, "age policy keeps the window fresh: {}", sc.len());
        assert_eq!(reg.path_count(sc.id()), Some(sc.len()));
    }

    #[test]
    fn drift_monitor_alarms_on_distribution_shift() {
        let reg = arc_registry();
        let mut rng = Rng::new(712);
        let (n, l, d) = (6, 8, 2);
        let ref_data = rng.brownian_batch(n, l, d, 0.2);
        let rb = PathBatch::uniform(&ref_data, n, l, d).unwrap();
        let id = reg.register(&rb).unwrap();
        let opts = KernelOptions::default();
        let mut mon =
            DriftMonitor::try_new(reg.clone(), id, opts, 3, 0.9, 1e-3, 3).unwrap();
        // In-distribution traffic: same generator scale.
        let mut calm = 0.0;
        for _ in 0..3 {
            let p = rng.brownian_path(l, d, 0.2);
            calm = mon.observe(&p, l).unwrap().mmd2;
        }
        // Drifted traffic: a strong deterministic trend.
        let mut s = DriftSample { mmd2: 0.0, alarm: false, window_len: 0 };
        for _ in 0..3 {
            let p: Vec<f64> = (0..l * d).map(|i| (i as f64) * 0.9).collect();
            s = mon.observe(&p, l).unwrap();
        }
        assert!(s.mmd2 > calm, "drift must raise the score: {} vs {calm}", s.mmd2);
        assert!(s.alarm, "drifted window must alarm (mmd2 = {})", s.mmd2);
        assert_eq!(s.window_len, 3);
        assert_eq!(mon.samples(), 6);
    }

    #[test]
    fn per_point_mode_matches_whole_path_observe_and_tracks_live_signature() {
        let reg = arc_registry();
        let mut rng = Rng::new(713);
        let (n, l, d) = (4, 6, 2);
        let ref_data = rng.brownian_batch(n, l, d, 0.3);
        let rb = PathBatch::uniform(&ref_data, n, l, d).unwrap();
        let id = reg.register(&rb).unwrap();
        let opts = KernelOptions::default();
        let depth = 3;
        let mut a = DriftMonitor::try_new(reg.clone(), id, opts, 2, 0.8, 0.5, depth).unwrap();
        let mut b = DriftMonitor::try_new(reg.clone(), id, opts, 2, 0.8, 0.5, depth).unwrap();
        let p = rng.brownian_path(l, d, 0.3);
        let whole = a.observe(&p, l).unwrap();
        for pt in p.chunks(d) {
            b.observe_point(pt).unwrap();
        }
        // Live signature mid-path equals the streaming signature of the
        // same points.
        let mut sref = StreamingSignature::new(d, depth);
        for pt in p.chunks(d) {
            sref.push(pt);
        }
        assert_eq!(b.live_signature(), sref.signature());
        let pointwise = b.complete_path().unwrap();
        assert_eq!(whole, pointwise, "per-point mode must match observe()");
        // After closing, the live accumulator restarts.
        assert!(a.live_signature()[1..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn constructor_and_shape_validation() {
        let reg = arc_registry();
        let mut rng = Rng::new(714);
        let data = rng.brownian_batch(2, 5, 2, 0.3);
        let pb = PathBatch::uniform(&data, 2, 5, 2).unwrap();
        let id = reg.register(&pb).unwrap();
        let opts = KernelOptions::default();
        assert!(SlidingCorpus::try_new(reg.clone(), &pb, 0, None).is_err());
        assert!(DriftMonitor::try_new(reg.clone(), id, opts, 0, 0.9, 0.1, 3).is_err());
        assert!(DriftMonitor::try_new(reg.clone(), id, opts, 2, 0.0, 0.1, 3).is_err());
        assert!(DriftMonitor::try_new(reg.clone(), id, opts, 2, 1.5, 0.1, 3).is_err());
        assert!(DriftMonitor::try_new(reg.clone(), CorpusId(999), opts, 2, 0.9, 0.1, 3).is_err());
        let mut mon = DriftMonitor::try_new(reg.clone(), id, opts, 2, 0.9, 0.1, 3).unwrap();
        assert!(mon.observe(&[0.0; 7], 3).is_err(), "ragged flat length");
        assert!(mon.complete_path().is_err(), "empty live path cannot close");
        mon.observe_point(&[0.0, 0.0]).unwrap();
        assert!(mon.complete_path().is_err(), "one-point path cannot close");
        // The pending point is kept: adding a second point closes cleanly.
        mon.observe_point(&[1.0, 1.0]).unwrap();
        assert!(mon.complete_path().is_ok());
    }
}
