//! Corpus service: register a reference corpus once, query it repeatedly,
//! append to it incrementally — the stateful serving layer on top of the
//! compile-once [`engine`](crate::engine).
//!
//! The practical regime for signature-kernel serving (KSig-style workloads)
//! is a large, mostly-static **reference corpus** queried again and again:
//! MMD² two-sample tests of fresh batches against a training corpus,
//! cross-Grams of queries against a support set. Recomputing the full
//! O(n²·L²) corpus-side work per request throws away everything the
//! previous request learned. This module splits the cost:
//!
//! * [`CorpusRegistry`] — owns registered corpora under stable
//!   [`CorpusId`]s (content-hash deduplicated) and, per kernel options
//!   actually queried, the derived state that dominates re-query cost: the
//!   corpus self-Gram `K_cc` for exact MMD², and the frozen
//!   [`FeatureMap`](crate::kernel::FeatureMap) + corpus feature matrix
//!   `Φ_c` for low-rank queries. A **warm** query pays only for its own
//!   rows (`K_qq`, `K_qc`, or `Φ_q`).
//! * [`TileScheduler`] — shards Gram work into cache-sized `tile × tile`
//!   blocks over the crate's thread pool
//!   ([`util::pool`](crate::util::pool), worker count from
//!   `PYSIGLIB_THREADS`). Each entry is an independent PDE solve, so the
//!   tiled Gram is bit-for-bit identical to the single-threaded and
//!   per-entry paths; tiles add locality and the *block* primitive that
//!   incremental appends are built on.
//! * **Incremental append** — [`CorpusRegistry::append`] extends the
//!   cached state in place: only the old×new cross strips and the new
//!   diagonal block of `K_cc` are solved, and only the new paths are
//!   featurised into `Φ_c`. The result is bit-identical to registering the
//!   combined corpus from scratch (property-tested); the Nyström landmark
//!   draw is pinned by the corpus's landmark pool (first `min(rank, n)`
//!   paths) so appends cannot move it once the corpus covers the rank
//!   budget.
//!
//! * **Streaming** ([`stream`]) — [`CorpusRegistry::extend_path`] appends
//!   points to one registered path by advancing Goursat **border strips**
//!   (`O(L_new·L)` cells per affected pair, see
//!   [`kernel::border`](crate::kernel::border)) instead of re-solving full
//!   grids; [`CorpusRegistry::evict`] gives sliding-window semantics; and
//!   [`DriftMonitor`](stream::DriftMonitor) turns the pair into a live
//!   MMD² drift alarm with exponentially-decayed window weights
//!   ([`CorpusRegistry::mmd2_window`]).
//!
//! * **Persistence** ([`persist`]) — [`CorpusRegistry::snapshot_to`]
//!   serialises every corpus *and* its warm derived state to a versioned,
//!   per-section-checksummed file (written atomically: temp + rename), and
//!   [`CorpusRegistry::restore_from`] rebuilds a registry that answers every
//!   query bit-identically to the original. Corrupt path sections fail the
//!   load with [`SigError::SnapshotCorrupt`](crate::SigError::SnapshotCorrupt);
//!   corrupt derived sections are dropped and rebuilt lazily, so a damaged
//!   snapshot degrades to a cold cache, never to wrong answers.
//!
//! The engine exposes corpora as first-class plans —
//! [`OpSpec::GramCorpus`](crate::engine::OpSpec::GramCorpus) /
//! [`OpSpec::Mmd2Corpus`](crate::engine::OpSpec::Mmd2Corpus) /
//! [`OpSpec::Mmd2Window`](crate::engine::OpSpec::Mmd2Window) compiled via
//! [`Plan::compile_corpus`](crate::engine::Plan::compile_corpus) — and the
//! coordinator serves the full lifecycle over the wire
//! (`RegisterCorpus` / `AppendCorpus` / `Mmd2Corpus` / `ExtendPath` /
//! `EvictCorpus` / `Mmd2Window` ops, CLI `corpus
//! register|append|mmd|watch`).
//!
//! ```no_run
//! use pysiglib::corpus::CorpusRegistry;
//! use pysiglib::{KernelOptions, PathBatch};
//!
//! let registry = CorpusRegistry::new();
//! # let corpus_data = vec![0.0; 64 * 32 * 3];
//! # let query_data = vec![0.0; 8 * 32 * 3];
//! let corpus = PathBatch::uniform(&corpus_data, 64, 32, 3)?;
//! let id = registry.register(&corpus)?;
//! let opts = KernelOptions::default();
//! let query = PathBatch::uniform(&query_data, 8, 32, 3)?;
//! let cold = registry.mmd2_query(id, &query, &opts, None)?; // builds K_cc
//! let warm = registry.mmd2_query(id, &query, &opts, None)?; // reuses it
//! assert_eq!(cold, warm);
//! # Ok::<(), pysiglib::SigError>(())
//! ```

pub mod persist;
pub mod registry;
pub mod stream;
pub mod tiles;

pub use registry::{CorpusId, CorpusRegistry, CorpusStats};
pub use stream::{DriftMonitor, DriftSample, SlidingCorpus};
pub use tiles::TileScheduler;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::{try_gram, try_mmd2, KernelOptions, LowRankSpec};
    use crate::path::{PathBatch, SigError};
    use crate::util::rng::Rng;

    fn batch(rng: &mut Rng, n: usize, l: usize, d: usize) -> Vec<f64> {
        rng.brownian_batch(n, l, d, 0.3)
    }

    #[test]
    fn register_is_content_hash_deduplicated() {
        let reg = CorpusRegistry::new();
        let mut rng = Rng::new(700);
        let data = batch(&mut rng, 4, 6, 2);
        let pb = PathBatch::uniform(&data, 4, 6, 2).unwrap();
        let a = reg.register(&pb).unwrap();
        let b = reg.register(&pb).unwrap();
        assert_eq!(a, b, "identical content must reuse the id");
        let other = batch(&mut rng, 4, 6, 2);
        let ob = PathBatch::uniform(&other, 4, 6, 2).unwrap();
        let c = reg.register(&ob).unwrap();
        assert_ne!(a, c);
        assert_eq!(reg.stats().registered, 2);
        assert_eq!(reg.ids(), vec![a, c]);
        assert_eq!(reg.path_count(a), Some(4));
        assert_eq!(reg.dim_of(a), Some(2));
    }

    #[test]
    fn exact_queries_match_direct_estimators_and_warm_cache_engages() {
        let reg = CorpusRegistry::new();
        let mut rng = Rng::new(701);
        let (n, qn, l, d) = (6, 3, 7, 2);
        let cdata = batch(&mut rng, n, l, d);
        let qdata = batch(&mut rng, qn, l, d);
        let cb = PathBatch::uniform(&cdata, n, l, d).unwrap();
        let qb = PathBatch::uniform(&qdata, qn, l, d).unwrap();
        let id = reg.register(&cb).unwrap();
        let opts = KernelOptions::default();
        let gram = reg.gram_query(id, &qb, &opts, None).unwrap();
        assert_eq!(gram, try_gram(&qb, &cb, &opts).unwrap());
        let cold = reg.mmd2_query(id, &qb, &opts, None).unwrap();
        assert_eq!(cold, try_mmd2(&qb, &cb, &opts).unwrap());
        let warm = reg.mmd2_query(id, &qb, &opts, None).unwrap();
        assert_eq!(cold, warm, "warm re-query must be bit-identical");
        let st = reg.stats();
        assert_eq!(st.cold_builds, 1);
        assert_eq!(st.warm_hits, 1);
        assert_eq!(st.queries, 3);
    }

    #[test]
    fn lowrank_queries_reuse_the_cached_feature_state() {
        let reg = CorpusRegistry::new();
        let mut rng = Rng::new(702);
        let (n, qn, l, d) = (6, 3, 6, 2);
        let cdata = batch(&mut rng, n, l, d);
        let qdata = batch(&mut rng, qn, l, d);
        let cb = PathBatch::uniform(&cdata, n, l, d).unwrap();
        let qb = PathBatch::uniform(&qdata, qn, l, d).unwrap();
        let id = reg.register(&cb).unwrap();
        let opts = KernelOptions::default();
        let spec = LowRankSpec::nystrom(4, 9);
        let cold = reg.mmd2_query(id, &qb, &opts, Some(&spec)).unwrap();
        let warm = reg.mmd2_query(id, &qb, &opts, Some(&spec)).unwrap();
        assert_eq!(cold, warm);
        let g = reg.gram_query(id, &qb, &opts, Some(&spec)).unwrap();
        assert_eq!(g.len(), qn * n);
        assert!(g.iter().all(|v| v.is_finite()));
        let st = reg.stats();
        assert_eq!(st.cold_builds, 1, "one feature-state build");
        assert_eq!(st.warm_hits, 2, "warm mmd2 + warm gram");
    }

    #[test]
    fn unknown_ids_and_mismatched_queries_error() {
        let reg = CorpusRegistry::new();
        let mut rng = Rng::new(703);
        let data = batch(&mut rng, 3, 5, 2);
        let pb = PathBatch::uniform(&data, 3, 5, 2).unwrap();
        let id = reg.register(&pb).unwrap();
        let opts = KernelOptions::default();
        assert!(matches!(
            reg.mmd2_query(CorpusId(999), &pb, &opts, None),
            Err(SigError::Invalid(_))
        ));
        let d3 = vec![0.0; 2 * 5 * 3];
        let q3 = PathBatch::uniform(&d3, 2, 5, 3).unwrap();
        assert!(matches!(
            reg.mmd2_query(id, &q3, &opts, None),
            Err(SigError::DimMismatch { .. })
        ));
        assert!(matches!(
            reg.append(CorpusId(999), &pb),
            Err(SigError::Invalid(_))
        ));
        assert!(matches!(
            reg.append(id, &q3),
            Err(SigError::DimMismatch { .. })
        ));
        let empty = PathBatch::ragged(&[], &[], 2).unwrap();
        assert!(matches!(
            reg.register(&empty),
            Err(SigError::InsufficientBatch { .. })
        ));
        // Empty append is a no-op.
        assert_eq!(reg.append(id, &empty).unwrap(), 3);
        // Empty query errors.
        assert!(matches!(
            reg.mmd2_query(id, &empty, &opts, None),
            Err(SigError::InsufficientBatch { .. })
        ));
    }

    #[test]
    fn append_extends_caches_and_updates_the_content_hash() {
        let reg = CorpusRegistry::new();
        let mut rng = Rng::new(704);
        let (l, d) = (6, 2);
        let part1 = batch(&mut rng, 4, l, d);
        let part2 = batch(&mut rng, 2, l, d);
        let p1 = PathBatch::uniform(&part1, 4, l, d).unwrap();
        let p2 = PathBatch::uniform(&part2, 2, l, d).unwrap();
        let opts = KernelOptions::default();
        let id = reg.register(&p1).unwrap();
        // Warm the exact cache, then append.
        let q = PathBatch::uniform(&part2, 2, l, d).unwrap();
        reg.mmd2_query(id, &q, &opts, None).unwrap();
        assert_eq!(reg.append(id, &p2).unwrap(), 6);
        // The appended corpus answers like the combined corpus.
        let mut combined = part1.clone();
        combined.extend_from_slice(&part2);
        let cb = PathBatch::uniform(&combined, 6, l, d).unwrap();
        let got = reg.mmd2_query(id, &q, &opts, None).unwrap();
        assert_eq!(got, try_mmd2(&q, &cb, &opts).unwrap());
        // ... and the warm cache was *extended*, not rebuilt.
        assert_eq!(reg.stats().cold_builds, 1);
        // Content-hash dedup now matches the combined content.
        let again = reg.register(&cb).unwrap();
        assert_eq!(again, id);
    }
}
