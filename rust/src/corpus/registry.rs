//! The corpus registry: register a reference corpus once, query it many
//! times, append to it incrementally.
//!
//! See the [module docs](crate::corpus) for the serving story. The registry
//! owns the path data and, per (kernel options, low-rank spec) actually
//! queried, the derived state that makes warm re-queries cheap:
//!
//! * **exact** — the full corpus self-Gram `K_cc` (`[n, n]`), the O(n²·L²)
//!   part of every MMD² against the corpus;
//! * **low-rank** — the frozen [`FeatureMap`] (Nyström landmarks drawn from
//!   the corpus's *landmark pool*, or the seeded random-signature sketch)
//!   and the corpus feature matrix `Φ_c` (`[n, r]`).
//!
//! **Append invariance.** Appending extends the cached state *in place*:
//! only the old×new cross strips and the new diagonal block of `K_cc` are
//! solved (via [`TileScheduler::gram_block_into`]), and only the new paths
//! are featurised into `Φ_c`. Both are bit-identical to registering the
//! combined corpus from scratch, because every Gram entry is an independent
//! computation and the feature map is pinned by the **landmark pool**: the
//! first `min(rank, n)` paths of the corpus. While the corpus holds at
//! least `rank` paths the pool — and with it the seeded landmark draw — is
//! a prefix that appends never change. An append that *grows* the pool
//! (corpus still smaller than `rank`) discards the cached map instead, and
//! the next query rebuilds it exactly as a from-scratch registration would.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::corpus::tiles::TileScheduler;
use crate::engine::MAX_BATCH_OUT;
use crate::kernel::lowrank::{feature_mean, FeatureMap, LowRankFeatures, LowRankSpec};
use crate::kernel::KernelOptions;
use crate::path::{PathBatch, SigError};
use crate::util::linalg::gemm_nt;
use crate::util::sync::{lock_unpoisoned, read_unpoisoned, write_unpoisoned};

/// Identifier of a registered corpus — small enough to travel in a wire
/// header field, stable across appends.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct CorpusId(pub u32);

impl std::fmt::Display for CorpusId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "corpus#{}", self.0)
    }
}

/// Registry observability counters (monotonic).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CorpusStats {
    /// Corpora registered (deduplicated registrations do not count).
    pub registered: u64,
    /// Append operations applied.
    pub appended: u64,
    /// Queries served (Gram + MMD², exact + low-rank).
    pub queries: u64,
    /// Queries that found their derived state already cached.
    pub warm_hits: u64,
    /// Queries that had to build derived state (self-Gram / feature map).
    pub cold_builds: u64,
}

/// Cached exact-kernel state for one [`KernelOptions`].
struct ExactCache {
    /// Corpus self-Gram `[n, n]` row-major.
    kcc: Vec<f64>,
}

/// Cached low-rank state for one (options, spec) pair.
struct LowRankCache {
    /// The frozen feature map (landmarks from the corpus's landmark pool,
    /// or the seeded sketch). Shared with in-flight queries.
    map: Arc<FeatureMap>,
    /// Corpus feature matrix `[n, map.rank()]` row-major.
    phi: Vec<f64>,
    /// Landmark-pool size the map was built from (`min(spec.rank, n)` at
    /// build time). While an append keeps this equal to `min(spec.rank,
    /// n_new)` the map is append-invariant and `phi` extends in place.
    pool: usize,
}

/// One registered corpus: owned path data plus the per-options caches.
struct CorpusEntry {
    dim: usize,
    data: Vec<f64>,
    lengths: Vec<usize>,
    hash: u64,
    exact: HashMap<KernelOptions, ExactCache>,
    lowrank: HashMap<(KernelOptions, LowRankSpec), LowRankCache>,
}

impl CorpusEntry {
    /// View the stored paths as a batch. Construction re-validates the
    /// stored data/lengths pair; a mismatch (impossible by construction)
    /// surfaces as a typed error rather than a panic on the request path.
    fn batch(&self) -> Result<PathBatch<'_>, SigError> {
        PathBatch::ragged(&self.data, &self.lengths, self.dim)
    }

    fn max_len(&self) -> usize {
        self.lengths.iter().copied().max().unwrap_or(0)
    }

    /// Shared query validation: dimension and refined-grid bounds against
    /// the corpus's longest path.
    fn check_query(&self, q: &PathBatch<'_>, opts: &KernelOptions) -> Result<(), SigError> {
        if q.dim() != self.dim {
            return Err(SigError::DimMismatch {
                left: q.dim(),
                right: self.dim,
            });
        }
        if q.is_empty() {
            return Err(SigError::InsufficientBatch { need: 1, got: 0 });
        }
        let mq = (0..q.batch()).map(|i| q.len_of(i)).max().unwrap_or(0);
        let mc = self.max_len();
        if mq >= 2 && mc >= 2 {
            crate::kernel::check_grid_size(mq, mc, opts)?;
        }
        Ok(())
    }
}

/// FNV-1a over the corpus content (dimension, lengths, raw f64 bits) — the
/// registry's dedup key. Collisions are survivable: a hash hit is confirmed
/// by full content comparison before an id is reused.
fn content_hash(dim: usize, lengths: &[usize], data: &[f64]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut eat = |v: u64| {
        for b in v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x1_0000_0000_01b3);
        }
    };
    eat(dim as u64);
    eat(lengths.len() as u64);
    for &l in lengths {
        eat(l as u64);
    }
    for &v in data {
        eat(v.to_bits());
    }
    h
}

/// A concurrent registry of reference corpora with per-corpus derived-state
/// caches. Cheap to share (`Arc`); registration is content-hash
/// deduplicated, queries are lock-shared, appends are exclusive per corpus.
pub struct CorpusRegistry {
    entries: Mutex<HashMap<u32, Arc<RwLock<CorpusEntry>>>>,
    by_hash: Mutex<HashMap<u64, u32>>,
    next_id: AtomicU32,
    tiles: TileScheduler,
    registered: AtomicU64,
    appended: AtomicU64,
    queries: AtomicU64,
    warm_hits: AtomicU64,
    cold_builds: AtomicU64,
}

impl Default for CorpusRegistry {
    fn default() -> Self {
        CorpusRegistry::new()
    }
}

impl CorpusRegistry {
    /// Empty registry with the environment-configured tile size.
    pub fn new() -> CorpusRegistry {
        CorpusRegistry::with_tiles(TileScheduler::from_env())
    }

    /// Empty registry with an explicit tile scheduler.
    pub fn with_tiles(tiles: TileScheduler) -> CorpusRegistry {
        CorpusRegistry {
            entries: Mutex::new(HashMap::new()),
            by_hash: Mutex::new(HashMap::new()),
            next_id: AtomicU32::new(1),
            tiles,
            registered: AtomicU64::new(0),
            appended: AtomicU64::new(0),
            queries: AtomicU64::new(0),
            warm_hits: AtomicU64::new(0),
            cold_builds: AtomicU64::new(0),
        }
    }

    /// Register a corpus. Content-hash keyed: registering byte-identical
    /// content again returns the existing id instead of a new copy.
    pub fn register(&self, batch: &PathBatch<'_>) -> Result<CorpusId, SigError> {
        if batch.is_empty() {
            return Err(SigError::InsufficientBatch { need: 1, got: 0 });
        }
        let lengths: Vec<usize> = (0..batch.batch()).map(|i| batch.len_of(i)).collect();
        let hash = content_hash(batch.dim(), &lengths, batch.data());
        // Hold the hash-map lock across lookup → verify → insert so two
        // concurrent registrations of identical content cannot both miss
        // and create duplicate corpora. Lock order is by_hash → entries →
        // entry.read; `append` releases its entry lock before touching
        // by_hash, so no cycle exists.
        let mut by_hash = lock_unpoisoned(&self.by_hash);
        if let Some(&id) = by_hash.get(&hash) {
            let arc = lock_unpoisoned(&self.entries).get(&id).cloned();
            if let Some(arc) = arc {
                // Hash hit: confirm it is not an FNV collision.
                let e = read_unpoisoned(&arc);
                if e.dim == batch.dim() && e.lengths == lengths && e.data == batch.data() {
                    return Ok(CorpusId(id));
                }
            }
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let entry = CorpusEntry {
            dim: batch.dim(),
            data: batch.data().to_vec(),
            lengths,
            hash,
            exact: HashMap::new(),
            lowrank: HashMap::new(),
        };
        lock_unpoisoned(&self.entries).insert(id, Arc::new(RwLock::new(entry)));
        by_hash.insert(hash, id);
        self.registered.fetch_add(1, Ordering::Relaxed);
        Ok(CorpusId(id))
    }

    /// Append paths to a registered corpus, extending every cached Gram /
    /// feature matrix in place (see the module docs for why the result is
    /// bit-identical to a from-scratch registration of the combined
    /// corpus). Returns the new path count. A cache whose extension fails
    /// (e.g. an appended path makes a refined grid exceed the hard cap) is
    /// dropped rather than left stale — the next query rebuilds or errors.
    pub fn append(&self, id: CorpusId, batch: &PathBatch<'_>) -> Result<usize, SigError> {
        let arc = self.entry(id)?;
        let mut e = write_unpoisoned(&arc);
        if batch.dim() != e.dim {
            return Err(SigError::DimMismatch {
                left: batch.dim(),
                right: e.dim,
            });
        }
        if batch.is_empty() {
            return Ok(e.lengths.len());
        }
        let old_hash = e.hash;
        let n_old = e.lengths.len();
        e.data.extend_from_slice(batch.data());
        for i in 0..batch.batch() {
            let l = batch.len_of(i);
            e.lengths.push(l);
        }
        let n = e.lengths.len();
        // Split borrows: the caches are extended against a view of the
        // (already extended) path data.
        let CorpusEntry {
            dim,
            data,
            lengths,
            hash,
            exact,
            lowrank,
        } = &mut *e;
        let cb = PathBatch::ragged(data, lengths, *dim)?;
        let exact_keys: Vec<KernelOptions> = exact.keys().copied().collect();
        for opts in exact_keys {
            let grown = match exact.get(&opts) {
                Some(c) => grow_kcc(&self.tiles, &cb, &c.kcc, n_old, n, &opts),
                None => continue,
            };
            match grown {
                Ok(kcc) => {
                    if let Some(c) = exact.get_mut(&opts) {
                        c.kcc = kcc;
                    }
                }
                Err(_) => {
                    exact.remove(&opts);
                }
            }
        }
        let new_batch = suffix_batch(&cb, n_old)?;
        let lr_keys: Vec<(KernelOptions, LowRankSpec)> = lowrank.keys().copied().collect();
        for key in lr_keys {
            let (opts, spec) = key;
            let (cache_pool, cache_map) = match lowrank.get(&key) {
                Some(c) => (c.pool, c.map.clone()),
                None => continue,
            };
            let pool_new = spec.rank.min(n);
            // Random-signature sketches depend only on (seed, shape), so
            // they extend regardless of the pool; Nyström maps extend while
            // the landmark pool is unchanged.
            let extendable = cache_pool == pool_new
                || matches!(spec.method, crate::kernel::LowRankMethod::RandomSig { .. });
            if extendable {
                // The map stays valid: only the new paths need feature rows.
                match cache_map.try_features(&new_batch) {
                    Ok(rows) => {
                        if let Some(c) = lowrank.get_mut(&key) {
                            c.phi.extend(rows);
                            c.pool = pool_new;
                        }
                    }
                    Err(_) => {
                        lowrank.remove(&key);
                    }
                }
            } else {
                // The pool grew (corpus was still below the rank budget):
                // rebuild exactly as a from-scratch registration would.
                match build_lowrank(&cb, &opts, &spec) {
                    Ok(rebuilt) => {
                        lowrank.insert(key, rebuilt);
                    }
                    Err(_) => {
                        lowrank.remove(&key);
                    }
                }
            }
        }
        *hash = content_hash(*dim, lengths, data);
        let new_hash = *hash;
        drop(e);
        {
            let mut by_hash = lock_unpoisoned(&self.by_hash);
            if by_hash.get(&old_hash) == Some(&id.0) {
                by_hash.remove(&old_hash);
            }
            by_hash.insert(new_hash, id.0);
        }
        self.appended.fetch_add(1, Ordering::Relaxed);
        Ok(n)
    }

    /// Cross-Gram `[q.batch(), n]` of a query batch against the corpus —
    /// exact (tiled PDE solves) or, with a spec, low-rank `Φ_q · Φ_cᵀ`
    /// reusing the cached corpus features.
    pub fn gram_query(
        &self,
        id: CorpusId,
        q: &PathBatch<'_>,
        opts: &KernelOptions,
        lowrank: Option<&LowRankSpec>,
    ) -> Result<Vec<f64>, SigError> {
        self.queries.fetch_add(1, Ordering::Relaxed);
        let arc = self.entry(id)?;
        match lowrank {
            None => {
                let e = read_unpoisoned(&arc);
                e.check_query(q, opts)?;
                let n = e.lengths.len();
                let total = q
                    .batch()
                    .checked_mul(n)
                    .filter(|&t| t <= MAX_BATCH_OUT)
                    .ok_or(SigError::TooLarge("corpus gram output"))?;
                let mut out = vec![0.0; total];
                self.tiles.gram_into(q, &e.batch()?, opts, &mut out)?;
                Ok(out)
            }
            Some(spec) => self.with_lowrank(&arc, q, opts, spec, |e, map, phi| {
                let (qb, n, r) = (q.batch(), e.lengths.len(), map.rank());
                let total = qb
                    .checked_mul(n)
                    .filter(|&t| t <= MAX_BATCH_OUT)
                    .ok_or(SigError::TooLarge("corpus gram output"))?;
                let phi_q = map.try_features(q)?;
                let mut out = vec![0.0; total];
                gemm_nt(qb, r, n, &phi_q, phi, &mut out);
                Ok(out)
            }),
        }
    }

    /// Biased MMD² between a query batch and the corpus. Exact queries
    /// reuse the cached corpus self-Gram (only the query-side `K_qq` and
    /// the cross `K_qc` are solved); low-rank queries reuse the cached
    /// feature map and corpus features (only the query rows are
    /// featurised).
    pub fn mmd2_query(
        &self,
        id: CorpusId,
        q: &PathBatch<'_>,
        opts: &KernelOptions,
        lowrank: Option<&LowRankSpec>,
    ) -> Result<f64, SigError> {
        self.queries.fetch_add(1, Ordering::Relaxed);
        let arc = self.entry(id)?;
        match lowrank {
            None => {
                // Query-side work always runs under the *shared* lock —
                // the exclusive lock is held only while building the
                // self-Gram, so concurrent warm queries are never blocked
                // behind another query's K_qq/K_qc solves.
                let mut just_built = false;
                loop {
                    {
                        let e = read_unpoisoned(&arc);
                        e.check_query(q, opts)?;
                        if let Some(c) = e.exact.get(opts) {
                            if !just_built {
                                self.warm_hits.fetch_add(1, Ordering::Relaxed);
                            }
                            return self.mmd2_exact_value(&e, q, opts, &c.kcc);
                        }
                    }
                    // Cold: build (or pick up a racing build of) the
                    // self-Gram, release, and retry the warm path. The
                    // cache can only vanish again if a concurrent append's
                    // extension failed — then the next lap rebuilds.
                    let mut e = write_unpoisoned(&arc);
                    e.check_query(q, opts)?;
                    if e.exact.get(opts).is_none() {
                        let kcc = build_kcc(&self.tiles, &e.batch()?, opts)?;
                        e.exact.insert(*opts, ExactCache { kcc });
                        self.cold_builds.fetch_add(1, Ordering::Relaxed);
                        just_built = true;
                    }
                }
            }
            Some(spec) => self.with_lowrank(&arc, q, opts, spec, |e, map, phi| {
                let r = map.rank();
                let phi_q = map.try_features(q)?;
                let mq = feature_mean(&phi_q, q.batch(), r);
                let mc = feature_mean(phi, e.lengths.len(), r);
                Ok(mq
                    .iter()
                    .zip(mc.iter())
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum())
            }),
        }
    }

    /// Number of paths in a corpus.
    pub fn path_count(&self, id: CorpusId) -> Option<usize> {
        let arc = lock_unpoisoned(&self.entries).get(&id.0).cloned()?;
        let n = read_unpoisoned(&arc).lengths.len();
        Some(n)
    }

    /// Path dimension of a corpus.
    pub fn dim_of(&self, id: CorpusId) -> Option<usize> {
        let arc = lock_unpoisoned(&self.entries).get(&id.0).cloned()?;
        let d = read_unpoisoned(&arc).dim;
        Some(d)
    }

    /// Registered corpus ids, ascending.
    pub fn ids(&self) -> Vec<CorpusId> {
        let mut ids: Vec<CorpusId> = lock_unpoisoned(&self.entries)
            .keys()
            .map(|&v| CorpusId(v))
            .collect();
        ids.sort_unstable();
        ids
    }

    /// Observability counters.
    pub fn stats(&self) -> CorpusStats {
        CorpusStats {
            registered: self.registered.load(Ordering::Relaxed),
            appended: self.appended.load(Ordering::Relaxed),
            queries: self.queries.load(Ordering::Relaxed),
            warm_hits: self.warm_hits.load(Ordering::Relaxed),
            cold_builds: self.cold_builds.load(Ordering::Relaxed),
        }
    }

    fn entry(&self, id: CorpusId) -> Result<Arc<RwLock<CorpusEntry>>, SigError> {
        lock_unpoisoned(&self.entries)
            .get(&id.0)
            .cloned()
            .ok_or(SigError::Invalid("unknown corpus id"))
    }

    /// Run `body` with the (warm or freshly built) low-rank state for
    /// (opts, spec), updating the warm/cold counters.
    fn with_lowrank<R>(
        &self,
        arc: &Arc<RwLock<CorpusEntry>>,
        q: &PathBatch<'_>,
        opts: &KernelOptions,
        spec: &LowRankSpec,
        body: impl Fn(&CorpusEntry, &FeatureMap, &[f64]) -> Result<R, SigError>,
    ) -> Result<R, SigError> {
        let key = (*opts, *spec);
        // Same locking discipline as the exact route: the exclusive lock
        // covers only the feature-state build; `body` (query featurisation)
        // always runs under the shared lock.
        let mut just_built = false;
        loop {
            {
                let e = read_unpoisoned(arc);
                e.check_query(q, opts)?;
                if let Some(c) = e.lowrank.get(&key) {
                    if !just_built {
                        self.warm_hits.fetch_add(1, Ordering::Relaxed);
                    }
                    return body(&e, &c.map, &c.phi);
                }
            }
            let mut e = write_unpoisoned(arc);
            e.check_query(q, opts)?;
            if e.lowrank.get(&key).is_none() {
                let built = build_lowrank(&e.batch()?, opts, spec)?;
                e.lowrank.insert(key, built);
                self.cold_builds.fetch_add(1, Ordering::Relaxed);
                just_built = true;
            }
        }
    }

    /// `mean(K_qq) − 2·mean(K_qc) + mean(K_cc)` with the corpus term served
    /// from cache — the same estimator (and the same summation order) as
    /// [`OpSpec::Mmd2`](crate::engine::OpSpec::Mmd2).
    fn mmd2_exact_value(
        &self,
        e: &CorpusEntry,
        q: &PathBatch<'_>,
        opts: &KernelOptions,
        kcc: &[f64],
    ) -> Result<f64, SigError> {
        let qb = q.batch();
        let n = e.lengths.len();
        let gram_len = |a: usize, b: usize| -> Result<usize, SigError> {
            a.checked_mul(b)
                .filter(|&t| t <= MAX_BATCH_OUT)
                .ok_or(SigError::TooLarge("corpus mmd2 gram matrices"))
        };
        let mut kqq = vec![0.0; gram_len(qb, qb)?];
        self.tiles.gram_into(q, q, opts, &mut kqq)?;
        let mut kqc = vec![0.0; gram_len(qb, n)?];
        self.tiles.gram_into(q, &e.batch()?, opts, &mut kqc)?;
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        Ok(mean(&kqq) - 2.0 * mean(&kqc) + mean(kcc))
    }
}

/// The corpus suffix `paths[n_old..]` as its own batch view.
fn suffix_batch<'a>(cb: &PathBatch<'a>, n_old: usize) -> Result<PathBatch<'a>, SigError> {
    let dim = cb.dim();
    let split = cb
        .offsets()
        .get(n_old)
        .copied()
        .ok_or(SigError::Invalid("internal: append offset out of bounds"))?
        * dim;
    let lens: Vec<usize> = (n_old..cb.batch()).map(|i| cb.len_of(i)).collect();
    let data = cb
        .data()
        .get(split..)
        .ok_or(SigError::Invalid("internal: append split exceeds corpus data"))?;
    PathBatch::ragged(data, &lens, dim)
}

/// Full corpus self-Gram (the cold build).
fn build_kcc(
    tiles: &TileScheduler,
    cb: &PathBatch<'_>,
    opts: &KernelOptions,
) -> Result<Vec<f64>, SigError> {
    let n = cb.batch();
    let total = n
        .checked_mul(n)
        .filter(|&t| t <= MAX_BATCH_OUT)
        .ok_or(SigError::TooLarge("corpus self-Gram"))?;
    let mut kcc = vec![0.0; total];
    tiles.gram_into(cb, cb, opts, &mut kcc)?;
    Ok(kcc)
}

/// Grow a cached `[n_old, n_old]` self-Gram to `[n, n]`: copy the retained
/// block, solve only the two new strips.
fn grow_kcc(
    tiles: &TileScheduler,
    cb: &PathBatch<'_>,
    old: &[f64],
    n_old: usize,
    n: usize,
    opts: &KernelOptions,
) -> Result<Vec<f64>, SigError> {
    let total = n
        .checked_mul(n)
        .filter(|&t| t <= MAX_BATCH_OUT)
        .ok_or(SigError::TooLarge("corpus self-Gram"))?;
    let mut kcc = vec![0.0; total];
    if n_old > 0 {
        for (dst, src) in kcc.chunks_mut(n).zip(old.chunks(n_old)).take(n_old) {
            if let Some(head) = dst.get_mut(..n_old) {
                head.copy_from_slice(src);
            }
        }
    }
    tiles.gram_block_into(cb, 0..n_old, cb, n_old..n, opts, &mut kcc, n, 0, n_old)?;
    tiles.gram_block_into(cb, n_old..n, cb, 0..n, opts, &mut kcc, n, n_old, 0)?;
    Ok(kcc)
}

/// Cold build of the low-rank state: map from the landmark pool (the first
/// `min(rank, n)` paths), features for the whole corpus.
fn build_lowrank(
    cb: &PathBatch<'_>,
    opts: &KernelOptions,
    spec: &LowRankSpec,
) -> Result<LowRankCache, SigError> {
    spec.validate()?;
    let n = cb.batch();
    let pool = spec.rank.min(n);
    let pool_lens: Vec<usize> = (0..pool).map(|i| cb.len_of(i)).collect();
    let split = cb
        .offsets()
        .get(pool)
        .copied()
        .ok_or(SigError::Invalid("internal: landmark pool out of bounds"))?
        * cb.dim();
    let data = cb
        .data()
        .get(..split)
        .ok_or(SigError::Invalid("internal: landmark split exceeds corpus data"))?;
    let pool_batch = PathBatch::ragged(data, &pool_lens, cb.dim())?;
    let map = Arc::new(FeatureMap::try_build(spec, opts, &pool_batch)?);
    let phi = map.try_features(cb)?;
    Ok(LowRankCache { map, phi, pool })
}
