//! The corpus registry: register a reference corpus once, query it many
//! times, append to it incrementally.
//!
//! See the [module docs](crate::corpus) for the serving story. The registry
//! owns the path data and, per (kernel options, low-rank spec) actually
//! queried, the derived state that makes warm re-queries cheap:
//!
//! * **exact** — the full corpus self-Gram `K_cc` (`[n, n]`), the O(n²·L²)
//!   part of every MMD² against the corpus;
//! * **low-rank** — the frozen [`FeatureMap`] (Nyström landmarks drawn from
//!   the corpus's *landmark pool*, or the seeded random-signature sketch)
//!   and the corpus feature matrix `Φ_c` (`[n, r]`).
//!
//! **Append invariance.** Appending extends the cached state *in place*:
//! only the old×new cross strips and the new diagonal block of `K_cc` are
//! solved (via [`TileScheduler::gram_block_into`]), and only the new paths
//! are featurised into `Φ_c`. Both are bit-identical to registering the
//! combined corpus from scratch, because every Gram entry is an independent
//! computation and the feature map is pinned by the **landmark pool**: the
//! first `min(rank, n)` paths of the corpus. While the corpus holds at
//! least `rank` paths the pool — and with it the seeded landmark draw — is
//! a prefix that appends never change. An append that *grows* the pool
//! (corpus still smaller than `rank`) discards the cached map instead, and
//! the next query rebuilds it exactly as a from-scratch registration would.
//!
//! **Streaming extension.** [`CorpusRegistry::extend_path`] appends points
//! to one *registered* path. Only row/column `k` of `K_cc` move, and with
//! the row solver each affected pair advances by a Goursat **border strip**
//! ([`crate::kernel::border`]): the retained last row/column of the solved
//! grid continues the sweep over `O(L_new·L)` fresh cells instead of the
//! full `O(L²)` grid. The first extension of a pair pays one full retaining
//! solve (there is no border yet — cold registration does not pay the
//! retention cost for paths that never stream); every later extension is a
//! strip. [`CorpusRegistry::evict`] drops the oldest paths, shrinking every
//! cache to the surviving suffix, and [`CorpusRegistry::mmd2_window`]
//! serves an exponentially-weighted MMD² for sliding live windows. All
//! three are bit-identical to rebuilding from scratch on the same data.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::corpus::persist::{self, BorderExport, CorpusExport, ExactExport, LowRankExport};
use crate::corpus::tiles::TileScheduler;
use crate::engine::MAX_BATCH_OUT;
use crate::kernel::border::{self, SchemeBorder};
use crate::kernel::delta::{delta_matrix, increments_into};
use crate::kernel::lowrank::{feature_mean, FeatureMap, LowRankFeatures, LowRankSpec};
use crate::kernel::{KernelOptions, SolverKind};
use crate::path::{PathBatch, SigError};
use crate::transforms::Transform;
use crate::util::linalg::gemm_nt;
use crate::util::sync::{lock_unpoisoned, read_unpoisoned, write_unpoisoned};

/// Identifier of a registered corpus — small enough to travel in a wire
/// header field, stable across appends.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct CorpusId(pub u32);

impl std::fmt::Display for CorpusId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "corpus#{}", self.0)
    }
}

/// Registry observability counters (monotonic).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CorpusStats {
    /// Corpora registered (deduplicated registrations do not count).
    pub registered: u64,
    /// Append operations applied.
    pub appended: u64,
    /// Queries served (Gram + MMD², exact + low-rank).
    pub queries: u64,
    /// Queries that found their derived state already cached.
    pub warm_hits: u64,
    /// Queries that had to build derived state (self-Gram / feature map).
    pub cold_builds: u64,
    /// Streaming path extensions applied (`extend_path`).
    pub extended: u64,
    /// Sliding-window evictions applied (`evict`).
    pub evicted: u64,
}

/// Cached exact-kernel state for one [`KernelOptions`].
struct ExactCache {
    /// Corpus self-Gram `[n, n]` row-major.
    kcc: Vec<f64>,
    /// Retained Goursat borders keyed by ordered path pair `(i, j)`,
    /// populated lazily by the first `extend_path` that touches a pair.
    /// Queries never read them; appends keep them (old grids are
    /// unchanged); evictions rekey the surviving suffix. Under
    /// `Scheme::Order2` each entry retains fine + coarse borders so strip
    /// extensions continue the full scheme.
    borders: HashMap<(usize, usize), SchemeBorder>,
}

/// Cached low-rank state for one (options, spec) pair.
struct LowRankCache {
    /// The frozen feature map (landmarks from the corpus's landmark pool,
    /// or the seeded sketch). Shared with in-flight queries.
    map: Arc<FeatureMap>,
    /// Corpus feature matrix `[n, map.rank()]` row-major.
    phi: Vec<f64>,
    /// Landmark-pool size the map was built from (`min(spec.rank, n)` at
    /// build time). While an append keeps this equal to `min(spec.rank,
    /// n_new)` the map is append-invariant and `phi` extends in place.
    pool: usize,
}

/// One registered corpus: owned path data plus the per-options caches.
struct CorpusEntry {
    dim: usize,
    data: Vec<f64>,
    lengths: Vec<usize>,
    hash: u64,
    /// Corpus age clock: the number of append batches applied since
    /// registration (registration itself is tick 0). In-place path
    /// extensions do not advance it — they refine a path, they don't
    /// refresh its age.
    tick: u64,
    /// Per-path birth tick, parallel to `lengths` (`born[i]` is the value
    /// of `tick` when path `i` arrived). Non-decreasing by construction:
    /// paths arrive in append order and eviction only drops prefixes —
    /// which is what makes age-based eviction a prefix drop too.
    born: Vec<u64>,
    exact: HashMap<KernelOptions, ExactCache>,
    lowrank: HashMap<(KernelOptions, LowRankSpec), LowRankCache>,
}

impl CorpusEntry {
    /// View the stored paths as a batch. Construction re-validates the
    /// stored data/lengths pair; a mismatch (impossible by construction)
    /// surfaces as a typed error rather than a panic on the request path.
    fn batch(&self) -> Result<PathBatch<'_>, SigError> {
        PathBatch::ragged(&self.data, &self.lengths, self.dim)
    }

    fn max_len(&self) -> usize {
        self.lengths.iter().copied().max().unwrap_or(0)
    }

    /// Shared query validation: dimension and refined-grid bounds against
    /// the corpus's longest path.
    fn check_query(&self, q: &PathBatch<'_>, opts: &KernelOptions) -> Result<(), SigError> {
        if q.dim() != self.dim {
            return Err(SigError::DimMismatch {
                left: q.dim(),
                right: self.dim,
            });
        }
        if q.is_empty() {
            return Err(SigError::InsufficientBatch { need: 1, got: 0 });
        }
        let mq = (0..q.batch()).map(|i| q.len_of(i)).max().unwrap_or(0);
        let mc = self.max_len();
        if mq >= 2 && mc >= 2 {
            crate::kernel::check_grid_size(mq, mc, opts)?;
        }
        Ok(())
    }
}

/// FNV-1a over the corpus content (dimension, lengths, raw f64 bits) — the
/// registry's dedup key. Collisions are survivable: a hash hit is confirmed
/// by full content comparison before an id is reused.
fn content_hash(dim: usize, lengths: &[usize], data: &[f64]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut eat = |v: u64| {
        for b in v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x1_0000_0000_01b3);
        }
    };
    eat(dim as u64);
    eat(lengths.len() as u64);
    for &l in lengths {
        eat(l as u64);
    }
    for &v in data {
        eat(v.to_bits());
    }
    h
}

/// A concurrent registry of reference corpora with per-corpus derived-state
/// caches. Cheap to share (`Arc`); registration is content-hash
/// deduplicated, queries are lock-shared, appends are exclusive per corpus.
pub struct CorpusRegistry {
    entries: Mutex<HashMap<u32, Arc<RwLock<CorpusEntry>>>>,
    by_hash: Mutex<HashMap<u64, u32>>,
    next_id: AtomicU32,
    tiles: TileScheduler,
    registered: AtomicU64,
    appended: AtomicU64,
    queries: AtomicU64,
    warm_hits: AtomicU64,
    cold_builds: AtomicU64,
    extended: AtomicU64,
    evicted: AtomicU64,
}

impl Default for CorpusRegistry {
    fn default() -> Self {
        CorpusRegistry::new()
    }
}

impl CorpusRegistry {
    /// Empty registry with the environment-configured tile size.
    pub fn new() -> CorpusRegistry {
        CorpusRegistry::with_tiles(TileScheduler::from_env())
    }

    /// Empty registry with an explicit tile scheduler.
    pub fn with_tiles(tiles: TileScheduler) -> CorpusRegistry {
        CorpusRegistry {
            entries: Mutex::new(HashMap::new()),
            by_hash: Mutex::new(HashMap::new()),
            next_id: AtomicU32::new(1),
            tiles,
            registered: AtomicU64::new(0),
            appended: AtomicU64::new(0),
            queries: AtomicU64::new(0),
            warm_hits: AtomicU64::new(0),
            cold_builds: AtomicU64::new(0),
            extended: AtomicU64::new(0),
            evicted: AtomicU64::new(0),
        }
    }

    /// Register a corpus. Content-hash keyed: registering byte-identical
    /// content again returns the existing id instead of a new copy.
    pub fn register(&self, batch: &PathBatch<'_>) -> Result<CorpusId, SigError> {
        if batch.is_empty() {
            return Err(SigError::InsufficientBatch { need: 1, got: 0 });
        }
        let lengths: Vec<usize> = (0..batch.batch()).map(|i| batch.len_of(i)).collect();
        let hash = content_hash(batch.dim(), &lengths, batch.data());
        // Hold the hash-map lock across lookup → verify → insert so two
        // concurrent registrations of identical content cannot both miss
        // and create duplicate corpora. Lock order is by_hash → entries →
        // entry.read; `append` releases its entry lock before touching
        // by_hash, so no cycle exists.
        let mut by_hash = lock_unpoisoned(&self.by_hash);
        if let Some(&id) = by_hash.get(&hash) {
            let arc = lock_unpoisoned(&self.entries).get(&id).cloned();
            if let Some(arc) = arc {
                // Hash hit: confirm it is not an FNV collision.
                let e = read_unpoisoned(&arc);
                if e.dim == batch.dim() && e.lengths == lengths && e.data == batch.data() {
                    return Ok(CorpusId(id));
                }
            }
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let entry = CorpusEntry {
            dim: batch.dim(),
            data: batch.data().to_vec(),
            tick: 0,
            born: vec![0; lengths.len()],
            lengths,
            hash,
            exact: HashMap::new(),
            lowrank: HashMap::new(),
        };
        lock_unpoisoned(&self.entries).insert(id, Arc::new(RwLock::new(entry)));
        by_hash.insert(hash, id);
        self.registered.fetch_add(1, Ordering::Relaxed);
        Ok(CorpusId(id))
    }

    /// Append paths to a registered corpus, extending every cached Gram /
    /// feature matrix in place (see the module docs for why the result is
    /// bit-identical to a from-scratch registration of the combined
    /// corpus). Returns the new path count. A cache whose extension fails
    /// (e.g. an appended path makes a refined grid exceed the hard cap) is
    /// dropped rather than left stale — the next query rebuilds or errors.
    pub fn append(&self, id: CorpusId, batch: &PathBatch<'_>) -> Result<usize, SigError> {
        let arc = self.entry(id)?;
        let mut e = write_unpoisoned(&arc);
        if batch.dim() != e.dim {
            return Err(SigError::DimMismatch {
                left: batch.dim(),
                right: e.dim,
            });
        }
        if batch.is_empty() {
            return Ok(e.lengths.len());
        }
        let old_hash = e.hash;
        let n_old = e.lengths.len();
        e.data.extend_from_slice(batch.data());
        for i in 0..batch.batch() {
            let l = batch.len_of(i);
            e.lengths.push(l);
        }
        e.tick += 1;
        let t = e.tick;
        e.born.resize(e.lengths.len(), t);
        let n = e.lengths.len();
        // Split borrows: the caches are extended against a view of the
        // (already extended) path data.
        let CorpusEntry {
            dim,
            data,
            lengths,
            hash,
            exact,
            lowrank,
            ..
        } = &mut *e;
        let cb = PathBatch::ragged(data, lengths, *dim)?;
        let exact_keys: Vec<KernelOptions> = exact.keys().copied().collect();
        for opts in exact_keys {
            let grown = match exact.get(&opts) {
                Some(c) => grow_kcc(&self.tiles, &cb, &c.kcc, n_old, n, &opts),
                None => continue,
            };
            match grown {
                Ok(kcc) => {
                    if let Some(c) = exact.get_mut(&opts) {
                        c.kcc = kcc;
                    }
                }
                Err(_) => {
                    exact.remove(&opts);
                }
            }
        }
        let new_batch = suffix_batch(&cb, n_old)?;
        let lr_keys: Vec<(KernelOptions, LowRankSpec)> = lowrank.keys().copied().collect();
        for key in lr_keys {
            let (opts, spec) = key;
            let (cache_pool, cache_map) = match lowrank.get(&key) {
                Some(c) => (c.pool, c.map.clone()),
                None => continue,
            };
            let pool_new = spec.rank.min(n);
            // Random-signature sketches depend only on (seed, shape), so
            // they extend regardless of the pool; Nyström maps extend while
            // the landmark pool is unchanged.
            let extendable = cache_pool == pool_new
                || matches!(spec.method, crate::kernel::LowRankMethod::RandomSig { .. });
            if extendable {
                // The map stays valid: only the new paths need feature rows.
                match cache_map.try_features(&new_batch) {
                    Ok(rows) => {
                        if let Some(c) = lowrank.get_mut(&key) {
                            c.phi.extend(rows);
                            c.pool = pool_new;
                        }
                    }
                    Err(_) => {
                        lowrank.remove(&key);
                    }
                }
            } else {
                // The pool grew (corpus was still below the rank budget):
                // rebuild exactly as a from-scratch registration would.
                match build_lowrank(&cb, &opts, &spec) {
                    Ok(rebuilt) => {
                        lowrank.insert(key, rebuilt);
                    }
                    Err(_) => {
                        lowrank.remove(&key);
                    }
                }
            }
        }
        *hash = content_hash(*dim, lengths, data);
        let new_hash = *hash;
        drop(e);
        {
            let mut by_hash = lock_unpoisoned(&self.by_hash);
            if by_hash.get(&old_hash) == Some(&id.0) {
                by_hash.remove(&old_hash);
            }
            by_hash.insert(new_hash, id.0);
        }
        self.appended.fetch_add(1, Ordering::Relaxed);
        Ok(n)
    }

    /// Append points to one registered path (streaming extension). Only
    /// row/column `path_idx` of each cached self-Gram change; with the row
    /// solver they advance by Goursat border strips — `O(L_new·L)` cells
    /// per pair once the pair's border has been retained (the first
    /// extension pays one full retaining solve) — and the blocked solver
    /// re-solves the row/column through the tile scheduler. Low-rank
    /// caches re-featurise the one extended row (or rebuild, if the path
    /// sits in a Nyström landmark pool). Every outcome is bit-identical to
    /// re-registering the extended corpus from scratch; a cache whose
    /// extension fails is dropped rather than left stale. Returns the
    /// path's new length in points.
    pub fn extend_path(
        &self,
        id: CorpusId,
        path_idx: usize,
        points: &[f64],
    ) -> Result<usize, SigError> {
        let arc = self.entry(id)?;
        let mut e = write_unpoisoned(&arc);
        if e.dim == 0 || points.len() % e.dim != 0 {
            return Err(SigError::Invalid(
                "extend_path: points are not a whole number of dim-d samples",
            ));
        }
        let l_old = *e
            .lengths
            .get(path_idx)
            .ok_or(SigError::Invalid("extend_path: path index out of range"))?;
        let add = points.len() / e.dim;
        if add == 0 {
            return Ok(l_old);
        }
        let old_hash = e.hash;
        let insert_at: usize = e.lengths.iter().take(path_idx + 1).sum::<usize>() * e.dim;
        if insert_at > e.data.len() {
            return Err(SigError::Invalid("extend_path: corpus layout corrupt"));
        }
        e.data.splice(insert_at..insert_at, points.iter().copied());
        let l_new = l_old + add;
        if let Some(l) = e.lengths.get_mut(path_idx) {
            *l = l_new;
        }
        let CorpusEntry {
            dim,
            data,
            lengths,
            hash,
            exact,
            lowrank,
            ..
        } = &mut *e;
        let cb = PathBatch::ragged(data, lengths, *dim)?;
        let exact_keys: Vec<KernelOptions> = exact.keys().copied().collect();
        for opts in exact_keys {
            let ok = match exact.get_mut(&opts) {
                Some(c) => extend_exact_cache(&self.tiles, &cb, c, path_idx, l_old, &opts),
                None => continue,
            };
            if ok.is_err() {
                exact.remove(&opts);
            }
        }
        let lr_keys: Vec<(KernelOptions, LowRankSpec)> = lowrank.keys().copied().collect();
        for key in lr_keys {
            let (opts, spec) = key;
            let (pool, map) = match lowrank.get(&key) {
                Some(c) => (c.pool, c.map.clone()),
                None => continue,
            };
            // Random-signature sketches never depend on the path data;
            // Nyström maps are frozen unless the extended path is one of
            // the landmarks.
            let map_intact = path_idx >= pool
                || matches!(spec.method, crate::kernel::LowRankMethod::RandomSig { .. });
            if map_intact {
                match refeaturise_row(&cb, path_idx, &map) {
                    Ok(row) => {
                        let r = map.rank();
                        if let Some(c) = lowrank.get_mut(&key) {
                            if let Some(dst) = c.phi.get_mut(path_idx * r..(path_idx + 1) * r) {
                                dst.copy_from_slice(&row);
                            }
                        }
                    }
                    Err(_) => {
                        lowrank.remove(&key);
                    }
                }
            } else {
                match build_lowrank(&cb, &opts, &spec) {
                    Ok(rebuilt) => {
                        lowrank.insert(key, rebuilt);
                    }
                    Err(_) => {
                        lowrank.remove(&key);
                    }
                }
            }
        }
        *hash = content_hash(*dim, lengths, data);
        let new_hash = *hash;
        drop(e);
        {
            let mut by_hash = lock_unpoisoned(&self.by_hash);
            if by_hash.get(&old_hash) == Some(&id.0) {
                by_hash.remove(&old_hash);
            }
            by_hash.insert(new_hash, id.0);
        }
        self.extended.fetch_add(1, Ordering::Relaxed);
        Ok(l_new)
    }

    /// Evict the oldest paths, keeping the most recent `keep` (sliding
    /// window / ring-buffer semantics). Every cache shrinks to the
    /// surviving suffix: the self-Gram keeps its bottom-right block and
    /// retained borders rekey (Gram entries are independent computations),
    /// random-signature features drop the evicted rows, and Nyström state
    /// rebuilds (its landmark pool is a corpus prefix, which eviction
    /// changes) — all bit-identical to registering the survivors from
    /// scratch. `keep = 0` is an error (an empty corpus has no means);
    /// `keep >= n` is a no-op. Returns the new path count.
    pub fn evict(&self, id: CorpusId, keep: usize) -> Result<usize, SigError> {
        if keep == 0 {
            return Err(SigError::Invalid("evict must keep at least one path"));
        }
        let arc = self.entry(id)?;
        let mut e = write_unpoisoned(&arc);
        let n_old = e.lengths.len();
        if keep >= n_old {
            return Ok(n_old);
        }
        let drop_n = n_old - keep;
        let old_hash = e.hash;
        let drop_pts: usize = e.lengths.iter().take(drop_n).sum();
        e.data.drain(..drop_pts * e.dim);
        e.lengths.drain(..drop_n);
        e.born.drain(..drop_n);
        let n = keep;
        let CorpusEntry {
            dim,
            data,
            lengths,
            hash,
            exact,
            lowrank,
            ..
        } = &mut *e;
        for c in exact.values_mut() {
            let mut kcc = vec![0.0; n * n];
            for (dst, src) in kcc.chunks_mut(n).zip(c.kcc.chunks(n_old).skip(drop_n)) {
                if let Some(tail) = src.get(drop_n..drop_n + n) {
                    dst.copy_from_slice(tail);
                }
            }
            c.kcc = kcc;
            let old_borders = std::mem::take(&mut c.borders);
            for ((a, b), pb) in old_borders {
                if a >= drop_n && b >= drop_n {
                    c.borders.insert((a - drop_n, b - drop_n), pb);
                }
            }
        }
        let cb = PathBatch::ragged(data, lengths, *dim)?;
        let lr_keys: Vec<(KernelOptions, LowRankSpec)> = lowrank.keys().copied().collect();
        for key in lr_keys {
            let (opts, spec) = key;
            if matches!(spec.method, crate::kernel::LowRankMethod::RandomSig { .. }) {
                // The sketch depends only on (seed, shape): drop the
                // evicted feature rows, keep the map.
                if let Some(c) = lowrank.get_mut(&key) {
                    let r = c.map.rank();
                    c.phi.drain(..drop_n * r);
                    c.pool = spec.rank.min(n);
                }
            } else {
                match build_lowrank(&cb, &opts, &spec) {
                    Ok(rebuilt) => {
                        lowrank.insert(key, rebuilt);
                    }
                    Err(_) => {
                        lowrank.remove(&key);
                    }
                }
            }
        }
        *hash = content_hash(*dim, lengths, data);
        let new_hash = *hash;
        drop(e);
        {
            let mut by_hash = lock_unpoisoned(&self.by_hash);
            if by_hash.get(&old_hash) == Some(&id.0) {
                by_hash.remove(&old_hash);
            }
            by_hash.insert(new_hash, id.0);
        }
        self.evicted.fetch_add(1, Ordering::Relaxed);
        Ok(n)
    }

    /// Age-based eviction: drop every path whose age — in append ticks,
    /// `tick − born[i]` — exceeds `max_age`, but always keep at least
    /// `keep_floor.max(1)` paths (an empty corpus has no means). Birth
    /// ticks are non-decreasing, so the survivors are exactly the trailing
    /// fresh run and the drop reuses [`evict`](CorpusRegistry::evict) —
    /// the same cache surgery, bit-identical to registering the survivors
    /// from scratch. Returns the new path count.
    ///
    /// The run is measured on a read-locked snapshot and applied by
    /// `evict`'s own write lock; an append racing between the two only
    /// raises the count `evict` keeps, it never drops a path this scan
    /// marked fresh (eviction is count-based from the newest end).
    pub fn evict_by_age(
        &self,
        id: CorpusId,
        max_age: u64,
        keep_floor: usize,
    ) -> Result<usize, SigError> {
        let arc = self.entry(id)?;
        let keep = {
            let e = read_unpoisoned(&arc);
            let n = e.lengths.len();
            let fresh = e
                .born
                .iter()
                .position(|&b| e.tick.saturating_sub(b) <= max_age)
                .map_or(0, |first| n - first);
            fresh.max(keep_floor).max(1)
        };
        self.evict(id, keep)
    }

    /// Exponentially-weighted MMD² between a query window and the corpus:
    /// the newest window path (the *last* row of `q`) has weight 1 and each
    /// older path decays by `decay ∈ (0, 1]`. `decay = 1` recovers the
    /// uniform estimator up to floating-point summation order. Exact-kernel
    /// only; the corpus term is served from the cached self-Gram.
    pub fn mmd2_window(
        &self,
        id: CorpusId,
        q: &PathBatch<'_>,
        opts: &KernelOptions,
        decay: f64,
    ) -> Result<f64, SigError> {
        self.mmd2_window_with_grad(id, q, opts, decay).map(|(v, _)| v)
    }

    /// [`mmd2_window`](CorpusRegistry::mmd2_window) plus the analytic
    /// derivative of the weighted estimator with respect to `decay`
    /// (FD-checked in the property tests) — the knob a monitor tunes.
    pub fn mmd2_window_with_grad(
        &self,
        id: CorpusId,
        q: &PathBatch<'_>,
        opts: &KernelOptions,
        decay: f64,
    ) -> Result<(f64, f64), SigError> {
        if !decay.is_finite() || decay <= 0.0 || decay > 1.0 {
            return Err(SigError::Invalid("window decay must lie in (0, 1]"));
        }
        self.queries.fetch_add(1, Ordering::Relaxed);
        let arc = self.entry(id)?;
        // Same warm/cold locking discipline as `mmd2_query`: query-side
        // solves always run under the shared lock.
        let mut just_built = false;
        loop {
            {
                let e = read_unpoisoned(&arc);
                e.check_query(q, opts)?;
                if let Some(c) = e.exact.get(opts) {
                    if !just_built {
                        self.warm_hits.fetch_add(1, Ordering::Relaxed);
                    }
                    return self.mmd2_window_value(&e, q, opts, &c.kcc, decay);
                }
            }
            let mut e = write_unpoisoned(&arc);
            e.check_query(q, opts)?;
            if e.exact.get(opts).is_none() {
                let kcc = build_kcc(&self.tiles, &e.batch()?, opts)?;
                e.exact.insert(
                    *opts,
                    ExactCache {
                        kcc,
                        borders: HashMap::new(),
                    },
                );
                self.cold_builds.fetch_add(1, Ordering::Relaxed);
                just_built = true;
            }
        }
    }

    /// Cross-Gram `[q.batch(), n]` of a query batch against the corpus —
    /// exact (tiled PDE solves) or, with a spec, low-rank `Φ_q · Φ_cᵀ`
    /// reusing the cached corpus features.
    pub fn gram_query(
        &self,
        id: CorpusId,
        q: &PathBatch<'_>,
        opts: &KernelOptions,
        lowrank: Option<&LowRankSpec>,
    ) -> Result<Vec<f64>, SigError> {
        self.queries.fetch_add(1, Ordering::Relaxed);
        let arc = self.entry(id)?;
        match lowrank {
            None => {
                let e = read_unpoisoned(&arc);
                e.check_query(q, opts)?;
                let n = e.lengths.len();
                let total = q
                    .batch()
                    .checked_mul(n)
                    .filter(|&t| t <= MAX_BATCH_OUT)
                    .ok_or(SigError::TooLarge("corpus gram output"))?;
                let mut out = vec![0.0; total];
                self.tiles.gram_into(q, &e.batch()?, opts, &mut out)?;
                Ok(out)
            }
            Some(spec) => self.with_lowrank(&arc, q, opts, spec, |e, map, phi| {
                let (qb, n, r) = (q.batch(), e.lengths.len(), map.rank());
                let total = qb
                    .checked_mul(n)
                    .filter(|&t| t <= MAX_BATCH_OUT)
                    .ok_or(SigError::TooLarge("corpus gram output"))?;
                let phi_q = map.try_features(q)?;
                let mut out = vec![0.0; total];
                gemm_nt(qb, r, n, &phi_q, phi, &mut out);
                Ok(out)
            }),
        }
    }

    /// Biased MMD² between a query batch and the corpus. Exact queries
    /// reuse the cached corpus self-Gram (only the query-side `K_qq` and
    /// the cross `K_qc` are solved); low-rank queries reuse the cached
    /// feature map and corpus features (only the query rows are
    /// featurised).
    pub fn mmd2_query(
        &self,
        id: CorpusId,
        q: &PathBatch<'_>,
        opts: &KernelOptions,
        lowrank: Option<&LowRankSpec>,
    ) -> Result<f64, SigError> {
        self.queries.fetch_add(1, Ordering::Relaxed);
        let arc = self.entry(id)?;
        match lowrank {
            None => {
                // Query-side work always runs under the *shared* lock —
                // the exclusive lock is held only while building the
                // self-Gram, so concurrent warm queries are never blocked
                // behind another query's K_qq/K_qc solves.
                let mut just_built = false;
                loop {
                    {
                        let e = read_unpoisoned(&arc);
                        e.check_query(q, opts)?;
                        if let Some(c) = e.exact.get(opts) {
                            if !just_built {
                                self.warm_hits.fetch_add(1, Ordering::Relaxed);
                            }
                            return self.mmd2_exact_value(&e, q, opts, &c.kcc);
                        }
                    }
                    // Cold: build (or pick up a racing build of) the
                    // self-Gram, release, and retry the warm path. The
                    // cache can only vanish again if a concurrent append's
                    // extension failed — then the next lap rebuilds.
                    let mut e = write_unpoisoned(&arc);
                    e.check_query(q, opts)?;
                    if e.exact.get(opts).is_none() {
                        let kcc = build_kcc(&self.tiles, &e.batch()?, opts)?;
                        e.exact.insert(
                            *opts,
                            ExactCache {
                                kcc,
                                borders: HashMap::new(),
                            },
                        );
                        self.cold_builds.fetch_add(1, Ordering::Relaxed);
                        just_built = true;
                    }
                }
            }
            Some(spec) => self.with_lowrank(&arc, q, opts, spec, |e, map, phi| {
                let r = map.rank();
                let phi_q = map.try_features(q)?;
                let mq = feature_mean(&phi_q, q.batch(), r);
                let mc = feature_mean(phi, e.lengths.len(), r);
                Ok(mq
                    .iter()
                    .zip(mc.iter())
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum())
            }),
        }
    }

    /// Number of paths in a corpus.
    pub fn path_count(&self, id: CorpusId) -> Option<usize> {
        let arc = lock_unpoisoned(&self.entries).get(&id.0).cloned()?;
        let n = read_unpoisoned(&arc).lengths.len();
        Some(n)
    }

    /// Path dimension of a corpus.
    pub fn dim_of(&self, id: CorpusId) -> Option<usize> {
        let arc = lock_unpoisoned(&self.entries).get(&id.0).cloned()?;
        let d = read_unpoisoned(&arc).dim;
        Some(d)
    }

    /// Registered corpus ids, ascending.
    pub fn ids(&self) -> Vec<CorpusId> {
        let mut ids: Vec<CorpusId> = lock_unpoisoned(&self.entries)
            .keys()
            .map(|&v| CorpusId(v))
            .collect();
        ids.sort_unstable();
        ids
    }

    /// Observability counters.
    pub fn stats(&self) -> CorpusStats {
        CorpusStats {
            registered: self.registered.load(Ordering::Relaxed),
            appended: self.appended.load(Ordering::Relaxed),
            queries: self.queries.load(Ordering::Relaxed),
            warm_hits: self.warm_hits.load(Ordering::Relaxed),
            cold_builds: self.cold_builds.load(Ordering::Relaxed),
            extended: self.extended.load(Ordering::Relaxed),
            evicted: self.evicted.load(Ordering::Relaxed),
        }
    }

    fn entry(&self, id: CorpusId) -> Result<Arc<RwLock<CorpusEntry>>, SigError> {
        lock_unpoisoned(&self.entries)
            .get(&id.0)
            .cloned()
            .ok_or(SigError::Invalid("unknown corpus id"))
    }

    /// Serialise every registered corpus — path data *and* warm derived
    /// state (self-Grams, retained Goursat borders, low-rank features) — to
    /// `path` in the versioned, checksummed snapshot format of
    /// [`persist`](crate::corpus::persist). The write is atomic (temp file
    /// in the same directory + rename), so a crash mid-write leaves any
    /// previous snapshot intact. Returns the number of corpora written.
    pub fn snapshot_to(&self, path: &std::path::Path) -> Result<usize, SigError> {
        let exports = self.export_all();
        let n = exports.len();
        persist::write_snapshot(&exports, path)?;
        Ok(n)
    }

    /// Rebuild a registry from a snapshot written by
    /// [`snapshot_to`](CorpusRegistry::snapshot_to). Every section's
    /// content hash is re-verified: a corrupt **path** section (or a
    /// damaged header / truncated file) fails the whole load with
    /// [`SigError::SnapshotCorrupt`]; a corrupt or shape-inconsistent
    /// **derived-state** section is dropped silently and rebuilt lazily by
    /// the next query that needs it. A restored registry answers every
    /// query path bit-identically to the one that was snapshotted
    /// (property-tested in `tests/props_persist.rs`).
    pub fn restore_from(path: &std::path::Path) -> Result<CorpusRegistry, SigError> {
        let exports = persist::read_snapshot(path)?;
        let reg = CorpusRegistry::new();
        for exp in exports {
            reg.import(exp)?;
        }
        Ok(reg)
    }

    /// Plain-data view of every entry for the snapshot writer. Ids are
    /// exported ascending; per-entry locks are taken one at a time (shared),
    /// so queries keep flowing while a snapshot streams out.
    fn export_all(&self) -> Vec<CorpusExport> {
        let arcs: Vec<(u32, Arc<RwLock<CorpusEntry>>)> = {
            let entries = lock_unpoisoned(&self.entries);
            let mut v: Vec<_> = entries.iter().map(|(&id, a)| (id, a.clone())).collect();
            v.sort_unstable_by_key(|(id, _)| *id);
            v
        };
        let mut out = Vec::with_capacity(arcs.len());
        for (id, arc) in arcs {
            let e = read_unpoisoned(&arc);
            let exact = e
                .exact
                .iter()
                .map(|(opts, c)| {
                    let mut borders: Vec<BorderExport> = c
                        .borders
                        .iter()
                        .map(|(&(i, j), b)| BorderExport {
                            i,
                            j,
                            border: b.clone(),
                        })
                        .collect();
                    borders.sort_unstable_by_key(|b| (b.i, b.j));
                    ExactExport {
                        opts: *opts,
                        kcc: c.kcc.clone(),
                        borders,
                    }
                })
                .collect();
            let lowrank = e
                .lowrank
                .iter()
                .map(|(&(opts, spec), c)| LowRankExport {
                    opts,
                    spec,
                    pool: c.pool,
                    phi: c.phi.clone(),
                })
                .collect();
            out.push(CorpusExport {
                id,
                dim: e.dim,
                tick: e.tick,
                hash: e.hash,
                lengths: e.lengths.clone(),
                born: e.born.clone(),
                data: e.data.clone(),
                exact,
                lowrank,
            });
        }
        out
    }

    /// Install one decoded corpus. The path payload is re-validated
    /// end-to-end (shape, birth-tick monotonicity, content hash) — any
    /// mismatch is [`SigError::SnapshotCorrupt`]. Derived state that does
    /// not fit the restored paths is dropped, never installed stale.
    fn import(&self, exp: CorpusExport) -> Result<(), SigError> {
        let CorpusExport {
            id,
            dim,
            tick,
            hash,
            lengths,
            born,
            data,
            exact,
            lowrank,
        } = exp;
        let corrupt = |m: &str| SigError::SnapshotCorrupt(m.to_string());
        if lengths.is_empty() || born.len() != lengths.len() {
            return Err(corrupt("corpus section: lengths/born tables disagree"));
        }
        let births_sorted = born.windows(2).all(|w| match w {
            [a, b] => a <= b,
            _ => true,
        });
        if !births_sorted || born.last().copied().unwrap_or(0) > tick {
            return Err(corrupt("corpus section: birth ticks out of order"));
        }
        let n = lengths.len();
        let (exact_map, lr_map) = {
            let cb = PathBatch::ragged(&data, &lengths, dim)
                .map_err(|e| SigError::SnapshotCorrupt(format!("corpus section: {e}")))?;
            if content_hash(dim, &lengths, &data) != hash {
                return Err(corrupt("corpus section: content hash mismatch"));
            }
            let mut exact_map = HashMap::new();
            for ex in exact {
                let want = n.checked_mul(n).filter(|&t| t <= MAX_BATCH_OUT);
                if want != Some(ex.kcc.len()) {
                    continue; // dropped: wrong shape for the restored corpus
                }
                let mut borders = HashMap::new();
                let fits = ex.borders.iter().all(|b| b.i < n && b.j < n);
                if !fits {
                    continue;
                }
                for b in ex.borders {
                    borders.insert((b.i, b.j), b.border);
                }
                exact_map.insert(
                    ex.opts,
                    ExactCache {
                        kcc: ex.kcc,
                        borders,
                    },
                );
            }
            let mut lr_map = HashMap::new();
            for lr in lowrank {
                if let Ok(cache) = restore_lowrank(&cb, &lr.opts, &lr.spec, lr.pool, lr.phi) {
                    lr_map.insert((lr.opts, lr.spec), cache);
                }
            }
            (exact_map, lr_map)
        };
        let entry = CorpusEntry {
            dim,
            data,
            lengths,
            hash,
            tick,
            born,
            exact: exact_map,
            lowrank: lr_map,
        };
        {
            let mut by_hash = lock_unpoisoned(&self.by_hash);
            let mut entries = lock_unpoisoned(&self.entries);
            if entries.contains_key(&id) {
                return Err(corrupt("corpus section: duplicate corpus id"));
            }
            entries.insert(id, Arc::new(RwLock::new(entry)));
            by_hash.insert(hash, id);
        }
        self.next_id.fetch_max(id.saturating_add(1), Ordering::Relaxed);
        self.registered.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Run `body` with the (warm or freshly built) low-rank state for
    /// (opts, spec), updating the warm/cold counters.
    fn with_lowrank<R>(
        &self,
        arc: &Arc<RwLock<CorpusEntry>>,
        q: &PathBatch<'_>,
        opts: &KernelOptions,
        spec: &LowRankSpec,
        body: impl Fn(&CorpusEntry, &FeatureMap, &[f64]) -> Result<R, SigError>,
    ) -> Result<R, SigError> {
        let key = (*opts, *spec);
        // Same locking discipline as the exact route: the exclusive lock
        // covers only the feature-state build; `body` (query featurisation)
        // always runs under the shared lock.
        let mut just_built = false;
        loop {
            {
                let e = read_unpoisoned(arc);
                e.check_query(q, opts)?;
                if let Some(c) = e.lowrank.get(&key) {
                    if !just_built {
                        self.warm_hits.fetch_add(1, Ordering::Relaxed);
                    }
                    return body(&e, &c.map, &c.phi);
                }
            }
            let mut e = write_unpoisoned(arc);
            e.check_query(q, opts)?;
            if e.lowrank.get(&key).is_none() {
                let built = build_lowrank(&e.batch()?, opts, spec)?;
                e.lowrank.insert(key, built);
                self.cold_builds.fetch_add(1, Ordering::Relaxed);
                just_built = true;
            }
        }
    }

    /// `mean(K_qq) − 2·mean(K_qc) + mean(K_cc)` with the corpus term served
    /// from cache — the same estimator (and the same summation order) as
    /// [`OpSpec::Mmd2`](crate::engine::OpSpec::Mmd2).
    fn mmd2_exact_value(
        &self,
        e: &CorpusEntry,
        q: &PathBatch<'_>,
        opts: &KernelOptions,
        kcc: &[f64],
    ) -> Result<f64, SigError> {
        let qb = q.batch();
        let n = e.lengths.len();
        let gram_len = |a: usize, b: usize| -> Result<usize, SigError> {
            a.checked_mul(b)
                .filter(|&t| t <= MAX_BATCH_OUT)
                .ok_or(SigError::TooLarge("corpus mmd2 gram matrices"))
        };
        let mut kqq = vec![0.0; gram_len(qb, qb)?];
        self.tiles.gram_into(q, q, opts, &mut kqq)?;
        let mut kqc = vec![0.0; gram_len(qb, n)?];
        self.tiles.gram_into(q, &e.batch()?, opts, &mut kqc)?;
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        Ok(mean(&kqq) - 2.0 * mean(&kqc) + mean(kcc))
    }

    /// Weighted MMD² and its ∂/∂decay. With `w_i = decay^(q−1−i)` and
    /// `S = Σ w_i`:
    ///
    ///   MMD²_w = (Σ_ij w_i w_j K_qq[i,j]) / S²
    ///          − 2·(Σ_i w_i Σ_j K_qc[i,j]) / (S·n) + mean(K_cc)
    ///
    /// The derivative follows by the product/quotient rules with
    /// `w_i' = (q−1−i)·decay^(q−2−i)`; the corpus term is constant.
    fn mmd2_window_value(
        &self,
        e: &CorpusEntry,
        q: &PathBatch<'_>,
        opts: &KernelOptions,
        kcc: &[f64],
        decay: f64,
    ) -> Result<(f64, f64), SigError> {
        let qb = q.batch();
        let n = e.lengths.len();
        let gram_len = |a: usize, b: usize| -> Result<usize, SigError> {
            a.checked_mul(b)
                .filter(|&t| t <= MAX_BATCH_OUT)
                .ok_or(SigError::TooLarge("corpus mmd2 gram matrices"))
        };
        let mut kqq = vec![0.0; gram_len(qb, qb)?];
        self.tiles.gram_into(q, q, opts, &mut kqq)?;
        let mut kqc = vec![0.0; gram_len(qb, n)?];
        self.tiles.gram_into(q, &e.batch()?, opts, &mut kqc)?;
        let mut w = vec![0.0; qb];
        let mut dw = vec![0.0; qb];
        for (i, (wi, dwi)) in w.iter_mut().zip(dw.iter_mut()).enumerate() {
            let p = (qb - 1 - i) as i32;
            *wi = decay.powi(p);
            *dwi = if p == 0 { 0.0 } else { p as f64 * decay.powi(p - 1) };
        }
        let s: f64 = w.iter().sum();
        let ds: f64 = dw.iter().sum();
        let (mut a, mut da) = (0.0, 0.0);
        for ((wi, dwi), row) in w.iter().zip(dw.iter()).zip(kqq.chunks(qb)) {
            for ((wj, dwj), &kv) in w.iter().zip(dw.iter()).zip(row.iter()) {
                a += wi * wj * kv;
                da += (dwi * wj + wi * dwj) * kv;
            }
        }
        let (mut b, mut db) = (0.0, 0.0);
        for ((wi, dwi), row) in w.iter().zip(dw.iter()).zip(kqc.chunks(n.max(1))) {
            let rs: f64 = row.iter().sum();
            b += wi * rs;
            db += dwi * rs;
        }
        let c = kcc.iter().sum::<f64>() / kcc.len().max(1) as f64;
        let nn = n.max(1) as f64;
        let s2 = s * s;
        let value = a / s2 - 2.0 * b / (s * nn) + c;
        let grad = da / s2 - 2.0 * a * ds / (s2 * s) - 2.0 * db / (s * nn)
            + 2.0 * b * ds / (s2 * nn);
        Ok((value, grad))
    }
}

/// The corpus suffix `paths[n_old..]` as its own batch view.
fn suffix_batch<'a>(cb: &PathBatch<'a>, n_old: usize) -> Result<PathBatch<'a>, SigError> {
    let dim = cb.dim();
    let split = cb
        .offsets()
        .get(n_old)
        .copied()
        .ok_or(SigError::Invalid("internal: append offset out of bounds"))?
        * dim;
    let lens: Vec<usize> = (n_old..cb.batch()).map(|i| cb.len_of(i)).collect();
    let data = cb
        .data()
        .get(split..)
        .ok_or(SigError::Invalid("internal: append split exceeds corpus data"))?;
    PathBatch::ragged(data, &lens, dim)
}

/// Full corpus self-Gram (the cold build).
fn build_kcc(
    tiles: &TileScheduler,
    cb: &PathBatch<'_>,
    opts: &KernelOptions,
) -> Result<Vec<f64>, SigError> {
    let n = cb.batch();
    let total = n
        .checked_mul(n)
        .filter(|&t| t <= MAX_BATCH_OUT)
        .ok_or(SigError::TooLarge("corpus self-Gram"))?;
    let mut kcc = vec![0.0; total];
    tiles.gram_into(cb, cb, opts, &mut kcc)?;
    Ok(kcc)
}

/// Grow a cached `[n_old, n_old]` self-Gram to `[n, n]`: copy the retained
/// block, solve only the two new strips.
fn grow_kcc(
    tiles: &TileScheduler,
    cb: &PathBatch<'_>,
    old: &[f64],
    n_old: usize,
    n: usize,
    opts: &KernelOptions,
) -> Result<Vec<f64>, SigError> {
    let total = n
        .checked_mul(n)
        .filter(|&t| t <= MAX_BATCH_OUT)
        .ok_or(SigError::TooLarge("corpus self-Gram"))?;
    let mut kcc = vec![0.0; total];
    if n_old > 0 {
        for (dst, src) in kcc.chunks_mut(n).zip(old.chunks(n_old)).take(n_old) {
            if let Some(head) = dst.get_mut(..n_old) {
                head.copy_from_slice(src);
            }
        }
    }
    tiles.gram_block_into(cb, 0..n_old, cb, n_old..n, opts, &mut kcc, n, 0, n_old)?;
    tiles.gram_block_into(cb, n_old..n, cb, 0..n, opts, &mut kcc, n, n_old, 0)?;
    Ok(kcc)
}

/// Extend one cached exact self-Gram in place after path `k` grew from
/// `l_old` to its current length: only row/column `k` change. With the row
/// solver each ordered pair advances by a Goursat border strip (the first
/// touch pays a full retaining solve); the blocked solver's schedule has a
/// different floating-point order than the border sweep, so it re-solves
/// the row/column through the tile scheduler instead. Both are
/// bit-identical to a from-scratch rebuild because every Gram entry is an
/// independent computation.
fn extend_exact_cache(
    tiles: &TileScheduler,
    cb: &PathBatch<'_>,
    cache: &mut ExactCache,
    k: usize,
    l_old: usize,
    opts: &KernelOptions,
) -> Result<(), SigError> {
    let n = cb.batch();
    let l_new = cb.len_of(k);
    let mc = (0..n).map(|j| cb.len_of(j)).max().unwrap_or(0);
    if l_new >= 2 && mc >= 2 {
        crate::kernel::check_grid_size(l_new, mc, opts)?;
    }
    if opts.solver != SolverKind::Row {
        tiles.gram_block_into(cb, k..k + 1, cb, 0..n, opts, &mut cache.kcc, n, k, 0)?;
        tiles.gram_block_into(cb, 0..n, cb, k..k + 1, opts, &mut cache.kcc, n, 0, k)?;
        cache.borders.retain(|&(a, b), _| a != k && b != k);
        return Ok(());
    }
    let dim = cb.dim();
    let tr = opts.exec.transform;
    let (lam1, lam2) = (opts.dyadic_x, opts.dyadic_y);
    let x_new = cb.values_of(k);
    let lx_sub = l_new - l_old + 1; // overlap point + appended points
    let sub_start = l_old.saturating_sub(1) * dim;
    let sub = x_new.get(sub_start..).unwrap_or(&[]);
    let stripable = l_old >= 2;
    for j in 0..n {
        if j == k {
            // Diagonal pair: both sides grew — columns first across the old
            // rows, then the new rows at full width (see kernel::border).
            let full_m = tr.out_len(l_new).saturating_sub(1);
            let t = match cache.borders.get_mut(&(k, k)) {
                Some(bd) if stripable => {
                    let x_old = x_new.get(..l_old * dim).unwrap_or(&[]);
                    let (m1, n1, strip) =
                        delta_strip(x_old, sub, l_old, lx_sub, dim, tr, full_m, full_m)?;
                    border::extend_cols_scheme(bd, &strip, m1, n1, lam1, lam2)?;
                    let (m2, n2, strip) =
                        delta_strip(sub, x_new, lx_sub, l_new, dim, tr, full_m, full_m)?;
                    border::extend_rows_scheme(bd, &strip, m2, n2, lam1, lam2)?;
                    bd.terminal()
                }
                _ => {
                    let (m, nn, dl) = delta_matrix(x_new, x_new, l_new, l_new, dim, tr);
                    let bd =
                        border::solve_full_retain_scheme(&dl, m, nn, lam1, lam2, opts.scheme)?;
                    let t = bd.terminal();
                    cache.borders.insert((k, k), bd);
                    t
                }
            };
            if let Some(slot) = cache.kcc.get_mut(k * n + k) {
                *slot = t;
            }
            continue;
        }
        let ly = cb.len_of(j);
        if ly < 2 {
            // Degenerate partner: the kernel is the constant 1, exactly as
            // the scalar per-pair path resolves it.
            for idx in [k * n + j, j * n + k] {
                if let Some(slot) = cache.kcc.get_mut(idx) {
                    *slot = 1.0;
                }
            }
            continue;
        }
        let y = cb.values_of(j);
        let full_rows = tr.out_len(l_new).saturating_sub(1);
        let full_cols = tr.out_len(ly).saturating_sub(1);
        // Pair (k, j): the extended path supplies the grid rows.
        let t = match cache.borders.get_mut(&(k, j)) {
            Some(bd) if stripable => {
                let (m1, n1, strip) =
                    delta_strip(sub, y, lx_sub, ly, dim, tr, full_rows, full_cols)?;
                border::extend_rows_scheme(bd, &strip, m1, n1, lam1, lam2)?;
                bd.terminal()
            }
            _ => {
                let (m, nn, dl) = delta_matrix(x_new, y, l_new, ly, dim, tr);
                let bd = border::solve_full_retain_scheme(&dl, m, nn, lam1, lam2, opts.scheme)?;
                let t = bd.terminal();
                cache.borders.insert((k, j), bd);
                t
            }
        };
        if let Some(slot) = cache.kcc.get_mut(k * n + j) {
            *slot = t;
        }
        // Pair (j, k): the extended path supplies the grid columns.
        let t = match cache.borders.get_mut(&(j, k)) {
            Some(bd) if stripable => {
                let (m1, n1, strip) =
                    delta_strip(y, sub, ly, lx_sub, dim, tr, full_cols, full_rows)?;
                border::extend_cols_scheme(bd, &strip, m1, n1, lam1, lam2)?;
                bd.terminal()
            }
            _ => {
                let (m, nn, dl) = delta_matrix(y, x_new, ly, l_new, dim, tr);
                let bd = border::solve_full_retain_scheme(&dl, m, nn, lam1, lam2, opts.scheme)?;
                let t = bd.terminal();
                cache.borders.insert((j, k), bd);
                t
            }
        };
        if let Some(slot) = cache.kcc.get_mut(j * n + k) {
            *slot = t;
        }
    }
    Ok(())
}

/// Fused Δ of a sub-path pair with the time-augmentation shift taken from
/// the *full* transformed pair extents (`full_rows`/`full_cols`,
/// transformed increment counts). The shift is uniform across a grid, so
/// every strip entry bit-matches the corresponding block of the full
/// pair's [`delta_matrix`] — the property the border sweeps rely on.
#[allow(clippy::too_many_arguments)]
fn delta_strip(
    x: &[f64],
    y: &[f64],
    lx: usize,
    ly: usize,
    dim: usize,
    tr: Transform,
    full_rows: usize,
    full_cols: usize,
) -> Result<(usize, usize, Vec<f64>), SigError> {
    if lx < 2 || ly < 2 || full_rows == 0 || full_cols == 0 || x.len() != lx * dim
        || y.len() != ly * dim
    {
        return Err(SigError::Invalid("delta strip: sub-path shape mismatch"));
    }
    let m = lx - 1;
    let n = ly - 1;
    let mut dx = vec![0.0; m * dim];
    let mut dy = vec![0.0; n * dim];
    increments_into(x, lx, dim, &mut dx);
    increments_into(y, ly, dim, &mut dy);
    let shift = match tr {
        Transform::None | Transform::LeadLag => 0.0,
        Transform::TimeAug | Transform::LeadLagTimeAug => {
            (1.0 / full_rows as f64) * (1.0 / full_cols as f64)
        }
    };
    match tr {
        Transform::None | Transform::TimeAug => {
            let mut out = vec![0.0; m * n];
            gemm_nt(m, dim, n, &dx, &dy, &mut out);
            if tr == Transform::TimeAug {
                for v in out.iter_mut() {
                    *v += shift;
                }
            }
            Ok((m, n, out))
        }
        Transform::LeadLag | Transform::LeadLagTimeAug => {
            let mut base = vec![0.0; m * n];
            gemm_nt(m, dim, n, &dx, &dy, &mut base);
            let rows = 2 * lx - 2;
            let cols = 2 * ly - 2;
            let mut out = vec![shift; rows * cols];
            for (a, orow) in out.chunks_mut(cols).enumerate() {
                let Some(brow) = base.get((a / 2) * n..(a / 2) * n + n) else {
                    continue;
                };
                for (b, o) in orow.iter_mut().enumerate() {
                    if a % 2 == b % 2 {
                        if let Some(&v) = brow.get(b / 2) {
                            *o += v;
                        }
                    }
                }
            }
            Ok((rows, cols, out))
        }
    }
}

/// Feature row of one corpus path under a frozen map. Per-path features
/// are independent computations (cross-Gram rows / signature sketches), so
/// a single-path batch yields the same bits as the full-batch build.
fn refeaturise_row(
    cb: &PathBatch<'_>,
    idx: usize,
    map: &FeatureMap,
) -> Result<Vec<f64>, SigError> {
    let lens = [cb.len_of(idx)];
    let single = PathBatch::ragged(cb.values_of(idx), &lens, cb.dim())?;
    map.try_features(&single)
}

/// Cold build of the low-rank state: map from the landmark pool (the first
/// `min(rank, n)` paths), features for the whole corpus.
fn build_lowrank(
    cb: &PathBatch<'_>,
    opts: &KernelOptions,
    spec: &LowRankSpec,
) -> Result<LowRankCache, SigError> {
    spec.validate()?;
    let n = cb.batch();
    let pool = spec.rank.min(n);
    let pool_lens: Vec<usize> = (0..pool).map(|i| cb.len_of(i)).collect();
    let split = cb
        .offsets()
        .get(pool)
        .copied()
        .ok_or(SigError::Invalid("internal: landmark pool out of bounds"))?
        * cb.dim();
    let data = cb
        .data()
        .get(..split)
        .ok_or(SigError::Invalid("internal: landmark split exceeds corpus data"))?;
    let pool_batch = PathBatch::ragged(data, &pool_lens, cb.dim())?;
    let map = Arc::new(FeatureMap::try_build(spec, opts, &pool_batch)?);
    let phi = map.try_features(cb)?;
    Ok(LowRankCache { map, phi, pool })
}

/// Restore a low-rank cache from snapshotted state: the feature matrix
/// `Φ_c` travels in the snapshot (it is the expensive O(n) part), while the
/// feature map is rebuilt deterministically from the landmark pool — the
/// same seeded construction as [`build_lowrank`], so the restored map is
/// bit-identical to the snapshotted one. Any shape disagreement with the
/// restored corpus is an error; the caller drops the section and the next
/// query rebuilds from scratch.
fn restore_lowrank(
    cb: &PathBatch<'_>,
    opts: &KernelOptions,
    spec: &LowRankSpec,
    pool: usize,
    phi: Vec<f64>,
) -> Result<LowRankCache, SigError> {
    spec.validate()?;
    let n = cb.batch();
    if pool != spec.rank.min(n) {
        return Err(SigError::Invalid(
            "restored landmark pool does not match the corpus",
        ));
    }
    let pool_lens: Vec<usize> = (0..pool).map(|i| cb.len_of(i)).collect();
    let split = cb
        .offsets()
        .get(pool)
        .copied()
        .ok_or(SigError::Invalid("internal: landmark pool out of bounds"))?
        * cb.dim();
    let data = cb
        .data()
        .get(..split)
        .ok_or(SigError::Invalid("internal: landmark split exceeds corpus data"))?;
    let pool_batch = PathBatch::ragged(data, &pool_lens, cb.dim())?;
    let map = Arc::new(FeatureMap::try_build(spec, opts, &pool_batch)?);
    let want = n
        .checked_mul(map.rank())
        .ok_or(SigError::TooLarge("restored feature matrix"))?;
    if phi.len() != want {
        return Err(SigError::Invalid(
            "restored feature matrix has the wrong shape",
        ));
    }
    Ok(LowRankCache { map, phi, pool })
}
