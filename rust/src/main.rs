//! pysiglib CLI: compute signatures / kernels, run the serving coordinator,
//! and drive workloads. See `pysiglib help`.

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(pysiglib::cli::cli_main(&args));
}
