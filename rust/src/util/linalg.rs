//! Minimal dense linear algebra: a cache-blocked GEMM used to precompute the
//! increment inner-product matrix Δ = dx · dyᵀ for signature kernels
//! (pySigLib realises this with torch.bmm; here it is a hand-rolled blocked
//! kernel), plus small helpers for the examples.

/// C[m,n] = A[m,k] · B[k,n]ᵀ-free row-major GEMM: C = A * B.
/// Plain ijk with k-blocking and an unrolled inner loop; enough to keep the
/// Δ precompute off the profile at bench sizes.
pub fn gemm(m: usize, k: usize, n: usize, a: &[f64], b: &[f64], c: &mut [f64]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    c.fill(0.0);
    const BK: usize = 64;
    for k0 in (0..k).step_by(BK) {
        let kend = (k0 + BK).min(k);
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut c[i * n..(i + 1) * n];
            for p in k0..kend {
                let av = arow[p];
                if av == 0.0 {
                    continue;
                }
                let brow = &b[p * n..(p + 1) * n];
                // Autovectorises: contiguous fused multiply-add over n.
                for (cv, bv) in crow.iter_mut().zip(brow.iter()) {
                    *cv += av * bv;
                }
            }
        }
    }
}

/// C = A · Bᵀ with A[m,k], B[n,k] row-major (the Δ = dx·dyᵀ case).
pub fn gemm_nt(m: usize, k: usize, n: usize, a: &[f64], b: &[f64], c: &mut [f64]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), n * k);
    assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            let mut acc = 0.0;
            for p in 0..k {
                acc += arow[p] * brow[p];
            }
            crow[j] = acc;
        }
    }
}

/// Dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0;
    for i in 0..a.len() {
        acc += a[i] * b[i];
    }
    acc
}

/// y += alpha * x
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

/// Euclidean norm.
pub fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// Max absolute difference between two slices.
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f64::max)
}

/// Relative L2 error ‖a-b‖/(‖b‖+eps).
pub fn rel_err(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut num = 0.0;
    let mut den = 0.0;
    for (x, y) in a.iter().zip(b) {
        num += (x - y) * (x - y);
        den += y * y;
    }
    num.sqrt() / (den.sqrt() + 1e-300)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive_gemm(m: usize, k: usize, n: usize, a: &[f64], b: &[f64]) -> Vec<f64> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                for p in 0..k {
                    c[i * n + j] += a[i * k + p] * b[p * n + j];
                }
            }
        }
        c
    }

    #[test]
    fn gemm_matches_naive() {
        let mut r = Rng::new(5);
        for &(m, k, n) in &[(1, 1, 1), (3, 4, 5), (17, 33, 9), (64, 128, 32)] {
            let mut a = vec![0.0; m * k];
            let mut b = vec![0.0; k * n];
            r.fill_normal(&mut a);
            r.fill_normal(&mut b);
            let mut c = vec![0.0; m * n];
            gemm(m, k, n, &a, &b, &mut c);
            let want = naive_gemm(m, k, n, &a, &b);
            assert!(max_abs_diff(&c, &want) < 1e-10);
        }
    }

    #[test]
    fn gemm_nt_matches_transposed_gemm() {
        let mut r = Rng::new(6);
        let (m, k, n) = (7, 5, 11);
        let mut a = vec![0.0; m * k];
        let mut bt = vec![0.0; n * k];
        r.fill_normal(&mut a);
        r.fill_normal(&mut bt);
        // b = btᵀ
        let mut b = vec![0.0; k * n];
        for j in 0..n {
            for p in 0..k {
                b[p * n + j] = bt[j * k + p];
            }
        }
        let mut c1 = vec![0.0; m * n];
        let mut c2 = vec![0.0; m * n];
        gemm_nt(m, k, n, &a, &bt, &mut c1);
        gemm(m, k, n, &a, &b, &mut c2);
        assert!(max_abs_diff(&c1, &c2) < 1e-10);
    }

    #[test]
    fn helpers() {
        assert_eq!(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
        let mut y = vec![1.0, 1.0];
        axpy(2.0, &[1.0, 2.0], &mut y);
        assert_eq!(y, vec![3.0, 5.0]);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
    }
}
