//! Minimal dense linear algebra: a cache-blocked GEMM used to precompute the
//! increment inner-product matrix Δ = dx · dyᵀ for signature kernels
//! (pySigLib realises this with torch.bmm; here it is a hand-rolled blocked
//! kernel), plus small helpers for the examples.

/// C[m,n] = A[m,k] · B[k,n]ᵀ-free row-major GEMM: C = A * B.
/// Plain ijk with k-blocking and an unrolled inner loop; enough to keep the
/// Δ precompute off the profile at bench sizes.
pub fn gemm(m: usize, k: usize, n: usize, a: &[f64], b: &[f64], c: &mut [f64]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    c.fill(0.0);
    const BK: usize = 64;
    for k0 in (0..k).step_by(BK) {
        let kend = (k0 + BK).min(k);
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut c[i * n..(i + 1) * n];
            for p in k0..kend {
                let av = arow[p];
                if av == 0.0 {
                    continue;
                }
                let brow = &b[p * n..(p + 1) * n];
                // Autovectorises: contiguous fused multiply-add over n.
                for (cv, bv) in crow.iter_mut().zip(brow.iter()) {
                    *cv += av * bv;
                }
            }
        }
    }
}

/// C = A · Bᵀ with A[m,k], B[n,k] row-major (the Δ = dx·dyᵀ case).
pub fn gemm_nt(m: usize, k: usize, n: usize, a: &[f64], b: &[f64], c: &mut [f64]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), n * k);
    assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            let mut acc = 0.0;
            for p in 0..k {
                acc += arow[p] * brow[p];
            }
            crow[j] = acc;
        }
    }
}

/// C = Aᵀ · B with A[m,n], B[m,p] row-major (the Δ-vjp `gdy = Δᵀ·dx` case).
/// Accumulation over the shared dimension runs in ascending row order for
/// every output element and zero entries of A are skipped, matching the
/// scalar per-pair adjoint loop this replaces term for term.
pub fn gemm_tn(m: usize, n: usize, p: usize, a: &[f64], b: &[f64], c: &mut [f64]) {
    assert_eq!(a.len(), m * n);
    assert_eq!(b.len(), m * p);
    assert_eq!(c.len(), n * p);
    c.fill(0.0);
    for i in 0..m {
        let arow = &a[i * n..(i + 1) * n];
        let brow = &b[i * p..(i + 1) * p];
        for (j, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let crow = &mut c[j * p..(j + 1) * p];
            // Autovectorises: contiguous fused multiply-add over p.
            for (cv, bv) in crow.iter_mut().zip(brow.iter()) {
                *cv += av * bv;
            }
        }
    }
}

/// Dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0;
    for i in 0..a.len() {
        acc += a[i] * b[i];
    }
    acc
}

/// y += alpha * x
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

/// Euclidean norm.
pub fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// Pivoted (rank-revealing) Cholesky factorisation of a symmetric PSD
/// matrix `a` (`[n, n]` row-major): finds a permutation π and a
/// lower-trapezoidal factor L such that `a[π,π] ≈ L·Lᵀ`, stopping after
/// `r` pivots once the largest residual diagonal drops below
/// `tol · max(initial diagonal)`.
///
/// Returns `(l, perm, r)` where `l` is `[n, n]` row-major in *pivoted* order
/// (only the first `r` columns are meaningful; the leading `r × r` block is
/// lower triangular with positive diagonal) and `perm[i]` is the original
/// index of pivoted row `i`. For a strictly positive-definite input and
/// `tol = 0` this is the ordinary Cholesky factorisation up to pivoting.
///
/// This is the factorisation behind the Nyström feature map
/// ([`kernel::lowrank`](crate::kernel::lowrank)): the leading `r` pivots are
/// a numerically well-conditioned landmark subset, and `K_{Z'Z'} = L₁·L₁ᵀ`
/// holds *exactly* for that subset (the truncation only drops directions the
/// remaining landmarks barely span).
pub fn pivoted_cholesky(a: &[f64], n: usize, tol: f64) -> (Vec<f64>, Vec<usize>, usize) {
    assert_eq!(a.len(), n * n);
    let mut l = vec![0.0; n * n];
    let mut perm: Vec<usize> = (0..n).collect();
    // Residual diagonal, indexed by *pivoted* position.
    let mut d: Vec<f64> = (0..n).map(|i| a[i * n + i]).collect();
    let max_diag = d.iter().cloned().fold(0.0, f64::max);
    let threshold = (tol * max_diag).max(0.0);
    for k in 0..n {
        // Greedy pivot: the largest residual diagonal.
        let (j, &dj) = d[k..]
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
            .map(|(i, v)| (k + i, v))
            .expect("k < n");
        if !(dj > threshold) || !dj.is_finite() {
            return (l, perm, k);
        }
        if j != k {
            perm.swap(k, j);
            d.swap(k, j);
            for p in 0..k {
                l.swap(k * n + p, j * n + p);
            }
        }
        let lkk = dj.sqrt();
        l[k * n + k] = lkk;
        for i in k + 1..n {
            let mut s = a[perm[i] * n + perm[k]];
            for p in 0..k {
                s -= l[i * n + p] * l[k * n + p];
            }
            let lik = s / lkk;
            l[i * n + k] = lik;
            d[i] -= lik * lik;
        }
    }
    (l, perm, n)
}

/// In-place forward substitution: solve `L·z = x` for the lower-triangular
/// leading `r × r` block of `l` (row-major with row stride `stride`),
/// overwriting `x[..r]` with `z`.
pub fn forward_substitute(l: &[f64], stride: usize, r: usize, x: &mut [f64]) {
    debug_assert!(x.len() >= r);
    for i in 0..r {
        let mut s = x[i];
        for j in 0..i {
            s -= l[i * stride + j] * x[j];
        }
        x[i] = s / l[i * stride + i];
    }
}

/// In-place back substitution against the transpose: solve `Lᵀ·z = x` for
/// the lower-triangular leading `r × r` block of `l`, overwriting `x[..r]`.
pub fn back_substitute_t(l: &[f64], stride: usize, r: usize, x: &mut [f64]) {
    debug_assert!(x.len() >= r);
    for i in (0..r).rev() {
        let mut s = x[i];
        for j in i + 1..r {
            s -= l[j * stride + i] * x[j];
        }
        x[i] = s / l[i * stride + i];
    }
}

/// Solve the symmetric positive-definite system `A·x = b` (`[n, n]`
/// row-major) by unpivoted Cholesky + two triangular solves. `None` if a
/// pivot fails (A not numerically PD) — callers add a ridge and retry.
pub fn solve_spd(a: &[f64], n: usize, b: &[f64]) -> Option<Vec<f64>> {
    assert_eq!(a.len(), n * n);
    assert_eq!(b.len(), n);
    let mut l = vec![0.0; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[i * n + j];
            for k in 0..j {
                s -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if !(s > 0.0) || !s.is_finite() {
                    return None;
                }
                l[i * n + i] = s.sqrt();
            } else {
                l[i * n + j] = s / l[j * n + j];
            }
        }
    }
    let mut x = b.to_vec();
    forward_substitute(&l, n, n, &mut x);
    back_substitute_t(&l, n, n, &mut x);
    Some(x)
}

/// Max absolute difference between two slices.
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f64::max)
}

/// Relative L2 error ‖a-b‖/(‖b‖+eps).
pub fn rel_err(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut num = 0.0;
    let mut den = 0.0;
    for (x, y) in a.iter().zip(b) {
        num += (x - y) * (x - y);
        den += y * y;
    }
    num.sqrt() / (den.sqrt() + 1e-300)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive_gemm(m: usize, k: usize, n: usize, a: &[f64], b: &[f64]) -> Vec<f64> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                for p in 0..k {
                    c[i * n + j] += a[i * k + p] * b[p * n + j];
                }
            }
        }
        c
    }

    #[test]
    fn gemm_matches_naive() {
        let mut r = Rng::new(5);
        for &(m, k, n) in &[(1, 1, 1), (3, 4, 5), (17, 33, 9), (64, 128, 32)] {
            let mut a = vec![0.0; m * k];
            let mut b = vec![0.0; k * n];
            r.fill_normal(&mut a);
            r.fill_normal(&mut b);
            let mut c = vec![0.0; m * n];
            gemm(m, k, n, &a, &b, &mut c);
            let want = naive_gemm(m, k, n, &a, &b);
            assert!(max_abs_diff(&c, &want) < 1e-10);
        }
    }

    #[test]
    fn gemm_nt_matches_transposed_gemm() {
        let mut r = Rng::new(6);
        let (m, k, n) = (7, 5, 11);
        let mut a = vec![0.0; m * k];
        let mut bt = vec![0.0; n * k];
        r.fill_normal(&mut a);
        r.fill_normal(&mut bt);
        // b = btᵀ
        let mut b = vec![0.0; k * n];
        for j in 0..n {
            for p in 0..k {
                b[p * n + j] = bt[j * k + p];
            }
        }
        let mut c1 = vec![0.0; m * n];
        let mut c2 = vec![0.0; m * n];
        gemm_nt(m, k, n, &a, &bt, &mut c1);
        gemm(m, k, n, &a, &b, &mut c2);
        assert!(max_abs_diff(&c1, &c2) < 1e-10);
    }

    #[test]
    fn gemm_tn_matches_transposed_gemm() {
        let mut r = Rng::new(7);
        let (m, n, p) = (9, 6, 4);
        let mut a = vec![0.0; m * n];
        let mut b = vec![0.0; m * p];
        r.fill_normal(&mut a);
        r.fill_normal(&mut b);
        // at = aᵀ
        let mut at = vec![0.0; n * m];
        for i in 0..m {
            for j in 0..n {
                at[j * m + i] = a[i * n + j];
            }
        }
        let mut c1 = vec![0.0; n * p];
        let mut c2 = vec![0.0; n * p];
        gemm_tn(m, n, p, &a, &b, &mut c1);
        gemm(n, m, p, &at, &b, &mut c2);
        assert!(max_abs_diff(&c1, &c2) < 1e-10);
    }

    /// Build a random symmetric PSD matrix B·Bᵀ of the given rank.
    fn random_psd(r: &mut Rng, n: usize, rank: usize) -> Vec<f64> {
        let mut b = vec![0.0; n * rank];
        r.fill_normal(&mut b);
        let mut a = vec![0.0; n * n];
        gemm_nt(n, rank, n, &b, &b, &mut a);
        a
    }

    #[test]
    fn pivoted_cholesky_reconstructs_full_rank_pd() {
        let mut r = Rng::new(21);
        for n in [1usize, 3, 7, 12] {
            let a = random_psd(&mut r, n, n + 2); // full rank a.s.
            let (l, perm, rank) = pivoted_cholesky(&a, n, 1e-12);
            assert_eq!(rank, n);
            // a[perm[i], perm[j]] == (L·Lᵀ)[i, j]
            for i in 0..n {
                for j in 0..n {
                    let mut s = 0.0;
                    for k in 0..rank {
                        s += l[i * n + k] * l[j * n + k];
                    }
                    assert!(
                        (s - a[perm[i] * n + perm[j]]).abs() < 1e-9,
                        "n={n} ({i},{j})"
                    );
                }
            }
        }
    }

    #[test]
    fn pivoted_cholesky_reveals_rank_deficiency() {
        let mut r = Rng::new(22);
        let (n, true_rank) = (8, 3);
        let a = random_psd(&mut r, n, true_rank);
        let (l, perm, rank) = pivoted_cholesky(&a, n, 1e-10);
        assert_eq!(rank, true_rank);
        // The truncated factor still reconstructs the matrix.
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..rank {
                    s += l[i * n + k] * l[j * n + k];
                }
                assert!((s - a[perm[i] * n + perm[j]]).abs() < 1e-8);
            }
        }
    }

    #[test]
    fn pivoted_cholesky_zero_matrix_has_rank_zero() {
        let (_, _, rank) = pivoted_cholesky(&[0.0; 9], 3, 1e-12);
        assert_eq!(rank, 0);
    }

    #[test]
    fn triangular_solves_invert_each_other() {
        // L = [[2,0],[1,3]]; solve L z = b then Lᵀ w = z reproduces
        // (L Lᵀ)⁻¹ b.
        let l = [2.0, 0.0, 1.0, 3.0];
        let b = [4.0, 11.0];
        let mut z = b.to_vec();
        forward_substitute(&l, 2, 2, &mut z);
        assert!((z[0] - 2.0).abs() < 1e-14 && (z[1] - 3.0).abs() < 1e-14);
        back_substitute_t(&l, 2, 2, &mut z);
        // Check against solve_spd on A = L Lᵀ.
        let a = [4.0, 2.0, 2.0, 10.0];
        let x = solve_spd(&a, 2, &b).unwrap();
        assert!(max_abs_diff(&z, &x) < 1e-12);
    }

    #[test]
    fn solve_spd_matches_direct_solution_and_rejects_indefinite() {
        let a = [3.0, 1.0, 1.0, 3.0];
        let x = solve_spd(&a, 2, &[5.0, 7.0]).unwrap();
        assert!((x[0] - 1.0).abs() < 1e-12 && (x[1] - 2.0).abs() < 1e-12);
        let indef = [1.0, 2.0, 2.0, 1.0]; // eigenvalues 3, -1
        assert!(solve_spd(&indef, 2, &[1.0, 1.0]).is_none());
    }

    #[test]
    fn helpers() {
        assert_eq!(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
        let mut y = vec![1.0, 1.0];
        axpy(2.0, &[1.0, 2.0], &mut y);
        assert_eq!(y, vec![3.0, 5.0]);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
    }
}
