//! Deterministic fault injection at I/O and queue seams.
//!
//! A **failpoint** is a named site compiled into the code as
//! `failpoint!("name")`, which evaluates to the site's configured `u64`
//! payload when armed and `None` otherwise. In a release build without the
//! `failpoints` feature the macro is a constant `None` — the optimiser
//! erases the site entirely, so production binaries carry zero overhead and
//! zero reachable fault paths (enforced by siglint's
//! `failpoint_release_free` rule: arming calls may only appear in test code
//! or behind `#[cfg(any(test, feature = "failpoints"))]`).
//!
//! Sites are armed per-name through a process-wide registry:
//!
//! ```ignore
//! failpoint::arm("snapshot.torn_write", 32);   // payload = byte cut point
//! // ... exercise the seam ...
//! failpoint::disarm("snapshot.torn_write");
//! ```
//!
//! The payload is site-defined: torn writes and short reads use it as a
//! truncation length, queue seams ignore it and treat any armed value as
//! "inject now". `arm_times` arms a site for a bounded number of hits so a
//! test can inject exactly N faults and then observe recovery. Tests that
//! arm failpoints should hold [`serial_guard`] — the registry is
//! process-global and `cargo test` runs tests concurrently.

#![cfg_attr(not(any(test, feature = "failpoints")), allow(dead_code))]

#[cfg(any(test, feature = "failpoints"))]
mod active {
    use crate::util::sync::lock_unpoisoned;
    use std::collections::HashMap;
    use std::sync::{Mutex, MutexGuard, OnceLock};

    /// Armed state of one site: the payload and an optional remaining-hit
    /// budget (`None` = armed until disarmed).
    struct Arm {
        value: u64,
        remaining: Option<u64>,
    }

    fn registry() -> &'static Mutex<HashMap<&'static str, Arm>> {
        static REGISTRY: OnceLock<Mutex<HashMap<&'static str, Arm>>> = OnceLock::new();
        REGISTRY.get_or_init(|| Mutex::new(HashMap::new()))
    }

    /// Arm `name` with `value` until [`disarm`]ed.
    pub fn arm(name: &'static str, value: u64) {
        lock_unpoisoned(registry()).insert(
            name,
            Arm {
                value,
                remaining: None,
            },
        );
    }

    /// Arm `name` for exactly `times` hits, then auto-disarm.
    pub fn arm_times(name: &'static str, times: u64, value: u64) {
        lock_unpoisoned(registry()).insert(
            name,
            Arm {
                value,
                remaining: Some(times),
            },
        );
    }

    /// Disarm one site (no-op if not armed).
    pub fn disarm(name: &str) {
        lock_unpoisoned(registry()).remove(name);
    }

    /// Disarm every site.
    pub fn disarm_all() {
        lock_unpoisoned(registry()).clear();
    }

    /// Site hook: the armed payload, decrementing a bounded budget.
    pub fn eval(name: &str) -> Option<u64> {
        let mut reg = lock_unpoisoned(registry());
        let arm = reg.get_mut(name)?;
        let value = arm.value;
        match arm.remaining.as_mut() {
            None => Some(value),
            Some(0) => {
                reg.remove(name);
                None
            }
            Some(n) => {
                *n -= 1;
                if *n == 0 {
                    // Last hit: deliver it, then disarm.
                    reg.remove(name);
                }
                Some(value)
            }
        }
    }

    /// Serialise tests that arm failpoints (the registry is process-wide).
    pub fn serial_guard() -> MutexGuard<'static, ()> {
        static GUARD: OnceLock<Mutex<()>> = OnceLock::new();
        lock_unpoisoned(GUARD.get_or_init(|| Mutex::new(())))
    }
}

#[cfg(any(test, feature = "failpoints"))]
pub use active::{arm, arm_times, disarm, disarm_all, eval, serial_guard};

/// Site hook — release builds without the `failpoints` feature compile to a
/// constant `None` and the optimiser removes the site.
#[cfg(not(any(test, feature = "failpoints")))]
#[inline(always)]
pub fn eval(_name: &str) -> Option<u64> {
    None
}

/// Evaluate a failpoint site: `Some(payload)` when armed, `None` otherwise.
/// See the [module docs](crate::util::failpoint) for payload semantics.
#[macro_export]
macro_rules! failpoint {
    ($name:expr) => {
        $crate::util::failpoint::eval($name)
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unarmed_sites_are_silent() {
        let _g = serial_guard();
        assert_eq!(failpoint!("failpoint.test.never_armed"), None);
    }

    #[test]
    fn arm_and_disarm_round_trip() {
        let _g = serial_guard();
        arm("failpoint.test.rt", 42);
        assert_eq!(failpoint!("failpoint.test.rt"), Some(42));
        assert_eq!(failpoint!("failpoint.test.rt"), Some(42), "sticky until disarmed");
        disarm("failpoint.test.rt");
        assert_eq!(failpoint!("failpoint.test.rt"), None);
    }

    #[test]
    fn bounded_arming_expires_after_its_budget() {
        let _g = serial_guard();
        arm_times("failpoint.test.bounded", 2, 7);
        assert_eq!(failpoint!("failpoint.test.bounded"), Some(7));
        assert_eq!(failpoint!("failpoint.test.bounded"), Some(7));
        assert_eq!(failpoint!("failpoint.test.bounded"), None, "budget spent");
        arm("failpoint.test.bounded", 1);
        disarm_all();
        assert_eq!(failpoint!("failpoint.test.bounded"), None);
    }
}
