//! A work-stealing-free, chunked data-parallel executor built on
//! `std::thread::scope` — the stand-in for `rayon` in this offline build.
//!
//! `parallel_for` splits an index range over worker threads with an atomic
//! chunk cursor, so uneven per-item cost (e.g. signature kernels over paths
//! of different lengths) still balances.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Process-wide explicit thread-count override (0 = none). Tests and
/// benches that sweep worker counts set this instead of mutating
/// `PYSIGLIB_THREADS` — `std::env::set_var` racing a concurrent `getenv`
/// is undefined behaviour at the libc level, and the env value is read
/// once per process anyway (see [`crate::config::env`]).
static THREAD_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Set (or with `None`, clear) an explicit worker-thread count that takes
/// precedence over `PYSIGLIB_THREADS`. Intended for tests and benches;
/// callers should restore `None` when done.
pub fn set_thread_override(n: Option<usize>) {
    THREAD_OVERRIDE.store(n.unwrap_or(0), Ordering::Relaxed);
}

/// Number of worker threads to use: an explicit [`set_thread_override`]
/// wins, else `PYSIGLIB_THREADS` (read once per process), else the
/// machine's available parallelism.
pub fn num_threads() -> usize {
    let over = THREAD_OVERRIDE.load(Ordering::Relaxed);
    if over >= 1 {
        return over;
    }
    if let Some(n) = crate::config::env::threads() {
        return n;
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Run `body(i)` for every `i in 0..n`, distributing indices over threads in
/// dynamically-claimed chunks. `body` must be `Sync` (it is shared by
/// reference across workers) and is responsible for disjoint writes — use
/// [`parallel_for_mut`] when each index owns a mutable output slice.
pub fn parallel_for<F: Fn(usize) + Sync>(n: usize, body: F) {
    parallel_for_chunked(n, 1, &body);
}

/// Like [`parallel_for`], but lets the caller pick a chunk granularity to
/// amortise the atomic fetch for very cheap bodies.
pub fn parallel_for_chunked<F: Fn(usize) + Sync>(n: usize, chunk: usize, body: &F) {
    let threads = num_threads().min(n.max(1));
    if threads <= 1 || n <= 1 {
        for i in 0..n {
            body(i);
        }
        return;
    }
    let chunk = chunk.max(1);
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let start = cursor.fetch_add(chunk, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                let end = (start + chunk).min(n);
                for i in start..end {
                    body(i);
                }
            });
        }
    });
}

/// Split `out` into `n` equal consecutive chunks of length `stride` and run
/// `body(i, chunk_i)` in parallel — the common "one output row per item"
/// pattern for batched signatures/kernels.
pub fn parallel_for_mut<T: Send, F: Fn(usize, &mut [T]) + Sync>(
    out: &mut [T],
    stride: usize,
    body: F,
) {
    assert!(stride > 0 && out.len() % stride == 0);
    let n = out.len() / stride;
    let threads = num_threads().min(n.max(1));
    if threads <= 1 || n <= 1 {
        for (i, c) in out.chunks_mut(stride).enumerate() {
            body(i, c);
        }
        return;
    }
    let cursor = AtomicUsize::new(0);
    // Hand each worker the base pointer; chunks are disjoint by construction.
    let base = out.as_mut_ptr() as usize;
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                // SAFETY: chunk i is out[i*stride .. (i+1)*stride], disjoint
                // across i, and `out` outlives the scope.
                let chunk = unsafe {
                    std::slice::from_raw_parts_mut((base as *mut T).add(i * stride), stride)
                };
                body(i, chunk);
            });
        }
    });
}

/// Like [`parallel_for_mut`], but with explicit per-chunk bounds: chunk `i`
/// is `out[bounds[i]..bounds[i+1]]`. This is the ragged-batch counterpart —
/// one output chunk per path, chunks of different sizes. `bounds` must be
/// non-decreasing, start at 0 and end at `out.len()`.
pub fn parallel_for_mut_ragged<T: Send, F: Fn(usize, &mut [T]) + Sync>(
    out: &mut [T],
    bounds: &[usize],
    body: F,
) {
    assert!(
        !bounds.is_empty() && bounds[0] == 0 && *bounds.last().unwrap() == out.len(),
        "bounds must span the output"
    );
    // A real assert, not a debug_assert: the raw-pointer chunk construction
    // below is only sound for non-decreasing bounds (disjointness), and this
    // is a safe pub fn — O(n) next to the thread spawns it precedes.
    assert!(
        bounds.windows(2).all(|w| w[0] <= w[1]),
        "bounds must be non-decreasing"
    );
    let n = bounds.len() - 1;
    let threads = num_threads().min(n.max(1));
    if threads <= 1 || n <= 1 {
        for i in 0..n {
            let (lo, hi) = (bounds[i], bounds[i + 1]);
            body(i, &mut out[lo..hi]);
        }
        return;
    }
    let cursor = AtomicUsize::new(0);
    // Hand each worker the base pointer; chunks are disjoint by construction
    // (bounds are non-decreasing).
    let base = out.as_mut_ptr() as usize;
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let (lo, hi) = (bounds[i], bounds[i + 1]);
                // SAFETY: chunk i is out[lo..hi]; the bounds are
                // non-decreasing so chunks are disjoint across i, and `out`
                // outlives the scope.
                let chunk = unsafe {
                    std::slice::from_raw_parts_mut((base as *mut T).add(lo), hi - lo)
                };
                body(i, chunk);
            });
        }
    });
}

/// A persistent pool of workers for the serving path, where per-request
/// thread spawning would dominate. Jobs are boxed closures; the pool drains
/// on drop.
pub struct ThreadPool {
    tx: Option<std::sync::mpsc::Sender<Box<dyn FnOnce() + Send>>>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `n` workers (at least 1).
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = std::sync::mpsc::channel::<Box<dyn FnOnce() + Send>>();
        let rx = std::sync::Arc::new(std::sync::Mutex::new(rx));
        let mut handles = Vec::with_capacity(n);
        for _ in 0..n {
            let rx = rx.clone();
            handles.push(std::thread::spawn(move || loop {
                let job = {
                    let guard = rx.lock().unwrap();
                    guard.recv()
                };
                match job {
                    Ok(job) => job(),
                    Err(_) => break,
                }
            }));
        }
        ThreadPool {
            tx: Some(tx),
            handles,
        }
    }

    /// Enqueue a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, job: F) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(job))
            .expect("workers gone");
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.handles.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn parallel_for_visits_every_index_once() {
        let n = 10_000;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        parallel_for(n, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_for_mut_disjoint_chunks() {
        let mut out = vec![0.0f64; 64 * 17];
        parallel_for_mut(&mut out, 17, |i, chunk| {
            for v in chunk.iter_mut() {
                *v = i as f64;
            }
        });
        for (i, c) in out.chunks(17).enumerate() {
            assert!(c.iter().all(|&v| v == i as f64));
        }
    }

    #[test]
    fn parallel_for_mut_ragged_disjoint_chunks() {
        let bounds = [0usize, 3, 3, 10, 24, 25];
        let mut out = vec![0.0f64; 25];
        parallel_for_mut_ragged(&mut out, &bounds, |i, chunk| {
            for v in chunk.iter_mut() {
                *v = i as f64 + 1.0;
            }
        });
        for i in 0..bounds.len() - 1 {
            assert!(out[bounds[i]..bounds[i + 1]]
                .iter()
                .all(|&v| v == i as f64 + 1.0));
        }
    }

    #[test]
    fn pool_runs_all_jobs() {
        let counter = std::sync::Arc::new(AtomicU64::new(0));
        {
            let pool = ThreadPool::new(4);
            for _ in 0..100 {
                let c = counter.clone();
                pool.execute(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
        } // drop waits for drain
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn zero_items_is_fine() {
        parallel_for(0, |_| panic!("must not run"));
    }

    #[test]
    fn thread_override_takes_precedence() {
        set_thread_override(Some(3));
        assert_eq!(num_threads(), 3);
        set_thread_override(None);
        assert!(num_threads() >= 1);
    }
}
