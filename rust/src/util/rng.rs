//! Deterministic pseudo-random number generation (xoshiro256**) used for
//! synthetic workloads, property tests and weight initialisation.
//!
//! Not cryptographic. Seeded explicitly everywhere so experiments are
//! reproducible run-to-run.

/// xoshiro256** generator (Blackman & Vigna). Period 2^256 - 1.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed via SplitMix64 expansion.
    pub fn new(seed: u64) -> Self {
        // SplitMix64 to fill the state; avoids the all-zero state for any seed.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    /// Next raw 64 bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> [0,1) double.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-300 {
                let u2 = self.uniform();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Fill a slice with standard normals.
    pub fn fill_normal(&mut self, out: &mut [f64]) {
        for v in out.iter_mut() {
            *v = self.normal();
        }
    }

    /// A Brownian-motion-like path: `len` points in `dim` dimensions,
    /// cumulative sum of N(0, scale^2) increments, started at the origin.
    /// Row-major `[len, dim]`.
    pub fn brownian_path(&mut self, len: usize, dim: usize, scale: f64) -> Vec<f64> {
        let mut out = vec![0.0; len * dim];
        for t in 1..len {
            for j in 0..dim {
                out[t * dim + j] = out[(t - 1) * dim + j] + scale * self.normal();
            }
        }
        out
    }

    /// Batch of Brownian paths, row-major `[batch, len, dim]`.
    pub fn brownian_batch(&mut self, batch: usize, len: usize, dim: usize, scale: f64) -> Vec<f64> {
        let mut out = Vec::with_capacity(batch * len * dim);
        for _ in 0..batch {
            out.extend_from_slice(&self.brownian_path(len, dim, scale));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let mut sum = 0.0;
        let mut sumsq = 0.0;
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sumsq += x * x;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn brownian_path_shape_and_start() {
        let mut r = Rng::new(9);
        let p = r.brownian_path(16, 3, 1.0);
        assert_eq!(p.len(), 48);
        assert_eq!(&p[..3], &[0.0, 0.0, 0.0]);
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(11);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }
}
