//! Minimal string-context error type — the from-scratch stand-in for
//! `anyhow` (unavailable offline). Provides [`Error`], a [`Result`] alias,
//! the [`format_err!`](crate::format_err) constructor macro (importable as
//! `use crate::format_err as anyhow;` for drop-in `anyhow!(..)` call sites),
//! and a [`Context`] extension trait for wrapping underlying errors with a
//! human-readable prefix.

use std::fmt;

/// A boxed-free, message-carrying error. Context wrapping concatenates into
/// the message, so `{e}` and `{e:#}` both print the full chain.
#[derive(Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(m: impl Into<String>) -> Error {
        Error { msg: m.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow!`-style constructor: `format_err!("parsing {path:?}: {e:?}")`.
#[macro_export]
macro_rules! format_err {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Attach context to an underlying error, `anyhow::Context`-style.
pub trait Context<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T>;
    fn with_context<S: fmt::Display, F: FnOnce() -> S>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Debug> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{msg}: {e:?}")))
    }
    fn with_context<S: fmt::Display, F: FnOnce() -> S>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e:?}", f())))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn context_wraps_and_displays() {
        let r: std::result::Result<(), std::num::ParseIntError> =
            "x".parse::<i32>().map(|_| ());
        let e = r.context("parsing x").unwrap_err();
        let s = format!("{e}");
        assert!(s.starts_with("parsing x: "), "{s}");
        let e2 = format_err!("plain {}", 42);
        assert_eq!(format!("{e2:#}"), "plain 42");
    }
}
