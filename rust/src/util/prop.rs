//! A miniature property-based testing harness (the offline stand-in for
//! `proptest`): run a property over many randomly generated cases, report the
//! seed and case on failure so it can be replayed deterministically.
//!
//! Usage:
//! ```
//! use pysiglib::util::prop::{check, Gen};
//! check("addition commutes", 200, |g| {
//!     let a = g.f64_in(-10.0, 10.0);
//!     let b = g.f64_in(-10.0, 10.0);
//!     assert!((a + b - (b + a)).abs() < 1e-12);
//! });
//! ```

use crate::util::rng::Rng;

/// Case generator handed to properties. Wraps the RNG with convenience
/// samplers for the domain (path shapes, truncation levels, dyadic orders).
pub struct Gen {
    rng: Rng,
    /// Human-readable trace of everything drawn, printed on failure.
    pub trace: Vec<String>,
}

impl Gen {
    fn new(seed: u64) -> Self {
        Gen {
            rng: Rng::new(seed),
            trace: Vec::new(),
        }
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        let v = self.rng.range(lo, hi);
        self.trace.push(format!("usize_in({lo},{hi}) = {v}"));
        v
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        let v = self.rng.uniform_in(lo, hi);
        self.trace.push(format!("f64_in({lo},{hi}) = {v:.6}"));
        v
    }

    pub fn bool(&mut self) -> bool {
        let v = self.rng.next_u64() & 1 == 1;
        self.trace.push(format!("bool = {v}"));
        v
    }

    /// Standard-normal vector of length n.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        let mut v = vec![0.0; n];
        self.rng.fill_normal(&mut v);
        self.trace.push(format!("normal_vec(len={n})"));
        v
    }

    /// A random path: `len` points in `dim` dims, Brownian-like so increments
    /// are O(scale) — keeps truncated signatures in a numerically sane range.
    pub fn path(&mut self, len: usize, dim: usize, scale: f64) -> Vec<f64> {
        let p = self.rng.brownian_path(len, dim, scale);
        self.trace.push(format!("path(len={len},dim={dim})"));
        p
    }

    /// Access the raw RNG for anything else.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `prop` over `cases` generated cases. Panics (failing the enclosing
/// `#[test]`) with the seed and the generator trace of the first failing
/// case. Honours `PYSIGLIB_PROP_SEED` to replay one specific case.
pub fn check<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(name: &str, cases: usize, prop: F) {
    // siglint: allow(env_discipline) -- test-harness replay knob, not serving configuration
    if let Ok(s) = std::env::var("PYSIGLIB_PROP_SEED") {
        let seed: u64 = s.parse().expect("PYSIGLIB_PROP_SEED must be u64");
        let mut g = Gen::new(seed);
        prop(&mut g);
        return;
    }
    let base = 0xD1CE_5EED_u64;
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64);
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen::new(seed);
            prop(&mut g);
            g
        });
        if let Err(err) = result {
            // Re-run to recover the trace (prop may have panicked midway).
            let mut g = Gen::new(seed);
            let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed on case {case} (seed {seed}).\n\
                 replay with PYSIGLIB_PROP_SEED={seed}\n\
                 draws: {:#?}\npanic: {msg}",
                g.trace
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("tautology", 50, |g| {
            let x = g.f64_in(0.0, 1.0);
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    fn failing_property_reports_seed() {
        let r = std::panic::catch_unwind(|| {
            check("always fails", 3, |_g| panic!("nope"));
        });
        let err = r.expect_err("should fail");
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("PYSIGLIB_PROP_SEED="), "got: {msg}");
    }
}
