//! Timing helpers shared by the bench harness and the coordinator metrics.

use std::time::{Duration, Instant};

/// Simple stopwatch.
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(Instant::now())
    }
    pub fn elapsed(&self) -> Duration {
        self.0.elapsed()
    }
    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// The paper reports the *minimum* runtime over 50 runs; this mirrors that
/// protocol with a configurable run count and a warmup run.
pub fn min_time_over<F: FnMut()>(runs: usize, mut f: F) -> f64 {
    f(); // warmup
    let mut best = f64::INFINITY;
    for _ in 0..runs {
        let t = Instant::now();
        f();
        best = best.min(t.elapsed().as_secs_f64());
    }
    best
}

/// Mean/min/max of repeated timings (used for coordinator metrics snapshots).
#[derive(Debug, Clone, Copy, Default)]
pub struct TimeStats {
    pub n: usize,
    pub total: f64,
    pub min: f64,
    pub max: f64,
}

impl TimeStats {
    pub fn record(&mut self, secs: f64) {
        if self.n == 0 {
            self.min = secs;
            self.max = secs;
        } else {
            self.min = self.min.min(secs);
            self.max = self.max.max(secs);
        }
        self.n += 1;
        self.total += secs;
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.total / self.n as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn min_time_is_positive_and_small_for_noop() {
        let t = min_time_over(3, || {});
        assert!(t >= 0.0 && t < 0.1);
    }

    #[test]
    fn stats_accumulate() {
        let mut s = TimeStats::default();
        s.record(1.0);
        s.record(3.0);
        assert_eq!(s.n, 2);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert!((s.mean() - 2.0).abs() < 1e-12);
    }
}
