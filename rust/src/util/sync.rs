//! Poison-tolerant lock acquisition for the serving path.
//!
//! `Mutex::lock` returns `Err` only when another thread panicked while
//! holding the guard. The serving invariant is panic-freedom on the request
//! path, so poisoning can originate only from test harness threads or
//! catastrophic bugs — and in either case the protected data (queues,
//! corpus maps) is structurally valid between operations: every critical
//! section either completes its mutation or pushes/pops whole items. We
//! therefore recover the guard instead of propagating a panic through the
//! coordinator, keeping the request path free of `unwrap` (enforced by
//! `siglint`'s `panic_freedom` rule).

use std::sync::{Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};

/// Lock a mutex, recovering the guard if a panicking thread poisoned it.
pub fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// Read-lock an `RwLock`, recovering the guard if poisoned.
pub fn read_unpoisoned<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(|p| p.into_inner())
}

/// Write-lock an `RwLock`, recovering the guard if poisoned.
pub fn write_unpoisoned<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(|p| p.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex, RwLock};

    #[test]
    fn lock_survives_poison() {
        let m = Arc::new(Mutex::new(7usize));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.lock().is_err(), "mutex should be poisoned");
        assert_eq!(*lock_unpoisoned(&m), 7);
    }

    #[test]
    fn rwlock_survives_poison() {
        let l = Arc::new(RwLock::new(vec![1, 2, 3]));
        let l2 = l.clone();
        let _ = std::thread::spawn(move || {
            let _g = l2.write().unwrap();
            panic!("poison it");
        })
        .join();
        assert_eq!(read_unpoisoned(&l).len(), 3);
        write_unpoisoned(&l).push(4);
        assert_eq!(read_unpoisoned(&l).len(), 4);
    }
}
