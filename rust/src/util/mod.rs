//! Shared substrates: RNG, thread pool, timing, small linear algebra.
//!
//! The build environment is fully offline, so the usual crates (`rand`,
//! `rayon`, `criterion`, `proptest`) are unavailable; each substrate here is
//! a from-scratch implementation of the minimal functionality this library
//! needs, with the same observable semantics.

pub mod error;
pub mod failpoint;
pub mod rng;
pub mod pool;
pub mod timing;
pub mod linalg;
pub mod prop;
pub mod sync;

pub use pool::{parallel_for, ThreadPool};
pub use rng::Rng;
pub use timing::{min_time_over, Stopwatch};
