//! Streaming and windowed signatures: the online complement to the batch
//! APIs. `StreamingSignature` maintains S(x_{0..t}) under point-by-point
//! arrival (one Horner step per point — O(sig_length · d) amortised), which
//! is the natural deployment mode for the financial data streams the paper
//! targets; `sliding_signatures` featurises every window of a long series.

use crate::path::{Path, SigError};
use crate::sig::horner::horner_step;
use crate::tensor::{group_inverse, tensor_prod, LevelLayout};

/// Online signature accumulator over a stream of points in R^d.
pub struct StreamingSignature {
    layout: LevelLayout,
    sig: Vec<f64>,
    scratch: Vec<f64>,
    last: Option<Vec<f64>>,
    count: usize,
}

impl StreamingSignature {
    /// Typed, fallible constructor: validates `dim`/`depth` like the rest of
    /// the crate (including the hostile-size guard of
    /// [`try_sig_length`](crate::sig::try_sig_length)).
    pub fn try_new(dim: usize, depth: usize) -> Result<Self, SigError> {
        crate::sig::try_sig_length(dim, depth)?;
        let layout = LevelLayout::new(dim, depth);
        let mut sig = vec![0.0; layout.total()];
        sig[0] = 1.0;
        let bcap = layout.level_size(depth.saturating_sub(1)).max(1);
        Ok(StreamingSignature {
            layout,
            sig,
            scratch: vec![0.0; bcap],
            last: None,
            count: 0,
        })
    }

    /// Panicking wrapper over [`StreamingSignature::try_new`].
    pub fn new(dim: usize, depth: usize) -> Self {
        StreamingSignature::try_new(dim, depth).expect("StreamingSignature: invalid dim/depth")
    }

    /// Feed the next point; updates the running signature by one Chen step.
    /// Errors if the point's dimension disagrees with the stream's.
    pub fn try_push(&mut self, point: &[f64]) -> Result<(), SigError> {
        if point.len() != self.layout.dim {
            return Err(SigError::DataLen {
                expected: self.layout.dim,
                got: point.len(),
            });
        }
        if let Some(last) = &self.last {
            let z: Vec<f64> = point.iter().zip(last.iter()).map(|(a, b)| a - b).collect();
            horner_step(&self.layout, &mut self.sig, &z, &mut self.scratch);
        }
        self.last = Some(point.to_vec());
        self.count += 1;
        Ok(())
    }

    /// Panicking wrapper over [`StreamingSignature::try_push`].
    pub fn push(&mut self, point: &[f64]) {
        self.try_push(point).expect("StreamingSignature::push: wrong point dimension")
    }

    /// Feed a whole typed path (its dimension must match the stream's).
    pub fn try_extend(&mut self, path: Path<'_>) -> Result<(), SigError> {
        if path.dim() != self.layout.dim {
            return Err(SigError::DimMismatch {
                left: path.dim(),
                right: self.layout.dim,
            });
        }
        for i in 0..path.len() {
            self.try_push(path.point(i))?;
        }
        Ok(())
    }

    /// Current signature of everything seen so far (identity before two
    /// points have arrived).
    pub fn signature(&self) -> &[f64] {
        &self.sig
    }

    /// Points consumed.
    pub fn len(&self) -> usize {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Reset to the empty-path state.
    pub fn reset(&mut self) {
        self.sig.fill(0.0);
        self.sig[0] = 1.0;
        self.last = None;
        self.count = 0;
    }

    /// Adopt an externally-held signature as the accumulated state, with
    /// `point` as the stream's current endpoint: subsequent pushes extend
    /// the adopted signature by one Chen step each. This is the
    /// checkpoint/restore hook the sliding-window recurrence
    /// ([`try_sliding_signatures`]) and the corpus
    /// [`DriftMonitor`](crate::corpus::stream::DriftMonitor) build on —
    /// every Horner step in those paths runs through
    /// [`try_push`](StreamingSignature::try_push).
    pub fn try_adopt(&mut self, sig: &[f64], point: &[f64]) -> Result<(), SigError> {
        if sig.len() != self.layout.total() {
            return Err(SigError::DataLen {
                expected: self.layout.total(),
                got: sig.len(),
            });
        }
        if point.len() != self.layout.dim {
            return Err(SigError::DataLen {
                expected: self.layout.dim,
                got: point.len(),
            });
        }
        self.sig.copy_from_slice(sig);
        self.last = Some(point.to_vec());
        self.count = 1;
        Ok(())
    }
}

/// Signatures of every sliding window `[i, i+window)` of a path, advancing
/// by `stride`. Uses Chen's identity incrementally: the signature of the
/// next window is  S(w') = S(seg_dropped)^{-1} ⊗ S(w) ⊗ S(seg_added),
/// costing two group operations per slide instead of recomputing the
/// window from scratch — an O(window/stride)-fold saving for dense strides.
///
/// Returns `[n_windows, sig_length]` row-major.
pub fn sliding_signatures(
    path: &[f64],
    len: usize,
    dim: usize,
    depth: usize,
    window: usize,
    stride: usize,
) -> Vec<f64> {
    let p = Path::new(path, len, dim).expect("sliding_signatures: invalid path shape");
    try_sliding_signatures(p, depth, window, stride)
        .expect("sliding_signatures: invalid window/stride/depth")
}

/// Typed, fallible [`sliding_signatures`]: validates the path shape (at
/// [`Path`] construction), depth, window and stride instead of asserting.
pub fn try_sliding_signatures(
    path: Path<'_>,
    depth: usize,
    window: usize,
    stride: usize,
) -> Result<Vec<f64>, SigError> {
    let (len, dim) = (path.len(), path.dim());
    crate::sig::try_sig_length(dim, depth)?;
    if window < 2 || window > len {
        return Err(SigError::Invalid("window must satisfy 2 <= window <= len"));
    }
    if stride == 0 {
        return Err(SigError::Invalid("stride must be at least 1"));
    }
    let path = path.data();
    let layout = LevelLayout::new(dim, depth);
    let total = layout.total();
    let n_windows = (len - window) / stride + 1;
    let mut out = vec![0.0; n_windows * total];

    // First window directly.
    let mut cur = crate::sig::sig(&path[..window * dim], window, dim, depth);
    out[..total].copy_from_slice(&cur);

    // Every Chen/Horner step below runs through one shared
    // [`StreamingSignature`]: reset, it accumulates the dropped prefix;
    // adopted onto the spliced state, it extends by the appended tail. The
    // step sequence is identical to the historical inline loops.
    let mut stream = StreamingSignature::try_new(dim, depth)?;
    let point = |i: usize| &path[i * dim..(i + 1) * dim];
    let mut inv = vec![0.0; total];
    let mut tmp = vec![0.0; total];
    for w in 1..n_windows {
        let prev_start = (w - 1) * stride;
        let start = w * stride;
        // S(dropped prefix) = signature over points [prev_start, start].
        stream.reset();
        for i in prev_start..=start {
            stream.try_push(point(i))?;
        }
        group_inverse(&layout, stream.signature(), &mut inv);
        tensor_prod(&layout, &inv, &cur, &mut tmp);
        // Append the new tail points (prev_end, end].
        let prev_end = prev_start + window - 1;
        let end = start + window - 1;
        stream.try_adopt(&tmp, point(prev_end))?;
        for i in prev_end + 1..=end {
            stream.try_push(point(i))?;
        }
        cur.copy_from_slice(stream.signature());
        out[w * total..(w + 1) * total].copy_from_slice(&cur);
    }
    Ok(out)
}

/// Expanding-window signatures: S(x_{0..k}) for every prefix end k in
/// `2..=len`, one Horner step each — `[len-1, sig_length]`.
pub fn expanding_signatures(path: &[f64], len: usize, dim: usize, depth: usize) -> Vec<f64> {
    let p = Path::new(path, len, dim).expect("expanding_signatures: invalid path shape");
    try_expanding_signatures(p, depth).expect("expanding_signatures: invalid depth/length")
}

/// Typed, fallible [`expanding_signatures`]: needs a path of at least two
/// points and a validated depth.
pub fn try_expanding_signatures(path: Path<'_>, depth: usize) -> Result<Vec<f64>, SigError> {
    let (len, dim) = (path.len(), path.dim());
    crate::sig::try_sig_length(dim, depth)?;
    if len < 2 {
        return Err(SigError::Invalid(
            "expanding signatures need at least two points",
        ));
    }
    let path = path.data();
    let layout = LevelLayout::new(dim, depth);
    let total = layout.total();
    let mut out = vec![0.0; (len - 1) * total];
    let mut stream = StreamingSignature::try_new(dim, depth)?;
    stream.try_push(&path[..dim])?;
    for i in 1..len {
        stream.try_push(&path[i * dim..(i + 1) * dim])?;
        out[(i - 1) * total..i * total].copy_from_slice(stream.signature());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::linalg::max_abs_diff;
    use crate::util::prop::check;

    #[test]
    fn streaming_matches_batch() {
        check("streaming == batch signature", 20, |g| {
            let len = g.usize_in(2, 20);
            let dim = g.usize_in(1, 3);
            let depth = g.usize_in(1, 4);
            let path = g.path(len, dim, 0.5);
            let mut s = StreamingSignature::new(dim, depth);
            for i in 0..len {
                s.push(&path[i * dim..(i + 1) * dim]);
            }
            let want = crate::sig::sig(&path, len, dim, depth);
            assert!(max_abs_diff(s.signature(), &want) < 1e-11);
        });
    }

    #[test]
    fn typed_constructors_validate_like_the_rest_of_the_crate() {
        assert!(matches!(
            StreamingSignature::try_new(0, 3),
            Err(SigError::ZeroDim)
        ));
        assert!(matches!(
            StreamingSignature::try_new(2, 0),
            Err(SigError::ZeroDepth)
        ));
        assert!(matches!(
            StreamingSignature::try_new(2, 64),
            Err(SigError::TooLarge(_))
        ));
        let mut s = StreamingSignature::try_new(2, 3).unwrap();
        assert!(matches!(
            s.try_push(&[1.0, 2.0, 3.0]),
            Err(SigError::DataLen {
                expected: 2,
                got: 3
            })
        ));
        s.try_push(&[0.0, 0.0]).unwrap();
        s.try_push(&[1.0, 2.0]).unwrap();
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn try_extend_matches_per_point_pushes() {
        let mut rng = crate::util::rng::Rng::new(62);
        let (len, dim, depth) = (9, 2, 3);
        let data = rng.brownian_path(len, dim, 0.5);
        let p = Path::new(&data, len, dim).unwrap();
        let mut a = StreamingSignature::try_new(dim, depth).unwrap();
        a.try_extend(p).unwrap();
        let mut b = StreamingSignature::new(dim, depth);
        for i in 0..len {
            b.push(&data[i * dim..(i + 1) * dim]);
        }
        assert_eq!(a.signature(), b.signature());
        // Mixed-dimension extension is a typed error.
        let d3 = [0.0; 6];
        let p3 = Path::new(&d3, 2, 3).unwrap();
        assert!(matches!(
            a.try_extend(p3),
            Err(SigError::DimMismatch { .. })
        ));
    }

    #[test]
    fn typed_windows_validate_arguments() {
        let data = [0.0, 1.0, 2.0, 3.0]; // 4 points in R^1
        let p = Path::new(&data, 4, 1).unwrap();
        assert!(matches!(
            try_sliding_signatures(p, 2, 1, 1),
            Err(SigError::Invalid(_))
        ));
        assert!(matches!(
            try_sliding_signatures(p, 2, 5, 1),
            Err(SigError::Invalid(_))
        ));
        assert!(matches!(
            try_sliding_signatures(p, 2, 2, 0),
            Err(SigError::Invalid(_))
        ));
        assert!(matches!(
            try_sliding_signatures(p, 0, 2, 1),
            Err(SigError::ZeroDepth)
        ));
        let got = try_sliding_signatures(p, 2, 2, 1).unwrap();
        assert_eq!(got, sliding_signatures(&data, 4, 1, 2, 2, 1));
        let single = [0.0];
        let sp = Path::new(&single, 1, 1).unwrap();
        assert!(matches!(
            try_expanding_signatures(sp, 2),
            Err(SigError::Invalid(_))
        ));
    }

    #[test]
    fn adopt_continues_like_an_uninterrupted_stream() {
        let mut rng = crate::util::rng::Rng::new(63);
        let (len, dim, depth) = (8, 2, 3);
        let data = rng.brownian_path(len, dim, 0.5);
        let mut whole = StreamingSignature::new(dim, depth);
        for i in 0..len {
            whole.push(&data[i * dim..(i + 1) * dim]);
        }
        // Checkpoint after 4 points, adopt into a fresh stream, continue.
        let mut head = StreamingSignature::new(dim, depth);
        for i in 0..4 {
            head.push(&data[i * dim..(i + 1) * dim]);
        }
        let ckpt = head.signature().to_vec();
        let mut tail = StreamingSignature::new(dim, depth);
        tail.try_adopt(&ckpt, &data[3 * dim..4 * dim]).unwrap();
        for i in 4..len {
            tail.push(&data[i * dim..(i + 1) * dim]);
        }
        assert_eq!(whole.signature(), tail.signature());
        assert!(tail.try_adopt(&ckpt[1..], &data[..dim]).is_err());
        assert!(tail.try_adopt(&ckpt, &data[..1]).is_err());
    }

    #[test]
    fn streaming_reset_restarts() {
        let mut s = StreamingSignature::new(2, 3);
        s.push(&[0.0, 0.0]);
        s.push(&[1.0, 1.0]);
        s.reset();
        assert!(s.is_empty());
        assert_eq!(s.signature()[0], 1.0);
        assert!(s.signature()[1..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn sliding_matches_direct_window_computation() {
        check("sliding windows == per-window signatures", 12, |g| {
            let len = g.usize_in(6, 24);
            let dim = g.usize_in(1, 3);
            let depth = g.usize_in(1, 3);
            let window = g.usize_in(3, len.min(8));
            let stride = g.usize_in(1, 3);
            let path = g.path(len, dim, 0.4);
            let got = sliding_signatures(&path, len, dim, depth, window, stride);
            let layout = LevelLayout::new(dim, depth);
            let total = layout.total();
            let n_windows = (len - window) / stride + 1;
            assert_eq!(got.len(), n_windows * total);
            for w in 0..n_windows {
                let s = w * stride;
                let want =
                    crate::sig::sig(&path[s * dim..(s + window) * dim], window, dim, depth);
                let err = max_abs_diff(&got[w * total..(w + 1) * total], &want);
                assert!(err < 1e-8, "window {w}: {err}");
            }
        });
    }

    #[test]
    fn expanding_prefixes_match() {
        let mut rng = crate::util::rng::Rng::new(61);
        let (len, dim, depth) = (10, 2, 3);
        let path = rng.brownian_path(len, dim, 0.5);
        let out = expanding_signatures(&path, len, dim, depth);
        let total = crate::sig::sig_length(dim, depth);
        for k in 2..=len {
            let want = crate::sig::sig(&path[..k * dim], k, dim, depth);
            let got = &out[(k - 2) * total..(k - 1) * total];
            assert!(max_abs_diff(got, &want) < 1e-12, "prefix {k}");
        }
    }
}
