//! Truncated path signatures (paper §2): forward via the direct algorithm
//! (Algorithm 1, iisignature-style) or Horner's algorithm (Algorithm 2, the
//! paper's optimised scheme), exact backpropagation via time-reversed
//! deconstruction (§2.4), log-signatures, and batched parallel APIs —
//! all with optional on-the-fly path transformations (§4).
//!
//! The typed, fallible entry points ([`try_signature`],
//! [`try_batch_signature`], [`try_signature_vjp`], …) take
//! [`Path`]/[`PathBatch`](crate::path::PathBatch) views and never panic on
//! malformed input; the flat-slice functions are thin wrappers over them.

pub mod backward;
pub mod batch;
pub mod direct;
pub mod horner;
pub mod logsig;
pub mod stream;

pub use backward::{signature_vjp, try_signature_vjp};
pub use batch::{
    batch_signature, batch_signature_vjp, try_batch_signature, try_batch_signature_vjp,
};
pub use direct::direct_step;
pub use horner::horner_step;
pub use logsig::{log_signature, log_signature_words, lyndon_words, try_batch_log_signature};
pub use stream::{
    expanding_signatures, sliding_signatures, try_expanding_signatures, try_sliding_signatures,
    StreamingSignature,
};

pub use crate::path::SigOptions;

use crate::path::{Path, SigError};
use crate::tensor::{exp_increment, LevelLayout};
use crate::transforms::{IncrementStream, Transform};

/// Which forward algorithm to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SigMethod {
    /// Algorithm 1 — the direct update, as in iisignature.
    Direct,
    /// Algorithm 2 — Horner's scheme, as in signatory/pySigLib (default).
    Horner,
}

/// Flat length of a signature truncated at `depth` for paths of dimension
/// `dim` (includes the constant level 0 entry).
pub fn sig_length(dim: usize, depth: usize) -> usize {
    LevelLayout::new(dim, depth).total()
}

/// Hard cap on the number of signature coefficients any fallible entry point
/// will compute (2^27 f64s = 1 GiB per signature).
pub const MAX_SIG_LEN: usize = 1 << 27;

/// Checked [`sig_length`]: returns an error instead of overflowing (and
/// panicking inside `LevelLayout`) or allocating absurdly when `dim`/`depth`
/// are hostile — e.g. taken from a wire header. Every `try_*` entry point
/// validates through this before touching the tensor layout.
pub fn try_sig_length(dim: usize, depth: usize) -> Result<usize, SigError> {
    if dim == 0 {
        return Err(SigError::ZeroDim);
    }
    if depth == 0 {
        return Err(SigError::ZeroDepth);
    }
    if dim == 1 {
        // Every level has one coefficient; closed form avoids a long loop.
        let total = depth
            .checked_add(1)
            .filter(|&t| t <= MAX_SIG_LEN)
            .ok_or(SigError::TooLarge("signature length"))?;
        return Ok(total);
    }
    // dim ≥ 2: the running total at least doubles per level, so this loop
    // exits (via the cap) within ~27 iterations.
    let mut total = 1usize;
    let mut level = 1usize;
    for _ in 0..depth {
        level = level
            .checked_mul(dim)
            .ok_or(SigError::TooLarge("signature length"))?;
        total = total
            .checked_add(level)
            .ok_or(SigError::TooLarge("signature length"))?;
        if total > MAX_SIG_LEN {
            return Err(SigError::TooLarge("signature length"));
        }
    }
    Ok(total)
}

/// Scratch length [`signature_into`] needs: the Horner B-buffer (design
/// choice (3)) or the exp(z) buffer of the direct algorithm.
pub(crate) fn sig_scratch_len(layout: &LevelLayout, method: SigMethod) -> usize {
    match method {
        SigMethod::Horner => layout.level_size(layout.depth.saturating_sub(1)).max(1),
        SigMethod::Direct => layout.total(),
    }
}

/// The core signature sweep, writing into caller-provided storage so that
/// compiled [`Plan`](crate::engine::Plan)s can run it with zero per-call
/// allocation. `layout` must be the layout of the *transformed* dimension,
/// `out` has length `layout.total()`, `z` has length `layout.dim`, `scratch`
/// has length ≥ [`sig_scratch_len`]. Assumes `depth >= 1` (validated at plan
/// compilation).
pub(crate) fn signature_into(
    data: &[f64],
    len: usize,
    dim: usize,
    method: SigMethod,
    transform: Transform,
    layout: &LevelLayout,
    out: &mut [f64],
    z: &mut [f64],
    scratch: &mut [f64],
) {
    debug_assert_eq!(out.len(), layout.total());
    debug_assert_eq!(z.len(), layout.dim);
    if len < 2 {
        out.fill(0.0);
        out[0] = 1.0;
        return;
    }
    let mut stream = IncrementStream::new(data, len, dim, transform);
    // Initialise with the first segment: A = exp(z_1).
    let has_first = stream.next_into(z);
    debug_assert!(has_first);
    exp_increment(layout, z, out);
    match method {
        SigMethod::Horner => {
            while stream.next_into(z) {
                horner_step(layout, out, z, scratch);
            }
        }
        SigMethod::Direct => {
            while stream.next_into(z) {
                direct_step(layout, out, z, scratch);
            }
        }
    }
}

/// Compute the truncated signature of a single typed path; it never panics
/// on malformed input. A thin wrapper that compiles a one-shot
/// [`Plan`](crate::engine::Plan) — for repeated same-shape calls, compile
/// the plan once and reuse it (see [`crate::engine`]).
///
/// Returns the flat signature of length [`sig_length`] *of the transformed
/// path's dimension* (`opts.exec.transform`), or an error when
/// `opts.depth == 0`.
pub fn try_signature(path: Path<'_>, opts: &SigOptions) -> Result<Vec<f64>, SigError> {
    let pb = crate::path::PathBatch::uniform(path.data(), 1, path.len(), path.dim())?;
    let plan = crate::engine::Plan::compile_forward(
        crate::engine::OpSpec::Sig(*opts),
        crate::engine::ShapeClass::uniform(path.dim(), path.len()),
    )?;
    Ok(plan.execute(&pb)?.into_values())
}

/// Compute the truncated signature of a single path (flat-slice wrapper over
/// [`try_signature`]; panics on malformed shapes).
///
/// * `path` — row-major `[len, dim]`.
/// * `depth` — truncation level N ≥ 1.
/// * `transform` — applied on-the-fly (the path is never materialised).
/// * `method` — direct or Horner.
pub fn signature(
    path: &[f64],
    len: usize,
    dim: usize,
    depth: usize,
    transform: Transform,
    method: SigMethod,
) -> Vec<f64> {
    let p = Path::new(path, len, dim).expect("signature: invalid path shape");
    try_signature(p, &SigOptions::new(depth).transform(transform).method(method))
        .expect("signature: invalid options")
}

/// Convenience: signature with no transform, Horner method.
pub fn sig(path: &[f64], len: usize, dim: usize, depth: usize) -> Vec<f64> {
    signature(path, len, dim, depth, Transform::None, SigMethod::Horner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{tensor_prod, TensorSeq};
    use crate::util::linalg::max_abs_diff;
    use crate::util::prop::check;

    /// Signature of a single linear segment is exp of the increment.
    #[test]
    fn linear_segment_is_tensor_exponential() {
        let path = [0.0, 0.0, 1.0, 2.0]; // 2 points in R^2
        let s = sig(&path, 2, 2, 4);
        let layout = LevelLayout::new(2, 4);
        let mut want = vec![0.0; layout.total()];
        exp_increment(&layout, &[1.0, 2.0], &mut want);
        assert!(max_abs_diff(&s, &want) < 1e-14);
    }

    #[test]
    fn direct_and_horner_agree() {
        check("direct == horner", 30, |g| {
            let len = g.usize_in(2, 20);
            let dim = g.usize_in(1, 4);
            let depth = g.usize_in(1, 5);
            let path = g.path(len, dim, 0.5);
            let a = signature(&path, len, dim, depth, Transform::None, SigMethod::Direct);
            let b = signature(&path, len, dim, depth, Transform::None, SigMethod::Horner);
            let err = max_abs_diff(&a, &b);
            assert!(err < 1e-10, "direct vs horner: {err}");
        });
    }

    #[test]
    fn chens_identity_concatenation() {
        check("Chen's identity", 25, |g| {
            let dim = g.usize_in(1, 3);
            let depth = g.usize_in(1, 4);
            let l1 = g.usize_in(2, 10);
            let l2 = g.usize_in(2, 10);
            let p1 = g.path(l1, dim, 0.5);
            let p2 = g.path(l2, dim, 0.5);
            // Concatenate: translate p2 so it starts at p1's endpoint, and
            // skip its first point (which coincides with p1's last). The
            // signature is translation-invariant, so S(translated p2) = S(p2)
            // and Chen's identity reads S(p1 · p2) = S(p1) ⊗ S(p2).
            let mut full = p1.clone();
            let last = &p1[(l1 - 1) * dim..];
            for i in 1..l2 {
                for j in 0..dim {
                    full.push(last[j] + p2[i * dim + j] - p2[j]);
                }
            }
            let s1 = sig(&p1, l1, dim, depth);
            let s2 = sig(&p2, l2, dim, depth);
            let sfull = sig(&full, l1 + l2 - 1, dim, depth);
            let layout = LevelLayout::new(dim, depth);
            let mut prod = vec![0.0; layout.total()];
            tensor_prod(&layout, &s1, &s2, &mut prod);
            let err = max_abs_diff(&sfull, &prod);
            assert!(err < 1e-9, "Chen violated: {err}");
        });
    }

    #[test]
    fn reversed_path_gives_group_inverse() {
        check("time reversal = inverse", 25, |g| {
            let len = g.usize_in(2, 12);
            let dim = g.usize_in(1, 3);
            let depth = g.usize_in(1, 4);
            let path = g.path(len, dim, 0.5);
            let mut rev = vec![0.0; len * dim];
            for i in 0..len {
                rev[i * dim..(i + 1) * dim]
                    .copy_from_slice(&path[(len - 1 - i) * dim..(len - i) * dim]);
            }
            let s = TensorSeq {
                layout: LevelLayout::new(dim, depth),
                data: sig(&path, len, dim, depth),
            };
            let srev = sig(&rev, len, dim, depth);
            let inv = s.inverse();
            assert!(max_abs_diff(&srev, &inv.data) < 1e-9);
        });
    }

    #[test]
    fn invariant_to_reparameterisation() {
        // Inserting a repeated point (zero increment) must not change S.
        let path = [0.0, 1.0, 3.0, 2.0]; // 2 points d=2... use 2x2
        let s1 = sig(&path, 2, 2, 3);
        let path2 = [0.0, 1.0, 0.0, 1.0, 3.0, 2.0];
        let s2 = sig(&path2, 3, 2, 3);
        assert!(max_abs_diff(&s1, &s2) < 1e-14);
    }

    #[test]
    fn trivial_path_is_identity() {
        let s = sig(&[1.0, 2.0], 1, 2, 3);
        assert_eq!(s[0], 1.0);
        assert!(s[1..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn depth_one_is_total_increment() {
        let path = [0.0, 0.0, 1.0, -1.0, 2.0, 5.0];
        let s = sig(&path, 3, 2, 1);
        assert_eq!(s.len(), 3);
        assert!((s[1] - 2.0).abs() < 1e-14);
        assert!((s[2] - 5.0).abs() < 1e-14);
    }

    /// Level-2 symmetric part is 0.5 * increment ⊗ increment (shuffle identity).
    #[test]
    fn level2_shuffle_identity() {
        check("level-2 shuffle identity", 20, |g| {
            let len = g.usize_in(2, 10);
            let dim = g.usize_in(1, 3);
            let path = g.path(len, dim, 0.6);
            let s = sig(&path, len, dim, 2);
            let layout = LevelLayout::new(dim, 2);
            let lvl1 = &s[1..1 + dim];
            let (o2, _) = layout.level_range(2);
            for i in 0..dim {
                for j in 0..dim {
                    let sym = s[o2 + i * dim + j] + s[o2 + j * dim + i];
                    let want = lvl1[i] * lvl1[j];
                    assert!((sym - want).abs() < 1e-9, "i={i} j={j}");
                }
            }
        });
    }

    #[test]
    fn on_the_fly_transforms_match_materialised() {
        check("fused transform == materialised transform", 20, |g| {
            let len = g.usize_in(2, 10);
            let dim = g.usize_in(1, 3);
            let depth = g.usize_in(1, 4);
            let path = g.path(len, dim, 0.5);
            for tr in [
                Transform::TimeAug,
                Transform::LeadLag,
                Transform::LeadLagTimeAug,
            ] {
                let fused = signature(&path, len, dim, depth, tr, SigMethod::Horner);
                let mat = crate::transforms::apply(tr, &path, len, dim);
                let want = sig(&mat, tr.out_len(len), tr.out_dim(dim), depth);
                let err = max_abs_diff(&fused, &want);
                assert!(err < 1e-10, "tr={tr:?}: {err}");
            }
        });
    }

    #[test]
    fn try_sig_length_matches_and_bounds() {
        assert_eq!(try_sig_length(2, 4).unwrap(), sig_length(2, 4));
        assert_eq!(try_sig_length(1, 7).unwrap(), sig_length(1, 7));
        assert_eq!(try_sig_length(3, 1).unwrap(), 4);
        // Hostile shapes error instead of overflowing the tensor layout.
        assert!(matches!(
            try_sig_length(2, 64),
            Err(crate::path::SigError::TooLarge(_))
        ));
        assert!(matches!(
            try_sig_length(1, usize::MAX),
            Err(crate::path::SigError::TooLarge(_))
        ));
        assert!(matches!(
            try_sig_length(usize::MAX, 2),
            Err(crate::path::SigError::TooLarge(_))
        ));
        assert!(try_sig_length(0, 3).is_err());
        assert!(try_sig_length(3, 0).is_err());
    }

    #[test]
    fn try_signature_rejects_zero_depth_and_matches_wrapper() {
        let path = [0.0, 0.0, 1.0, 2.0, 3.0, 1.0];
        let p = Path::new(&path, 3, 2).unwrap();
        assert_eq!(
            try_signature(p, &SigOptions::new(0)),
            Err(crate::path::SigError::ZeroDepth)
        );
        let typed = try_signature(p, &SigOptions::new(3)).unwrap();
        let flat = sig(&path, 3, 2, 3);
        assert_eq!(typed, flat);
    }
}
