//! Backpropagation through truncated signatures (paper §2.4).
//!
//! The forward pass is a product of segment exponentials,
//! S_ℓ = S_{ℓ-1} ⊗ exp(z_ℓ). The backward pass walks the path in reverse,
//! *deconstructing* the signature with the time-reversed path —
//! S_{ℓ-1} = S_ℓ ⊗ exp(−z_ℓ), itself one Horner step (the paper's
//! modification of Reizenstein's algorithm) — so the intermediate signatures
//! never need to be stored. At each step the chain rule through
//! S_ℓ = S_{ℓ-1} ⊗ E(z_ℓ) yields three level-wise contractions:
//!
//! * ∂F/∂E_j   = Σ_i  S_{ℓ-1,i} ⌟ G_{i+j}      (left contraction)
//! * ∂F/∂S_i   = Σ_j  G_{i+j} ⌞ E_j             (right contraction)
//! * ∂F/∂z     from ∂F/∂E_j via d(z^{⊗j}/j!)/dz
//!
//! all realised as contiguous gemv-like loops over the flat layout.

use crate::path::{Path, SigError, SigOptions};
use crate::sig::horner::horner_step;
use crate::tensor::{exp_increment, LevelLayout};
use crate::transforms::{increments_vjp, IncrementStream, Transform};

/// Typed, fallible vector–Jacobian product of the truncated signature:
/// given `grad_sig` = ∂F/∂S(x) (flat, length `sig_length(out_dim, depth)`),
/// returns ∂F/∂x as a `[len, dim]` row-major vector.
pub fn try_signature_vjp(
    path: Path<'_>,
    opts: &SigOptions,
    grad_sig: &[f64],
) -> Result<Vec<f64>, SigError> {
    opts.validate()?;
    let od = opts.exec.transform.out_dim(path.dim());
    let slen = crate::sig::try_sig_length(od, opts.depth)?;
    if grad_sig.len() != slen {
        return Err(SigError::CotangentLen {
            expected: slen,
            got: grad_sig.len(),
        });
    }
    let s = crate::sig::try_signature(path, opts)?;
    Ok(signature_vjp_with_sig(
        path.data(),
        path.len(),
        path.dim(),
        opts.depth,
        opts.exec.transform,
        &s,
        grad_sig,
    ))
}

/// Vector–Jacobian product of the truncated signature (flat-slice wrapper
/// over [`try_signature_vjp`]; panics on malformed shapes).
///
/// Given `grad_sig` = ∂F/∂S(x) (flat, length `sig_length(out_dim, depth)`),
/// returns ∂F/∂x as a `[len, dim]` row-major vector. The signature is
/// recomputed internally (one forward sweep) unless provided via
/// [`signature_vjp_with_sig`].
pub fn signature_vjp(
    path: &[f64],
    len: usize,
    dim: usize,
    depth: usize,
    transform: Transform,
    grad_sig: &[f64],
) -> Vec<f64> {
    let p = Path::new(path, len, dim).expect("signature_vjp: invalid path shape");
    try_signature_vjp(p, &SigOptions::new(depth).transform(transform), grad_sig)
        .expect("signature_vjp: invalid cotangent")
}

/// [`signature_vjp`] given the precomputed forward signature `sig` (must be
/// the signature of the *transformed* path at the same depth).
pub fn signature_vjp_with_sig(
    path: &[f64],
    len: usize,
    dim: usize,
    depth: usize,
    transform: Transform,
    sig: &[f64],
    grad_sig: &[f64],
) -> Vec<f64> {
    assert!(depth >= 1);
    let od = transform.out_dim(dim);
    let layout = LevelLayout::new(od, depth);
    assert_eq!(sig.len(), layout.total());
    assert_eq!(grad_sig.len(), layout.total());
    let mut grad_x = vec![0.0; len * dim];
    if len < 2 {
        return grad_x;
    }

    // Materialise the (transformed) increments once; the backward sweep
    // needs them in reverse order.
    let mut stream = IncrementStream::new(path, len, dim, transform);
    let steps = stream.num_steps();
    let mut zs = vec![0.0; steps * od];
    for s_idx in 0..steps {
        let ok = stream.next_into(&mut zs[s_idx * od..(s_idx + 1) * od]);
        debug_assert!(ok);
    }

    let total = layout.total();
    let mut s_cur = sig.to_vec(); // S_ℓ, deconstructed as we walk back
    let mut g = grad_sig.to_vec(); // ∂F/∂S_ℓ
    let mut e = vec![0.0; total];
    let mut grad_e = vec![0.0; total];
    let mut new_g = vec![0.0; total];
    let mut negz = vec![0.0; od];
    let bcap = layout.level_size(depth.saturating_sub(1)).max(1);
    let mut b = vec![0.0; bcap];
    let mut grad_z = vec![0.0; steps * od];
    // factorials 1/j!
    let mut inv_fact = vec![1.0; depth + 1];
    for j in 1..=depth {
        inv_fact[j] = inv_fact[j - 1] / j as f64;
    }
    // scratch for the z-contractions
    let mut contract_a = vec![0.0; layout.level_size(depth)];
    let mut contract_b = vec![0.0; layout.level_size(depth)];

    for step in (0..steps).rev() {
        let z = &zs[step * od..(step + 1) * od];
        // 1. Deconstruct: S_{ℓ-1} = S_ℓ ⊗ exp(−z) — one Horner step.
        for j in 0..od {
            negz[j] = -z[j];
        }
        horner_step(&layout, &mut s_cur, &negz, &mut b);
        // 2. E = exp(z).
        exp_increment(&layout, z, &mut e);

        // 3. grad_E_j = Σ_{i} S_i ⌟ G_{i+j}:
        //    grad_E_j[v] += S_i[u] * G_{i+j}[u*d^j + v].
        grad_e.fill(0.0);
        for j in 1..=depth {
            let (js, je) = layout.level_range(j);
            let lj = je - js;
            let ge = &mut grad_e[js..je];
            for i in 0..=depth - j {
                let (is_, ie) = layout.level_range(i);
                let (ns, _ne) = layout.level_range(i + j);
                let sv = &s_cur[is_..ie];
                for (u, &su) in sv.iter().enumerate() {
                    if su == 0.0 {
                        continue;
                    }
                    let gr = &g[ns + u * lj..ns + (u + 1) * lj];
                    for (o, &gv) in ge.iter_mut().zip(gr.iter()) {
                        *o += su * gv;
                    }
                }
            }
        }

        // 4. New adjoint: grad_S_i[u] = Σ_j ⟨G_{i+j}[u·d^j ..], E_j⟩.
        new_g.fill(0.0);
        for i in 0..=depth {
            let (is_, ie) = layout.level_range(i);
            let li = ie - is_;
            let ng = &mut new_g[is_..ie];
            for j in 0..=depth - i {
                let (js, je) = layout.level_range(j);
                let lj = je - js;
                let (ns, _ne) = layout.level_range(i + j);
                let ev = &e[js..je];
                for u in 0..li {
                    let gr = &g[ns + u * lj..ns + (u + 1) * lj];
                    let mut acc = 0.0;
                    for (&gv, &evv) in gr.iter().zip(ev.iter()) {
                        acc += gv * evv;
                    }
                    ng[u] += acc;
                }
            }
        }

        // 5. grad_z from grad_E: E_j = z^{⊗j}/j!, so
        //    ∂F/∂z_a = Σ_j (1/j!) Σ_{m=1..j} ⟨grad_E_j, z^{m-1} ⊗ e_a ⊗ z^{j-m}⟩.
        let gz = &mut grad_z[step * od..(step + 1) * od];
        for j in 1..=depth {
            let (js, je) = layout.level_range(j);
            let cj = inv_fact[j];
            // Walk m = 1..j keeping "left contraction so far" in contract_a:
            // after m-1 left contractions the live block has d^{j-m+1} entries.
            let mut cur_len = je - js;
            contract_a[..cur_len].copy_from_slice(&grad_e[js..je]);
            for m in 1..=j {
                // Right-contract (j - m) times from contract_a into a d-vector.
                {
                    let src = &contract_a[..cur_len];
                    let mut tmp_len = cur_len;
                    contract_b[..tmp_len].copy_from_slice(src);
                    for _ in 0..j - m {
                        let nlen = tmp_len / od;
                        for w in 0..nlen {
                            let row = &contract_b[w * od..(w + 1) * od];
                            let mut acc = 0.0;
                            for (&t, &zz) in row.iter().zip(z.iter()) {
                                acc += t * zz;
                            }
                            contract_b[w] = acc;
                        }
                        tmp_len = nlen;
                    }
                    debug_assert_eq!(tmp_len, od);
                    for a_ in 0..od {
                        gz[a_] += cj * contract_b[a_];
                    }
                }
                // Left-contract once more for the next m (if any).
                if m < j {
                    let nlen = cur_len / od;
                    for w in 0..nlen {
                        let mut acc = 0.0;
                        for (u, &zz) in z.iter().enumerate() {
                            acc += zz * contract_a[u * nlen + w];
                        }
                        contract_b[w] = acc;
                    }
                    contract_a[..nlen].copy_from_slice(&contract_b[..nlen]);
                    cur_len = nlen;
                }
            }
        }

        std::mem::swap(&mut g, &mut new_g);
    }

    // 6. Scatter increment gradients back to path points through the
    //    transform adjoint.
    increments_vjp(transform, &grad_z, len, dim, &mut grad_x);
    grad_x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sig::sig;
    use crate::util::prop::check;

    /// Central-difference check of the full vjp for all transforms.
    #[test]
    fn vjp_matches_finite_differences() {
        check("signature vjp vs finite differences", 12, |g| {
            let len = g.usize_in(2, 7);
            let dim = g.usize_in(1, 3);
            let depth = g.usize_in(1, 4);
            let path = g.path(len, dim, 0.5);
            for tr in [Transform::None, Transform::TimeAug, Transform::LeadLag] {
                let od = tr.out_dim(dim);
                let slen = crate::sig::sig_length(od, depth);
                let gs = g.normal_vec(slen);
                let gx = signature_vjp(&path, len, dim, depth, tr, &gs);
                let f = |p: &[f64]| -> f64 {
                    let s = crate::sig::signature(
                        p,
                        len,
                        dim,
                        depth,
                        tr,
                        crate::sig::SigMethod::Horner,
                    );
                    s.iter().zip(gs.iter()).map(|(a, b)| a * b).sum()
                };
                let eps = 1e-5;
                for i in 0..len * dim {
                    let mut pp = path.to_vec();
                    pp[i] += eps;
                    let mut pm = path.to_vec();
                    pm[i] -= eps;
                    let fd = (f(&pp) - f(&pm)) / (2.0 * eps);
                    let tol = 1e-4 * (1.0 + fd.abs());
                    assert!(
                        (fd - gx[i]).abs() < tol,
                        "tr={tr:?} len={len} dim={dim} depth={depth} i={i}: fd={fd} vjp={}",
                        gx[i]
                    );
                }
            }
        });
    }

    /// Gradient of level-1 coordinates is exactly endpoint-minus-start.
    #[test]
    fn level_one_gradient_is_telescoping() {
        let len = 6;
        let dim = 2;
        let depth = 3;
        let mut rng = crate::util::rng::Rng::new(17);
        let path = rng.brownian_path(len, dim, 1.0);
        // F = S^{(1)}_0 (first level-1 coordinate) = x_{L-1,0} - x_{0,0}.
        let slen = crate::sig::sig_length(dim, depth);
        let mut gs = vec![0.0; slen];
        gs[1] = 1.0;
        let gx = signature_vjp(&path, len, dim, depth, Transform::None, &gs);
        for i in 0..len {
            for j in 0..dim {
                let want = if j != 0 {
                    0.0
                } else if i == 0 {
                    -1.0
                } else if i == len - 1 {
                    1.0
                } else {
                    0.0
                };
                assert!(
                    (gx[i * dim + j] - want).abs() < 1e-10,
                    "i={i} j={j}: {}",
                    gx[i * dim + j]
                );
            }
        }
    }

    #[test]
    fn zero_cotangent_gives_zero_gradient() {
        let path = [0.0, 0.0, 1.0, 2.0, 0.5, -1.0];
        let gs = vec![0.0; crate::sig::sig_length(2, 3)];
        let gx = signature_vjp(&path, 3, 2, 3, Transform::None, &gs);
        assert!(gx.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn with_sig_variant_matches() {
        let mut rng = crate::util::rng::Rng::new(3);
        let path = rng.brownian_path(8, 2, 0.7);
        let s = sig(&path, 8, 2, 4);
        let mut gs = vec![0.0; s.len()];
        rng.fill_normal(&mut gs);
        let a = signature_vjp(&path, 8, 2, 4, Transform::None, &gs);
        let b = signature_vjp_with_sig(&path, 8, 2, 4, Transform::None, &s, &gs);
        assert!(crate::util::linalg::max_abs_diff(&a, &b) < 1e-12);
    }
}
