//! Algorithm 2 — Horner's scheme for the signature update, as used by
//! signatory and pySigLib.
//!
//! For each level k (N down to 2) the update
//!   A_k ← Σ_{i=0..k} A_i ⊗ z^{⊗(k-i)}/(k-i)!
//! is factored as
//!   A_k ← (B_k + A_{k-1}) ⊗ z + A_k,
//!   B_k = ((…((z/k + A_1) ⊗ z/(k-1) + A_2) ⊗ z/(k-2) + …) ⊗ z/2,
//! which minimises tensor multiplications and memory traffic.
//!
//! Design choices (paper §2.3): (3) one contiguous scratch block sized for
//! B_N is reused by every level's B_k, and the in-place multiplication
//! `B ← B ⊗ z/(k-i)` runs in *reverse* index order so old entries are only
//! overwritten after their last read; (4) the final `(B + A_{k-1}) ⊗ z` is
//! accumulated directly into A_k.

use crate::tensor::LevelLayout;

/// One Chen step by Horner's algorithm: `a ← a ⊗ exp(z)`, in place.
///
/// `b` is caller-provided scratch of length ≥ d^(N-1) (i.e.
/// `layout.level_size(N-1)`), reused across calls — design choice (3).
pub fn horner_step(layout: &LevelLayout, a: &mut [f64], z: &[f64], b: &mut [f64]) {
    let d = layout.dim;
    let depth = layout.depth;
    debug_assert_eq!(a.len(), layout.total());
    debug_assert_eq!(z.len(), d);
    if depth >= 2 {
        debug_assert!(b.len() >= layout.level_size(depth - 1));
    }
    for k in (2..=depth).rev() {
        // B = z / k  (level-1 content)
        let inv_k = 1.0 / k as f64;
        for j in 0..d {
            b[j] = z[j] * inv_k;
        }
        let mut cur = d; // current number of live entries in b (level i+1 has d^{i+1})
        for i in 1..=k.saturating_sub(2) {
            // B += A_i
            let (is_, ie) = layout.level_range(i);
            let av = &a[is_..ie];
            for (bv, &avv) in b[..cur].iter_mut().zip(av.iter()) {
                *bv += avv;
            }
            // B ← B ⊗ z/(k-i), in place, reverse order over u (design
            // choice (3)): u descending guarantees b[u] is read before the
            // write range [u·d, u·d+d) can touch it. Within one u the read
            // happens first, so j ascends — contiguous stores vectorize.
            let scale = 1.0 / (k - i) as f64;
            for u in (0..cur).rev() {
                let v = b[u] * scale;
                let dst = u * d;
                for j in 0..d {
                    b[dst + j] = v * z[j];
                }
            }
            cur *= d;
        }
        // B += A_{k-1}
        let (ps, pe) = layout.level_range(k - 1);
        debug_assert_eq!(cur, pe - ps);
        {
            let (lower, _) = a.split_at(pe);
            let av = &lower[ps..pe];
            for (bv, &avv) in b[..cur].iter_mut().zip(av.iter()) {
                *bv += avv;
            }
        }
        // A_k += B ⊗ z  (design choice (4): written directly into A_k).
        let (ks, _ke) = layout.level_range(k);
        let out = &mut a[ks..ks + cur * d];
        for u in 0..cur {
            let bu = b[u];
            if bu == 0.0 {
                continue;
            }
            let dst = &mut out[u * d..(u + 1) * d];
            for (o, &zj) in dst.iter_mut().zip(z.iter()) {
                *o += bu * zj;
            }
        }
    }
    // A_1 += z
    if depth >= 1 {
        for j in 0..d {
            a[1 + j] += z[j];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{exp_increment, tensor_prod};
    use crate::util::linalg::max_abs_diff;
    use crate::util::prop::check;

    #[test]
    fn step_equals_tensor_product_with_exp() {
        check("horner step == A ⊗ exp(z)", 40, |g| {
            let d = g.usize_in(1, 4);
            let n = g.usize_in(1, 6);
            let layout = LevelLayout::new(d, n);
            let mut a = g.normal_vec(layout.total());
            a[0] = 1.0;
            let z = g.normal_vec(d);
            let mut e = vec![0.0; layout.total()];
            exp_increment(&layout, &z, &mut e);
            let mut want = vec![0.0; layout.total()];
            tensor_prod(&layout, &a, &e, &mut want);
            let bcap = layout.level_size(n.saturating_sub(1)).max(1);
            let mut b = vec![0.0; bcap];
            horner_step(&layout, &mut a, &z, &mut b);
            let err = max_abs_diff(&a, &want);
            assert!(err < 1e-10, "err {err}");
        });
    }

    #[test]
    fn depth_one_only_updates_level_one() {
        let layout = LevelLayout::new(2, 1);
        let mut a = vec![1.0, 0.5, -0.5];
        let mut b = vec![0.0; 1];
        horner_step(&layout, &mut a, &[1.0, 2.0], &mut b);
        assert_eq!(a, vec![1.0, 1.5, 1.5]);
    }

    #[test]
    fn dim_one_paths_work() {
        // d = 1: every level has a single entry; exercises the u*d == u
        // aliasing edge of the in-place reverse multiply.
        let layout = LevelLayout::new(1, 6);
        let mut a = vec![0.0; layout.total()];
        exp_increment(&layout, &[0.5], &mut a);
        let mut b = vec![0.0; 1];
        horner_step(&layout, &mut a, &[0.25], &mut b);
        // Signature of a 1-d path depends only on total increment: exp(0.75).
        let mut want = vec![0.0; layout.total()];
        exp_increment(&layout, &[0.75], &mut want);
        assert!(max_abs_diff(&a, &want) < 1e-12);
    }
}
