//! Batched signature APIs: one output row per path, optionally parallel over
//! the batch (the paper's Table 1 "serial" vs "parallel" columns).
//!
//! The typed entry points take a [`PathBatch`] and therefore support
//! **ragged** batches (paths of different lengths, no padding): signature
//! rows stay uniform — the signature length depends only on the (transformed)
//! dimension and the depth — while vjps come back in the batch's own ragged
//! layout.

pub use crate::path::SigOptions;
use crate::path::{PathBatch, SigError};
use crate::sig::{sig_length, signature_vjp, try_sig_length, try_signature};
use crate::util::pool::{parallel_for, parallel_for_mut, parallel_for_mut_ragged};

/// Hard cap on the number of f64s a batched output may hold (2^30 = 8 GiB) —
/// a wire-reachable allocation guard, not a practical limitation.
const MAX_BATCH_OUT: usize = 1 << 30;

/// Signatures of a typed (possibly ragged) batch of paths.
///
/// Returns `[batch, sig_length(out_dim, depth)]` row-major — rows are
/// uniform even for ragged batches.
pub fn try_batch_signature(
    paths: &PathBatch<'_>,
    opts: &SigOptions,
) -> Result<Vec<f64>, SigError> {
    opts.validate()?;
    let od = opts.exec.transform.out_dim(paths.dim());
    let slen = try_sig_length(od, opts.depth)?;
    let b = paths.batch();
    let total = b
        .checked_mul(slen)
        .filter(|&t| t <= MAX_BATCH_OUT)
        .ok_or(SigError::TooLarge("batched signature output"))?;
    let mut out = vec![0.0; total];
    if b == 0 {
        return Ok(out);
    }
    let work = |i: usize, row: &mut [f64]| {
        // Cannot fail: the batch and options were validated above.
        let s = try_signature(paths.path(i), opts).expect("validated");
        row.copy_from_slice(&s);
    };
    if opts.exec.parallel {
        parallel_for_mut(&mut out, slen, work);
    } else {
        for (i, row) in out.chunks_mut(slen).enumerate() {
            work(i, row);
        }
    }
    Ok(out)
}

/// Batched vjp over a typed (possibly ragged) batch: given ∂F/∂signatures
/// `[batch, slen]`, return ∂F/∂paths in the batch's flat (ragged) layout.
pub fn try_batch_signature_vjp(
    paths: &PathBatch<'_>,
    grad_sigs: &[f64],
    opts: &SigOptions,
) -> Result<Vec<f64>, SigError> {
    opts.validate()?;
    let od = opts.exec.transform.out_dim(paths.dim());
    let slen = try_sig_length(od, opts.depth)?;
    let b = paths.batch();
    let expected = b
        .checked_mul(slen)
        .filter(|&t| t <= MAX_BATCH_OUT)
        .ok_or(SigError::TooLarge("batched signature cotangent"))?;
    if grad_sigs.len() != expected {
        return Err(SigError::CotangentLen {
            expected,
            got: grad_sigs.len(),
        });
    }
    let dim = paths.dim();
    let mut out = vec![0.0; paths.total_points() * dim];
    if b == 0 {
        return Ok(out);
    }
    let bounds = paths.element_offsets();
    let work = |i: usize, row: &mut [f64]| {
        let p = paths.path(i);
        let gs = &grad_sigs[i * slen..(i + 1) * slen];
        let gx = signature_vjp(p.data(), p.len(), p.dim(), opts.depth, opts.exec.transform, gs);
        row.copy_from_slice(&gx);
    };
    if opts.exec.parallel {
        parallel_for_mut_ragged(&mut out, &bounds, work);
    } else {
        for i in 0..b {
            let (lo, hi) = (bounds[i], bounds[i + 1]);
            work(i, &mut out[lo..hi]);
        }
    }
    Ok(out)
}

/// Signatures of a uniform batch of paths (flat-slice wrapper over
/// [`try_batch_signature`]; panics on malformed shapes).
///
/// * `paths` — row-major `[batch, len, dim]`.
/// * returns `[batch, sig_length(out_dim, depth)]`.
pub fn batch_signature(
    paths: &[f64],
    batch: usize,
    len: usize,
    dim: usize,
    opts: &SigOptions,
) -> Vec<f64> {
    let pb = PathBatch::uniform(paths, batch, len, dim)
        .expect("batch_signature: invalid batch shape");
    try_batch_signature(&pb, opts).expect("batch_signature: invalid options")
}

/// Batched vjp (flat-slice wrapper over [`try_batch_signature_vjp`]): given
/// ∂F/∂signatures `[batch, slen]`, return ∂F/∂paths `[batch, len, dim]`.
pub fn batch_signature_vjp(
    paths: &[f64],
    grad_sigs: &[f64],
    batch: usize,
    len: usize,
    dim: usize,
    opts: &SigOptions,
) -> Vec<f64> {
    let pb = PathBatch::uniform(paths, batch, len, dim)
        .expect("batch_signature_vjp: invalid batch shape");
    try_batch_signature_vjp(&pb, grad_sigs, opts).expect("batch_signature_vjp: invalid cotangent")
}

/// Convenience: mean of signatures over the batch — the "expected signature",
/// used by the MMD/two-sample example. Parallel reduction over chunks.
pub fn expected_signature(
    paths: &[f64],
    batch: usize,
    len: usize,
    dim: usize,
    opts: &SigOptions,
) -> Vec<f64> {
    let od = opts.exec.transform.out_dim(dim);
    let slen = sig_length(od, opts.depth);
    let sigs = batch_signature(paths, batch, len, dim, opts);
    let mut mean = vec![0.0; slen];
    for row in sigs.chunks(slen) {
        for (m, &v) in mean.iter_mut().zip(row.iter()) {
            *m += v;
        }
    }
    let inv = 1.0 / batch.max(1) as f64;
    for m in mean.iter_mut() {
        *m *= inv;
    }
    mean
}

/// Stream large batches through a bounded amount of memory: calls `sink`
/// with (index, signature) instead of materialising `[batch, slen]`.
pub fn batch_signature_streaming<F: Fn(usize, &[f64]) + Sync>(
    paths: &[f64],
    batch: usize,
    len: usize,
    dim: usize,
    opts: &SigOptions,
    sink: F,
) {
    let pb = PathBatch::uniform(paths, batch, len, dim)
        .expect("batch_signature_streaming: invalid batch shape");
    parallel_for(batch, |i| {
        let s = try_signature(pb.path(i), opts).expect("validated");
        sink(i, &s);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sig::SigMethod;
    use crate::transforms::Transform;
    use crate::util::linalg::max_abs_diff;
    use crate::util::rng::Rng;

    #[test]
    fn batch_matches_single() {
        let mut rng = Rng::new(2);
        let (b, l, d, n) = (7, 12, 3, 4);
        let paths = rng.brownian_batch(b, l, d, 0.5);
        let opts = SigOptions::new(n);
        let out = batch_signature(&paths, b, l, d, &opts);
        let slen = sig_length(d, n);
        for i in 0..b {
            let single = crate::sig::sig(&paths[i * l * d..(i + 1) * l * d], l, d, n);
            assert!(max_abs_diff(&out[i * slen..(i + 1) * slen], &single) < 1e-14);
        }
    }

    #[test]
    fn serial_and_parallel_agree() {
        let mut rng = Rng::new(4);
        let (b, l, d, n) = (16, 20, 2, 5);
        let paths = rng.brownian_batch(b, l, d, 0.5);
        let par = batch_signature(&paths, b, l, d, &SigOptions::new(n));
        let ser = batch_signature(&paths, b, l, d, &SigOptions::new(n).serial());
        assert!(max_abs_diff(&par, &ser) < 1e-15);
    }

    #[test]
    fn batch_vjp_matches_single() {
        let mut rng = Rng::new(8);
        let (b, l, d, n) = (5, 8, 2, 3);
        let paths = rng.brownian_batch(b, l, d, 0.5);
        let slen = sig_length(d, n);
        let mut gs = vec![0.0; b * slen];
        rng.fill_normal(&mut gs);
        let opts = SigOptions::new(n);
        let gx = batch_signature_vjp(&paths, &gs, b, l, d, &opts);
        for i in 0..b {
            let single = signature_vjp(
                &paths[i * l * d..(i + 1) * l * d],
                l,
                d,
                n,
                Transform::None,
                &gs[i * slen..(i + 1) * slen],
            );
            assert!(max_abs_diff(&gx[i * l * d..(i + 1) * l * d], &single) < 1e-14);
        }
    }

    #[test]
    fn expected_signature_is_mean() {
        let mut rng = Rng::new(12);
        let (b, l, d, n) = (4, 6, 2, 3);
        let paths = rng.brownian_batch(b, l, d, 0.5);
        let opts = SigOptions::new(n);
        let es = expected_signature(&paths, b, l, d, &opts);
        let sigs = batch_signature(&paths, b, l, d, &opts);
        let slen = sig_length(d, n);
        for j in 0..slen {
            let mean: f64 = (0..b).map(|i| sigs[i * slen + j]).sum::<f64>() / b as f64;
            assert!((es[j] - mean).abs() < 1e-12);
        }
    }

    #[test]
    fn streaming_matches_batch() {
        let mut rng = Rng::new(13);
        let (b, l, d, n) = (6, 10, 2, 3);
        let paths = rng.brownian_batch(b, l, d, 0.5);
        let opts = SigOptions::new(n);
        let batchout = batch_signature(&paths, b, l, d, &opts);
        let slen = sig_length(d, n);
        let collected = std::sync::Mutex::new(vec![0.0; b * slen]);
        batch_signature_streaming(&paths, b, l, d, &opts, |i, s| {
            collected.lock().unwrap()[i * slen..(i + 1) * slen].copy_from_slice(s);
        });
        assert!(max_abs_diff(&collected.into_inner().unwrap(), &batchout) < 1e-15);
    }

    /// Ragged batches bit-match a per-path loop over `sig` — including
    /// length-1 paths (identity signature).
    #[test]
    fn ragged_batch_bitmatches_per_path_loop() {
        let mut rng = Rng::new(14);
        let (d, n) = (2, 3);
        let lengths = [5usize, 1, 12, 2, 7];
        let mut data = Vec::new();
        for &l in &lengths {
            data.extend(rng.brownian_path(l, d, 0.5));
        }
        let pb = PathBatch::ragged(&data, &lengths, d).unwrap();
        for opts in [SigOptions::new(n), SigOptions::new(n).serial()] {
            let out = try_batch_signature(&pb, &opts).unwrap();
            let slen = sig_length(d, n);
            let mut off = 0;
            for (i, &l) in lengths.iter().enumerate() {
                let want = crate::sig::sig(&data[off * d..(off + l) * d], l, d, n);
                assert_eq!(&out[i * slen..(i + 1) * slen], &want[..], "path {i}");
                off += l;
            }
        }
    }

    /// Ragged vjp bit-matches the per-path loop, in the ragged layout.
    #[test]
    fn ragged_vjp_bitmatches_per_path_loop() {
        let mut rng = Rng::new(15);
        let (d, n) = (2, 3);
        let lengths = [4usize, 1, 9, 3];
        let mut data = Vec::new();
        for &l in &lengths {
            data.extend(rng.brownian_path(l, d, 0.5));
        }
        let pb = PathBatch::ragged(&data, &lengths, d).unwrap();
        let slen = sig_length(d, n);
        let mut gs = vec![0.0; lengths.len() * slen];
        rng.fill_normal(&mut gs);
        let gx = try_batch_signature_vjp(&pb, &gs, &SigOptions::new(n)).unwrap();
        assert_eq!(gx.len(), pb.total_points() * d);
        let mut off = 0;
        for (i, &l) in lengths.iter().enumerate() {
            let want = signature_vjp(
                &data[off * d..(off + l) * d],
                l,
                d,
                n,
                Transform::None,
                &gs[i * slen..(i + 1) * slen],
            );
            assert_eq!(&gx[off * d..(off + l) * d], &want[..], "path {i}");
            off += l;
        }
    }

    #[test]
    fn empty_ragged_batch_yields_empty_output() {
        let pb = PathBatch::ragged(&[], &[], 3).unwrap();
        let out = try_batch_signature(&pb, &SigOptions::new(4)).unwrap();
        assert!(out.is_empty());
        let gx = try_batch_signature_vjp(&pb, &[], &SigOptions::new(4)).unwrap();
        assert!(gx.is_empty());
    }

    #[test]
    fn bad_cotangent_length_is_an_error() {
        let data = [0.0, 0.0, 1.0, 1.0];
        let pb = PathBatch::uniform(&data, 1, 2, 2).unwrap();
        let r = try_batch_signature_vjp(&pb, &[1.0, 2.0], &SigOptions::new(2));
        assert!(matches!(r, Err(SigError::CotangentLen { .. })));
    }

    #[test]
    fn methods_agree_on_ragged_batches() {
        let mut rng = Rng::new(16);
        let d = 2;
        let lengths = [3usize, 6, 2];
        let mut data = Vec::new();
        for &l in &lengths {
            data.extend(rng.brownian_path(l, d, 0.4));
        }
        let pb = PathBatch::ragged(&data, &lengths, d).unwrap();
        let h = try_batch_signature(&pb, &SigOptions::new(3)).unwrap();
        let dr = try_batch_signature(&pb, &SigOptions::new(3).method(SigMethod::Direct)).unwrap();
        assert!(max_abs_diff(&h, &dr) < 1e-10);
    }
}
