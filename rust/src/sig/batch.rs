//! Batched signature APIs: one output row per path, optionally parallel over
//! the batch (the paper's Table 1 "serial" vs "parallel" columns).
//!
//! The typed entry points take a [`PathBatch`] and therefore support
//! **ragged** batches (paths of different lengths, no padding): signature
//! rows stay uniform — the signature length depends only on the (transformed)
//! dimension and the depth — while vjps come back in the batch's own ragged
//! layout.

pub use crate::path::SigOptions;
use crate::engine::{OpSpec, Plan, ShapeClass};
use crate::path::{PathBatch, SigError};
use crate::sig::{sig_length, try_signature};
use crate::util::pool::parallel_for;

/// Signatures of a typed (possibly ragged) batch of paths — a thin wrapper
/// that compiles a one-shot forward [`Plan`]; compile the plan yourself (or
/// use a [`Session`](crate::engine::Session)) to amortise it across calls.
///
/// Returns `[batch, sig_length(out_dim, depth)]` row-major — rows are
/// uniform even for ragged batches.
pub fn try_batch_signature(
    paths: &PathBatch<'_>,
    opts: &SigOptions,
) -> Result<Vec<f64>, SigError> {
    let plan = Plan::compile_forward(OpSpec::Sig(*opts), ShapeClass::for_batch(paths))?;
    Ok(plan.execute(paths)?.into_values())
}

/// Batched vjp over a typed (possibly ragged) batch: given ∂F/∂signatures
/// `[batch, slen]`, return ∂F/∂paths in the batch's flat (ragged) layout.
/// Routed through [`ExecutionRecord::vjp`](crate::engine::ExecutionRecord::vjp),
/// so the forward signatures feed the backward sweep directly.
pub fn try_batch_signature_vjp(
    paths: &PathBatch<'_>,
    grad_sigs: &[f64],
    opts: &SigOptions,
) -> Result<Vec<f64>, SigError> {
    let plan = Plan::compile(OpSpec::Sig(*opts), ShapeClass::for_batch(paths))?;
    let record = plan.execute(paths)?;
    record.vjp(grad_sigs)?.into_single()
}

/// Signatures of a uniform batch of paths (flat-slice wrapper over
/// [`try_batch_signature`]; panics on malformed shapes).
///
/// * `paths` — row-major `[batch, len, dim]`.
/// * returns `[batch, sig_length(out_dim, depth)]`.
pub fn batch_signature(
    paths: &[f64],
    batch: usize,
    len: usize,
    dim: usize,
    opts: &SigOptions,
) -> Vec<f64> {
    let pb = PathBatch::uniform(paths, batch, len, dim)
        .expect("batch_signature: invalid batch shape");
    try_batch_signature(&pb, opts).expect("batch_signature: invalid options")
}

/// Batched vjp (flat-slice wrapper over [`try_batch_signature_vjp`]): given
/// ∂F/∂signatures `[batch, slen]`, return ∂F/∂paths `[batch, len, dim]`.
pub fn batch_signature_vjp(
    paths: &[f64],
    grad_sigs: &[f64],
    batch: usize,
    len: usize,
    dim: usize,
    opts: &SigOptions,
) -> Vec<f64> {
    let pb = PathBatch::uniform(paths, batch, len, dim)
        .expect("batch_signature_vjp: invalid batch shape");
    try_batch_signature_vjp(&pb, grad_sigs, opts).expect("batch_signature_vjp: invalid cotangent")
}

/// Convenience: mean of signatures over the batch — the "expected signature",
/// used by the MMD/two-sample example. Parallel reduction over chunks.
pub fn expected_signature(
    paths: &[f64],
    batch: usize,
    len: usize,
    dim: usize,
    opts: &SigOptions,
) -> Vec<f64> {
    let od = opts.exec.transform.out_dim(dim);
    let slen = sig_length(od, opts.depth);
    let sigs = batch_signature(paths, batch, len, dim, opts);
    let mut mean = vec![0.0; slen];
    for row in sigs.chunks(slen) {
        for (m, &v) in mean.iter_mut().zip(row.iter()) {
            *m += v;
        }
    }
    let inv = 1.0 / batch.max(1) as f64;
    for m in mean.iter_mut() {
        *m *= inv;
    }
    mean
}

/// Stream large batches through a bounded amount of memory: calls `sink`
/// with (index, signature) instead of materialising `[batch, slen]`.
pub fn batch_signature_streaming<F: Fn(usize, &[f64]) + Sync>(
    paths: &[f64],
    batch: usize,
    len: usize,
    dim: usize,
    opts: &SigOptions,
    sink: F,
) {
    let pb = PathBatch::uniform(paths, batch, len, dim)
        .expect("batch_signature_streaming: invalid batch shape");
    parallel_for(batch, |i| {
        let s = try_signature(pb.path(i), opts).expect("validated");
        sink(i, &s);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sig::{signature_vjp, SigMethod};
    use crate::transforms::Transform;
    use crate::util::linalg::max_abs_diff;
    use crate::util::rng::Rng;

    #[test]
    fn batch_matches_single() {
        let mut rng = Rng::new(2);
        let (b, l, d, n) = (7, 12, 3, 4);
        let paths = rng.brownian_batch(b, l, d, 0.5);
        let opts = SigOptions::new(n);
        let out = batch_signature(&paths, b, l, d, &opts);
        let slen = sig_length(d, n);
        for i in 0..b {
            let single = crate::sig::sig(&paths[i * l * d..(i + 1) * l * d], l, d, n);
            assert!(max_abs_diff(&out[i * slen..(i + 1) * slen], &single) < 1e-14);
        }
    }

    #[test]
    fn serial_and_parallel_agree() {
        let mut rng = Rng::new(4);
        let (b, l, d, n) = (16, 20, 2, 5);
        let paths = rng.brownian_batch(b, l, d, 0.5);
        let par = batch_signature(&paths, b, l, d, &SigOptions::new(n));
        let ser = batch_signature(&paths, b, l, d, &SigOptions::new(n).serial());
        assert!(max_abs_diff(&par, &ser) < 1e-15);
    }

    #[test]
    fn batch_vjp_matches_single() {
        let mut rng = Rng::new(8);
        let (b, l, d, n) = (5, 8, 2, 3);
        let paths = rng.brownian_batch(b, l, d, 0.5);
        let slen = sig_length(d, n);
        let mut gs = vec![0.0; b * slen];
        rng.fill_normal(&mut gs);
        let opts = SigOptions::new(n);
        let gx = batch_signature_vjp(&paths, &gs, b, l, d, &opts);
        for i in 0..b {
            let single = signature_vjp(
                &paths[i * l * d..(i + 1) * l * d],
                l,
                d,
                n,
                Transform::None,
                &gs[i * slen..(i + 1) * slen],
            );
            assert!(max_abs_diff(&gx[i * l * d..(i + 1) * l * d], &single) < 1e-14);
        }
    }

    #[test]
    fn expected_signature_is_mean() {
        let mut rng = Rng::new(12);
        let (b, l, d, n) = (4, 6, 2, 3);
        let paths = rng.brownian_batch(b, l, d, 0.5);
        let opts = SigOptions::new(n);
        let es = expected_signature(&paths, b, l, d, &opts);
        let sigs = batch_signature(&paths, b, l, d, &opts);
        let slen = sig_length(d, n);
        for j in 0..slen {
            let mean: f64 = (0..b).map(|i| sigs[i * slen + j]).sum::<f64>() / b as f64;
            assert!((es[j] - mean).abs() < 1e-12);
        }
    }

    #[test]
    fn streaming_matches_batch() {
        let mut rng = Rng::new(13);
        let (b, l, d, n) = (6, 10, 2, 3);
        let paths = rng.brownian_batch(b, l, d, 0.5);
        let opts = SigOptions::new(n);
        let batchout = batch_signature(&paths, b, l, d, &opts);
        let slen = sig_length(d, n);
        let collected = std::sync::Mutex::new(vec![0.0; b * slen]);
        batch_signature_streaming(&paths, b, l, d, &opts, |i, s| {
            collected.lock().unwrap()[i * slen..(i + 1) * slen].copy_from_slice(s);
        });
        assert!(max_abs_diff(&collected.into_inner().unwrap(), &batchout) < 1e-15);
    }

    /// Ragged batches bit-match a per-path loop over `sig` — including
    /// length-1 paths (identity signature).
    #[test]
    fn ragged_batch_bitmatches_per_path_loop() {
        let mut rng = Rng::new(14);
        let (d, n) = (2, 3);
        let lengths = [5usize, 1, 12, 2, 7];
        let mut data = Vec::new();
        for &l in &lengths {
            data.extend(rng.brownian_path(l, d, 0.5));
        }
        let pb = PathBatch::ragged(&data, &lengths, d).unwrap();
        for opts in [SigOptions::new(n), SigOptions::new(n).serial()] {
            let out = try_batch_signature(&pb, &opts).unwrap();
            let slen = sig_length(d, n);
            let mut off = 0;
            for (i, &l) in lengths.iter().enumerate() {
                let want = crate::sig::sig(&data[off * d..(off + l) * d], l, d, n);
                assert_eq!(&out[i * slen..(i + 1) * slen], &want[..], "path {i}");
                off += l;
            }
        }
    }

    /// Ragged vjp bit-matches the per-path loop, in the ragged layout.
    #[test]
    fn ragged_vjp_bitmatches_per_path_loop() {
        let mut rng = Rng::new(15);
        let (d, n) = (2, 3);
        let lengths = [4usize, 1, 9, 3];
        let mut data = Vec::new();
        for &l in &lengths {
            data.extend(rng.brownian_path(l, d, 0.5));
        }
        let pb = PathBatch::ragged(&data, &lengths, d).unwrap();
        let slen = sig_length(d, n);
        let mut gs = vec![0.0; lengths.len() * slen];
        rng.fill_normal(&mut gs);
        let gx = try_batch_signature_vjp(&pb, &gs, &SigOptions::new(n)).unwrap();
        assert_eq!(gx.len(), pb.total_points() * d);
        let mut off = 0;
        for (i, &l) in lengths.iter().enumerate() {
            let want = signature_vjp(
                &data[off * d..(off + l) * d],
                l,
                d,
                n,
                Transform::None,
                &gs[i * slen..(i + 1) * slen],
            );
            assert_eq!(&gx[off * d..(off + l) * d], &want[..], "path {i}");
            off += l;
        }
    }

    #[test]
    fn empty_ragged_batch_yields_empty_output() {
        let pb = PathBatch::ragged(&[], &[], 3).unwrap();
        let out = try_batch_signature(&pb, &SigOptions::new(4)).unwrap();
        assert!(out.is_empty());
        let gx = try_batch_signature_vjp(&pb, &[], &SigOptions::new(4)).unwrap();
        assert!(gx.is_empty());
    }

    #[test]
    fn bad_cotangent_length_is_an_error() {
        let data = [0.0, 0.0, 1.0, 1.0];
        let pb = PathBatch::uniform(&data, 1, 2, 2).unwrap();
        let r = try_batch_signature_vjp(&pb, &[1.0, 2.0], &SigOptions::new(2));
        assert!(matches!(r, Err(SigError::CotangentLen { .. })));
    }

    #[test]
    fn methods_agree_on_ragged_batches() {
        let mut rng = Rng::new(16);
        let d = 2;
        let lengths = [3usize, 6, 2];
        let mut data = Vec::new();
        for &l in &lengths {
            data.extend(rng.brownian_path(l, d, 0.4));
        }
        let pb = PathBatch::ragged(&data, &lengths, d).unwrap();
        let h = try_batch_signature(&pb, &SigOptions::new(3)).unwrap();
        let dr = try_batch_signature(&pb, &SigOptions::new(3).method(SigMethod::Direct)).unwrap();
        assert!(max_abs_diff(&h, &dr) < 1e-10);
    }
}
