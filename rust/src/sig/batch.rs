//! Batched signature APIs: one output row per path, optionally parallel over
//! the batch (the paper's Table 1 "serial" vs "parallel" columns).

use crate::sig::{SigMethod, sig_length, signature, signature_vjp};
use crate::transforms::Transform;
use crate::util::pool::{parallel_for_mut, parallel_for};

/// Options for batched signature computation.
#[derive(Clone, Copy, Debug)]
pub struct SigOptions {
    pub depth: usize,
    pub transform: Transform,
    pub method: SigMethod,
    /// Parallelise over the batch dimension.
    pub parallel: bool,
}

impl SigOptions {
    pub fn new(depth: usize) -> Self {
        SigOptions {
            depth,
            transform: Transform::None,
            method: SigMethod::Horner,
            parallel: true,
        }
    }
    pub fn transform(mut self, t: Transform) -> Self {
        self.transform = t;
        self
    }
    pub fn method(mut self, m: SigMethod) -> Self {
        self.method = m;
        self
    }
    pub fn serial(mut self) -> Self {
        self.parallel = false;
        self
    }
}

/// Signatures of a batch of paths.
///
/// * `paths` — row-major `[batch, len, dim]`.
/// * returns `[batch, sig_length(out_dim, depth)]`.
pub fn batch_signature(
    paths: &[f64],
    batch: usize,
    len: usize,
    dim: usize,
    opts: &SigOptions,
) -> Vec<f64> {
    assert_eq!(paths.len(), batch * len * dim);
    let od = opts.transform.out_dim(dim);
    let slen = sig_length(od, opts.depth);
    let mut out = vec![0.0; batch * slen];
    if batch == 0 {
        return out;
    }
    let work = |i: usize, row: &mut [f64]| {
        let p = &paths[i * len * dim..(i + 1) * len * dim];
        let s = signature(p, len, dim, opts.depth, opts.transform, opts.method);
        row.copy_from_slice(&s);
    };
    if opts.parallel {
        parallel_for_mut(&mut out, slen, work);
    } else {
        for (i, row) in out.chunks_mut(slen).enumerate() {
            work(i, row);
        }
    }
    out
}

/// Batched vjp: given ∂F/∂signatures `[batch, slen]`, return ∂F/∂paths
/// `[batch, len, dim]`.
pub fn batch_signature_vjp(
    paths: &[f64],
    grad_sigs: &[f64],
    batch: usize,
    len: usize,
    dim: usize,
    opts: &SigOptions,
) -> Vec<f64> {
    assert_eq!(paths.len(), batch * len * dim);
    let od = opts.transform.out_dim(dim);
    let slen = sig_length(od, opts.depth);
    assert_eq!(grad_sigs.len(), batch * slen);
    let mut out = vec![0.0; batch * len * dim];
    if batch == 0 {
        return out;
    }
    let stride = len * dim;
    let work = |i: usize, row: &mut [f64]| {
        let p = &paths[i * stride..(i + 1) * stride];
        let gs = &grad_sigs[i * slen..(i + 1) * slen];
        let gx = signature_vjp(p, len, dim, opts.depth, opts.transform, gs);
        row.copy_from_slice(&gx);
    };
    if opts.parallel {
        parallel_for_mut(&mut out, stride, work);
    } else {
        for (i, row) in out.chunks_mut(stride).enumerate() {
            work(i, row);
        }
    }
    out
}

/// Convenience: mean of signatures over the batch — the "expected signature",
/// used by the MMD/two-sample example. Parallel reduction over chunks.
pub fn expected_signature(
    paths: &[f64],
    batch: usize,
    len: usize,
    dim: usize,
    opts: &SigOptions,
) -> Vec<f64> {
    let od = opts.transform.out_dim(dim);
    let slen = sig_length(od, opts.depth);
    let sigs = batch_signature(paths, batch, len, dim, opts);
    let mut mean = vec![0.0; slen];
    for row in sigs.chunks(slen) {
        for (m, &v) in mean.iter_mut().zip(row.iter()) {
            *m += v;
        }
    }
    let inv = 1.0 / batch.max(1) as f64;
    for m in mean.iter_mut() {
        *m *= inv;
    }
    mean
}

/// Stream large batches through a bounded amount of memory: calls `sink`
/// with (index, signature) instead of materialising `[batch, slen]`.
pub fn batch_signature_streaming<F: Fn(usize, &[f64]) + Sync>(
    paths: &[f64],
    batch: usize,
    len: usize,
    dim: usize,
    opts: &SigOptions,
    sink: F,
) {
    assert_eq!(paths.len(), batch * len * dim);
    parallel_for(batch, |i| {
        let p = &paths[i * len * dim..(i + 1) * len * dim];
        let s = signature(p, len, dim, opts.depth, opts.transform, opts.method);
        sink(i, &s);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::linalg::max_abs_diff;
    use crate::util::rng::Rng;

    #[test]
    fn batch_matches_single() {
        let mut rng = Rng::new(2);
        let (b, l, d, n) = (7, 12, 3, 4);
        let paths = rng.brownian_batch(b, l, d, 0.5);
        let opts = SigOptions::new(n);
        let out = batch_signature(&paths, b, l, d, &opts);
        let slen = sig_length(d, n);
        for i in 0..b {
            let single = crate::sig::sig(&paths[i * l * d..(i + 1) * l * d], l, d, n);
            assert!(max_abs_diff(&out[i * slen..(i + 1) * slen], &single) < 1e-14);
        }
    }

    #[test]
    fn serial_and_parallel_agree() {
        let mut rng = Rng::new(4);
        let (b, l, d, n) = (16, 20, 2, 5);
        let paths = rng.brownian_batch(b, l, d, 0.5);
        let par = batch_signature(&paths, b, l, d, &SigOptions::new(n));
        let ser = batch_signature(&paths, b, l, d, &SigOptions::new(n).serial());
        assert!(max_abs_diff(&par, &ser) < 1e-15);
    }

    #[test]
    fn batch_vjp_matches_single() {
        let mut rng = Rng::new(8);
        let (b, l, d, n) = (5, 8, 2, 3);
        let paths = rng.brownian_batch(b, l, d, 0.5);
        let slen = sig_length(d, n);
        let mut gs = vec![0.0; b * slen];
        rng.fill_normal(&mut gs);
        let opts = SigOptions::new(n);
        let gx = batch_signature_vjp(&paths, &gs, b, l, d, &opts);
        for i in 0..b {
            let single = signature_vjp(
                &paths[i * l * d..(i + 1) * l * d],
                l,
                d,
                n,
                Transform::None,
                &gs[i * slen..(i + 1) * slen],
            );
            assert!(max_abs_diff(&gx[i * l * d..(i + 1) * l * d], &single) < 1e-14);
        }
    }

    #[test]
    fn expected_signature_is_mean() {
        let mut rng = Rng::new(12);
        let (b, l, d, n) = (4, 6, 2, 3);
        let paths = rng.brownian_batch(b, l, d, 0.5);
        let opts = SigOptions::new(n);
        let es = expected_signature(&paths, b, l, d, &opts);
        let sigs = batch_signature(&paths, b, l, d, &opts);
        let slen = sig_length(d, n);
        for j in 0..slen {
            let mean: f64 = (0..b).map(|i| sigs[i * slen + j]).sum::<f64>() / b as f64;
            assert!((es[j] - mean).abs() < 1e-12);
        }
    }

    #[test]
    fn streaming_matches_batch() {
        let mut rng = Rng::new(13);
        let (b, l, d, n) = (6, 10, 2, 3);
        let paths = rng.brownian_batch(b, l, d, 0.5);
        let opts = SigOptions::new(n);
        let batchout = batch_signature(&paths, b, l, d, &opts);
        let slen = sig_length(d, n);
        let collected = std::sync::Mutex::new(vec![0.0; b * slen]);
        batch_signature_streaming(&paths, b, l, d, &opts, |i, s| {
            collected.lock().unwrap()[i * slen..(i + 1) * slen].copy_from_slice(s);
        });
        assert!(max_abs_diff(&collected.into_inner().unwrap(), &batchout) < 1e-15);
    }
}
