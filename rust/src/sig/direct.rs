//! Algorithm 1 — the direct signature update, as used by iisignature.
//!
//! Design choices (paper §2.2): (1) the signature lives in one flat
//! contiguous array; (2) levels are updated in reverse order (N down to 1)
//! so the update can be written in place — level k reads only levels i < k,
//! which have not been touched yet in this step.

use crate::tensor::{exp_increment, LevelLayout};

/// One Chen step of the direct algorithm: `a ← a ⊗ exp(z)`, in place.
///
/// `e` is caller-provided scratch of length `layout.total()` that receives
/// exp(z) (kept across calls to avoid reallocation).
pub fn direct_step(layout: &LevelLayout, a: &mut [f64], z: &[f64], e: &mut [f64]) {
    debug_assert_eq!(a.len(), layout.total());
    debug_assert_eq!(z.len(), layout.dim);
    exp_increment(layout, z, e);
    let depth = layout.depth;
    for k in (1..=depth).rev() {
        let (ks, ke) = layout.level_range(k);
        // A_k += Σ_{i=1..k-1} A_i ⊗ E_{k-i}  (i = 0 term is E_k added below;
        // i = k term is A_k ⊗ E_0 = A_k, already in place).
        for i in 1..k {
            let j = k - i;
            let (is_, ie) = layout.level_range(i);
            let (js, je) = layout.level_range(j);
            let lj = je - js;
            // Split-borrow: levels i and j are strictly below level k.
            let (lower, upper) = a.split_at_mut(ks);
            let av = &lower[is_..ie];
            let ev = &e[js..je];
            let out = &mut upper[..ke - ks];
            for (u, &au) in av.iter().enumerate() {
                if au == 0.0 {
                    continue;
                }
                let dst = &mut out[u * lj..(u + 1) * lj];
                for (o, &evv) in dst.iter_mut().zip(ev.iter()) {
                    *o += au * evv;
                }
            }
        }
        // A_k += E_k
        let ev = &e[ks..ke];
        let av = &mut a[ks..ke];
        for (o, &v) in av.iter_mut().zip(ev.iter()) {
            *o += v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::tensor_prod;
    use crate::util::linalg::max_abs_diff;
    use crate::util::prop::check;

    /// The in-place step must equal the out-of-place tensor product with
    /// exp(z) — the definitional Chen update.
    #[test]
    fn step_equals_tensor_product_with_exp() {
        check("direct step == A ⊗ exp(z)", 30, |g| {
            let d = g.usize_in(1, 4);
            let n = g.usize_in(1, 5);
            let layout = LevelLayout::new(d, n);
            let mut a = g.normal_vec(layout.total());
            a[0] = 1.0;
            let z = g.normal_vec(d);
            let mut e = vec![0.0; layout.total()];
            exp_increment(&layout, &z, &mut e);
            let mut want = vec![0.0; layout.total()];
            tensor_prod(&layout, &a, &e, &mut want);
            let mut scratch = vec![0.0; layout.total()];
            direct_step(&layout, &mut a, &z, &mut scratch);
            let err = max_abs_diff(&a, &want);
            assert!(err < 1e-10, "err {err}");
        });
    }

    #[test]
    fn zero_increment_is_noop() {
        let layout = LevelLayout::new(3, 3);
        let mut a = vec![0.0; layout.total()];
        a[0] = 1.0;
        a[2] = 0.5;
        a[7] = -1.25;
        let before = a.clone();
        let mut e = vec![0.0; layout.total()];
        direct_step(&layout, &mut a, &[0.0, 0.0, 0.0], &mut e);
        assert_eq!(a, before);
    }
}
