//! Log-signatures: the tensor logarithm of the signature, plus the
//! Lyndon-word compressed representation (signatory's "words" mode — the
//! coefficients of the expanded log at Lyndon-word indices form coordinates
//! in a basis of the free Lie algebra, since the Lyndon basis expansion is
//! unitriangular with respect to its own words).

use crate::tensor::{tensor_log, LevelLayout};
use crate::transforms::Transform;

/// Expanded (tensor-form) log-signature of a path: flat layout identical to
/// the signature's; the scalar level is always 0.
pub fn log_signature(
    path: &[f64],
    len: usize,
    dim: usize,
    depth: usize,
    tr: Transform,
) -> Vec<f64> {
    let s = crate::sig::signature(path, len, dim, depth, tr, crate::sig::SigMethod::Horner);
    let layout = LevelLayout::new(tr.out_dim(dim), depth);
    let mut out = vec![0.0; layout.total()];
    tensor_log(&layout, &s, &mut out);
    out
}

/// Typed, fallible log-signatures of a (possibly ragged) batch: one row of
/// `sig_length(out_dim, depth)` coefficients per path. A thin wrapper that
/// compiles a one-shot [`Plan`](crate::engine::Plan); shared by the router
/// (uniform and ragged frames) and the CLI.
pub fn try_batch_log_signature(
    paths: &crate::path::PathBatch<'_>,
    opts: &crate::path::SigOptions,
) -> Result<Vec<f64>, crate::path::SigError> {
    use crate::engine::{OpSpec, Plan, ShapeClass};
    let plan = Plan::compile_forward(OpSpec::LogSig(*opts), ShapeClass::for_batch(paths))?;
    Ok(plan.execute(paths)?.into_values())
}

/// Enumerate all Lyndon words over alphabet {0,..,dim-1} with length in
/// [1, depth], in lexicographic order, via Duval's algorithm.
pub fn lyndon_words(dim: usize, depth: usize) -> Vec<Vec<usize>> {
    assert!(dim >= 1 && depth >= 1);
    let mut out = Vec::new();
    if dim == 1 {
        // Single-letter alphabet: the only Lyndon word is "0".
        return vec![vec![0]];
    }
    let mut w = vec![0usize];
    loop {
        if w.len() <= depth {
            out.push(w.clone());
        }
        // Duval: extend periodically to length `depth`, then increment.
        let m = w.len();
        while w.len() < depth {
            let c = w[w.len() - m];
            w.push(c);
        }
        while let Some(&last) = w.last() {
            if last == dim - 1 {
                w.pop();
            } else {
                break;
            }
        }
        if w.is_empty() {
            break;
        }
        *w.last_mut().unwrap() += 1;
    }
    out
}

/// Flat index of a word (i_1,...,i_k) inside level k of the layout.
fn word_index(layout: &LevelLayout, word: &[usize]) -> usize {
    let d = layout.dim;
    let mut idx = 0usize;
    for &c in word {
        idx = idx * d + c;
    }
    layout.offset(word.len()) + idx
}

/// Compressed log-signature: coefficients of the expanded log at Lyndon-word
/// indices, ordered as [`lyndon_words`]. Length = number of Lyndon words of
/// length ≤ depth (the dimension of the truncated free Lie algebra).
pub fn log_signature_words(
    path: &[f64],
    len: usize,
    dim: usize,
    depth: usize,
    tr: Transform,
) -> Vec<f64> {
    let od = tr.out_dim(dim);
    let layout = LevelLayout::new(od, depth);
    let expanded = log_signature(path, len, dim, depth, tr);
    lyndon_words(od, depth)
        .iter()
        .map(|w| expanded[word_index(&layout, w)])
        .collect()
}

/// Dimension of the free Lie algebra truncated at `depth` over `dim`
/// letters (Witt's formula): Σ_{k≤N} (1/k) Σ_{e|k} μ(e) d^{k/e}.
pub fn lie_dim(dim: usize, depth: usize) -> usize {
    fn mobius(mut n: usize) -> i64 {
        let mut mu = 1i64;
        let mut p = 2;
        while p * p <= n {
            if n % p == 0 {
                n /= p;
                if n % p == 0 {
                    return 0;
                }
                mu = -mu;
            }
            p += 1;
        }
        if n > 1 {
            mu = -mu;
        }
        mu
    }
    let mut total = 0i64;
    for k in 1..=depth {
        let mut acc = 0i64;
        for e in 1..=k {
            if k % e == 0 {
                acc += mobius(e) * (dim as i64).pow((k / e) as u32);
            }
        }
        total += acc / k as i64;
    }
    total as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn lyndon_count_matches_witt_formula() {
        for d in 1..=4 {
            for n in 1..=5 {
                let words = lyndon_words(d, n);
                assert_eq!(words.len(), lie_dim(d, n), "d={d} n={n}");
            }
        }
    }

    #[test]
    fn lyndon_words_d2_n3_known() {
        // Lyndon words over {0,1} up to length 3: 0, 001, 01, 011, 1.
        let w = lyndon_words(2, 3);
        let want: Vec<Vec<usize>> = vec![
            vec![0],
            vec![0, 0, 1],
            vec![0, 1],
            vec![0, 1, 1],
            vec![1],
        ];
        assert_eq!(w, want);
    }

    #[test]
    fn linear_path_log_is_level_one_only() {
        // log S(linear segment) = increment (primitive element).
        let path = [0.0, 0.0, 2.0, -1.0];
        let l = log_signature(&path, 2, 2, 4, Transform::None);
        assert!(l[0].abs() < 1e-14);
        assert!((l[1] - 2.0).abs() < 1e-12);
        assert!((l[2] + 1.0).abs() < 1e-12);
        assert!(l[3..].iter().all(|&v| v.abs() < 1e-12));
    }

    #[test]
    fn level2_log_is_antisymmetric() {
        // The level-2 part of log S is the Lévy area — antisymmetric.
        check("log-sig level-2 antisymmetry", 20, |g| {
            let len = g.usize_in(3, 10);
            let dim = g.usize_in(2, 4);
            let path = g.path(len, dim, 0.7);
            let l = log_signature(&path, len, dim, 2, Transform::None);
            let layout = crate::tensor::LevelLayout::new(dim, 2);
            let (o2, _) = layout.level_range(2);
            for i in 0..dim {
                for j in 0..dim {
                    let a = l[o2 + i * dim + j];
                    let b = l[o2 + j * dim + i];
                    assert!((a + b).abs() < 1e-9, "i={i} j={j}: {a} {b}");
                }
            }
        });
    }

    #[test]
    fn words_mode_has_lie_dimension() {
        let mut rng = crate::util::rng::Rng::new(5);
        let path = rng.brownian_path(10, 3, 0.5);
        let w = log_signature_words(&path, 10, 3, 4, Transform::None);
        assert_eq!(w.len(), lie_dim(3, 4));
    }
}
