//! Kernel ridge regression on signature kernels — the standard supervised
//! learning head for the kernels this library computes (distribution
//! regression, path-dependent payoff pricing, etc. in the paper's
//! ecosystem). Solves (K + λI)α = y on a training Gram matrix and predicts
//! with cross-Gram rows; includes the kernel-normalisation option
//! k̃(x,y) = k(x,y)/√(k(x,x)k(y,y)) that keeps signature kernels of long
//! paths in a numerically sane range.

use crate::kernel::{gram, KernelOptions};

/// Cholesky of A + λI; None if a pivot fails (not PD at this ridge).
fn try_cholesky(a0: &[f64], n: usize, lam: f64) -> Option<Vec<f64>> {
    let mut a = a0.to_vec();
    for i in 0..n {
        a[i * n + i] += lam;
    }
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[i * n + j];
            for k in 0..j {
                s -= a[i * n + k] * a[j * n + k];
            }
            if i == j {
                if !(s > 0.0) || !s.is_finite() {
                    return None;
                }
                a[i * n + i] = s.sqrt();
            } else {
                a[i * n + j] = s / a[j * n + j];
            }
        }
    }
    Some(a)
}

/// Fitted signature-kernel ridge regressor.
pub struct KernelRidge {
    /// Training paths, flattened `[n, len, dim]` (owned copy).
    train: Vec<f64>,
    n: usize,
    len: usize,
    dim: usize,
    alpha: Vec<f64>,
    opts: KernelOptions,
    normalize: bool,
    /// √k(x_i,x_i) for the training set when normalising.
    train_norms: Vec<f64>,
}

/// Solve (A + λ·mean(diag)·I) x = y for symmetric near-PSD A via Cholesky.
/// λ is *relative* to the mean diagonal so the same value works for raw and
/// normalised kernels; the PDE-discretised Gram can carry small negative
/// eigenvalues (quadrature error), which the ridge must dominate.
fn solve_ridge(a: Vec<f64>, n: usize, lambda: f64, y: &[f64]) -> Vec<f64> {
    let mean_diag = (0..n).map(|i| a[i * n + i]).sum::<f64>() / n as f64;
    // The discretised Gram can have negative eigenvalues larger than the
    // requested ridge (coarse dyadic orders); escalate λ until Cholesky
    // succeeds rather than failing the fit.
    let mut lam = lambda * mean_diag.max(1e-300);
    let mut attempt = 0;
    let l = loop {
        match try_cholesky(&a, n, lam) {
            Some(l) => break l,
            None => {
                attempt += 1;
                assert!(attempt <= 8, "ridge system not PD even at λ = {lam}");
                lam *= 10.0;
            }
        }
    };
    let a = l;
    // Forward + back substitution.
    let mut z = vec![0.0; n];
    for i in 0..n {
        let mut s = y[i];
        for k in 0..i {
            s -= a[i * n + k] * z[k];
        }
        z[i] = s / a[i * n + i];
    }
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = z[i];
        for k in i + 1..n {
            s -= a[k * n + i] * x[k];
        }
        x[i] = s / a[i * n + i];
    }
    x
}

impl KernelRidge {
    /// Fit on training paths `[n, len, dim]` with targets `[n]`.
    pub fn fit(
        paths: &[f64],
        y: &[f64],
        n: usize,
        len: usize,
        dim: usize,
        lambda: f64,
        normalize: bool,
        opts: &KernelOptions,
    ) -> KernelRidge {
        assert_eq!(paths.len(), n * len * dim);
        assert_eq!(y.len(), n);
        assert!(lambda > 0.0);
        let mut k = gram(paths, paths, n, n, len, len, dim, opts);
        assert!(
            k.iter().all(|v| v.is_finite()),
            "signature-kernel Gram overflowed f64; rescale the paths (the \
             kernel grows exponentially in path 1-variation) or increase \
             the dyadic order"
        );
        let mut train_norms = vec![1.0; n];
        if normalize {
            for i in 0..n {
                train_norms[i] = k[i * n + i].max(1e-300).sqrt();
            }
            for i in 0..n {
                for j in 0..n {
                    k[i * n + j] /= train_norms[i] * train_norms[j];
                }
            }
        }
        let alpha = solve_ridge(k, n, lambda, y);
        KernelRidge {
            train: paths.to_vec(),
            n,
            len,
            dim,
            alpha,
            opts: *opts,
            normalize,
            train_norms,
        }
    }

    /// Predict for query paths `[m, len, dim]` -> `[m]`.
    pub fn predict(&self, paths: &[f64], m: usize) -> Vec<f64> {
        assert_eq!(paths.len(), m * self.len * self.dim);
        let mut kx = gram(
            paths, &self.train, m, self.n, self.len, self.len, self.dim, &self.opts,
        );
        if self.normalize {
            let kqq = crate::kernel::batch_kernel(
                paths, paths, m, self.len, self.len, self.dim, &self.opts,
            );
            for i in 0..m {
                let qi = kqq[i].max(1e-300).sqrt();
                for j in 0..self.n {
                    kx[i * self.n + j] /= qi * self.train_norms[j];
                }
            }
        }
        (0..m)
            .map(|i| {
                kx[i * self.n..(i + 1) * self.n]
                    .iter()
                    .zip(&self.alpha)
                    .map(|(k, a)| k * a)
                    .sum()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transforms::Transform;
    use crate::util::rng::Rng;

    fn dataset(
        rng: &mut Rng,
        n: usize,
        len: usize,
        dim: usize,
    ) -> (Vec<f64>, Vec<f64>) {
        // Target: a smooth path functional (endpoint displacement norm +
        // quadratic variation of first channel) — learnable from signatures.
        let mut paths = Vec::with_capacity(n * len * dim);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let p = rng.brownian_path(len, dim, 0.3);
            let mut disp = 0.0;
            for j in 0..dim {
                let d = p[(len - 1) * dim + j] - p[j];
                disp += d * d;
            }
            let qv: f64 = (0..len - 1)
                .map(|i| (p[(i + 1) * dim] - p[i * dim]).powi(2))
                .sum();
            y.push(disp.sqrt() + qv);
            paths.extend(p);
        }
        (paths, y)
    }

    #[test]
    fn interpolates_training_data_with_small_ridge() {
        let mut rng = Rng::new(91);
        let (n, len, dim) = (16, 8, 2);
        let (paths, y) = dataset(&mut rng, n, len, dim);
        let opts = KernelOptions::default().transform(Transform::TimeAug);
        let model = KernelRidge::fit(&paths, &y, n, len, dim, 1e-8, true, &opts);
        let pred = model.predict(&paths, n);
        let err = crate::util::linalg::rel_err(&pred, &y);
        assert!(err < 1e-3, "train rel err {err}");
    }

    #[test]
    fn generalizes_better_than_mean_predictor() {
        let mut rng = Rng::new(92);
        let (n, m, len, dim) = (48, 24, 8, 2);
        let (xtr, ytr) = dataset(&mut rng, n, len, dim);
        let (xte, yte) = dataset(&mut rng, m, len, dim);
        let opts = KernelOptions::default().dyadic(2, 2).transform(Transform::TimeAug);
        let model = KernelRidge::fit(&xtr, &ytr, n, len, dim, 1e-2, true, &opts);
        let pred = model.predict(&xte, m);
        let mean = ytr.iter().sum::<f64>() / n as f64;
        let mse = |p: &dyn Fn(usize) -> f64| -> f64 {
            (0..m).map(|i| (p(i) - yte[i]).powi(2)).sum::<f64>() / m as f64
        };
        let mse_model = mse(&|i| pred[i]);
        let mse_mean = mse(&|_| mean);
        assert!(
            mse_model < 0.5 * mse_mean,
            "model {mse_model} vs mean {mse_mean}"
        );
    }

    #[test]
    fn normalized_kernel_handles_long_paths() {
        // Unnormalised signature kernels explode with path size; the
        // normalised regressor must stay finite and fit.
        let mut rng = Rng::new(93);
        let (n, len, dim) = (8, 32, 2);
        let mut paths = Vec::new();
        let mut y = Vec::new();
        for i in 0..n {
            let p = rng.brownian_path(len, dim, 0.25); // large-ish increments
            y.push(i as f64);
            paths.extend(p);
        }
        let opts = KernelOptions::default();
        let model = KernelRidge::fit(&paths, &y, n, len, dim, 1e-4, true, &opts);
        let pred = model.predict(&paths, n);
        assert!(pred.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn ridge_solver_matches_direct_inverse_2x2() {
        // (K + λI)α = y with K = [[2,1],[1,2]], λ=1 ⇒ [[3,1],[1,3]]α = y.
        let k = vec![2.0, 1.0, 1.0, 2.0];
        let y = [5.0, 7.0];
        // λ is relative to mean(diag) = 2, so λ = 0.5 adds identity·1.
        let alpha = solve_ridge(k, 2, 0.5, &y);
        // inverse of [[3,1],[1,3]] = 1/8 [[3,-1],[-1,3]]
        let want = [(3.0 * 5.0 - 7.0) / 8.0, (-5.0 + 3.0 * 7.0) / 8.0];
        assert!((alpha[0] - want[0]).abs() < 1e-12);
        assert!((alpha[1] - want[1]).abs() < 1e-12);
    }
}
