//! Kernel ridge regression on signature kernels — the standard supervised
//! learning head for the kernels this library computes (distribution
//! regression, path-dependent payoff pricing, etc. in the paper's
//! ecosystem). Solves (K + λI)α = y on a training Gram matrix and predicts
//! with cross-Gram rows; includes the kernel-normalisation option
//! k̃(x,y) = k(x,y)/√(k(x,x)k(y,y)) that keeps signature kernels of long
//! paths in a numerically sane range.
//!
//! Training and query sets may be **ragged** (paths of different lengths):
//! fit with [`KernelRidge::try_fit`] on a [`PathBatch`] and predict on any
//! other batch — the cross-Gram pairs every length with every other.

use crate::kernel::{try_batch_kernel, try_gram, KernelOptions};
use crate::path::{PathBatch, SigError};

/// Cholesky of A + λI; None if a pivot fails (not PD at this ridge).
fn try_cholesky(a0: &[f64], n: usize, lam: f64) -> Option<Vec<f64>> {
    let mut a = a0.to_vec();
    for i in 0..n {
        a[i * n + i] += lam;
    }
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[i * n + j];
            for k in 0..j {
                s -= a[i * n + k] * a[j * n + k];
            }
            if i == j {
                if !(s > 0.0) || !s.is_finite() {
                    return None;
                }
                a[i * n + i] = s.sqrt();
            } else {
                a[i * n + j] = s / a[j * n + j];
            }
        }
    }
    Some(a)
}

/// Fitted signature-kernel ridge regressor.
pub struct KernelRidge {
    /// Training paths, flat (possibly ragged) buffer (owned copy).
    train: Vec<f64>,
    /// Per-path lengths of the training set.
    train_lengths: Vec<usize>,
    dim: usize,
    /// Shared training length when the fit batch was uniform — required by
    /// the legacy [`KernelRidge::predict`] wrapper.
    uniform_len: Option<usize>,
    alpha: Vec<f64>,
    opts: KernelOptions,
    normalize: bool,
    /// √k(x_i,x_i) for the training set when normalising.
    train_norms: Vec<f64>,
}

/// Solve (A + λ·mean(diag)·I) x = y for symmetric near-PSD A via Cholesky.
/// λ is *relative* to the mean diagonal so the same value works for raw and
/// normalised kernels; the PDE-discretised Gram can carry small negative
/// eigenvalues (quadrature error), which the ridge must dominate.
fn solve_ridge(a: Vec<f64>, n: usize, lambda: f64, y: &[f64]) -> Result<Vec<f64>, SigError> {
    let mean_diag = (0..n).map(|i| a[i * n + i]).sum::<f64>() / n as f64;
    // The discretised Gram can have negative eigenvalues larger than the
    // requested ridge (coarse dyadic orders); escalate λ until Cholesky
    // succeeds rather than failing the fit.
    let mut lam = lambda * mean_diag.max(1e-300);
    let mut attempt = 0;
    let l = loop {
        match try_cholesky(&a, n, lam) {
            Some(l) => break l,
            None => {
                attempt += 1;
                if attempt > 8 {
                    return Err(SigError::NonFinite(
                        "ridge system not positive definite even after escalating λ",
                    ));
                }
                lam *= 10.0;
            }
        }
    };
    let a = l;
    // Forward + back substitution.
    let mut z = vec![0.0; n];
    for i in 0..n {
        let mut s = y[i];
        for k in 0..i {
            s -= a[i * n + k] * z[k];
        }
        z[i] = s / a[i * n + i];
    }
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = z[i];
        for k in i + 1..n {
            s -= a[k * n + i] * x[k];
        }
        x[i] = s / a[i * n + i];
    }
    Ok(x)
}

impl KernelRidge {
    /// Typed, fallible fit on a (possibly ragged) batch of training paths
    /// with targets `[n]`. A thin wrapper that compiles a one-shot
    /// [`Plan`](crate::engine::Plan) with op spec
    /// [`OpSpec::Krr`](crate::engine::OpSpec::Krr).
    pub fn try_fit(
        paths: &PathBatch<'_>,
        y: &[f64],
        lambda: f64,
        normalize: bool,
        opts: &KernelOptions,
    ) -> Result<KernelRidge, SigError> {
        let plan = crate::engine::Plan::compile(
            crate::engine::OpSpec::Krr {
                opts: *opts,
                lambda,
                normalize,
            },
            crate::engine::ShapeClass::for_batch(paths),
        )?;
        plan.execute_fit(paths, y)?.into_kernel_ridge()
    }

    /// The fitted dual coefficients α of (K + λI)α = y.
    pub fn alpha(&self) -> &[f64] {
        &self.alpha
    }

    /// Rank-budgeted fit: solve the **r×r normal equations** in an explicit
    /// low-rank feature space instead of the n×n dual system — O(n·r²)
    /// total against `try_fit`'s O(n²·L²) Gram + O(n³) solve. Landmarks for
    /// a Nyström spec are drawn (seeded) from the training batch. Returns a
    /// [`LowRankRidge`], which predicts in O(r) kernel/signature evaluations
    /// per query. A thin wrapper compiling a one-shot
    /// [`OpSpec::KrrLowRank`](crate::engine::OpSpec::KrrLowRank) plan.
    pub fn try_fit_lowrank(
        paths: &PathBatch<'_>,
        y: &[f64],
        lambda: f64,
        lowrank: crate::kernel::lowrank::LowRankSpec,
        opts: &KernelOptions,
    ) -> Result<crate::kernel::lowrank::LowRankRidge, SigError> {
        let plan = crate::engine::Plan::compile(
            crate::engine::OpSpec::KrrLowRank {
                opts: *opts,
                lowrank,
                lambda,
            },
            crate::engine::ShapeClass::for_batch(paths),
        )?;
        plan.execute_fit(paths, y)?.into_lowrank_ridge()
    }

    /// The fitting logic behind [`KernelRidge::try_fit`], called by the
    /// engine's KRR plans (kept separate so the wrapper → plan → fit chain
    /// does not recurse).
    pub(crate) fn fit_impl(
        paths: &PathBatch<'_>,
        y: &[f64],
        lambda: f64,
        normalize: bool,
        opts: &KernelOptions,
    ) -> Result<KernelRidge, SigError> {
        let n = paths.batch();
        if y.len() != n {
            return Err(SigError::CotangentLen {
                expected: n,
                got: y.len(),
            });
        }
        if n == 0 {
            return Err(SigError::InsufficientBatch { need: 1, got: 0 });
        }
        if !(lambda > 0.0) {
            return Err(SigError::NonFinite("ridge λ must be positive"));
        }
        let mut k = try_gram(paths, paths, opts)?;
        if !k.iter().all(|v| v.is_finite()) {
            // The kernel grows exponentially in path 1-variation; rescale the
            // paths or increase the dyadic order.
            return Err(SigError::NonFinite("signature-kernel Gram overflowed f64"));
        }
        let mut train_norms = vec![1.0; n];
        if normalize {
            for i in 0..n {
                train_norms[i] = k[i * n + i].max(1e-300).sqrt();
            }
            for i in 0..n {
                for j in 0..n {
                    k[i * n + j] /= train_norms[i] * train_norms[j];
                }
            }
        }
        let alpha = solve_ridge(k, n, lambda, y)?;
        let train_lengths: Vec<usize> = (0..n).map(|i| paths.len_of(i)).collect();
        Ok(KernelRidge {
            train: paths.data().to_vec(),
            train_lengths,
            dim: paths.dim(),
            uniform_len: paths.uniform_len(),
            alpha,
            opts: *opts,
            normalize,
            train_norms,
        })
    }

    /// Fit on uniform training paths `[n, len, dim]` with targets `[n]`
    /// (flat-slice wrapper over [`KernelRidge::try_fit`]; panics on
    /// malformed shapes).
    pub fn fit(
        paths: &[f64],
        y: &[f64],
        n: usize,
        len: usize,
        dim: usize,
        lambda: f64,
        normalize: bool,
        opts: &KernelOptions,
    ) -> KernelRidge {
        let pb = PathBatch::uniform(paths, n, len, dim).expect("KernelRidge::fit: invalid shape");
        KernelRidge::try_fit(&pb, y, lambda, normalize, opts).expect("KernelRidge::fit")
    }

    /// The training batch as a typed view over the owned copy.
    fn train_batch(&self) -> PathBatch<'_> {
        PathBatch::ragged(&self.train, &self.train_lengths, self.dim)
            .expect("internal: stored training batch is valid")
    }

    /// Typed, fallible prediction for a (possibly ragged) batch of query
    /// paths; returns `[paths.batch()]`.
    pub fn try_predict(&self, paths: &PathBatch<'_>) -> Result<Vec<f64>, SigError> {
        if paths.dim() != self.dim {
            return Err(SigError::DimMismatch {
                left: paths.dim(),
                right: self.dim,
            });
        }
        let m = paths.batch();
        let n = self.train_lengths.len();
        let train = self.train_batch();
        let mut kx = try_gram(paths, &train, &self.opts)?;
        if self.normalize {
            let kqq = try_batch_kernel(paths, paths, &self.opts)?;
            for i in 0..m {
                let qi = kqq[i].max(1e-300).sqrt();
                for j in 0..n {
                    kx[i * n + j] /= qi * self.train_norms[j];
                }
            }
        }
        Ok((0..m)
            .map(|i| {
                kx[i * n..(i + 1) * n]
                    .iter()
                    .zip(&self.alpha)
                    .map(|(k, a)| k * a)
                    .sum()
            })
            .collect())
    }

    /// Predict for uniform query paths `[m, len, dim]` -> `[m]`, where `len`
    /// is the (uniform) training length (flat-slice wrapper over
    /// [`KernelRidge::try_predict`]; panics on malformed shapes or when the
    /// model was fitted on a ragged training set).
    pub fn predict(&self, paths: &[f64], m: usize) -> Vec<f64> {
        let len = self
            .uniform_len
            .expect("KernelRidge::predict: model fitted on a ragged batch; use try_predict");
        let pb = PathBatch::uniform(paths, m, len, self.dim)
            .expect("KernelRidge::predict: invalid shape");
        self.try_predict(&pb).expect("KernelRidge::predict")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transforms::Transform;
    use crate::util::rng::Rng;

    fn dataset(
        rng: &mut Rng,
        n: usize,
        len: usize,
        dim: usize,
    ) -> (Vec<f64>, Vec<f64>) {
        // Target: a smooth path functional (endpoint displacement norm +
        // quadratic variation of first channel) — learnable from signatures.
        let mut paths = Vec::with_capacity(n * len * dim);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let p = rng.brownian_path(len, dim, 0.3);
            let mut disp = 0.0;
            for j in 0..dim {
                let d = p[(len - 1) * dim + j] - p[j];
                disp += d * d;
            }
            let qv: f64 = (0..len - 1)
                .map(|i| (p[(i + 1) * dim] - p[i * dim]).powi(2))
                .sum();
            y.push(disp.sqrt() + qv);
            paths.extend(p);
        }
        (paths, y)
    }

    #[test]
    fn interpolates_training_data_with_small_ridge() {
        let mut rng = Rng::new(91);
        let (n, len, dim) = (16, 8, 2);
        let (paths, y) = dataset(&mut rng, n, len, dim);
        let opts = KernelOptions::default().transform(Transform::TimeAug);
        let model = KernelRidge::fit(&paths, &y, n, len, dim, 1e-8, true, &opts);
        let pred = model.predict(&paths, n);
        let err = crate::util::linalg::rel_err(&pred, &y);
        assert!(err < 1e-3, "train rel err {err}");
    }

    #[test]
    fn generalizes_better_than_mean_predictor() {
        let mut rng = Rng::new(92);
        let (n, m, len, dim) = (48, 24, 8, 2);
        let (xtr, ytr) = dataset(&mut rng, n, len, dim);
        let (xte, yte) = dataset(&mut rng, m, len, dim);
        let opts = KernelOptions::default().dyadic(2, 2).transform(Transform::TimeAug);
        let model = KernelRidge::fit(&xtr, &ytr, n, len, dim, 1e-2, true, &opts);
        let pred = model.predict(&xte, m);
        let mean = ytr.iter().sum::<f64>() / n as f64;
        let mse = |p: &dyn Fn(usize) -> f64| -> f64 {
            (0..m).map(|i| (p(i) - yte[i]).powi(2)).sum::<f64>() / m as f64
        };
        let mse_model = mse(&|i| pred[i]);
        let mse_mean = mse(&|_| mean);
        assert!(
            mse_model < 0.5 * mse_mean,
            "model {mse_model} vs mean {mse_mean}"
        );
    }

    #[test]
    fn normalized_kernel_handles_long_paths() {
        // Unnormalised signature kernels explode with path size; the
        // normalised regressor must stay finite and fit.
        let mut rng = Rng::new(93);
        let (n, len, dim) = (8, 32, 2);
        let mut paths = Vec::new();
        let mut y = Vec::new();
        for i in 0..n {
            let p = rng.brownian_path(len, dim, 0.25); // large-ish increments
            y.push(i as f64);
            paths.extend(p);
        }
        let opts = KernelOptions::default();
        let model = KernelRidge::fit(&paths, &y, n, len, dim, 1e-4, true, &opts);
        let pred = model.predict(&paths, n);
        assert!(pred.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn ridge_solver_matches_direct_inverse_2x2() {
        // (K + λI)α = y with K = [[2,1],[1,2]], λ=1 ⇒ [[3,1],[1,3]]α = y.
        let k = vec![2.0, 1.0, 1.0, 2.0];
        let y = [5.0, 7.0];
        // λ is relative to mean(diag) = 2, so λ = 0.5 adds identity·1.
        let alpha = solve_ridge(k, 2, 0.5, &y).unwrap();
        // inverse of [[3,1],[1,3]] = 1/8 [[3,-1],[-1,3]]
        let want = [(3.0 * 5.0 - 7.0) / 8.0, (-5.0 + 3.0 * 7.0) / 8.0];
        assert!((alpha[0] - want[0]).abs() < 1e-12);
        assert!((alpha[1] - want[1]).abs() < 1e-12);
    }

    #[test]
    fn fits_and_predicts_on_ragged_paths() {
        // Variable-length training set: target = squared endpoint
        // displacement of the first channel (length-independent).
        let mut rng = Rng::new(94);
        let dim = 2;
        let lengths: Vec<usize> = (0..20).map(|i| 5 + (i % 7)).collect();
        let mut data = Vec::new();
        let mut y = Vec::new();
        for &l in &lengths {
            let p = rng.brownian_path(l, dim, 0.25);
            let d0 = p[(l - 1) * dim] - p[0];
            y.push(d0 * d0);
            data.extend(p);
        }
        let pb = PathBatch::ragged(&data, &lengths, dim).unwrap();
        let opts = KernelOptions::default().transform(Transform::TimeAug);
        let model = KernelRidge::try_fit(&pb, &y, 1e-6, true, &opts).unwrap();
        let pred = model.try_predict(&pb).unwrap();
        let err = crate::util::linalg::rel_err(&pred, &y);
        assert!(err < 1e-2, "ragged train rel err {err}");
        // The uniform `predict` wrapper refuses ragged-trained models via
        // panic; the typed route must also reject dim mismatches cleanly.
        let bad = PathBatch::uniform(&[0.0; 6], 1, 2, 3).unwrap();
        assert!(matches!(
            model.try_predict(&bad),
            Err(SigError::DimMismatch { .. })
        ));
    }
}
