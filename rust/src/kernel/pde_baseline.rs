//! The *approximate* PDE-based backpropagation that pySigLib's exact scheme
//! replaces (paper §3.4; the approach of Lemercier et al. [30], as
//! implemented by the `sigkernel` package).
//!
//! It exploits the continuum factorisation
//!     ∂k(x,y)/∂Δ(s,t) ≈ k(x|[0,s], y|[0,t]) · k(x|[s,1], y|[t,1]):
//! the first factor is the forward PDE grid; the second is the forward grid
//! of the *time-reversed* paths, read at reflected indices. This identity is
//! exact only in the continuum limit — on a coarse grid (short paths, low
//! dyadic order) the gradients are biased, which is precisely the paper's
//! motivation for Algorithm 4. The `grad_accuracy` bench quantifies this.

use crate::kernel::delta::{delta_matrix, delta_vjp_to_paths};
use crate::kernel::solver::solve_pde_grid;
use crate::kernel::KernelOptions;

/// Approximate ∂F/∂Δ via the two-PDE (forward + reversed) scheme.
pub fn sig_kernel_vjp_delta_pde_approx(
    delta: &[f64],
    m: usize,
    n: usize,
    lam1: u32,
    lam2: u32,
    grad_out: f64,
) -> Vec<f64> {
    assert_eq!(delta.len(), m * n);
    // Reversed-path Δ: increments of the reversed path are the negated
    // increments in reverse order, so Δ_rev[i,j] = Δ[m-1-i, n-1-j]
    // (the two sign flips cancel).
    let mut delta_rev = vec![0.0; m * n];
    for i in 0..m {
        for j in 0..n {
            delta_rev[i * n + j] = delta[(m - 1 - i) * n + (n - 1 - j)];
        }
    }
    let fwd = solve_pde_grid(delta, m, n, lam1, lam2);
    let rev = solve_pde_grid(&delta_rev, m, n, lam1, lam2);
    let rows = m << lam1;
    let cols = n << lam2;
    let w = cols + 1;
    let scale = 1.0 / (1u64 << (lam1 + lam2)) as f64;
    let mut d2 = vec![0.0; m * n];
    // For each refined cell (s,t): k(x|[0,s], y|[0,t]) · k(x|[s+1,1], y|[t+1,1]).
    for s in 0..rows {
        for t in 0..cols {
            let before = fwd[s * w + t];
            let after = rev[(rows - 1 - s) * w + (cols - 1 - t)];
            d2[(s >> lam1) * n + (t >> lam2)] += grad_out * before * after * scale;
        }
    }
    d2
}

/// Approximate vjp of the signature kernel with respect to both paths —
/// drop-in comparable to [`super::backward::sig_kernel_vjp`].
pub fn sig_kernel_vjp_pde_approx(
    x: &[f64],
    y: &[f64],
    lx: usize,
    ly: usize,
    dim: usize,
    opts: &KernelOptions,
    grad_out: f64,
) -> (Vec<f64>, Vec<f64>) {
    let (m, n, delta) = delta_matrix(x, y, lx, ly, dim, opts.exec.transform);
    let d2 =
        sig_kernel_vjp_delta_pde_approx(&delta, m, n, opts.dyadic_x, opts.dyadic_y, grad_out);
    let mut gx = vec![0.0; lx * dim];
    let mut gy = vec![0.0; ly * dim];
    delta_vjp_to_paths(&d2, x, y, lx, ly, dim, opts.exec.transform, &mut gx, &mut gy);
    (gx, gy)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::backward::sig_kernel_vjp;
    use crate::util::linalg::rel_err;
    use crate::util::rng::Rng;

    /// The approximation converges to the exact gradient as the dyadic order
    /// grows (continuum limit) — and is visibly biased at order 0 on short
    /// paths. Both facts together are the paper's §3.4 claim.
    #[test]
    fn converges_to_exact_with_refinement() {
        let mut rng = Rng::new(31);
        let (l, d) = (4, 2);
        let x = rng.brownian_path(l, d, 0.5);
        let y = rng.brownian_path(l, d, 0.5);
        let mut errs = Vec::new();
        for lam in [0u32, 2, 4] {
            let opts = KernelOptions::default().dyadic(lam, lam);
            let (exact, _) = sig_kernel_vjp(&x, &y, l, l, d, &opts, 1.0);
            let (approx, _) = sig_kernel_vjp_pde_approx(&x, &y, l, l, d, &opts, 1.0);
            errs.push(rel_err(&approx, &exact));
        }
        assert!(
            errs[2] < errs[0] * 0.5,
            "no convergence: errors {errs:?}"
        );
        // At dyadic order 0 on a short path the bias is material (> 0.1%).
        assert!(errs[0] > 1e-3, "baseline suspiciously exact: {errs:?}");
    }

    #[test]
    fn roughly_matches_exact_on_fine_grids() {
        let mut rng = Rng::new(32);
        let (l, d) = (6, 2);
        let x = rng.brownian_path(l, d, 0.4);
        let y = rng.brownian_path(l, d, 0.4);
        let opts = KernelOptions::default().dyadic(4, 4);
        let (exact, _) = sig_kernel_vjp(&x, &y, l, l, d, &opts, 1.0);
        let (approx, _) = sig_kernel_vjp_pde_approx(&x, &y, l, l, d, &opts, 1.0);
        let e = rel_err(&approx, &exact);
        assert!(e < 0.05, "rel err {e}");
    }
}
