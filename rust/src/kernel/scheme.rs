//! Discretisation schemes and error-adaptive refinement for the Goursat
//! solver ("Numerical Schemes for Signature Kernels": higher-order schemes
//! reach the same accuracy on much coarser grids).
//!
//! Two user-facing knobs live here, both carried on
//! [`KernelOptions`](crate::path::KernelOptions):
//!
//! * [`Scheme`] — `Order1` is the paper's Algorithm-3 update, unchanged bit
//!   for bit. `Order2` is its Richardson extrapolation: solve the pair at
//!   the requested orders (λ1, λ2) *and* at the coarsened orders
//!   (λ1−1, λ2−1), then combine `k₂ = (4·k_fine − k_coarse)/3`, cancelling
//!   the leading error term. The fine sweep runs the identical scalar FP
//!   sequence as `Order1`, so the lanes/borders bit-identity lattice holds
//!   per scheme. At λ = (0, 0) no coarser grid exists, so `Order2`
//!   degenerates to the fine solve alone (returned directly — running the
//!   combine on equal grids would perturb the value by one rounding).
//! * [`TargetEps`] — an error target ε that **replaces** fixed λ: before a
//!   full solve, [`resolve_target_eps`] probes a small subsample of pairs
//!   on a dyadic ladder, estimates each candidate's discretisation error
//!   from the λ vs λ+1 difference, and rewrites the options to the cheapest
//!   (scheme, λ) meeting ε.
//!
//! Cost model (cells solved for an `[m, n]` Δ): `Order1` at λ costs
//! `4^λ·mn`; `Order2` at λ costs `(4^λ + 4^{λ−1})·mn = 1.25·4^λ·mn` — so
//! `Order2` at λ−1 costs `0.3125·4^λ·mn`, strictly fewer cells than
//! `Order1` at λ, which is the accuracy-per-FLOP trade the bench gate
//! (`benches/accuracy.rs` + `ci/check_accuracy.py`) measures and enforces.

use crate::path::{KernelOptions, PathBatch, SigError};

/// Which Goursat discretisation to run. Carried on
/// [`KernelOptions`](crate::path::KernelOptions) and dispatched in the
/// scalar solver, the lane engine, the blocked solver, border strips, and
/// the Algorithm-4 backward (siglint's `scheme_exhaustive` rule keeps the
/// dispatch sites total).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Scheme {
    /// The paper's order-1 update — every existing result is bit-identical
    /// under this default.
    #[default]
    Order1,
    /// Richardson extrapolation over (λ, λ−1): `(4·k_fine − k_coarse)/3`.
    Order2,
}

impl Scheme {
    /// Wire byte for this scheme.
    pub fn to_u8(self) -> u8 {
        match self {
            Scheme::Order1 => 0,
            Scheme::Order2 => 1,
        }
    }

    /// Decode a wire byte; `None` for unknown values.
    pub fn from_u8(v: u8) -> Option<Scheme> {
        match v {
            0 => Some(Scheme::Order1),
            1 => Some(Scheme::Order2),
            _ => None,
        }
    }
}

/// The coarsened dyadic orders an `Order2` solve pairs with (λ1, λ2):
/// one step down on each axis, saturating at zero.
pub fn coarse_orders(lam1: u32, lam2: u32) -> (u32, u32) {
    (lam1.saturating_sub(1), lam2.saturating_sub(1))
}

/// True when `Order2` has no coarser grid to extrapolate against and
/// degenerates to the fine solve alone.
pub fn order2_degenerate(lam1: u32, lam2: u32) -> bool {
    lam1 == 0 && lam2 == 0
}

/// The Richardson combine. One expression, used verbatim by the scalar
/// solver, every lane of the lane engine, borders, and the probe — so all
/// producers agree bitwise.
#[inline]
pub fn richardson_combine(fine: f64, coarse: f64) -> f64 {
    (4.0 * fine - coarse) / 3.0
}

/// Cotangent seeds for the two `Order2` adjoint sweeps: ∂k₂/∂k_fine = 4/3,
/// ∂k₂/∂k_coarse = −1/3. One expression shared by the scalar and lane
/// backward so their accumulation sequences match bitwise.
#[inline]
pub fn order2_seeds(w: f64) -> (f64, f64) {
    (w * (4.0 / 3.0), w * (-1.0 / 3.0))
}

/// Relative cell cost of solving one `[m, n]` Δ under (scheme, λ1, λ2), in
/// units of `m·n` cells. The resolver ranks candidates by this.
pub fn cell_cost(scheme: Scheme, lam1: u32, lam2: u32) -> u128 {
    let lam1 = lam1.min(63);
    let lam2 = lam2.min(63);
    let fine = 1u128 << (lam1 + lam2);
    match scheme {
        Scheme::Order1 => fine,
        Scheme::Order2 if order2_degenerate(lam1, lam2) => fine,
        Scheme::Order2 => {
            let (c1, c2) = coarse_orders(lam1, lam2);
            fine + (1u128 << (c1 + c2))
        }
    }
}

/// Error target carried on [`KernelOptions`](crate::path::KernelOptions).
///
/// `KernelOptions` is `Copy + Eq + Hash` (it keys plan and corpus caches),
/// so the target is stored as raw `f64` bits with an explicit set flag —
/// no sentinel value is stolen from the ε domain, which keeps hostile
/// inputs (0, negative, NaN, ∞) representable and rejectable at plan
/// compile instead of silently reinterpreted.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TargetEps {
    set: bool,
    bits: u64,
}

impl TargetEps {
    /// No target: the fixed (scheme, λ) in the options is used as-is.
    pub const UNSET: TargetEps = TargetEps { set: false, bits: 0 };

    /// Store a target (validated at plan compile, not here, so hostile
    /// values surface as typed errors rather than panics).
    pub fn new(eps: f64) -> TargetEps {
        TargetEps {
            set: true,
            bits: eps.to_bits(),
        }
    }

    /// The target, if one was set.
    pub fn get(self) -> Option<f64> {
        if self.set {
            Some(f64::from_bits(self.bits))
        } else {
            None
        }
    }

    /// Plan-compile validation: a set target must be a finite positive
    /// number (0, negatives, NaN and ∞ are all rejected — ε = 0 is not
    /// reachable by any finite grid).
    pub fn validate(self) -> Result<(), SigError> {
        match self.get() {
            None => Ok(()),
            Some(e) if e.is_finite() && e > 0.0 => Ok(()),
            Some(_) => Err(SigError::NonFinite(
                "target_eps must be a finite positive number",
            )),
        }
    }
}

impl Default for TargetEps {
    fn default() -> Self {
        TargetEps::UNSET
    }
}

/// Dyadic ladder ceiling for the probe (candidate λ ∈ 0..=MAX_ADAPT_LAMBDA).
const MAX_ADAPT_LAMBDA: u32 = 6;

/// Per-solve cell budget for one probe pair — candidates whose probe grid
/// would exceed this are not evaluated (long paths refine less far, exactly
/// the regime where coarse grids suffice).
const PROBE_CELLS_MAX: u128 = 1 << 22;

/// Probe pairs drawn from each side (diagonal-ish subsample).
const PROBE_PAIRS: usize = 2;

/// Resolve `target_eps`: when set, probe a subsample and rewrite the
/// options to the cheapest (scheme, λ) whose estimated discretisation
/// error meets ε; when unset, return the options unchanged.
///
/// The probe solves each subsampled pair's Δ once, then walks an order-1
/// dyadic ladder `k₁(λ)` (Order-2 values derive from it for free:
/// `k₂(λ) = (4·k₁(λ) − k₁(λ−1))/3`). A candidate's error estimate is the
/// max over probe pairs of `|k(λ) − k(λ+1)| / max(1, |k(λ+1)|)`.
/// Candidates are ranked by [`cell_cost`] and the first (cheapest) one
/// meeting ε wins; if none does, the most accurate evaluated candidate is
/// used. The procedure is **deterministic** in (x, y, opts) — forward and
/// backward paths re-resolve independently and land on the same grid —
/// and the returned options have the target cleared, so resolution is
/// idempotent. Smaller ε can only move the choice to a costlier candidate
/// (the feasible set shrinks), which is the monotonicity property
/// `tests/props_scheme.rs` pins.
pub fn resolve_target_eps(
    x: &PathBatch<'_>,
    y: &PathBatch<'_>,
    opts: &KernelOptions,
) -> Result<KernelOptions, SigError> {
    let Some(eps) = opts.target_eps.get() else {
        return Ok(*opts);
    };
    let mut resolved = *opts;
    resolved.target_eps = TargetEps::UNSET;
    if !(eps.is_finite() && eps > 0.0) {
        return Err(SigError::NonFinite(
            "target_eps must be a finite positive number",
        ));
    }
    // Diagonal-ish subsample: pair i with i (mod the smaller side). Skip
    // degenerate paths — their kernel is exactly 1 at every grid.
    let (bx, by) = (x.batch(), y.batch());
    let mut ladders: Vec<Vec<f64>> = Vec::new();
    let mut evaluated_max = 0u32; // ladder length shared by all pairs
    if bx > 0 && by > 0 {
        // Ladder ceiling: largest λ any probe pair can afford, bounded by
        // MAX_ADAPT_LAMBDA + 1 (the +1 supplies the λ vs λ+1 estimate at
        // the top candidate).
        evaluated_max = MAX_ADAPT_LAMBDA + 1;
        for i in 0..bx.min(PROBE_PAIRS) {
            let j = i % by;
            let (lx, ly) = (x.len_of(i), y.len_of(j));
            if lx < 2 || ly < 2 {
                continue;
            }
            let tr = opts.exec.transform;
            let (m, n, delta) =
                crate::kernel::delta::delta_matrix(x.values_of(i), y.values_of(j), lx, ly,
                    x.dim(), tr);
            while evaluated_max > 0 {
                let cells = (m as u128) * (n as u128) * (1u128 << (2 * evaluated_max));
                if cells <= PROBE_CELLS_MAX {
                    break;
                }
                evaluated_max -= 1;
            }
            let ladder: Vec<f64> = (0..=evaluated_max)
                .map(|lam| crate::kernel::solver::solve_pde(&delta, m, n, lam, lam))
                .collect();
            ladders.push(ladder);
        }
    }
    if ladders.is_empty() || evaluated_max == 0 {
        // Nothing to probe (empty / degenerate subsample, or even λ = 1 is
        // over budget): keep the options' own grid.
        return Ok(resolved);
    }
    // Candidate value at (scheme, λ) for ladder `l` (λ < evaluated_max is
    // guaranteed by the caller loop below).
    let value_at = |l: &[f64], scheme: Scheme, lam: u32| -> f64 {
        let lam = lam as usize;
        match scheme {
            Scheme::Order1 => l[lam],
            Scheme::Order2 if lam == 0 => l[0],
            Scheme::Order2 => richardson_combine(l[lam], l[lam - 1]),
        }
    };
    let mut candidates: Vec<(u128, Scheme, u32, f64)> = Vec::new();
    for lam in 0..evaluated_max {
        for scheme in [Scheme::Order1, Scheme::Order2] {
            if scheme == Scheme::Order2 && lam == 0 {
                continue; // degenerate: identical to Order1 at λ = 0
            }
            let mut err = 0.0f64;
            for l in &ladders {
                let here = value_at(l, scheme, lam);
                let next = value_at(l, scheme, lam + 1);
                let e = (here - next).abs() / next.abs().max(1.0);
                err = err.max(e);
            }
            candidates.push((cell_cost(scheme, lam, lam), scheme, lam, err));
        }
    }
    // Cheapest first; ties broken by (scheme, λ) order of insertion, which
    // is already deterministic.
    candidates.sort_by(|a, b| (a.0, a.2, a.1.to_u8()).cmp(&(b.0, b.2, b.1.to_u8())));
    let chosen = candidates
        .iter()
        .find(|c| c.3 <= eps)
        .or_else(|| {
            candidates
                .iter()
                .min_by(|a, b| a.3.partial_cmp(&b.3).unwrap_or(std::cmp::Ordering::Equal))
        })
        .copied();
    if let Some((_, scheme, lam, _)) = chosen {
        resolved.scheme = scheme;
        resolved.dyadic_x = lam;
        resolved.dyadic_y = lam;
    }
    Ok(resolved)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn combine_and_seeds_are_consistent() {
        let (f, c) = (1.25, 1.20);
        let k2 = richardson_combine(f, c);
        assert!((k2 - (4.0 * f - c) / 3.0).abs() == 0.0);
        let (sf, sc) = order2_seeds(0.7);
        assert!((sf - 0.7 * 4.0 / 3.0).abs() < 1e-15);
        assert!((sc + 0.7 / 3.0).abs() < 1e-15);
    }

    #[test]
    fn cost_model_orders_correctly() {
        // The acceptance claim: Order2 at λ−1 < Order1 at λ, strictly.
        for lam in 1..8u32 {
            assert!(cell_cost(Scheme::Order2, lam - 1, lam - 1) < cell_cost(Scheme::Order1, lam, lam));
        }
        assert_eq!(cell_cost(Scheme::Order1, 2, 2), 16);
        assert_eq!(cell_cost(Scheme::Order2, 2, 2), 20);
        assert_eq!(cell_cost(Scheme::Order2, 0, 0), 1);
    }

    #[test]
    fn target_eps_validation() {
        assert!(TargetEps::UNSET.validate().is_ok());
        assert!(TargetEps::new(1e-4).validate().is_ok());
        for bad in [0.0, -1.0, f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            assert!(TargetEps::new(bad).validate().is_err(), "eps={bad}");
        }
    }

    #[test]
    fn scheme_wire_roundtrip() {
        for s in [Scheme::Order1, Scheme::Order2] {
            assert_eq!(Scheme::from_u8(s.to_u8()), Some(s));
        }
        assert_eq!(Scheme::from_u8(2), None);
    }

    #[test]
    fn resolution_is_idempotent_and_clears_eps() {
        let mut rng = Rng::new(91);
        let (b, l, d) = (3, 8, 2);
        let data = rng.brownian_batch(b, l, d, 0.4);
        let xb = crate::path::PathBatch::uniform(&data, b, l, d).unwrap();
        let opts = KernelOptions::default().target_eps(1e-3);
        let r1 = resolve_target_eps(&xb, &xb, &opts).unwrap();
        assert_eq!(r1.target_eps, TargetEps::UNSET);
        let r2 = resolve_target_eps(&xb, &xb, &r1).unwrap();
        assert_eq!(r1, r2);
    }

    #[test]
    fn looser_eps_never_costs_more() {
        let mut rng = Rng::new(92);
        let (b, l, d) = (2, 10, 2);
        let data = rng.brownian_batch(b, l, d, 0.5);
        let xb = crate::path::PathBatch::uniform(&data, b, l, d).unwrap();
        let mut last_cost = u128::MAX;
        for eps in [1e-7, 1e-5, 1e-3, 1e-1] {
            let r = resolve_target_eps(&xb, &xb, &KernelOptions::default().target_eps(eps))
                .unwrap();
            let cost = cell_cost(r.scheme, r.dyadic_x, r.dyadic_y);
            assert!(cost <= last_cost, "eps={eps}: cost {cost} > {last_cost}");
            last_cost = cost;
        }
    }
}
