//! The paper's GPU scheme (§3.3), faithfully simulated on CPU: anti-diagonal
//! wavefront over the PDE grid, processed in **row-blocks of 32**, with only
//! **three rotating anti-diagonal buffers** live (the GPU keeps them in
//! shared memory). The initial-condition row in "global memory" is
//! overwritten by each block's final row, becoming the next block's initial
//! condition — so stream length is never limited by the 32-thread allocation.
//!
//! Numerics are identical to the row solver; this module exists (a) as the
//! correctness model for the CUDA/Pallas dataflow, and (b) to let the
//! ablation benches compare the two schedules on CPU.

/// Rows processed per block — the warp width in the paper's CUDA kernel.
pub const BLOCK_ROWS: usize = 32;

/// Scheme-dispatched blocked solve: same combine convention as
/// [`super::solver::solve_pde_scheme`], with both the fine and the coarse
/// sweep on the blocked anti-diagonal schedule.
pub fn solve_pde_blocked_scheme(
    delta: &[f64],
    m: usize,
    n: usize,
    lam1: u32,
    lam2: u32,
    scheme: crate::kernel::scheme::Scheme,
) -> f64 {
    use crate::kernel::scheme::{coarse_orders, order2_degenerate, richardson_combine, Scheme};
    match scheme {
        Scheme::Order1 => solve_pde_blocked(delta, m, n, lam1, lam2),
        Scheme::Order2 => {
            let fine = solve_pde_blocked(delta, m, n, lam1, lam2);
            if order2_degenerate(lam1, lam2) {
                return fine;
            }
            let (c1, c2) = coarse_orders(lam1, lam2);
            richardson_combine(fine, solve_pde_blocked(delta, m, n, c1, c2))
        }
    }
}

/// Solve the Goursat PDE with the blocked anti-diagonal schedule.
/// Same contract as [`super::solver::solve_pde`].
pub fn solve_pde_blocked(delta: &[f64], m: usize, n: usize, lam1: u32, lam2: u32) -> f64 {
    assert_eq!(delta.len(), m * n);
    let rows = m << lam1;
    let cols = n << lam2;
    let scale = 1.0 / (1u64 << (lam1 + lam2)) as f64;

    // "Global memory": the carried initial-condition row (row r0 of the
    // current block), initially all ones.
    let mut init_row = vec![1.0; cols + 1];

    // "Shared memory": three rotating anti-diagonals. Buffer index i holds
    // k[r0 + i, j] for the cell of the current diagonal at local row i.
    let bcap = BLOCK_ROWS + 1;
    let mut d_prev2 = vec![0.0; bcap];
    let mut d_prev = vec![0.0; bcap];
    let mut d_cur = vec![0.0; bcap];

    let mut r0 = 0; // first (known) row of the block
    while r0 < rows {
        let b = BLOCK_ROWS.min(rows - r0); // new rows computed in this block
        // Diagonal m_idx contains local cells (i, m_idx - i), i = 0..=b.
        // i = 0 is the init row; j = 0 is the unit left boundary.
        for m_idx in 0..=(b + cols) {
            // Rotate buffers: cur -> prev -> prev2.
            std::mem::swap(&mut d_prev2, &mut d_prev);
            std::mem::swap(&mut d_prev, &mut d_cur);
            let lo = m_idx.saturating_sub(cols);
            let hi = m_idx.min(b);
            // (In CUDA this loop is the 32 threads of the warp, one per i.)
            for i in lo..=hi {
                let j = m_idx - i;
                let v = if i == 0 {
                    init_row[j]
                } else if j == 0 {
                    1.0
                } else {
                    let gi = r0 + i; // global row of the node
                    let p = delta[((gi - 1) >> lam1) * n + ((j - 1) >> lam2)] * scale;
                    let p2 = p * p * (1.0 / 12.0);
                    let a = 1.0 + 0.5 * p + p2;
                    let bb = 1.0 - p2;
                    // k[i-1,j] and k[i,j-1] live on the previous diagonal;
                    // k[i-1,j-1] on the one before.
                    (d_prev[i - 1] + d_prev[i]) * a - d_prev2[i - 1] * bb
                };
                d_cur[i] = v;
                // The block's last row streams back to "global memory" and
                // becomes the next block's initial condition.
                if i == b {
                    init_row[j] = v;
                }
            }
        }
        r0 += b;
    }
    init_row[cols]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::solver::solve_pde;
    use crate::util::prop::check;

    #[test]
    fn matches_row_solver_across_sizes() {
        check("blocked == row", 30, |g| {
            // Cross the 32-row block boundary in both dimensions.
            let m = g.usize_in(1, 80);
            let n = g.usize_in(1, 80);
            let delta: Vec<f64> = g.normal_vec(m * n).iter().map(|v| v * 0.2).collect();
            let kr = solve_pde(&delta, m, n, 0, 0);
            let kb = solve_pde_blocked(&delta, m, n, 0, 0);
            assert!(
                (kr - kb).abs() <= 1e-9 * kr.abs().max(1.0),
                "m={m} n={n}: {kr} vs {kb}"
            );
        });
    }

    #[test]
    fn matches_with_dyadic_refinement() {
        check("blocked == row (dyadic)", 15, |g| {
            let m = g.usize_in(1, 20);
            let n = g.usize_in(1, 20);
            let lam1 = g.usize_in(0, 3) as u32;
            let lam2 = g.usize_in(0, 3) as u32;
            let delta: Vec<f64> = g.normal_vec(m * n).iter().map(|v| v * 0.2).collect();
            let kr = solve_pde(&delta, m, n, lam1, lam2);
            let kb = solve_pde_blocked(&delta, m, n, lam1, lam2);
            assert!(
                (kr - kb).abs() <= 1e-9 * kr.abs().max(1.0),
                "m={m} n={n} λ=({lam1},{lam2}): {kr} vs {kb}"
            );
        });
    }

    /// Cross-schedule **bit-identity**: the anti-diagonal blocked sweep and
    /// the row sweep evaluate the same recurrence with the same A(p)/B(p)
    /// and a commutative two-term sum (top + left vs left + top), so the
    /// results must match exactly — across dyadic orders λ1, λ2 ∈ {0,1,2}
    /// and row counts that are not multiples of the 32-row block (the
    /// init-row carry's boundary cases had no dedicated coverage before).
    #[test]
    fn blocked_bitmatches_row_across_schedules() {
        check("blocked ≡ row (bitwise)", 20, |g| {
            let m = g.usize_in(1, 70);
            let n = g.usize_in(1, 70);
            let lam1 = g.usize_in(0, 2) as u32;
            let lam2 = g.usize_in(0, 2) as u32;
            let delta: Vec<f64> = g.normal_vec(m * n).iter().map(|v| v * 0.2).collect();
            let kr = solve_pde(&delta, m, n, lam1, lam2);
            let kb = solve_pde_blocked(&delta, m, n, lam1, lam2);
            assert_eq!(kr, kb, "m={m} n={n} λ=({lam1},{lam2})");
        });
        // Deterministic boundary sizes: rows straddling the 32-row block in
        // the *refined* grid too (m·2^λ1 crossing 32/64).
        for &(m, lam1) in &[(31usize, 0u32), (33, 0), (17, 1), (9, 2), (65, 0), (16, 1)] {
            for &lam2 in &[0u32, 1, 2] {
                let n = 5;
                let delta: Vec<f64> =
                    (0..m * n).map(|i| ((i % 11) as f64 - 5.0) * 0.04).collect();
                let kr = solve_pde(&delta, m, n, lam1, lam2);
                let kb = solve_pde_blocked(&delta, m, n, lam1, lam2);
                assert_eq!(kr, kb, "m={m} λ=({lam1},{lam2})");
            }
        }
    }

    #[test]
    fn exact_block_boundary_sizes() {
        // rows exactly 32, 64: the init-row carry is exercised end-to-end.
        for &m in &[32usize, 33, 64, 65] {
            let delta: Vec<f64> = (0..m * 3).map(|i| ((i % 7) as f64 - 3.0) * 0.05).collect();
            let kr = solve_pde(&delta, m, 3, 0, 0);
            let kb = solve_pde_blocked(&delta, m, 3, 0, 0);
            assert!((kr - kb).abs() < 1e-10, "m={m}");
        }
    }
}
