//! Algorithm 3 — the CPU Goursat-PDE sweep for signature kernels.
//!
//! Solves k(s,t) over the grid refined dyadically to order (λ1, λ2), using
//! the second-order discretisation of eq. (1):
//!
//!   k[s+1,t+1] = (k[s+1,t] + k[s,t+1])·A(p) − k[s,t]·B(p),
//!   A(p) = 1 + p/2 + p²/12,  B(p) = 1 − p²/12,
//!   p = Δ[s ≫ λ1, t ≫ λ2] / 2^{λ1+λ2}.
//!
//! Design choices (paper §3.2): (1) λ1 and λ2 are independent; (2) Δ is
//! precomputed by one GEMM (see [`super::delta`]); (3) dyadic refinement is
//! applied *on-the-fly* via the index shift `s ≫ λ1` — the refined path and
//! refined Δ are never materialised (other packages precompute them, paying
//! 4^λ memory).

use std::sync::atomic::{AtomicU64, Ordering};

/// Process-wide count of forward Goursat cells solved by the row-sweep
/// solvers (scalar and lane-batched). Mirrors `border_cells_solved`: an
/// occupancy probe for tests and benchmarks — e.g. proving that a
/// retained-grid `record.vjp` re-solves **zero** forward cells.
static PDE_FWD_CELLS: AtomicU64 = AtomicU64::new(0);

#[inline]
pub(crate) fn count_fwd_cells(n: u64) {
    PDE_FWD_CELLS.fetch_add(n, Ordering::Relaxed);
}

/// Total refined forward cells solved so far by this process (row solvers
/// only; the blocked solver has its own tiling and is not counted here).
pub fn pde_cells_solved() -> u64 {
    PDE_FWD_CELLS.load(Ordering::Relaxed)
}

/// Solve the PDE and return the terminal value k(1,1).
///
/// `delta` is the `[m, n]` increment inner-product matrix (m = lx−1,
/// n = ly−1); the refined grid has `(m·2^λ1 + 1) × (n·2^λ2 + 1)` nodes but
/// only two rows are ever live.
pub fn solve_pde(delta: &[f64], m: usize, n: usize, lam1: u32, lam2: u32) -> f64 {
    let mut prev = Vec::new();
    let mut cur = Vec::new();
    solve_pde_with(delta, m, n, lam1, lam2, &mut prev, &mut cur)
}

/// [`solve_pde`] with caller-provided row buffers (`prev`, `cur`), resized to
/// `cols + 1` in place — the engine's kernel plans reuse them across
/// executions so the steady state allocates nothing.
pub fn solve_pde_with(
    delta: &[f64],
    m: usize,
    n: usize,
    lam1: u32,
    lam2: u32,
    prev: &mut Vec<f64>,
    cur: &mut Vec<f64>,
) -> f64 {
    assert_eq!(delta.len(), m * n);
    let rows = m << lam1;
    let cols = n << lam2;
    count_fwd_cells((rows * cols) as u64);
    let scale = 1.0 / (1u64 << (lam1 + lam2)) as f64;
    prev.clear();
    prev.resize(cols + 1, 1.0);
    cur.clear();
    cur.resize(cols + 1, 1.0);
    // NOTE (§Perf): a "two-pass" restructure of this loop (vectorisable
    // prev-row combination + minimal serial FMA chain) was tried and
    // *reverted* — on this testbed it is ~20% slower than the fused loop
    // (extra coefficient/cterm memory traffic outweighs the shorter
    // dependency chain). See EXPERIMENTS.md §Perf and the
    // `pde_sweep/*` rows of the ablations bench. Batching across pairs
    // instead is what pays: see [`super::lanes`].
    //
    // Dyadic-run hoist: p — hence A(p), B(p) — is constant for 2^λ2
    // consecutive refined t steps (`t >> λ2` does not move within a run),
    // so the coefficients are computed once per run instead of once per
    // refined cell. Bit-identical to the per-cell form (same expressions,
    // same inputs, evaluated fewer times); measured in the
    // `pde_sweep/dyadic*` ablation rows.
    let run = 1usize << lam2;
    for s in 0..rows {
        let drow = &delta[(s >> lam1) * n..(s >> lam1) * n + n];
        cur[0] = 1.0;
        // Inner loop: contiguous over t, three streams (cur, prev) — the
        // memory-bound hot loop of the paper's CPU algorithm.
        let mut k_left = 1.0; // cur[t]
        let mut t = 0usize;
        for &d in drow.iter() {
            let p = d * scale;
            let p2 = p * p * (1.0 / 12.0);
            let a = 1.0 + 0.5 * p + p2;
            let b = 1.0 - p2;
            for _ in 0..run {
                let v = (k_left + prev[t + 1]) * a - prev[t] * b;
                cur[t + 1] = v;
                k_left = v;
                t += 1;
            }
        }
        std::mem::swap(prev, cur);
    }
    prev[cols]
}

/// Scheme-dispatched terminal solve: [`Scheme::Order1`] is
/// [`solve_pde_with`] unchanged; [`Scheme::Order2`] runs the identical
/// sweep at (λ1, λ2) and at the coarsened orders, then Richardson-combines
/// the terminals (`(4·k_fine − k_coarse)/3`). At λ = (0, 0) the coarse grid
/// coincides with the fine one, so the fine value is returned directly.
/// The fine sweep's FP sequence is exactly the `Order1` sequence — the
/// bit-identity anchor every lane/border/backward scheme path shares.
pub fn solve_pde_scheme(
    delta: &[f64],
    m: usize,
    n: usize,
    lam1: u32,
    lam2: u32,
    scheme: crate::kernel::scheme::Scheme,
    prev: &mut Vec<f64>,
    cur: &mut Vec<f64>,
) -> f64 {
    use crate::kernel::scheme::{coarse_orders, order2_degenerate, richardson_combine, Scheme};
    match scheme {
        Scheme::Order1 => solve_pde_with(delta, m, n, lam1, lam2, prev, cur),
        Scheme::Order2 => {
            let fine = solve_pde_with(delta, m, n, lam1, lam2, prev, cur);
            if order2_degenerate(lam1, lam2) {
                return fine;
            }
            let (c1, c2) = coarse_orders(lam1, lam2);
            let coarse = solve_pde_with(delta, m, n, c1, c2, prev, cur);
            richardson_combine(fine, coarse)
        }
    }
}

/// Solve the PDE keeping the whole grid — needed by the exact backward pass
/// (Algorithm 4). Returns the `[(rows+1) × (cols+1)]` grid row-major, where
/// rows = m·2^λ1, cols = n·2^λ2.
pub fn solve_pde_grid(delta: &[f64], m: usize, n: usize, lam1: u32, lam2: u32) -> Vec<f64> {
    let rows = m << lam1;
    let cols = n << lam2;
    let mut k = vec![1.0; (rows + 1) * (cols + 1)];
    solve_pde_grid_into(delta, m, n, lam1, lam2, &mut k);
    k
}

/// [`solve_pde_grid`] into caller-provided storage of length
/// `(m·2^λ1 + 1) × (n·2^λ2 + 1)` — used by the engine's record-keeping
/// kernel plans so the retained grids live in the workspace arena.
pub fn solve_pde_grid_into(
    delta: &[f64],
    m: usize,
    n: usize,
    lam1: u32,
    lam2: u32,
    k: &mut [f64],
) {
    assert_eq!(delta.len(), m * n);
    let rows = m << lam1;
    let cols = n << lam2;
    count_fwd_cells((rows * cols) as u64);
    let scale = 1.0 / (1u64 << (lam1 + lam2)) as f64;
    let w = cols + 1;
    assert_eq!(k.len(), (rows + 1) * w);
    k.fill(1.0);
    // Same dyadic-run coefficient hoist as [`solve_pde_with`] (bit-identical
    // to the per-cell form).
    let run = 1usize << lam2;
    for s in 0..rows {
        let drow = &delta[(s >> lam1) * n..(s >> lam1) * n + n];
        let (top, bot) = k.split_at_mut((s + 1) * w);
        let prev = &top[s * w..(s + 1) * w];
        let cur = &mut bot[..w];
        let mut k_left = 1.0;
        let mut t = 0usize;
        for &d in drow.iter() {
            let p = d * scale;
            let p2 = p * p * (1.0 / 12.0);
            let a = 1.0 + 0.5 * p + p2;
            let b = 1.0 - p2;
            for _ in 0..run {
                let v = (k_left + prev[t + 1]) * a - prev[t] * b;
                cur[t + 1] = v;
                k_left = v;
                t += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn zero_delta_gives_one() {
        // ⟨dx, dy⟩ ≡ 0 ⇒ k ≡ 1 (orthogonal paths).
        let d = vec![0.0; 12];
        assert_eq!(solve_pde(&d, 3, 4, 0, 0), 1.0);
        assert_eq!(solve_pde(&d, 3, 4, 2, 1), 1.0);
    }

    #[test]
    fn grid_terminal_matches_scalar_solver() {
        check("grid[-1,-1] == solve_pde", 25, |g| {
            let m = g.usize_in(1, 10);
            let n = g.usize_in(1, 10);
            let lam1 = g.usize_in(0, 2) as u32;
            let lam2 = g.usize_in(0, 2) as u32;
            let delta: Vec<f64> = g.normal_vec(m * n).iter().map(|v| v * 0.3).collect();
            let k = solve_pde(&delta, m, n, lam1, lam2);
            let grid = solve_pde_grid(&delta, m, n, lam1, lam2);
            let last = *grid.last().unwrap();
            assert!((k - last).abs() < 1e-12, "{k} vs {last}");
        });
    }

    #[test]
    fn single_cell_quadrature() {
        // One cell, Δ = p: k = A(p)·2 − B(p) with k-neighbours 1 ⇒
        // k = 2(1 + p/2 + p²/12) − (1 − p²/12) = 1 + p + p²/4.
        let p = 0.37;
        let k = solve_pde(&[p], 1, 1, 0, 0);
        let want = 2.0 * (1.0 + 0.5 * p + p * p / 12.0) - (1.0 - p * p / 12.0);
        assert!((k - want).abs() < 1e-15);
    }

    #[test]
    fn monotone_in_delta_for_positive_delta() {
        // For Δ ≥ 0 the kernel increases with Δ.
        let k1 = solve_pde(&[0.1, 0.1, 0.1, 0.1], 2, 2, 0, 0);
        let k2 = solve_pde(&[0.2, 0.2, 0.2, 0.2], 2, 2, 0, 0);
        assert!(k2 > k1);
    }

    /// The shipped dyadic-run coefficient hoist must be bit-identical to
    /// the historical per-refined-cell form (same expressions on the same
    /// inputs, computed once per 2^λ2 run instead of per cell).
    #[test]
    fn dyadic_run_hoist_bitmatches_per_cell_form() {
        fn per_cell_reference(delta: &[f64], m: usize, n: usize, lam1: u32, lam2: u32) -> f64 {
            let rows = m << lam1;
            let cols = n << lam2;
            let scale = 1.0 / (1u64 << (lam1 + lam2)) as f64;
            let mut prev = vec![1.0; cols + 1];
            let mut cur = vec![1.0; cols + 1];
            for s in 0..rows {
                let drow = &delta[(s >> lam1) * n..(s >> lam1) * n + n];
                cur[0] = 1.0;
                let mut k_left = 1.0;
                for t in 0..cols {
                    let p = drow[t >> lam2] * scale;
                    let p2 = p * p * (1.0 / 12.0);
                    let a = 1.0 + 0.5 * p + p2;
                    let b = 1.0 - p2;
                    let v = (k_left + prev[t + 1]) * a - prev[t] * b;
                    cur[t + 1] = v;
                    k_left = v;
                }
                std::mem::swap(&mut prev, &mut cur);
            }
            prev[cols]
        }
        check("run-hoisted == per-cell", 25, |g| {
            let m = g.usize_in(1, 12);
            let n = g.usize_in(1, 12);
            let lam1 = g.usize_in(0, 3) as u32;
            let lam2 = g.usize_in(0, 3) as u32;
            let delta: Vec<f64> = g.normal_vec(m * n).iter().map(|v| v * 0.3).collect();
            let hoisted = solve_pde(&delta, m, n, lam1, lam2);
            let reference = per_cell_reference(&delta, m, n, lam1, lam2);
            assert_eq!(hoisted, reference, "m={m} n={n} λ=({lam1},{lam2})");
        });
    }

    #[test]
    fn order2_scheme_combines_fine_and_coarse() {
        use crate::kernel::scheme::{richardson_combine, Scheme};
        let delta = [0.3, -0.2, 0.15, 0.4, 0.05, -0.1];
        let (m, n) = (2, 3);
        let mut p = Vec::new();
        let mut c = Vec::new();
        // Order1 dispatch is the plain solver, bitwise.
        assert_eq!(
            solve_pde_scheme(&delta, m, n, 2, 1, Scheme::Order1, &mut p, &mut c),
            solve_pde(&delta, m, n, 2, 1)
        );
        // Order2 is the documented combine of the two plain solves.
        let fine = solve_pde(&delta, m, n, 2, 1);
        let coarse = solve_pde(&delta, m, n, 1, 0);
        assert_eq!(
            solve_pde_scheme(&delta, m, n, 2, 1, Scheme::Order2, &mut p, &mut c),
            richardson_combine(fine, coarse)
        );
        // Degenerate λ = (0,0): the fine value itself, no combine rounding.
        assert_eq!(
            solve_pde_scheme(&delta, m, n, 0, 0, Scheme::Order2, &mut p, &mut c),
            solve_pde(&delta, m, n, 0, 0)
        );
    }

    #[test]
    fn grid_boundaries_are_one() {
        let delta = [0.3, -0.2, 0.15, 0.4];
        let grid = solve_pde_grid(&delta, 2, 2, 1, 0);
        let rows = 2 << 1;
        let cols = 2;
        let w = cols + 1;
        for s in 0..=rows {
            assert_eq!(grid[s * w], 1.0);
        }
        for t in 0..=cols {
            assert_eq!(grid[t], 1.0);
        }
    }
}
