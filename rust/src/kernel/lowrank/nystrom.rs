//! Nyström low-rank features for the signature kernel.
//!
//! Given r landmark paths Z, the Nyström approximation of the kernel is
//!
//!   k̂(x, y) = k_Z(x)ᵀ · K_ZZ⁺ · k_Z(y),
//!
//! which an explicit feature map realises as φ(x) = L⁻¹ k_{Z'}(x), where
//! K_{Z'Z'} = L·Lᵀ is the pivoted Cholesky factorisation of the landmark
//! Gram restricted to its numerically independent pivot subset Z' ⊆ Z
//! ([`pivoted_cholesky`](crate::util::linalg::pivoted_cholesky)). Each
//! feature row costs r kernel PDE solves plus an r² triangular solve, so a
//! full feature matrix is O(n·r·L²) against the exact Gram's O(n²·L²). The
//! landmark self-Gram and every cross-Gram route through [`try_gram`], so
//! they ride the engine's lane-batched PDE schedule
//! ([`kernel::lanes`](crate::kernel::lanes)): landmarks share a length
//! class by construction, which keeps the lane groups full.
//!
//! The feature map is **exact on the landmark span**: for query points that
//! are themselves landmarks, Φ·Φᵀ reproduces the exact Gram (the basis of
//! the full-rank recovery property test).

use crate::kernel::lowrank::LowRankFeatures;
use crate::kernel::{try_gram, try_gram_vjp, KernelOptions};
use crate::path::{PathBatch, SigError};
use crate::util::linalg::{back_substitute_t, forward_substitute, pivoted_cholesky};

/// Relative pivot threshold for the landmark Gram factorisation: pivots
/// whose residual diagonal falls below `tol · max(diag)` are dropped, so a
/// numerically redundant landmark shrinks the rank instead of poisoning the
/// triangular solves.
const PIVOT_TOL: f64 = 1e-12;

/// Nyström feature map over an owned set of landmark paths.
///
/// Gradients through [`LowRankFeatures::try_features_vjp`] treat the
/// landmarks as **constants** (the standard stop-gradient convention for
/// landmark methods) and route each ∂k(x_i, z_j)/∂x_i through the exact
/// Algorithm-4 kernel backward via
/// [`try_gram_vjp`](crate::kernel::try_gram_vjp).
pub struct NystromFeatures {
    /// Selected landmark paths (pivot order), flat ragged buffer.
    land_data: Vec<f64>,
    land_lens: Vec<usize>,
    dim: usize,
    opts: KernelOptions,
    /// Lower-triangular Cholesky factor of the pivot-subset landmark Gram,
    /// dense `[rank, rank]` row-major.
    chol: Vec<f64>,
    rank: usize,
}

impl NystromFeatures {
    /// Build the feature map from a (possibly ragged) batch of landmark
    /// paths. The effective rank can be smaller than `landmarks.batch()`
    /// when landmarks are numerically redundant.
    pub fn try_new(
        landmarks: &PathBatch<'_>,
        opts: &KernelOptions,
    ) -> Result<NystromFeatures, SigError> {
        let r0 = landmarks.batch();
        if r0 == 0 {
            return Err(SigError::InsufficientBatch { need: 1, got: 0 });
        }
        let mut kzz = try_gram(landmarks, landmarks, opts)?;
        if !kzz.iter().all(|v| v.is_finite()) {
            return Err(SigError::NonFinite("landmark Gram overflowed f64"));
        }
        // With asymmetric dyadic orders (λ1 ≠ λ2) the discretised kernel is
        // not symmetric in its arguments; the factorisation needs a
        // symmetric matrix, so target the symmetrised kernel (exact-recovery
        // guarantees then hold for symmetric solves, where this is a no-op
        // up to roundoff).
        for i in 0..r0 {
            for j in 0..i {
                let s = 0.5 * (kzz[i * r0 + j] + kzz[j * r0 + i]);
                kzz[i * r0 + j] = s;
                kzz[j * r0 + i] = s;
            }
        }
        let (l, perm, rank) = pivoted_cholesky(&kzz, r0, PIVOT_TOL);
        if rank == 0 {
            return Err(SigError::NonFinite("landmark Gram is numerically zero"));
        }
        // Keep only the pivot subset, re-packed dense: landmarks in pivot
        // order and the leading rank × rank triangle of the factor.
        let mut land_data = Vec::new();
        let mut land_lens = Vec::with_capacity(rank);
        for &p in perm.iter().take(rank) {
            land_data.extend_from_slice(landmarks.values_of(p));
            land_lens.push(landmarks.len_of(p));
        }
        let mut chol = vec![0.0; rank * rank];
        for i in 0..rank {
            for j in 0..=i {
                chol[i * rank + j] = l[i * r0 + j];
            }
        }
        Ok(NystromFeatures {
            land_data,
            land_lens,
            dim: landmarks.dim(),
            opts: *opts,
            chol,
            rank,
        })
    }

    /// The retained pivot-subset landmarks as a typed batch.
    pub fn landmarks(&self) -> PathBatch<'_> {
        PathBatch::ragged(&self.land_data, &self.land_lens, self.dim)
            .expect("internal: stored landmark batch is valid")
    }

    fn check_dim(&self, x: &PathBatch<'_>) -> Result<(), SigError> {
        if x.dim() != self.dim {
            return Err(SigError::DimMismatch {
                left: x.dim(),
                right: self.dim,
            });
        }
        Ok(())
    }
}

impl LowRankFeatures for NystromFeatures {
    fn rank(&self) -> usize {
        self.rank
    }

    /// Φ = C·L⁻ᵀ where C is the `[batch, rank]` cross-Gram against the
    /// pivot landmarks — one forward substitution per row.
    fn try_features(&self, x: &PathBatch<'_>) -> Result<Vec<f64>, SigError> {
        self.check_dim(x)?;
        let mut c = try_gram(x, &self.landmarks(), &self.opts)?;
        if !c.iter().all(|v| v.is_finite()) {
            return Err(SigError::NonFinite("cross Gram overflowed f64"));
        }
        for row in c.chunks_mut(self.rank) {
            forward_substitute(&self.chol, self.rank, self.rank, row);
        }
        Ok(c)
    }

    /// Path gradients of F given Ḡ = ∂F/∂Φ: since Φ = C·L⁻ᵀ,
    /// ∂F/∂C = Ḡ·L⁻¹ (one transposed back substitution per row), and the
    /// cross-Gram backward distributes those weights through Algorithm 4.
    fn try_features_vjp(
        &self,
        x: &PathBatch<'_>,
        grad_phi: &[f64],
    ) -> Result<Vec<f64>, SigError> {
        self.check_dim(x)?;
        let expected = x.batch() * self.rank;
        if grad_phi.len() != expected {
            return Err(SigError::CotangentLen {
                expected,
                got: grad_phi.len(),
            });
        }
        let mut w = grad_phi.to_vec();
        for row in w.chunks_mut(self.rank) {
            back_substitute_t(&self.chol, self.rank, self.rank, row);
        }
        let (gx, _gz) = try_gram_vjp(x, &self.landmarks(), &w, &self.opts)?;
        Ok(gx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::lowrank::try_gram_lowrank;
    use crate::util::linalg::max_abs_diff;
    use crate::util::rng::Rng;

    #[test]
    fn full_landmark_set_reproduces_exact_gram() {
        let mut rng = Rng::new(500);
        let (n, l, d) = (6, 5, 2);
        let data = rng.brownian_batch(n, l, d, 0.25);
        let xb = PathBatch::uniform(&data, n, l, d).unwrap();
        let opts = KernelOptions::default();
        let f = NystromFeatures::try_new(&xb, &opts).unwrap();
        let approx = try_gram_lowrank(&f, &xb, &xb).unwrap();
        let exact = try_gram(&xb, &xb, &opts).unwrap();
        assert!(
            max_abs_diff(&approx, &exact) < 1e-8,
            "err {}",
            max_abs_diff(&approx, &exact)
        );
    }

    #[test]
    fn duplicate_landmarks_shrink_the_effective_rank() {
        let mut rng = Rng::new(501);
        let (l, d) = (5, 2);
        let one = rng.brownian_path(l, d, 0.3);
        let mut data = one.clone();
        data.extend_from_slice(&one); // exact duplicate
        data.extend(rng.brownian_path(l, d, 0.3));
        let zb = PathBatch::uniform(&data, 3, l, d).unwrap();
        let f = NystromFeatures::try_new(&zb, &KernelOptions::default()).unwrap();
        assert_eq!(f.rank(), 2, "duplicate landmark must be dropped");
    }

    #[test]
    fn empty_landmarks_and_dim_mismatch_error() {
        let empty = PathBatch::ragged(&[], &[], 2).unwrap();
        assert!(matches!(
            NystromFeatures::try_new(&empty, &KernelOptions::default()),
            Err(SigError::InsufficientBatch { .. })
        ));
        let mut rng = Rng::new(502);
        let data = rng.brownian_batch(3, 4, 2, 0.3);
        let zb = PathBatch::uniform(&data, 3, 4, 2).unwrap();
        let f = NystromFeatures::try_new(&zb, &KernelOptions::default()).unwrap();
        let d3 = vec![0.0; 2 * 4 * 3];
        let q = PathBatch::uniform(&d3, 2, 4, 3).unwrap();
        assert!(matches!(
            f.try_features(&q),
            Err(SigError::DimMismatch { .. })
        ));
    }
}
