//! Low-rank signature-kernel approximations (KSig-style): explicit rank-r
//! feature maps Φ ∈ R^{n × r} that replace every quadratic-in-n Gram/MMD/KRR
//! entry point with an O(n·r²) one.
//!
//! Two approximation families implement the common [`LowRankFeatures`]
//! trait:
//!
//! * [`NystromFeatures`] — r landmark paths, the n×r cross-kernel solved by
//!   the exact Goursat PDE, and a pivoted Cholesky of the landmark Gram.
//!   Accurate whenever the landmark span covers the data; exact at full
//!   rank. Cost: O(n·r) PDE solves + O(n·r²) linear algebra.
//! * [`RandomSigFeatures`] — truncated signatures projected by a seeded
//!   Gaussian/Rademacher sketch. Data-independent map (exact gradients, no
//!   landmark caveat), no PDE solves; accuracy set by truncation depth and
//!   sketch width.
//!
//! On top of Φ: [`try_gram_lowrank`], [`try_mmd2_lowrank`] (biased) /
//! [`try_mmd2_lowrank_unbiased`], [`try_mmd2_lowrank_with_grad`], and
//! [`LowRankRidge`] (the O(n·r²) normal-equation counterpart of
//! [`KernelRidge`](crate::kernel::KernelRidge)). The engine exposes the same
//! estimators as first-class plans
//! ([`OpSpec::GramLowRank`](crate::engine::OpSpec::GramLowRank) /
//! [`Mmd2LowRank`](crate::engine::OpSpec::Mmd2LowRank) /
//! [`KrrLowRank`](crate::engine::OpSpec::KrrLowRank)) whose records retain
//! the feature matrices for reuse and whose vjps route path gradients
//! through the exact kernel/signature backward machinery.

pub mod nystrom;
pub mod randsig;

pub use nystrom::NystromFeatures;
pub use randsig::{RandomSigFeatures, SketchKind};

use crate::kernel::KernelOptions;
use crate::path::{PathBatch, SigError};
use crate::util::linalg::{gemm_nt, solve_spd};
use crate::util::rng::Rng;

/// A rank-r feature map φ: paths → R^r approximating the signature kernel
/// as k(x, y) ≈ φ(x)·φ(y).
pub trait LowRankFeatures {
    /// Effective rank r (feature dimension). May be smaller than requested
    /// when landmarks are numerically redundant.
    fn rank(&self) -> usize;

    /// Feature matrix Φ for a (possibly ragged) batch: `[batch, rank]`
    /// row-major.
    fn try_features(&self, x: &PathBatch<'_>) -> Result<Vec<f64>, SigError>;

    /// Path gradients of F given Ḡ = ∂F/∂Φ (`[batch, rank]`), returned in
    /// the batch's own flat (possibly ragged) layout. Routed through the
    /// exact kernel/signature backward schemes; Nyström landmarks are
    /// treated as constants.
    fn try_features_vjp(
        &self,
        x: &PathBatch<'_>,
        grad_phi: &[f64],
    ) -> Result<Vec<f64>, SigError>;
}

/// Which approximation family a low-rank engine plan should build.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LowRankMethod {
    /// Nyström landmarks drawn (seeded, without replacement) from the
    /// reference batch.
    Nystrom,
    /// Random projection of depth-`depth` truncated signatures.
    RandomSig { depth: usize, sketch: SketchKind },
}

/// Hashable, `Copy` description of a low-rank approximation — the part of a
/// low-rank [`OpSpec`](crate::engine::OpSpec) that joins the plan-cache key.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct LowRankSpec {
    pub method: LowRankMethod,
    /// Requested rank (landmark count / sketch width). Capped at the
    /// reference batch size for Nyström.
    pub rank: usize,
    /// Seed for landmark sampling / sketch generation — same seed, same map.
    pub seed: u64,
}

impl LowRankSpec {
    /// Nyström with `rank` landmarks.
    pub fn nystrom(rank: usize, seed: u64) -> LowRankSpec {
        LowRankSpec {
            method: LowRankMethod::Nystrom,
            rank,
            seed,
        }
    }

    /// Random signature features: depth-`depth` signatures, Rademacher
    /// sketch of width `rank`.
    pub fn random_sig(rank: usize, depth: usize, seed: u64) -> LowRankSpec {
        LowRankSpec {
            method: LowRankMethod::RandomSig {
                depth,
                sketch: SketchKind::Rademacher,
            },
            rank,
            seed,
        }
    }

    /// Validate the data-independent parts (rank/depth positivity).
    pub fn validate(&self) -> Result<(), SigError> {
        if self.rank == 0 {
            return Err(SigError::Invalid("low-rank feature rank must be at least 1"));
        }
        if let LowRankMethod::RandomSig { depth, .. } = self.method {
            if depth == 0 {
                return Err(SigError::ZeroDepth);
            }
        }
        Ok(())
    }
}

/// Sample `rank` distinct indices from `0..batch` (partial Fisher–Yates,
/// seeded). Returns all of `0..batch` (shuffled) when `rank >= batch`.
pub fn sample_landmark_indices(batch: usize, rank: usize, seed: u64) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..batch).collect();
    let mut rng = Rng::new(seed);
    let take = rank.min(batch);
    for i in 0..take {
        let j = i + rng.below(batch - i);
        idx.swap(i, j);
    }
    idx.truncate(take);
    idx
}

/// An owned feature map of either family — what low-rank engine plans build
/// at execute time and retain on their
/// [`ExecutionRecord`](crate::engine::ExecutionRecord)s.
pub enum FeatureMap {
    Nystrom(NystromFeatures),
    RandomSig(RandomSigFeatures),
}

impl FeatureMap {
    /// Build the map a [`LowRankSpec`] describes. Nyström draws its
    /// landmarks (seeded, without replacement) from `reference` — by
    /// convention the *second* batch of a pair op, so that gradients with
    /// respect to the first batch are exact. Random signature features only
    /// need the reference's dimension.
    pub fn try_build(
        spec: &LowRankSpec,
        opts: &KernelOptions,
        reference: &PathBatch<'_>,
    ) -> Result<FeatureMap, SigError> {
        spec.validate()?;
        match spec.method {
            LowRankMethod::Nystrom => {
                if reference.is_empty() {
                    return Err(SigError::InsufficientBatch { need: 1, got: 0 });
                }
                let idx = sample_landmark_indices(reference.batch(), spec.rank, spec.seed);
                let mut data = Vec::new();
                let mut lens = Vec::with_capacity(idx.len());
                for &i in &idx {
                    data.extend_from_slice(reference.values_of(i));
                    lens.push(reference.len_of(i));
                }
                let zb = PathBatch::ragged(&data, &lens, reference.dim())?;
                Ok(FeatureMap::Nystrom(NystromFeatures::try_new(&zb, opts)?))
            }
            LowRankMethod::RandomSig { depth, sketch } => {
                Ok(FeatureMap::RandomSig(RandomSigFeatures::try_new(
                    reference.dim(),
                    depth,
                    spec.rank,
                    spec.seed,
                    sketch,
                    opts.exec,
                )?))
            }
        }
    }
}

impl LowRankFeatures for FeatureMap {
    fn rank(&self) -> usize {
        match self {
            FeatureMap::Nystrom(f) => f.rank(),
            FeatureMap::RandomSig(f) => f.rank(),
        }
    }

    fn try_features(&self, x: &PathBatch<'_>) -> Result<Vec<f64>, SigError> {
        match self {
            FeatureMap::Nystrom(f) => f.try_features(x),
            FeatureMap::RandomSig(f) => f.try_features(x),
        }
    }

    fn try_features_vjp(
        &self,
        x: &PathBatch<'_>,
        grad_phi: &[f64],
    ) -> Result<Vec<f64>, SigError> {
        match self {
            FeatureMap::Nystrom(f) => f.try_features_vjp(x, grad_phi),
            FeatureMap::RandomSig(f) => f.try_features_vjp(x, grad_phi),
        }
    }
}

/// Low-rank Gram matrix `[bx, by]`: Φx·Φyᵀ — O((bx + by)·r) feature rows
/// plus one O(bx·by·r) GEMM, against the exact Gram's bx·by PDE solves.
pub fn try_gram_lowrank<F: LowRankFeatures + ?Sized>(
    f: &F,
    x: &PathBatch<'_>,
    y: &PathBatch<'_>,
) -> Result<Vec<f64>, SigError> {
    let phi_x = f.try_features(x)?;
    let phi_y = f.try_features(y)?;
    let (bx, by) = (x.batch(), y.batch());
    let mut out = vec![0.0; bx * by];
    gemm_nt(bx, f.rank(), by, &phi_x, &phi_y, &mut out);
    Ok(out)
}

/// Column means of a `[b, r]` feature matrix (shared with the engine's
/// low-rank MMD² op).
pub(crate) fn feature_mean(phi: &[f64], b: usize, r: usize) -> Vec<f64> {
    let mut m = vec![0.0; r];
    for row in phi.chunks(r) {
        for (o, &v) in m.iter_mut().zip(row.iter()) {
            *o += v;
        }
    }
    let inv = 1.0 / b.max(1) as f64;
    for v in m.iter_mut() {
        *v *= inv;
    }
    m
}

fn check_mmd_batches(
    x: &PathBatch<'_>,
    y: &PathBatch<'_>,
    need: usize,
) -> Result<(), SigError> {
    let got = x.batch().min(y.batch());
    if got < need {
        return Err(SigError::InsufficientBatch { need, got });
    }
    Ok(())
}

/// Low-rank **biased** MMD² (V-statistic): with K ≈ ΦΦᵀ the estimator
/// collapses to ‖mean(Φx) − mean(Φy)‖² — O((bx + by)·r) after the feature
/// rows, no Gram materialised.
pub fn try_mmd2_lowrank<F: LowRankFeatures + ?Sized>(
    f: &F,
    x: &PathBatch<'_>,
    y: &PathBatch<'_>,
) -> Result<f64, SigError> {
    check_mmd_batches(x, y, 1)?;
    let phi_x = f.try_features(x)?;
    let phi_y = f.try_features(y)?;
    let r = f.rank();
    let mx = feature_mean(&phi_x, x.batch(), r);
    let my = feature_mean(&phi_y, y.batch(), r);
    Ok(mx
        .iter()
        .zip(my.iter())
        .map(|(a, b)| (a - b) * (a - b))
        .sum())
}

/// Low-rank **unbiased** MMD² (U-statistic, diagonal terms excluded) — the
/// two-sample-testing estimator, from feature sums alone.
pub fn try_mmd2_lowrank_unbiased<F: LowRankFeatures + ?Sized>(
    f: &F,
    x: &PathBatch<'_>,
    y: &PathBatch<'_>,
) -> Result<f64, SigError> {
    check_mmd_batches(x, y, 2)?;
    let phi_x = f.try_features(x)?;
    let phi_y = f.try_features(y)?;
    let r = f.rank();
    let (bx, by) = (x.batch(), y.batch());
    // Σ_{i≠j} φi·φj = ‖Σφ‖² − Σ‖φi‖², all from one pass.
    let stats = |phi: &[f64]| -> (Vec<f64>, f64) {
        let mut s = vec![0.0; r];
        let mut sq = 0.0;
        for row in phi.chunks(r) {
            for (o, &v) in s.iter_mut().zip(row.iter()) {
                *o += v;
            }
            sq += row.iter().map(|v| v * v).sum::<f64>();
        }
        (s, sq)
    };
    let (sx, qx) = stats(&phi_x);
    let (sy, qy) = stats(&phi_y);
    let nx = bx as f64;
    let ny = by as f64;
    let sxx: f64 = sx.iter().map(|v| v * v).sum();
    let syy: f64 = sy.iter().map(|v| v * v).sum();
    let sxy: f64 = sx.iter().zip(sy.iter()).map(|(a, b)| a * b).sum();
    Ok((sxx - qx) / (nx * (nx - 1.0)) - 2.0 * sxy / (nx * ny) + (syy - qy) / (ny * (ny - 1.0)))
}

/// Low-rank biased MMD² and its exact gradient with respect to the x-paths
/// (the generator sample in training): ∂/∂φ(x_i) = (2/bx)(mean Φx − mean Φy),
/// mapped to path space through the feature map's backward.
pub fn try_mmd2_lowrank_with_grad<F: LowRankFeatures + ?Sized>(
    f: &F,
    x: &PathBatch<'_>,
    y: &PathBatch<'_>,
) -> Result<(f64, Vec<f64>), SigError> {
    check_mmd_batches(x, y, 1)?;
    let phi_x = f.try_features(x)?;
    let phi_y = f.try_features(y)?;
    let r = f.rank();
    let (bx, by) = (x.batch(), y.batch());
    let mx = feature_mean(&phi_x, bx, r);
    let my = feature_mean(&phi_y, by, r);
    let value = mx
        .iter()
        .zip(my.iter())
        .map(|(a, b)| (a - b) * (a - b))
        .sum();
    let scale = 2.0 / bx as f64;
    let row: Vec<f64> = mx
        .iter()
        .zip(my.iter())
        .map(|(a, b)| scale * (a - b))
        .collect();
    let mut grad_phi = vec![0.0; bx * r];
    for chunk in grad_phi.chunks_mut(r) {
        chunk.copy_from_slice(&row);
    }
    let grad = f.try_features_vjp(x, &grad_phi)?;
    Ok((value, grad))
}

/// Ridge regression in low-rank feature space — the O(n·r²) counterpart of
/// [`KernelRidge`](crate::kernel::KernelRidge): solves the r×r normal
/// equations (ΦᵀΦ + λ·tr(ΦᵀΦ)/r·I)·w = Φᵀy instead of the n×n dual system.
/// Fit via [`KernelRidge::try_fit_lowrank`](crate::kernel::KernelRidge::try_fit_lowrank)
/// or a [`KrrLowRank`](crate::engine::OpSpec::KrrLowRank) plan.
pub struct LowRankRidge {
    map: FeatureMap,
    weights: Vec<f64>,
}

impl LowRankRidge {
    /// Fit on a (possibly ragged) training batch with targets `[n]`. λ is
    /// relative to the mean feature-Gram diagonal (same convention as the
    /// exact KRR) and escalates tenfold until the system is numerically PD.
    pub fn try_fit(
        map: FeatureMap,
        paths: &PathBatch<'_>,
        y: &[f64],
        lambda: f64,
    ) -> Result<LowRankRidge, SigError> {
        let n = paths.batch();
        if y.len() != n {
            return Err(SigError::CotangentLen {
                expected: n,
                got: y.len(),
            });
        }
        if n == 0 {
            return Err(SigError::InsufficientBatch { need: 1, got: 0 });
        }
        if !(lambda > 0.0) {
            return Err(SigError::NonFinite("ridge λ must be positive"));
        }
        let r = map.rank();
        let phi = map.try_features(paths)?;
        if !phi.iter().all(|v| v.is_finite()) {
            return Err(SigError::NonFinite("low-rank feature matrix overflowed f64"));
        }
        // Normal equations: ΦᵀΦ [r, r] and Φᵀy [r].
        let mut ata = vec![0.0; r * r];
        let mut atb = vec![0.0; r];
        for (row, &t) in phi.chunks(r).zip(y.iter()) {
            for i in 0..r {
                let ri = row[i];
                atb[i] += ri * t;
                for j in 0..=i {
                    ata[i * r + j] += ri * row[j];
                }
            }
        }
        for i in 0..r {
            for j in i + 1..r {
                ata[i * r + j] = ata[j * r + i];
            }
        }
        let mean_diag = (0..r).map(|i| ata[i * r + i]).sum::<f64>() / r as f64;
        let mut lam = lambda * mean_diag.max(1e-300);
        let mut attempt = 0;
        let weights = loop {
            let mut sys = ata.clone();
            for i in 0..r {
                sys[i * r + i] += lam;
            }
            match solve_spd(&sys, r, &atb) {
                Some(w) => break w,
                None => {
                    attempt += 1;
                    if attempt > 8 {
                        return Err(SigError::NonFinite(
                            "low-rank ridge system not positive definite even after escalating λ",
                        ));
                    }
                    lam *= 10.0;
                }
            }
        };
        Ok(LowRankRidge { map, weights })
    }

    /// The fitted feature-space weights `[rank]`.
    pub fn weights(&self) -> &[f64] {
        &self.weights
    }

    /// The feature map the model predicts with.
    pub fn feature_map(&self) -> &FeatureMap {
        &self.map
    }

    /// Predict for a (possibly ragged) batch of query paths: Φ(q)·w.
    pub fn try_predict(&self, paths: &PathBatch<'_>) -> Result<Vec<f64>, SigError> {
        let phi = self.map.try_features(paths)?;
        let r = self.map.rank();
        Ok(phi
            .chunks(r)
            .map(|row| row.iter().zip(&self.weights).map(|(p, w)| p * w).sum())
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::{try_mmd2, try_mmd2_unbiased};
    use crate::util::rng::Rng;

    #[test]
    fn landmark_sampling_is_seeded_distinct_and_capped() {
        let a = sample_landmark_indices(10, 4, 3);
        let b = sample_landmark_indices(10, 4, 3);
        assert_eq!(a, b);
        assert_eq!(a.len(), 4);
        let mut sorted = a.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 4, "indices must be distinct: {a:?}");
        // rank >= batch: every index exactly once.
        let mut all = sample_landmark_indices(5, 99, 1);
        all.sort_unstable();
        assert_eq!(all, vec![0, 1, 2, 3, 4]);
        assert!(sample_landmark_indices(0, 3, 1).is_empty());
    }

    /// The low-rank estimators agree with the exact estimators evaluated on
    /// the low-rank Gram ΦΦᵀ (internal consistency of the O(n·r) formulas).
    #[test]
    fn mmd_formulas_match_explicit_lowrank_gram() {
        let mut rng = Rng::new(520);
        let (bx, by, l, d) = (4, 5, 5, 2);
        let x = rng.brownian_batch(bx, l, d, 0.3);
        let y = rng.brownian_batch(by, l, d, 0.4);
        let xb = PathBatch::uniform(&x, bx, l, d).unwrap();
        let yb = PathBatch::uniform(&y, by, l, d).unwrap();
        let opts = KernelOptions::default();
        let map = FeatureMap::try_build(&LowRankSpec::nystrom(3, 9), &opts, &yb).unwrap();
        let r = map.rank();
        let phi_x = map.try_features(&xb).unwrap();
        let phi_y = map.try_features(&yb).unwrap();
        let gram = |a: &[f64], ba: usize, b: &[f64], bb: usize| -> Vec<f64> {
            let mut g = vec![0.0; ba * bb];
            gemm_nt(ba, r, bb, a, b, &mut g);
            g
        };
        let kxx = gram(&phi_x, bx, &phi_x, bx);
        let kxy = gram(&phi_x, bx, &phi_y, by);
        let kyy = gram(&phi_y, by, &phi_y, by);
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        let want_biased = mean(&kxx) - 2.0 * mean(&kxy) + mean(&kyy);
        let got_biased = try_mmd2_lowrank(&map, &xb, &yb).unwrap();
        assert!((got_biased - want_biased).abs() < 1e-12);
        let off = |v: &[f64], b: usize| {
            let tot: f64 = v.iter().sum();
            let diag: f64 = (0..b).map(|i| v[i * b + i]).sum();
            (tot - diag) / (b * (b - 1)) as f64
        };
        let want_unbiased = off(&kxx, bx) - 2.0 * mean(&kxy) + off(&kyy, by);
        let got_unbiased = try_mmd2_lowrank_unbiased(&map, &xb, &yb).unwrap();
        assert!((got_unbiased - want_unbiased).abs() < 1e-12);
        // And the explicit Gram entry point agrees with the manual GEMM.
        assert_eq!(try_gram_lowrank(&map, &xb, &yb).unwrap(), kxy);
    }

    /// Full-rank Nyström over the pooled corpus reproduces the exact MMD²
    /// estimators (both kinds).
    #[test]
    fn full_rank_mmd_matches_exact() {
        let mut rng = Rng::new(521);
        let (b, l, d) = (4, 5, 2);
        let x = rng.brownian_batch(b, l, d, 0.3);
        let y = rng.brownian_batch(b, l, d, 0.5);
        let xb = PathBatch::uniform(&x, b, l, d).unwrap();
        let yb = PathBatch::uniform(&y, b, l, d).unwrap();
        let opts = KernelOptions::default();
        let mut pooled = x.clone();
        pooled.extend_from_slice(&y);
        let zb = PathBatch::uniform(&pooled, 2 * b, l, d).unwrap();
        let f = NystromFeatures::try_new(&zb, &opts).unwrap();
        let exact_b = try_mmd2(&xb, &yb, &opts).unwrap();
        let exact_u = try_mmd2_unbiased(&xb, &yb, &opts).unwrap();
        let lr_b = try_mmd2_lowrank(&f, &xb, &yb).unwrap();
        let lr_u = try_mmd2_lowrank_unbiased(&f, &xb, &yb).unwrap();
        assert!((exact_b - lr_b).abs() < 1e-8, "{exact_b} vs {lr_b}");
        assert!((exact_u - lr_u).abs() < 1e-8, "{exact_u} vs {lr_u}");
    }

    #[test]
    fn lowrank_ridge_fits_training_targets() {
        let mut rng = Rng::new(522);
        let (n, l, d) = (12, 6, 2);
        let mut paths = Vec::new();
        let mut y = Vec::new();
        for _ in 0..n {
            let p = rng.brownian_path(l, d, 0.3);
            // Endpoint displacement norm: learnable from signatures.
            let mut disp = 0.0;
            for j in 0..d {
                let dj = p[(l - 1) * d + j] - p[j];
                disp += dj * dj;
            }
            y.push(disp.sqrt());
            paths.extend(p);
        }
        let pb = PathBatch::uniform(&paths, n, l, d).unwrap();
        let opts = KernelOptions::default();
        // Full-rank Nyström on the training set: behaves like exact KRR.
        let map = FeatureMap::try_build(&LowRankSpec::nystrom(n, 4), &opts, &pb).unwrap();
        let model = LowRankRidge::try_fit(map, &pb, &y, 1e-8).unwrap();
        let pred = model.try_predict(&pb).unwrap();
        let err = crate::util::linalg::rel_err(&pred, &y);
        assert!(err < 1e-3, "train rel err {err}");
        assert_eq!(model.weights().len(), model.feature_map().rank());
    }

    #[test]
    fn lowrank_ridge_rejects_bad_inputs() {
        let data = [0.0, 0.0, 1.0, 1.0];
        let pb = PathBatch::uniform(&data, 1, 2, 2).unwrap();
        let opts = KernelOptions::default();
        let map = FeatureMap::try_build(&LowRankSpec::nystrom(1, 0), &opts, &pb).unwrap();
        assert!(matches!(
            LowRankRidge::try_fit(map, &pb, &[1.0, 2.0], 1e-3),
            Err(SigError::CotangentLen { .. })
        ));
        let map = FeatureMap::try_build(&LowRankSpec::nystrom(1, 0), &opts, &pb).unwrap();
        assert!(matches!(
            LowRankRidge::try_fit(map, &pb, &[1.0], 0.0),
            Err(SigError::NonFinite(_))
        ));
    }
}
