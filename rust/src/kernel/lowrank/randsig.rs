//! Random truncated-signature features for the signature kernel.
//!
//! The truncated signature S_N(x) is an explicit (if wide) feature map whose
//! inner product approximates the signature kernel; a seeded random sketch
//! P ∈ R^{r × slen} with E[PᵀP] = I compresses it to rank r:
//!
//!   φ(x) = P · S_N(x),   E[φ(x)·φ(y)] = ⟨S_N(x), S_N(y)⟩ ≈ k(x, y).
//!
//! Unlike Nyström, the map depends only on (seed, shape) — not on any data —
//! so gradients through it are exact with no frozen-landmark caveat, and a
//! feature row costs one signature sweep plus an r × slen GEMV: O(n·r·slen)
//! for the whole matrix, with no kernel PDE solves at all.

use crate::kernel::lowrank::LowRankFeatures;
use crate::path::{ExecOptions, PathBatch, SigError, SigOptions};
use crate::sig::{try_batch_signature, try_batch_signature_vjp, try_sig_length};
use crate::util::linalg::{gemm, gemm_nt};
use crate::util::rng::Rng;

/// Distribution of the sketch entries (both scaled by 1/√rank so that
/// E[PᵀP] = I).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SketchKind {
    /// i.i.d. N(0, 1/rank).
    Gaussian,
    /// i.i.d. ±1/√rank (cheaper to sample, bounded).
    Rademacher,
}

/// Hard cap on sketch matrix entries (2^27 f64s = 1 GiB) — wire/CLI-reachable
/// allocation guard. Engine plan compilation checks the same bound
/// (`validate_lowrank_spec`), so a spec that compiles cannot fail here.
pub(crate) const MAX_SKETCH: usize = 1 << 27;

/// Seeded random projection of truncated signatures.
pub struct RandomSigFeatures {
    sig_opts: SigOptions,
    dim: usize,
    slen: usize,
    rank: usize,
    /// `[rank, slen]` row-major.
    sketch: Vec<f64>,
}

impl RandomSigFeatures {
    /// Build the map for paths of dimension `dim`, signatures truncated at
    /// `depth`, projected to `rank` features with the seeded sketch. `exec`
    /// carries the transform/parallel policy the signature sweep should use.
    pub fn try_new(
        dim: usize,
        depth: usize,
        rank: usize,
        seed: u64,
        kind: SketchKind,
        exec: ExecOptions,
    ) -> Result<RandomSigFeatures, SigError> {
        if rank == 0 {
            return Err(SigError::Invalid("low-rank feature rank must be at least 1"));
        }
        let out_dim = exec.transform.out_dim(dim);
        let slen = try_sig_length(out_dim, depth)?;
        let total = rank
            .checked_mul(slen)
            .filter(|&t| t <= MAX_SKETCH)
            .ok_or(SigError::TooLarge("random signature sketch"))?;
        let mut sketch = vec![0.0; total];
        let mut rng = Rng::new(seed);
        let scale = 1.0 / (rank as f64).sqrt();
        match kind {
            SketchKind::Gaussian => {
                for v in sketch.iter_mut() {
                    *v = scale * rng.normal();
                }
            }
            SketchKind::Rademacher => {
                for v in sketch.iter_mut() {
                    *v = if rng.next_u64() & 1 == 0 { scale } else { -scale };
                }
            }
        }
        let mut sig_opts = SigOptions::new(depth);
        sig_opts.exec = exec;
        Ok(RandomSigFeatures {
            sig_opts,
            dim,
            slen,
            rank,
            sketch,
        })
    }

    /// Flat length of the underlying truncated signature.
    pub fn sig_length(&self) -> usize {
        self.slen
    }

    fn check_dim(&self, x: &PathBatch<'_>) -> Result<(), SigError> {
        if x.dim() != self.dim {
            return Err(SigError::DimMismatch {
                left: x.dim(),
                right: self.dim,
            });
        }
        Ok(())
    }
}

impl LowRankFeatures for RandomSigFeatures {
    fn rank(&self) -> usize {
        self.rank
    }

    /// Φ = S·Pᵀ with S the `[batch, slen]` truncated signatures.
    fn try_features(&self, x: &PathBatch<'_>) -> Result<Vec<f64>, SigError> {
        self.check_dim(x)?;
        let sigs = try_batch_signature(x, &self.sig_opts)?;
        let b = x.batch();
        let mut phi = vec![0.0; b * self.rank];
        gemm_nt(b, self.slen, self.rank, &sigs, &self.sketch, &mut phi);
        Ok(phi)
    }

    /// ∂F/∂S = Ḡ·P, then the exact time-reversed signature backward
    /// ([`sig::backward`](crate::sig::backward)) maps it to path space.
    fn try_features_vjp(
        &self,
        x: &PathBatch<'_>,
        grad_phi: &[f64],
    ) -> Result<Vec<f64>, SigError> {
        self.check_dim(x)?;
        let b = x.batch();
        let expected = b * self.rank;
        if grad_phi.len() != expected {
            return Err(SigError::CotangentLen {
                expected,
                got: grad_phi.len(),
            });
        }
        let mut grad_sigs = vec![0.0; b * self.slen];
        gemm(b, self.rank, self.slen, grad_phi, &self.sketch, &mut grad_sigs);
        try_batch_signature_vjp(x, &grad_sigs, &self.sig_opts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::lowrank::try_gram_lowrank;
    use crate::kernel::{try_gram, KernelOptions};
    use crate::util::linalg::rel_err;
    use crate::util::rng::Rng;

    #[test]
    fn same_seed_is_deterministic_different_seed_is_not() {
        let exec = ExecOptions::default();
        let a = RandomSigFeatures::try_new(2, 3, 8, 7, SketchKind::Gaussian, exec).unwrap();
        let b = RandomSigFeatures::try_new(2, 3, 8, 7, SketchKind::Gaussian, exec).unwrap();
        let c = RandomSigFeatures::try_new(2, 3, 8, 8, SketchKind::Gaussian, exec).unwrap();
        assert_eq!(a.sketch, b.sketch);
        assert_ne!(a.sketch, c.sketch);
    }

    /// With a large rank the sketched Gram concentrates on the truncated
    /// signature Gram, which itself approximates the kernel for small paths.
    #[test]
    fn sketched_gram_approximates_exact_gram() {
        let mut rng = Rng::new(510);
        let (n, l, d) = (5, 4, 2);
        let data = rng.brownian_batch(n, l, d, 0.2);
        let xb = PathBatch::uniform(&data, n, l, d).unwrap();
        let exact = try_gram(&xb, &xb, &KernelOptions::default().dyadic(4, 4)).unwrap();
        let f = RandomSigFeatures::try_new(
            d,
            6,
            4096,
            11,
            SketchKind::Rademacher,
            ExecOptions::default(),
        )
        .unwrap();
        let approx = try_gram_lowrank(&f, &xb, &xb).unwrap();
        let err = rel_err(&approx, &exact);
        assert!(err < 0.05, "rel err {err}");
    }

    #[test]
    fn hostile_shapes_error_cleanly() {
        let exec = ExecOptions::default();
        assert!(matches!(
            RandomSigFeatures::try_new(2, 3, 0, 1, SketchKind::Gaussian, exec),
            Err(SigError::Invalid(_))
        ));
        assert!(matches!(
            RandomSigFeatures::try_new(2, 0, 4, 1, SketchKind::Gaussian, exec),
            Err(SigError::ZeroDepth)
        ));
        assert!(matches!(
            RandomSigFeatures::try_new(64, 64, 1 << 20, 1, SketchKind::Gaussian, exec),
            Err(SigError::TooLarge(_))
        ));
    }
}
