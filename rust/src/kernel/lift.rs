//! Static-kernel lifts for signature kernels (the sigkernel package's
//! "static kernel" option): instead of the Euclidean inner product
//! ⟨dx_i, dy_j⟩, drive the Goursat PDE with the second-order finite
//! difference of a static kernel κ on path *values*:
//!
//!   Δ^κ[i,j] = κ(x_{i+1}, y_{j+1}) − κ(x_{i+1}, y_j)
//!            − κ(x_i,     y_{j+1}) + κ(x_i,     y_j),
//!
//! which equals ⟨dx_i, dy_j⟩ exactly for the linear kernel and lifts the
//! paths into an RBF feature space otherwise — the standard trick for
//! high-dimensional state spaces.

use crate::kernel::solver::solve_pde;

/// Static kernel choices.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum StaticKernel {
    /// κ(u, v) = ⟨u, v⟩ — recovers the plain signature kernel.
    Linear,
    /// κ(u, v) = exp(−‖u−v‖² / (2σ²)).
    Rbf { sigma: f64 },
}

impl StaticKernel {
    #[inline]
    pub fn eval(&self, u: &[f64], v: &[f64]) -> f64 {
        match self {
            StaticKernel::Linear => crate::util::linalg::dot(u, v),
            StaticKernel::Rbf { sigma } => {
                let mut d2 = 0.0;
                for (a, b) in u.iter().zip(v.iter()) {
                    d2 += (a - b) * (a - b);
                }
                (-d2 / (2.0 * sigma * sigma)).exp()
            }
        }
    }
}

/// Δ^κ matrix from the static-kernel second difference: `[lx-1, ly-1]`.
pub fn lifted_delta(
    x: &[f64],
    y: &[f64],
    lx: usize,
    ly: usize,
    dim: usize,
    kappa: StaticKernel,
) -> Vec<f64> {
    assert_eq!(x.len(), lx * dim);
    assert_eq!(y.len(), ly * dim);
    // Gram of point values, then second difference. One pass, O(lx·ly·d).
    let mut g = vec![0.0; lx * ly];
    for i in 0..lx {
        for j in 0..ly {
            g[i * ly + j] = kappa.eval(&x[i * dim..(i + 1) * dim], &y[j * dim..(j + 1) * dim]);
        }
    }
    let m = lx - 1;
    let n = ly - 1;
    let mut delta = vec![0.0; m * n];
    for i in 0..m {
        for j in 0..n {
            delta[i * n + j] = g[(i + 1) * ly + (j + 1)] - g[(i + 1) * ly + j]
                - g[i * ly + (j + 1)]
                + g[i * ly + j];
        }
    }
    delta
}

/// Signature kernel with a static-kernel lift.
pub fn sig_kernel_lifted(
    x: &[f64],
    y: &[f64],
    lx: usize,
    ly: usize,
    dim: usize,
    kappa: StaticKernel,
    lam1: u32,
    lam2: u32,
) -> f64 {
    let delta = lifted_delta(x, y, lx, ly, dim, kappa);
    solve_pde(&delta, lx - 1, ly - 1, lam1, lam2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::{sig_kernel, KernelOptions};
    use crate::util::prop::check;

    #[test]
    fn linear_lift_recovers_plain_kernel() {
        check("linear lift == plain kernel", 15, |g| {
            let lx = g.usize_in(2, 10);
            let ly = g.usize_in(2, 10);
            let d = g.usize_in(1, 3);
            let x = g.path(lx, d, 0.4);
            let y = g.path(ly, d, 0.4);
            let k1 = sig_kernel_lifted(&x, &y, lx, ly, d, StaticKernel::Linear, 1, 1);
            let k2 = sig_kernel(&x, &y, lx, ly, d, &KernelOptions::default().dyadic(1, 1));
            assert!((k1 - k2).abs() < 1e-10 * k2.abs().max(1.0));
        });
    }

    #[test]
    fn rbf_lift_is_symmetric_and_bounded_by_selfkernels() {
        check("rbf lift symmetry", 10, |g| {
            let l = g.usize_in(2, 8);
            let d = g.usize_in(1, 3);
            let x = g.path(l, d, 0.5);
            let y = g.path(l, d, 0.5);
            let kap = StaticKernel::Rbf { sigma: 1.0 };
            let kxy = sig_kernel_lifted(&x, &y, l, l, d, kap, 0, 0);
            let kyx = sig_kernel_lifted(&y, &x, l, l, d, kap, 0, 0);
            assert!((kxy - kyx).abs() < 1e-10);
            // Cauchy–Schwarz in the lifted RKHS.
            let kxx = sig_kernel_lifted(&x, &x, l, l, d, kap, 0, 0);
            let kyy = sig_kernel_lifted(&y, &y, l, l, d, kap, 0, 0);
            assert!(kxy * kxy <= kxx * kyy * (1.0 + 1e-6), "CS violated");
        });
    }

    #[test]
    fn rbf_large_sigma_approaches_degenerate_kernel() {
        // σ → ∞: κ → 1 everywhere ⇒ Δ^κ → 0 ⇒ k → 1.
        let mut rng = crate::util::rng::Rng::new(81);
        let x = rng.brownian_path(6, 2, 0.5);
        let y = rng.brownian_path(6, 2, 0.5);
        let k = sig_kernel_lifted(&x, &y, 6, 6, 2, StaticKernel::Rbf { sigma: 1e6 }, 0, 0);
        assert!((k - 1.0).abs() < 1e-6, "k = {k}");
    }

    #[test]
    fn rbf_kernel_scale_invariance_breaks_linearity() {
        // The RBF lift must genuinely differ from the linear kernel.
        let mut rng = crate::util::rng::Rng::new(82);
        let x = rng.brownian_path(6, 2, 0.8);
        let y = rng.brownian_path(6, 2, 0.8);
        let kl = sig_kernel_lifted(&x, &y, 6, 6, 2, StaticKernel::Linear, 0, 0);
        let kr = sig_kernel_lifted(&x, &y, 6, 6, 2, StaticKernel::Rbf { sigma: 0.5 }, 0, 0);
        assert!((kl - kr).abs() > 1e-6);
    }
}
