//! Lane-batched multi-pair PDE engine: one Goursat sweep advances W
//! independent kernels.
//!
//! The CPU row sweep ([`super::solver::solve_pde_with`]) is memory-bound
//! with a serial `k[s,t-1] → k[s,t]` dependency, and vectorising *within* a
//! single PDE was tried and **reverted** — the two-pass restructure of the
//! inner loop is ~20% slower on this testbed (extra coefficient/cterm
//! memory traffic outweighs the shorter dependency chain; see the NOTE in
//! `solver.rs` and the `pde_sweep/*` rows of the ablations bench). KSig and
//! the paper's GPU scheme get their throughput the other way: batching
//! *across pairs*. Every (x, y) pair in a Gram tile runs the exact same
//! instruction sequence, so W pairs can ride the SIMD lanes of one sweep
//! with **zero cross-lane dependencies** — and bit-identical results to the
//! scalar solver, since each lane performs the same FP ops in the same
//! order.
//!
//! Three layers:
//!
//! * [`solve_pde_lanes`] — the structure-of-arrays solver: W independent
//!   grids advance per inner-loop iteration over interleaved `[cols+1, W]`
//!   row buffers. W is a const generic fixed to 4 or 8 and the arithmetic
//!   is plain fixed-size-array code (no `std::simd`, no `unsafe`), so LLVM
//!   autovectorises the per-lane FMA block.
//! * [`delta_block_lanes`] — the tile-level Δ precompute: the W pairs of a
//!   lane group share one x row, so their increment matrices stack into a
//!   **single GEMM** `dx · [dy_0; …; dy_{W-1}]ᵀ` whose output *is* the
//!   lane-interleaved `[m, W, n]` delta block — one GEMM per lane group
//!   instead of one per pair ([`gemm_nt`] computes every entry as an
//!   independent fixed-order dot product, so stacking is bit-neutral).
//! * [`solve_gram_row`] — the dispatcher every Gram producer calls: groups
//!   a row's columns by shape class (ragged batches are sorted by length —
//!   unstable, allocation-free — so equal-length paths form runs), packs
//!   full lane groups of `width`, and finishes the remainder with the
//!   scalar per-pair path.
//!
//! **Bit-identity.** Lane w of a group evaluates exactly the scalar
//! recurrence `v = (k_left + prev[t+1])·A(p) − prev[t]·B(p)` on exactly the
//! scalar Δ values, in the same order — lane batching is pure schedule, so
//! Gram/MMD²/corpus results are bit-for-bit identical to the scalar path
//! for every width (property-tested in `tests/props_lanes.rs`). The
//! [`SolverKind::Blocked`](crate::kernel::SolverKind::Blocked) schedule is
//! served scalar (it models the GPU dataflow; lane-batching it would be
//! redundant with the row schedule's lanes).
//!
//! **Cost model.** A lane group amortises the sweep's loop control and
//! turns W dependent scalar FMA chains into W-wide independent ones, but
//! needs W same-shape pairs per group: uniform batches default to W = 8,
//! ragged batches to W = 4 (equal-length runs are shorter), and
//! `PYSIGLIB_LANES` overrides both (`0` = scalar, values snap to 4 or 8).
//! Pairs that do not fill a group fall back to the scalar path and are
//! counted in [`stats`] as the scalar remainder.

use std::ops::Range;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::kernel::delta::{delta_matrix_into, increments_into};
use crate::kernel::{KernelOptions, SolverKind};
use crate::path::PathBatch;
use crate::transforms::Transform;
use crate::util::linalg::gemm_nt;

/// The supported lane widths (const-generic instantiations of
/// [`solve_pde_lanes`]).
pub const LANE_WIDTHS: [usize; 2] = [4, 8];

// ---------------------------------------------------------------------------
// Occupancy counters (process-wide, monotonic) — mirrored into the serving
// metrics snapshot so tile/lane occupancy is observable in production.

static TILES_EXECUTED: AtomicU64 = AtomicU64::new(0);
static LANE_GROUPS: AtomicU64 = AtomicU64::new(0);
static SCALAR_PAIRS: AtomicU64 = AtomicU64::new(0);

/// Snapshot of the lane engine's occupancy counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LaneStats {
    /// Gram tiles executed by the [`TileScheduler`](crate::corpus::TileScheduler).
    pub tiles_executed: u64,
    /// Full lane groups dispatched through [`solve_pde_lanes`].
    pub lane_groups: u64,
    /// Pairs solved by the scalar remainder while lane batching was active
    /// (degenerate pairs and lanes-off runs are not counted).
    pub scalar_pairs: u64,
}

/// Current occupancy counters (monotonic across the process lifetime).
pub fn stats() -> LaneStats {
    LaneStats {
        tiles_executed: TILES_EXECUTED.load(Ordering::Relaxed),
        lane_groups: LANE_GROUPS.load(Ordering::Relaxed),
        scalar_pairs: SCALAR_PAIRS.load(Ordering::Relaxed),
    }
}

/// Record one executed Gram tile (called by the tile scheduler).
pub(crate) fn count_tile() {
    TILES_EXECUTED.fetch_add(1, Ordering::Relaxed);
}

// ---------------------------------------------------------------------------
// Lane-width resolution.

/// Snap a requested width to a supported one: `0`/`1` mean scalar, other
/// values round to the nearest of [`LANE_WIDTHS`].
pub fn normalize_lane_width(w: usize) -> usize {
    if w <= 1 {
        0
    } else if w <= 5 {
        4
    } else {
        8
    }
}

/// The `PYSIGLIB_LANES` override, normalised; `None` when unset/unparsable.
/// Read once per process and cached (see [`crate::config::env`]).
pub fn lane_width_override() -> Option<usize> {
    crate::config::env::lanes().map(normalize_lane_width)
}

/// Default width for a shape profile: uniform classes fill W = 8 groups
/// whenever at least 8 pairs share a tile row; ragged classes use W = 4
/// because equal-length runs are shorter.
pub fn default_lane_width(uniform: bool) -> usize {
    if uniform {
        8
    } else {
        4
    }
}

/// Resolved lane width for a shape profile: the environment override wins,
/// else the per-class default. Read at plan / scheduler construction time
/// (not per execute), so a compiled plan's schedule is stable.
pub fn lane_width_for(uniform: bool) -> usize {
    lane_width_override().unwrap_or_else(|| default_lane_width(uniform))
}

// ---------------------------------------------------------------------------
// The SoA solver.

/// Solve W independent Goursat PDEs in one sweep.
///
/// `delta` is the lane-interleaved `[m, W, n]` block (lane w's Δ row `s'`
/// starts at `delta[(s'·W + w)·n]`) — exactly the layout
/// [`delta_block_lanes`] produces. `prev`/`cur` are caller-provided
/// interleaved `[cols+1, W]` row buffers, resized in place (the engine's
/// Gram plans route them through the workspace arena so the steady state
/// allocates nothing). Returns the W terminal values k(1,1).
///
/// Each lane evaluates the scalar recurrence of
/// [`solve_pde_with`](super::solver::solve_pde_with) on its own Δ values in
/// the same order, so lane results are bit-identical to W scalar solves.
/// The dyadic-run coefficient hoist matches the scalar solver's: A(p)/B(p)
/// are computed once per `2^λ2` run.
pub fn solve_pde_lanes<const W: usize>(
    delta: &[f64],
    m: usize,
    n: usize,
    lam1: u32,
    lam2: u32,
    prev: &mut Vec<f64>,
    cur: &mut Vec<f64>,
) -> [f64; W] {
    assert_eq!(delta.len(), m * W * n);
    let rows = m << lam1;
    let cols = n << lam2;
    let scale = 1.0 / (1u64 << (lam1 + lam2)) as f64;
    prev.clear();
    prev.resize((cols + 1) * W, 1.0);
    cur.clear();
    cur.resize((cols + 1) * W, 1.0);
    let run = 1usize << lam2;
    for s in 0..rows {
        let dbase = (s >> lam1) * W * n;
        cur[..W].fill(1.0);
        let mut k_left = [1.0f64; W];
        let mut a = [0.0f64; W];
        let mut b = [0.0f64; W];
        let mut t = 0usize;
        for tc in 0..n {
            for w in 0..W {
                let p = delta[dbase + w * n + tc] * scale;
                let p2 = p * p * (1.0 / 12.0);
                a[w] = 1.0 + 0.5 * p + p2;
                b[w] = 1.0 - p2;
            }
            for _ in 0..run {
                // The W-wide FMA block: no cross-lane dependency, contiguous
                // interleaved loads/stores — the autovectorisation target.
                for w in 0..W {
                    let v = (k_left[w] + prev[(t + 1) * W + w]) * a[w] - prev[t * W + w] * b[w];
                    cur[(t + 1) * W + w] = v;
                    k_left[w] = v;
                }
                t += 1;
            }
        }
        std::mem::swap(prev, cur);
    }
    let mut out = [0.0; W];
    out.copy_from_slice(&prev[cols * W..(cols + 1) * W]);
    out
}

// ---------------------------------------------------------------------------
// Tile-level Δ precompute.

/// Pack the Δ blocks of a lane group — one x path against W same-length y
/// paths — into the lane-interleaved `[m_t, W, n_t]` layout with a single
/// stacked GEMM.
///
/// The W increment matrices stack as `dys = [dy_0; …; dy_{W-1}]`
/// (`[W·n, dim]`), and `dx · dysᵀ` lands row-major as `[m, W·n]` — which
/// *is* `[m, W, n]`: lane w's Δ row i occupies `out[(i·W + w)·n ..]`.
/// Transforms are fused exactly as in
/// [`delta_matrix_into`](crate::kernel::delta::delta_matrix_into): the
/// time-augmentation shift is a constant add, lead-lag expands each lane's
/// base block by increment parity. Returns the transformed `(rows, cols)`
/// per lane. Every lane's entries are bit-identical to the per-pair
/// precompute ([`gemm_nt`] computes each entry as an independent
/// fixed-order dot product).
///
/// Scratch: `dx` is `[(lx−1)·dim]`, `dys` is `[W·(ly−1)·dim]`, `base` is
/// `[(lx−1)·W·(ly−1)]` for the lead-lag transforms (may be empty
/// otherwise), `out` holds `rows·W·cols` of the transformed block; all may
/// be larger than needed.
#[allow(clippy::too_many_arguments)]
pub fn delta_block_lanes<const W: usize>(
    x: &[f64],
    lx: usize,
    ys: &[&[f64]; W],
    ly: usize,
    dim: usize,
    transform: Transform,
    dx: &mut [f64],
    dys: &mut [f64],
    base: &mut [f64],
    out: &mut [f64],
) -> (usize, usize) {
    let m = lx - 1;
    let n = ly - 1;
    increments_into(x, lx, dim, &mut dx[..m * dim]);
    for (w, y) in ys.iter().enumerate() {
        increments_into(y, ly, dim, &mut dys[w * n * dim..(w + 1) * n * dim]);
    }
    match transform {
        Transform::None | Transform::TimeAug => {
            let out = &mut out[..m * W * n];
            gemm_nt(m, dim, W * n, &dx[..m * dim], &dys[..W * n * dim], out);
            if transform == Transform::TimeAug {
                let shift = (1.0 / m as f64) * (1.0 / n as f64);
                for v in out.iter_mut() {
                    *v += shift;
                }
            }
            (m, n)
        }
        Transform::LeadLag | Transform::LeadLagTimeAug => {
            let base = &mut base[..m * W * n];
            gemm_nt(m, dim, W * n, &dx[..m * dim], &dys[..W * n * dim], base);
            let rows = 2 * lx - 2;
            let cols = 2 * ly - 2;
            let shift = if transform == Transform::LeadLagTimeAug {
                (1.0 / rows as f64) * (1.0 / cols as f64)
            } else {
                0.0
            };
            let out = &mut out[..rows * W * cols];
            out.fill(shift);
            for a in 0..rows {
                for w in 0..W {
                    let orow = &mut out[(a * W + w) * cols..(a * W + w + 1) * cols];
                    let brow = &base[((a / 2) * W + w) * n..((a / 2) * W + w + 1) * n];
                    for (bcol, ov) in orow.iter_mut().enumerate() {
                        if a % 2 == bcol % 2 {
                            *ov += brow[bcol / 2];
                        }
                    }
                }
            }
            (rows, cols)
        }
    }
}

// ---------------------------------------------------------------------------
// Per-worker scratch.

/// Per-worker scratch for lane-batched Gram rows: increment buffers, the
/// lane-interleaved Δ block, the two interleaved solver rows and the
/// column-grouping index. Plain growable buffers here ([`ensure`] grows
/// them on demand for the tile scheduler); the engine's Gram plans assemble
/// the same struct from arena-checked-out buffers sized at worker start, so
/// `ensure` never grows there and the steady state stays allocation-free.
///
/// [`ensure`]: LaneScratch::ensure
#[derive(Default)]
pub struct LaneScratch {
    /// `[(lx−1)·dim]` raw x increments.
    pub dx: Vec<f64>,
    /// `[W·(ly−1)·dim]` stacked y increments (its `[..(ly−1)·dim]` prefix
    /// doubles as the scalar path's dy scratch).
    pub dys: Vec<f64>,
    /// `[(lx−1)·W·(ly−1)]` base block for the lead-lag transforms.
    pub base: Vec<f64>,
    /// `[m_t·W·n_t]` lane-interleaved transformed Δ block (its leading
    /// `[m_t·n_t]` doubles as the scalar path's Δ scratch).
    pub delta: Vec<f64>,
    /// Interleaved `[cols+1, W]` solver rows.
    pub prev: Vec<f64>,
    pub cur: Vec<f64>,
    /// Column indices grouped by length (ragged batches).
    pub idx: Vec<usize>,
}

/// Buffer lengths a `(lx, ly, dim, transform, width)` row needs — the one
/// place the scratch-sizing arithmetic lives. [`LaneScratch::ensure`] grows
/// to these per row, and the engine's arena checkout pre-takes them at the
/// batch's maxima (sizes are monotone in `lx`/`ly`, so per-row `ensure`
/// never exceeds the checkout and the zero-allocation steady state holds
/// by construction, not by two hand-synchronized copies of the formulas).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LaneSizes {
    /// Raw x increments `[(lx−1)·dim]`.
    pub dx: usize,
    /// Stacked y increments `[W·(ly−1)·dim]`.
    pub dys: usize,
    /// Lead-lag base block `[(lx−1)·W·(ly−1)]` (0 when unused).
    pub base: usize,
    /// Lane-interleaved transformed Δ block `[m_t·W·n_t]`.
    pub delta: usize,
    /// One interleaved `[cols+1, W]` solver row (`prev` and `cur` each).
    pub row: usize,
}

/// Compute [`LaneSizes`] for a row of `(x: lx) × (y: ly)` pairs at `width`.
pub fn lane_sizes(
    lx: usize,
    ly: usize,
    dim: usize,
    transform: Transform,
    width: usize,
    lam2: u32,
) -> LaneSizes {
    let w = width.max(1);
    let (mi, ni) = (lx.saturating_sub(1), ly.saturating_sub(1));
    let (mt, nt) = if lx < 2 || ly < 2 {
        (0, 0)
    } else {
        (transform.out_len(lx) - 1, transform.out_len(ly) - 1)
    };
    let needs_base = matches!(transform, Transform::LeadLag | Transform::LeadLagTimeAug);
    LaneSizes {
        dx: mi * dim,
        dys: w * ni * dim,
        base: if needs_base { mi * w * ni } else { 0 },
        delta: mt * w * nt,
        row: ((nt << lam2) + 1) * w,
    }
}

impl LaneScratch {
    /// Empty scratch; buffers grow on first use.
    pub fn new() -> LaneScratch {
        LaneScratch::default()
    }

    /// Grow every buffer to [`lane_sizes`] for this row (never shrinks —
    /// arena-provided buffers stay intact).
    pub fn ensure(
        &mut self,
        lx: usize,
        ly: usize,
        dim: usize,
        transform: Transform,
        width: usize,
        lam2: u32,
    ) {
        let s = lane_sizes(lx, ly, dim, transform, width, lam2);
        let grow = |buf: &mut Vec<f64>, len: usize| {
            if buf.len() < len {
                buf.resize(len, 0.0);
            }
        };
        grow(&mut self.dx, s.dx);
        grow(&mut self.dys, s.dys);
        grow(&mut self.base, s.base);
        grow(&mut self.delta, s.delta);
        grow(&mut self.prev, s.row);
        grow(&mut self.cur, s.row);
    }
}

// ---------------------------------------------------------------------------
// The Gram-row dispatcher.

/// Solve one Gram row k(x_i, y_j) for `j ∈ cols` into
/// `out[j − cols.start]`, lane-batched.
///
/// Columns are grouped by shape class: for ragged batches the column
/// indices are sorted by path length (an unstable, allocation-free sort —
/// group composition cannot affect values) so equal-length paths form
/// runs; full groups of `width` are packed
/// ([`delta_block_lanes`]) and solved by [`solve_pde_lanes`], the remainder
/// by the scalar per-pair path (bit-identical by construction, so `width`
/// is pure schedule). `width < 4` — and any
/// [`SolverKind::Blocked`](crate::kernel::SolverKind::Blocked) request —
/// runs fully scalar. Degenerate pairs (either path shorter than 2 points)
/// are the constant 1.
#[allow(clippy::too_many_arguments)]
pub fn solve_gram_row(
    x: &PathBatch<'_>,
    i: usize,
    y: &PathBatch<'_>,
    cols: Range<usize>,
    opts: &KernelOptions,
    width: usize,
    sc: &mut LaneScratch,
    out: &mut [f64],
) {
    assert_eq!(out.len(), cols.len());
    if cols.is_empty() {
        return;
    }
    // Defensive re-snap: the engine and scheduler pass normalized widths,
    // but this is a public entry point and the group solver is instantiated
    // only for W ∈ {4, 8}. Blocked-solver requests drop to the scalar
    // schedule *before* scratch sizing, so they never pay for W-wide
    // buffers they cannot use.
    let width = if opts.solver == SolverKind::Row {
        normalize_lane_width(width)
    } else {
        0
    };
    let lx = x.len_of(i);
    if lx < 2 {
        out.fill(1.0);
        return;
    }
    let my = (cols.start..cols.end).map(|j| y.len_of(j)).max().unwrap_or(0);
    let tr = opts.exec.transform;
    sc.ensure(lx, my, x.dim(), tr, width, opts.dyadic_y);
    let lane_ok = width >= 4;
    if !lane_ok {
        for (slot, j) in out.iter_mut().zip(cols) {
            *slot = scalar_entry(x, i, y, j, opts, sc);
        }
        return;
    }
    // Partition: degenerate columns resolve inline, the rest group by length.
    let mut idx = std::mem::take(&mut sc.idx);
    idx.clear();
    for j in cols.start..cols.end {
        if y.len_of(j) < 2 {
            out[j - cols.start] = 1.0;
        } else {
            idx.push(j);
        }
    }
    if y.uniform_len().is_none() {
        // Unstable sort: allocation-free (a stable sort would heap-allocate
        // scratch on every ragged row strip, breaking the engine's
        // zero-allocation steady state), and group composition cannot
        // affect values — every Gram entry is computed independently.
        idx.sort_unstable_by_key(|&j| y.len_of(j));
    }
    let (mut groups, mut scalars) = (0u64, 0u64);
    let mut pos = 0;
    while pos < idx.len() {
        let ly = y.len_of(idx[pos]);
        let mut end = pos + 1;
        while end < idx.len() && y.len_of(idx[end]) == ly {
            end += 1;
        }
        // Full lane groups of this equal-length run, then the remainder.
        while pos + width <= end {
            let group = &idx[pos..pos + width];
            match width {
                4 => solve_group_into::<4>(x, i, y, group, opts, sc, cols.start, out),
                _ => solve_group_into::<8>(x, i, y, group, opts, sc, cols.start, out),
            }
            groups += 1;
            pos += width;
        }
        while pos < end {
            let j = idx[pos];
            out[j - cols.start] = scalar_entry(x, i, y, j, opts, sc);
            scalars += 1;
            pos += 1;
        }
    }
    sc.idx = idx;
    if groups > 0 {
        LANE_GROUPS.fetch_add(groups, Ordering::Relaxed);
    }
    if scalars > 0 {
        SCALAR_PAIRS.fetch_add(scalars, Ordering::Relaxed);
    }
}

/// One full lane group: pack the Δ block with one stacked GEMM, sweep all W
/// kernels, scatter the terminals to their output slots.
#[allow(clippy::too_many_arguments)]
fn solve_group_into<const W: usize>(
    x: &PathBatch<'_>,
    i: usize,
    y: &PathBatch<'_>,
    group: &[usize],
    opts: &KernelOptions,
    sc: &mut LaneScratch,
    col0: usize,
    out: &mut [f64],
) {
    debug_assert_eq!(group.len(), W);
    let ly = y.len_of(group[0]);
    let ys: [&[f64]; W] = std::array::from_fn(|w| y.values_of(group[w]));
    let LaneScratch {
        dx,
        dys,
        base,
        delta,
        prev,
        cur,
        ..
    } = sc;
    let (mt, nt) = delta_block_lanes::<W>(
        x.values_of(i),
        x.len_of(i),
        &ys,
        ly,
        x.dim(),
        opts.exec.transform,
        dx,
        dys,
        base,
        delta,
    );
    let vals = solve_pde_lanes::<W>(
        &delta[..mt * W * nt],
        mt,
        nt,
        opts.dyadic_x,
        opts.dyadic_y,
        prev,
        cur,
    );
    for (w, &j) in group.iter().enumerate() {
        out[j - col0] = vals[w];
    }
}

/// One scalar Gram entry — exactly the per-pair computation of the
/// pre-lane engine (Δ via [`delta_matrix_into`], then the requested
/// sweep), so lane-off and remainder values match the historical path bit
/// for bit.
fn scalar_entry(
    x: &PathBatch<'_>,
    i: usize,
    y: &PathBatch<'_>,
    j: usize,
    opts: &KernelOptions,
    sc: &mut LaneScratch,
) -> f64 {
    let (lx, ly) = (x.len_of(i), y.len_of(j));
    if lx < 2 || ly < 2 {
        return 1.0;
    }
    let LaneScratch {
        dx,
        dys,
        base,
        delta,
        prev,
        cur,
        ..
    } = sc;
    let (m, n) = delta_matrix_into(
        x.values_of(i),
        y.values_of(j),
        lx,
        ly,
        x.dim(),
        opts.exec.transform,
        dx,
        dys,
        base,
        delta,
    );
    match opts.solver {
        SolverKind::Row => crate::kernel::solver::solve_pde_with(
            &delta[..m * n],
            m,
            n,
            opts.dyadic_x,
            opts.dyadic_y,
            prev,
            cur,
        ),
        SolverKind::Blocked => {
            crate::kernel::solve_pde_blocked(&delta[..m * n], m, n, opts.dyadic_x, opts.dyadic_y)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::delta::delta_matrix;
    use crate::kernel::solver::solve_pde;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    /// Interleave W scalar Δ matrices into the `[m, W, n]` lane block.
    fn interleave<const W: usize>(deltas: &[Vec<f64>], m: usize, n: usize) -> Vec<f64> {
        let mut out = vec![0.0; m * W * n];
        for (w, d) in deltas.iter().enumerate() {
            for s in 0..m {
                out[(s * W + w) * n..(s * W + w) * n + n].copy_from_slice(&d[s * n..(s + 1) * n]);
            }
        }
        out
    }

    fn check_lanes<const W: usize>(g: &mut crate::util::prop::Gen) {
        let m = g.usize_in(1, 9);
        let n = g.usize_in(1, 9);
        let lam1 = g.usize_in(0, 2) as u32;
        let lam2 = g.usize_in(0, 2) as u32;
        let deltas: Vec<Vec<f64>> = (0..W)
            .map(|_| g.normal_vec(m * n).iter().map(|v| v * 0.3).collect())
            .collect();
        let block = interleave::<W>(&deltas, m, n);
        let (mut prev, mut cur) = (Vec::new(), Vec::new());
        let got = solve_pde_lanes::<W>(&block, m, n, lam1, lam2, &mut prev, &mut cur);
        for (w, d) in deltas.iter().enumerate() {
            let want = solve_pde(d, m, n, lam1, lam2);
            assert_eq!(got[w], want, "lane {w} of {W} (m={m} n={n} λ=({lam1},{lam2}))");
        }
    }

    #[test]
    fn lanes_bitmatch_scalar_solver() {
        check("solve_pde_lanes == W × solve_pde", 20, |g| {
            check_lanes::<4>(g);
            check_lanes::<8>(g);
        });
    }

    #[test]
    fn delta_block_bitmatches_per_pair_precompute() {
        check("stacked Δ block == per-pair Δ", 15, |g| {
            const W: usize = 4;
            let lx = g.usize_in(2, 7);
            let ly = g.usize_in(2, 7);
            let d = g.usize_in(1, 3);
            let x = g.path(lx, d, 0.5);
            let ys: Vec<Vec<f64>> = (0..W).map(|_| g.path(ly, d, 0.5)).collect();
            let yrefs: [&[f64]; W] = std::array::from_fn(|w| ys[w].as_slice());
            for tr in [
                Transform::None,
                Transform::TimeAug,
                Transform::LeadLag,
                Transform::LeadLagTimeAug,
            ] {
                let mut sc = LaneScratch::new();
                sc.ensure(lx, ly, d, tr, W, 0);
                let (mt, nt) = delta_block_lanes::<W>(
                    &x, lx, &yrefs, ly, d, tr, &mut sc.dx, &mut sc.dys, &mut sc.base,
                    &mut sc.delta,
                );
                for (w, y) in ys.iter().enumerate() {
                    let (rm, cm, want) = delta_matrix(&x, y, lx, ly, d, tr);
                    assert_eq!((mt, nt), (rm, cm), "tr={tr:?}");
                    for s in 0..mt {
                        for t in 0..nt {
                            assert_eq!(
                                sc.delta[(s * W + w) * nt + t],
                                want[s * nt + t],
                                "tr={tr:?} lane {w} cell ({s},{t})"
                            );
                        }
                    }
                }
            }
        });
    }

    #[test]
    fn gram_row_bitmatches_scalar_for_every_width() {
        let mut rng = Rng::new(910);
        let d = 2;
        // Ragged y with repeated lengths so lane groups actually form.
        let ylens = [5usize, 7, 5, 5, 7, 5, 1, 5, 7, 5, 5, 7, 5, 5];
        let mut ydata = Vec::new();
        for &l in &ylens {
            ydata.extend(rng.brownian_path(l, d, 0.4));
        }
        let yb = PathBatch::ragged(&ydata, &ylens, d).unwrap();
        let xdata = rng.brownian_path(6, d, 0.4);
        let xb = PathBatch::uniform(&xdata, 1, 6, d).unwrap();
        for opts in [
            KernelOptions::default(),
            KernelOptions::default().dyadic(1, 2),
            KernelOptions::default().transform(Transform::LeadLag),
            KernelOptions::default().transform(Transform::TimeAug),
        ] {
            let mut want = vec![0.0; ylens.len()];
            let mut sc = LaneScratch::new();
            solve_gram_row(&xb, 0, &yb, 0..ylens.len(), &opts, 0, &mut sc, &mut want);
            for width in LANE_WIDTHS {
                let mut got = vec![0.0; ylens.len()];
                let mut sc = LaneScratch::new();
                solve_gram_row(&xb, 0, &yb, 0..ylens.len(), &opts, width, &mut sc, &mut got);
                assert_eq!(got, want, "width={width} opts={opts:?}");
            }
        }
    }

    #[test]
    fn occupancy_counters_move_with_lane_traffic() {
        let before = stats();
        let mut rng = Rng::new(911);
        let d = 2;
        let n = 11; // one group of 8 + three scalar remainder pairs
        let data = rng.brownian_batch(n, 6, d, 0.4);
        let yb = PathBatch::uniform(&data, n, 6, d).unwrap();
        let x = rng.brownian_path(5, d, 0.4);
        let xb = PathBatch::uniform(&x, 1, 5, d).unwrap();
        let mut out = vec![0.0; n];
        let mut sc = LaneScratch::new();
        solve_gram_row(&xb, 0, &yb, 0..n, &KernelOptions::default(), 8, &mut sc, &mut out);
        let after = stats();
        assert!(after.lane_groups >= before.lane_groups + 1);
        assert!(after.scalar_pairs >= before.scalar_pairs + 3);
    }

    #[test]
    fn width_normalisation_and_defaults() {
        assert_eq!(normalize_lane_width(0), 0);
        assert_eq!(normalize_lane_width(1), 0);
        assert_eq!(normalize_lane_width(2), 4);
        assert_eq!(normalize_lane_width(4), 4);
        assert_eq!(normalize_lane_width(5), 4);
        assert_eq!(normalize_lane_width(6), 8);
        assert_eq!(normalize_lane_width(8), 8);
        assert_eq!(normalize_lane_width(64), 8);
        assert_eq!(default_lane_width(true), 8);
        assert_eq!(default_lane_width(false), 4);
    }
}
