//! Lane-batched multi-pair PDE engine: one Goursat sweep advances W
//! independent kernels.
//!
//! The CPU row sweep ([`super::solver::solve_pde_with`]) is memory-bound
//! with a serial `k[s,t-1] → k[s,t]` dependency, and vectorising *within* a
//! single PDE was tried and **reverted** — the two-pass restructure of the
//! inner loop is ~20% slower on this testbed (extra coefficient/cterm
//! memory traffic outweighs the shorter dependency chain; see the NOTE in
//! `solver.rs` and the `pde_sweep/*` rows of the ablations bench). KSig and
//! the paper's GPU scheme get their throughput the other way: batching
//! *across pairs*. Every (x, y) pair in a Gram tile runs the exact same
//! instruction sequence, so W pairs can ride the SIMD lanes of one sweep
//! with **zero cross-lane dependencies** — and bit-identical results to the
//! scalar solver, since each lane performs the same FP ops in the same
//! order.
//!
//! Three layers:
//!
//! * [`solve_pde_lanes`] — the structure-of-arrays solver: W independent
//!   grids advance per inner-loop iteration over interleaved `[cols+1, W]`
//!   row buffers. W is a const generic fixed to 4 or 8 and the arithmetic
//!   is plain fixed-size-array code (no `std::simd`, no `unsafe`), so LLVM
//!   autovectorises the per-lane FMA block.
//! * [`delta_block_lanes`] — the tile-level Δ precompute: the W pairs of a
//!   lane group share one x row, so their increment matrices stack into a
//!   **single GEMM** `dx · [dy_0; …; dy_{W-1}]ᵀ` whose output *is* the
//!   lane-interleaved `[m, W, n]` delta block — one GEMM per lane group
//!   instead of one per pair ([`gemm_nt`] computes every entry as an
//!   independent fixed-order dot product, so stacking is bit-neutral).
//! * [`solve_gram_row`] — the dispatcher every Gram producer calls: groups
//!   a row's columns by shape class (ragged batches are sorted by length —
//!   unstable, allocation-free — so equal-length paths form runs), packs
//!   full lane groups of `width`, and finishes the remainder with the
//!   scalar per-pair path.
//!
//! **Bit-identity.** Lane w of a group evaluates exactly the scalar
//! recurrence `v = (k_left + prev[t+1])·A(p) − prev[t]·B(p)` on exactly the
//! scalar Δ values, in the same order — lane batching is pure schedule, so
//! Gram/MMD²/corpus results are bit-for-bit identical to the scalar path
//! for every width (property-tested in `tests/props_lanes.rs`). The
//! [`SolverKind::Blocked`](crate::kernel::SolverKind::Blocked) schedule is
//! served scalar (it models the GPU dataflow; lane-batching it would be
//! redundant with the row schedule's lanes).
//!
//! **Cost model.** A lane group amortises the sweep's loop control and
//! turns W dependent scalar FMA chains into W-wide independent ones, but
//! needs W same-shape pairs per group: uniform batches default to W = 8,
//! ragged batches to W = 4 (equal-length runs are shorter), and
//! `PYSIGLIB_LANES` overrides both (`0` = scalar, values snap to 4 or 8).
//! Pairs that do not fill a group fall back to the scalar path and are
//! counted in [`stats`] as the scalar remainder.

use std::ops::Range;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::kernel::backward::{sig_kernel_vjp_delta_acc, sig_kernel_vjp_delta_into};
use crate::kernel::delta::{
    apply_difference_adjoint, delta_matrix_into, fold_grad_delta, grad_increments_into,
    increments_into,
};
use crate::kernel::scheme::{
    coarse_orders, order2_degenerate, order2_seeds, richardson_combine, Scheme,
};
use crate::kernel::solver::solve_pde_grid_into;
use crate::kernel::{KernelOptions, SolverKind};
use crate::path::PathBatch;
use crate::transforms::Transform;
use crate::util::linalg::{gemm_nt, gemm_tn};

/// The supported lane widths (const-generic instantiations of
/// [`solve_pde_lanes`]).
pub const LANE_WIDTHS: [usize; 2] = [4, 8];

// ---------------------------------------------------------------------------
// Occupancy counters (process-wide, monotonic) — mirrored into the serving
// metrics snapshot so tile/lane occupancy is observable in production.

static TILES_EXECUTED: AtomicU64 = AtomicU64::new(0);
static LANE_GROUPS: AtomicU64 = AtomicU64::new(0);
static SCALAR_PAIRS: AtomicU64 = AtomicU64::new(0);
static VJP_LANE_GROUPS: AtomicU64 = AtomicU64::new(0);
static VJP_SCALAR_PAIRS: AtomicU64 = AtomicU64::new(0);

/// Snapshot of the lane engine's occupancy counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LaneStats {
    /// Gram tiles executed by the [`TileScheduler`](crate::corpus::TileScheduler).
    pub tiles_executed: u64,
    /// Full lane groups dispatched through [`solve_pde_lanes`].
    pub lane_groups: u64,
    /// Pairs solved by the scalar remainder while lane batching was active
    /// (degenerate pairs and lanes-off runs are not counted).
    pub scalar_pairs: u64,
    /// Full lane groups dispatched through the backward sweep
    /// ([`vjp_pde_lanes`]).
    pub vjp_lane_groups: u64,
    /// Pairs solved by the backward scalar remainder.
    pub vjp_scalar_pairs: u64,
}

/// Current occupancy counters (monotonic across the process lifetime).
pub fn stats() -> LaneStats {
    LaneStats {
        tiles_executed: TILES_EXECUTED.load(Ordering::Relaxed),
        lane_groups: LANE_GROUPS.load(Ordering::Relaxed),
        scalar_pairs: SCALAR_PAIRS.load(Ordering::Relaxed),
        vjp_lane_groups: VJP_LANE_GROUPS.load(Ordering::Relaxed),
        vjp_scalar_pairs: VJP_SCALAR_PAIRS.load(Ordering::Relaxed),
    }
}

/// Record one executed Gram tile (called by the tile scheduler).
pub(crate) fn count_tile() {
    TILES_EXECUTED.fetch_add(1, Ordering::Relaxed);
}

// ---------------------------------------------------------------------------
// Lane-width resolution.

/// Snap a requested width to a supported one: `0`/`1` mean scalar, other
/// values round to the nearest of [`LANE_WIDTHS`].
pub fn normalize_lane_width(w: usize) -> usize {
    if w <= 1 {
        0
    } else if w <= 5 {
        4
    } else {
        8
    }
}

/// The `PYSIGLIB_LANES` override, normalised; `None` when unset/unparsable.
/// Read once per process and cached (see [`crate::config::env`]).
pub fn lane_width_override() -> Option<usize> {
    crate::config::env::lanes().map(normalize_lane_width)
}

/// Default width for a shape profile: uniform classes fill W = 8 groups
/// whenever at least 8 pairs share a tile row; ragged classes use W = 4
/// because equal-length runs are shorter.
pub fn default_lane_width(uniform: bool) -> usize {
    if uniform {
        8
    } else {
        4
    }
}

/// Resolved lane width for a shape profile: the environment override wins,
/// else the per-class default. Read at plan / scheduler construction time
/// (not per execute), so a compiled plan's schedule is stable.
pub fn lane_width_for(uniform: bool) -> usize {
    lane_width_override().unwrap_or_else(|| default_lane_width(uniform))
}

// ---------------------------------------------------------------------------
// The SoA solver.

/// Solve W independent Goursat PDEs in one sweep.
///
/// `delta` is the lane-interleaved `[m, W, n]` block (lane w's Δ row `s'`
/// starts at `delta[(s'·W + w)·n]`) — exactly the layout
/// [`delta_block_lanes`] produces. `prev`/`cur` are caller-provided
/// interleaved `[cols+1, W]` row buffers, resized in place (the engine's
/// Gram plans route them through the workspace arena so the steady state
/// allocates nothing). Returns the W terminal values k(1,1).
///
/// Each lane evaluates the scalar recurrence of
/// [`solve_pde_with`](super::solver::solve_pde_with) on its own Δ values in
/// the same order, so lane results are bit-identical to W scalar solves.
/// The dyadic-run coefficient hoist matches the scalar solver's: A(p)/B(p)
/// are computed once per `2^λ2` run.
pub fn solve_pde_lanes<const W: usize>(
    delta: &[f64],
    m: usize,
    n: usize,
    lam1: u32,
    lam2: u32,
    prev: &mut Vec<f64>,
    cur: &mut Vec<f64>,
) -> [f64; W] {
    assert_eq!(delta.len(), m * W * n);
    let rows = m << lam1;
    let cols = n << lam2;
    let scale = 1.0 / (1u64 << (lam1 + lam2)) as f64;
    prev.clear();
    prev.resize((cols + 1) * W, 1.0);
    cur.clear();
    cur.resize((cols + 1) * W, 1.0);
    let run = 1usize << lam2;
    for s in 0..rows {
        let dbase = (s >> lam1) * W * n;
        cur[..W].fill(1.0);
        let mut k_left = [1.0f64; W];
        let mut a = [0.0f64; W];
        let mut b = [0.0f64; W];
        let mut t = 0usize;
        for tc in 0..n {
            for w in 0..W {
                let p = delta[dbase + w * n + tc] * scale;
                let p2 = p * p * (1.0 / 12.0);
                a[w] = 1.0 + 0.5 * p + p2;
                b[w] = 1.0 - p2;
            }
            for _ in 0..run {
                // The W-wide FMA block: no cross-lane dependency, contiguous
                // interleaved loads/stores — the autovectorisation target.
                for w in 0..W {
                    let v = (k_left[w] + prev[(t + 1) * W + w]) * a[w] - prev[t * W + w] * b[w];
                    cur[(t + 1) * W + w] = v;
                    k_left[w] = v;
                }
                t += 1;
            }
        }
        std::mem::swap(prev, cur);
    }
    let mut out = [0.0; W];
    out.copy_from_slice(&prev[cols * W..(cols + 1) * W]);
    out
}

/// Scheme-dispatched lane solve: same combine convention as
/// [`solve_pde_scheme`](super::solver::solve_pde_scheme), applied per lane.
///
/// `Order2` runs the fine sweep at (λ1, λ2) and a second sweep at the
/// coarsened orders, then Richardson-combines per lane with the exact
/// scalar expression — so lane results stay bit-identical to W scalar
/// [`solve_pde_scheme`] calls. `prev`/`cur` are reused across both sweeps
/// ([`solve_pde_lanes`] resizes them itself).
#[allow(clippy::too_many_arguments)]
pub fn solve_pde_lanes_scheme<const W: usize>(
    delta: &[f64],
    m: usize,
    n: usize,
    lam1: u32,
    lam2: u32,
    scheme: Scheme,
    prev: &mut Vec<f64>,
    cur: &mut Vec<f64>,
) -> [f64; W] {
    match scheme {
        Scheme::Order1 => solve_pde_lanes::<W>(delta, m, n, lam1, lam2, prev, cur),
        Scheme::Order2 => {
            let fine = solve_pde_lanes::<W>(delta, m, n, lam1, lam2, prev, cur);
            if order2_degenerate(lam1, lam2) {
                return fine;
            }
            let (c1, c2) = coarse_orders(lam1, lam2);
            let coarse = solve_pde_lanes::<W>(delta, m, n, c1, c2, prev, cur);
            std::array::from_fn(|w| richardson_combine(fine[w], coarse[w]))
        }
    }
}

// ---------------------------------------------------------------------------
// Tile-level Δ precompute.

/// Pack the Δ blocks of a lane group — one x path against W same-length y
/// paths — into the lane-interleaved `[m_t, W, n_t]` layout with a single
/// stacked GEMM.
///
/// The W increment matrices stack as `dys = [dy_0; …; dy_{W-1}]`
/// (`[W·n, dim]`), and `dx · dysᵀ` lands row-major as `[m, W·n]` — which
/// *is* `[m, W, n]`: lane w's Δ row i occupies `out[(i·W + w)·n ..]`.
/// Transforms are fused exactly as in
/// [`delta_matrix_into`](crate::kernel::delta::delta_matrix_into): the
/// time-augmentation shift is a constant add, lead-lag expands each lane's
/// base block by increment parity. Returns the transformed `(rows, cols)`
/// per lane. Every lane's entries are bit-identical to the per-pair
/// precompute ([`gemm_nt`] computes each entry as an independent
/// fixed-order dot product).
///
/// Scratch: `dx` is `[(lx−1)·dim]`, `dys` is `[W·(ly−1)·dim]`, `base` is
/// `[(lx−1)·W·(ly−1)]` for the lead-lag transforms (may be empty
/// otherwise), `out` holds `rows·W·cols` of the transformed block; all may
/// be larger than needed.
#[allow(clippy::too_many_arguments)]
pub fn delta_block_lanes<const W: usize>(
    x: &[f64],
    lx: usize,
    ys: &[&[f64]; W],
    ly: usize,
    dim: usize,
    transform: Transform,
    dx: &mut [f64],
    dys: &mut [f64],
    base: &mut [f64],
    out: &mut [f64],
) -> (usize, usize) {
    let m = lx - 1;
    let n = ly - 1;
    increments_into(x, lx, dim, &mut dx[..m * dim]);
    for (w, y) in ys.iter().enumerate() {
        increments_into(y, ly, dim, &mut dys[w * n * dim..(w + 1) * n * dim]);
    }
    match transform {
        Transform::None | Transform::TimeAug => {
            let out = &mut out[..m * W * n];
            gemm_nt(m, dim, W * n, &dx[..m * dim], &dys[..W * n * dim], out);
            if transform == Transform::TimeAug {
                let shift = (1.0 / m as f64) * (1.0 / n as f64);
                for v in out.iter_mut() {
                    *v += shift;
                }
            }
            (m, n)
        }
        Transform::LeadLag | Transform::LeadLagTimeAug => {
            let base = &mut base[..m * W * n];
            gemm_nt(m, dim, W * n, &dx[..m * dim], &dys[..W * n * dim], base);
            let rows = 2 * lx - 2;
            let cols = 2 * ly - 2;
            let shift = if transform == Transform::LeadLagTimeAug {
                (1.0 / rows as f64) * (1.0 / cols as f64)
            } else {
                0.0
            };
            let out = &mut out[..rows * W * cols];
            out.fill(shift);
            for a in 0..rows {
                for w in 0..W {
                    let orow = &mut out[(a * W + w) * cols..(a * W + w + 1) * cols];
                    let brow = &base[((a / 2) * W + w) * n..((a / 2) * W + w + 1) * n];
                    for (bcol, ov) in orow.iter_mut().enumerate() {
                        if a % 2 == bcol % 2 {
                            *ov += brow[bcol / 2];
                        }
                    }
                }
            }
            (rows, cols)
        }
    }
}

// ---------------------------------------------------------------------------
// Per-worker scratch.

/// Per-worker scratch for lane-batched Gram rows: increment buffers, the
/// lane-interleaved Δ block, the two interleaved solver rows and the
/// column-grouping index. Plain growable buffers here ([`ensure`] grows
/// them on demand for the tile scheduler); the engine's Gram plans assemble
/// the same struct from arena-checked-out buffers sized at worker start, so
/// `ensure` never grows there and the steady state stays allocation-free.
///
/// [`ensure`]: LaneScratch::ensure
#[derive(Default)]
pub struct LaneScratch {
    /// `[(lx−1)·dim]` raw x increments.
    pub dx: Vec<f64>,
    /// `[W·(ly−1)·dim]` stacked y increments (its `[..(ly−1)·dim]` prefix
    /// doubles as the scalar path's dy scratch).
    pub dys: Vec<f64>,
    /// `[(lx−1)·W·(ly−1)]` base block for the lead-lag transforms.
    pub base: Vec<f64>,
    /// `[m_t·W·n_t]` lane-interleaved transformed Δ block (its leading
    /// `[m_t·n_t]` doubles as the scalar path's Δ scratch).
    pub delta: Vec<f64>,
    /// Interleaved `[cols+1, W]` solver rows.
    pub prev: Vec<f64>,
    pub cur: Vec<f64>,
    /// Column indices grouped by length (ragged batches).
    pub idx: Vec<usize>,
}

/// Buffer lengths a `(lx, ly, dim, transform, width)` row needs — the one
/// place the scratch-sizing arithmetic lives. [`LaneScratch::ensure`] grows
/// to these per row, and the engine's arena checkout pre-takes them at the
/// batch's maxima (sizes are monotone in `lx`/`ly`, so per-row `ensure`
/// never exceeds the checkout and the zero-allocation steady state holds
/// by construction, not by two hand-synchronized copies of the formulas).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LaneSizes {
    /// Raw x increments `[(lx−1)·dim]`.
    pub dx: usize,
    /// Stacked y increments `[W·(ly−1)·dim]`.
    pub dys: usize,
    /// Lead-lag base block `[(lx−1)·W·(ly−1)]` (0 when unused).
    pub base: usize,
    /// Lane-interleaved transformed Δ block `[m_t·W·n_t]`.
    pub delta: usize,
    /// One interleaved `[cols+1, W]` solver row (`prev` and `cur` each).
    pub row: usize,
}

/// Compute [`LaneSizes`] for a row of `(x: lx) × (y: ly)` pairs at `width`.
pub fn lane_sizes(
    lx: usize,
    ly: usize,
    dim: usize,
    transform: Transform,
    width: usize,
    lam2: u32,
) -> LaneSizes {
    let w = width.max(1);
    let (mi, ni) = (lx.saturating_sub(1), ly.saturating_sub(1));
    let (mt, nt) = if lx < 2 || ly < 2 {
        (0, 0)
    } else {
        (transform.out_len(lx) - 1, transform.out_len(ly) - 1)
    };
    let needs_base = matches!(transform, Transform::LeadLag | Transform::LeadLagTimeAug);
    LaneSizes {
        dx: mi * dim,
        dys: w * ni * dim,
        base: if needs_base { mi * w * ni } else { 0 },
        delta: mt * w * nt,
        row: ((nt << lam2) + 1) * w,
    }
}

impl LaneScratch {
    /// Empty scratch; buffers grow on first use.
    pub fn new() -> LaneScratch {
        LaneScratch::default()
    }

    /// Grow every buffer to [`lane_sizes`] for this row (never shrinks —
    /// arena-provided buffers stay intact).
    pub fn ensure(
        &mut self,
        lx: usize,
        ly: usize,
        dim: usize,
        transform: Transform,
        width: usize,
        lam2: u32,
    ) {
        let s = lane_sizes(lx, ly, dim, transform, width, lam2);
        let grow = |buf: &mut Vec<f64>, len: usize| {
            if buf.len() < len {
                buf.resize(len, 0.0);
            }
        };
        grow(&mut self.dx, s.dx);
        grow(&mut self.dys, s.dys);
        grow(&mut self.base, s.base);
        grow(&mut self.delta, s.delta);
        grow(&mut self.prev, s.row);
        grow(&mut self.cur, s.row);
    }
}

// ---------------------------------------------------------------------------
// The Gram-row dispatcher.

/// Solve one Gram row k(x_i, y_j) for `j ∈ cols` into
/// `out[j − cols.start]`, lane-batched.
///
/// Columns are grouped by shape class: for ragged batches the column
/// indices are sorted by path length (an unstable, allocation-free sort —
/// group composition cannot affect values) so equal-length paths form
/// runs; full groups of `width` are packed
/// ([`delta_block_lanes`]) and solved by [`solve_pde_lanes`], the remainder
/// by the scalar per-pair path (bit-identical by construction, so `width`
/// is pure schedule). `width < 4` — and any
/// [`SolverKind::Blocked`](crate::kernel::SolverKind::Blocked) request —
/// runs fully scalar. Degenerate pairs (either path shorter than 2 points)
/// are the constant 1.
#[allow(clippy::too_many_arguments)]
pub fn solve_gram_row(
    x: &PathBatch<'_>,
    i: usize,
    y: &PathBatch<'_>,
    cols: Range<usize>,
    opts: &KernelOptions,
    width: usize,
    sc: &mut LaneScratch,
    out: &mut [f64],
) {
    assert_eq!(out.len(), cols.len());
    if cols.is_empty() {
        return;
    }
    // Defensive re-snap: the engine and scheduler pass normalized widths,
    // but this is a public entry point and the group solver is instantiated
    // only for W ∈ {4, 8}. Blocked-solver requests drop to the scalar
    // schedule *before* scratch sizing, so they never pay for W-wide
    // buffers they cannot use.
    let width = if opts.solver == SolverKind::Row {
        normalize_lane_width(width)
    } else {
        0
    };
    let lx = x.len_of(i);
    if lx < 2 {
        out.fill(1.0);
        return;
    }
    let my = (cols.start..cols.end).map(|j| y.len_of(j)).max().unwrap_or(0);
    let tr = opts.exec.transform;
    sc.ensure(lx, my, x.dim(), tr, width, opts.dyadic_y);
    let lane_ok = width >= 4;
    if !lane_ok {
        for (slot, j) in out.iter_mut().zip(cols) {
            *slot = scalar_entry(x, i, y, j, opts, sc);
        }
        return;
    }
    // Partition: degenerate columns resolve inline, the rest group by length.
    let mut idx = std::mem::take(&mut sc.idx);
    idx.clear();
    for j in cols.start..cols.end {
        if y.len_of(j) < 2 {
            out[j - cols.start] = 1.0;
        } else {
            idx.push(j);
        }
    }
    if y.uniform_len().is_none() {
        // Unstable sort: allocation-free (a stable sort would heap-allocate
        // scratch on every ragged row strip, breaking the engine's
        // zero-allocation steady state), and group composition cannot
        // affect values — every Gram entry is computed independently.
        idx.sort_unstable_by_key(|&j| y.len_of(j));
    }
    let (mut groups, mut scalars) = (0u64, 0u64);
    let mut pos = 0;
    while pos < idx.len() {
        let ly = y.len_of(idx[pos]);
        let mut end = pos + 1;
        while end < idx.len() && y.len_of(idx[end]) == ly {
            end += 1;
        }
        // Full lane groups of this equal-length run, then the remainder.
        while pos + width <= end {
            let group = &idx[pos..pos + width];
            match width {
                4 => solve_group_into::<4>(x, i, y, group, opts, sc, cols.start, out),
                _ => solve_group_into::<8>(x, i, y, group, opts, sc, cols.start, out),
            }
            groups += 1;
            pos += width;
        }
        while pos < end {
            let j = idx[pos];
            out[j - cols.start] = scalar_entry(x, i, y, j, opts, sc);
            scalars += 1;
            pos += 1;
        }
    }
    sc.idx = idx;
    if groups > 0 {
        LANE_GROUPS.fetch_add(groups, Ordering::Relaxed);
    }
    if scalars > 0 {
        SCALAR_PAIRS.fetch_add(scalars, Ordering::Relaxed);
    }
}

/// One full lane group: pack the Δ block with one stacked GEMM, sweep all W
/// kernels, scatter the terminals to their output slots.
#[allow(clippy::too_many_arguments)]
fn solve_group_into<const W: usize>(
    x: &PathBatch<'_>,
    i: usize,
    y: &PathBatch<'_>,
    group: &[usize],
    opts: &KernelOptions,
    sc: &mut LaneScratch,
    col0: usize,
    out: &mut [f64],
) {
    debug_assert_eq!(group.len(), W);
    let ly = y.len_of(group[0]);
    let ys: [&[f64]; W] = std::array::from_fn(|w| y.values_of(group[w]));
    let LaneScratch {
        dx,
        dys,
        base,
        delta,
        prev,
        cur,
        ..
    } = sc;
    let (mt, nt) = delta_block_lanes::<W>(
        x.values_of(i),
        x.len_of(i),
        &ys,
        ly,
        x.dim(),
        opts.exec.transform,
        dx,
        dys,
        base,
        delta,
    );
    let vals = solve_pde_lanes_scheme::<W>(
        &delta[..mt * W * nt],
        mt,
        nt,
        opts.dyadic_x,
        opts.dyadic_y,
        opts.scheme,
        prev,
        cur,
    );
    for (w, &j) in group.iter().enumerate() {
        out[j - col0] = vals[w];
    }
}

/// One scalar Gram entry — exactly the per-pair computation of the
/// pre-lane engine (Δ via [`delta_matrix_into`], then the requested
/// sweep), so lane-off and remainder values match the historical path bit
/// for bit.
fn scalar_entry(
    x: &PathBatch<'_>,
    i: usize,
    y: &PathBatch<'_>,
    j: usize,
    opts: &KernelOptions,
    sc: &mut LaneScratch,
) -> f64 {
    let (lx, ly) = (x.len_of(i), y.len_of(j));
    if lx < 2 || ly < 2 {
        return 1.0;
    }
    let LaneScratch {
        dx,
        dys,
        base,
        delta,
        prev,
        cur,
        ..
    } = sc;
    let (m, n) = delta_matrix_into(
        x.values_of(i),
        y.values_of(j),
        lx,
        ly,
        x.dim(),
        opts.exec.transform,
        dx,
        dys,
        base,
        delta,
    );
    match opts.solver {
        SolverKind::Row => crate::kernel::solver::solve_pde_scheme(
            &delta[..m * n],
            m,
            n,
            opts.dyadic_x,
            opts.dyadic_y,
            opts.scheme,
            prev,
            cur,
        ),
        SolverKind::Blocked => crate::kernel::blocked::solve_pde_blocked_scheme(
            &delta[..m * n],
            m,
            n,
            opts.dyadic_x,
            opts.dyadic_y,
            opts.scheme,
        ),
    }
}

// ---------------------------------------------------------------------------
// The backward pass: lane-batched Algorithm 4.
//
// The adjoint sweep has exactly the forward's structure — a serial recurrence
// over the refined grid with no cross-pair dependency — so the same SoA trick
// applies: W reverse Goursat traversals advance per pass over interleaved
// `[cols+1, W]` adjoint rows, each lane replaying the scalar FP sequence of
// [`sig_kernel_vjp_delta_into`] on its own Δ/grid values. Lane batching is
// pure schedule in the backward direction too, so gradients are bit-identical
// to the scalar Algorithm-4 path for every width (property-tested in
// `tests/props_grad.rs`). The backward always differentiates the *row*
// discretisation (Algorithm 4 needs the full forward grid), matching the
// historical per-pair vjp entry points regardless of `opts.solver`.

/// Solve W independent Goursat PDEs keeping the whole grids, lane-interleaved:
/// node (s, t) of lane w lands at `grid[(s·(cols+1) + t)·W + w]`.
///
/// `delta` is the `[m, W, n]` block from [`delta_block_lanes`]; `grid` must
/// have length `(rows+1)·(cols+1)·W`. Each lane runs the scalar recurrence of
/// [`solve_pde_grid_into`] in the same order (same dyadic-run coefficient
/// hoist), so every retained node is bit-identical to W scalar grid solves.
pub fn solve_pde_grid_lanes<const W: usize>(
    delta: &[f64],
    m: usize,
    n: usize,
    lam1: u32,
    lam2: u32,
    grid: &mut [f64],
) {
    assert_eq!(delta.len(), m * W * n);
    let rows = m << lam1;
    let cols = n << lam2;
    let gw = cols + 1;
    assert_eq!(grid.len(), (rows + 1) * gw * W);
    crate::kernel::solver::count_fwd_cells((W * rows * cols) as u64);
    let scale = 1.0 / (1u64 << (lam1 + lam2)) as f64;
    grid.fill(1.0);
    let run = 1usize << lam2;
    for s in 0..rows {
        let dbase = (s >> lam1) * W * n;
        let (top, bot) = grid.split_at_mut((s + 1) * gw * W);
        let prev = &top[s * gw * W..];
        let cur = &mut bot[..gw * W];
        let mut k_left = [1.0f64; W];
        let mut a = [0.0f64; W];
        let mut b = [0.0f64; W];
        let mut t = 0usize;
        for tc in 0..n {
            for w in 0..W {
                let p = delta[dbase + w * n + tc] * scale;
                let p2 = p * p * (1.0 / 12.0);
                a[w] = 1.0 + 0.5 * p + p2;
                b[w] = 1.0 - p2;
            }
            for _ in 0..run {
                for w in 0..W {
                    let v = (k_left[w] + prev[(t + 1) * W + w]) * a[w] - prev[t * W + w] * b[w];
                    cur[(t + 1) * W + w] = v;
                    k_left[w] = v;
                }
                t += 1;
            }
        }
    }
}

/// The lane-batched Algorithm-4 adjoint sweep: W reverse Goursat traversals
/// per pass.
///
/// `delta` is the `[m, W, n]` block, `grid` the interleaved forward grids
/// from [`solve_pde_grid_lanes`], `grad_out` the per-lane ∂F/∂k(1,1) seeds.
/// `d1_below`/`d1_cur` are the two live interleaved `[cols+1, W]` adjoint
/// rows (resized in place); `d2` receives the `[m, W, n]` ∂F/∂Δ block,
/// zeroed here. Lane w performs the exact scalar op sequence of
/// [`sig_kernel_vjp_delta_into`] — same conditionals (they depend only on
/// the shared geometry), same accumulation order — so each lane's `d2` is
/// bit-identical to the scalar adjoint.
#[allow(clippy::too_many_arguments)]
pub fn vjp_pde_lanes<const W: usize>(
    delta: &[f64],
    m: usize,
    n: usize,
    lam1: u32,
    lam2: u32,
    grid: &[f64],
    grad_out: &[f64; W],
    d1_below: &mut Vec<f64>,
    d1_cur: &mut Vec<f64>,
    d2: &mut [f64],
) {
    d2.fill(0.0);
    vjp_pde_lanes_acc::<W>(
        delta, m, n, lam1, lam2, grid, grad_out, d1_below, d1_cur, d2,
    );
}

/// Accumulating form of [`vjp_pde_lanes`]: identical sweep, but `d2` is
/// **added to** rather than zeroed — the lane-batched composition primitive
/// for `Order2` backward, where the fine pass (seed `(4/3)·w̄`) and the
/// coarse pass (seed `(−1/3)·w̄`) fold into one ∂F/∂Δ block. Mirrors
/// [`sig_kernel_vjp_delta_acc`](crate::kernel::backward::sig_kernel_vjp_delta_acc)
/// per lane, op for op.
#[allow(clippy::too_many_arguments)]
pub fn vjp_pde_lanes_acc<const W: usize>(
    delta: &[f64],
    m: usize,
    n: usize,
    lam1: u32,
    lam2: u32,
    grid: &[f64],
    grad_out: &[f64; W],
    d1_below: &mut Vec<f64>,
    d1_cur: &mut Vec<f64>,
    d2: &mut [f64],
) {
    assert_eq!(delta.len(), m * W * n);
    let rows = m << lam1;
    let cols = n << lam2;
    let gw = cols + 1;
    assert_eq!(grid.len(), (rows + 1) * gw * W);
    assert_eq!(d2.len(), m * W * n);
    let scale = 1.0 / (1u64 << (lam1 + lam2)) as f64;
    d1_below.clear();
    d1_below.resize(gw * W, 0.0);
    d1_cur.clear();
    d1_cur.resize(gw * W, 0.0);
    let mut below = &mut d1_below[..];
    let mut curr = &mut d1_cur[..];
    // p at refined cell (s, t) of lane w.
    let p_at =
        |w: usize, s: usize, t: usize| delta[((s >> lam1) * W + w) * n + (t >> lam2)] * scale;
    for s in (1..=rows).rev() {
        for t in (1..=cols).rev() {
            // The W-wide adjoint block: no cross-lane dependency.
            for w in 0..W {
                let mut v = 0.0;
                if s == rows && t == cols {
                    v = grad_out[w];
                } else {
                    if s < rows {
                        let p = p_at(w, s, t - 1);
                        v += below[t * W + w] * (1.0 + 0.5 * p + p * p / 12.0);
                    }
                    if t < cols {
                        let p = p_at(w, s - 1, t);
                        v += curr[(t + 1) * W + w] * (1.0 + 0.5 * p + p * p / 12.0);
                    }
                    if s < rows && t < cols {
                        let p = p_at(w, s, t);
                        v -= below[(t + 1) * W + w] * (1.0 - p * p / 12.0);
                    }
                }
                curr[t * W + w] = v;
                let p = p_at(w, s - 1, t - 1);
                let k_l = grid[(s * gw + (t - 1)) * W + w];
                let k_u = grid[((s - 1) * gw + t) * W + w];
                let k_ul = grid[((s - 1) * gw + (t - 1)) * W + w];
                let dk_dp = (k_l + k_u) * (0.5 + p / 6.0) + k_ul * (p / 6.0);
                d2[(((s - 1) >> lam1) * W + w) * n + ((t - 1) >> lam2)] += v * dk_dp * scale;
            }
        }
        std::mem::swap(&mut below, &mut curr);
    }
}

/// The lane-batched Δ-vjp accumulator — the backward mirror of
/// [`delta_block_lanes`]: reduce the W transformed ∂F/∂Δ' blocks to per-lane
/// increment gradients.
///
/// `d2` is the `[m_t, W, n_t]` output of [`vjp_pde_lanes`]; `dx`/`dys` are
/// the *raw* increments the forward pack already computed (reused, not
/// recomputed). The gdy side of all W lanes is one stacked `Aᵀ·B` GEMM
/// ([`gemm_tn`] — `d2` viewed as `[m, W·n]` lands the output per-lane
/// contiguous `[W, n, dim]`, exactly the `dys` layout); the gdx side runs
/// per lane in the GEMM's element order. Both match the scalar
/// [`grad_increments_into`] term for term, so lane gradients stay
/// bit-identical to the scalar adjoint.
#[allow(clippy::too_many_arguments)]
pub fn grad_block_lanes<const W: usize>(
    d2: &[f64],
    lx: usize,
    ly: usize,
    dim: usize,
    transform: Transform,
    dx: &[f64],
    dys: &[f64],
    gd: &mut [f64],
    gdx: &mut [f64],
    gdy: &mut [f64],
) {
    let m = lx - 1;
    let n = ly - 1;
    // Reduce the transformed gradient to the base Δ per lane (the constant
    // time shift has zero path derivative; lead-lag folds equal parities in
    // the scalar `fold_grad_delta` order).
    let gds: &[f64] = match transform {
        Transform::None | Transform::TimeAug => {
            assert_eq!(d2.len(), m * W * n);
            d2
        }
        Transform::LeadLag | Transform::LeadLagTimeAug => {
            let rows = 2 * m;
            let cols = 2 * n;
            assert_eq!(d2.len(), rows * W * cols);
            let gd = &mut gd[..m * W * n];
            gd.fill(0.0);
            for a in 0..rows {
                for w in 0..W {
                    let drow = &d2[(a * W + w) * cols..(a * W + w + 1) * cols];
                    let grow = &mut gd[((a / 2) * W + w) * n..((a / 2) * W + w + 1) * n];
                    for (b, &v) in drow.iter().enumerate() {
                        if a % 2 == b % 2 {
                            grow[b / 2] += v;
                        }
                    }
                }
            }
            gd
        }
    };
    // gdy for all lanes: one stacked transposed GEMM.
    gemm_tn(m, W * n, dim, gds, &dx[..m * dim], &mut gdy[..W * n * dim]);
    // gdx per lane: gd_w · dy_w over the interleaved rows, ascending shared
    // index with zero entries skipped — the [`gemm`](crate::util::linalg::gemm)
    // element order.
    let gdx = &mut gdx[..W * m * dim];
    gdx.fill(0.0);
    for w in 0..W {
        for i in 0..m {
            let grow = &gds[(i * W + w) * n..(i * W + w) * n + n];
            let orow = &mut gdx[(w * m + i) * dim..(w * m + i + 1) * dim];
            for (j, &g) in grow.iter().enumerate() {
                if g == 0.0 {
                    continue;
                }
                let dyrow = &dys[(w * n + j) * dim..(w * n + j + 1) * dim];
                for (ov, dv) in orow.iter_mut().zip(dyrow.iter()) {
                    *ov += g * dv;
                }
            }
        }
    }
}

/// Buffer lengths a backward `(lx, ly, dim, transform, width)` row needs on
/// top of the forward [`LaneSizes`] — the one place the backward
/// scratch-sizing arithmetic lives (see [`lane_sizes`] for the contract).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct VjpLaneSizes {
    /// Forward pack + sweep scratch.
    pub fwd: LaneSizes,
    /// Interleaved retained forward grids `[(rows+1)·(cols+1)·W]`.
    pub grid: usize,
    /// One interleaved `[cols+1, W]` adjoint row (two are needed).
    pub d1: usize,
    /// Lane-interleaved `[m_t, W, n_t]` ∂F/∂Δ' block.
    pub d2: usize,
    /// Lead-lag fold target `[(lx−1)·W·(ly−1)]` (0 when unused).
    pub gd: usize,
    /// Stacked per-lane x-increment gradients `[W·(lx−1)·dim]`.
    pub gdx: usize,
    /// Stacked per-lane y-increment gradients `[W·(ly−1)·dim]`.
    pub gdy: usize,
}

/// Compute [`VjpLaneSizes`] for a backward row of `(x: lx) × (y: ly)` pairs.
pub fn vjp_lane_sizes(
    lx: usize,
    ly: usize,
    dim: usize,
    transform: Transform,
    width: usize,
    lam1: u32,
    lam2: u32,
) -> VjpLaneSizes {
    let fwd = lane_sizes(lx, ly, dim, transform, width, lam2);
    let w = width.max(1);
    let (mi, ni) = (lx.saturating_sub(1), ly.saturating_sub(1));
    let (mt, nt) = if lx < 2 || ly < 2 {
        (0, 0)
    } else {
        (transform.out_len(lx) - 1, transform.out_len(ly) - 1)
    };
    let (rows, cols) = (mt << lam1, nt << lam2);
    let needs_base = matches!(transform, Transform::LeadLag | Transform::LeadLagTimeAug);
    VjpLaneSizes {
        fwd,
        grid: (rows + 1) * (cols + 1) * w,
        d1: (cols + 1) * w,
        d2: mt * w * nt,
        gd: if needs_base { mi * w * ni } else { 0 },
        gdx: w * mi * dim,
        gdy: w * ni * dim,
    }
}

/// Per-worker scratch for lane-batched backward Gram rows: the forward pack
/// scratch plus retained grids, adjoint rows and increment-gradient buffers.
/// Growable like [`LaneScratch`]; the shared Gram backward sizes one per
/// worker at the batch's maxima, so the per-pair hot loop allocates nothing.
#[derive(Default)]
pub struct VjpLaneScratch {
    /// Forward pack + sweep scratch (its `idx` doubles as the backward
    /// column-grouping index).
    pub fwd: LaneScratch,
    /// Interleaved retained forward grids.
    pub grid: Vec<f64>,
    /// The two live interleaved adjoint rows.
    pub d1a: Vec<f64>,
    pub d1b: Vec<f64>,
    /// Lane-interleaved ∂F/∂Δ' block.
    pub d2: Vec<f64>,
    /// Lead-lag fold target.
    pub gd: Vec<f64>,
    /// Stacked per-lane increment gradients.
    pub gdx: Vec<f64>,
    pub gdy: Vec<f64>,
}

impl VjpLaneScratch {
    /// Empty scratch; buffers grow on first use.
    pub fn new() -> VjpLaneScratch {
        VjpLaneScratch::default()
    }

    /// Grow every buffer to [`vjp_lane_sizes`] for this row (never shrinks).
    #[allow(clippy::too_many_arguments)]
    pub fn ensure(
        &mut self,
        lx: usize,
        ly: usize,
        dim: usize,
        transform: Transform,
        width: usize,
        lam1: u32,
        lam2: u32,
    ) {
        self.fwd.ensure(lx, ly, dim, transform, width, lam2);
        let s = vjp_lane_sizes(lx, ly, dim, transform, width, lam1, lam2);
        let grow = |buf: &mut Vec<f64>, len: usize| {
            if buf.len() < len {
                buf.resize(len, 0.0);
            }
        };
        grow(&mut self.grid, s.grid);
        grow(&mut self.d1a, s.d1);
        grow(&mut self.d1b, s.d1);
        grow(&mut self.d2, s.d2);
        grow(&mut self.gd, s.gd);
        grow(&mut self.gdx, s.gdx);
        grow(&mut self.gdy, s.gdy);
    }
}

/// Backward one Gram row: accumulate `Σ_j weights[j]·∂k(x_i, y_j)/∂·` into
/// `gxrow` (`[lx·dim]`, x_i's gradient) and `gy` (a whole-batch y-gradient
/// buffer addressed by the `yo` element offsets), lane-batched.
///
/// The dispatcher mirrors [`solve_gram_row`]: zero-weight and degenerate
/// columns are skipped, the survivors group by shape class, full groups of
/// `width` ride [`vjp_pde_lanes`], the remainder runs scalar. One deliberate
/// difference: ragged columns are sorted by length at **every** width,
/// scalar included — `gxrow` accumulates across columns, so the column order
/// must be width-independent for the lane schedule to stay bit-identical to
/// the scalar one. The backward always solves the row discretisation
/// (Algorithm 4 differentiates through the retained row grid), whatever
/// `opts.solver` says about the forward.
#[allow(clippy::too_many_arguments)]
pub fn vjp_gram_row(
    x: &PathBatch<'_>,
    i: usize,
    y: &PathBatch<'_>,
    cols: Range<usize>,
    weights: &[f64],
    opts: &KernelOptions,
    width: usize,
    sc: &mut VjpLaneScratch,
    gxrow: &mut [f64],
    gy: &mut [f64],
    yo: &[usize],
) {
    assert_eq!(weights.len(), cols.len());
    if cols.is_empty() {
        return;
    }
    let width = normalize_lane_width(width);
    let lx = x.len_of(i);
    if lx < 2 {
        // Constant kernel row: zero gradient everywhere.
        return;
    }
    let c0 = cols.start;
    let my = (cols.start..cols.end)
        .filter(|&j| weights[j - c0] != 0.0)
        .map(|j| y.len_of(j))
        .max()
        .unwrap_or(0);
    let tr = opts.exec.transform;
    sc.ensure(lx, my, x.dim(), tr, width, opts.dyadic_x, opts.dyadic_y);
    let mut idx = std::mem::take(&mut sc.fwd.idx);
    idx.clear();
    for j in cols.start..cols.end {
        if weights[j - c0] != 0.0 && y.len_of(j) >= 2 {
            idx.push(j);
        }
    }
    if y.uniform_len().is_none() {
        idx.sort_unstable_by_key(|&j| y.len_of(j));
    }
    let (mut groups, mut scalars) = (0u64, 0u64);
    let mut pos = 0;
    while pos < idx.len() {
        let ly = y.len_of(idx[pos]);
        let mut end = pos + 1;
        while end < idx.len() && y.len_of(idx[end]) == ly {
            end += 1;
        }
        if width >= 4 {
            while pos + width <= end {
                let group = &idx[pos..pos + width];
                match width {
                    4 => vjp_group_into::<4>(x, i, y, group, weights, c0, opts, sc, gxrow, gy, yo),
                    _ => vjp_group_into::<8>(x, i, y, group, weights, c0, opts, sc, gxrow, gy, yo),
                }
                groups += 1;
                pos += width;
            }
        }
        while pos < end {
            let j = idx[pos];
            scalar_vjp_entry(x, i, y, j, weights[j - c0], opts, sc, gxrow, gy, yo);
            scalars += 1;
            pos += 1;
        }
    }
    sc.fwd.idx = idx;
    if groups > 0 {
        VJP_LANE_GROUPS.fetch_add(groups, Ordering::Relaxed);
    }
    if scalars > 0 {
        VJP_SCALAR_PAIRS.fetch_add(scalars, Ordering::Relaxed);
    }
}

/// One full backward lane group: pack Δ (stacked GEMM), recompute the W
/// forward grids in one sweep, run the W-wide adjoint, reduce to increment
/// gradients, and apply the difference adjoints per lane in group order —
/// the exact sequence the scalar schedule produces.
#[allow(clippy::too_many_arguments)]
fn vjp_group_into<const W: usize>(
    x: &PathBatch<'_>,
    i: usize,
    y: &PathBatch<'_>,
    group: &[usize],
    weights: &[f64],
    c0: usize,
    opts: &KernelOptions,
    sc: &mut VjpLaneScratch,
    gxrow: &mut [f64],
    gy: &mut [f64],
    yo: &[usize],
) {
    debug_assert_eq!(group.len(), W);
    let (lx, ly) = (x.len_of(i), y.len_of(group[0]));
    let dim = x.dim();
    let ys: [&[f64]; W] = std::array::from_fn(|w| y.values_of(group[w]));
    let seeds: [f64; W] = std::array::from_fn(|w| weights[group[w] - c0]);
    let VjpLaneScratch {
        fwd,
        grid,
        d1a,
        d1b,
        d2,
        gd,
        gdx,
        gdy,
    } = sc;
    let (mt, nt) = delta_block_lanes::<W>(
        x.values_of(i),
        lx,
        &ys,
        ly,
        dim,
        opts.exec.transform,
        &mut fwd.dx,
        &mut fwd.dys,
        &mut fwd.base,
        &mut fwd.delta,
    );
    let delta = &fwd.delta[..mt * W * nt];
    let glen = ((mt << opts.dyadic_x) + 1) * ((nt << opts.dyadic_y) + 1) * W;
    solve_pde_grid_lanes::<W>(delta, mt, nt, opts.dyadic_x, opts.dyadic_y, &mut grid[..glen]);
    if opts.scheme == Scheme::Order2 && !order2_degenerate(opts.dyadic_x, opts.dyadic_y) {
        // Order-2 adjoint: the fine pass is seeded with (4/3)·w̄ (and zeroes
        // d2), then the coarse grid is re-solved into the same scratch
        // prefix and its pass accumulates with seed (−1/3)·w̄ — per lane the
        // exact scalar sequence of `sig_kernel_vjp_delta_scheme_into`.
        let fine_seeds: [f64; W] = std::array::from_fn(|w| order2_seeds(seeds[w]).0);
        vjp_pde_lanes::<W>(
            delta,
            mt,
            nt,
            opts.dyadic_x,
            opts.dyadic_y,
            &grid[..glen],
            &fine_seeds,
            d1a,
            d1b,
            &mut d2[..mt * W * nt],
        );
        let (c1, c2) = coarse_orders(opts.dyadic_x, opts.dyadic_y);
        let clen = ((mt << c1) + 1) * ((nt << c2) + 1) * W;
        solve_pde_grid_lanes::<W>(delta, mt, nt, c1, c2, &mut grid[..clen]);
        let coarse_seeds: [f64; W] = std::array::from_fn(|w| order2_seeds(seeds[w]).1);
        vjp_pde_lanes_acc::<W>(
            delta,
            mt,
            nt,
            c1,
            c2,
            &grid[..clen],
            &coarse_seeds,
            d1a,
            d1b,
            &mut d2[..mt * W * nt],
        );
    } else {
        vjp_pde_lanes::<W>(
            delta,
            mt,
            nt,
            opts.dyadic_x,
            opts.dyadic_y,
            &grid[..glen],
            &seeds,
            d1a,
            d1b,
            &mut d2[..mt * W * nt],
        );
    }
    let (m, n) = (lx - 1, ly - 1);
    grad_block_lanes::<W>(
        &d2[..mt * W * nt],
        lx,
        ly,
        dim,
        opts.exec.transform,
        &fwd.dx,
        &fwd.dys,
        gd,
        gdx,
        gdy,
    );
    for (w, &j) in group.iter().enumerate() {
        apply_difference_adjoint(gxrow, &gdx[w * m * dim..(w * m + m) * dim], m, dim);
        let gyj = &mut gy[yo[j]..yo[j + 1]];
        apply_difference_adjoint(gyj, &gdy[w * n * dim..(w * n + n) * dim], n, dim);
    }
}

/// One scalar backward Gram entry — exactly the per-pair Algorithm-4
/// computation (Δ pack, full forward grid, adjoint sweep, Δ-vjp), run
/// against the shared scratch so the hot loop allocates nothing. The lane
/// remainder and the lanes-off schedule both land here, so backward values
/// match the historical `try_sig_kernel_vjp` path bit for bit.
#[allow(clippy::too_many_arguments)]
fn scalar_vjp_entry(
    x: &PathBatch<'_>,
    i: usize,
    y: &PathBatch<'_>,
    j: usize,
    seed: f64,
    opts: &KernelOptions,
    sc: &mut VjpLaneScratch,
    gxrow: &mut [f64],
    gy: &mut [f64],
    yo: &[usize],
) {
    let (lx, ly) = (x.len_of(i), y.len_of(j));
    debug_assert!(lx >= 2 && ly >= 2);
    let dim = x.dim();
    let VjpLaneScratch {
        fwd,
        grid,
        d1a,
        d1b,
        d2,
        gd,
        gdx,
        gdy,
    } = sc;
    let (mt, nt) = delta_matrix_into(
        x.values_of(i),
        y.values_of(j),
        lx,
        ly,
        dim,
        opts.exec.transform,
        &mut fwd.dx,
        &mut fwd.dys,
        &mut fwd.base,
        &mut fwd.delta,
    );
    let delta = &fwd.delta[..mt * nt];
    let glen = ((mt << opts.dyadic_x) + 1) * ((nt << opts.dyadic_y) + 1);
    solve_pde_grid_into(delta, mt, nt, opts.dyadic_x, opts.dyadic_y, &mut grid[..glen]);
    if opts.scheme == Scheme::Order2 && !order2_degenerate(opts.dyadic_x, opts.dyadic_y) {
        // The scalar Order-2 composition: zero ∂F/∂Δ, fine pass at (4/3)·w̄,
        // coarse grid re-solved into the same scratch prefix, coarse pass
        // accumulated at (−1/3)·w̄ — the `sig_kernel_vjp_delta_scheme_into`
        // sequence run against the shared scratch.
        let (sf, sc2) = order2_seeds(seed);
        d2[..mt * nt].fill(0.0);
        sig_kernel_vjp_delta_acc(
            delta,
            mt,
            nt,
            opts.dyadic_x,
            opts.dyadic_y,
            &grid[..glen],
            sf,
            d1a,
            d1b,
            &mut d2[..mt * nt],
        );
        let (c1, c2) = coarse_orders(opts.dyadic_x, opts.dyadic_y);
        let clen = ((mt << c1) + 1) * ((nt << c2) + 1);
        solve_pde_grid_into(delta, mt, nt, c1, c2, &mut grid[..clen]);
        sig_kernel_vjp_delta_acc(
            delta,
            mt,
            nt,
            c1,
            c2,
            &grid[..clen],
            sc2,
            d1a,
            d1b,
            &mut d2[..mt * nt],
        );
    } else {
        sig_kernel_vjp_delta_into(
            delta,
            mt,
            nt,
            opts.dyadic_x,
            opts.dyadic_y,
            &grid[..glen],
            seed,
            d1a,
            d1b,
            &mut d2[..mt * nt],
        );
    }
    let (m, n) = (lx - 1, ly - 1);
    let gdt = fold_grad_delta(&d2[..mt * nt], m, n, opts.exec.transform, gd);
    grad_increments_into(gdt, m, n, dim, &fwd.dx, &fwd.dys, gdx, gdy);
    apply_difference_adjoint(gxrow, &gdx[..m * dim], m, dim);
    apply_difference_adjoint(&mut gy[yo[j]..yo[j + 1]], &gdy[..n * dim], n, dim);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::delta::delta_matrix;
    use crate::kernel::solver::solve_pde;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    /// Interleave W scalar Δ matrices into the `[m, W, n]` lane block.
    fn interleave<const W: usize>(deltas: &[Vec<f64>], m: usize, n: usize) -> Vec<f64> {
        let mut out = vec![0.0; m * W * n];
        for (w, d) in deltas.iter().enumerate() {
            for s in 0..m {
                out[(s * W + w) * n..(s * W + w) * n + n].copy_from_slice(&d[s * n..(s + 1) * n]);
            }
        }
        out
    }

    fn check_lanes<const W: usize>(g: &mut crate::util::prop::Gen) {
        let m = g.usize_in(1, 9);
        let n = g.usize_in(1, 9);
        let lam1 = g.usize_in(0, 2) as u32;
        let lam2 = g.usize_in(0, 2) as u32;
        let deltas: Vec<Vec<f64>> = (0..W)
            .map(|_| g.normal_vec(m * n).iter().map(|v| v * 0.3).collect())
            .collect();
        let block = interleave::<W>(&deltas, m, n);
        let (mut prev, mut cur) = (Vec::new(), Vec::new());
        let got = solve_pde_lanes::<W>(&block, m, n, lam1, lam2, &mut prev, &mut cur);
        for (w, d) in deltas.iter().enumerate() {
            let want = solve_pde(d, m, n, lam1, lam2);
            assert_eq!(got[w], want, "lane {w} of {W} (m={m} n={n} λ=({lam1},{lam2}))");
        }
    }

    #[test]
    fn lanes_bitmatch_scalar_solver() {
        check("solve_pde_lanes == W × solve_pde", 20, |g| {
            check_lanes::<4>(g);
            check_lanes::<8>(g);
        });
    }

    fn check_lanes_scheme<const W: usize>(g: &mut crate::util::prop::Gen) {
        let m = g.usize_in(1, 9);
        let n = g.usize_in(1, 9);
        let lam1 = g.usize_in(0, 2) as u32;
        let lam2 = g.usize_in(0, 2) as u32;
        let deltas: Vec<Vec<f64>> = (0..W)
            .map(|_| g.normal_vec(m * n).iter().map(|v| v * 0.3).collect())
            .collect();
        let block = interleave::<W>(&deltas, m, n);
        let (mut prev, mut cur) = (Vec::new(), Vec::new());
        for scheme in [Scheme::Order1, Scheme::Order2] {
            let got = solve_pde_lanes_scheme::<W>(
                &block, m, n, lam1, lam2, scheme, &mut prev, &mut cur,
            );
            for (w, d) in deltas.iter().enumerate() {
                let (mut sp, mut sc) = (Vec::new(), Vec::new());
                let want = crate::kernel::solver::solve_pde_scheme(
                    d, m, n, lam1, lam2, scheme, &mut sp, &mut sc,
                );
                assert_eq!(
                    got[w], want,
                    "{scheme:?} lane {w} of {W} (m={m} n={n} λ=({lam1},{lam2}))"
                );
            }
        }
    }

    #[test]
    fn scheme_lanes_bitmatch_scalar_scheme_solver() {
        check("solve_pde_lanes_scheme == W × solve_pde_scheme", 15, |g| {
            check_lanes_scheme::<4>(g);
            check_lanes_scheme::<8>(g);
        });
    }

    #[test]
    fn delta_block_bitmatches_per_pair_precompute() {
        check("stacked Δ block == per-pair Δ", 15, |g| {
            const W: usize = 4;
            let lx = g.usize_in(2, 7);
            let ly = g.usize_in(2, 7);
            let d = g.usize_in(1, 3);
            let x = g.path(lx, d, 0.5);
            let ys: Vec<Vec<f64>> = (0..W).map(|_| g.path(ly, d, 0.5)).collect();
            let yrefs: [&[f64]; W] = std::array::from_fn(|w| ys[w].as_slice());
            for tr in [
                Transform::None,
                Transform::TimeAug,
                Transform::LeadLag,
                Transform::LeadLagTimeAug,
            ] {
                let mut sc = LaneScratch::new();
                sc.ensure(lx, ly, d, tr, W, 0);
                let (mt, nt) = delta_block_lanes::<W>(
                    &x, lx, &yrefs, ly, d, tr, &mut sc.dx, &mut sc.dys, &mut sc.base,
                    &mut sc.delta,
                );
                for (w, y) in ys.iter().enumerate() {
                    let (rm, cm, want) = delta_matrix(&x, y, lx, ly, d, tr);
                    assert_eq!((mt, nt), (rm, cm), "tr={tr:?}");
                    for s in 0..mt {
                        for t in 0..nt {
                            assert_eq!(
                                sc.delta[(s * W + w) * nt + t],
                                want[s * nt + t],
                                "tr={tr:?} lane {w} cell ({s},{t})"
                            );
                        }
                    }
                }
            }
        });
    }

    #[test]
    fn gram_row_bitmatches_scalar_for_every_width() {
        let mut rng = Rng::new(910);
        let d = 2;
        // Ragged y with repeated lengths so lane groups actually form.
        let ylens = [5usize, 7, 5, 5, 7, 5, 1, 5, 7, 5, 5, 7, 5, 5];
        let mut ydata = Vec::new();
        for &l in &ylens {
            ydata.extend(rng.brownian_path(l, d, 0.4));
        }
        let yb = PathBatch::ragged(&ydata, &ylens, d).unwrap();
        let xdata = rng.brownian_path(6, d, 0.4);
        let xb = PathBatch::uniform(&xdata, 1, 6, d).unwrap();
        for opts in [
            KernelOptions::default(),
            KernelOptions::default().dyadic(1, 2),
            KernelOptions::default().transform(Transform::LeadLag),
            KernelOptions::default().transform(Transform::TimeAug),
        ] {
            let mut want = vec![0.0; ylens.len()];
            let mut sc = LaneScratch::new();
            solve_gram_row(&xb, 0, &yb, 0..ylens.len(), &opts, 0, &mut sc, &mut want);
            for width in LANE_WIDTHS {
                let mut got = vec![0.0; ylens.len()];
                let mut sc = LaneScratch::new();
                solve_gram_row(&xb, 0, &yb, 0..ylens.len(), &opts, width, &mut sc, &mut got);
                assert_eq!(got, want, "width={width} opts={opts:?}");
            }
        }
    }

    #[test]
    fn occupancy_counters_move_with_lane_traffic() {
        let before = stats();
        let mut rng = Rng::new(911);
        let d = 2;
        let n = 11; // one group of 8 + three scalar remainder pairs
        let data = rng.brownian_batch(n, 6, d, 0.4);
        let yb = PathBatch::uniform(&data, n, 6, d).unwrap();
        let x = rng.brownian_path(5, d, 0.4);
        let xb = PathBatch::uniform(&x, 1, 5, d).unwrap();
        let mut out = vec![0.0; n];
        let mut sc = LaneScratch::new();
        solve_gram_row(&xb, 0, &yb, 0..n, &KernelOptions::default(), 8, &mut sc, &mut out);
        let after = stats();
        assert!(after.lane_groups >= before.lane_groups + 1);
        assert!(after.scalar_pairs >= before.scalar_pairs + 3);
    }

    #[test]
    fn grid_lanes_bitmatch_scalar_grid_solver() {
        check("solve_pde_grid_lanes == W × solve_pde_grid", 15, |g| {
            const W: usize = 4;
            let m = g.usize_in(1, 7);
            let n = g.usize_in(1, 7);
            let lam1 = g.usize_in(0, 2) as u32;
            let lam2 = g.usize_in(0, 2) as u32;
            let deltas: Vec<Vec<f64>> = (0..W)
                .map(|_| g.normal_vec(m * n).iter().map(|v| v * 0.3).collect())
                .collect();
            let block = interleave::<W>(&deltas, m, n);
            let (rows, cols) = (m << lam1, n << lam2);
            let gw = cols + 1;
            let mut grid = vec![0.0; (rows + 1) * gw * W];
            solve_pde_grid_lanes::<W>(&block, m, n, lam1, lam2, &mut grid);
            for (w, d) in deltas.iter().enumerate() {
                let want = crate::kernel::solver::solve_pde_grid(d, m, n, lam1, lam2);
                for s in 0..=rows {
                    for t in 0..gw {
                        assert_eq!(
                            grid[(s * gw + t) * W + w],
                            want[s * gw + t],
                            "lane {w} node ({s},{t}) m={m} n={n} λ=({lam1},{lam2})"
                        );
                    }
                }
            }
        });
    }

    #[test]
    fn vjp_lanes_bitmatch_scalar_adjoint() {
        check("vjp_pde_lanes == W × sig_kernel_vjp_delta", 15, |g| {
            const W: usize = 4;
            let m = g.usize_in(1, 7);
            let n = g.usize_in(1, 7);
            let lam1 = g.usize_in(0, 2) as u32;
            let lam2 = g.usize_in(0, 2) as u32;
            let deltas: Vec<Vec<f64>> = (0..W)
                .map(|_| g.normal_vec(m * n).iter().map(|v| v * 0.3).collect())
                .collect();
            let seeds: [f64; W] = std::array::from_fn(|w| 0.25 + 0.5 * w as f64);
            let block = interleave::<W>(&deltas, m, n);
            let (rows, cols) = (m << lam1, n << lam2);
            let gw = cols + 1;
            let mut grid = vec![0.0; (rows + 1) * gw * W];
            solve_pde_grid_lanes::<W>(&block, m, n, lam1, lam2, &mut grid);
            let (mut d1a, mut d1b) = (Vec::new(), Vec::new());
            let mut d2 = vec![0.0; m * W * n];
            vjp_pde_lanes::<W>(
                &block, m, n, lam1, lam2, &grid, &seeds, &mut d1a, &mut d1b, &mut d2,
            );
            for (w, d) in deltas.iter().enumerate() {
                let sgrid = crate::kernel::solver::solve_pde_grid(d, m, n, lam1, lam2);
                let want = crate::kernel::backward::sig_kernel_vjp_delta(
                    d, m, n, lam1, lam2, &sgrid, seeds[w],
                );
                for s in 0..m {
                    for t in 0..n {
                        assert_eq!(
                            d2[(s * W + w) * n + t],
                            want[s * n + t],
                            "lane {w} cell ({s},{t}) m={m} n={n} λ=({lam1},{lam2})"
                        );
                    }
                }
            }
        });
    }

    #[test]
    fn vjp_gram_row_bitmatches_scalar_for_every_width() {
        let mut rng = Rng::new(912);
        let d = 2;
        // Ragged y with repeated lengths (so groups form), a degenerate path
        // and a zero-weight column (both must be skipped identically).
        let ylens = [5usize, 7, 5, 5, 7, 5, 1, 5, 7, 5, 5, 7, 5, 5];
        let mut ydata = Vec::new();
        for &l in &ylens {
            ydata.extend(rng.brownian_path(l, d, 0.4));
        }
        let yb = PathBatch::ragged(&ydata, &ylens, d).unwrap();
        let xdata = rng.brownian_path(6, d, 0.4);
        let xb = PathBatch::uniform(&xdata, 1, 6, d).unwrap();
        let lx = 6;
        let mut yo = vec![0usize; ylens.len() + 1];
        for (j, &l) in ylens.iter().enumerate() {
            yo[j + 1] = yo[j] + l * d;
        }
        let mut weights: Vec<f64> = (0..ylens.len()).map(|j| 0.3 + 0.1 * j as f64).collect();
        weights[3] = 0.0;
        for opts in [
            KernelOptions::default(),
            KernelOptions::default().dyadic(1, 2),
            KernelOptions::default().transform(Transform::LeadLag),
            KernelOptions::default().transform(Transform::TimeAug),
        ] {
            let mut gx_want = vec![0.0; lx * d];
            let mut gy_want = vec![0.0; ydata.len()];
            let mut sc = VjpLaneScratch::new();
            vjp_gram_row(
                &xb, 0, &yb, 0..ylens.len(), &weights, &opts, 0, &mut sc, &mut gx_want,
                &mut gy_want, &yo,
            );
            assert!(gx_want.iter().any(|v| *v != 0.0), "degenerate reference");
            for width in LANE_WIDTHS {
                let mut gx = vec![0.0; lx * d];
                let mut gy = vec![0.0; ydata.len()];
                let mut sc = VjpLaneScratch::new();
                vjp_gram_row(
                    &xb, 0, &yb, 0..ylens.len(), &weights, &opts, width, &mut sc, &mut gx,
                    &mut gy, &yo,
                );
                assert_eq!(gx, gx_want, "gx width={width} opts={opts:?}");
                assert_eq!(gy, gy_want, "gy width={width} opts={opts:?}");
            }
        }
        // The zero-weight column and the degenerate path must receive no
        // gradient at all.
        let mut gx = vec![0.0; lx * d];
        let mut gy = vec![0.0; ydata.len()];
        let mut sc = VjpLaneScratch::new();
        vjp_gram_row(
            &xb, 0, &yb, 0..ylens.len(), &weights, &KernelOptions::default(), 8, &mut sc,
            &mut gx, &mut gy, &yo,
        );
        assert!(gy[yo[3]..yo[4]].iter().all(|v| *v == 0.0), "zero-weight column");
        assert!(gy[yo[6]..yo[7]].iter().all(|v| *v == 0.0), "degenerate column");
    }

    #[test]
    fn backward_occupancy_counters_move_with_lane_traffic() {
        let before = stats();
        let mut rng = Rng::new(913);
        let d = 2;
        let n = 11; // one group of 8 + three scalar remainder pairs
        let data = rng.brownian_batch(n, 6, d, 0.4);
        let yb = PathBatch::uniform(&data, n, 6, d).unwrap();
        let x = rng.brownian_path(5, d, 0.4);
        let xb = PathBatch::uniform(&x, 1, 5, d).unwrap();
        let mut yo = vec![0usize; n + 1];
        for j in 0..n {
            yo[j + 1] = yo[j] + 6 * d;
        }
        let weights = vec![1.0; n];
        let mut gx = vec![0.0; 5 * d];
        let mut gy = vec![0.0; data.len()];
        let mut sc = VjpLaneScratch::new();
        vjp_gram_row(
            &xb, 0, &yb, 0..n, &weights, &KernelOptions::default(), 8, &mut sc, &mut gx,
            &mut gy, &yo,
        );
        let after = stats();
        assert!(after.vjp_lane_groups >= before.vjp_lane_groups + 1);
        assert!(after.vjp_scalar_pairs >= before.vjp_scalar_pairs + 3);
    }

    #[test]
    fn width_normalisation_and_defaults() {
        assert_eq!(normalize_lane_width(0), 0);
        assert_eq!(normalize_lane_width(1), 0);
        assert_eq!(normalize_lane_width(2), 4);
        assert_eq!(normalize_lane_width(4), 4);
        assert_eq!(normalize_lane_width(5), 4);
        assert_eq!(normalize_lane_width(6), 8);
        assert_eq!(normalize_lane_width(8), 8);
        assert_eq!(normalize_lane_width(64), 8);
        assert_eq!(default_lane_width(true), 8);
        assert_eq!(default_lane_width(false), 4);
    }
}
