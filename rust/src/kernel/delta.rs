//! The increment inner-product matrix Δ[i,j] = ⟨dx_i, dy_j⟩ that drives the
//! Goursat PDE, with path transformations fused in rather than materialised
//! (paper design note (2): when d is large this matmul is almost all of the
//! runtime — it is a single blocked GEMM here, torch.bmm in pySigLib).

use crate::transforms::Transform;
use crate::util::linalg::{gemm, gemm_nt, gemm_tn};

/// Increments of `path` (`[len, dim]`): `[len-1, dim]`.
pub fn increments(path: &[f64], len: usize, dim: usize) -> Vec<f64> {
    let mut out = vec![0.0; (len - 1) * dim];
    increments_into(path, len, dim, &mut out);
    out
}

/// [`increments`] into caller-provided storage of length `(len-1)*dim`.
pub fn increments_into(path: &[f64], len: usize, dim: usize, out: &mut [f64]) {
    assert_eq!(path.len(), len * dim);
    assert!(len >= 2);
    assert_eq!(out.len(), (len - 1) * dim);
    for i in 0..len - 1 {
        for j in 0..dim {
            out[i * dim + j] = path[(i + 1) * dim + j] - path[i * dim + j];
        }
    }
}

/// Δ matrix for the *transformed* paths, built without materialising them.
///
/// Returns `(rows, cols, delta)` where `rows`/`cols` are the number of
/// increments of the transformed x/y and `delta` is row-major `[rows, cols]`.
///
/// * `None`:     Δ[i,j] = ⟨dx_i, dy_j⟩ — one GEMM.
/// * `TimeAug`:  Δ'[i,j] = Δ[i,j] + dt_x · dt_y (the time channels are
///   uniform, so their product is a constant shift).
/// * `LeadLag`:  the transformed increments alternate lead/lag moves; cross
///   parities are orthogonal, equal parities reduce to the base Δ:
///   Δ'[a,b] = (a ≡ b mod 2) ? Δ[⌊a/2⌋, ⌊b/2⌋] : 0.
/// * `LeadLagTimeAug`: lead-lag structure plus the constant time shift.
pub fn delta_matrix(
    x: &[f64],
    y: &[f64],
    lx: usize,
    ly: usize,
    dim: usize,
    transform: Transform,
) -> (usize, usize, Vec<f64>) {
    let m = lx - 1;
    let n = ly - 1;
    let rows = transform.out_len(lx) - 1;
    let cols = transform.out_len(ly) - 1;
    let mut dx = vec![0.0; m * dim];
    let mut dy = vec![0.0; n * dim];
    let needs_base = matches!(transform, Transform::LeadLag | Transform::LeadLagTimeAug);
    let mut base = vec![0.0; if needs_base { m * n } else { 0 }];
    let mut out = vec![0.0; rows * cols];
    delta_matrix_into(x, y, lx, ly, dim, transform, &mut dx, &mut dy, &mut base, &mut out);
    (rows, cols, out)
}

/// [`delta_matrix`] into caller-provided storage. `dx`/`dy` are scratch of
/// length `(lx-1)*dim` / `(ly-1)*dim`; `base` is scratch of length
/// `(lx-1)*(ly-1)` for the lead-lag transforms (and may be empty otherwise);
/// `out` has length `rows*cols` of the *transformed* Δ. Returns
/// `(rows, cols)`. The engine's kernel plans route every shape-dependent
/// buffer through their workspace arena via this entry point.
#[allow(clippy::too_many_arguments)]
pub fn delta_matrix_into(
    x: &[f64],
    y: &[f64],
    lx: usize,
    ly: usize,
    dim: usize,
    transform: Transform,
    dx: &mut [f64],
    dy: &mut [f64],
    base: &mut [f64],
    out: &mut [f64],
) -> (usize, usize) {
    let m = lx - 1;
    let n = ly - 1;
    increments_into(x, lx, dim, &mut dx[..m * dim]);
    increments_into(y, ly, dim, &mut dy[..n * dim]);
    match transform {
        Transform::None | Transform::TimeAug => {
            let out = &mut out[..m * n];
            gemm_nt(m, dim, n, &dx[..m * dim], &dy[..n * dim], out);
            if transform == Transform::TimeAug {
                let shift = (1.0 / m as f64) * (1.0 / n as f64);
                for v in out.iter_mut() {
                    *v += shift;
                }
            }
            (m, n)
        }
        Transform::LeadLag | Transform::LeadLagTimeAug => {
            let base = &mut base[..m * n];
            gemm_nt(m, dim, n, &dx[..m * dim], &dy[..n * dim], base);
            let rows = 2 * lx - 2;
            let cols = 2 * ly - 2;
            let shift = if transform == Transform::LeadLagTimeAug {
                (1.0 / rows as f64) * (1.0 / cols as f64)
            } else {
                0.0
            };
            let out = &mut out[..rows * cols];
            out.fill(shift);
            for a in 0..rows {
                for b in 0..cols {
                    if a % 2 == b % 2 {
                        out[a * cols + b] += base[(a / 2) * n + (b / 2)];
                    }
                }
            }
            (rows, cols)
        }
    }
}

/// Reduce the transformed ∂F/∂Δ' (`[rows, cols]`) to the base ∂F/∂Δ
/// (`[m, n]`). For `None`/`TimeAug` the transformed matrix *is* the base
/// matrix (the constant time shift has zero path derivative) and is returned
/// by reference without a copy; the lead-lag transforms fold equal parities
/// into `gd` (ascending `a` outer, `b` inner — the order every caller
/// replicates, so scalar and lane schedules stay bit-identical).
pub fn fold_grad_delta<'a>(
    grad_delta: &'a [f64],
    m: usize,
    n: usize,
    transform: Transform,
    gd: &'a mut [f64],
) -> &'a [f64] {
    match transform {
        Transform::None | Transform::TimeAug => {
            assert_eq!(grad_delta.len(), m * n);
            grad_delta
        }
        Transform::LeadLag | Transform::LeadLagTimeAug => {
            let rows = 2 * m;
            let cols = 2 * n;
            assert_eq!(grad_delta.len(), rows * cols);
            let gd = &mut gd[..m * n];
            gd.fill(0.0);
            for a in 0..rows {
                for b in 0..cols {
                    if a % 2 == b % 2 {
                        gd[(a / 2) * n + (b / 2)] += grad_delta[a * cols + b];
                    }
                }
            }
            gd
        }
    }
}

/// Δ[i,j] = ⟨dx_i, dy_j⟩ ⇒ ∂F/∂dx = gd·dy and ∂F/∂dy = gdᵀ·dx. Both GEMMs
/// zero their outputs, skip zero entries of `gd`, and accumulate each output
/// element in ascending shared-dimension order — term for term the historical
/// fused adjoint loop.
pub fn grad_increments_into(
    gd: &[f64],
    m: usize,
    n: usize,
    dim: usize,
    dx: &[f64],
    dy: &[f64],
    gdx: &mut [f64],
    gdy: &mut [f64],
) {
    gemm(m, n, dim, gd, &dy[..n * dim], &mut gdx[..m * dim]);
    gemm_tn(m, n, dim, gd, &dx[..m * dim], &mut gdy[..n * dim]);
}

/// Difference adjoint: dx_i = x_{i+1} − x_i, so each increment gradient
/// feeds `+` into the right endpoint and `−` into the left.
pub fn apply_difference_adjoint(grad: &mut [f64], gincr: &[f64], segs: usize, dim: usize) {
    for i in 0..segs {
        for c in 0..dim {
            grad[(i + 1) * dim + c] += gincr[i * dim + c];
            grad[i * dim + c] -= gincr[i * dim + c];
        }
    }
}

/// Scratch for [`delta_vjp_to_paths_with`] — every buffer grows monotonically
/// so a per-thread instance makes the backward hot loop allocation-free.
#[derive(Default)]
pub struct DeltaVjpScratch {
    pub gd: Vec<f64>,
    pub dx: Vec<f64>,
    pub dy: Vec<f64>,
    pub gdx: Vec<f64>,
    pub gdy: Vec<f64>,
}

impl DeltaVjpScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Grow every buffer to cover a `(lx, ly, dim)` pair.
    pub fn ensure(&mut self, lx: usize, ly: usize, dim: usize) {
        let m = lx.saturating_sub(1);
        let n = ly.saturating_sub(1);
        grow(&mut self.gd, m * n);
        grow(&mut self.dx, m * dim);
        grow(&mut self.dy, n * dim);
        grow(&mut self.gdx, m * dim);
        grow(&mut self.gdy, n * dim);
    }
}

fn grow(buf: &mut Vec<f64>, len: usize) {
    if buf.len() < len {
        buf.resize(len, 0.0);
    }
}

/// Adjoint of [`delta_matrix`]: given ∂F/∂Δ' (`[rows, cols]` of the
/// transformed Δ), accumulate ∂F/∂x and ∂F/∂y (`[lx, dim]`, `[ly, dim]`).
pub fn delta_vjp_to_paths(
    grad_delta: &[f64],
    x: &[f64],
    y: &[f64],
    lx: usize,
    ly: usize,
    dim: usize,
    transform: Transform,
    grad_x: &mut [f64],
    grad_y: &mut [f64],
) {
    let mut sc = DeltaVjpScratch::new();
    sc.ensure(lx, ly, dim);
    delta_vjp_to_paths_with(grad_delta, x, y, lx, ly, dim, transform, &mut sc, grad_x, grad_y);
}

/// [`delta_vjp_to_paths`] against caller-provided scratch (`ensure`d for the
/// pair) — the allocation-free form the backward hot loops use. Bit-identical
/// to the allocating wrapper: identical stages on identical inputs.
#[allow(clippy::too_many_arguments)]
pub fn delta_vjp_to_paths_with(
    grad_delta: &[f64],
    x: &[f64],
    y: &[f64],
    lx: usize,
    ly: usize,
    dim: usize,
    transform: Transform,
    sc: &mut DeltaVjpScratch,
    grad_x: &mut [f64],
    grad_y: &mut [f64],
) {
    let m = lx - 1;
    let n = ly - 1;
    increments_into(x, lx, dim, &mut sc.dx[..m * dim]);
    increments_into(y, ly, dim, &mut sc.dy[..n * dim]);
    let gd = fold_grad_delta(grad_delta, m, n, transform, &mut sc.gd);
    grad_increments_into(gd, m, n, dim, &sc.dx, &sc.dy, &mut sc.gdx, &mut sc.gdy);
    apply_difference_adjoint(grad_x, &sc.gdx[..m * dim], m, dim);
    apply_difference_adjoint(grad_y, &sc.gdy[..n * dim], n, dim);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn delta_matches_materialised_transform() {
        check("fused Δ == materialised Δ", 25, |g| {
            let lx = g.usize_in(2, 8);
            let ly = g.usize_in(2, 8);
            let d = g.usize_in(1, 4);
            let x = g.path(lx, d, 0.7);
            let y = g.path(ly, d, 0.7);
            for tr in [
                Transform::None,
                Transform::TimeAug,
                Transform::LeadLag,
                Transform::LeadLagTimeAug,
            ] {
                let (r, c, fused) = delta_matrix(&x, &y, lx, ly, d, tr);
                let xm = crate::transforms::apply(tr, &x, lx, d);
                let ym = crate::transforms::apply(tr, &y, ly, d);
                let (rm, cm, mat) = delta_matrix(
                    &xm,
                    &ym,
                    tr.out_len(lx),
                    tr.out_len(ly),
                    tr.out_dim(d),
                    Transform::None,
                );
                assert_eq!((r, c), (rm, cm), "tr={tr:?}");
                let err = crate::util::linalg::max_abs_diff(&fused, &mat);
                assert!(err < 1e-12, "tr={tr:?}: {err}");
            }
        });
    }

    #[test]
    fn delta_vjp_matches_finite_difference() {
        check("Δ vjp", 10, |g| {
            let lx = g.usize_in(2, 5);
            let ly = g.usize_in(2, 5);
            let d = g.usize_in(1, 3);
            let x = g.path(lx, d, 0.7);
            let y = g.path(ly, d, 0.7);
            for tr in [Transform::None, Transform::TimeAug, Transform::LeadLag] {
                let (r, c, _) = delta_matrix(&x, &y, lx, ly, d, tr);
                let gd = g.normal_vec(r * c);
                let mut gx = vec![0.0; lx * d];
                let mut gy = vec![0.0; ly * d];
                delta_vjp_to_paths(&gd, &x, &y, lx, ly, d, tr, &mut gx, &mut gy);
                let f = |xx: &[f64], yy: &[f64]| -> f64 {
                    let (_, _, dm) = delta_matrix(xx, yy, lx, ly, d, tr);
                    dm.iter().zip(gd.iter()).map(|(a, b)| a * b).sum()
                };
                let eps = 1e-6;
                for i in 0..lx * d {
                    let mut xp = x.to_vec();
                    xp[i] += eps;
                    let mut xm_ = x.to_vec();
                    xm_[i] -= eps;
                    let fd = (f(&xp, &y) - f(&xm_, &y)) / (2.0 * eps);
                    assert!((fd - gx[i]).abs() < 1e-4, "tr={tr:?} x[{i}]: {fd} vs {}", gx[i]);
                }
                for j in 0..ly * d {
                    let mut yp = y.to_vec();
                    yp[j] += eps;
                    let mut ym_ = y.to_vec();
                    ym_[j] -= eps;
                    let fd = (f(&x, &yp) - f(&x, &ym_)) / (2.0 * eps);
                    assert!((fd - gy[j]).abs() < 1e-4, "tr={tr:?} y[{j}]: {fd} vs {}", gy[j]);
                }
            }
        });
    }
}
