//! Goursat border-strip solves for streaming path extension.
//!
//! Extending a registered corpus path from `L` to `L + L_new` points moves
//! the right/bottom edges of every PDE grid that path participates in. The
//! full grid never needs to be re-solved: the Goursat recurrence
//!
//!   k[s+1,t+1] = (k[s+1,t] + k[s,t+1])·A(p) − k[s,t]·B(p)
//!
//! only looks one row up and one column left, so retaining the **last grid
//! row** (`bottom`) and **last grid column** (`right`) of each solved pair
//! is enough to continue the sweep into the new strip:
//!
//! * appending rows (the x path grew): sweep `L_new·2^λ1` fresh rows from
//!   the retained bottom row — `O(L_new · L)` cells;
//! * appending columns (the y path grew): sweep the `L_new·2^λ2`-wide
//!   column strip down all retained rows, seeding each row's left neighbour
//!   from the retained right column — `O(L · L_new)` cells;
//! * both (the diagonal pair): columns first across the old rows, then rows
//!   at the full new width.
//!
//! Every cell is computed by exactly the same floating-point expression on
//! exactly the same neighbour values as [`super::solver::solve_pde_with`]
//! (same dyadic-run coefficient hoist, same evaluation order within a row),
//! so strip extension is **bit-identical** to re-solving the whole grid from
//! scratch — asserted cell-for-cell by the property tests below.
//!
//! The process-wide [`border_cells_solved`] counter mirrors the lane
//! engine's occupancy counters: tests and the `corpus watch` CLI use it to
//! assert that an extension solved `O(L_new·L)` cells, not `O(L²)`.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::path::SigError;

/// Cells solved by border sweeps (full retaining solves + strip extensions),
/// process-wide. Monotone counter: always `Ordering::Relaxed`.
static BORDER_CELLS: AtomicU64 = AtomicU64::new(0);

fn count_cells(n: u64) {
    BORDER_CELLS.fetch_add(n, Ordering::Relaxed);
}

/// Total grid cells solved by this module since process start.
pub fn border_cells_solved() -> u64 {
    BORDER_CELLS.load(Ordering::Relaxed)
}

/// Retained boundary state of one solved Goursat grid: the last row and
/// last column (each including its 1.0 boundary corner at index 0). The
/// terminal kernel value is the shared last element of both.
#[derive(Clone, Debug, PartialEq)]
pub struct PairBorder {
    /// Grid row `rows`: `cols + 1` values, `bottom[0] = 1.0`.
    bottom: Vec<f64>,
    /// Grid column `cols`: `rows + 1` values, `right[0] = 1.0`.
    right: Vec<f64>,
}

impl PairBorder {
    /// Terminal kernel value k(1,1) of the solved grid.
    pub fn terminal(&self) -> f64 {
        self.bottom.last().copied().unwrap_or(1.0)
    }

    /// Refined row count of the solved grid.
    pub fn rows(&self) -> usize {
        self.right.len().saturating_sub(1)
    }

    /// Refined column count of the solved grid.
    pub fn cols(&self) -> usize {
        self.bottom.len().saturating_sub(1)
    }

    /// Retained memory in f64 slots (for cache accounting).
    pub fn retained_len(&self) -> usize {
        self.bottom.len() + self.right.len()
    }

    /// The retained `(bottom, right)` vectors — the snapshot serialiser's
    /// view of the border state.
    pub fn parts(&self) -> (&[f64], &[f64]) {
        (&self.bottom, &self.right)
    }

    /// Reassemble a border from its retained vectors (snapshot restore).
    /// Validates the structural invariants — both vectors non-empty, both
    /// starting at the 1.0 boundary corner, and sharing their terminal
    /// value bit-for-bit — so a corrupt snapshot section cannot smuggle a
    /// malformed border into the strip-extension sweeps.
    pub fn from_parts(bottom: Vec<f64>, right: Vec<f64>) -> Result<PairBorder, SigError> {
        let corners_ok = matches!((bottom.first(), right.first()), (Some(&b0), Some(&r0))
            if b0.to_bits() == 1.0f64.to_bits() && r0.to_bits() == 1.0f64.to_bits());
        let terminal_ok = matches!((bottom.last(), right.last()), (Some(&bl), Some(&rl))
            if bl.to_bits() == rl.to_bits());
        if !corners_ok || !terminal_ok {
            return Err(SigError::Invalid(
                "border parts must start at the 1.0 corner and share a terminal",
            ));
        }
        Ok(PairBorder { bottom, right })
    }
}

/// Refined grid extents and the shared p-scale for a `[m, n]` delta at
/// dyadic orders (λ1, λ2); errors instead of overflowing.
fn extents(m: usize, n: usize, lam1: u32, lam2: u32) -> Result<(usize, usize, f64), SigError> {
    if lam1 + lam2 >= 63 {
        return Err(SigError::Invalid("dyadic order too large for a border solve"));
    }
    let rows = m
        .checked_shl(lam1)
        .ok_or(SigError::TooLarge("border grid rows"))?;
    let cols = n
        .checked_shl(lam2)
        .ok_or(SigError::TooLarge("border grid cols"))?;
    rows.checked_mul(cols)
        .ok_or(SigError::TooLarge("border grid cells"))?;
    let scale = 1.0 / (1u64 << (lam1 + lam2)) as f64;
    Ok((rows, cols, scale))
}

/// Advance one grid row. `prev` holds the previous full row (`cols + 1`
/// values including its left entry); `cur[0]` holds this row's left
/// neighbour on entry and `cur[1..]` receives the new cells. The
/// coefficient stream replays [`super::solver::solve_pde_with`] exactly:
/// A/B hoisted once per 2^λ2-cell dyadic run, cells in ascending t.
fn sweep_row(drow: &[f64], scale: f64, run: usize, prev: &[f64], cur: &mut [f64]) {
    let Some((first, rest)) = cur.split_first_mut() else {
        return;
    };
    let mut k_left = *first;
    let mut cur_iter = rest.iter_mut();
    let mut prev_iter = prev.windows(2);
    for &d in drow {
        let p = d * scale;
        let p2 = p * p * (1.0 / 12.0);
        let a = 1.0 + 0.5 * p + p2;
        let b = 1.0 - p2;
        for _ in 0..run {
            let (Some(w), Some(c)) = (prev_iter.next(), cur_iter.next()) else {
                return;
            };
            let [pt, pt1] = w else {
                return;
            };
            let v = (k_left + *pt1) * a - *pt * b;
            *c = v;
            k_left = v;
        }
    }
}

/// Solve the full `[m, n]` grid once, retaining its border. `O(m·n·2^{λ1+λ2})`
/// cells — paid once per pair when a path first enters the streaming regime;
/// every later extension is a strip.
pub fn solve_full_retain(
    delta: &[f64],
    m: usize,
    n: usize,
    lam1: u32,
    lam2: u32,
) -> Result<PairBorder, SigError> {
    if m == 0 || n == 0 || delta.len() != m * n {
        return Err(SigError::Invalid("border solve: delta shape mismatch"));
    }
    let (rows, cols, scale) = extents(m, n, lam1, lam2)?;
    let run = 1usize << lam2;
    let mut prev = vec![1.0; cols + 1];
    let mut cur = vec![1.0; cols + 1];
    let mut right = Vec::with_capacity(rows + 1);
    right.push(1.0);
    for s in 0..rows {
        if let Some(c0) = cur.first_mut() {
            *c0 = 1.0;
        }
        let base = (s >> lam1) * n;
        let drow = delta
            .get(base..base + n)
            .ok_or(SigError::Invalid("border solve: delta row out of range"))?;
        sweep_row(drow, scale, run, &prev, &mut cur);
        right.push(cur.last().copied().unwrap_or(1.0));
        std::mem::swap(&mut prev, &mut cur);
    }
    count_cells((rows * cols) as u64);
    Ok(PairBorder { bottom: prev, right })
}

/// Extend a solved grid downward: the x path gained increments, `strip` is
/// the `[m_add, n]` delta of the new rows against the full y. Sweeps
/// `m_add·2^λ1` rows from the retained bottom; `O(m_add·n)` cells. The new
/// rows' terminals append to `right`; `bottom` is replaced.
pub fn extend_rows(
    border: &mut PairBorder,
    strip: &[f64],
    m_add: usize,
    n: usize,
    lam1: u32,
    lam2: u32,
) -> Result<(), SigError> {
    if m_add == 0 || n == 0 || strip.len() != m_add * n {
        return Err(SigError::Invalid("border extend: row-strip shape mismatch"));
    }
    let (add_rows, cols, scale) = extents(m_add, n, lam1, lam2)?;
    if border.bottom.len() != cols + 1 {
        return Err(SigError::Invalid("border extend: retained bottom row width mismatch"));
    }
    let run = 1usize << lam2;
    let mut prev = std::mem::take(&mut border.bottom);
    let mut cur = vec![1.0; cols + 1];
    for s in 0..add_rows {
        if let Some(c0) = cur.first_mut() {
            *c0 = 1.0;
        }
        let base = (s >> lam1) * n;
        let drow = strip
            .get(base..base + n)
            .ok_or(SigError::Invalid("border extend: strip row out of range"))?;
        sweep_row(drow, scale, run, &prev, &mut cur);
        border.right.push(cur.last().copied().unwrap_or(1.0));
        std::mem::swap(&mut prev, &mut cur);
    }
    border.bottom = prev;
    count_cells((add_rows * cols) as u64);
    Ok(())
}

/// Extend a solved grid rightward: the y path gained increments, `strip` is
/// the `[m, n_add]` delta of all existing x rows against the new y columns.
/// Sweeps the `n_add·2^λ2`-wide column strip down the retained rows, seeding
/// each row's left neighbour from the retained right column; `O(m·n_add)`
/// cells. The last strip row extends `bottom`; `right` is replaced.
pub fn extend_cols(
    border: &mut PairBorder,
    strip: &[f64],
    m: usize,
    n_add: usize,
    lam1: u32,
    lam2: u32,
) -> Result<(), SigError> {
    if m == 0 || n_add == 0 || strip.len() != m * n_add {
        return Err(SigError::Invalid("border extend: col-strip shape mismatch"));
    }
    let (rows, strip_cols, scale) = extents(m, n_add, lam1, lam2)?;
    if border.right.len() != rows + 1 {
        return Err(SigError::Invalid("border extend: retained right column height mismatch"));
    }
    let run = 1usize << lam2;
    let mut prev = vec![1.0; strip_cols + 1];
    let mut cur = vec![1.0; strip_cols + 1];
    let mut new_right = Vec::with_capacity(rows + 1);
    new_right.push(1.0);
    for s in 0..rows {
        let left = border
            .right
            .get(s + 1)
            .copied()
            .ok_or(SigError::Invalid("border extend: right column out of range"))?;
        if let Some(c0) = cur.first_mut() {
            *c0 = left;
        }
        let base = (s >> lam1) * n_add;
        let drow = strip
            .get(base..base + n_add)
            .ok_or(SigError::Invalid("border extend: strip row out of range"))?;
        sweep_row(drow, scale, run, &prev, &mut cur);
        new_right.push(cur.last().copied().unwrap_or(1.0));
        std::mem::swap(&mut prev, &mut cur);
    }
    border.bottom.extend_from_slice(prev.get(1..).unwrap_or(&[]));
    border.right = new_right;
    count_cells((rows * strip_cols) as u64);
    Ok(())
}

// ---------------------------------------------------------------------------
// Scheme-aware borders: a streaming pair solved under `Scheme::Order2`
// retains TWO borders — the fine grid's at (λ1, λ2) and the coarse grid's at
// the coarsened orders — and every strip extension continues both sweeps, so
// the Richardson-combined terminal stays bit-identical to a from-scratch
// `solve_pde_scheme` after any append sequence.

use crate::kernel::scheme::{coarse_orders, order2_degenerate, richardson_combine, Scheme};

/// Retained border state of one streaming pair under a solver scheme.
/// `Order1` (and degenerate `Order2` at λ = (0,0)) keep only the fine
/// border; non-degenerate `Order2` also keeps the coarse grid's.
#[derive(Clone, Debug, PartialEq)]
pub struct SchemeBorder {
    fine: PairBorder,
    coarse: Option<PairBorder>,
}

impl SchemeBorder {
    /// Terminal kernel value under the scheme the border was solved with:
    /// the fine terminal alone, or the Richardson combine when a coarse
    /// border is retained.
    pub fn terminal(&self) -> f64 {
        match &self.coarse {
            None => self.fine.terminal(),
            Some(c) => richardson_combine(self.fine.terminal(), c.terminal()),
        }
    }

    /// Retained memory in f64 slots across both borders.
    pub fn retained_len(&self) -> usize {
        self.fine.retained_len() + self.coarse.as_ref().map_or(0, PairBorder::retained_len)
    }

    /// Refined row count of the fine grid.
    pub fn rows(&self) -> usize {
        self.fine.rows()
    }

    /// Refined column count of the fine grid.
    pub fn cols(&self) -> usize {
        self.fine.cols()
    }

    /// The fine-grid border (snapshot serialisation).
    pub fn fine(&self) -> &PairBorder {
        &self.fine
    }

    /// The coarse-grid border, when the scheme retained one.
    pub fn coarse(&self) -> Option<&PairBorder> {
        self.coarse.as_ref()
    }

    /// Reassemble from validated pair borders (snapshot restore).
    pub fn from_parts(fine: PairBorder, coarse: Option<PairBorder>) -> SchemeBorder {
        SchemeBorder { fine, coarse }
    }
}

/// Whether `scheme` at (λ1, λ2) needs a second, coarse border.
fn wants_coarse(scheme: Scheme, lam1: u32, lam2: u32) -> bool {
    scheme == Scheme::Order2 && !order2_degenerate(lam1, lam2)
}

/// Scheme-aware [`solve_full_retain`]: one fine solve, plus the coarse
/// solve when the scheme calls for it.
pub fn solve_full_retain_scheme(
    delta: &[f64],
    m: usize,
    n: usize,
    lam1: u32,
    lam2: u32,
    scheme: Scheme,
) -> Result<SchemeBorder, SigError> {
    let fine = solve_full_retain(delta, m, n, lam1, lam2)?;
    let coarse = if wants_coarse(scheme, lam1, lam2) {
        let (c1, c2) = coarse_orders(lam1, lam2);
        Some(solve_full_retain(delta, m, n, c1, c2)?)
    } else {
        None
    };
    Ok(SchemeBorder { fine, coarse })
}

/// Scheme-aware [`extend_rows`]: continues the fine sweep and, when
/// retained, the coarse sweep over the same strip.
pub fn extend_rows_scheme(
    border: &mut SchemeBorder,
    strip: &[f64],
    m_add: usize,
    n: usize,
    lam1: u32,
    lam2: u32,
) -> Result<(), SigError> {
    extend_rows(&mut border.fine, strip, m_add, n, lam1, lam2)?;
    if let Some(coarse) = border.coarse.as_mut() {
        let (c1, c2) = coarse_orders(lam1, lam2);
        extend_rows(coarse, strip, m_add, n, c1, c2)?;
    }
    Ok(())
}

/// Scheme-aware [`extend_cols`]: continues the fine sweep and, when
/// retained, the coarse sweep over the same strip.
pub fn extend_cols_scheme(
    border: &mut SchemeBorder,
    strip: &[f64],
    m: usize,
    n_add: usize,
    lam1: u32,
    lam2: u32,
) -> Result<(), SigError> {
    extend_cols(&mut border.fine, strip, m, n_add, lam1, lam2)?;
    if let Some(coarse) = border.coarse.as_mut() {
        let (c1, c2) = coarse_orders(lam1, lam2);
        extend_cols(coarse, strip, m, n_add, c1, c2)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::solver::solve_pde_grid;
    use crate::util::prop::check;

    /// Border of the full grid, extracted from a from-scratch whole-grid
    /// solve — the reference every strip path must bit-match.
    fn reference_border(delta: &[f64], m: usize, n: usize, lam1: u32, lam2: u32) -> PairBorder {
        let rows = m << lam1;
        let cols = n << lam2;
        let grid = solve_pde_grid(delta, m, n, lam1, lam2);
        let w = cols + 1;
        let bottom = grid[rows * w..(rows + 1) * w].to_vec();
        let right = (0..=rows).map(|s| grid[s * w + cols]).collect();
        PairBorder { bottom, right }
    }

    #[test]
    fn full_retain_bitmatches_whole_grid_solve() {
        check("solve_full_retain == grid border", 25, |g| {
            let m = g.usize_in(1, 10);
            let n = g.usize_in(1, 10);
            let lam1 = g.usize_in(0, 2) as u32;
            let lam2 = g.usize_in(0, 2) as u32;
            let delta: Vec<f64> = g.normal_vec(m * n).iter().map(|v| v * 0.3).collect();
            let got = solve_full_retain(&delta, m, n, lam1, lam2).unwrap();
            let want = reference_border(&delta, m, n, lam1, lam2);
            assert_eq!(got, want, "m={m} n={n} λ=({lam1},{lam2})");
        });
    }

    #[test]
    fn row_extension_bitmatches_from_scratch() {
        check("extend_rows == rescratch", 25, |g| {
            let m = g.usize_in(1, 8);
            let m_add = g.usize_in(1, 6);
            let n = g.usize_in(1, 8);
            let lam1 = g.usize_in(0, 2) as u32;
            let lam2 = g.usize_in(0, 2) as u32;
            let full: Vec<f64> = g.normal_vec((m + m_add) * n).iter().map(|v| v * 0.3).collect();
            let mut b = solve_full_retain(&full[..m * n], m, n, lam1, lam2).unwrap();
            extend_rows(&mut b, &full[m * n..], m_add, n, lam1, lam2).unwrap();
            let want = solve_full_retain(&full, m + m_add, n, lam1, lam2).unwrap();
            assert_eq!(b, want, "m={m}+{m_add} n={n} λ=({lam1},{lam2})");
        });
    }

    #[test]
    fn col_extension_bitmatches_from_scratch() {
        check("extend_cols == rescratch", 25, |g| {
            let m = g.usize_in(1, 8);
            let n = g.usize_in(1, 8);
            let n_add = g.usize_in(1, 6);
            let lam1 = g.usize_in(0, 2) as u32;
            let lam2 = g.usize_in(0, 2) as u32;
            // Row-major [m, n + n_add] delta, split into left block + strip.
            let full: Vec<f64> = g
                .normal_vec(m * (n + n_add))
                .iter()
                .map(|v| v * 0.3)
                .collect();
            let nc = n + n_add;
            let left: Vec<f64> =
                (0..m).flat_map(|i| full[i * nc..i * nc + n].to_vec()).collect();
            let strip: Vec<f64> =
                (0..m).flat_map(|i| full[i * nc + n..(i + 1) * nc].to_vec()).collect();
            let mut b = solve_full_retain(&left, m, n, lam1, lam2).unwrap();
            extend_cols(&mut b, &strip, m, n_add, lam1, lam2).unwrap();
            let want = solve_full_retain(&full, m, nc, lam1, lam2).unwrap();
            assert_eq!(b, want, "m={m} n={n}+{n_add} λ=({lam1},{lam2})");
        });
    }

    #[test]
    fn diagonal_extension_composes_cols_then_rows() {
        // Both sides grew (the self-pair of an extended path): extend the
        // old rows rightward first, then sweep the new rows at full width.
        check("diag extension == rescratch", 25, |g| {
            let m = g.usize_in(1, 7);
            let add = g.usize_in(1, 5);
            let lam = g.usize_in(0, 2) as u32;
            let nt = m + add;
            let full: Vec<f64> = g.normal_vec(nt * nt).iter().map(|v| v * 0.3).collect();
            let top_left: Vec<f64> =
                (0..m).flat_map(|i| full[i * nt..i * nt + m].to_vec()).collect();
            let col_strip: Vec<f64> =
                (0..m).flat_map(|i| full[i * nt + m..(i + 1) * nt].to_vec()).collect();
            let row_strip = full[m * nt..].to_vec();
            let mut b = solve_full_retain(&top_left, m, m, lam, lam).unwrap();
            extend_cols(&mut b, &col_strip, m, add, lam, lam).unwrap();
            extend_rows(&mut b, &row_strip, add, nt, lam, lam).unwrap();
            let want = solve_full_retain(&full, nt, nt, lam, lam).unwrap();
            assert_eq!(b, want, "m={m}+{add} λ={lam}");
        });
    }

    #[test]
    fn strip_extension_counts_strip_cells_only() {
        let (m, n, add) = (6, 6, 2);
        let delta = vec![0.1; (m + add) * n];
        let mut b = solve_full_retain(&delta[..m * n], m, n, 1, 1).unwrap();
        let before = border_cells_solved();
        extend_rows(&mut b, &delta[m * n..], add, n, 1, 1).unwrap();
        let solved = border_cells_solved() - before;
        assert_eq!(solved, ((add << 1) * (n << 1)) as u64);
        assert!(solved < ((m + add) << 1) as u64 * ((n << 1) as u64));
    }

    #[test]
    fn scheme_border_extension_bitmatches_scheme_rescratch() {
        // An Order-2 streaming pair extended by strips must land on exactly
        // the terminal a from-scratch `solve_pde_scheme` produces — both
        // retained sweeps continue, and the combine is the same expression.
        check("scheme strips == scheme rescratch", 20, |g| {
            let m = g.usize_in(1, 7);
            let add = g.usize_in(1, 5);
            let lam = g.usize_in(0, 2) as u32;
            let nt = m + add;
            let full: Vec<f64> = g.normal_vec(nt * nt).iter().map(|v| v * 0.3).collect();
            let top_left: Vec<f64> =
                (0..m).flat_map(|i| full[i * nt..i * nt + m].to_vec()).collect();
            let col_strip: Vec<f64> =
                (0..m).flat_map(|i| full[i * nt + m..(i + 1) * nt].to_vec()).collect();
            let row_strip = full[m * nt..].to_vec();
            for scheme in [Scheme::Order1, Scheme::Order2] {
                let mut b = solve_full_retain_scheme(&top_left, m, m, lam, lam, scheme).unwrap();
                extend_cols_scheme(&mut b, &col_strip, m, add, lam, lam).unwrap();
                extend_rows_scheme(&mut b, &row_strip, add, nt, lam, lam).unwrap();
                let want = solve_full_retain_scheme(&full, nt, nt, lam, lam, scheme).unwrap();
                assert_eq!(b, want, "{scheme:?} m={m}+{add} λ={lam}");
                let (mut sp, mut sc) = (Vec::new(), Vec::new());
                let direct = crate::kernel::solver::solve_pde_scheme(
                    &full, nt, nt, lam, lam, scheme, &mut sp, &mut sc,
                );
                assert_eq!(b.terminal(), direct, "{scheme:?} terminal m={m}+{add} λ={lam}");
            }
        });
        // Degenerate Order2 at λ = (0,0) retains no coarse border.
        let delta = vec![0.1; 9];
        let b = solve_full_retain_scheme(&delta, 3, 3, 0, 0, Scheme::Order2).unwrap();
        assert!(b.coarse.is_none());
        assert_eq!(b.terminal(), solve_full_retain(&delta, 3, 3, 0, 0).unwrap().terminal());
    }

    #[test]
    fn shape_mismatches_are_typed_errors() {
        let delta = vec![0.1; 6];
        assert!(solve_full_retain(&delta, 2, 4, 0, 0).is_err());
        let mut b = solve_full_retain(&delta, 2, 3, 0, 0).unwrap();
        assert!(extend_rows(&mut b, &delta, 1, 4, 0, 0).is_err());
        assert!(extend_cols(&mut b, &delta, 3, 2, 0, 0).is_err());
        assert!(extend_rows(&mut b, &[], 0, 3, 0, 0).is_err());
    }
}
