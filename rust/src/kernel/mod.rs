//! Signature kernels (paper §3): the Goursat-PDE solver (Algorithm 3) with
//! on-the-fly dyadic refinement and independent orders λ1 ≠ λ2, a blocked
//! anti-diagonal solver mirroring the paper's GPU scheme (§3.3), the novel
//! exact backpropagation (Algorithm 4, §3.4), the approximate PDE-based
//! baseline it replaces, batched / Gram APIs with a GEMM Δ precompute, and
//! the [`lanes`] engine that advances W independent pair-PDEs per sweep
//! (the SIMD-across-pairs schedule every Gram/MMD²/corpus producer rides).

pub mod backward;
pub mod blocked;
pub mod border;
pub mod delta;
pub mod gram;
pub mod krr;
pub mod lanes;
pub mod lift;
pub mod lowrank;
pub mod pde_baseline;
pub mod scheme;
pub mod solver;

pub use backward::{sig_kernel_vjp, sig_kernel_vjp_delta, sig_kernel_vjp_delta_acc,
    sig_kernel_vjp_delta_into, sig_kernel_vjp_delta_scheme_into, try_sig_kernel_vjp};
pub use blocked::solve_pde_blocked;
pub use border::{border_cells_solved, PairBorder, SchemeBorder};
pub use delta::{delta_matrix, delta_vjp_to_paths};
pub use gram::{
    batch_kernel, batch_kernel_vjp, gram, gram_vjp, mmd2, mmd2_with_grad, try_batch_kernel,
    try_batch_kernel_vjp, try_gram, try_gram_vjp, try_gram_vjp_with_lanes, try_mmd2,
    try_mmd2_unbiased, try_mmd2_unbiased_with_grad, try_mmd2_with_grad,
};
pub(crate) use gram::gram_vjp_sym_with_lanes;
pub use krr::KernelRidge;
pub use lanes::{
    solve_pde_lanes, solve_pde_lanes_scheme, vjp_pde_lanes, vjp_pde_lanes_acc, LaneScratch,
    LaneStats,
};
pub use lowrank::{
    try_gram_lowrank, try_mmd2_lowrank, try_mmd2_lowrank_unbiased, try_mmd2_lowrank_with_grad,
    FeatureMap, LowRankFeatures, LowRankMethod, LowRankRidge, LowRankSpec, NystromFeatures,
    RandomSigFeatures, SketchKind,
};
pub use lift::{lifted_delta, sig_kernel_lifted, StaticKernel};
pub use pde_baseline::sig_kernel_vjp_pde_approx;
pub use scheme::{resolve_target_eps, Scheme, TargetEps};
pub use solver::{
    pde_cells_solved, solve_pde, solve_pde_grid, solve_pde_grid_into, solve_pde_scheme,
    solve_pde_with,
};

pub use crate::path::KernelOptions;

use crate::path::{Path, SigError};

/// Which PDE sweep to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SolverKind {
    /// Row-major two-row sweep — the CPU algorithm (Algorithm 3).
    Row,
    /// Anti-diagonal sweep in row-blocks of 32 with three rotating diagonal
    /// buffers — the paper's GPU dataflow (§3.3), simulated on CPU.
    Blocked,
}

/// Hard cap on refined PDE grid cells reachable from the fallible API
/// (2^30 ≈ 1e9 cells) — guards wire-supplied dyadic orders and lengths
/// against shift overflow and absurd allocations.
const MAX_GRID_CELLS: u128 = 1 << 30;

/// Validate that the dyadically refined grid for an (lx, ly) pair is sane.
pub(crate) fn check_grid_size(
    lx: usize,
    ly: usize,
    opts: &KernelOptions,
) -> Result<(), SigError> {
    if opts.dyadic_x > 32 || opts.dyadic_y > 32 {
        return Err(SigError::TooLarge("dyadic refinement order"));
    }
    // The transform can lengthen the paths (lead-lag: 2L−1); bound the grid
    // the solver actually sees.
    let tlx = opts.exec.transform.out_len(lx);
    let tly = opts.exec.transform.out_len(ly);
    let rows = ((tlx - 1) as u128) << opts.dyadic_x;
    let cols = ((tly - 1) as u128) << opts.dyadic_y;
    if (rows + 1) * (cols + 1) > MAX_GRID_CELLS {
        return Err(SigError::TooLarge("refined PDE grid"));
    }
    Ok(())
}

/// Typed, fallible signature kernel k(x, y). The paths must share a
/// dimension; a path with fewer than two points has the identity signature,
/// so the kernel degenerates to 1. A thin wrapper that compiles a one-shot
/// [`Plan`](crate::engine::Plan) — compile the plan once yourself (or use a
/// [`Session`](crate::engine::Session)) when the same shape class recurs.
pub fn try_sig_kernel(x: Path<'_>, y: Path<'_>, opts: &KernelOptions) -> Result<f64, SigError> {
    if x.dim() != y.dim() {
        return Err(SigError::DimMismatch {
            left: x.dim(),
            right: y.dim(),
        });
    }
    if x.len() < 2 || y.len() < 2 {
        return Ok(1.0);
    }
    check_grid_size(x.len(), y.len(), opts)?;
    let xb = crate::path::PathBatch::uniform(x.data(), 1, x.len(), x.dim())?;
    let yb = crate::path::PathBatch::uniform(y.data(), 1, y.len(), y.dim())?;
    let plan = crate::engine::Plan::compile_forward(
        crate::engine::OpSpec::SigKernel(*opts),
        crate::engine::ShapeClass::for_pair(&xb, &yb),
    )?;
    Ok(plan.execute_pair(&xb, &yb)?.value())
}

/// Signature kernel k(x, y) of two paths (`[lx, d]`, `[ly, d]` row-major) —
/// flat-slice wrapper over [`try_sig_kernel`]; panics on malformed shapes.
pub fn sig_kernel(
    x: &[f64],
    y: &[f64],
    lx: usize,
    ly: usize,
    dim: usize,
    opts: &KernelOptions,
) -> f64 {
    let xp = Path::new(x, lx, dim).expect("sig_kernel: invalid x shape");
    let yp = Path::new(y, ly, dim).expect("sig_kernel: invalid y shape");
    try_sig_kernel(xp, yp, opts).expect("sig_kernel")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transforms::Transform;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    #[test]
    fn typed_kernel_degenerate_and_mismatched_paths() {
        let x = [0.0, 0.0]; // single point in R^2
        let y = [0.0, 0.0, 1.0, 2.0];
        let xp = Path::new(&x, 1, 2).unwrap();
        let yp = Path::new(&y, 2, 2).unwrap();
        let opts = KernelOptions::default();
        // Identity signature ⇒ k == 1 exactly.
        assert_eq!(try_sig_kernel(xp, yp, &opts), Ok(1.0));
        let z = [0.0, 1.0, 2.0];
        let zp = Path::new(&z, 1, 3).unwrap();
        assert!(matches!(
            try_sig_kernel(yp, zp, &opts),
            Err(SigError::DimMismatch { .. })
        ));
    }

    /// k(x, y) for linear 1-d paths x_t = a·t, y_t = b·t on [0,1] is
    /// Σ_n (ab)^n / (n!)^2 (the signature inner product in closed form).
    #[test]
    fn linear_paths_match_bessel_series() {
        for &(a, b) in &[(0.5, 0.8), (1.0, 1.0), (-0.7, 1.3), (2.0, -0.4)] {
            let x = [0.0, a];
            let y = [0.0, b];
            let opts = KernelOptions::default().dyadic(7, 7);
            let got = sig_kernel(&x, &y, 2, 2, 1, &opts);
            let mut want = 0.0;
            let mut term = 1.0;
            for n in 0..40 {
                if n > 0 {
                    term *= (a * b) / (n as f64 * n as f64);
                }
                want += term;
            }
            assert!(
                (got - want).abs() < 1e-4 * want.abs().max(1.0),
                "a={a} b={b}: got {got}, want {want}"
            );
        }
    }

    #[test]
    fn kernel_is_symmetric() {
        check("kernel symmetry", 20, |g| {
            let lx = g.usize_in(2, 12);
            let ly = g.usize_in(2, 12);
            let d = g.usize_in(1, 4);
            let x = g.path(lx, d, 0.4);
            let y = g.path(ly, d, 0.4);
            let opts = KernelOptions::default();
            let kxy = sig_kernel(&x, &y, lx, ly, d, &opts);
            let kyx = sig_kernel(&y, &x, ly, lx, d, &opts);
            assert!((kxy - kyx).abs() < 1e-10, "{kxy} vs {kyx}");
        });
    }

    #[test]
    fn kernel_with_self_is_at_least_one() {
        // k(x,x) = ‖S(x)‖² ≥ 1 (level 0 contributes 1).
        check("k(x,x) >= 1", 15, |g| {
            let l = g.usize_in(2, 10);
            let d = g.usize_in(1, 3);
            let x = g.path(l, d, 0.4);
            let k = sig_kernel(&x, &x, l, l, d, &KernelOptions::default().dyadic(2, 2));
            assert!(k >= 1.0 - 1e-9, "k(x,x) = {k}");
        });
    }

    /// Against the explicit truncated signature inner product: for paths with
    /// small increments the signature series converges fast, so a deep
    /// truncated inner product approximates the kernel well.
    #[test]
    fn matches_truncated_signature_inner_product() {
        check("kernel ≈ <S(x), S(y)> truncated", 10, |g| {
            let lx = g.usize_in(2, 5);
            let ly = g.usize_in(2, 5);
            let d = g.usize_in(1, 3);
            let x = g.path(lx, d, 0.2);
            let y = g.path(ly, d, 0.2);
            let opts = KernelOptions::default().dyadic(6, 6);
            let k = sig_kernel(&x, &y, lx, ly, d, &opts);
            let depth = 10;
            let sx = crate::sig::sig(&x, lx, d, depth);
            let sy = crate::sig::sig(&y, ly, d, depth);
            let ip = crate::tensor::inner_product(&sx, &sy);
            assert!(
                (k - ip).abs() < 2e-3 * ip.abs().max(1.0),
                "kernel {k} vs truncated inner product {ip}"
            );
        });
    }

    #[test]
    fn row_and_blocked_agree() {
        check("row == blocked solver", 20, |g| {
            let lx = g.usize_in(2, 40);
            let ly = g.usize_in(2, 40);
            let d = g.usize_in(1, 3);
            let x = g.path(lx, d, 0.3);
            let y = g.path(ly, d, 0.3);
            let lam1 = g.usize_in(0, 2) as u32;
            let lam2 = g.usize_in(0, 2) as u32;
            let base = KernelOptions::default().dyadic(lam1, lam2);
            let kr = sig_kernel(&x, &y, lx, ly, d, &base);
            let kb = sig_kernel(&x, &y, lx, ly, d, &base.solver(SolverKind::Blocked));
            assert!(
                (kr - kb).abs() < 1e-9 * kr.abs().max(1.0),
                "row {kr} vs blocked {kb}"
            );
        });
    }

    #[test]
    fn dyadic_refinement_converges() {
        // Successive dyadic orders should approach a limit.
        let mut rng = Rng::new(77);
        let (l, d) = (6, 2);
        let x = rng.brownian_path(l, d, 0.5);
        let y = rng.brownian_path(l, d, 0.5);
        let ks: Vec<f64> = (0..5)
            .map(|lam| sig_kernel(&x, &y, l, l, d, &KernelOptions::default().dyadic(lam, lam)))
            .collect();
        let d1 = (ks[1] - ks[0]).abs();
        let d3 = (ks[4] - ks[3]).abs();
        assert!(d3 < d1, "no convergence: diffs {d1} .. {d3}");
    }

    #[test]
    fn asymmetric_dyadic_orders_work() {
        let mut rng = Rng::new(78);
        let x = rng.brownian_path(4, 2, 0.5);
        let y = rng.brownian_path(16, 2, 0.5);
        // refine only the short path
        let k = sig_kernel(&x, &y, 4, 16, 2, &KernelOptions::default().dyadic(3, 0));
        assert!(k.is_finite());
        // roughly consistent with symmetric refinement
        let k2 = sig_kernel(&x, &y, 4, 16, 2, &KernelOptions::default().dyadic(2, 2));
        assert!((k - k2).abs() < 0.2 * k.abs().max(1.0));
    }

    #[test]
    fn transforms_match_materialised() {
        check("kernel fused transform == materialised", 10, |g| {
            let l = g.usize_in(2, 8);
            let d = g.usize_in(1, 3);
            let x = g.path(l, d, 0.4);
            let y = g.path(l, d, 0.4);
            for tr in [Transform::TimeAug, Transform::LeadLag] {
                let fused =
                    sig_kernel(&x, &y, l, l, d, &KernelOptions::default().transform(tr));
                let xm = crate::transforms::apply(tr, &x, l, d);
                let ym = crate::transforms::apply(tr, &y, l, d);
                let want = sig_kernel(
                    &xm,
                    &ym,
                    tr.out_len(l),
                    tr.out_len(l),
                    tr.out_dim(d),
                    &KernelOptions::default(),
                );
                assert!(
                    (fused - want).abs() < 1e-10 * want.abs().max(1.0),
                    "tr={tr:?}: {fused} vs {want}"
                );
            }
        });
    }
}
