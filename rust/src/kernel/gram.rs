//! Batched signature-kernel computations: paired batches, Gram matrices,
//! their vjps, and the signature-kernel MMD used for two-sample testing and
//! generative-model training (the paper's headline application).
//!
//! The typed entry points take [`PathBatch`]es and therefore support
//! **ragged** batches: every pair (x_i, y_j) is solved on its own
//! (len_x_i − 1) × (len_y_j − 1) PDE grid, so mixed-length corpora need no
//! padding, and gradients come back in each batch's own ragged layout.
//!
//! Gram production is **lane-batched**: the engine plans these wrappers
//! compile group each row's pairs by shape class (ragged batches are
//! grouped by equal length) and advance W = 4 or 8 kernels per Goursat
//! sweep through [`kernel::lanes`](crate::kernel::lanes), with a scalar
//! remainder — bit-identical to the scalar path, ~W× less sweep overhead
//! on multi-pair rows. `PYSIGLIB_LANES=0` restores the scalar schedule.

use crate::engine::{OpSpec, Plan, ShapeClass};
use crate::kernel::lanes::{
    lane_width_for, normalize_lane_width, vjp_gram_row, vjp_lane_sizes, VjpLaneScratch,
};
use crate::kernel::KernelOptions;
use crate::path::{PathBatch, SigError};
use crate::util::pool::num_threads;

fn check_dims(x: &PathBatch<'_>, y: &PathBatch<'_>, opts: &KernelOptions) -> Result<(), SigError> {
    if x.dim() != y.dim() {
        return Err(SigError::DimMismatch {
            left: x.dim(),
            right: y.dim(),
        });
    }
    // Grid sizes are monotone in path length, so validating the longest
    // (x, y) pair bounds every pair — after this, per-pair `try_sig_kernel`
    // calls cannot fail and the parallel closures may unwrap.
    let mx = (0..x.batch()).map(|i| x.len_of(i)).max().unwrap_or(0);
    let my = (0..y.batch()).map(|j| y.len_of(j)).max().unwrap_or(0);
    if mx >= 2 && my >= 2 {
        crate::kernel::check_grid_size(mx, my, opts)?;
    }
    Ok(())
}

/// Typed paired batch: k(x_i, y_i) for i = 0..batch, ragged-capable.
/// Returns `[batch]`. A thin wrapper compiling a one-shot forward
/// [`Plan`]; compile the plan yourself to amortise it across calls.
pub fn try_batch_kernel(
    x: &PathBatch<'_>,
    y: &PathBatch<'_>,
    opts: &KernelOptions,
) -> Result<Vec<f64>, SigError> {
    let plan = Plan::compile_forward(OpSpec::SigKernel(*opts), ShapeClass::for_pair(x, y))?;
    Ok(plan.execute_pair(x, y)?.into_values())
}

/// Paired batch: k(x_i, y_i) for i = 0..batch (flat-slice wrapper over
/// [`try_batch_kernel`]; panics on malformed shapes).
/// `x` is `[batch, lx, dim]`, `y` is `[batch, ly, dim]`; returns `[batch]`.
pub fn batch_kernel(
    x: &[f64],
    y: &[f64],
    batch: usize,
    lx: usize,
    ly: usize,
    dim: usize,
    opts: &KernelOptions,
) -> Vec<f64> {
    let xb = PathBatch::uniform(x, batch, lx, dim).expect("batch_kernel: invalid x shape");
    let yb = PathBatch::uniform(y, batch, ly, dim).expect("batch_kernel: invalid y shape");
    try_batch_kernel(&xb, &yb, opts).expect("batch_kernel")
}

/// Typed paired-batch vjp: given ∂F/∂k_i, return (∂F/∂x, ∂F/∂y) in each
/// batch's own (possibly ragged) flat layout. Routed through
/// [`ExecutionRecord::vjp`](crate::engine::ExecutionRecord::vjp): the
/// forward solve retains each pair's Δ matrix and PDE grid, and Algorithm 4
/// runs on them directly.
pub fn try_batch_kernel_vjp(
    x: &PathBatch<'_>,
    y: &PathBatch<'_>,
    grad_k: &[f64],
    opts: &KernelOptions,
) -> Result<(Vec<f64>, Vec<f64>), SigError> {
    let plan = Plan::compile(OpSpec::SigKernel(*opts), ShapeClass::for_pair(x, y))?;
    let record = plan.execute_pair(x, y)?;
    record.vjp(grad_k)?.into_pair()
}

/// Paired-batch vjp (flat-slice wrapper over [`try_batch_kernel_vjp`]):
/// given ∂F/∂k_i, return (∂F/∂x, ∂F/∂y).
pub fn batch_kernel_vjp(
    x: &[f64],
    y: &[f64],
    grad_k: &[f64],
    batch: usize,
    lx: usize,
    ly: usize,
    dim: usize,
    opts: &KernelOptions,
) -> (Vec<f64>, Vec<f64>) {
    let xb = PathBatch::uniform(x, batch, lx, dim).expect("batch_kernel_vjp: invalid x shape");
    let yb = PathBatch::uniform(y, batch, ly, dim).expect("batch_kernel_vjp: invalid y shape");
    try_batch_kernel_vjp(&xb, &yb, grad_k, opts).expect("batch_kernel_vjp")
}

/// Typed full Gram matrix: `[bx, by]` of k(x_i, y_j), ragged-capable —
/// every pair is solved on its own grid. Parallel over all pairs. A thin
/// wrapper compiling a one-shot forward [`Plan`].
pub fn try_gram(
    x: &PathBatch<'_>,
    y: &PathBatch<'_>,
    opts: &KernelOptions,
) -> Result<Vec<f64>, SigError> {
    let plan = Plan::compile_forward(OpSpec::Gram(*opts), ShapeClass::for_pair(x, y))?;
    Ok(plan.execute_pair(x, y)?.into_values())
}

/// Full Gram matrix: `[bx, by]` of k(x_i, y_j) (flat-slice wrapper over
/// [`try_gram`]; panics on malformed shapes). Parallel over all pairs.
pub fn gram(
    x: &[f64],
    y: &[f64],
    bx: usize,
    by: usize,
    lx: usize,
    ly: usize,
    dim: usize,
    opts: &KernelOptions,
) -> Vec<f64> {
    let xb = PathBatch::uniform(x, bx, lx, dim).expect("gram: invalid x shape");
    let yb = PathBatch::uniform(y, by, ly, dim).expect("gram: invalid y shape");
    try_gram(&xb, &yb, opts).expect("gram")
}

/// Resolve the lane width the backward pass actually runs at: normalise the
/// request, then degrade to scalar if retaining W interleaved forward grids
/// at the batch's longest pair would blow the grid-cell cap (width is pure
/// schedule, so degrading is value-neutral).
fn clamp_vjp_width(
    x: &PathBatch<'_>,
    y: &PathBatch<'_>,
    opts: &KernelOptions,
    width: usize,
) -> usize {
    let width = normalize_lane_width(width);
    if width == 0 {
        return 0;
    }
    let mx = (0..x.batch()).map(|i| x.len_of(i)).max().unwrap_or(0);
    let my = (0..y.batch()).map(|j| y.len_of(j)).max().unwrap_or(0);
    if mx < 2 || my < 2 {
        return 0;
    }
    let s = vjp_lane_sizes(
        mx,
        my,
        x.dim(),
        opts.exec.transform,
        width,
        opts.dyadic_x,
        opts.dyadic_y,
    );
    if s.grid as u128 > super::MAX_GRID_CELLS {
        0
    } else {
        width
    }
}

/// The shared lane-scheduled Gram backward every consumer routes through:
/// accumulate `∂F/∂x` and `∂F/∂y` of the weighted Gram `Σ w_ij·k(x_i, y_j)`.
///
/// Parallelised over x-rows with a **static** partition — worker t owns rows
/// `i ≡ t (mod nt)`, ascending — so which per-thread ∂F/∂y buffer every
/// column contribution lands in, hence the final merge order of each gy
/// element, is deterministic: results are a pure function of the inputs and
/// `num_threads()`, independent of scheduling and of `width`. All validation
/// and sizing happens before the thread scope, so the worker bodies are
/// infallible — no `expect` inside the scope by construction.
fn gram_vjp_with_lanes(
    x: &PathBatch<'_>,
    y: &PathBatch<'_>,
    weights: &[f64],
    opts: &KernelOptions,
    width: usize,
) -> Result<(Vec<f64>, Vec<f64>), SigError> {
    // Resolve a `target_eps` request up front (deterministic, so the
    // backward lands on exactly the grid the forward ran) — before
    // `check_dims`/`clamp_vjp_width`, which size off the resolved λ.
    let resolved = crate::kernel::scheme::resolve_target_eps(x, y, opts)?;
    let opts = &resolved;
    check_dims(x, y, opts)?;
    let (bx, by) = (x.batch(), y.batch());
    if weights.len() != bx * by {
        return Err(SigError::CotangentLen {
            expected: bx * by,
            got: weights.len(),
        });
    }
    let dim = x.dim();
    let mut gx = vec![0.0; x.total_points() * dim];
    let gy_total = y.total_points() * dim;
    if bx == 0 || by == 0 {
        return Ok((gx, vec![0.0; gy_total]));
    }
    let width = clamp_vjp_width(x, y, opts, width);
    let xo = x.element_offsets();
    let yo = y.element_offsets();
    let nt = num_threads().min(bx);
    let mut gy_parts = vec![vec![0.0; gy_total]; nt];
    // gx rows are owned by exactly one worker (disjoint writes through the
    // base pointer, as in `parallel_for_mut_ragged`); gy is accumulated into
    // per-thread buffers and merged below — no lock on the hot path.
    let gx_base = gx.as_mut_ptr() as usize;
    std::thread::scope(|s| {
        let (xo, yo) = (&xo, &yo);
        for (t, part) in gy_parts.iter_mut().enumerate() {
            s.spawn(move || {
                let mut sc = VjpLaneScratch::new();
                let mut i = t;
                while i < bx {
                    // SAFETY: row i is gx[xo[i]..xo[i+1]], written by exactly
                    // one worker (i ≡ t mod nt; offsets are non-decreasing);
                    // `gx` outlives the scope.
                    let gxrow = unsafe {
                        std::slice::from_raw_parts_mut(
                            (gx_base as *mut f64).add(xo[i]),
                            xo[i + 1] - xo[i],
                        )
                    };
                    vjp_gram_row(
                        x,
                        i,
                        y,
                        0..by,
                        &weights[i * by..(i + 1) * by],
                        opts,
                        width,
                        &mut sc,
                        gxrow,
                        part,
                        yo,
                    );
                    i += nt;
                }
            });
        }
    });
    let mut gy = vec![0.0; gy_total];
    for part in gy_parts {
        for (o, v) in gy.iter_mut().zip(part.iter()) {
            *o += v;
        }
    }
    Ok((gx, gy))
}

/// Slot-separated symmetric Gram backward for the self-term of MMD²-style
/// objectives: for x against itself with **symmetric** weights
/// (`w_ij == w_ji`, debug-asserted), return the two slot gradients
/// `(gx1, gx2)` — `gx1[i] = Σ_j w_ij·∂₁k(x_i, x_j)`,
/// `gx2[j] = Σ_i w_ij·∂₂k(x_i, x_j)` — from roughly **half** the adjoint
/// solves.
///
/// Requires `dyadic_x == dyadic_y`: the forward grid of (x_j, x_i) is then
/// the transpose of (x_i, x_j)'s, so one solve of the upper-triangle pair
/// {i, j} yields all four contributions (∂₁ and ∂₂ of both orientations) —
/// `∂₁k(x_j, x_i)` is `∂₂k(x_i, x_j)` computed by the very same FP ops
/// (IEEE `+`/`×` are commutative in their operands, and [`gemm_tn`] runs
/// the transposed accumulation in matching order). The slots are kept
/// separate so callers can reproduce the two-slot path's final
/// `gx1 + gx2 + …` association exactly; at λ > 0 the per-coarse-cell Δ-vjp
/// accumulation order transposes, so cross-orientation reuse is equal to
/// ~1e-12 rather than bitwise (guarded in `tests/props_grad.rs`).
///
/// [`gemm_tn`]: crate::util::linalg::gemm_tn
pub(crate) fn gram_vjp_sym_with_lanes(
    x: &PathBatch<'_>,
    weights: &[f64],
    opts: &KernelOptions,
    width: usize,
) -> Result<(Vec<f64>, Vec<f64>), SigError> {
    // Resolution picks a symmetric λ, so the transpose-reuse invariant
    // (`dyadic_x == dyadic_y`) survives an ε-adaptive request.
    let resolved = crate::kernel::scheme::resolve_target_eps(x, x, opts)?;
    let opts = &resolved;
    debug_assert_eq!(opts.dyadic_x, opts.dyadic_y);
    check_dims(x, x, opts)?;
    let bx = x.batch();
    if weights.len() != bx * bx {
        return Err(SigError::CotangentLen {
            expected: bx * bx,
            got: weights.len(),
        });
    }
    #[cfg(debug_assertions)]
    for i in 0..bx {
        for j in 0..i {
            debug_assert_eq!(
                weights[i * bx + j],
                weights[j * bx + i],
                "gram_vjp_sym_with_lanes needs symmetric weights"
            );
        }
    }
    let dim = x.dim();
    let total = x.total_points() * dim;
    let mut gx1 = vec![0.0; total];
    let mut gx2 = vec![0.0; total];
    if bx == 0 {
        return Ok((gx1, gx2));
    }
    let width = clamp_vjp_width(x, x, opts, width);
    let xo = x.element_offsets();
    let nt = num_threads().min(bx);
    // Per unordered pair {i, j} (j > i, owned by row i): one solve with seed
    // w_ij gives (g₁, g₂); g₁ feeds gx1[i] (direct) *and* gx2[i] (it equals
    // ∂₂k(x_j, x_i)), g₂ feeds gx2[j] *and* gx1[j] (both scattered through
    // `off` parts, merged into both slots below). The diagonal solve feeds
    // gx1[i] directly and gx2[i] through `diag` parts (merged into gx2
    // only), keeping each slot's diagonal term faithful.
    let mut off_parts = vec![vec![0.0; total]; nt];
    let mut diag_parts = vec![vec![0.0; total]; nt];
    let g1_base = gx1.as_mut_ptr() as usize;
    let g2_base = gx2.as_mut_ptr() as usize;
    std::thread::scope(|s| {
        let xo = &xo;
        for (t, (off, diag)) in off_parts.iter_mut().zip(diag_parts.iter_mut()).enumerate() {
            s.spawn(move || {
                let mut sc = VjpLaneScratch::new();
                let mut rowacc: Vec<f64> = Vec::new();
                let mut i = t;
                while i < bx {
                    let rl = xo[i + 1] - xo[i];
                    // SAFETY: rows i ≡ t (mod nt) of gx1/gx2 are written by
                    // exactly this worker (offsets are non-decreasing); both
                    // buffers outlive the scope.
                    let g1row = unsafe {
                        std::slice::from_raw_parts_mut((g1_base as *mut f64).add(xo[i]), rl)
                    };
                    let g2row = unsafe {
                        std::slice::from_raw_parts_mut((g2_base as *mut f64).add(xo[i]), rl)
                    };
                    let wrow = &weights[i * bx..(i + 1) * bx];
                    // Diagonal pair: ∂₁ → slot 1 direct, ∂₂ → slot 2 via
                    // the diag part.
                    vjp_gram_row(
                        x, i, x, i..i + 1, &wrow[i..i + 1], opts, width, &mut sc, g1row, diag, xo,
                    );
                    // Strict upper row: the shared Σ_j ∂₁ term, accumulated
                    // once and applied to both slots.
                    rowacc.clear();
                    rowacc.resize(rl, 0.0);
                    vjp_gram_row(
                        x, i, x, i + 1..bx, &wrow[i + 1..], opts, width, &mut sc, &mut rowacc,
                        off, xo,
                    );
                    for c in 0..rl {
                        g1row[c] += rowacc[c];
                        g2row[c] += rowacc[c];
                    }
                    i += nt;
                }
            });
        }
    });
    for part in off_parts {
        for ((o1, o2), v) in gx1.iter_mut().zip(gx2.iter_mut()).zip(part.iter()) {
            *o1 += v;
            *o2 += v;
        }
    }
    for part in diag_parts {
        for (o, v) in gx2.iter_mut().zip(part.iter()) {
            *o += v;
        }
    }
    Ok((gx1, gx2))
}

/// Typed Gram vjp: given W = ∂F/∂Gram (`[bx, by]`), return
/// (∂F/∂x, ∂F/∂y) in each batch's own (possibly ragged) flat layout.
///
/// Lane-batched ([`kernel::lanes`](crate::kernel::lanes)): each row's
/// nonzero-weight columns group by shape class and ride the W-wide
/// Algorithm-4 adjoint sweep, bit-identically to the scalar backward.
/// Parallelised over x-rows with per-thread accumulation buffers for the
/// shared ∂F/∂y (merged in fixed order at the end) — no lock on the hot
/// path.
pub fn try_gram_vjp(
    x: &PathBatch<'_>,
    y: &PathBatch<'_>,
    weights: &[f64],
    opts: &KernelOptions,
) -> Result<(Vec<f64>, Vec<f64>), SigError> {
    gram_vjp_with_lanes(x, y, weights, opts, lane_width_for(y.uniform_len().is_some()))
}

/// [`try_gram_vjp`] with the lane width pinned instead of resolved from the
/// shape profile and `PYSIGLIB_LANES`. Width is pure schedule — results are
/// bit-identical across widths (property-tested) — so this exists for tests
/// and benches that compare schedules.
pub fn try_gram_vjp_with_lanes(
    x: &PathBatch<'_>,
    y: &PathBatch<'_>,
    weights: &[f64],
    opts: &KernelOptions,
    width: usize,
) -> Result<(Vec<f64>, Vec<f64>), SigError> {
    gram_vjp_with_lanes(x, y, weights, opts, width)
}

/// Gram vjp (flat-slice wrapper over [`try_gram_vjp`]): given
/// W = ∂F/∂Gram (`[bx, by]`), return
/// (∂F/∂x `[bx,lx,dim]`, ∂F/∂y `[by,ly,dim]`).
pub fn gram_vjp(
    x: &[f64],
    y: &[f64],
    weights: &[f64],
    bx: usize,
    by: usize,
    lx: usize,
    ly: usize,
    dim: usize,
    opts: &KernelOptions,
) -> (Vec<f64>, Vec<f64>) {
    let xb = PathBatch::uniform(x, bx, lx, dim).expect("gram_vjp: invalid x shape");
    let yb = PathBatch::uniform(y, by, ly, dim).expect("gram_vjp: invalid y shape");
    try_gram_vjp(&xb, &yb, weights, opts).expect("gram_vjp")
}

/// Typed squared signature-kernel MMD between two path distributions (biased
/// V-statistic): mean(Kxx) − 2·mean(Kxy) + mean(Kyy). Ragged-capable.
pub fn try_mmd2(
    x: &PathBatch<'_>,
    y: &PathBatch<'_>,
    opts: &KernelOptions,
) -> Result<f64, SigError> {
    let plan = Plan::compile_forward(OpSpec::Mmd2(*opts), ShapeClass::for_pair(x, y))?;
    Ok(plan.execute_pair(x, y)?.value())
}

/// Squared signature-kernel MMD (flat-slice wrapper over [`try_mmd2`]).
pub fn mmd2(
    x: &[f64],
    y: &[f64],
    bx: usize,
    by: usize,
    lx: usize,
    ly: usize,
    dim: usize,
    opts: &KernelOptions,
) -> f64 {
    let xb = PathBatch::uniform(x, bx, lx, dim).expect("mmd2: invalid x shape");
    let yb = PathBatch::uniform(y, by, ly, dim).expect("mmd2: invalid y shape");
    try_mmd2(&xb, &yb, opts).expect("mmd2")
}

/// Typed unbiased MMD² (U-statistic): excludes the diagonals of Kxx and Kyy.
/// This is the estimator used for two-sample hypothesis testing. A thin
/// wrapper compiling a one-shot forward
/// [`OpSpec::Mmd2Unbiased`](crate::engine::OpSpec::Mmd2Unbiased) plan.
pub fn try_mmd2_unbiased(
    x: &PathBatch<'_>,
    y: &PathBatch<'_>,
    opts: &KernelOptions,
) -> Result<f64, SigError> {
    let plan = Plan::compile_forward(OpSpec::Mmd2Unbiased(*opts), ShapeClass::for_pair(x, y))?;
    Ok(plan.execute_pair(x, y)?.value())
}

/// Typed unbiased MMD² and its exact gradient with respect to the x-paths —
/// the U-statistic counterpart of [`try_mmd2_with_grad`]. The gradient
/// differs from the biased one only in the Kxx weights (off-diagonal
/// 1/(bx(bx−1)) instead of uniform 1/bx²); it routes through the same
/// weighted-Gram Algorithm-4 backward.
pub fn try_mmd2_unbiased_with_grad(
    x: &PathBatch<'_>,
    y: &PathBatch<'_>,
    opts: &KernelOptions,
) -> Result<(f64, Vec<f64>), SigError> {
    let plan = Plan::compile(OpSpec::Mmd2Unbiased(*opts), ShapeClass::for_pair(x, y))?;
    let record = plan.execute_pair(x, y)?;
    let value = record.value();
    let grad = record.vjp(&[1.0])?.into_single()?;
    Ok((value, grad))
}

/// Unbiased MMD² (flat-slice wrapper over [`try_mmd2_unbiased`]).
pub fn mmd2_unbiased(
    x: &[f64],
    y: &[f64],
    bx: usize,
    by: usize,
    lx: usize,
    ly: usize,
    dim: usize,
    opts: &KernelOptions,
) -> f64 {
    let xb = PathBatch::uniform(x, bx, lx, dim).expect("mmd2_unbiased: invalid x shape");
    let yb = PathBatch::uniform(y, by, ly, dim).expect("mmd2_unbiased: invalid y shape");
    try_mmd2_unbiased(&xb, &yb, opts).expect("mmd2_unbiased")
}

/// Typed MMD² and its exact gradient with respect to the x-paths (the
/// generator sample in training): uses Algorithm 4 end-to-end through both
/// Gram terms. The gradient comes back in x's own (possibly ragged) layout.
pub fn try_mmd2_with_grad(
    x: &PathBatch<'_>,
    y: &PathBatch<'_>,
    opts: &KernelOptions,
) -> Result<(f64, Vec<f64>), SigError> {
    let plan = Plan::compile(OpSpec::Mmd2(*opts), ShapeClass::for_pair(x, y))?;
    let record = plan.execute_pair(x, y)?;
    let value = record.value();
    let grad = record.vjp(&[1.0])?.into_single()?;
    Ok((value, grad))
}

/// MMD² and its exact gradient with respect to the x-paths (flat-slice
/// wrapper over [`try_mmd2_with_grad`]).
pub fn mmd2_with_grad(
    x: &[f64],
    y: &[f64],
    bx: usize,
    by: usize,
    lx: usize,
    ly: usize,
    dim: usize,
    opts: &KernelOptions,
) -> (f64, Vec<f64>) {
    let xb = PathBatch::uniform(x, bx, lx, dim).expect("mmd2_with_grad: invalid x shape");
    let yb = PathBatch::uniform(y, by, ly, dim).expect("mmd2_with_grad: invalid y shape");
    try_mmd2_with_grad(&xb, &yb, opts).expect("mmd2_with_grad")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::backward::sig_kernel_vjp;
    use crate::kernel::sig_kernel;
    use crate::util::linalg::max_abs_diff;
    use crate::util::rng::Rng;

    #[test]
    fn batch_matches_single() {
        let mut rng = Rng::new(41);
        let (b, l, d) = (6, 8, 2);
        let x = rng.brownian_batch(b, l, d, 0.4);
        let y = rng.brownian_batch(b, l, d, 0.4);
        let opts = KernelOptions::default();
        let ks = batch_kernel(&x, &y, b, l, l, d, &opts);
        for i in 0..b {
            let k = sig_kernel(
                &x[i * l * d..(i + 1) * l * d],
                &y[i * l * d..(i + 1) * l * d],
                l,
                l,
                d,
                &opts,
            );
            assert!((ks[i] - k).abs() < 1e-14);
        }
    }

    #[test]
    fn gram_is_symmetric_for_same_batch() {
        let mut rng = Rng::new(42);
        let (b, l, d) = (5, 6, 2);
        let x = rng.brownian_batch(b, l, d, 0.4);
        let g = gram(&x, &x, b, b, l, l, d, &KernelOptions::default());
        for i in 0..b {
            for j in 0..b {
                assert!((g[i * b + j] - g[j * b + i]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn gram_psd_via_quadratic_form() {
        // vᵀ K v ≥ 0 for the self-Gram (PSD kernel matrix).
        let mut rng = Rng::new(43);
        let (b, l, d) = (6, 6, 2);
        let x = rng.brownian_batch(b, l, d, 0.3);
        let g = gram(&x, &x, b, b, l, l, d, &KernelOptions::default().dyadic(2, 2));
        for trial in 0..5 {
            let mut v = vec![0.0; b];
            let mut r2 = Rng::new(100 + trial);
            r2.fill_normal(&mut v);
            let mut q = 0.0;
            for i in 0..b {
                for j in 0..b {
                    q += v[i] * g[i * b + j] * v[j];
                }
            }
            assert!(q > -1e-8, "quadratic form {q}");
        }
    }

    #[test]
    fn serial_parallel_gram_agree() {
        let mut rng = Rng::new(44);
        let (b, l, d) = (4, 7, 2);
        let x = rng.brownian_batch(b, l, d, 0.4);
        let y = rng.brownian_batch(b, l, d, 0.4);
        let par = gram(&x, &y, b, b, l, l, d, &KernelOptions::default());
        let ser = gram(&x, &y, b, b, l, l, d, &KernelOptions::default().serial());
        assert!(max_abs_diff(&par, &ser) < 1e-15);
    }

    #[test]
    fn gram_vjp_matches_pairwise_sum() {
        let mut rng = Rng::new(45);
        let (bx, by, l, d) = (3, 4, 5, 2);
        let x = rng.brownian_batch(bx, l, d, 0.4);
        let y = rng.brownian_batch(by, l, d, 0.4);
        let mut w = vec![0.0; bx * by];
        rng.fill_normal(&mut w);
        let opts = KernelOptions::default();
        let (gx, gy) = gram_vjp(&x, &y, &w, bx, by, l, l, d, &opts);
        // Reference: accumulate pairwise vjps serially.
        let mut gx_ref = vec![0.0; bx * l * d];
        let mut gy_ref = vec![0.0; by * l * d];
        for i in 0..bx {
            for j in 0..by {
                let (a, b) = sig_kernel_vjp(
                    &x[i * l * d..(i + 1) * l * d],
                    &y[j * l * d..(j + 1) * l * d],
                    l,
                    l,
                    d,
                    &opts,
                    w[i * by + j],
                );
                for (o, v) in gx_ref[i * l * d..(i + 1) * l * d].iter_mut().zip(a.iter()) {
                    *o += v;
                }
                for (o, v) in gy_ref[j * l * d..(j + 1) * l * d].iter_mut().zip(b.iter()) {
                    *o += v;
                }
            }
        }
        assert!(max_abs_diff(&gx, &gx_ref) < 1e-12);
        assert!(max_abs_diff(&gy, &gy_ref) < 1e-12);
    }

    #[test]
    fn mmd_of_identical_distributions_is_small() {
        let mut rng = Rng::new(46);
        let (b, l, d) = (8, 6, 2);
        let x = rng.brownian_batch(b, l, d, 0.4);
        // identical samples: biased MMD² of x with itself is exactly 0
        let m = mmd2(&x, &x, b, b, l, l, d, &KernelOptions::default());
        assert!(m.abs() < 1e-10, "mmd²(x,x) = {m}");
    }

    #[test]
    fn mmd_separates_different_scales() {
        let mut rng = Rng::new(47);
        let (b, l, d) = (10, 8, 2);
        let x = rng.brownian_batch(b, l, d, 0.3);
        let y = rng.brownian_batch(b, l, d, 1.0);
        let same = mmd2_unbiased(
            &x,
            &rng.brownian_batch(b, l, d, 0.3),
            b,
            b,
            l,
            l,
            d,
            &KernelOptions::default(),
        );
        let diff = mmd2_unbiased(&x, &y, b, b, l, l, d, &KernelOptions::default());
        assert!(diff > same, "diff {diff} vs same {same}");
    }

    #[test]
    fn mmd_grad_matches_finite_differences() {
        let mut rng = Rng::new(48);
        let (bx, by, l, d) = (3, 3, 4, 2);
        let x = rng.brownian_batch(bx, l, d, 0.4);
        let y = rng.brownian_batch(by, l, d, 0.5);
        let opts = KernelOptions::default();
        let (_, grad) = mmd2_with_grad(&x, &y, bx, by, l, l, d, &opts);
        let eps = 1e-5;
        for idx in [0usize, 3, 7, 11, 23 % (bx * l * d)] {
            let mut xp = x.clone();
            xp[idx] += eps;
            let mut xm = x.clone();
            xm[idx] -= eps;
            let fp = mmd2(&xp, &y, bx, by, l, l, d, &opts);
            let fm = mmd2(&xm, &y, bx, by, l, l, d, &opts);
            let fd = (fp - fm) / (2.0 * eps);
            assert!(
                (fd - grad[idx]).abs() < 1e-5 * (1.0 + fd.abs()),
                "idx={idx}: fd={fd} grad={}",
                grad[idx]
            );
        }
    }

    /// Ragged Gram bit-matches the per-pair loop over `sig_kernel`,
    /// including length-1 paths (kernel exactly 1).
    #[test]
    fn ragged_gram_bitmatches_per_pair_loop() {
        let mut rng = Rng::new(49);
        let d = 2;
        let xl = [4usize, 1, 9];
        let yl = [2usize, 7, 1, 5];
        let mut xdata = Vec::new();
        for &l in &xl {
            xdata.extend(rng.brownian_path(l, d, 0.4));
        }
        let mut ydata = Vec::new();
        for &l in &yl {
            ydata.extend(rng.brownian_path(l, d, 0.4));
        }
        let xb = PathBatch::ragged(&xdata, &xl, d).unwrap();
        let yb = PathBatch::ragged(&ydata, &yl, d).unwrap();
        let opts = KernelOptions::default().dyadic(1, 0);
        for opts in [opts, opts.serial()] {
            let g = try_gram(&xb, &yb, &opts).unwrap();
            let mut xo = 0;
            for (i, &lx) in xl.iter().enumerate() {
                let mut yo = 0;
                for (j, &ly) in yl.iter().enumerate() {
                    let want = if lx < 2 || ly < 2 {
                        1.0
                    } else {
                        sig_kernel(
                            &xdata[xo * d..(xo + lx) * d],
                            &ydata[yo * d..(yo + ly) * d],
                            lx,
                            ly,
                            d,
                            &opts,
                        )
                    };
                    assert_eq!(g[i * yl.len() + j], want, "pair ({i},{j})");
                    yo += ly;
                }
                xo += lx;
            }
        }
    }

    /// Ragged Gram vjp matches serially accumulated per-pair vjps.
    #[test]
    fn ragged_gram_vjp_matches_pairwise_sum() {
        let mut rng = Rng::new(50);
        let d = 2;
        let xl = [3usize, 6];
        let yl = [5usize, 2, 4];
        let mut xdata = Vec::new();
        for &l in &xl {
            xdata.extend(rng.brownian_path(l, d, 0.4));
        }
        let mut ydata = Vec::new();
        for &l in &yl {
            ydata.extend(rng.brownian_path(l, d, 0.4));
        }
        let xb = PathBatch::ragged(&xdata, &xl, d).unwrap();
        let yb = PathBatch::ragged(&ydata, &yl, d).unwrap();
        let mut w = vec![0.0; xl.len() * yl.len()];
        rng.fill_normal(&mut w);
        let opts = KernelOptions::default();
        let (gx, gy) = try_gram_vjp(&xb, &yb, &w, &opts).unwrap();
        let mut gx_ref = vec![0.0; xb.total_points() * d];
        let mut gy_ref = vec![0.0; yb.total_points() * d];
        let xo = xb.element_offsets();
        let yo = yb.element_offsets();
        for i in 0..xl.len() {
            for j in 0..yl.len() {
                let (a, b) = sig_kernel_vjp(
                    xb.values_of(i),
                    yb.values_of(j),
                    xl[i],
                    yl[j],
                    d,
                    &opts,
                    w[i * yl.len() + j],
                );
                for (o, v) in gx_ref[xo[i]..xo[i + 1]].iter_mut().zip(a.iter()) {
                    *o += v;
                }
                for (o, v) in gy_ref[yo[j]..yo[j + 1]].iter_mut().zip(b.iter()) {
                    *o += v;
                }
            }
        }
        assert!(max_abs_diff(&gx, &gx_ref) < 1e-12);
        assert!(max_abs_diff(&gy, &gy_ref) < 1e-12);
    }

    /// The half-solve symmetric shortcut agrees with the two-slot backward
    /// (tight tolerance; the slot-separated bit-identity guard at bx = 2 and
    /// λ = 0 lives in `tests/props_grad.rs`).
    #[test]
    fn symmetric_shortcut_matches_two_slot_path() {
        let mut rng = Rng::new(51);
        let (b, l, d) = (5, 6, 2);
        let x = rng.brownian_batch(b, l, d, 0.4);
        let xb = PathBatch::uniform(&x, b, l, d).unwrap();
        let mut w = vec![0.0; b * b];
        rng.fill_normal(&mut w);
        for i in 0..b {
            for j in 0..i {
                w[i * b + j] = w[j * b + i];
            }
        }
        for opts in [KernelOptions::default(), KernelOptions::default().dyadic(1, 1)] {
            let (r1, r2) = try_gram_vjp(&xb, &xb, &w, &opts).unwrap();
            for width in [0usize, 4, 8] {
                let (g1, g2) = gram_vjp_sym_with_lanes(&xb, &w, &opts, width).unwrap();
                assert!(max_abs_diff(&g1, &r1) < 1e-12, "slot1 width={width}");
                assert!(max_abs_diff(&g2, &r2) < 1e-12, "slot2 width={width}");
            }
        }
    }

    #[test]
    fn empty_and_mismatched_batches_error_cleanly() {
        let data = [0.0, 0.0, 1.0, 1.0];
        let one = PathBatch::uniform(&data, 1, 2, 2).unwrap();
        let empty = PathBatch::ragged(&[], &[], 2).unwrap();
        let opts = KernelOptions::default();
        // Empty Gram is fine (an empty matrix) …
        assert!(try_gram(&empty, &one, &opts).unwrap().is_empty());
        // … but MMD over an empty sample is an error, not NaN.
        assert!(matches!(
            try_mmd2(&empty, &one, &opts),
            Err(SigError::InsufficientBatch { .. })
        ));
        // Paired ops need equal batch sizes.
        assert!(matches!(
            try_batch_kernel(&empty, &one, &opts),
            Err(SigError::BatchMismatch { .. })
        ));
        // Dim mismatch is caught before any compute.
        let d3 = [0.0; 6];
        let three = PathBatch::uniform(&d3, 1, 2, 3).unwrap();
        assert!(matches!(
            try_gram(&one, &three, &opts),
            Err(SigError::DimMismatch { .. })
        ));
    }
}
