//! Batched signature-kernel computations: paired batches, Gram matrices,
//! their vjps, and the signature-kernel MMD used for two-sample testing and
//! generative-model training (the paper's headline application).

use crate::kernel::backward::sig_kernel_vjp;
use crate::kernel::{sig_kernel, KernelOptions};
use crate::util::pool::{num_threads, parallel_for_mut};

/// Paired batch: k(x_i, y_i) for i = 0..batch.
/// `x` is `[batch, lx, dim]`, `y` is `[batch, ly, dim]`; returns `[batch]`.
pub fn batch_kernel(
    x: &[f64],
    y: &[f64],
    batch: usize,
    lx: usize,
    ly: usize,
    dim: usize,
    opts: &KernelOptions,
) -> Vec<f64> {
    assert_eq!(x.len(), batch * lx * dim);
    assert_eq!(y.len(), batch * ly * dim);
    let mut out = vec![0.0; batch];
    if batch == 0 {
        return out;
    }
    let work = |i: usize, slot: &mut [f64]| {
        slot[0] = sig_kernel(
            &x[i * lx * dim..(i + 1) * lx * dim],
            &y[i * ly * dim..(i + 1) * ly * dim],
            lx,
            ly,
            dim,
            opts,
        );
    };
    if opts.parallel {
        parallel_for_mut(&mut out, 1, work);
    } else {
        for i in 0..batch {
            let mut slot = [0.0];
            work(i, &mut slot);
            out[i] = slot[0];
        }
    }
    out
}

/// Paired-batch vjp: given ∂F/∂k_i, return (∂F/∂x, ∂F/∂y).
pub fn batch_kernel_vjp(
    x: &[f64],
    y: &[f64],
    grad_k: &[f64],
    batch: usize,
    lx: usize,
    ly: usize,
    dim: usize,
    opts: &KernelOptions,
) -> (Vec<f64>, Vec<f64>) {
    assert_eq!(grad_k.len(), batch);
    let mut gx = vec![0.0; batch * lx * dim];
    let gy = std::sync::Mutex::new(vec![0.0; batch * ly * dim]);
    let sy = ly * dim;
    parallel_for_mut(&mut gx, lx * dim, |i, gxrow| {
        let (gxi, gyi) = sig_kernel_vjp(
            &x[i * lx * dim..(i + 1) * lx * dim],
            &y[i * sy..(i + 1) * sy],
            lx,
            ly,
            dim,
            opts,
            grad_k[i],
        );
        gxrow.copy_from_slice(&gxi);
        gy.lock().unwrap()[i * sy..(i + 1) * sy].copy_from_slice(&gyi);
    });
    (gx, gy.into_inner().unwrap())
}

/// Full Gram matrix: `[bx, by]` of k(x_i, y_j). Parallel over all pairs.
pub fn gram(
    x: &[f64],
    y: &[f64],
    bx: usize,
    by: usize,
    lx: usize,
    ly: usize,
    dim: usize,
    opts: &KernelOptions,
) -> Vec<f64> {
    assert_eq!(x.len(), bx * lx * dim);
    assert_eq!(y.len(), by * ly * dim);
    let mut out = vec![0.0; bx * by];
    if bx * by == 0 {
        return out;
    }
    let work = |p: usize, slot: &mut [f64]| {
        let i = p / by;
        let j = p % by;
        slot[0] = sig_kernel(
            &x[i * lx * dim..(i + 1) * lx * dim],
            &y[j * ly * dim..(j + 1) * ly * dim],
            lx,
            ly,
            dim,
            opts,
        );
    };
    if opts.parallel {
        parallel_for_mut(&mut out, 1, work);
    } else {
        for p in 0..bx * by {
            let mut slot = [0.0];
            work(p, &mut slot);
            out[p] = slot[0];
        }
    }
    out
}

/// Gram vjp: given W = ∂F/∂Gram (`[bx, by]`), return
/// (∂F/∂x `[bx,lx,dim]`, ∂F/∂y `[by,ly,dim]`).
///
/// Parallelised over x-rows with per-thread accumulation buffers for the
/// shared ∂F/∂y (merged once at the end) — no lock on the hot path.
pub fn gram_vjp(
    x: &[f64],
    y: &[f64],
    weights: &[f64],
    bx: usize,
    by: usize,
    lx: usize,
    ly: usize,
    dim: usize,
    opts: &KernelOptions,
) -> (Vec<f64>, Vec<f64>) {
    assert_eq!(weights.len(), bx * by);
    let sx = lx * dim;
    let sy = ly * dim;
    let mut gx = vec![0.0; bx * sx];
    let nt = num_threads().min(bx.max(1));
    let mut gy_parts = vec![vec![0.0; by * sy]; nt];
    let next = std::sync::atomic::AtomicUsize::new(0);
    // gx rows are claimed exactly once per i (disjoint writes through the
    // base pointer, as in `parallel_for_mut`); gy is accumulated into
    // per-thread buffers and merged below — no lock on the hot path.
    let gx_base = gx.as_mut_ptr() as usize;
    std::thread::scope(|s| {
        let next = &next;
        for part in gy_parts.iter_mut() {
            s.spawn(move || loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= bx {
                    break;
                }
                // SAFETY: row i is written by exactly one worker; `gx`
                // outlives the scope.
                let gxrow = unsafe {
                    std::slice::from_raw_parts_mut((gx_base as *mut f64).add(i * sx), sx)
                };
                for j in 0..by {
                    let w = weights[i * by + j];
                    if w == 0.0 {
                        continue;
                    }
                    let (gxi, gyj) = sig_kernel_vjp(
                        &x[i * sx..(i + 1) * sx],
                        &y[j * sy..(j + 1) * sy],
                        lx,
                        ly,
                        dim,
                        opts,
                        w,
                    );
                    for (o, v) in gxrow.iter_mut().zip(gxi.iter()) {
                        *o += v;
                    }
                    for (o, v) in part[j * sy..(j + 1) * sy].iter_mut().zip(gyj.iter()) {
                        *o += v;
                    }
                }
            });
        }
    });
    let mut gy = vec![0.0; by * sy];
    for part in gy_parts {
        for (o, v) in gy.iter_mut().zip(part.iter()) {
            *o += v;
        }
    }
    (gx, gy)
}

/// Squared signature-kernel MMD between two path distributions (biased
/// V-statistic): mean(Kxx) − 2·mean(Kxy) + mean(Kyy).
pub fn mmd2(
    x: &[f64],
    y: &[f64],
    bx: usize,
    by: usize,
    lx: usize,
    ly: usize,
    dim: usize,
    opts: &KernelOptions,
) -> f64 {
    let kxx = gram(x, x, bx, bx, lx, lx, dim, opts);
    let kxy = gram(x, y, bx, by, lx, ly, dim, opts);
    let kyy = gram(y, y, by, by, ly, ly, dim, opts);
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    mean(&kxx) - 2.0 * mean(&kxy) + mean(&kyy)
}

/// Unbiased MMD² (U-statistic): excludes the diagonals of Kxx and Kyy.
/// This is the estimator used for two-sample hypothesis testing.
pub fn mmd2_unbiased(
    x: &[f64],
    y: &[f64],
    bx: usize,
    by: usize,
    lx: usize,
    ly: usize,
    dim: usize,
    opts: &KernelOptions,
) -> f64 {
    assert!(bx >= 2 && by >= 2);
    let kxx = gram(x, x, bx, bx, lx, lx, dim, opts);
    let kxy = gram(x, y, bx, by, lx, ly, dim, opts);
    let kyy = gram(y, y, by, by, ly, ly, dim, opts);
    let off_mean = |v: &[f64], b: usize| {
        let total: f64 = v.iter().sum();
        let diag: f64 = (0..b).map(|i| v[i * b + i]).sum();
        (total - diag) / (b * (b - 1)) as f64
    };
    let mean_xy = kxy.iter().sum::<f64>() / (bx * by) as f64;
    off_mean(&kxx, bx) - 2.0 * mean_xy + off_mean(&kyy, by)
}

/// MMD² and its exact gradient with respect to the x-paths (the generator
/// sample in training): uses Algorithm 4 end-to-end through both Gram terms.
pub fn mmd2_with_grad(
    x: &[f64],
    y: &[f64],
    bx: usize,
    by: usize,
    lx: usize,
    ly: usize,
    dim: usize,
    opts: &KernelOptions,
) -> (f64, Vec<f64>) {
    let value = mmd2(x, y, bx, by, lx, ly, dim, opts);
    // ∂/∂x_i [ (1/bx²)ΣΣ k(x_a,x_b) ] = (2/bx²) Σ_b ∇₁k(x_i, x_b) (symmetry).
    let wxx = vec![2.0 / (bx * bx) as f64; bx * bx];
    let (gxx, _) = gram_vjp(x, x, &wxx, bx, bx, lx, lx, dim, opts);
    let wxy = vec![-2.0 / (bx * by) as f64; bx * by];
    let (gxy, _) = gram_vjp(x, y, &wxy, bx, by, lx, ly, dim, opts);
    let grad: Vec<f64> = gxx.iter().zip(gxy.iter()).map(|(a, b)| a + b).collect();
    (value, grad)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::linalg::max_abs_diff;
    use crate::util::rng::Rng;

    #[test]
    fn batch_matches_single() {
        let mut rng = Rng::new(41);
        let (b, l, d) = (6, 8, 2);
        let x = rng.brownian_batch(b, l, d, 0.4);
        let y = rng.brownian_batch(b, l, d, 0.4);
        let opts = KernelOptions::default();
        let ks = batch_kernel(&x, &y, b, l, l, d, &opts);
        for i in 0..b {
            let k = sig_kernel(
                &x[i * l * d..(i + 1) * l * d],
                &y[i * l * d..(i + 1) * l * d],
                l,
                l,
                d,
                &opts,
            );
            assert!((ks[i] - k).abs() < 1e-14);
        }
    }

    #[test]
    fn gram_is_symmetric_for_same_batch() {
        let mut rng = Rng::new(42);
        let (b, l, d) = (5, 6, 2);
        let x = rng.brownian_batch(b, l, d, 0.4);
        let g = gram(&x, &x, b, b, l, l, d, &KernelOptions::default());
        for i in 0..b {
            for j in 0..b {
                assert!((g[i * b + j] - g[j * b + i]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn gram_psd_via_quadratic_form() {
        // vᵀ K v ≥ 0 for the self-Gram (PSD kernel matrix).
        let mut rng = Rng::new(43);
        let (b, l, d) = (6, 6, 2);
        let x = rng.brownian_batch(b, l, d, 0.3);
        let g = gram(&x, &x, b, b, l, l, d, &KernelOptions::default().dyadic(2, 2));
        for trial in 0..5 {
            let mut v = vec![0.0; b];
            let mut r2 = Rng::new(100 + trial);
            r2.fill_normal(&mut v);
            let mut q = 0.0;
            for i in 0..b {
                for j in 0..b {
                    q += v[i] * g[i * b + j] * v[j];
                }
            }
            assert!(q > -1e-8, "quadratic form {q}");
        }
    }

    #[test]
    fn serial_parallel_gram_agree() {
        let mut rng = Rng::new(44);
        let (b, l, d) = (4, 7, 2);
        let x = rng.brownian_batch(b, l, d, 0.4);
        let y = rng.brownian_batch(b, l, d, 0.4);
        let par = gram(&x, &y, b, b, l, l, d, &KernelOptions::default());
        let ser = gram(&x, &y, b, b, l, l, d, &KernelOptions::default().serial());
        assert!(max_abs_diff(&par, &ser) < 1e-15);
    }

    #[test]
    fn gram_vjp_matches_pairwise_sum() {
        let mut rng = Rng::new(45);
        let (bx, by, l, d) = (3, 4, 5, 2);
        let x = rng.brownian_batch(bx, l, d, 0.4);
        let y = rng.brownian_batch(by, l, d, 0.4);
        let mut w = vec![0.0; bx * by];
        rng.fill_normal(&mut w);
        let opts = KernelOptions::default();
        let (gx, gy) = gram_vjp(&x, &y, &w, bx, by, l, l, d, &opts);
        // Reference: accumulate pairwise vjps serially.
        let mut gx_ref = vec![0.0; bx * l * d];
        let mut gy_ref = vec![0.0; by * l * d];
        for i in 0..bx {
            for j in 0..by {
                let (a, b) = sig_kernel_vjp(
                    &x[i * l * d..(i + 1) * l * d],
                    &y[j * l * d..(j + 1) * l * d],
                    l,
                    l,
                    d,
                    &opts,
                    w[i * by + j],
                );
                for (o, v) in gx_ref[i * l * d..(i + 1) * l * d].iter_mut().zip(a.iter()) {
                    *o += v;
                }
                for (o, v) in gy_ref[j * l * d..(j + 1) * l * d].iter_mut().zip(b.iter()) {
                    *o += v;
                }
            }
        }
        assert!(max_abs_diff(&gx, &gx_ref) < 1e-12);
        assert!(max_abs_diff(&gy, &gy_ref) < 1e-12);
    }

    #[test]
    fn mmd_of_identical_distributions_is_small() {
        let mut rng = Rng::new(46);
        let (b, l, d) = (8, 6, 2);
        let x = rng.brownian_batch(b, l, d, 0.4);
        // identical samples: biased MMD² of x with itself is exactly 0
        let m = mmd2(&x, &x, b, b, l, l, d, &KernelOptions::default());
        assert!(m.abs() < 1e-10, "mmd²(x,x) = {m}");
    }

    #[test]
    fn mmd_separates_different_scales() {
        let mut rng = Rng::new(47);
        let (b, l, d) = (10, 8, 2);
        let x = rng.brownian_batch(b, l, d, 0.3);
        let y = rng.brownian_batch(b, l, d, 1.0);
        let same = mmd2_unbiased(
            &x,
            &rng.brownian_batch(b, l, d, 0.3),
            b,
            b,
            l,
            l,
            d,
            &KernelOptions::default(),
        );
        let diff = mmd2_unbiased(&x, &y, b, b, l, l, d, &KernelOptions::default());
        assert!(diff > same, "diff {diff} vs same {same}");
    }

    #[test]
    fn mmd_grad_matches_finite_differences() {
        let mut rng = Rng::new(48);
        let (bx, by, l, d) = (3, 3, 4, 2);
        let x = rng.brownian_batch(bx, l, d, 0.4);
        let y = rng.brownian_batch(by, l, d, 0.5);
        let opts = KernelOptions::default();
        let (_, grad) = mmd2_with_grad(&x, &y, bx, by, l, l, d, &opts);
        let eps = 1e-5;
        for idx in [0usize, 3, 7, 11, 23 % (bx * l * d)] {
            let mut xp = x.clone();
            xp[idx] += eps;
            let mut xm = x.clone();
            xm[idx] -= eps;
            let fp = mmd2(&xp, &y, bx, by, l, l, d, &opts);
            let fm = mmd2(&xm, &y, bx, by, l, l, d, &opts);
            let fd = (fp - fm) / (2.0 * eps);
            assert!(
                (fd - grad[idx]).abs() < 1e-5 * (1.0 + fd.abs()),
                "idx={idx}: fd={fd} grad={}",
                grad[idx]
            );
        }
    }
}
