//! Algorithm 4 — exact backpropagation through the signature-kernel solver
//! (paper §3.4, the novel contribution): differentiate *through the
//! discretised solver* in a single reverse traversal of the PDE grid,
//! rather than approximating the gradient with a second PDE.
//!
//! Given ∂F/∂k(1,1), one backward sweep computes the adjoint
//! d1[s,t] = ∂F/∂k̂[s,t] via
//!
//!   d1[s,t] = d1[s+1,t]·A(p_{s,t-1}) + d1[s,t+1]·A(p_{s-1,t})
//!           − d1[s+1,t+1]·B(p_{s,t}),
//!
//! and accumulates, for every *cell* (s,t) of the refined grid,
//!
//!   ∂F/∂Δ[s≫λ1, t≫λ2] += d1[s+1,t+1] ·
//!       [ (k̂[s+1,t] + k̂[s,t+1])·(½ + p/6) + k̂[s,t]·p/6 ] · 2^{−(λ1+λ2)},
//!
//! the last factor being the chain rule through the dyadic scaling p = Δ·2^{−λ}.
//! Serial complexity O(2^{λ1+λ2} L1 L2) — one grid traversal, versus
//! O(2^{λ1+λ2} L1² L2²) for naive per-entry differentiation.

use crate::kernel::delta::{delta_matrix, delta_vjp_to_paths};
use crate::kernel::solver::solve_pde_grid;
use crate::kernel::KernelOptions;

/// ∂F/∂Δ for the Goursat solver: `grad_out` = ∂F/∂k(1,1); returns the
/// `[m, n]` gradient with respect to the (unrefined) Δ matrix.
///
/// `grid` must be the forward grid from [`solve_pde_grid`] for the same
/// `(delta, m, n, lam1, lam2)`.
pub fn sig_kernel_vjp_delta(
    delta: &[f64],
    m: usize,
    n: usize,
    lam1: u32,
    lam2: u32,
    grid: &[f64],
    grad_out: f64,
) -> Vec<f64> {
    let w = (n << lam2) + 1;
    let mut d2 = vec![0.0; m * n];
    let mut d1_below = vec![0.0; w];
    let mut d1_cur = vec![0.0; w];
    sig_kernel_vjp_delta_into(
        delta,
        m,
        n,
        lam1,
        lam2,
        grid,
        grad_out,
        &mut d1_below,
        &mut d1_cur,
        &mut d2,
    );
    d2
}

/// [`sig_kernel_vjp_delta`] against caller-provided storage: `d1_below` /
/// `d1_cur` are the two live adjoint rows (resized to `cols + 1` in place)
/// and `d2` is the `[m, n]` output, zeroed here. The backward hot loops
/// (Gram rows, record replays) route through this form so the steady state
/// allocates nothing per pair.
#[allow(clippy::too_many_arguments)]
pub fn sig_kernel_vjp_delta_into(
    delta: &[f64],
    m: usize,
    n: usize,
    lam1: u32,
    lam2: u32,
    grid: &[f64],
    grad_out: f64,
    d1_below: &mut Vec<f64>,
    d1_cur: &mut Vec<f64>,
    d2: &mut [f64],
) {
    d2.fill(0.0);
    sig_kernel_vjp_delta_acc(delta, m, n, lam1, lam2, grid, grad_out, d1_below, d1_cur, d2);
}

/// Accumulating form of [`sig_kernel_vjp_delta_into`]: identical adjoint
/// sweep, but `d2` is **not** zeroed — contributions add to whatever is
/// already there. This is the primitive the `Order2` backward composes:
/// one zeroing fine sweep seeded with (4/3)·w followed by one accumulating
/// coarse sweep seeded with (−1/3)·w, in that order everywhere (scalar,
/// lanes, record replay) so all backward producers share one FP sequence.
#[allow(clippy::too_many_arguments)]
pub fn sig_kernel_vjp_delta_acc(
    delta: &[f64],
    m: usize,
    n: usize,
    lam1: u32,
    lam2: u32,
    grid: &[f64],
    grad_out: f64,
    d1_below: &mut Vec<f64>,
    d1_cur: &mut Vec<f64>,
    d2: &mut [f64],
) {
    assert_eq!(delta.len(), m * n);
    let rows = m << lam1;
    let cols = n << lam2;
    let w = cols + 1;
    assert_eq!(grid.len(), (rows + 1) * w);
    assert_eq!(d2.len(), m * n);
    let scale = 1.0 / (1u64 << (lam1 + lam2)) as f64;

    // Adjoint sweep, two live rows: d1_below = d1[s+1, ·], d1_cur = d1[s, ·].
    // (§Perf: a split vector-pass/serial-chain variant of this loop was
    // tried and reverted — ~20% slower here, same story as `solve_pde`.)
    d1_below.clear();
    d1_below.resize(w, 0.0);
    d1_cur.clear();
    d1_cur.resize(w, 0.0);
    let mut d1_below = &mut d1_below[..];
    let mut d1_cur = &mut d1_cur[..];
    // p at refined cell (s, t): cells are (0..rows) × (0..cols).
    let p_at = |s: usize, t: usize| -> f64 { delta[(s >> lam1) * n + (t >> lam2)] * scale };

    for s in (1..=rows).rev() {
        // Compute d1[s, t] for t = cols..1.
        for t in (1..=cols).rev() {
            let mut v = 0.0;
            if s == rows && t == cols {
                v = grad_out;
            } else {
                // d1[s+1, t] · A(p_{s, t-1}): node (s,t) feeds (s+1, t)
                // through cell (s, t-1).
                if s < rows {
                    let p = p_at(s, t - 1);
                    v += d1_below[t] * (1.0 + 0.5 * p + p * p / 12.0);
                }
                // d1[s, t+1] · A(p_{s-1, t})
                if t < cols {
                    let p = p_at(s - 1, t);
                    v += d1_cur[t + 1] * (1.0 + 0.5 * p + p * p / 12.0);
                }
                // − d1[s+1, t+1] · B(p_{s, t})
                if s < rows && t < cols {
                    let p = p_at(s, t);
                    v -= d1_below[t + 1] * (1.0 - p * p / 12.0);
                }
            }
            d1_cur[t] = v;
            // Accumulate ∂F/∂Δ for cell (s-1, t-1), whose output node is
            // (s, t): d1[s,t]·[(k̂[s,t-1] + k̂[s-1,t])·A'(p) − k̂[s-1,t-1]·B'(p)].
            let p = p_at(s - 1, t - 1);
            let k_l = grid[s * w + (t - 1)];
            let k_u = grid[(s - 1) * w + t];
            let k_ul = grid[(s - 1) * w + (t - 1)];
            let dk_dp = (k_l + k_u) * (0.5 + p / 6.0) + k_ul * (p / 6.0);
            d2[((s - 1) >> lam1) * n + ((t - 1) >> lam2)] += v * dk_dp * scale;
        }
        std::mem::swap(&mut d1_below, &mut d1_cur);
    }
}

/// Scheme-dispatched Δ-vjp over **retained** grids (the engine's record
/// replay): for `Order1` (or degenerate `Order2`), `grid_coarse` is unused
/// and this is [`sig_kernel_vjp_delta_into`]; for `Order2`, the fine sweep
/// is seeded with (4/3)·w and the coarse sweep — which requires
/// `grid_coarse`, the retained forward grid at the coarsened orders —
/// accumulates with (−1/3)·w. Every `Scheme` variant must stay dispatched
/// here (siglint `scheme_exhaustive`).
#[allow(clippy::too_many_arguments)]
pub fn sig_kernel_vjp_delta_scheme_into(
    delta: &[f64],
    m: usize,
    n: usize,
    lam1: u32,
    lam2: u32,
    scheme: crate::kernel::scheme::Scheme,
    grid: &[f64],
    grid_coarse: Option<&[f64]>,
    grad_out: f64,
    d1_below: &mut Vec<f64>,
    d1_cur: &mut Vec<f64>,
    d2: &mut [f64],
) {
    use crate::kernel::scheme::{coarse_orders, order2_degenerate, order2_seeds, Scheme};
    match scheme {
        Scheme::Order1 => {
            sig_kernel_vjp_delta_into(
                delta, m, n, lam1, lam2, grid, grad_out, d1_below, d1_cur, d2,
            );
        }
        Scheme::Order2 if order2_degenerate(lam1, lam2) => {
            sig_kernel_vjp_delta_into(
                delta, m, n, lam1, lam2, grid, grad_out, d1_below, d1_cur, d2,
            );
        }
        Scheme::Order2 => {
            let (sf, sc) = order2_seeds(grad_out);
            let (c1, c2) = coarse_orders(lam1, lam2);
            d2.fill(0.0);
            sig_kernel_vjp_delta_acc(delta, m, n, lam1, lam2, grid, sf, d1_below, d1_cur, d2);
            let coarse = grid_coarse.unwrap_or(&[]);
            sig_kernel_vjp_delta_acc(delta, m, n, c1, c2, coarse, sc, d1_below, d1_cur, d2);
        }
    }
}

/// Typed, fallible exact vjp of the signature kernel with respect to both
/// paths. Returns `(grad_x, grad_y)` in the paths' own `[len, dim]` layouts,
/// already chained through the path transform in `opts.exec.transform`.
/// A path with fewer than two points makes the kernel constant (1), so its
/// gradient is zero. Honours `opts.scheme`: the `Order2` backward runs the
/// fine and coarse adjoint sweeps with the Richardson seeds.
pub fn try_sig_kernel_vjp(
    x: crate::path::Path<'_>,
    y: crate::path::Path<'_>,
    opts: &KernelOptions,
    grad_out: f64,
) -> Result<(Vec<f64>, Vec<f64>), crate::path::SigError> {
    if x.dim() != y.dim() {
        return Err(crate::path::SigError::DimMismatch {
            left: x.dim(),
            right: y.dim(),
        });
    }
    let (lx, ly, dim) = (x.len(), y.len(), x.dim());
    if lx < 2 || ly < 2 {
        return Ok((vec![0.0; lx * dim], vec![0.0; ly * dim]));
    }
    // Resolve an ε-adaptive request exactly as the plan/engine paths do, so
    // the direct API and a compiled plan agree on (scheme, λ) for the same
    // inputs.
    let resolved;
    let opts = if opts.target_eps.get().is_some() {
        let xb = crate::path::PathBatch::uniform(x.data(), 1, lx, dim)?;
        let yb = crate::path::PathBatch::uniform(y.data(), 1, ly, dim)?;
        resolved = crate::kernel::scheme::resolve_target_eps(&xb, &yb, opts)?;
        &resolved
    } else {
        opts
    };
    crate::kernel::check_grid_size(lx, ly, opts)?;
    let (m, n, delta) = delta_matrix(x.data(), y.data(), lx, ly, dim, opts.exec.transform);
    let (lam1, lam2) = (opts.dyadic_x, opts.dyadic_y);
    let grid = solve_pde_grid(&delta, m, n, lam1, lam2);
    let coarse;
    let grid_coarse = if opts.scheme == crate::kernel::scheme::Scheme::Order2
        && !crate::kernel::scheme::order2_degenerate(lam1, lam2)
    {
        let (c1, c2) = crate::kernel::scheme::coarse_orders(lam1, lam2);
        coarse = solve_pde_grid(&delta, m, n, c1, c2);
        Some(coarse.as_slice())
    } else {
        None
    };
    let w = (n << lam2) + 1;
    let mut d2 = vec![0.0; m * n];
    let mut d1_below = vec![0.0; w];
    let mut d1_cur = vec![0.0; w];
    sig_kernel_vjp_delta_scheme_into(
        &delta,
        m,
        n,
        lam1,
        lam2,
        opts.scheme,
        &grid,
        grid_coarse,
        grad_out,
        &mut d1_below,
        &mut d1_cur,
        &mut d2,
    );
    let mut gx = vec![0.0; lx * dim];
    let mut gy = vec![0.0; ly * dim];
    delta_vjp_to_paths(
        &d2,
        x.data(),
        y.data(),
        lx,
        ly,
        dim,
        opts.exec.transform,
        &mut gx,
        &mut gy,
    );
    Ok((gx, gy))
}

/// Exact vjp of the signature kernel with respect to both paths (flat-slice
/// wrapper over [`try_sig_kernel_vjp`]; panics on malformed shapes).
///
/// Returns `(grad_x, grad_y)` with shapes `[lx, dim]`, `[ly, dim]`,
/// already chained through the path transform in `opts.exec.transform`.
pub fn sig_kernel_vjp(
    x: &[f64],
    y: &[f64],
    lx: usize,
    ly: usize,
    dim: usize,
    opts: &KernelOptions,
    grad_out: f64,
) -> (Vec<f64>, Vec<f64>) {
    let xp = crate::path::Path::new(x, lx, dim).expect("sig_kernel_vjp: invalid x shape");
    let yp = crate::path::Path::new(y, ly, dim).expect("sig_kernel_vjp: invalid y shape");
    try_sig_kernel_vjp(xp, yp, opts, grad_out).expect("sig_kernel_vjp")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::sig_kernel;
    use crate::transforms::Transform;
    use crate::util::prop::check;

    #[test]
    fn vjp_delta_matches_finite_differences() {
        check("kernel ∂/∂Δ vs finite differences", 12, |g| {
            let m = g.usize_in(1, 6);
            let n = g.usize_in(1, 6);
            let lam1 = g.usize_in(0, 2) as u32;
            let lam2 = g.usize_in(0, 2) as u32;
            let delta: Vec<f64> = g.normal_vec(m * n).iter().map(|v| v * 0.4).collect();
            let grid = solve_pde_grid(&delta, m, n, lam1, lam2);
            let gout = g.f64_in(0.5, 2.0);
            let d2 = sig_kernel_vjp_delta(&delta, m, n, lam1, lam2, &grid, gout);
            let eps = 1e-6;
            for idx in 0..m * n {
                let mut dp = delta.clone();
                dp[idx] += eps;
                let mut dm = delta.clone();
                dm[idx] -= eps;
                let fp = crate::kernel::solve_pde(&dp, m, n, lam1, lam2);
                let fm = crate::kernel::solve_pde(&dm, m, n, lam1, lam2);
                let fd = gout * (fp - fm) / (2.0 * eps);
                assert!(
                    (fd - d2[idx]).abs() < 1e-5 * (1.0 + fd.abs()),
                    "m={m} n={n} λ=({lam1},{lam2}) idx={idx}: fd={fd} vjp={}",
                    d2[idx]
                );
            }
        });
    }

    #[test]
    fn vjp_paths_matches_finite_differences() {
        check("kernel path vjp vs finite differences", 8, |g| {
            let lx = g.usize_in(2, 5);
            let ly = g.usize_in(2, 5);
            let d = g.usize_in(1, 3);
            let x = g.path(lx, d, 0.5);
            let y = g.path(ly, d, 0.5);
            for tr in [Transform::None, Transform::TimeAug, Transform::LeadLag] {
                let opts = KernelOptions::default().dyadic(1, 1).transform(tr);
                let (gx, gy) = sig_kernel_vjp(&x, &y, lx, ly, d, &opts, 1.0);
                let eps = 1e-6;
                for i in 0..lx * d {
                    let mut xp = x.to_vec();
                    xp[i] += eps;
                    let mut xm = x.to_vec();
                    xm[i] -= eps;
                    let fd = (sig_kernel(&xp, &y, lx, ly, d, &opts)
                        - sig_kernel(&xm, &y, lx, ly, d, &opts))
                        / (2.0 * eps);
                    assert!(
                        (fd - gx[i]).abs() < 1e-4 * (1.0 + fd.abs()),
                        "tr={tr:?} x[{i}]: fd={fd} vjp={}",
                        gx[i]
                    );
                }
                for j in 0..ly * d {
                    let mut yp = y.to_vec();
                    yp[j] += eps;
                    let mut ym = y.to_vec();
                    ym[j] -= eps;
                    let fd = (sig_kernel(&x, &yp, lx, ly, d, &opts)
                        - sig_kernel(&x, &ym, lx, ly, d, &opts))
                        / (2.0 * eps);
                    assert!(
                        (fd - gy[j]).abs() < 1e-4 * (1.0 + fd.abs()),
                        "tr={tr:?} y[{j}]: fd={fd} vjp={}",
                        gy[j]
                    );
                }
            }
        });
    }

    #[test]
    fn grad_scales_linearly_with_cotangent() {
        let mut rng = crate::util::rng::Rng::new(21);
        let x = rng.brownian_path(5, 2, 0.5);
        let y = rng.brownian_path(6, 2, 0.5);
        let opts = KernelOptions::default();
        let (g1, _) = sig_kernel_vjp(&x, &y, 5, 6, 2, &opts, 1.0);
        let (g3, _) = sig_kernel_vjp(&x, &y, 5, 6, 2, &opts, 3.0);
        for i in 0..g1.len() {
            assert!((3.0 * g1[i] - g3[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn symmetric_inputs_give_symmetric_grads() {
        // k(x,y) = k(y,x) ⇒ ∇_x k(x,y) == ∇_y' k(y,x) with roles swapped.
        let mut rng = crate::util::rng::Rng::new(22);
        let x = rng.brownian_path(5, 2, 0.5);
        let y = rng.brownian_path(7, 2, 0.5);
        let opts = KernelOptions::default().dyadic(1, 0);
        let opts_swap = KernelOptions::default().dyadic(0, 1);
        let (gx, gy) = sig_kernel_vjp(&x, &y, 5, 7, 2, &opts, 1.0);
        let (gy2, gx2) = sig_kernel_vjp(&y, &x, 7, 5, 2, &opts_swap, 1.0);
        assert!(crate::util::linalg::max_abs_diff(&gx, &gx2) < 1e-10);
        assert!(crate::util::linalg::max_abs_diff(&gy, &gy2) < 1e-10);
    }
}
