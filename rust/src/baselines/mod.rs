//! Reimplementations of the comparator libraries' *algorithmic choices*, used
//! by the benchmark harness to reproduce the paper's tables. The speedups
//! pySigLib reports are algorithmic (memory layout, Horner factorisation,
//! on-the-fly refinement, exact vjp), so faithful reimplementations of the
//! baselines' strategies isolate exactly those effects:
//!
//! * [`naive_signature`] — esig-style: out-of-place tensor products with
//!   fresh allocations every step, no in-place update ordering.
//! * `sig::direct` (Algorithm 1) — iisignature-style direct updates.
//! * [`full_grid_kernel`] — sigkernel-style: *materialises* the dyadically
//!   refined Δ and keeps the whole PDE grid allocated; fails (like the real
//!   package, a dash in Table 2) when the grid exceeds a memory budget.
//! * [`gpu_style_kernel`] — sigkernel's GPU scheme assigns one thread per
//!   anti-diagonal entry, so streams longer than the 1024-thread block are
//!   refused; reproduced structurally here.
//! * [`iisig_backward`] — iisignature recomputes the signature during the
//!   backward pass (the asterisk in Table 1); modeled by a forward
//!   recomputation followed by the standard vjp.

use crate::tensor::{exp_increment, tensor_prod, LevelLayout};
use crate::transforms::Transform;

/// Errors mirroring the comparator packages' failure modes (the dashes in
/// the paper's Table 2).
#[derive(Debug, PartialEq, Eq)]
pub enum BaselineError {
    GridTooLarge(usize),
    ThreadLimit(usize),
}

impl std::fmt::Display for BaselineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BaselineError::GridTooLarge(n) => {
                write!(f, "PDE grid of {n} nodes exceeds the full-grid memory budget")
            }
            BaselineError::ThreadLimit(n) => {
                write!(f, "anti-diagonal of {n} entries exceeds the 1024-thread GPU block")
            }
        }
    }
}

impl std::error::Error for BaselineError {}

/// esig-style truncated signature: mathematically identical to
/// `sig::signature`, but with the naive memory strategy — a full out-of-place
/// truncated tensor product (and two fresh allocations) per path step.
pub fn naive_signature(path: &[f64], len: usize, dim: usize, depth: usize) -> Vec<f64> {
    assert!(len >= 1 && depth >= 1);
    let layout = LevelLayout::new(dim, depth);
    if len < 2 {
        let mut a = vec![0.0; layout.total()];
        a[0] = 1.0;
        return a;
    }
    let mut z = vec![0.0; dim];
    for j in 0..dim {
        z[j] = path[dim + j] - path[j];
    }
    let mut acc = vec![0.0; layout.total()];
    exp_increment(&layout, &z, &mut acc);
    for i in 1..len - 1 {
        for j in 0..dim {
            z[j] = path[(i + 1) * dim + j] - path[i * dim + j];
        }
        // Naive: materialise exp(z), multiply out-of-place, replace.
        let mut e = vec![0.0; layout.total()];
        exp_increment(&layout, &z, &mut e);
        let mut next = vec![0.0; layout.total()];
        tensor_prod(&layout, &acc, &e, &mut next);
        acc = next;
    }
    acc
}

/// Memory budget for the full-grid baseline, in grid nodes (f64s). Matches
/// the order of magnitude at which `sigkernel`'s CPU path starts failing on
/// a 32 GB machine in the paper's Table 2 (dash at B=128, L=1024, λ=0 once
/// the batch is accounted for: 128 · 1025² ≈ 1.3e8 nodes · 8 B ≈ 1 GB per
/// stored tensor, with autograd copies pushing past RAM).
pub const FULL_GRID_NODE_BUDGET: usize = 1 << 27;

/// sigkernel-style CPU kernel: precompute the *refined* Δ (2^{λ1+λ2}·m·n
/// entries — pySigLib's on-the-fly indexing avoids this) and keep the whole
/// PDE grid resident. Returns the kernel value, or the failure the real
/// package would hit.
pub fn full_grid_kernel(
    delta: &[f64],
    m: usize,
    n: usize,
    lam1: u32,
    lam2: u32,
) -> Result<f64, BaselineError> {
    let rows = m << lam1;
    let cols = n << lam2;
    let nodes = (rows + 1) * (cols + 1);
    if nodes > FULL_GRID_NODE_BUDGET {
        return Err(BaselineError::GridTooLarge(nodes));
    }
    // Materialise the refined Δ — the allocation pySigLib skips.
    let scale = 1.0 / (1u64 << (lam1 + lam2)) as f64;
    let mut refined = vec![0.0; rows * cols];
    for s in 0..rows {
        for t in 0..cols {
            refined[s * cols + t] = delta[(s >> lam1) * n + (t >> lam2)] * scale;
        }
    }
    // Full-grid solve.
    let w = cols + 1;
    let mut k = vec![1.0; (rows + 1) * w];
    for s in 0..rows {
        for t in 0..cols {
            let p = refined[s * cols + t];
            let p2 = p * p / 12.0;
            let a = 1.0 + 0.5 * p + p2;
            let b = 1.0 - p2;
            k[(s + 1) * w + t + 1] = (k[(s + 1) * w + t] + k[s * w + t + 1]) * a - k[s * w + t] * b;
        }
    }
    Ok(k[(rows + 1) * w - 1])
}

/// Thread budget of one CUDA block in the comparator's GPU scheme.
pub const GPU_THREAD_LIMIT: usize = 1024;

/// sigkernel-style GPU kernel: one thread per anti-diagonal entry, so the
/// computation is refused outright when the diagonal exceeds the block's
/// 1024 threads (the paper's Table 2 dash at L = 1024 with λ = 0 ⇒ diagonal
/// 1024 ≥ limit once boundaries are counted). pySigLib's block-of-32 scheme
/// (see [`crate::kernel::blocked`]) removes the limit.
pub fn gpu_style_kernel(
    delta: &[f64],
    m: usize,
    n: usize,
    lam1: u32,
    lam2: u32,
) -> Result<f64, BaselineError> {
    let rows = m << lam1;
    let cols = n << lam2;
    let diag = rows.min(cols) + 1;
    if diag > GPU_THREAD_LIMIT {
        return Err(BaselineError::ThreadLimit(diag));
    }
    Ok(crate::kernel::solver::solve_pde(delta, m, n, lam1, lam2))
}

/// iisignature-style backward pass: the package recomputes the signature
/// during the backward pass (Table 1's asterisk), so its cost is forward +
/// vjp. Functionally identical gradients.
pub fn iisig_backward(
    path: &[f64],
    len: usize,
    dim: usize,
    depth: usize,
    grad_sig: &[f64],
) -> Vec<f64> {
    // Forced recomputation of the forward signature...
    let s = crate::sig::signature(
        path,
        len,
        dim,
        depth,
        Transform::None,
        crate::sig::SigMethod::Direct,
    );
    // ...then the standard deconstruction-based vjp.
    crate::sig::backward::signature_vjp_with_sig(
        path,
        len,
        dim,
        depth,
        Transform::None,
        &s,
        grad_sig,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::linalg::max_abs_diff;
    use crate::util::prop::check;

    #[test]
    fn naive_matches_horner() {
        check("naive == horner signature", 20, |g| {
            let len = g.usize_in(2, 12);
            let dim = g.usize_in(1, 3);
            let depth = g.usize_in(1, 4);
            let p = g.path(len, dim, 0.5);
            let a = naive_signature(&p, len, dim, depth);
            let b = crate::sig::sig(&p, len, dim, depth);
            assert!(max_abs_diff(&a, &b) < 1e-10);
        });
    }

    #[test]
    fn full_grid_matches_streaming_solver() {
        check("full grid == two-row solver", 20, |g| {
            let m = g.usize_in(1, 10);
            let n = g.usize_in(1, 10);
            let lam = g.usize_in(0, 2) as u32;
            let delta: Vec<f64> = g.normal_vec(m * n).iter().map(|v| v * 0.3).collect();
            let a = full_grid_kernel(&delta, m, n, lam, lam).unwrap();
            let b = crate::kernel::solve_pde(&delta, m, n, lam, lam);
            assert!((a - b).abs() < 1e-12);
        });
    }

    #[test]
    fn full_grid_fails_above_budget() {
        // 2^14 × 2^14 nodes > 2^27: must refuse, like the real package OOMs.
        let delta = vec![0.0; 1];
        let r = full_grid_kernel(&delta, 1, 1, 14, 14);
        assert!(matches!(r, Err(BaselineError::GridTooLarge(_))));
    }

    #[test]
    fn gpu_style_fails_beyond_thread_limit() {
        let m = 1100;
        let delta = vec![0.01; m * m];
        let r = gpu_style_kernel(&delta, m, m, 0, 0);
        assert!(matches!(r, Err(BaselineError::ThreadLimit(_))));
        // pySigLib's blocked scheme handles the same input fine.
        let k = crate::kernel::solve_pde_blocked(&delta, m, m, 0, 0);
        assert!(k.is_finite());
    }

    #[test]
    fn iisig_backward_matches_pysiglib_backward() {
        let mut rng = crate::util::rng::Rng::new(55);
        let p = rng.brownian_path(7, 2, 0.5);
        let slen = crate::sig::sig_length(2, 3);
        let mut gs = vec![0.0; slen];
        rng.fill_normal(&mut gs);
        let a = iisig_backward(&p, 7, 2, 3, &gs);
        let b = crate::sig::signature_vjp(&p, 7, 2, 3, Transform::None, &gs);
        assert!(max_abs_diff(&a, &b) < 1e-12);
    }
}
