//! Benchmark harness (the offline stand-in for criterion), following the
//! paper's measurement protocol: *minimum* wall-clock over R runs after a
//! warmup (§5: "the minimum runtime is taken over 50 runs").
//!
//! Rows print aligned for terminal reading and are persisted twice on drop:
//! as CSV (`bench_results/<suite>.csv`, the historical format) and as
//! machine-readable JSON (`bench_results/BENCH_<suite>.json` with min and
//! median seconds, run counts and the git revision) so the perf trajectory
//! can be tracked across PRs.

use std::io::Write;
use std::time::Instant;

/// Number of timed runs (the paper uses 50; override with PYSIGLIB_BENCH_RUNS
/// to trade precision for wall-clock when sweeping large shapes).
pub fn bench_runs(default: usize) -> usize {
    // siglint: allow(env_discipline) -- bench-harness knob read at suite start, not serving configuration
    std::env::var("PYSIGLIB_BENCH_RUNS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// One timed case: name plus the aggregate of its runs.
struct Row {
    case: String,
    min: f64,
    median: f64,
    runs: usize,
}

/// A benchmark suite: prints a header, times closures, writes CSV + JSON.
pub struct Suite {
    name: String,
    rows: Vec<Row>,
}

impl Suite {
    pub fn new(name: &str) -> Suite {
        println!("\n== {name} ==");
        println!("{:<56} {:>12}", "case", "min time (s)");
        Suite {
            name: name.to_string(),
            rows: Vec::new(),
        }
    }

    /// Minimum time over `runs` of `f` (after one warmup), recorded+printed;
    /// the median is kept alongside for the JSON trajectory. Set
    /// PYSIGLIB_BENCH_NOWARMUP=1 to skip the warmup execution (useful when a
    /// full-suite capture must fit a wall-clock budget).
    pub fn time<F: FnMut()>(&mut self, case: &str, runs: usize, mut f: F) -> f64 {
        // siglint: allow(env_discipline) -- bench-harness knob, not serving configuration
        if std::env::var("PYSIGLIB_BENCH_NOWARMUP").as_deref() != Ok("1") {
            f(); // warmup
        }
        let runs = runs.max(1);
        let mut samples = Vec::with_capacity(runs);
        for _ in 0..runs {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_secs_f64());
        }
        samples.sort_by(|a, b| a.partial_cmp(b).expect("timings are finite"));
        let best = samples[0];
        let median = if samples.len() % 2 == 1 {
            samples[samples.len() / 2]
        } else {
            0.5 * (samples[samples.len() / 2 - 1] + samples[samples.len() / 2])
        };
        println!("{case:<56} {best:>12.6}");
        self.rows.push(Row {
            case: case.to_string(),
            min: best,
            median,
            runs,
        });
        best
    }

    /// Record a precomputed timing (e.g. a failure marker uses NaN).
    pub fn record(&mut self, case: &str, secs: f64) {
        if secs.is_nan() {
            println!("{case:<56} {:>12}", "-");
        } else {
            println!("{case:<56} {secs:>12.6}");
        }
        self.rows.push(Row {
            case: case.to_string(),
            min: secs,
            median: secs,
            runs: 0,
        });
    }

    /// Look up a recorded row's min time (for derived ratios).
    pub fn get(&self, case: &str) -> Option<f64> {
        self.rows.iter().find(|r| r.case == case).map(|r| r.min)
    }

    /// Look up a recorded row's median time (for derived ratios gated on
    /// medians, e.g. the lane-over-scalar speedup floors).
    pub fn get_median(&self, case: &str) -> Option<f64> {
        self.rows.iter().find(|r| r.case == case).map(|r| r.median)
    }

    /// Drop the recorded rows without persisting (used by self-tests).
    pub fn discard(&mut self) {
        self.rows.clear();
    }

    /// The JSON document written on drop (public for testing).
    pub fn json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"suite\": \"{}\",\n", json_escape(&self.name)));
        s.push_str(&format!("  \"git_rev\": \"{}\",\n", json_escape(&git_rev())));
        s.push_str("  \"cases\": [\n");
        for (i, r) in self.rows.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"case\": \"{}\", \"min_seconds\": {}, \"median_seconds\": {}, \"runs\": {}}}{}\n",
                json_escape(&r.case),
                json_num(r.min),
                json_num(r.median),
                r.runs,
                if i + 1 < self.rows.len() { "," } else { "" }
            ));
        }
        s.push_str("  ]\n}\n");
        s
    }
}

/// JSON has no NaN/Inf: failure markers become null.
fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Current git revision (short), or "unknown" outside a work tree.
fn git_rev() -> String {
    std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

impl Drop for Suite {
    fn drop(&mut self) {
        if self.rows.is_empty() {
            return;
        }
        let dir = std::path::Path::new("bench_results");
        if std::fs::create_dir_all(dir).is_err() {
            return;
        }
        let csv = dir.join(format!("{}.csv", self.name));
        if let Ok(mut f) = std::fs::File::create(&csv) {
            let _ = writeln!(f, "case,min_seconds");
            for r in &self.rows {
                let _ = writeln!(f, "{},{}", r.case, r.min);
            }
            println!("[wrote {}]", csv.display());
        }
        let json = dir.join(format!("BENCH_{}.json", self.name));
        if let Ok(mut f) = std::fs::File::create(&json) {
            let _ = f.write_all(self.json().as_bytes());
            println!("[wrote {}]", json.display());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_records_and_queries() {
        let mut s = Suite::new("selftest");
        let t = s.time("noop", 3, || {});
        assert!(t >= 0.0);
        s.record("marker", f64::NAN);
        assert!(s.get("noop").is_some());
        assert!(s.get("missing").is_none());
        // prevent the CSV/JSON drop from polluting the repo during tests
        s.discard();
    }

    #[test]
    fn runs_override_respects_default() {
        assert!(bench_runs(7) >= 1);
    }

    #[test]
    fn json_document_is_well_formed() {
        let mut s = Suite::new("jsontest");
        s.time("a \"quoted\" case", 2, || {});
        s.record("failed", f64::NAN);
        let j = s.json();
        assert!(j.contains("\"suite\": \"jsontest\""));
        assert!(j.contains("\"git_rev\": \""));
        assert!(j.contains("a \\\"quoted\\\" case"));
        assert!(j.contains("\"min_seconds\": null"));
        assert!(j.contains("\"runs\": 2"));
        // Balanced braces/brackets as a cheap structural check.
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
        s.discard();
    }

    #[test]
    fn median_is_between_min_and_max() {
        let mut s = Suite::new("medtest");
        s.time("spin", 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        let r = &s.rows[0];
        assert!(r.median >= r.min);
        assert_eq!(r.runs, 5);
        s.discard();
    }
}
