//! Benchmark harness (the offline stand-in for criterion), following the
//! paper's measurement protocol: *minimum* wall-clock over R runs after a
//! warmup (§5: "the minimum runtime is taken over 50 runs").
//!
//! Rows print aligned for terminal reading and are also appended as CSV to
//! `bench_results/<suite>.csv` so EXPERIMENTS.md can quote exact numbers.

use std::io::Write;
use std::time::Instant;

/// Number of timed runs (the paper uses 50; override with PYSIGLIB_BENCH_RUNS
/// to trade precision for wall-clock when sweeping large shapes).
pub fn bench_runs(default: usize) -> usize {
    std::env::var("PYSIGLIB_BENCH_RUNS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// A benchmark suite: prints a header, times closures, writes CSV.
pub struct Suite {
    name: String,
    rows: Vec<(String, f64)>,
}

impl Suite {
    pub fn new(name: &str) -> Suite {
        println!("\n== {name} ==");
        println!("{:<56} {:>12}", "case", "min time (s)");
        Suite {
            name: name.to_string(),
            rows: Vec::new(),
        }
    }

    /// Minimum time over `runs` of `f` (after one warmup), recorded+printed.
    /// Set PYSIGLIB_BENCH_NOWARMUP=1 to skip the warmup execution (useful
    /// when a full-suite capture must fit a wall-clock budget).
    pub fn time<F: FnMut()>(&mut self, case: &str, runs: usize, mut f: F) -> f64 {
        if std::env::var("PYSIGLIB_BENCH_NOWARMUP").as_deref() != Ok("1") {
            f(); // warmup
        }
        let mut best = f64::INFINITY;
        for _ in 0..runs.max(1) {
            let t = Instant::now();
            f();
            best = best.min(t.elapsed().as_secs_f64());
        }
        println!("{case:<56} {best:>12.6}");
        self.rows.push((case.to_string(), best));
        best
    }

    /// Record a precomputed timing (e.g. a failure marker uses NaN).
    pub fn record(&mut self, case: &str, secs: f64) {
        if secs.is_nan() {
            println!("{case:<56} {:>12}", "-");
        } else {
            println!("{case:<56} {secs:>12.6}");
        }
        self.rows.push((case.to_string(), secs));
    }

    /// Look up a recorded row (for derived ratios).
    pub fn get(&self, case: &str) -> Option<f64> {
        self.rows
            .iter()
            .find(|(c, _)| c == case)
            .map(|(_, t)| *t)
    }
}

impl Drop for Suite {
    fn drop(&mut self) {
        if self.rows.is_empty() {
            return;
        }
        let dir = std::path::Path::new("bench_results");
        if std::fs::create_dir_all(dir).is_err() {
            return;
        }
        let path = dir.join(format!("{}.csv", self.name));
        if let Ok(mut f) = std::fs::File::create(&path) {
            let _ = writeln!(f, "case,min_seconds");
            for (case, secs) in &self.rows {
                let _ = writeln!(f, "{case},{secs}");
            }
            println!("[wrote {}]", path.display());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_records_and_queries() {
        let mut s = Suite::new("selftest");
        let t = s.time("noop", 3, || {});
        assert!(t >= 0.0);
        s.record("marker", f64::NAN);
        assert!(s.get("noop").is_some());
        assert!(s.get("missing").is_none());
        // prevent the CSV drop from polluting the repo during tests
        s.rows.clear();
    }

    #[test]
    fn runs_override_respects_default() {
        assert!(bench_runs(7) >= 1);
    }
}
